"""L2 train-step sanity: shapes, finiteness, learning signal, and the
fp16_naive failure mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    b, o, a = cfg["batch"], cfg["obs_dim"], cfg["act_dim"]
    f = np.float32
    return (
        rng.standard_normal((b, o)).astype(f),
        rng.uniform(-1, 1, (b, a)).astype(f),
        rng.uniform(0, 1, b).astype(f),
        rng.standard_normal((b, o)).astype(f),
        np.ones(b, f),
        rng.standard_normal((b, a)).astype(f),
        rng.standard_normal((b, a)).astype(f),
    )


@pytest.mark.parametrize("variant", ["fp32", "fp16_ours"])
def test_train_step_runs_and_updates(variant):
    cfg = model.default_cfg(obs_dim=3, act_dim=1, hidden=16, batch=8, variant=variant)
    state = model.init_state(0, cfg)
    step = jax.jit(model.make_train_step(cfg))
    batch = make_batch(cfg)
    s1, metrics = step(state, *batch)
    m = np.asarray(metrics)
    assert np.all(np.isfinite(m)), f"metrics {m}"
    assert float(s1["t"][0]) == 1.0
    # params moved
    w0 = np.asarray(jax.tree.leaves(state["params"]["actor"])[0])
    w1 = np.asarray(jax.tree.leaves(s1["params"]["actor"])[0])
    assert not np.array_equal(w0, w1)
    # a second step composes
    s2, m2 = step(s1, *make_batch(cfg, 1))
    assert np.all(np.isfinite(np.asarray(m2)))
    assert float(s2["t"][0]) == 2.0


def test_fp16_ours_state_stays_f16_representable():
    cfg = model.default_cfg(hidden=16, batch=8, variant="fp16_ours")
    state = model.init_state(0, cfg)
    step = jax.jit(model.make_train_step(cfg))
    for i in range(3):
        state, _ = step(state, *make_batch(cfg, i))
    for leaf in jax.tree.leaves(state["params"]):
        x = np.asarray(leaf)
        np.testing.assert_array_equal(x, x.astype(np.float16).astype(np.float32))


def test_critic_loss_decreases_on_fixed_batch():
    cfg = model.default_cfg(hidden=32, batch=16, variant="fp32")
    cfg["lr"] = 1e-3
    state = model.init_state(0, cfg)
    step = jax.jit(model.make_train_step(cfg))
    batch = make_batch(cfg, 3)
    losses = []
    for _ in range(60):
        state, m = step(state, *batch)
        losses.append(float(np.asarray(m)[0]))
    assert losses[-1] < losses[0], f"{losses[0]} -> {losses[-1]}"


def test_act_fn_bounded_actions():
    cfg = model.default_cfg(hidden=16, batch=8, variant="fp16_ours")
    state = model.init_state(0, cfg)
    act = jax.jit(model.make_act(cfg))
    obs = np.zeros((1, cfg["obs_dim"]), np.float32)
    eps = np.ones((1, cfg["act_dim"]), np.float32)
    a = np.asarray(act(state["params"]["actor"], obs, eps))
    assert a.shape == (1, cfg["act_dim"])
    assert np.all(np.abs(a) <= 1.0)


def test_fp32_and_fp16_ours_agree_initially():
    """One step from the same init: fp16+ours should track fp32 closely
    (the whole point of the paper)."""
    cfg32 = model.default_cfg(hidden=16, batch=8, variant="fp32")
    cfg16 = model.default_cfg(hidden=16, batch=8, variant="fp16_ours")
    s32 = model.init_state(0, cfg32)
    s16 = model.init_state(0, cfg16)
    batch = make_batch(cfg32, 5)
    _, m32 = jax.jit(model.make_train_step(cfg32))(s32, *batch)
    _, m16 = jax.jit(model.make_train_step(cfg16))(s16, *batch)
    m32, m16 = np.asarray(m32), np.asarray(m16)
    # critic loss and q-values in the same ballpark
    assert abs(m32[0] - m16[0]) < 0.1 * (1 + abs(m32[0])), f"{m32} vs {m16}"
