"""tanh-Gaussian log-prob kernel vs the f64 oracle; failure-mode checks
for the unfixed variants in fp16."""

import numpy as np

from compile.kernels.logprob import tanh_gaussian
from compile.kernels.ref import tanh_gaussian_ref


def rand_head(b, a, seed, mu_scale=1.0, ls_center=-1.0):
    rng = np.random.default_rng(seed)
    mu = (rng.standard_normal((b, a)) * mu_scale).astype(np.float32)
    ls = (ls_center + rng.standard_normal((b, a)) * 0.3).astype(np.float32)
    eps = rng.standard_normal((b, a)).astype(np.float32)
    return mu, ls, eps


def test_matches_oracle_f32():
    mu, ls, eps = rand_head(64, 4, 1)
    a, lp = tanh_gaussian(mu, ls, eps)
    a_ref, lp_ref = tanh_gaussian_ref(mu, ls, eps)
    np.testing.assert_allclose(np.asarray(a), a_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(lp), lp_ref, rtol=1e-4, atol=1e-4)


def test_fix_and_nofix_agree_in_f32():
    """Statement 1: the rewrites are identities in high precision."""
    mu, ls, eps = rand_head(32, 3, 2)
    _, lp_fix = tanh_gaussian(mu, ls, eps, softplus_fix=True, normal_fix=True)
    _, lp_raw = tanh_gaussian(mu, ls, eps, softplus_fix=False, normal_fix=False)
    np.testing.assert_allclose(np.asarray(lp_fix), np.asarray(lp_raw),
                               rtol=1e-4, atol=1e-4)


def test_softplus_overflow_without_fix_fp16():
    """u << 0 -> exp(-2u) overflows fp16 without the fix."""
    mu = np.full((1, 1), -8.0, np.float16)
    ls = np.full((1, 1), -3.0, np.float16)
    eps = np.zeros((1, 1), np.float16)
    _, lp_raw = tanh_gaussian(mu, ls, eps, softplus_fix=False, normal_fix=True)
    assert not np.isfinite(np.asarray(lp_raw))[0, 0]
    _, lp_fix = tanh_gaussian(mu, ls, eps, softplus_fix=True, normal_fix=True)
    assert np.isfinite(np.asarray(lp_fix))[0, 0]


def test_normal_underflow_without_fix_fp16():
    """sigma ~= e^-10: sigma^2 underflows fp16; the ratio form survives."""
    mu = np.full((1, 1), 0.3, np.float16)
    ls = np.full((1, 1), -10.0, np.float16)
    eps = np.full((1, 1), 1.5, np.float16)
    _, lp_raw = tanh_gaussian(mu, ls, eps, softplus_fix=True, normal_fix=False)
    assert not np.isfinite(np.asarray(lp_raw))[0, 0]
    _, lp_fix = tanh_gaussian(mu, ls, eps, softplus_fix=True, normal_fix=True)
    assert np.isfinite(np.asarray(lp_fix))[0, 0]


def test_actions_bounded():
    mu, ls, eps = rand_head(128, 6, 3, mu_scale=5.0)
    a, _ = tanh_gaussian(mu.astype(np.float16), ls.astype(np.float16),
                         eps.astype(np.float16))
    a = np.asarray(a)
    assert np.all(a >= -1.0) and np.all(a <= 1.0)
