"""Pallas quantize kernel vs the f64 oracle and vs IEEE f16 semantics."""

import numpy as np
import pytest

from compile.kernels.quantize import quantize
from compile.kernels.ref import quantize_ref


def rand(n, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(np.float32)


@pytest.mark.parametrize("exp,man", [(5, 10), (8, 7), (5, 7), (5, 5), (4, 3)])
def test_matches_oracle(exp, man):
    x = np.concatenate([
        rand(512, 1), rand(512, 2, 1e-4), rand(512, 3, 1e4),
        np.asarray([0.0, -0.0, 1.0, -1.0, 65504.0, 65520.0, 1e-8], np.float32),
    ])
    got = np.asarray(quantize(x, exp, man))
    want = quantize_ref(x, exp, man)
    np.testing.assert_array_equal(got, want)


def test_fp16_matches_numpy_half():
    """(5, 10) must agree with IEEE binary16 everywhere finite."""
    rng = np.random.default_rng(7)
    x = np.concatenate([
        (rng.standard_normal(4096) * 10 ** rng.uniform(-8, 5, 4096)).astype(np.float32),
        np.asarray([6.1e-5, 5.96e-8, 2.98e-8, 2.99e-8, 65519.0, 65520.0], np.float32),
    ])
    got = np.asarray(quantize(x, 5, 10))
    want = x.astype(np.float16).astype(np.float32)
    np.testing.assert_array_equal(got, want)


def test_preserves_specials():
    x = np.asarray([np.inf, -np.inf, 0.0, -0.0], np.float32)
    got = np.asarray(quantize(x, 5, 10))
    np.testing.assert_array_equal(got, x)
    assert np.signbit(got[3])
    assert np.isnan(quantize(np.asarray([np.nan], np.float32), 5, 10))[0]


def test_underflow_to_zero():
    x = np.asarray([1e-9, -1e-9], np.float32)
    got = np.asarray(quantize(x, 5, 10))
    np.testing.assert_array_equal(got, np.asarray([0.0, -0.0], np.float32))


def test_fewer_bits_coarser():
    x = rand(1000, 9)
    prev_err = 0.0
    for man in (10, 7, 5, 3):
        q = np.asarray(quantize(x, 5, man))
        err = float(np.mean(np.abs(q - x)))
        assert err >= prev_err
        prev_err = err


def test_shape_preserved_2d():
    x = rand(600, 11).reshape(20, 30)
    q = np.asarray(quantize(x, 5, 7))
    assert q.shape == (20, 30)
