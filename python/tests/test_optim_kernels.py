"""hAdam and Kahan-EMA Pallas kernels vs the f64 oracles, plus the
paper's Statement-1 equivalences."""

import numpy as np

from compile.kernels.hadam import hadam_update
from compile.kernels.kahan import kahan_ema_update
from compile.kernels.ref import adam_ref, hadam_ref, kahan_ema_ref


def test_hadam_matches_oracle_f32():
    rng = np.random.default_rng(1)
    n = 300
    p = rng.standard_normal(n).astype(np.float32)
    m = np.zeros(n, np.float32)
    w = np.zeros(n, np.float32)
    c = np.zeros(n, np.float32)
    g = (rng.standard_normal(n) * 0.01).astype(np.float32)
    t = np.asarray([1], np.int32)
    got = hadam_update(p, m, w, c, g, t, lr=1e-3)
    want = hadam_ref(p, m, w, c, g, 1, lr=1e-3, dtype=np.float64)
    for gv, wv in zip(got, want):
        np.testing.assert_allclose(np.asarray(gv), wv, rtol=2e-5, atol=1e-7)


def test_hadam_equals_adam_in_high_precision():
    """Statement 1: hAdam == Adam when nothing under/overflows."""
    rng = np.random.default_rng(2)
    n = 64
    p = rng.standard_normal(n).astype(np.float32)
    m = np.zeros(n, np.float32)
    wh = np.zeros(n, np.float32)
    c = np.zeros(n, np.float32)
    v = np.zeros(n, np.float64)
    pa = p.astype(np.float64)
    ma = np.zeros(n, np.float64)
    ph, mh = p.copy(), m.copy()
    for t in range(1, 30):
        g = (rng.standard_normal(n) * 0.1).astype(np.float32)
        ph, mh, wh, c = (np.asarray(x) for x in hadam_update(
            ph, mh, wh, c, g, np.asarray([t], np.int32), lr=1e-2, kahan=True))
        pa, ma, v = adam_ref(pa, ma, v, g, t, lr=1e-2)
    np.testing.assert_allclose(ph, pa, rtol=1e-3, atol=1e-5)


def test_hadam_fp16_survives_tiny_gradients():
    """g = 1e-5: g**2 underflows fp16 (naive Adam stalls/NaNs) but the
    hypot-form w tracks it."""
    n = 8
    p = np.ones(n, np.float16)
    m = np.zeros(n, np.float16)
    w = np.zeros(n, np.float16)
    c = np.zeros(n, np.float16)
    for t in range(1, 20):
        g = np.full(n, 1e-2, np.float16)  # representable, g^2 = 1e-4 ok
        p, m, w, c = hadam_update(p, m, w, c, g, np.asarray([t], np.int32),
                                  lr=1e-3, gamma=1.0)
    p = np.asarray(p)
    assert np.all(np.isfinite(p))
    assert np.all(p < 1.0), "must make progress"
    # and with truly tiny grads, w stays alive thanks to hypot
    w2 = np.zeros(n, np.float16)
    g = np.full(n, 1e-5, np.float16)
    _, _, w2, _ = hadam_update(np.ones(n, np.float16), np.zeros(n, np.float16),
                               w2, np.zeros(n, np.float16), g,
                               np.asarray([1], np.int32), lr=1e-3)
    assert np.all(np.asarray(w2) > 0), "hypot second moment must not underflow"
    assert np.float16(1e-5) ** 2 == 0, "sanity: naive v would underflow"


def test_compound_scaling_invariance_f32():
    """gamma-scaled grads + gamma*eps denominator == unscaled update."""
    rng = np.random.default_rng(3)
    n = 50
    p0 = rng.standard_normal(n).astype(np.float32)
    g = (rng.standard_normal(n) * 1e-3).astype(np.float32)
    z = np.zeros(n, np.float32)
    t = np.asarray([1], np.int32)
    plain = hadam_update(p0, z, z, z, g, t, lr=1e-2, gamma=1.0)
    scaled = hadam_update(p0, z, z, z, g * 1e4, t, lr=1e-2, gamma=1e4)
    np.testing.assert_allclose(np.asarray(plain[0]), np.asarray(scaled[0]),
                               rtol=1e-4, atol=1e-7)


def test_kahan_ema_matches_oracle():
    rng = np.random.default_rng(4)
    n = 128
    buf = rng.standard_normal(n).astype(np.float32)
    comp = np.zeros(n, np.float32)
    psi = rng.standard_normal(n).astype(np.float32)
    got = kahan_ema_update(buf, comp, psi, tau=0.005, scale=1.0)
    want = kahan_ema_ref(buf, comp, psi, tau=0.005, scale=1.0, dtype=np.float64)
    np.testing.assert_allclose(np.asarray(got[0]), want[0], rtol=1e-5, atol=1e-6)


def test_kahan_ema_fp16_tracks_where_plain_stalls():
    n = 32
    tau, scale = 0.005, 1e4
    psi = np.ones(n, np.float16)
    buf = (np.full(n, 0.9, np.float16) * np.float16(scale)).astype(np.float16)
    comp = np.zeros(n, np.float16)
    plain = np.full(n, 0.9, np.float16)
    for _ in range(3000):
        buf, comp = kahan_ema_update(buf, comp, psi, tau=tau, scale=scale)
        plain = (plain + np.float16(tau) * (psi - plain)).astype(np.float16)
    hat = np.asarray(buf, np.float32) / scale
    k_err = float(np.max(np.abs(hat - 1.0)))
    p_err = float(np.max(np.abs(plain.astype(np.float32) - 1.0)))
    assert k_err < 6e-3, f"kahan err {k_err}"
    assert p_err > 3 * max(k_err, 1e-4), f"plain {p_err} vs kahan {k_err}"
