"""Pytest bootstrap: make the `compile` package importable when the suite
is invoked from the repository root (`python -m pytest python/tests -q`),
which is how CI and the quickstart run it."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
