"""L2: the SAC computation graph in JAX — actor/critic forward, losses,
gradients, optimizer and target update fused into one ``train_step``
function per precision variant, AOT-lowered by aot.py to HLO text that
the Rust runtime executes via PJRT.

Variants
--------
* ``fp32``       — f32 everywhere, classic Adam, plain EMA target.
* ``fp16_naive`` — f16 params/activations/grads/optimizer, no fixes:
                   Adam's ``g**2`` and ``eps=1e-8`` underflow, the policy
                   log-prob overflows — the paper's Figure 1 failure.
* ``fp16_ours``  — f16 everywhere plus the paper's six methods: hAdam +
                   compound loss scaling + Kahan parameter updates (L1
                   kernels ``hadam``/``kahan``), softplus-fix and
                   normal-fix in the policy, Kahan-momentum target EMA.

The L1 Pallas kernels are used on the non-differentiated paths (optimizer
update, target EMA, and the next-action log-prob, which enters the critic
target with stop-gradient); the differentiated actor path uses the same
equations inline so ``jax.grad`` applies. Everything is traced into one
jitted function, so the lowered HLO contains the interpreted Pallas ops.

Interface convention: all inputs/outputs of the lowered functions are
**f32** (the Rust side then never handles f16 literals); f16 variants
cast at the function boundary, which is exact in the f16→f32 direction
and value-preserving on the way back because every internal value is
already f16-representable.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.hadam import hadam_update
from .kernels.kahan import kahan_ema_update
from .kernels.logprob import tanh_gaussian

HALF_LOG_2PI = 0.9189385332046727
LOG2 = 0.6931471805599453


def default_cfg(obs_dim=3, act_dim=1, hidden=64, batch=64, variant="fp32"):
    """Hyperparameters follow the paper's Table 4 (states)."""
    return dict(
        obs_dim=obs_dim,
        act_dim=act_dim,
        hidden=hidden,
        batch=batch,
        variant=variant,
        gamma_rl=0.99,
        tau=0.005,
        lr=1e-4,
        b1=0.9,
        b2=0.999,
        eps=1e-8,
        init_temp=0.1,
        ls_lo=-5.0,
        ls_hi=2.0,
        loss_scale=1e4 if variant == "fp16_ours" else 1.0,
        kahan_scale=1e4,
        target_entropy=-float(act_dim),
    )


def dtype_of(cfg):
    return jnp.float16 if cfg["variant"].startswith("fp16") else jnp.float32


# --------------------------------------------------------------------- init

def _init_linear(key, fan_in, fan_out):
    w = jax.random.orthogonal(key, max(fan_in, fan_out))[:fan_out, :fan_in]
    return {"w": np.asarray(w, np.float32), "b": np.zeros(fan_out, np.float32)}


def _init_mlp(key, dims):
    keys = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": _init_linear(keys[i], dims[i], dims[i + 1]) for i in range(len(dims) - 1)}


def init_state(seed, cfg):
    """Build the full training-state pytree (f32 numpy leaves)."""
    key = jax.random.PRNGKey(seed)
    ka, kc1, kc2 = jax.random.split(key, 3)
    o, a, h = cfg["obs_dim"], cfg["act_dim"], cfg["hidden"]
    actor = _init_mlp(ka, [o, h, h, 2 * a])
    critic = {
        "q1": _init_mlp(kc1, [o + a, h, h, 1]),
        "q2": _init_mlp(kc2, [o + a, h, h, 1]),
    }
    zeros_like_tree = lambda t: jax.tree.map(lambda x: np.zeros_like(x), t)
    C = cfg["kahan_scale"] if cfg["variant"] == "fp16_ours" else 1.0
    state = {
        "params": {"actor": actor, "critic": critic,
                   "log_alpha": np.asarray([np.log(cfg["init_temp"])], np.float32)},
        "target_buf": jax.tree.map(lambda x: np.asarray(x * C, np.float32), critic),
        "target_comp": zeros_like_tree(critic),
        "opt": {
            "actor": {"m": zeros_like_tree(actor), "w": zeros_like_tree(actor)},
            "critic": {"m": zeros_like_tree(critic), "w": zeros_like_tree(critic),
                       "c": zeros_like_tree(critic)},
            "alpha": {"m": np.zeros(1, np.float32), "w": np.zeros(1, np.float32),
                      "c": np.zeros(1, np.float32)},
        },
        "t": np.zeros(1, np.float32),  # step counter (f32 interface)
    }
    # f16 variants: round the initial point into f16 so Rust/JAX agree
    if cfg["variant"].startswith("fp16"):
        f16 = lambda x: np.asarray(np.asarray(x, np.float16), np.float32)
        state = jax.tree.map(f16, state)
    return state


# ------------------------------------------------------------------ forward

def mlp_fwd(p, x):
    n = len(p)
    for i in range(n):
        lay = p[f"l{i}"]
        x = x @ lay["w"].T + lay["b"]
        if i + 1 < n:
            x = jax.nn.relu(x)
    return x


def actor_head(p, obs, cfg, dt):
    z = mlp_fwd(p, obs)
    a = cfg["act_dim"]
    mu, raw = z[:, :a], z[:, a:]
    lo, hi = cfg["ls_lo"], cfg["ls_hi"]
    ls = jnp.asarray(lo, dt) + jnp.asarray(0.5 * (hi - lo), dt) * (jnp.tanh(raw) + jnp.asarray(1.0, dt))
    return mu, ls


def sample_logprob_inline(mu, ls, eps, cfg, dt):
    """Differentiable tanh-Gaussian log-prob (same math as the L1 kernel),
    with softplus-fix / normal-fix switched by the variant."""
    fixes = cfg["variant"] == "fp16_ours"
    sigma = jnp.exp(ls)
    u = mu + eps * sigma
    act = jnp.tanh(u)
    if fixes:
        r = (u - mu) / sigma
        nl = jnp.asarray(-0.5, dt) * r * r - ls - jnp.asarray(HALF_LOG_2PI, dt)
    else:
        d = u - mu
        nl = jnp.asarray(-0.5, dt) * (d * d) / (sigma * sigma) - ls - jnp.asarray(HALF_LOG_2PI, dt)
    x = jnp.asarray(-2.0, dt) * u
    if fixes:
        sp = jnp.where(x > 10.0, x, jnp.log1p(jnp.exp(jnp.minimum(x, 10.0))))
    else:
        sp = jnp.log(jnp.asarray(1.0, dt) + jnp.exp(x))
    tc = jnp.asarray(2.0, dt) * (jnp.asarray(LOG2, dt) - u - sp)
    return act, jnp.sum(nl - tc, axis=-1)


def critic_fwd(p, obs, act):
    x = jnp.concatenate([obs, act], axis=-1)
    return mlp_fwd(p["q1"], x)[:, 0], mlp_fwd(p["q2"], x)[:, 0]


# --------------------------------------------------------------- optimizers

def _adam_plain(params, opt, grads, t, cfg, dt):
    """Classic Adam in the working dtype (fp32 and fp16_naive paths)."""
    b1, b2, eps, lr = cfg["b1"], cfg["b2"], cfg["eps"], cfg["lr"]
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, m, v, g):
        m = jnp.asarray(b1, dt) * m + jnp.asarray(1 - b1, dt) * g
        v = jnp.asarray(b2, dt) * v + jnp.asarray(1 - b2, dt) * (g * g)
        mh = m / bc1.astype(dt)
        vh = v / bc2.astype(dt)
        p = p - jnp.asarray(lr, dt) * mh / (jnp.sqrt(vh) + jnp.asarray(eps, dt))
        return p, m, v

    out = jax.tree.map(upd, params, opt["m"], opt["w"], grads)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {**opt, "m": new_m, "w": new_v}


def _hadam_kernel_opt(params, opt, grads, t_i32, cfg, kahan):
    """hAdam + compound scaling (+ Kahan) via the L1 Pallas kernel."""
    gamma = cfg["loss_scale"]

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_m = treedef.flatten_up_to(opt["m"])
    leaves_w = treedef.flatten_up_to(opt["w"])
    leaves_c = treedef.flatten_up_to(opt["c"]) if kahan else [jnp.zeros_like(x) for x in leaves_p]
    leaves_g = treedef.flatten_up_to(grads)
    outs = [
        hadam_update(p, m, w, c, g, t_i32, lr=cfg["lr"], b1=cfg["b1"],
                     b2=cfg["b2"], eps=cfg["eps"], gamma=gamma, kahan=kahan)
        for p, m, w, c, g in zip(leaves_p, leaves_m, leaves_w, leaves_c, leaves_g)
    ]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_w = treedef.unflatten([o[2] for o in outs])
    new_opt = {**opt, "m": new_m, "w": new_w}
    if kahan:
        new_opt["c"] = treedef.unflatten([o[3] for o in outs])
    return new_p, new_opt


# --------------------------------------------------------------- train step

def make_train_step(cfg):
    """Build the fused critic+actor+alpha+target update for the variant."""
    dt = dtype_of(cfg)
    ours = cfg["variant"] == "fp16_ours"
    gamma = cfg["loss_scale"]
    C = cfg["kahan_scale"] if ours else 1.0

    def step(state, obs, act, rew, next_obs, not_done, eps_next, eps_cur):
        # cast the f32 interface into the working dtype
        cast = lambda tree: jax.tree.map(lambda x: x.astype(dt), tree)
        params = cast(state["params"])
        tgt_buf = cast(state["target_buf"])
        tgt_comp = cast(state["target_comp"])
        opt = cast(state["opt"])
        obs, act, rew = obs.astype(dt), act.astype(dt), rew.astype(dt)
        next_obs, not_done = next_obs.astype(dt), not_done.astype(dt)
        eps_next, eps_cur = eps_next.astype(dt), eps_cur.astype(dt)
        t_new = state["t"][0] + 1.0  # f32 counter
        t_i32 = jnp.asarray([t_new], jnp.int32)

        alpha = jnp.exp(params["log_alpha"][0].astype(dt))

        # ---- critic target (no grad): L1 logprob kernel ----------------
        mu_n, ls_n = actor_head(params["actor"], next_obs, cfg, dt)
        a_next, lp_elem = tanh_gaussian(mu_n, ls_n, eps_next,
                                        softplus_fix=ours, normal_fix=ours)
        logp_next = jnp.sum(lp_elem, axis=-1)
        target_params = jax.tree.map(lambda b: b * jnp.asarray(1.0 / C, dt), tgt_buf)
        tq1, tq2 = critic_fwd(target_params, next_obs, a_next)
        v = jnp.minimum(tq1, tq2) - alpha * logp_next
        y = rew + jnp.asarray(cfg["gamma_rl"], dt) * not_done * v
        y = jax.lax.stop_gradient(y)

        # ---- critic update ---------------------------------------------
        def critic_loss_fn(cp):
            q1, q2 = critic_fwd(cp, obs, act)
            l = jnp.mean((q1 - y) ** 2) + jnp.mean((q2 - y) ** 2)
            return l * jnp.asarray(gamma, dt), (q1, q2)

        (closs_scaled, (q1, _q2)), cgrads = jax.value_and_grad(
            critic_loss_fn, has_aux=True)(params["critic"])
        if ours:
            new_critic, new_opt_c = _hadam_kernel_opt(
                params["critic"], opt["critic"], cgrads, t_i32, cfg, kahan=True)
        else:
            new_critic, new_opt_c = _adam_plain(
                params["critic"], opt["critic"], cgrads, t_new, cfg, dt)
            new_opt_c["c"] = opt["critic"]["c"]

        # ---- actor update (inline differentiable log-prob) -------------
        def actor_loss_fn(ap):
            mu, ls = actor_head(ap, obs, cfg, dt)
            a_cur, logp = sample_logprob_inline(mu, ls, eps_cur, cfg, dt)
            q1a, q2a = critic_fwd(new_critic, obs, a_cur)
            qmin = jnp.minimum(q1a, q2a)
            return jnp.mean(alpha * logp - qmin) * jnp.asarray(gamma, dt), logp

        (_aloss, logp_cur), agrads = jax.value_and_grad(
            actor_loss_fn, has_aux=True)(params["actor"])
        if ours:
            new_actor, new_opt_a = _hadam_kernel_opt(
                params["actor"], opt["actor"], agrads, t_i32, cfg, kahan=False)
        else:
            new_actor, new_opt_a = _adam_plain(
                params["actor"], opt["actor"], agrads, t_new, cfg, dt)

        # ---- temperature -------------------------------------------------
        logp_sg = jax.lax.stop_gradient(logp_cur)
        mean_term = jnp.mean(logp_sg + jnp.asarray(cfg["target_entropy"], dt))
        galpha = (-alpha * mean_term * jnp.asarray(gamma, dt)).reshape(1)
        if ours:
            new_la, new_opt_al = _hadam_kernel_opt(
                params["log_alpha"], opt["alpha"],
                galpha, t_i32, cfg, kahan=True)
        else:
            la = params["log_alpha"]
            m = jnp.asarray(cfg["b1"], dt) * opt["alpha"]["m"] + jnp.asarray(1 - cfg["b1"], dt) * galpha
            v = jnp.asarray(cfg["b2"], dt) * opt["alpha"]["w"] + jnp.asarray(1 - cfg["b2"], dt) * galpha ** 2
            mh = m / (1.0 - cfg["b1"] ** t_new).astype(dt)
            vh = v / (1.0 - cfg["b2"] ** t_new).astype(dt)
            new_la = la - jnp.asarray(cfg["lr"], dt) * mh / (jnp.sqrt(vh) + jnp.asarray(cfg["eps"], dt))
            new_opt_al = {"m": m, "w": v, "c": opt["alpha"]["c"]}

        # ---- target EMA ---------------------------------------------------
        if ours:
            flat_b, tdef = jax.tree.flatten(tgt_buf)
            flat_c = tdef.flatten_up_to(tgt_comp)
            flat_p = tdef.flatten_up_to(new_critic)
            outs = [kahan_ema_update(b, c, p, tau=cfg["tau"], scale=C)
                    for b, c, p in zip(flat_b, flat_c, flat_p)]
            new_tbuf = tdef.unflatten([o[0] for o in outs])
            new_tcomp = tdef.unflatten([o[1] for o in outs])
        else:
            tau = jnp.asarray(cfg["tau"], dt)
            new_tbuf = jax.tree.map(lambda b, p: b + tau * (p - b), tgt_buf, new_critic)
            new_tcomp = tgt_comp

        # ---- pack (back to the f32 interface) --------------------------
        uncast = lambda tree: jax.tree.map(lambda x: x.astype(jnp.float32), tree)
        new_state = {
            "params": uncast({"actor": new_actor, "critic": new_critic,
                              "log_alpha": new_la}),
            "target_buf": uncast(new_tbuf),
            "target_comp": uncast(new_tcomp),
            "opt": uncast({"actor": new_opt_a, "critic": new_opt_c,
                           "alpha": new_opt_al}),
            "t": jnp.asarray([t_new], jnp.float32),
        }
        metrics = jnp.stack([
            (closs_scaled / jnp.asarray(gamma, dt)).astype(jnp.float32),
            jnp.mean(q1).astype(jnp.float32),
            jnp.mean(logp_cur).astype(jnp.float32),
            alpha.astype(jnp.float32),
        ])
        return new_state, metrics

    return step


def make_act(cfg, stochastic=True):
    """Policy-inference function: (actor_params, obs, eps) -> action."""
    dt = dtype_of(cfg)
    ours = cfg["variant"] == "fp16_ours"

    def act(actor, obs, eps):
        actor = jax.tree.map(lambda x: x.astype(dt), actor)
        mu, ls = actor_head(actor, obs.astype(dt), cfg, dt)
        if stochastic:
            a, _ = tanh_gaussian(mu, ls, eps.astype(dt),
                                 softplus_fix=ours, normal_fix=ours)
        else:
            a = jnp.tanh(mu)
        return a.astype(jnp.float32)

    return act
