"""L1 Pallas kernel: the hAdam update (paper §3 method 1, Algorithm 1),
fused with compound loss scaling (method 5) and optionally Kahan-
compensated parameter application (method 6).

One elementwise pass per parameter tensor:

    m   <- b1*m + (1-b1)*g                      (g carries the scale gamma)
    w   <- hypot(sqrt(b2)*w, sqrt(1-b2)*g)      (stable hypot)
    mh  <- m / (1 - b1^t)
    wh  <- w / sqrt(1 - b2^t)
    d   <- -lr * mh / (wh + gamma*eps)
    Kahan: y = d - c ; tnew = p + y ; c = (tnew - p) - y ; p = tnew

All arithmetic runs in the tensor's dtype (f16 for the paper's runs), so
under/overflow happen exactly where real fp16 hardware would hit them.

TPU mapping: bandwidth-bound read-modify-write over four equal-shape
buffers (p, m, w, c); one VMEM tile each per grid step, hypot lowers to
VPU mul/rsqrt — no MXU involvement.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _hypot_stable(a, b, tiny):
    """max*sqrt(1+(min/(max+tiny))^2) — no intermediate under/overflow."""
    aa, ab = jnp.abs(a), jnp.abs(b)
    mx = jnp.maximum(aa, ab)
    mn = jnp.minimum(aa, ab)
    r = mn / (mx + tiny)
    out = mx * jnp.sqrt(1.0 + r * r)
    return jnp.where(mx == 0.0, jnp.zeros_like(mx), out)


def _hadam_kernel(p_ref, m_ref, w_ref, c_ref, g_ref, t_ref, o_p, o_m, o_w, o_c,
                  *, lr, b1, b2, eps, gamma, kahan):
    dt = p_ref[...].dtype
    one = jnp.asarray(1.0, dt)
    g = g_ref[...]
    m = jnp.asarray(b1, dt) * m_ref[...] + jnp.asarray(1.0 - b1, dt) * g
    tiny = jnp.asarray(6e-8 if dt == jnp.float16 else 1e-45, dt)
    w = _hypot_stable(
        jnp.asarray(b2, dt) ** jnp.asarray(0.5, dt) * w_ref[...],
        jnp.asarray((1.0 - b2) ** 0.5, dt) * g,
        tiny,
    )
    # bias corrections: scalars computed in f32, then cast
    t = t_ref[0].astype(jnp.float32)
    bc1 = 1.0 - jnp.asarray(b1, jnp.float32) ** t
    bc2 = jnp.sqrt(1.0 - jnp.asarray(b2, jnp.float32) ** t)
    mh = m * (one / bc1.astype(dt))
    wh = w * (one / bc2.astype(dt))
    d = jnp.asarray(-lr, dt) * (mh / (wh + jnp.asarray(gamma * eps, dt)))
    if kahan:
        c = c_ref[...]
        y = d - c
        tnew = p_ref[...] + y
        o_c[...] = (tnew - p_ref[...]) - y
        o_p[...] = tnew
    else:
        o_c[...] = c_ref[...]
        o_p[...] = p_ref[...] + d
    o_m[...] = m
    o_w[...] = w


@functools.partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps", "gamma", "kahan"))
def hadam_update(p, m, w, c, g, t, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                 gamma=1.0, kahan=True):
    """Apply one hAdam step. All array args share one flat shape and
    dtype; ``t`` is a length-1 int32 step counter (1-based). Returns
    ``(p', m', w', c')``."""
    shape = p.shape
    dt = p.dtype
    n = p.size
    padded = ((n + BLOCK - 1) // BLOCK) * BLOCK

    def pad(x):
        return jnp.pad(x.reshape(-1), (0, padded - n))

    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    outs = pl.pallas_call(
        functools.partial(_hadam_kernel, lr=lr, b1=b1, b2=b2, eps=eps,
                          gamma=gamma, kahan=kahan),
        out_shape=[jax.ShapeDtypeStruct((padded,), dt)] * 4,
        grid=(padded // BLOCK,),
        in_specs=[spec, spec, spec, spec, spec,
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[spec] * 4,
        interpret=True,
    )(pad(p), pad(m), pad(w), pad(c), pad(g), t.astype(jnp.int32))
    return tuple(o[:n].reshape(shape) for o in outs)
