"""L1 Pallas kernel: tanh-Gaussian action sampling + log-probability with
the paper's softplus-fix (method 2) and normal-fix (method 3).

Inputs are the policy head ``mu``/``log_sigma`` and standard-normal noise
``eps``; outputs the squashed action and per-element log-prob terms (the
caller sums over the action dimension). All arithmetic in the input
dtype, so fp16 under/overflow is faithful.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HALF_LOG_2PI = 0.9189385332046727
LOG2 = 0.6931471805599453
BLOCK = 2048


def _softplus_neg2u(x, fix: bool, k: float):
    """log(1 + exp(x)) for x = -2u; linearized above K when fixed."""
    if fix:
        safe = jnp.minimum(x, k)
        sp = jnp.log1p(jnp.exp(safe))
        return jnp.where(x > k, x, sp)
    return jnp.log(1.0 + jnp.exp(x))  # overflows fp16 for x > 11.09


def _logprob_kernel(mu_ref, ls_ref, eps_ref, o_a, o_lp, *, softplus_fix,
                    normal_fix, k, sigma_eps):
    dt = mu_ref[...].dtype
    mu, ls, eps = mu_ref[...], ls_ref[...], eps_ref[...]
    sigma = jnp.exp(ls) + jnp.asarray(sigma_eps, dt)
    u = mu + eps * sigma
    a = jnp.tanh(u)
    if normal_fix:
        r = (u - mu) / sigma
        nl = jnp.asarray(-0.5, dt) * (r * r) - ls - jnp.asarray(HALF_LOG_2PI, dt)
    else:
        d = u - mu
        nl = jnp.asarray(-0.5, dt) * ((d * d) / (sigma * sigma)) - ls \
            - jnp.asarray(HALF_LOG_2PI, dt)
    x = jnp.asarray(-2.0, dt) * u
    sp = _softplus_neg2u(x, softplus_fix, k)
    tc = jnp.asarray(2.0, dt) * (jnp.asarray(LOG2, dt) - u - sp)
    o_a[...] = a
    o_lp[...] = nl - tc


@functools.partial(jax.jit, static_argnames=("softplus_fix", "normal_fix", "k", "sigma_eps"))
def tanh_gaussian(mu, log_sigma, eps, *, softplus_fix=True, normal_fix=True,
                  k=10.0, sigma_eps=0.0):
    """Sample squashed-Gaussian actions and per-element log-probs.
    Returns ``(action, logp_elem)`` with the input shape; sum ``logp_elem``
    over the action axis for the policy log-likelihood."""
    shape = mu.shape
    dt = mu.dtype
    n = mu.size
    padded = ((n + BLOCK - 1) // BLOCK) * BLOCK

    def pad(x):
        return jnp.pad(x.reshape(-1), (0, padded - n))

    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    a, lp = pl.pallas_call(
        functools.partial(_logprob_kernel, softplus_fix=softplus_fix,
                          normal_fix=normal_fix, k=k, sigma_eps=sigma_eps),
        out_shape=[jax.ShapeDtypeStruct((padded,), dt)] * 2,
        grid=(padded // BLOCK,),
        in_specs=[spec] * 3,
        out_specs=[spec] * 2,
        interpret=True,
    )(pad(mu), pad(log_sigma), pad(eps))
    return a[:n].reshape(shape), lp[:n].reshape(shape)
