"""L1 Pallas kernel: Kahan-compensated accumulation step (paper §3
methods 4 & 6, Algorithm 2), used for the target network's scaled EMA.

    delta = (C*tau) * (psi - hat)        (hat = buf / C)
    y = delta - c ; t = buf + y ; c = (t - buf) - y ; buf = t

The C*tau product is formed *before* touching the tiny difference so the
increment clears the subnormal range (the whole point of the paper's
buffer scale C).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _kahan_ema_kernel(buf_ref, c_ref, psi_ref, o_buf, o_c, *, tau, scale):
    dt = buf_ref[...].dtype
    ct = jnp.asarray(scale * tau, dt)
    inv_c = jnp.asarray(1.0 / scale, dt)
    hat = buf_ref[...] * inv_c
    delta = ct * (psi_ref[...] - hat)
    y = delta - c_ref[...]
    t = buf_ref[...] + y
    o_c[...] = (t - buf_ref[...]) - y
    o_buf[...] = t


@functools.partial(jax.jit, static_argnames=("tau", "scale"))
def kahan_ema_update(buf, comp, psi, *, tau, scale):
    """One compensated soft-update step on the scaled buffer
    ``buf = C * psi_hat``. Returns ``(buf', comp')``; read the target
    weights as ``buf' / C``."""
    shape = buf.shape
    dt = buf.dtype
    n = buf.size
    padded = ((n + BLOCK - 1) // BLOCK) * BLOCK

    def pad(x):
        return jnp.pad(x.reshape(-1), (0, padded - n))

    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    outs = pl.pallas_call(
        functools.partial(_kahan_ema_kernel, tau=tau, scale=scale),
        out_shape=[jax.ShapeDtypeStruct((padded,), dt)] * 2,
        grid=(padded // BLOCK,),
        in_specs=[spec] * 3,
        out_specs=[spec] * 2,
        interpret=True,
    )(pad(buf), pad(comp), pad(psi))
    return tuple(o[:n].reshape(shape) for o in outs)
