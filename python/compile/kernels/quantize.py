"""L1 Pallas kernel: parameterized floating-point quantizer (the qtorch
replacement used for the paper's Figure 4 format sweep).

Rounds f32 values to the nearest representable value of a
``(exp_bits, man_bits)`` binary format with IEEE semantics: gradual
underflow (subnormals), round-to-nearest-even, overflow to ±inf.

The algorithm is the exact float-arithmetic analogue of the Rust
``lowp::FloatFormat::quantize`` (rust/src/lowp/format.rs): snap to the
local ULP grid via exact power-of-two scaling. All intermediate products
are exact in f32 for ``man_bits <= 23``, so the two implementations agree
bit-for-bit (checked by python/tests/test_quantize.py against ref.py and
by the cross-language fixtures).

TPU mapping (DESIGN.md §Hardware-Adaptation): this is a bandwidth-bound
elementwise pass; the BlockSpec tiles a flat view of the tensor through
VMEM, one read-modify-write per element, no transcendentals (the `ulp`
is built by integer exponent manipulation, lowered to VPU integer ops).
``interpret=True`` everywhere — the CPU PJRT client cannot execute Mosaic
custom calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flat tile processed per grid step. On TPU this would be sized to a VMEM
# sector (e.g. 512*128 f32 = 256 KiB); in interpret mode it only affects
# trace time.
BLOCK = 4096


def _quantize_math(x, exp_bits: int, man_bits: int):
    """Pure-jnp RNE quantization of f32 ``x`` into (exp_bits, man_bits).

    Shared by the Pallas kernel body and (via ref.py) the oracle.
    """
    bias = (1 << (exp_bits - 1)) - 1
    emax = bias
    emin = 1 - bias
    max_val = (2.0 ** (emax + 1)) - 2.0 ** (emax - man_bits)

    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    e_field = ((bits >> 23) & 0xFF).astype(jnp.int32) - 127

    # ULP of the target format around |x|: 2^(e - man) for normals,
    # constant 2^(emin - man) in the subnormal range.
    ulp_exp = jnp.maximum(e_field, emin) - man_bits
    # construct 2^ulp_exp exactly via the exponent field (ulp_exp is
    # always > -127 for the formats we support: emin - man >= -126)
    ulp = jax.lax.bitcast_convert_type(
        ((ulp_exp + 127).astype(jnp.uint32) << 23), jnp.float32
    )

    steps = x / ulp  # exact: power-of-two scaling
    rounded = jnp.round(steps)  # jnp.round is round-half-to-even
    q = rounded * ulp  # exact

    # overflow -> +-inf ; preserve nan/inf/signed zero
    overflow = jnp.abs(q) > max_val
    q = jnp.where(overflow, jnp.sign(x) * jnp.inf, q)
    q = jnp.where(jnp.isfinite(x), q, x)
    q = jnp.where(x == 0.0, x, q)
    return q.astype(jnp.float32)


def _quantize_kernel(x_ref, o_ref, *, exp_bits, man_bits):
    o_ref[...] = _quantize_math(x_ref[...], exp_bits, man_bits)


@functools.partial(jax.jit, static_argnums=(1, 2))
def quantize(x, exp_bits: int, man_bits: int):
    """Quantize an f32 array into the (exp_bits, man_bits) format via the
    Pallas kernel (interpret mode). Shape-preserving."""
    orig_shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    padded = ((n + BLOCK - 1) // BLOCK) * BLOCK
    flat = jnp.pad(flat, (0, padded - n))
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, exp_bits=exp_bits, man_bits=man_bits),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        grid=(padded // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,
    )(flat)
    return out[:n].reshape(orig_shape)
