"""Pure-numpy/jnp oracles for every L1 kernel — the correctness signal
the pytest suite checks the Pallas kernels against.

The quantizer oracle works in f64 (mirroring the Rust implementation in
rust/src/lowp/format.rs exactly); the optimizer/policy oracles are
straight transliterations of the papers' equations in f64, downcast at
the end.
"""

import numpy as np

HALF_LOG_2PI = 0.9189385332046727
LOG2 = 0.6931471805599453


def quantize_ref(x, exp_bits: int, man_bits: int):
    """f64 reference RNE quantization (same algorithm as the Rust side)."""
    x = np.asarray(x, dtype=np.float32)
    out = np.empty_like(x)
    bias = (1 << (exp_bits - 1)) - 1
    emax = bias
    emin = 1 - bias
    maxv = (2.0 ** (emax + 1)) - 2.0 ** (emax - man_bits)
    flat = x.reshape(-1)
    o = out.reshape(-1)
    for i, v in enumerate(flat):
        if v == 0.0 or not np.isfinite(v):
            o[i] = v
            continue
        xd = float(v)
        ax = abs(xd)
        e = int(np.floor(np.log2(ax)))
        # correct edge case: log2 of exact powers can round badly
        if 2.0 ** (e + 1) <= ax:
            e += 1
        elif 2.0 ** e > ax:
            e -= 1
        ulp_exp = (emin if e < emin else e) - man_bits
        ulp = 2.0 ** ulp_exp
        steps = ax / ulp
        rounded = np.round(steps)  # numpy round-half-even
        q = rounded * ulp
        if q > maxv:
            q = np.inf
        o[i] = np.copysign(q, xd)
    return out


def hypot_stable_ref(a, b, tiny):
    aa, ab = np.abs(a), np.abs(b)
    mx = np.maximum(aa, ab)
    mn = np.minimum(aa, ab)
    r = mn / (mx + tiny)
    out = mx * np.sqrt(1.0 + r * r)
    return np.where(mx == 0.0, 0.0, out)


def hadam_ref(p, m, w, c, g, t, *, lr, b1=0.9, b2=0.999, eps=1e-8,
              gamma=1.0, kahan=True, dtype=np.float64):
    """Reference hAdam step in ``dtype`` (f64 by default = 'infinite
    precision' for Statement-1 style checks)."""
    cast = lambda x: np.asarray(x, dtype)
    p, m, w, c, g = map(cast, (p, m, w, c, g))
    m = b1 * m + (1 - b1) * g
    tiny = 6e-8 if dtype == np.float16 else 1e-45
    w = hypot_stable_ref(np.sqrt(b2) * w, np.sqrt(1 - b2) * g, tiny)
    bc1 = 1.0 - b1 ** float(t)
    bc2 = np.sqrt(1.0 - b2 ** float(t))
    mh = m / bc1
    wh = w / bc2
    d = cast(-lr) * (mh / (wh + gamma * eps))
    if kahan:
        y = d - c
        tnew = p + y
        c = (tnew - p) - y
        p = tnew
    else:
        p = p + d
    return p, m, w, c


def adam_ref(p, m, v, g, t, *, lr, b1=0.9, b2=0.999, eps=1e-8):
    """Classic Adam in f64 — the 'infinite precision' baseline hAdam must
    coincide with (paper Statement 1)."""
    p, m, v, g = (np.asarray(x, np.float64) for x in (p, m, v, g))
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1.0 - b1 ** float(t))
    vh = v / (1.0 - b2 ** float(t))
    p = p - lr * mh / (np.sqrt(vh) + eps)
    return p, m, v


def kahan_ema_ref(buf, comp, psi, *, tau, scale, dtype=np.float64):
    cast = lambda x: np.asarray(x, dtype)
    buf, comp, psi = map(cast, (buf, comp, psi))
    ct = cast(scale * tau)
    hat = buf * cast(1.0 / scale)
    delta = ct * (psi - hat)
    y = delta - comp
    t = buf + y
    comp = (t - buf) - y
    return t, comp


def tanh_gaussian_ref(mu, log_sigma, eps, *, sigma_eps=0.0):
    """f64 tanh-Gaussian sample + per-element log-prob (no fixes needed in
    f64 — this is the ground truth both fixed and unfixed kernels must
    match in high precision)."""
    mu, ls, eps = (np.asarray(x, np.float64) for x in (mu, log_sigma, eps))
    sigma = np.exp(ls) + sigma_eps
    u = mu + eps * sigma
    a = np.tanh(u)
    r = (u - mu) / sigma
    nl = -0.5 * r * r - ls - HALF_LOG_2PI
    tc = 2.0 * (LOG2 - u - np.logaddexp(0.0, -2.0 * u))
    return a, nl - tc
