"""AOT lowering: build the L2 train-step / act functions for each
precision variant, lower them to **HLO text** (the interchange format the
`xla` crate's 0.5.1 XLA accepts — serialized protos from jax >= 0.5 carry
64-bit ids it rejects), and emit:

    artifacts/<name>.hlo.txt      one per function x variant
    artifacts/state_<variant>.bin raw little-endian f32 initial state
    artifacts/manifest.txt        line-based index the Rust runtime parses

Manifest grammar (one token stream per line):

    dims obs=3 act=1 hidden=64 batch=64 task=pendulum_swingup
    artifact <name> <file>
    in <name> f32 <d0>x<d1>...
    out <name> f32 <dims>
    state <variant> <file> <n_leaves>

Run via ``python python/compile/aot.py --out artifacts``.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402

VARIANTS = ("fp32", "fp16_naive", "fp16_ours")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def leaf_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            else:
                parts.append(str(p))
        names.append(".".join(parts))
    return names


def shape_str(x):
    return "x".join(str(d) for d in x.shape) if x.shape else "1"


def emit(cfg, out_dir, manifest_lines):
    variant = cfg["variant"]
    state = model.init_state(0, cfg)
    b, o, a = cfg["batch"], cfg["obs_dim"], cfg["act_dim"]
    f32 = jnp.float32
    batch_specs = dict(
        obs=jax.ShapeDtypeStruct((b, o), f32),
        act=jax.ShapeDtypeStruct((b, a), f32),
        rew=jax.ShapeDtypeStruct((b,), f32),
        next_obs=jax.ShapeDtypeStruct((b, o), f32),
        not_done=jax.ShapeDtypeStruct((b,), f32),
        eps_next=jax.ShapeDtypeStruct((b, a), f32),
        eps_cur=jax.ShapeDtypeStruct((b, a), f32),
    )
    state_spec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, f32), state)
    snames = leaf_names(state)
    sleaves = jax.tree.leaves(state)

    # ---- train step -----------------------------------------------------
    step = model.make_train_step(cfg)
    lowered = jax.jit(step).lower(state_spec, *batch_specs.values())
    fname = f"train_{variant}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest_lines.append(f"artifact train_{variant} {fname}")
    for n, leaf in zip(snames, sleaves):
        manifest_lines.append(f"in state.{n} f32 {shape_str(np.asarray(leaf))}")
    for n, spec in batch_specs.items():
        manifest_lines.append(f"in {n} f32 {shape_str(spec)}")
    for n, leaf in zip(snames, sleaves):
        manifest_lines.append(f"out state.{n} f32 {shape_str(np.asarray(leaf))}")
    manifest_lines.append("out metrics f32 4")

    # ---- act ------------------------------------------------------------
    act_fn = model.make_act(cfg, stochastic=True)
    actor_spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, f32), state["params"]["actor"]
    )
    lowered = jax.jit(act_fn).lower(
        actor_spec,
        jax.ShapeDtypeStruct((1, o), f32),
        jax.ShapeDtypeStruct((1, a), f32),
    )
    fname = f"act_{variant}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest_lines.append(f"artifact act_{variant} {fname}")
    actor_names = leaf_names(state["params"]["actor"])
    actor_leaves = jax.tree.leaves(state["params"]["actor"])
    for n, leaf in zip(actor_names, actor_leaves):
        manifest_lines.append(f"in actor.{n} f32 {shape_str(np.asarray(leaf))}")
    manifest_lines.append(f"in obs f32 1x{o}")
    manifest_lines.append(f"in eps f32 1x{a}")
    manifest_lines.append(f"out action f32 1x{a}")

    # ---- initial state --------------------------------------------------
    sfile = f"state_{variant}.bin"
    with open(os.path.join(out_dir, sfile), "wb") as f:
        for leaf in sleaves:
            f.write(np.asarray(leaf, "<f4").tobytes())
    manifest_lines.append(f"state {variant} {sfile} {len(sleaves)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="sentinel path; artifacts land in its directory")
    ap.add_argument("--obs", type=int, default=3)
    ap.add_argument("--act", type=int, default=1)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--task", default="pendulum_swingup")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = [
        f"dims obs={args.obs} act={args.act} hidden={args.hidden} "
        f"batch={args.batch} task={args.task}"
    ]
    for variant in VARIANTS:
        cfg = model.default_cfg(args.obs, args.act, args.hidden, args.batch, variant)
        print(f"[aot] lowering variant {variant} ...", flush=True)
        emit(cfg, out_dir, manifest)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    # sentinel for the Makefile dependency
    with open(os.path.abspath(args.out), "w") as f:
        f.write("see manifest.txt\n")
    print(f"[aot] wrote {len(manifest)} manifest lines to {out_dir}")


if __name__ == "__main__":
    main()
