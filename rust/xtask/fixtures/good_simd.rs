// Known-good fixture: vector capability is consumed through nn::simd's
// safe dispatch surface — the detected level, not raw intrinsics.

pub fn widen_is_accelerated(fmt: crate::lowp::HalfFormat) -> bool {
    crate::nn::simd::detect().accelerates(fmt)
}
