// Known-bad fixture: every `unsafe` here lacks a `// SAFETY:` header,
// so tidy must flag each site (rule: safety).

struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}

pub fn read_first(p: *const f32) -> f32 {
    // a comment that is not a safety argument
    unsafe { *p }
}

unsafe fn write(p: *mut f32, v: f32) {
    unsafe { *p = v };
}
