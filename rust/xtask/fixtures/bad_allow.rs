// Known-bad fixture: tidy-allow escapes that name an unknown rule or
// omit the mandatory reason must themselves be flagged.

pub fn f(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap() // tidy-allow(everything): not a real rule
}

pub fn g(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap() // tidy-allow(panic):
}
