// Known-bad fixture (scanned as a non-simd module): raw feature
// detection and intrinsic paths outside nn/simd.rs without an escape.

pub fn has_fast_widen() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("f16c")
}
