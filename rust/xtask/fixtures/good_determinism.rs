// Known-good fixture: deterministic containers by default, and the one
// wall-clock read is an audited telemetry-only escape.

use std::collections::BTreeMap;

pub fn tally(xs: &[u32]) -> usize {
    let mut m: BTreeMap<u32, u32> = BTreeMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    m.len()
}

pub fn timed(work: impl FnOnce()) -> f64 {
    let t0 = std::time::Instant::now(); // tidy-allow(determinism): telemetry only — never feeds computation
    work();
    t0.elapsed().as_secs_f64()
}
