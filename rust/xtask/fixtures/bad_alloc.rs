// Known-bad fixture: an allocation in a fn reachable from the hot
// learner entry point, with no tidy-allow(alloc) escape.

pub struct SacAgent {
    buf: Vec<f32>,
}

impl SacAgent {
    pub fn update_round(&mut self) {
        self.scratch();
    }

    fn scratch(&mut self) {
        let v: Vec<f32> = Vec::with_capacity(64);
        self.buf.extend_from_slice(&v);
    }
}
