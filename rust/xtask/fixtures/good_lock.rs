// Known-good fixture: every function acquires `a` before `b`, and the
// worker shape is legal — the inner wait loop only waits on the condvar
// of the lock it re-acquires (`job`); the outer loop touching `done`
// afterwards is a different (outer) loop, which the innermost-loop
// scoping of the condvar rule deliberately permits.

use std::sync::{Condvar, Mutex};

pub struct Pool {
    a: Mutex<u32>,
    b: Mutex<u32>,
    job: Mutex<Option<u32>>,
    done: Mutex<u32>,
    cv: Condvar,
}

impl Pool {
    pub fn sum(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *ga + *gb
    }

    pub fn diff(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *ga - *gb
    }

    pub fn worker_loop(&self) {
        loop {
            let mut g = self.job.lock().unwrap();
            while g.is_none() {
                g = self.cv.wait(g).unwrap();
            }
            let task = g.take();
            drop(g);
            let mut d = self.done.lock().unwrap();
            *d += task.unwrap_or(0);
        }
    }
}
