// Known-bad fixture: a test tree that pins some fused APIs but never
// references `fuse_group` (or most of the others) — the parity pass
// must flag every uncovered API.

#[test]
fn pooled_runs_match_serial() {
    // parity: run_spans
    // parity: run_chunked
    run_all_backends();
}
