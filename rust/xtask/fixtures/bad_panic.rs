// Known-bad fixture: bare unwrap/expect in library (non-test) code.

pub fn load(path: &str) -> String {
    let text = std::fs::read_to_string(path).unwrap();
    text.lines().next().expect("empty file").to_string()
}
