// Known-good fixture: every `unsafe` carries a SAFETY justification,
// including one header covering a contiguous run and an attribute
// between the comment and the item.

struct SendPtr(*mut f32);
// SAFETY: the pointer is only dereferenced at disjoint offsets by the
// pool tasks, so sharing it across threads cannot alias.
unsafe impl Send for SendPtr {}
// SAFETY: as above — disjoint offsets only.
unsafe impl Sync for SendPtr {}

/// Reads the first element.
// SAFETY: callers must pass a pointer valid for reads of one f32.
#[inline]
unsafe fn read_first(p: *const f32) -> f32 {
    // SAFETY: delegated caller contract: `p` is valid for reads.
    unsafe { *p }
}

pub fn run(a: *mut f32, b: *mut f32) {
    // SAFETY: spans are disjoint — each task owns its stretch.
    let x = unsafe { *a };
    let y = unsafe { *b };
    let _ = (x, y);
}
