// Known-bad fixture (scanned as a deterministic-core module): hasher
// maps, wall clocks, and ad-hoc threads without tidy-allow escapes.

use std::collections::HashMap;
use std::time::Instant;

pub fn tally(xs: &[u32]) -> usize {
    let mut m: HashMap<u32, u32> = HashMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    m.len()
}

pub fn timed() -> f64 {
    let t0 = Instant::now();
    std::thread::spawn(|| {}).join().ok();
    t0.elapsed().as_secs_f64()
}
