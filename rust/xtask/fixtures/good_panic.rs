// Known-good fixture: library code returns errors; a poisoned-lock
// unwrap is an audited escape; tests may unwrap freely.

use std::sync::Mutex;

pub fn load(path: &str) -> Result<String, std::io::Error> {
    std::fs::read_to_string(path)
}

pub fn peek(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap() // tidy-allow(panic): lock poisoning means another task already panicked — propagating is correct
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
