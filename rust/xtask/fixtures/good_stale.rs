// Known-good fixture: every tidy-allow escape still covers a line the
// named rule would fire on — none are stale.

pub fn peek(m: &std::sync::Mutex<u32>) -> u32 {
    // tidy-allow(panic): poisoned lock propagates a prior panic
    *m.lock().unwrap()
}

pub fn fingerprint(v: f32) -> u32 {
    v.to_bits() // tidy-allow(precision): hashing the pattern — no rounding decision
}
