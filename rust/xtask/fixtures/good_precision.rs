// Known-good fixture: bit patterns may be observed (not used to round)
// behind an audited escape; quantization itself stays in lowp.

pub fn fingerprint(v: f32, h: &mut u64) {
    // tidy-allow(precision): hashing the bit pattern for a replay
    // fingerprint — no rounding decision is made here.
    for b in v.to_bits().to_le_bytes() {
        *h = (*h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
    }
}
