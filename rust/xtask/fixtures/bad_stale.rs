// Known-bad fixture: well-formed tidy-allow escapes whose target line
// no longer contains anything the named rule would fire on.

pub fn peek(m: &std::sync::Mutex<u32>) -> u32 {
    // tidy-allow(panic): poisoned lock propagates a prior panic
    let g = m.lock();
    g.map(|v| *v).unwrap_or(0)
}

pub fn double(x: f32) -> f32 {
    let y = x * 2.0; // tidy-allow(precision): stale inline escape
    y
}
