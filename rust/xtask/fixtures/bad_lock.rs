// Known-bad fixture: two functions acquire the same pair of mutexes in
// opposite orders — a cycle in the acquisition-order graph (ABBA).

use std::sync::Mutex;

pub struct Pair {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl Pair {
    pub fn ab(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *ga + *gb
    }

    pub fn ba(&self) -> u32 {
        let gb = self.b.lock().unwrap();
        let ga = self.a.lock().unwrap();
        *ga + *gb
    }
}
