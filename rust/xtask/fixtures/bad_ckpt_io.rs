// Known-bad fixture: checkpoint code writing final paths directly and
// unwrapping I/O results instead of propagating errors with context.

use std::fs::File;
use std::io::Write;

pub fn save(path: &str, payload: &[u8]) {
    // bare create on the final path: a crash mid-write leaves a torn file
    let mut f = File::create(path).unwrap();
    f.write_all(payload).unwrap();
}

pub fn save_small(path: &str, payload: &[u8]) {
    std::fs::write(path, payload).expect("writing checkpoint");
}
