// Known-good fixture: checkpoint bytes flow through the audited atomic
// writer (temp create escaped with a reason), errors carry path context,
// and tests may unwrap freely.

use anyhow::{Context, Result};
use std::fs::File;
use std::io::Write;
use std::path::Path;

pub fn write_atomic(tmp: &Path, path: &Path, bytes: &[u8]) -> Result<()> {
    // tidy-allow(ckpt-io): this IS the atomic writer — the create targets
    // the temp sibling, never the final path
    let mut f = File::create(tmp).with_context(|| format!("creating temp {}", tmp.display()))?;
    f.write_all(bytes).with_context(|| format!("writing temp {}", tmp.display()))?;
    f.sync_all().with_context(|| format!("fsync temp {}", tmp.display()))?;
    std::fs::rename(tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        std::fs::write("/tmp/x", b"bytes").unwrap();
    }
}
