// Known-good fixture: every fused API under the parity contract is
// referenced — some by direct call, some by `// parity:` marker next to
// the test that covers the API indirectly.

#[test]
fn pair_forwards_match_sequential() {
    check(net.forward_pair(&a, &b));
    check(net.forward_train_pair(&a, &b));
}

#[test]
fn pooled_backends_match_serial() {
    // parity: run_spans
    // parity: run_chunked
    // parity: fuse_group
    // parity: par_step_into
    run_all_backends();
}

#[test]
fn serve_and_replay_match_reference() {
    // parity: act_batch
    // parity: sample_round_into
    serve_round();
}

#[test]
fn half_storage_matches_f32_tier() {
    check(gemm_nt_bias_q_half(&a, &b, fmt, &mut c, m, k, n, None, prec));
    // parity: gemm_nt_bias_q_pair_half
    run_packed_critic_pair();
}

#[test]
fn f32_simd_tier_matches_scalar_oracle() {
    check(gemm_bias_q_at(level, &a, &b, &mut c, m, k, n, None, prec));
    check(gemm_nt_bias_q_at(level, &a, &bt, &mut c, m, k, n, None, prec));
    check(gemm_tn_bias_q_at(level, &at, &b, &mut c, m, k, n, None, prec));
    check(quantize_slice_rne_at(level, e, mb, &mut xs));
    // parity: pack_half_slice_at
    // parity: unpack_half_slice_at
    run_half_pack_parity();
}
