// Known-bad fixture (scanned as a non-lowp module): raw float bit
// twiddling outside lowp/ without a tidy-allow escape.

pub fn truncate_mantissa(x: f32) -> f32 {
    f32::from_bits(x.to_bits() & 0xffff_0000)
}
