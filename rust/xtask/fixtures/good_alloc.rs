// Known-good fixture: the hot path writes into preallocated scratch;
// cold construction allocates freely (unreachable from the hot roots),
// and the one warm-path allocation carries an audited escape.

pub struct SacAgent {
    buf: Vec<f32>,
}

impl SacAgent {
    /// Cold constructor — not reachable from `update_round`, so its
    /// allocations are fine without an escape.
    pub fn new(cap: usize) -> SacAgent {
        SacAgent { buf: Vec::with_capacity(cap) }
    }

    pub fn update_round(&mut self) {
        self.step();
        self.warm();
    }

    fn step(&mut self) {
        self.buf.fill(0.0);
    }

    fn warm(&mut self) {
        // tidy-allow(alloc): one-time warmup buffer, reused afterwards
        let w: Vec<f32> = Vec::with_capacity(8);
        self.buf.extend_from_slice(&w);
    }
}

/// Free fn that allocates but is reachable from no hot entry point.
pub fn cold_report() -> String {
    format!("buffered")
}
