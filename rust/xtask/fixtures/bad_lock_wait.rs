// Known-bad fixture: the loop re-locks `done` on every wakeup while
// parked on the condvar guarding `job` — the waker needing `done` can
// be starved by the sleeper.

use std::sync::{Condvar, Mutex};

pub struct Queue {
    job: Mutex<u32>,
    done: Mutex<bool>,
    cv: Condvar,
}

impl Queue {
    pub fn drain(&self) {
        let mut g = self.job.lock().unwrap();
        loop {
            let d = self.done.lock().unwrap();
            if *d {
                break;
            }
            drop(d);
            g = self.cv.wait(g).unwrap();
        }
    }
}
