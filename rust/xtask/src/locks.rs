//! Lock-order analysis over the threaded modules
//! (`coordinator/pipeline.rs`, `serve/`, `nn/pool.rs`).
//!
//! Within each function body the pass tracks `let g = <name>.lock()`
//! guard bindings (a guard dies when the brace depth drops below its
//! acquisition depth, or at `drop(g)`), records an edge `A -> B`
//! whenever `B` is acquired while a guard on `A` is live, and fails on:
//!
//! * a cycle in the acquisition-order graph (classic ABBA deadlock
//!   shape), or
//! * an **innermost** loop whose body both acquires a lock `X` and
//!   blocks on a condvar whose guard belongs to a different lock `Y`
//!   (re-locking X every wakeup while parked on Y starves the waker).
//!   The innermost scoping matters: an outer collection loop may
//!   legitimately touch a completion lock after an inner wait loop on
//!   the work lock finishes (the pool's `worker_loop` does exactly
//!   this).

use crate::parse::FnItem;
use crate::scan::SourceFile;
use crate::Diag;
use std::collections::{BTreeMap, BTreeSet};

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `<ident> . (try_)? lock ()` sites: `(lock name, ident char pos)`.
fn lock_sites(ch: &[char]) -> Vec<(String, usize)> {
    let n = ch.len();
    let mut out = Vec::new();
    for i in 0..n {
        if ch[i] != '.' {
            continue;
        }
        let mut q = i + 1;
        while q < n && ch[q].is_whitespace() {
            q += 1;
        }
        let mut w = q;
        while w < n && is_ident_char(ch[w]) {
            w += 1;
        }
        let word: String = ch[q..w].iter().collect();
        if word != "lock" && word != "try_lock" {
            continue;
        }
        let mut x = w;
        while x < n && ch[x].is_whitespace() {
            x += 1;
        }
        if !(x + 1 < n && ch[x] == '(' && ch[x + 1] == ')') {
            continue;
        }
        // identifier immediately before the dot
        let mut b = i;
        while b > 0 && ch[b - 1].is_whitespace() {
            b -= 1;
        }
        let e = b;
        while b > 0 && is_ident_char(ch[b - 1]) {
            b -= 1;
        }
        if b < e {
            out.push((ch[b..e].iter().collect(), b));
        }
    }
    out
}

/// First `let [mut] <name>` on the line: `(let char pos, binding)`.
fn first_let(ch: &[char]) -> Option<(usize, String)> {
    let n = ch.len();
    let mut i = 0;
    while i < n {
        if !(ch[i].is_alphabetic() || ch[i] == '_') {
            i += 1;
            continue;
        }
        let s = i;
        let mut e = i;
        while e < n && is_ident_char(ch[e]) {
            e += 1;
        }
        let word: String = ch[s..e].iter().collect();
        i = e;
        if word != "let" {
            continue;
        }
        let mut q = e;
        while q < n && ch[q].is_whitespace() {
            q += 1;
        }
        let mut w = q;
        while w < n && is_ident_char(ch[w]) {
            w += 1;
        }
        let mut name: String = ch[q..w].iter().collect();
        if name == "mut" {
            let mut q2 = w;
            while q2 < n && ch[q2].is_whitespace() {
                q2 += 1;
            }
            let mut w2 = q2;
            while w2 < n && is_ident_char(ch[w2]) {
                w2 += 1;
            }
            name = ch[q2..w2].iter().collect();
        }
        if name.is_empty() {
            return None;
        }
        return Some((s, name));
    }
    None
}

/// `.wait(g)` / `.wait_while(g, ..)` / `.wait_timeout(g, ..)` guard
/// arguments.
fn wait_guards(ch: &[char]) -> Vec<String> {
    let n = ch.len();
    let mut out = Vec::new();
    for i in 0..n {
        if ch[i] != '.' {
            continue;
        }
        let mut q = i + 1;
        while q < n && ch[q].is_whitespace() {
            q += 1;
        }
        let mut w = q;
        while w < n && is_ident_char(ch[w]) {
            w += 1;
        }
        let word: String = ch[q..w].iter().collect();
        if !matches!(word.as_str(), "wait" | "wait_while" | "wait_timeout") {
            continue;
        }
        let mut x = w;
        while x < n && ch[x].is_whitespace() {
            x += 1;
        }
        if x >= n || ch[x] != '(' {
            continue;
        }
        let mut g = x + 1;
        while g < n && ch[g].is_whitespace() {
            g += 1;
        }
        let mut ge = g;
        while ge < n && is_ident_char(ch[ge]) {
            ge += 1;
        }
        if ge > g {
            out.push(ch[g..ge].iter().collect());
        }
    }
    out
}

/// `drop(<ident>)` call arguments.
fn drop_targets(ch: &[char]) -> Vec<String> {
    let n = ch.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        if !(ch[i].is_alphabetic() || ch[i] == '_') {
            i += 1;
            continue;
        }
        let s = i;
        let mut e = i;
        while e < n && is_ident_char(ch[e]) {
            e += 1;
        }
        let word: String = ch[s..e].iter().collect();
        i = e;
        if word != "drop" || (s > 0 && is_ident_char(ch[s - 1])) {
            continue;
        }
        let mut q = e;
        while q < n && ch[q].is_whitespace() {
            q += 1;
        }
        if q >= n || ch[q] != '(' {
            continue;
        }
        let mut g = q + 1;
        while g < n && ch[g].is_whitespace() {
            g += 1;
        }
        let mut ge = g;
        while ge < n && is_ident_char(ch[ge]) {
            ge += 1;
        }
        let mut r = ge;
        while r < n && ch[r].is_whitespace() {
            r += 1;
        }
        if ge > g && r < n && ch[r] == ')' {
            out.push(ch[g..ge].iter().collect());
        }
    }
    out
}

fn in_scope(rel: &str) -> bool {
    rel.ends_with("coordinator/pipeline.rs") || rel.contains("serve/") || rel.ends_with("nn/pool.rs")
}

/// Tracked state for one innermost loop: open depth, locks acquired in
/// its body, condvar waits `(lock of guard, 1-based line)`, header line.
struct LoopInfo {
    open_depth: i32,
    locks: BTreeSet<String>,
    waits: BTreeSet<(String, usize)>,
    first_line: usize,
}

/// Acquisition-order edges: `(held, acquired) -> first (file, line)`.
pub type LockEdges = BTreeMap<(String, String), (String, usize)>;

/// Run the lock-order pass. Returns the diagnostics and the
/// acquisition-order edges.
pub fn lock_pass(files: &[SourceFile], fns: &[FnItem]) -> (Vec<Diag>, LockEdges) {
    let mut diags = Vec::new();
    let mut edges: LockEdges = BTreeMap::new();
    for f in fns {
        let file = &files[f.file];
        if !in_scope(&file.rel) {
            continue;
        }
        let end = f
            .body_end
            .unwrap_or(file.lines.len().saturating_sub(1))
            .min(file.lines.len().saturating_sub(1));
        let mut held: Vec<(String, String, i32)> = Vec::new(); // (binding, lock, depth)
        let mut bindings: BTreeMap<String, String> = BTreeMap::new();
        let mut depth = 0i32;
        let mut loops: Vec<LoopInfo> = Vec::new();
        for li in f.body_start..=end {
            if file.mask[li] {
                continue;
            }
            let code = &file.lines[li].code;
            let ch: Vec<char> = code.chars().collect();
            let mut opens_loop = ["loop", "while", "for"]
                .iter()
                .any(|t| crate::scan::has_token(code, t));
            for (lock, pos) in lock_sites(&ch) {
                for (_, h, _) in &held {
                    if *h != lock {
                        edges
                            .entry((h.clone(), lock.clone()))
                            .or_insert_with(|| (file.rel.clone(), li + 1));
                    }
                }
                for lp in loops.iter_mut() {
                    lp.locks.insert(lock.clone());
                }
                if let Some((lpos, binding)) = first_let(&ch) {
                    if lpos < pos {
                        bindings.insert(binding.clone(), lock.clone());
                        held.push((binding, lock.clone(), depth));
                    }
                }
            }
            for g in wait_guards(&ch) {
                if let Some(lock) = bindings.get(&g) {
                    if let Some(lp) = loops.last_mut() {
                        lp.waits.insert((lock.clone(), li + 1));
                    }
                }
            }
            for d in drop_targets(&ch) {
                held.retain(|(b, _, _)| *b != d);
            }
            for c in code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        if opens_loop {
                            loops.push(LoopInfo {
                                open_depth: depth,
                                locks: BTreeSet::new(),
                                waits: BTreeSet::new(),
                                first_line: li + 1,
                            });
                            opens_loop = false;
                        }
                    }
                    '}' => {
                        depth -= 1;
                        held.retain(|&(_, _, d)| d <= depth);
                        while loops.last().is_some_and(|lp| lp.open_depth > depth) {
                            let Some(lp) = loops.pop() else { break };
                            for (wl, wline) in &lp.waits {
                                for l in &lp.locks {
                                    if l != wl {
                                        diags.push(Diag {
                                            file: file.rel.clone(),
                                            line: *wline,
                                            rule: "lock-order",
                                            msg: format!(
                                                "loop at line {} locks `{l}` and waits on a \
                                                 condvar of `{wl}` — split the loop or wait \
                                                 and lock under the same mutex",
                                                lp.first_line
                                            ),
                                        });
                                    }
                                }
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
    }
    // cycle detection over the acquisition-order graph
    let mut graph: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    let mut nodes: BTreeSet<&str> = BTreeSet::new();
    for (a, b) in edges.keys() {
        graph.entry(a.as_str()).or_default().insert(b.as_str());
        nodes.insert(a.as_str());
        nodes.insert(b.as_str());
    }
    #[derive(Clone, Copy, PartialEq)]
    enum Color {
        White,
        Gray,
        Black,
    }
    fn dfs<'a>(
        n: &'a str,
        path: &mut Vec<&'a str>,
        graph: &BTreeMap<&'a str, BTreeSet<&'a str>>,
        color: &mut BTreeMap<&'a str, Color>,
        diags: &mut Vec<Diag>,
    ) {
        color.insert(n, Color::Gray);
        path.push(n);
        for &m in graph.get(n).into_iter().flatten() {
            match color.get(m) {
                Some(Color::Gray) => {
                    let mut cyc: Vec<&str> = path.clone();
                    cyc.push(m);
                    diags.push(Diag {
                        file: "lock-graph".to_string(),
                        line: 0,
                        rule: "lock-order",
                        msg: format!("lock-order cycle: {}", cyc.join(" -> ")),
                    });
                }
                Some(Color::White) => dfs(m, path, graph, color, diags),
                _ => {}
            }
        }
        path.pop();
        color.insert(n, Color::Black);
    }
    let mut color: BTreeMap<&str, Color> = nodes.iter().map(|&n| (n, Color::White)).collect();
    for &n in &nodes {
        if color.get(n) == Some(&Color::White) {
            dfs(n, &mut Vec::new(), &graph, &mut color, &mut diags);
        }
    }
    (diags, edges)
}
