//! Item-level parser: a brace/paren-aware token walk over the blanked
//! code that recovers `impl` blocks and `fn` items with their body
//! extents. Deliberately approximate — no expression grammar — but
//! exact about the two things the semantic passes need: which lines
//! belong to which function, and which impl type owns it.
//!
//! The two disambiguation rules that make this work on real code:
//!
//! * `impl` / `trait` / `fn` keywords only open an item when they sit
//!   at **item position**: paren depth zero, preceded (after
//!   whitespace) by one of `; { } ] )` or an item-qualifier word
//!   (`pub`, `unsafe`, `const`, `async`, `extern`, `default`). This
//!   keeps `impl Fn(usize)` in an argument list from opening a bogus
//!   impl scope.
//! * A `fn`'s own signature is not a call site (the later call
//!   extractor skips an identifier-before-`(` whose preceding word is
//!   `fn`).

use crate::scan::SourceFile;

/// One parsed `fn` item (test-gated fns are skipped at parse time).
#[derive(Debug)]
pub struct FnItem {
    /// Index into the file list handed to [`parse_fns`].
    pub file: usize,
    /// Enclosing `impl`/`trait` type name, if any (`Self` resolved).
    pub impl_ty: Option<String>,
    /// 0-based line of the `fn` keyword.
    pub sig_line: usize,
    /// 0-based line of the body `{`.
    pub body_start: usize,
    /// 0-based line of the matching `}` (None if unclosed at EOF).
    pub body_end: Option<usize>,
    pub name: String,
}

impl FnItem {
    /// Display key: `Type::name` (or `::name` for free fns).
    pub fn key(&self) -> String {
        format!("{}::{}", self.impl_ty.as_deref().unwrap_or(""), self.name)
    }
}

const ITEM_QUALIFIERS: &[&str] = &["unsafe", "pub", "const", "async", "extern", "default"];

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// True if the keyword starting at `i` sits at item/statement position.
fn item_position(ch: &[char], i: usize) -> bool {
    let mut j = i as isize - 1;
    while j >= 0 && matches!(ch[j as usize], ' ' | '\t' | '\n') {
        j -= 1;
    }
    if j < 0 {
        return true;
    }
    let c = ch[j as usize];
    if matches!(c, ';' | '{' | '}' | ']' | ')') {
        return true;
    }
    let mut k = j;
    while k >= 0 && is_ident_char(ch[k as usize]) {
        k -= 1;
    }
    let word: String = ch[(k + 1) as usize..=j as usize].iter().collect();
    ITEM_QUALIFIERS.contains(&word.as_str())
}

/// Drop balanced `<...>` generics from an impl header.
fn strip_generics(s: &str) -> String {
    let mut out = String::new();
    let mut d = 0usize;
    for c in s.chars() {
        match c {
            '<' => d += 1,
            '>' => d = d.saturating_sub(1),
            _ if d == 0 => out.push(c),
            _ => {}
        }
    }
    out
}

/// Leading identifier of `s` (after trimming), if any.
fn first_ident(s: &str) -> Option<String> {
    let t = s.trim_start();
    let end = t.find(|c: char| !is_ident_char(c)).unwrap_or(t.len());
    let id = &t[..end];
    (!id.is_empty() && !id.starts_with(|c: char| c.is_ascii_digit())).then(|| id.to_string())
}

/// Self type of an impl header (the text between `impl` and `{`):
/// strip generics, take the right side of ` for `, drop any `where`
/// clause, then the last `::` path segment's leading identifier.
fn impl_type_of(header: &str) -> Option<String> {
    let mut h = strip_generics(header);
    if let Some(p) = h.find(" for ") {
        h = h[p + " for ".len()..].to_string();
    }
    let mut h = h.trim().to_string();
    if let Some(p) = h.find("where") {
        h = h[..p].trim().to_string();
    }
    let seg = h.rsplit("::").next().unwrap_or("").trim();
    first_ident(seg)
}

/// Scan forward from `k` for the body `{` (or a terminating `;`) at
/// paren depth zero. Returns the index of that char, or `ch.len()`.
fn find_body_open(ch: &[char], mut k: usize) -> usize {
    let mut par = 0i32;
    while k < ch.len() {
        match ch[k] {
            '(' => par += 1,
            ')' => par -= 1,
            '{' | ';' if par == 0 => break,
            _ => {}
        }
        k += 1;
    }
    k
}

/// Parse every non-test `fn` item in `files`, attributing each to its
/// enclosing impl/trait type and recording body line extents.
pub fn parse_fns(files: &[SourceFile]) -> Vec<FnItem> {
    enum Scope {
        Impl(Option<String>),
        Fn(Option<usize>),
    }
    let mut fns: Vec<FnItem> = Vec::new();
    for (fidx, file) in files.iter().enumerate() {
        let text: String =
            file.lines.iter().map(|l| l.code.as_str()).collect::<Vec<_>>().join("\n");
        let ch: Vec<char> = text.chars().collect();
        let n = ch.len();
        // char index -> 0-based line number
        let mut line_of = Vec::with_capacity(n + 1);
        let mut ln = 0usize;
        for &c in &ch {
            line_of.push(ln);
            if c == '\n' {
                ln += 1;
            }
        }
        line_of.push(ln);
        let mut depth = 0i32;
        let mut par = 0i32;
        let mut scopes: Vec<(Scope, i32)> = Vec::new();
        let mut i = 0usize;
        while i < n {
            let c = ch[i];
            if c.is_alphabetic() || c == '_' {
                let mut j = i;
                while j < n && is_ident_char(ch[j]) {
                    j += 1;
                }
                let ident: String = ch[i..j].iter().collect();
                if (ident == "impl" || ident == "trait") && par == 0 && item_position(&ch, i) {
                    let k = find_body_open(&ch, j);
                    par = 0;
                    if k < n && ch[k] == '{' {
                        let header: String = ch[j..k].iter().collect();
                        let ty = if ident == "impl" {
                            impl_type_of(&header)
                        } else {
                            first_ident(&header)
                        };
                        scopes.push((Scope::Impl(ty), depth));
                    }
                    i = k;
                    continue;
                }
                if ident == "fn" && par == 0 && item_position(&ch, i) {
                    // fn name follows directly (after whitespace)
                    let mut s = j;
                    while s < n && ch[s].is_whitespace() {
                        s += 1;
                    }
                    let mut e = s;
                    while e < n && is_ident_char(ch[e]) {
                        e += 1;
                    }
                    if e == s {
                        i = j;
                        continue;
                    }
                    let name: String = ch[s..e].iter().collect();
                    let sig_line = line_of[i];
                    let k = find_body_open(&ch, e);
                    par = 0;
                    if k >= n || ch[k] == ';' {
                        i = k.min(n);
                        continue;
                    }
                    let impl_ty = scopes.iter().rev().find_map(|(sc, _)| match sc {
                        Scope::Impl(ty) => Some(ty.clone()),
                        Scope::Fn(_) => None,
                    });
                    if !file.mask[sig_line] {
                        scopes.push((Scope::Fn(Some(fns.len())), depth));
                        fns.push(FnItem {
                            file: fidx,
                            impl_ty: impl_ty.flatten(),
                            sig_line,
                            body_start: line_of[k],
                            body_end: None,
                            name,
                        });
                    } else {
                        scopes.push((Scope::Fn(None), depth));
                    }
                    i = k;
                    continue;
                }
                i = j;
                continue;
            }
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    while scopes.last().is_some_and(|&(_, d)| d == depth) {
                        if let Some((Scope::Fn(Some(idx)), _)) = scopes.pop() {
                            fns[idx].body_end = Some(line_of[i]);
                        }
                    }
                }
                '(' => par += 1,
                ')' => par = (par - 1).max(0),
                _ => {}
            }
            i += 1;
        }
    }
    fns
}
