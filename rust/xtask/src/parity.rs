//! Parity-contract coverage map: every fused/pooled API whose output
//! is claimed bitwise-identical to a reference path must be pinned by
//! at least one test under `rust/tests/` — either by calling the API
//! or by carrying a `// parity: <api>` marker next to the test that
//! covers it indirectly.

use crate::scan::{has_token, SourceFile};
use crate::Diag;

/// The fused APIs under parity contract (see INVARIANTS.md
/// "Parity-coverage contract").
pub const PARITY_APIS: &[&str] = &[
    "forward_pair",
    "forward_train_pair",
    "par_step_into",
    "run_spans",
    "run_chunked",
    "fuse_group",
    "act_batch",
    "sample_round_into",
    "gemm_nt_bias_q_half",
    "gemm_nt_bias_q_pair_half",
    "gemm_bias_q_at",
    "gemm_nt_bias_q_at",
    "gemm_tn_bias_q_at",
    "quantize_slice_rne_at",
    "pack_half_slice_at",
    "unpack_half_slice_at",
];

/// True if any line in `test_files` references `api` by token or by a
/// `// parity:` marker comment.
fn referenced(test_files: &[SourceFile], api: &str) -> bool {
    test_files.iter().any(|f| {
        f.lines.iter().any(|l| {
            has_token(&l.code, api)
                || (l.comment.contains("parity:") && l.comment.contains(api))
        })
    })
}

/// Flag every parity-contract API with no reference in `rust/tests/`.
pub fn parity_pass(test_files: &[SourceFile]) -> Vec<Diag> {
    PARITY_APIS
        .iter()
        .filter(|api| !referenced(test_files, api))
        .map(|api| Diag {
            file: "rust/tests".to_string(),
            line: 0,
            rule: "parity",
            msg: format!(
                "fused API `{api}` has no test reference in rust/tests/ — call it from a \
                 test or add a `// parity: {api}` marker next to the covering test"
            ),
        })
        .collect()
}
