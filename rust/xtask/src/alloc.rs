//! Hot-path allocation lint: no function reachable from the hot entry
//! points may contain an allocating expression unless the line carries
//! a `// tidy-allow(alloc): <reason>` escape.
//!
//! Matching is plain-substring over blanked code (not token-bounded):
//! `.clone()` must not match `clone_from`, but `vec!` must match
//! `vec![`. Known miss, documented in INVARIANTS.md: a turbofished
//! `.collect::<Vec<_>>()` does not match `.collect()`.

use crate::graph::{hot_reachability, owned_by_nested};
use crate::parse::FnItem;
use crate::scan::{allowed, SourceFile};
use crate::Diag;
use std::collections::BTreeSet;

/// Expressions that take the heap lock. Sanctioned allocation-free
/// idioms (`.push` into reserved capacity, `ensure_shape`,
/// `clone_from`, `fill`, `extend_from_slice`) are deliberately absent.
pub const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "vec!",
    "with_capacity",
    ".to_vec()",
    ".collect()",
    ".clone()",
    "Box::new",
    "format!",
];

/// True if `code` contains any allocating expression.
pub fn has_alloc_token(code: &str) -> bool {
    ALLOC_TOKENS.iter().any(|t| code.contains(t))
}

/// Run the allocation lint over the parsed source tree.
pub fn alloc_pass(
    files: &[SourceFile],
    fns: &[FnItem],
    edges: &[BTreeSet<usize>],
) -> Vec<Diag> {
    let reach = hot_reachability(fns, edges);
    let mut diags = Vec::new();
    for (idx, f) in fns.iter().enumerate() {
        let Some(via) = &reach[idx] else { continue };
        let file = &files[f.file];
        let end = f.body_end.unwrap_or(file.lines.len().saturating_sub(1));
        for li in f.sig_line..=end.min(file.lines.len().saturating_sub(1)) {
            if file.mask[li] || owned_by_nested(fns, idx, li) {
                continue;
            }
            let code = &file.lines[li].code;
            for tok in ALLOC_TOKENS {
                if code.contains(tok) && !allowed(&file.lines, li, "alloc") {
                    diags.push(Diag {
                        file: file.rel.clone(),
                        line: li + 1,
                        rule: "alloc",
                        msg: format!(
                            "`{tok}` in hot fn `{}` (reachable from `{via}`); make it \
                             allocation-free or escape with `// tidy-allow(alloc): <reason>`",
                            f.key()
                        ),
                    });
                    break; // one alloc diag per line
                }
            }
        }
    }
    diags
}
