//! Lexical layer: split source text into per-line (code, comment) pairs
//! with string/char literals and comments blanked, plus the token and
//! comment-block helpers every pass builds on.
//!
//! Blanking happens before any rule matching, so tokens inside docs or
//! message strings can never trip a rule; `//` comment text is kept
//! separately for the `SAFETY:` / `tidy-allow` lookups.

/// One source line after scanning: code with comments/strings blanked,
/// plus the text of any `//` comment that appeared on the line.
#[derive(Debug, Default)]
pub struct Line {
    pub code: String,
    pub comment: String,
}

/// One scanned source file: repo-relative path (forward slashes),
/// scanned lines, and the `#[cfg(test)]` mask.
pub struct SourceFile {
    pub rel: String,
    pub lines: Vec<Line>,
    pub mask: Vec<bool>,
}

impl SourceFile {
    pub fn new(rel: &str, text: &str) -> SourceFile {
        let lines = scan(text);
        let mask = test_mask(&lines);
        SourceFile { rel: rel.to_string(), lines, mask }
    }
}

/// Length of the char literal starting at `ch[i] == '\''`, or `None`
/// if this quote is a lifetime. Handles `'a'`, `'\n'`, `'\''`, `'\u{..}'`.
fn char_lit_len(ch: &[char], i: usize) -> Option<usize> {
    let next = *ch.get(i + 1)?;
    if next == '\\' {
        (3..12).find(|&k| ch.get(i + k) == Some(&'\'')).map(|k| k + 1)
    } else if next != '\'' && ch.get(i + 2) == Some(&'\'') {
        Some(3)
    } else {
        None
    }
}

/// If `ch[j..]` is `#*"` (a raw-string opener after `r`), the hash count.
fn raw_open(ch: &[char], j: usize) -> Option<usize> {
    let mut h = 0;
    while ch.get(j + h) == Some(&'#') {
        h += 1;
    }
    (ch.get(j + h) == Some(&'"')).then_some(h)
}

/// Split source text into [`Line`]s: comments, string literals, and
/// char literals are blanked out of `code`; `//` comment text (doc or
/// plain) is collected into `comment`.
pub fn scan(text: &str) -> Vec<Line> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        Block(usize),
        Str,
        RawStr(usize),
    }
    let ch: Vec<char> = text.chars().collect();
    let n = ch.len();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut st = St::Code;
    let mut i = 0;
    while i < n {
        let c = ch[i];
        let next = if i + 1 < n { ch[i + 1] } else { '\0' };
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let prev_ident = i > 0 && (ch[i - 1].is_alphanumeric() || ch[i - 1] == '_');
                if c == '/' && next == '/' {
                    st = St::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                } else if c == '/' && next == '*' {
                    st = St::Block(1);
                    cur.code.push(' ');
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur.code.push(' ');
                    i += 1;
                } else if c == 'r' && !prev_ident && raw_open(&ch, i + 1).is_some() {
                    let h = raw_open(&ch, i + 1).unwrap_or(0);
                    st = St::RawStr(h);
                    cur.code.push(' ');
                    i += 2 + h;
                } else if c == '\'' {
                    match char_lit_len(&ch, i) {
                        Some(len) => {
                            cur.code.push(' ');
                            i += len;
                        }
                        None => {
                            cur.code.push(c);
                            i += 1;
                        }
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::Block(d) => {
                if c == '*' && next == '/' {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    i += 2;
                } else if c == '/' && next == '*' {
                    st = St::Block(d + 1);
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '"' {
                        st = St::Code;
                    }
                    i += 1;
                }
            }
            St::RawStr(h) => {
                let closes = c == '"'
                    && ch.get(i + 1..i + 1 + h).is_some_and(|s| s.iter().all(|&x| x == '#'));
                if closes {
                    st = St::Code;
                    i += 1 + h;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// True if `code` contains `tok` bounded by non-identifier characters.
pub fn has_token(code: &str, tok: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let p = start + pos;
        let before_ok =
            code[..p].chars().next_back().is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        let after_ok = code[p + tok.len()..]
            .chars()
            .next()
            .is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        if before_ok && after_ok {
            return true;
        }
        start = p + tok.len();
    }
    false
}

/// Mark lines inside `#[cfg(test)]`-gated items (attribute through the
/// matching close brace, via brace counting over blanked code).
pub fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0usize;
        let mut opened = false;
        let mut j = i;
        'item: while j < lines.len() {
            mask[j] = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth = depth.saturating_sub(1);
                        if opened && depth == 0 {
                            break 'item;
                        }
                    }
                    ';' if !opened => break 'item, // braceless item (use, decl)
                    _ => {}
                }
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// True if the comment block covering `lines[i]` satisfies `pred`: a
/// trailing comment on the line itself, or the contiguous `//` block
/// directly above (skipping attributes and doc comments; when
/// `through_unsafe_runs`, also skipping adjacent lines that themselves
/// contain `unsafe`, so one `// SAFETY:` header can cover a run).
pub fn covered(
    lines: &[Line],
    i: usize,
    through_unsafe_runs: bool,
    pred: impl Fn(&str) -> bool,
) -> bool {
    if pred(&lines[i].comment) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        let com = lines[j].comment.trim();
        if code.is_empty() && com.is_empty() {
            return false; // blank line terminates the block
        }
        if code.is_empty() {
            if com.starts_with("///") || com.starts_with("//!") {
                continue; // doc comments are transparent
            }
            if pred(com) {
                return true;
            }
            continue;
        }
        if code.starts_with('#') {
            continue; // attributes are transparent
        }
        if through_unsafe_runs && has_token(code, "unsafe") {
            if pred(com) {
                return true;
            }
            continue;
        }
        return pred(com);
    }
    false
}

/// True if a well-formed `// tidy-allow(<rule>): <reason>` covers line `i`.
pub fn allowed(lines: &[Line], i: usize, rule: &str) -> bool {
    let needle = format!("tidy-allow({rule}):");
    covered(lines, i, false, |c| {
        c.find(&needle).is_some_and(|p| !c[p + needle.len()..].trim().is_empty())
    })
}
