//! Stale-suppression detection: every well-formed
//! `// tidy-allow(<rule>): <reason>` must still cover a line that the
//! named rule would actually fire on. An allow whose target line was
//! refactored away is dead weight that silently blesses future
//! regressions — this pass makes it a diagnostic instead.
//!
//! Target resolution (lexical, mirrors how `allowed` searches upward):
//! an inline allow targets its own line; a comment-line allow targets
//! the first code line below it, skipping comment-only and attribute
//! lines, stopping at a fully blank line.

use crate::alloc::has_alloc_token;
use crate::scan::{has_token, Line};
use crate::{Diag, ALLOWABLE_RULES, DETERMINISM_TOKENS, SIMD_TOKENS};

/// Would `rule` ever fire on a line whose blanked code is `code`?
fn line_triggers(rule: &str, code: &str) -> bool {
    match rule {
        "determinism" => DETERMINISM_TOKENS.iter().any(|&(t, _)| has_token(code, t)),
        "precision" => has_token(code, "to_bits") || has_token(code, "from_bits"),
        "simd" => SIMD_TOKENS.iter().any(|t| code.contains(t)),
        "panic" => code.contains(".unwrap()") || code.contains(".expect("),
        "ckpt-io" => {
            code.contains("File::create")
                || code.contains("fs::write")
                || code.contains(".unwrap()")
                || code.contains(".expect(")
        }
        "alloc" => has_alloc_token(code),
        _ => true,
    }
}

/// Flag well-formed allows that no longer cover a rule-relevant line.
pub fn stale_pass(rel: &str, lines: &[Line]) -> Vec<Diag> {
    let mut diags = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        let Some(p) = l.comment.find("tidy-allow(") else { continue };
        let rest = &l.comment[p + "tidy-allow(".len()..];
        let Some(q) = rest.find(')') else { continue };
        let rule = &rest[..q];
        let reason = rest[q + 1..].trim_start();
        let well_formed = ALLOWABLE_RULES.contains(&rule)
            && reason.strip_prefix(':').is_some_and(|r| !r.trim().is_empty());
        if !well_formed {
            continue; // allow-syntax owns malformed/unknown allows
        }
        // target line: this line if it has code, else the next code
        // line below (comments/attributes transparent, blank stops)
        let target = if !l.code.trim().is_empty() {
            Some(i)
        } else {
            let mut tgt = None;
            for (j, l2) in lines.iter().enumerate().skip(i + 1) {
                let c2 = l2.code.trim();
                if c2.is_empty() && l2.comment.trim().is_empty() {
                    break;
                }
                if !c2.is_empty() && !c2.starts_with('#') {
                    tgt = Some(j);
                    break;
                }
            }
            tgt
        };
        if target.is_none_or(|t| !line_triggers(rule, &lines[t].code)) {
            diags.push(Diag {
                file: rel.to_string(),
                line: i + 1,
                rule: "stale-allow",
                msg: format!(
                    "tidy-allow({rule}) does not cover a {rule}-relevant line — \
                     remove the stale escape"
                ),
            });
        }
    }
    diags
}
