//! Intra-crate call-graph approximation over the parsed `fn` items,
//! and the hot-entry set the allocation lint starts from.
//!
//! Resolution is name-based and deliberately over-approximate (an
//! unresolvable receiver type falls back to "every impl fn with that
//! name"), which is the safe direction for a lint: a spurious edge can
//! only make the checker ask for an annotation, never miss a real
//! allocation. Three call shapes are recognized on each blanked line:
//!
//! * `.m(`        — method: every impl fn named `m`
//! * `Type::m(`   — qualified: fns in `impl Type` (`Self` resolves to
//!   the enclosing impl type; an unknown `Type` resolves to nothing)
//! * `m(`         — bare: free fns named `m`, plus same-impl siblings
//!
//! Macros (`name!(`) and the `fn` keyword of a signature are excluded.

use crate::parse::FnItem;
use crate::scan::SourceFile;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

const KEYWORDS: &[&str] = &[
    "if", "else", "while", "for", "loop", "match", "return", "let", "mut", "fn", "pub", "impl",
    "trait", "struct", "enum", "use", "mod", "const", "static", "ref", "move", "in", "as",
    "break", "continue", "where", "unsafe", "dyn", "type", "crate", "super", "self", "Self",
    "true", "false",
];

/// One call site extracted from a line of blanked code.
pub enum Call {
    Method(String),
    Qualified(Option<String>, String),
    Bare(String),
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Trailing identifier of `s`, if `s` ends with one.
fn last_ident(s: &str) -> Option<&str> {
    let e = s.len();
    let b = s.rfind(|c: char| !is_ident_char(c)).map_or(0, |p| p + c_len(s, p));
    (b < e).then(|| &s[b..e])
}

fn c_len(s: &str, p: usize) -> usize {
    s[p..].chars().next().map_or(1, char::len_utf8)
}

/// Extract the calls on one line. `cur_impl` resolves `Self::`.
pub fn calls_on_line(code: &str, cur_impl: Option<&str>) -> Vec<Call> {
    let ch: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    let n = ch.len();
    while i < n {
        let c = ch[i];
        if !(c.is_alphabetic() || c == '_') {
            i += 1;
            continue;
        }
        let s = i;
        let mut e = i;
        while e < n && is_ident_char(ch[e]) {
            e += 1;
        }
        // whitespace then `(` makes it a call; `!` makes it a macro
        let mut p = e;
        while p < n && ch[p].is_whitespace() {
            p += 1;
        }
        if p >= n || ch[p] != '(' {
            i = e;
            continue;
        }
        let name: String = ch[s..e].iter().collect();
        i = e;
        if KEYWORDS.contains(&name.as_str()) {
            continue;
        }
        let before: String = ch[..s].iter().collect();
        let before = before.trim_end();
        if last_ident(before) == Some("fn") {
            continue; // a signature is not a call
        }
        if before.ends_with('.') {
            out.push(Call::Method(name));
        } else if before.ends_with("::") {
            let ty = last_ident(before[..before.len() - 2].trim_end()).map(|t| {
                if t == "Self" { cur_impl.unwrap_or(t).to_string() } else { t.to_string() }
            });
            out.push(Call::Qualified(ty, name));
        } else {
            out.push(Call::Bare(name));
        }
    }
    out
}

/// True if `lines[li]` of file `f.file` belongs to a fn nested inside
/// `f` (closures keep their lines; only named nested fns steal them).
pub fn owned_by_nested(fns: &[FnItem], idx: usize, li: usize) -> bool {
    let f = &fns[idx];
    let f_end = f.body_end.unwrap_or(usize::MAX);
    fns.iter().enumerate().any(|(jdx, g)| {
        jdx != idx
            && g.file == f.file
            && g.body_end.is_some_and(|ge| {
                g.body_start >= f.body_start
                    && ge <= f_end
                    && g.body_start <= li
                    && li <= ge
            })
    })
}

/// Build the call graph: `edges[i]` is the set of fns `i` may call.
pub fn build_graph(files: &[SourceFile], fns: &[FnItem]) -> Vec<BTreeSet<usize>> {
    let mut by_impl: BTreeMap<(&str, &str), Vec<usize>> = BTreeMap::new();
    let mut impl_by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    let mut free_by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (idx, f) in fns.iter().enumerate() {
        match &f.impl_ty {
            Some(ty) => {
                by_impl.entry((ty.as_str(), f.name.as_str())).or_default().push(idx);
                impl_by_name.entry(f.name.as_str()).or_default().push(idx);
            }
            None => free_by_name.entry(f.name.as_str()).or_default().push(idx),
        }
    }
    let mut edges: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); fns.len()];
    for (idx, f) in fns.iter().enumerate() {
        let file = &files[f.file];
        let end = f.body_end.unwrap_or(file.lines.len().saturating_sub(1));
        for li in f.body_start..=end.min(file.lines.len().saturating_sub(1)) {
            if owned_by_nested(fns, idx, li) {
                continue;
            }
            for call in calls_on_line(&file.lines[li].code, f.impl_ty.as_deref()) {
                match call {
                    Call::Method(name) => {
                        for &t in impl_by_name.get(name.as_str()).into_iter().flatten() {
                            edges[idx].insert(t);
                        }
                    }
                    Call::Qualified(Some(ty), name) => {
                        for &t in
                            by_impl.get(&(ty.as_str(), name.as_str())).into_iter().flatten()
                        {
                            edges[idx].insert(t);
                        }
                    }
                    Call::Qualified(None, _) => {}
                    Call::Bare(name) => {
                        for &t in free_by_name.get(name.as_str()).into_iter().flatten() {
                            edges[idx].insert(t);
                        }
                        if let Some(ty) = &f.impl_ty {
                            for &t in
                                by_impl.get(&(ty.as_str(), name.as_str())).into_iter().flatten()
                            {
                                edges[idx].insert(t);
                            }
                        }
                    }
                }
            }
        }
    }
    edges
}

/// The learner/collector/serve hot entry points the allocation lint
/// starts from (see INVARIANTS.md "Hot-path allocation contract").
const HOT_ENTRIES: &[(Option<&str>, &str)] = &[
    (Some("SacAgent"), "update_round"),
    (Some("UpdateSchedule"), "run_round"),
    (Some("VecEnv"), "par_step_into"),
    (None, "flush_batch"),
];

/// Root set: the named hot entries plus every `ReplayBuffer`
/// `sample_*_into` sampler. Returns `(fn index, provenance label)`.
pub fn hot_roots(fns: &[FnItem]) -> Vec<(usize, String)> {
    let mut roots = Vec::new();
    for (idx, f) in fns.iter().enumerate() {
        for &(ty, name) in HOT_ENTRIES {
            if f.name == name && (ty.is_none() || f.impl_ty.as_deref() == ty) {
                roots.push((idx, f.key()));
            }
        }
        if f.impl_ty.as_deref() == Some("ReplayBuffer")
            && f.name.starts_with("sample_")
            && f.name.ends_with("_into")
        {
            roots.push((idx, f.key()));
        }
    }
    roots
}

/// BFS from the hot roots; `reach[i]` holds the provenance label of the
/// first root that reached fn `i` (None if cold).
pub fn hot_reachability(fns: &[FnItem], edges: &[BTreeSet<usize>]) -> Vec<Option<String>> {
    let mut reach: Vec<Option<String>> = vec![None; fns.len()];
    let mut q = VecDeque::new();
    for (idx, label) in hot_roots(fns) {
        if reach[idx].is_none() {
            reach[idx] = Some(label);
            q.push_back(idx);
        }
    }
    while let Some(u) = q.pop_front() {
        for &v in &edges[u] {
            if reach[v].is_none() {
                reach[v] = reach[u].clone();
                q.push_back(v);
            }
        }
    }
    reach
}
