//! `lprl-tidy` — project-invariant static analysis for the lprl tree.
//!
//! Run with `cargo run -p xtask -- tidy`. Zero external dependencies:
//! every pass is lexical/line-level over `rust/src`, `rust/tests`, and
//! `rust/benches`, in the style of rustc's `tidy`. The contracts being
//! enforced are documented in `INVARIANTS.md` at the repo root; the
//! rule families are:
//!
//! * **safety** — every `unsafe` block/fn/impl must be covered by an
//!   immediately preceding `// SAFETY:` justification (a single header
//!   may cover a contiguous run of unsafe lines). No escape hatch.
//! * **determinism** — inside the deterministic-core modules
//!   ([`DETERMINISM_CORE`]), constructs that make results depend on
//!   hasher seeds, wall clocks, machine shape, or ad-hoc threads/RNG
//!   are forbidden unless escaped with `// tidy-allow(determinism): <reason>`.
//! * **precision** — `to_bits`/`from_bits` bit twiddling is only legal
//!   inside `lowp/`, so `lowp::Precision` stays the single source of
//!   numerical truth. Escape: `// tidy-allow(precision): <reason>`.
//! * **panic** — no `.unwrap()` / `.expect(` in library code outside
//!   `#[cfg(test)]` regions without `// tidy-allow(panic): <reason>`.
//! * **lint-wall** — the workspace lint table (`[workspace.lints]`,
//!   `unsafe_op_in_unsafe_fn = "deny"`) and the lib-level deny must not
//!   be silently dropped.
//!
//! The scanner blanks comments, string literals, and char literals
//! before matching, so tokens inside docs or messages never trip a
//! rule; `//` comment text is kept separately for the `SAFETY:` /
//! `tidy-allow` lookups. Fixtures under `rust/xtask/fixtures/` pin the
//! behaviour of every rule family (see the tests at the bottom), and
//! `tree_is_clean` asserts the real tree passes — so `cargo test`
//! fails if either the rules or the codebase regress.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules under `rust/src/` forming the deterministic core: everything
/// a seeded training run flows through, where bitwise reproducibility
/// is a tested contract.
const DETERMINISM_CORE: &[&str] =
    &["nn", "lowp", "optim", "sac", "replay", "rngs", "envs", "coordinator"];

/// Forbidden-in-core constructs and why each breaks determinism.
const DETERMINISM_TOKENS: &[(&str, &str)] = &[
    ("HashMap", "nondeterministic iteration order"),
    ("HashSet", "nondeterministic iteration order"),
    ("RandomState", "randomized hasher state"),
    ("Instant::now", "wall-clock value flowing into computation"),
    ("SystemTime", "wall-clock value flowing into computation"),
    ("thread::spawn", "ad-hoc thread: parallelism must flow through nn::pool"),
    ("thread::scope", "ad-hoc threads: parallelism must flow through nn::pool"),
    ("thread::Builder", "ad-hoc thread: parallelism must flow through nn::pool"),
    ("available_parallelism", "machine-shape value"),
    ("thread_rng", "ad-hoc RNG: randomness must flow through rngs::Pcg64"),
    ("from_entropy", "ad-hoc RNG: randomness must flow through rngs::Pcg64"),
];

/// Rules that may be escaped with `// tidy-allow(<rule>): <reason>`.
/// `safety` is deliberately absent: a SAFETY argument is never optional.
const ALLOWABLE_RULES: &[&str] = &["determinism", "precision", "panic"];

/// One source line after scanning: code with comments/strings blanked,
/// plus the text of any `//` comment that appeared on the line.
#[derive(Debug, Default)]
struct Line {
    code: String,
    comment: String,
}

/// One rule violation, reported as `file:line: [rule] message`.
#[derive(Debug)]
struct Diag {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl Diag {
    fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

// --------------------------------------------------------------- scanner

/// Length of the char literal starting at `ch[i] == '\''`, or `None`
/// if this quote is a lifetime. Handles `'a'`, `'\n'`, `'\''`, `'\u{..}'`.
fn char_lit_len(ch: &[char], i: usize) -> Option<usize> {
    let next = *ch.get(i + 1)?;
    if next == '\\' {
        (3..12).find(|&k| ch.get(i + k) == Some(&'\'')).map(|k| k + 1)
    } else if next != '\'' && ch.get(i + 2) == Some(&'\'') {
        Some(3)
    } else {
        None
    }
}

/// If `ch[j..]` is `#*"` (a raw-string opener after `r`), the hash count.
fn raw_open(ch: &[char], j: usize) -> Option<usize> {
    let mut h = 0;
    while ch.get(j + h) == Some(&'#') {
        h += 1;
    }
    (ch.get(j + h) == Some(&'"')).then_some(h)
}

/// Split source text into [`Line`]s: comments, string literals, and
/// char literals are blanked out of `code`; `//` comment text (doc or
/// plain) is collected into `comment`.
fn scan(text: &str) -> Vec<Line> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        Block(usize),
        Str,
        RawStr(usize),
    }
    let ch: Vec<char> = text.chars().collect();
    let n = ch.len();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut st = St::Code;
    let mut i = 0;
    while i < n {
        let c = ch[i];
        let next = if i + 1 < n { ch[i + 1] } else { '\0' };
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let prev_ident = i > 0 && (ch[i - 1].is_alphanumeric() || ch[i - 1] == '_');
                if c == '/' && next == '/' {
                    st = St::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                } else if c == '/' && next == '*' {
                    st = St::Block(1);
                    cur.code.push(' ');
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur.code.push(' ');
                    i += 1;
                } else if c == 'r' && !prev_ident && raw_open(&ch, i + 1).is_some() {
                    let h = raw_open(&ch, i + 1).unwrap_or(0);
                    st = St::RawStr(h);
                    cur.code.push(' ');
                    i += 2 + h;
                } else if c == '\'' {
                    match char_lit_len(&ch, i) {
                        Some(len) => {
                            cur.code.push(' ');
                            i += len;
                        }
                        None => {
                            cur.code.push(c);
                            i += 1;
                        }
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::Block(d) => {
                if c == '*' && next == '/' {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    i += 2;
                } else if c == '/' && next == '*' {
                    st = St::Block(d + 1);
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '"' {
                        st = St::Code;
                    }
                    i += 1;
                }
            }
            St::RawStr(h) => {
                let closes = c == '"'
                    && ch.get(i + 1..i + 1 + h).is_some_and(|s| s.iter().all(|&x| x == '#'));
                if closes {
                    st = St::Code;
                    i += 1 + h;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// True if `code` contains `tok` bounded by non-identifier characters.
fn has_token(code: &str, tok: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let p = start + pos;
        let before_ok = code[..p]
            .chars()
            .next_back()
            .is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        let after_ok = code[p + tok.len()..]
            .chars()
            .next()
            .is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        if before_ok && after_ok {
            return true;
        }
        start = p + tok.len();
    }
    false
}

/// Mark lines inside `#[cfg(test)]`-gated items (attribute through the
/// matching close brace, via brace counting over blanked code).
fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0usize;
        let mut opened = false;
        let mut j = i;
        'item: while j < lines.len() {
            mask[j] = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth = depth.saturating_sub(1);
                        if opened && depth == 0 {
                            break 'item;
                        }
                    }
                    ';' if !opened => break 'item, // braceless item (use, decl)
                    _ => {}
                }
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// True if the comment block covering `lines[i]` satisfies `pred`: a
/// trailing comment on the line itself, or the contiguous `//` block
/// directly above (skipping attributes and doc comments; when
/// `through_unsafe_runs`, also skipping adjacent lines that themselves
/// contain `unsafe`, so one `// SAFETY:` header can cover a run).
fn covered(
    lines: &[Line],
    i: usize,
    through_unsafe_runs: bool,
    pred: impl Fn(&str) -> bool,
) -> bool {
    if pred(&lines[i].comment) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        let com = lines[j].comment.trim();
        if code.is_empty() && com.is_empty() {
            return false; // blank line terminates the block
        }
        if code.is_empty() {
            if com.starts_with("///") || com.starts_with("//!") {
                continue; // doc comments are transparent
            }
            if pred(com) {
                return true;
            }
            continue;
        }
        if code.starts_with('#') {
            continue; // attributes are transparent
        }
        if through_unsafe_runs && has_token(code, "unsafe") {
            if pred(com) {
                return true;
            }
            continue;
        }
        return pred(com);
    }
    false
}

/// True if a well-formed `// tidy-allow(<rule>): <reason>` covers line `i`.
fn allowed(lines: &[Line], i: usize, rule: &str) -> bool {
    let needle = format!("tidy-allow({rule}):");
    covered(lines, i, false, |c| {
        c.find(&needle).is_some_and(|p| !c[p + needle.len()..].trim().is_empty())
    })
}

// ----------------------------------------------------------------- rules

/// Run every per-file rule over one source file. `rel` is the
/// repo-relative path (forward slashes); it decides which rules apply.
fn analyze_file(rel: &str, text: &str) -> Vec<Diag> {
    let lines = scan(text);
    let mask = test_mask(&lines);
    let in_src = rel.starts_with("rust/src/");
    let in_core = DETERMINISM_CORE
        .iter()
        .any(|m| rel.starts_with(&format!("rust/src/{m}/")) || rel == &format!("rust/src/{m}.rs"));
    let in_lowp = rel.starts_with("rust/src/lowp/");
    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, msg: String| {
        out.push(Diag { file: rel.to_string(), line, rule, msg });
    };

    for (idx, l) in lines.iter().enumerate() {
        let ln = idx + 1;
        let code = &l.code;

        // safety: everywhere, including tests and benches — unsafe is
        // unsafe no matter where it appears.
        if has_token(code, "unsafe") && !covered(&lines, idx, true, |c| c.contains("SAFETY:")) {
            push(
                ln,
                "safety",
                "`unsafe` without an immediately preceding `// SAFETY:` justification".to_string(),
            );
        }

        let lib_code = in_src && !mask[idx];

        if lib_code && in_core {
            for &(tok, why) in DETERMINISM_TOKENS {
                if has_token(code, tok) && !allowed(&lines, idx, "determinism") {
                    push(
                        ln,
                        "determinism",
                        format!(
                            "`{tok}` in a deterministic-core module ({why}); \
                             fix or escape with `// tidy-allow(determinism): <reason>`"
                        ),
                    );
                    break; // one determinism diag per line
                }
            }
        }

        if lib_code && !in_lowp {
            for tok in ["to_bits", "from_bits"] {
                if has_token(code, tok) && !allowed(&lines, idx, "precision") {
                    push(
                        ln,
                        "precision",
                        format!(
                            "`{tok}` outside lowp/ — bit twiddling belongs behind \
                             lowp::Precision; fix or escape with \
                             `// tidy-allow(precision): <reason>`"
                        ),
                    );
                    break;
                }
            }
        }

        if lib_code
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && !allowed(&lines, idx, "panic")
        {
            push(
                ln,
                "panic",
                "`.unwrap()`/`.expect()` in library code — return an error, or escape \
                 with `// tidy-allow(panic): <reason>`"
                    .to_string(),
            );
        }

        // allow-syntax: every escape must name a known rule and carry a
        // reason, so stale or typo'd annotations cannot silence anything.
        if let Some(p) = l.comment.find("tidy-allow(") {
            let rest = &l.comment[p + "tidy-allow(".len()..];
            match rest.find(')') {
                Some(q) => {
                    let rule = &rest[..q];
                    let reason_ok = rest[q + 1..]
                        .trim_start()
                        .strip_prefix(':')
                        .is_some_and(|r| !r.trim().is_empty());
                    if !ALLOWABLE_RULES.contains(&rule) {
                        push(
                            ln,
                            "allow-syntax",
                            format!(
                                "tidy-allow names unknown rule `{rule}` (allowed: {})",
                                ALLOWABLE_RULES.join(", ")
                            ),
                        );
                    } else if !reason_ok {
                        push(
                            ln,
                            "allow-syntax",
                            format!("tidy-allow({rule}) must carry a reason: `// tidy-allow({rule}): <reason>`"),
                        );
                    }
                }
                None => push(ln, "allow-syntax", "malformed tidy-allow comment".to_string()),
            }
        }
    }
    out
}

/// The lint wall: fail if the workspace lint table or the lib-level
/// `unsafe_op_in_unsafe_fn` deny is dropped.
fn lint_wall(root: &Path, diags: &mut Vec<Diag>) {
    let checks: &[(&str, &str)] = &[
        ("Cargo.toml", "[workspace.lints.rust]"),
        ("Cargo.toml", "unsafe_op_in_unsafe_fn = \"deny\""),
        ("rust/Cargo.toml", "[lints]"),
        ("rust/Cargo.toml", "workspace = true"),
        ("rust/src/lib.rs", "#![deny(unsafe_op_in_unsafe_fn)]"),
    ];
    for &(file, needle) in checks {
        let ok = std::fs::read_to_string(root.join(file))
            .map(|t| t.contains(needle))
            .unwrap_or(false);
        if !ok {
            diags.push(Diag {
                file: file.to_string(),
                line: 0,
                rule: "lint-wall",
                msg: format!("expected `{needle}` — the lint wall must not be dropped"),
            });
        }
    }
}

// ------------------------------------------------------------------ walk

/// Collect `.rs` files under `dir`, recursively, in sorted order so
/// diagnostics are stable across platforms.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = rd.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rust_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Run the full tidy pass over a repo checkout.
fn run_tidy(root: &Path) -> Vec<Diag> {
    let mut diags = Vec::new();
    lint_wall(root, &mut diags);
    let mut files = Vec::new();
    for d in ["rust/src", "rust/tests", "rust/benches"] {
        rust_files(&root.join(d), &mut files);
    }
    for f in &files {
        let rel =
            f.strip_prefix(root).unwrap_or(f).to_string_lossy().replace('\\', "/");
        match std::fs::read_to_string(f) {
            Ok(text) => diags.extend(analyze_file(&rel, &text)),
            Err(e) => diags.push(Diag {
                file: rel,
                line: 0,
                rule: "lint-wall",
                msg: format!("unreadable source file: {e}"),
            }),
        }
    }
    diags
}

/// Repo root: xtask lives at `<root>/rust/xtask`.
fn repo_root() -> PathBuf {
    let md = std::env::var("CARGO_MANIFEST_DIR")
        .unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").to_string());
    Path::new(&md)
        .ancestors()
        .nth(2)
        .expect("xtask sits two levels below the repo root")
        .to_path_buf()
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) != Some("tidy") {
        eprintln!("usage: cargo run -p xtask -- tidy [--root <repo>]");
        return ExitCode::from(2);
    }
    let root = if args.get(1).map(String::as_str) == Some("--root") {
        PathBuf::from(args.get(2).map(String::as_str).unwrap_or("."))
    } else {
        repo_root()
    };
    let diags = run_tidy(&root);
    if diags.is_empty() {
        eprintln!("tidy: clean (safety, determinism, precision, panic, lint-wall)");
        return ExitCode::SUCCESS;
    }
    for d in &diags {
        eprintln!("{}", d.render());
    }
    eprintln!("tidy: {} violation(s)", diags.len());
    ExitCode::FAILURE
}

// ----------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> String {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
    }

    fn rules_hit(rel: &str, name: &str) -> Vec<&'static str> {
        analyze_file(rel, &fixture(name)).iter().map(|d| d.rule).collect()
    }

    #[test]
    fn scanner_blanks_comments_and_strings() {
        let lines = scan("let x = \"unsafe HashMap\"; // unsafe in a comment\n/* unsafe */ let y = 1;\n");
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(!has_token(&lines[0].code, "HashMap"));
        assert!(lines[0].comment.contains("unsafe in a comment"));
        assert!(!has_token(&lines[1].code, "unsafe"));
        assert!(lines[1].code.contains("let y"));
    }

    #[test]
    fn scanner_char_literals_vs_lifetimes() {
        let lines = scan("fn f<'a>(s: &'a str) { s.split('\"').count(); let c = '\\''; }\n");
        // the quoted chars must not open a string and swallow the rest
        assert!(lines[0].code.contains("count()"));
        assert!(lines[0].code.contains("let c"));
        let lines = scan("let s = r#\"unsafe \"quoted\" text\"#; let t = 2;\n");
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(lines[0].code.contains("let t"));
    }

    #[test]
    fn multiline_strings_stay_blanked() {
        let lines = scan("let s = \"line one\nunsafe line two\";\nlet x = 1;\n");
        assert!(!has_token(&lines[1].code, "unsafe"));
        assert!(lines[2].code.contains("let x"));
    }

    #[test]
    fn cfg_test_mask_covers_gated_mod() {
        let text = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn tail() {}\n";
        let lines = scan(text);
        let mask = test_mask(&lines);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn token_boundaries_respected() {
        assert!(has_token("unsafe fn f()", "unsafe"));
        assert!(!has_token("#![deny(unsafe_op_in_unsafe_fn)]", "unsafe"));
        assert!(has_token("let m: HashMap<u32, u32>", "HashMap"));
        assert!(!has_token("let m = MyHashMapLike::new()", "HashMap"));
    }

    #[test]
    fn safety_header_covers_contiguous_unsafe_run() {
        let text = "// SAFETY: spans are disjoint.\nlet a = unsafe { f(p) };\nlet b = unsafe { f(q) };\n";
        let d = analyze_file("rust/src/nn/x.rs", text);
        assert!(d.iter().all(|d| d.rule != "safety"), "{d:?}");
        // ...but a non-unsafe code line breaks the run
        let text = "// SAFETY: spans are disjoint.\nlet a = unsafe { f(p) };\nlet c = 1;\nlet b = unsafe { f(q) };\n";
        let d = analyze_file("rust/src/nn/x.rs", text);
        assert!(d.iter().any(|d| d.rule == "safety" && d.line == 4), "{d:?}");
    }

    #[test]
    fn bad_fixtures_are_flagged() {
        assert!(rules_hit("rust/src/nn/x.rs", "bad_safety.rs").contains(&"safety"));
        assert!(rules_hit("rust/src/sac/x.rs", "bad_determinism.rs").contains(&"determinism"));
        assert!(rules_hit("rust/src/replay/x.rs", "bad_precision.rs").contains(&"precision"));
        assert!(rules_hit("rust/src/runtime/x.rs", "bad_panic.rs").contains(&"panic"));
        assert!(rules_hit("rust/src/nn/x.rs", "bad_allow.rs").contains(&"allow-syntax"));
    }

    #[test]
    fn good_fixtures_pass() {
        for (rel, name) in [
            ("rust/src/nn/x.rs", "good_safety.rs"),
            ("rust/src/sac/x.rs", "good_determinism.rs"),
            ("rust/src/replay/x.rs", "good_precision.rs"),
            ("rust/src/runtime/x.rs", "good_panic.rs"),
        ] {
            let d = analyze_file(rel, &fixture(name));
            assert!(d.is_empty(), "{name}: {d:?}");
        }
    }

    #[test]
    fn rules_scope_by_path() {
        let det = "pub fn f() { let t = Instant::now(); t.elapsed(); }\n";
        // core module: flagged; non-core (serve) module: not a determinism target
        assert!(analyze_file("rust/src/nn/x.rs", det).iter().any(|d| d.rule == "determinism"));
        assert!(analyze_file("rust/src/serve/x.rs", det).iter().all(|d| d.rule != "determinism"));
        let bits = "pub fn f(x: f32) -> u32 { x.to_bits() }\n";
        // lowp owns bit twiddling; tests/benches are exempt from panic/precision
        assert!(analyze_file("rust/src/lowp/x.rs", bits).is_empty());
        assert!(analyze_file("rust/src/sac/x.rs", bits).iter().any(|d| d.rule == "precision"));
        assert!(analyze_file("rust/benches/x.rs", "fn f() { x.unwrap(); }\n").is_empty());
    }

    #[test]
    fn allow_requires_reason_and_known_rule() {
        let d = analyze_file("rust/src/nn/x.rs", "let x = m.lock().unwrap(); // tidy-allow(panic):\n");
        assert!(d.iter().any(|d| d.rule == "allow-syntax"), "{d:?}");
        let d = analyze_file("rust/src/nn/x.rs", "let x = 1; // tidy-allow(safety): nope\n");
        assert!(d.iter().any(|d| d.rule == "allow-syntax"), "{d:?}");
        let d = analyze_file(
            "rust/src/nn/x.rs",
            "let x = m.lock().unwrap(); // tidy-allow(panic): poisoned lock means a task panicked\n",
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn tree_is_clean() {
        let diags = run_tidy(&repo_root());
        assert!(
            diags.is_empty(),
            "tidy violations:\n{}",
            diags.iter().map(Diag::render).collect::<Vec<_>>().join("\n")
        );
    }
}
