//! `lprl-tidy` — project-invariant static analysis for the lprl tree.
//!
//! Run with `cargo run -p xtask -- tidy`. Zero external dependencies:
//! the lexical layer ([`scan`]) blanks comments/strings per line, and a
//! token-tree parser ([`parse`]) recovers `fn` items and impl types so
//! the cross-file passes can reason about reachability. The contracts
//! being enforced are documented in `INVARIANTS.md`; the rule families:
//!
//! * **safety** — every `unsafe` block/fn/impl must be covered by an
//!   immediately preceding `// SAFETY:` justification (a single header
//!   may cover a contiguous run of unsafe lines). No escape hatch.
//! * **determinism** — inside the deterministic-core modules
//!   ([`DETERMINISM_CORE`]), constructs that make results depend on
//!   hasher seeds, wall clocks, machine shape, or ad-hoc threads/RNG
//!   are forbidden unless escaped with `// tidy-allow(determinism): <reason>`.
//! * **precision** — `to_bits`/`from_bits` bit twiddling is only legal
//!   inside `lowp/`, so `lowp::Precision` stays the single source of
//!   numerical truth. Escape: `// tidy-allow(precision): <reason>`.
//! * **simd** — explicit vector code (`std::arch`/`core::arch`
//!   intrinsics, feature-detection macros) is only legal inside
//!   `nn/simd.rs`, so the scalar-oracle parity contract has a single
//!   enforcement surface. Escape: `// tidy-allow(simd): <reason>`.
//! * **panic** — no `.unwrap()` / `.expect(` in library code outside
//!   `#[cfg(test)]` regions without `// tidy-allow(panic): <reason>`.
//! * **ckpt-io** — inside `ckpt/`, no bare `File::create`/`fs::write`
//!   (every checkpoint byte must flow through the atomic
//!   temp+fsync+rename writer) and no `.unwrap()`/`.expect(` on I/O
//!   results (errors must propagate with path context). Escape:
//!   `// tidy-allow(ckpt-io): <reason>` — reserved for the atomic
//!   writer's own temp-file create and the fault injector.
//! * **alloc** — no heap allocation in any fn reachable from the hot
//!   entry points (learner update round, pooled env stepping, serve
//!   batch flush, replay samplers) without `// tidy-allow(alloc): <reason>`
//!   ([`alloc`], over the call graph built by [`graph`]).
//! * **lock-order** — the threaded modules must acquire locks in a
//!   cycle-free global order, and no loop may re-lock one mutex while
//!   parked on a condvar guarding another ([`locks`]).
//! * **parity** — every fused/pooled API under the bitwise-parity
//!   contract must be pinned by a test in `rust/tests/` ([`parity`]).
//! * **stale-allow** — a `tidy-allow` escape whose target line no
//!   longer triggers the named rule is itself a diagnostic ([`stale`]).
//! * **lint-wall** — the workspace lint table (`[workspace.lints]`,
//!   `unsafe_op_in_unsafe_fn = "deny"`) and the lib-level deny must not
//!   be silently dropped.
//!
//! Output formats: `--format=text` (default, human-readable to
//! stderr), `--format=json` (stable sorted array to stdout, for
//! tooling), `--format=github` (GitHub Actions `::error` annotations).
//! Fixtures under `rust/xtask/fixtures/` pin the behaviour of every
//! rule family (see the tests at the bottom), and `tree_is_clean`
//! asserts the real tree passes every pass — so `cargo test` fails if
//! either the rules or the codebase regress.

mod alloc;
mod graph;
mod locks;
mod parity;
mod parse;
mod scan;
mod stale;

use scan::{allowed, covered, has_token, SourceFile};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules under `rust/src/` forming the deterministic core: everything
/// a seeded training run flows through, where bitwise reproducibility
/// is a tested contract.
const DETERMINISM_CORE: &[&str] =
    &["nn", "lowp", "optim", "sac", "replay", "rngs", "envs", "coordinator"];

/// Forbidden-in-core constructs and why each breaks determinism.
const DETERMINISM_TOKENS: &[(&str, &str)] = &[
    ("HashMap", "nondeterministic iteration order"),
    ("HashSet", "nondeterministic iteration order"),
    ("RandomState", "randomized hasher state"),
    ("Instant::now", "wall-clock value flowing into computation"),
    ("SystemTime", "wall-clock value flowing into computation"),
    ("thread::spawn", "ad-hoc thread: parallelism must flow through nn::pool"),
    ("thread::scope", "ad-hoc threads: parallelism must flow through nn::pool"),
    ("thread::Builder", "ad-hoc thread: parallelism must flow through nn::pool"),
    ("available_parallelism", "machine-shape value"),
    ("thread_rng", "ad-hoc RNG: randomness must flow through rngs::Pcg64"),
    ("from_entropy", "ad-hoc RNG: randomness must flow through rngs::Pcg64"),
];

/// Explicit-SIMD constructs that must stay inside [`SIMD_HOME`]: raw
/// intrinsic paths and the runtime feature-detection macros. Matched by
/// substring (the paths carry `::`, which token boundaries can't see).
pub(crate) const SIMD_TOKENS: &[&str] = &[
    "std::arch",
    "core::arch",
    "is_x86_feature_detected",
    "is_aarch64_feature_detected",
];

/// The one module allowed to contain explicit SIMD.
const SIMD_HOME: &str = "rust/src/nn/simd.rs";

/// Rules that may be escaped with `// tidy-allow(<rule>): <reason>`.
/// `safety` is deliberately absent: a SAFETY argument is never optional.
const ALLOWABLE_RULES: &[&str] =
    &["determinism", "precision", "simd", "panic", "alloc", "ckpt-io"];

/// One rule violation, reported as `file:line: [rule] message`.
#[derive(Debug)]
struct Diag {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl Diag {
    fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }

    /// GitHub Actions workflow-command annotation.
    fn github(&self) -> String {
        format!(
            "::error file={},line={}::{}",
            gh_property(&self.file),
            self.line,
            gh_message(&format!("[{}] {}", self.rule, self.msg))
        )
    }
}

// ----------------------------------------------------------------- rules

/// Run every per-file rule over one scanned source file.
fn analyze_source(sf: &SourceFile) -> Vec<Diag> {
    let rel = sf.rel.as_str();
    let lines = &sf.lines;
    let mask = &sf.mask;
    let in_src = rel.starts_with("rust/src/");
    let in_core = DETERMINISM_CORE
        .iter()
        .any(|m| rel.starts_with(&format!("rust/src/{m}/")) || rel == format!("rust/src/{m}.rs"));
    let in_lowp = rel.starts_with("rust/src/lowp/");
    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, msg: String| {
        out.push(Diag { file: rel.to_string(), line, rule, msg });
    };

    for (idx, l) in lines.iter().enumerate() {
        let ln = idx + 1;
        let code = &l.code;

        // safety: everywhere, including tests and benches — unsafe is
        // unsafe no matter where it appears.
        if has_token(code, "unsafe") && !covered(lines, idx, true, |c| c.contains("SAFETY:")) {
            push(
                ln,
                "safety",
                "`unsafe` without an immediately preceding `// SAFETY:` justification".to_string(),
            );
        }

        let lib_code = in_src && !mask[idx];

        if lib_code && in_core {
            for &(tok, why) in DETERMINISM_TOKENS {
                if has_token(code, tok) && !allowed(lines, idx, "determinism") {
                    push(
                        ln,
                        "determinism",
                        format!(
                            "`{tok}` in a deterministic-core module ({why}); \
                             fix or escape with `// tidy-allow(determinism): <reason>`"
                        ),
                    );
                    break; // one determinism diag per line
                }
            }
        }

        if lib_code && !in_lowp {
            for tok in ["to_bits", "from_bits"] {
                if has_token(code, tok) && !allowed(lines, idx, "precision") {
                    push(
                        ln,
                        "precision",
                        format!(
                            "`{tok}` outside lowp/ — bit twiddling belongs behind \
                             lowp::Precision; fix or escape with \
                             `// tidy-allow(precision): <reason>`"
                        ),
                    );
                    break;
                }
            }
        }

        if lib_code && rel != SIMD_HOME {
            for tok in SIMD_TOKENS {
                if code.contains(tok) && !allowed(lines, idx, "simd") {
                    push(
                        ln,
                        "simd",
                        format!(
                            "`{tok}` outside nn/simd.rs — explicit vector code belongs \
                             behind nn::simd's dispatched kernels (the scalar-parity \
                             boundary); fix or escape with `// tidy-allow(simd): <reason>`"
                        ),
                    );
                    break;
                }
            }
        }

        if lib_code && rel.starts_with("rust/src/ckpt/") {
            if (code.contains("File::create") || code.contains("fs::write"))
                && !allowed(lines, idx, "ckpt-io")
            {
                push(
                    ln,
                    "ckpt-io",
                    "bare `File::create`/`fs::write` in ckpt/ — checkpoint bytes must go \
                     through the atomic temp+fsync+rename writer; escape with \
                     `// tidy-allow(ckpt-io): <reason>` only for the writer itself"
                        .to_string(),
                );
            } else if (code.contains(".unwrap()") || code.contains(".expect("))
                && !allowed(lines, idx, "ckpt-io")
            {
                push(
                    ln,
                    "ckpt-io",
                    "`.unwrap()`/`.expect()` on I/O in ckpt/ — checkpoint I/O errors must \
                     propagate with path context; escape with \
                     `// tidy-allow(ckpt-io): <reason>`"
                        .to_string(),
                );
            }
        }

        if lib_code
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && !allowed(lines, idx, "panic")
        {
            push(
                ln,
                "panic",
                "`.unwrap()`/`.expect()` in library code — return an error, or escape \
                 with `// tidy-allow(panic): <reason>`"
                    .to_string(),
            );
        }

        // allow-syntax: every escape must name a known rule and carry a
        // reason, so stale or typo'd annotations cannot silence anything.
        if let Some(p) = l.comment.find("tidy-allow(") {
            let rest = &l.comment[p + "tidy-allow(".len()..];
            match rest.find(')') {
                Some(q) => {
                    let rule = &rest[..q];
                    let reason_ok = rest[q + 1..]
                        .trim_start()
                        .strip_prefix(':')
                        .is_some_and(|r| !r.trim().is_empty());
                    if !ALLOWABLE_RULES.contains(&rule) {
                        push(
                            ln,
                            "allow-syntax",
                            format!(
                                "tidy-allow names unknown rule `{rule}` (allowed: {})",
                                ALLOWABLE_RULES.join(", ")
                            ),
                        );
                    } else if !reason_ok {
                        push(
                            ln,
                            "allow-syntax",
                            format!("tidy-allow({rule}) must carry a reason: `// tidy-allow({rule}): <reason>`"),
                        );
                    }
                }
                None => push(ln, "allow-syntax", "malformed tidy-allow comment".to_string()),
            }
        }
    }
    out.extend(stale::stale_pass(rel, lines));
    out
}

/// Per-file rules over raw text (test/fixture entry point).
fn analyze_file(rel: &str, text: &str) -> Vec<Diag> {
    analyze_source(&SourceFile::new(rel, text))
}

/// The lint wall: fail if the workspace lint table or the lib-level
/// `unsafe_op_in_unsafe_fn` deny is dropped.
fn lint_wall(root: &Path, diags: &mut Vec<Diag>) {
    let checks: &[(&str, &str)] = &[
        ("Cargo.toml", "[workspace.lints.rust]"),
        ("Cargo.toml", "unsafe_op_in_unsafe_fn = \"deny\""),
        ("rust/Cargo.toml", "[lints]"),
        ("rust/Cargo.toml", "workspace = true"),
        ("rust/src/lib.rs", "#![deny(unsafe_op_in_unsafe_fn)]"),
    ];
    for &(file, needle) in checks {
        let ok = std::fs::read_to_string(root.join(file))
            .map(|t| t.contains(needle))
            .unwrap_or(false);
        if !ok {
            diags.push(Diag {
                file: file.to_string(),
                line: 0,
                rule: "lint-wall",
                msg: format!("expected `{needle}` — the lint wall must not be dropped"),
            });
        }
    }
}

// ------------------------------------------------------------------ walk

/// Collect `.rs` files under `dir`, recursively, in sorted order so
/// diagnostics are stable across platforms.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = rd.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rust_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Read and scan every `.rs` file under `root/dir`; unreadable files
/// become diagnostics rather than aborting the run.
fn load_dir(root: &Path, dir: &str, diags: &mut Vec<Diag>) -> Vec<SourceFile> {
    let mut paths = Vec::new();
    rust_files(&root.join(dir), &mut paths);
    let mut out = Vec::new();
    for p in &paths {
        let rel = p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/");
        match std::fs::read_to_string(p) {
            Ok(text) => out.push(SourceFile::new(&rel, &text)),
            Err(e) => diags.push(Diag {
                file: rel,
                line: 0,
                rule: "lint-wall",
                msg: format!("unreadable source file: {e}"),
            }),
        }
    }
    out
}

/// Run the full tidy pass over a repo checkout. Diagnostics come back
/// sorted by (file, line, rule, message) so every output format is
/// stable across runs and platforms.
fn run_tidy(root: &Path) -> Vec<Diag> {
    let mut diags = Vec::new();
    lint_wall(root, &mut diags);
    let src = load_dir(root, "rust/src", &mut diags);
    let tests = load_dir(root, "rust/tests", &mut diags);
    let benches = load_dir(root, "rust/benches", &mut diags);
    for sf in src.iter().chain(&tests).chain(&benches) {
        diags.extend(analyze_source(sf));
    }
    let fns = parse::parse_fns(&src);
    let edges = graph::build_graph(&src, &fns);
    diags.extend(alloc::alloc_pass(&src, &fns, &edges));
    diags.extend(locks::lock_pass(&src, &fns).0);
    diags.extend(parity::parity_pass(&tests));
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.msg.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.msg.as_str()))
    });
    diags
}

// ---------------------------------------------------------------- output

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 8);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Stable JSON array of diagnostics, one object per line.
fn render_json(diags: &[Diag]) -> String {
    let mut out = String::from("[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"msg\":\"{}\"}}",
            json_escape(&d.file),
            d.line,
            json_escape(d.rule),
            json_escape(&d.msg)
        ));
    }
    out.push_str(if diags.is_empty() { "]" } else { "\n]" });
    out
}

/// Escape a GitHub workflow-command property value.
fn gh_property(s: &str) -> String {
    s.replace('%', "%25")
        .replace('\r', "%0D")
        .replace('\n', "%0A")
        .replace(':', "%3A")
        .replace(',', "%2C")
}

/// Escape a GitHub workflow-command message.
fn gh_message(s: &str) -> String {
    s.replace('%', "%25").replace('\r', "%0D").replace('\n', "%0A")
}

// ------------------------------------------------------------------ main

/// Repo root: xtask lives at `<root>/rust/xtask`.
fn repo_root() -> PathBuf {
    let md = std::env::var("CARGO_MANIFEST_DIR")
        .unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").to_string());
    Path::new(&md)
        .ancestors()
        .nth(2)
        .expect("xtask sits two levels below the repo root")
        .to_path_buf()
}

#[derive(Clone, Copy)]
enum Format {
    Text,
    Json,
    Github,
}

const CLEAN_MSG: &str = "tidy: clean (safety, determinism, precision, simd, panic, alloc, \
                         ckpt-io, lock-order, parity, stale-allow, lint-wall)";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) != Some("tidy") {
        eprintln!("usage: cargo run -p xtask -- tidy [--root <repo>] [--format=text|json|github]");
        return ExitCode::from(2);
    }
    let mut root: Option<PathBuf> = None;
    let mut format = Format::Text;
    let mut it = args.iter().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => root = it.next().map(PathBuf::from),
            "--format=text" => format = Format::Text,
            "--format=json" => format = Format::Json,
            "--format=github" => format = Format::Github,
            other => {
                eprintln!("unknown argument `{other}`");
                eprintln!(
                    "usage: cargo run -p xtask -- tidy [--root <repo>] [--format=text|json|github]"
                );
                return ExitCode::from(2);
            }
        }
    }
    let root = root.unwrap_or_else(repo_root);
    let diags = run_tidy(&root);
    match format {
        Format::Text => {
            if diags.is_empty() {
                eprintln!("{CLEAN_MSG}");
            } else {
                for d in &diags {
                    eprintln!("{}", d.render());
                }
                eprintln!("tidy: {} violation(s)", diags.len());
            }
        }
        Format::Json => {
            println!("{}", render_json(&diags));
            if !diags.is_empty() {
                eprintln!("tidy: {} violation(s)", diags.len());
            }
        }
        Format::Github => {
            if diags.is_empty() {
                eprintln!("{CLEAN_MSG}");
            } else {
                for d in &diags {
                    println!("{}", d.github());
                }
                eprintln!("tidy: {} violation(s)", diags.len());
            }
        }
    }
    if diags.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

// ----------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::scan::{scan, test_mask};
    use super::*;

    fn fixture(name: &str) -> String {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
    }

    fn rules_hit(rel: &str, name: &str) -> Vec<&'static str> {
        analyze_file(rel, &fixture(name)).iter().map(|d| d.rule).collect()
    }

    /// Parse one fixture as the whole source tree and build its graph.
    fn semantic(
        rel: &str,
        name: &str,
    ) -> (Vec<SourceFile>, Vec<parse::FnItem>, Vec<std::collections::BTreeSet<usize>>) {
        let files = vec![SourceFile::new(rel, &fixture(name))];
        let fns = parse::parse_fns(&files);
        let edges = graph::build_graph(&files, &fns);
        (files, fns, edges)
    }

    #[test]
    fn scanner_blanks_comments_and_strings() {
        let lines =
            scan("let x = \"unsafe HashMap\"; // unsafe in a comment\n/* unsafe */ let y = 1;\n");
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(!has_token(&lines[0].code, "HashMap"));
        assert!(lines[0].comment.contains("unsafe in a comment"));
        assert!(!has_token(&lines[1].code, "unsafe"));
        assert!(lines[1].code.contains("let y"));
    }

    #[test]
    fn scanner_char_literals_vs_lifetimes() {
        let lines = scan("fn f<'a>(s: &'a str) { s.split('\"').count(); let c = '\\''; }\n");
        // the quoted chars must not open a string and swallow the rest
        assert!(lines[0].code.contains("count()"));
        assert!(lines[0].code.contains("let c"));
        let lines = scan("let s = r#\"unsafe \"quoted\" text\"#; let t = 2;\n");
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(lines[0].code.contains("let t"));
    }

    #[test]
    fn multiline_strings_stay_blanked() {
        let lines = scan("let s = \"line one\nunsafe line two\";\nlet x = 1;\n");
        assert!(!has_token(&lines[1].code, "unsafe"));
        assert!(lines[2].code.contains("let x"));
    }

    #[test]
    fn cfg_test_mask_covers_gated_mod() {
        let text = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn tail() {}\n";
        let lines = scan(text);
        let mask = test_mask(&lines);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn token_boundaries_respected() {
        assert!(has_token("unsafe fn f()", "unsafe"));
        assert!(!has_token("#![deny(unsafe_op_in_unsafe_fn)]", "unsafe"));
        assert!(has_token("let m: HashMap<u32, u32>", "HashMap"));
        assert!(!has_token("let m = MyHashMapLike::new()", "HashMap"));
    }

    #[test]
    fn safety_header_covers_contiguous_unsafe_run() {
        let text = "// SAFETY: spans are disjoint.\nlet a = unsafe { f(p) };\nlet b = unsafe { f(q) };\n";
        let d = analyze_file("rust/src/nn/x.rs", text);
        assert!(d.iter().all(|d| d.rule != "safety"), "{d:?}");
        // ...but a non-unsafe code line breaks the run
        let text = "// SAFETY: spans are disjoint.\nlet a = unsafe { f(p) };\nlet c = 1;\nlet b = unsafe { f(q) };\n";
        let d = analyze_file("rust/src/nn/x.rs", text);
        assert!(d.iter().any(|d| d.rule == "safety" && d.line == 4), "{d:?}");
    }

    #[test]
    fn bad_fixtures_are_flagged() {
        assert!(rules_hit("rust/src/nn/x.rs", "bad_safety.rs").contains(&"safety"));
        assert!(rules_hit("rust/src/sac/x.rs", "bad_determinism.rs").contains(&"determinism"));
        assert!(rules_hit("rust/src/replay/x.rs", "bad_precision.rs").contains(&"precision"));
        assert!(rules_hit("rust/src/nn/gemm.rs", "bad_simd.rs").contains(&"simd"));
        assert!(rules_hit("rust/src/runtime/x.rs", "bad_panic.rs").contains(&"panic"));
        assert!(rules_hit("rust/src/ckpt/x.rs", "bad_ckpt_io.rs").contains(&"ckpt-io"));
        assert!(rules_hit("rust/src/nn/x.rs", "bad_allow.rs").contains(&"allow-syntax"));
    }

    #[test]
    fn good_fixtures_pass() {
        for (rel, name) in [
            ("rust/src/nn/x.rs", "good_safety.rs"),
            ("rust/src/sac/x.rs", "good_determinism.rs"),
            ("rust/src/replay/x.rs", "good_precision.rs"),
            ("rust/src/nn/gemm.rs", "good_simd.rs"),
            ("rust/src/runtime/x.rs", "good_panic.rs"),
            ("rust/src/ckpt/x.rs", "good_ckpt_io.rs"),
        ] {
            let d = analyze_file(rel, &fixture(name));
            assert!(d.is_empty(), "{name}: {d:?}");
        }
    }

    #[test]
    fn rules_scope_by_path() {
        let det = "pub fn f() { let t = Instant::now(); t.elapsed(); }\n";
        // core module: flagged; non-core (serve) module: not a determinism target
        assert!(analyze_file("rust/src/nn/x.rs", det).iter().any(|d| d.rule == "determinism"));
        assert!(analyze_file("rust/src/serve/x.rs", det).iter().all(|d| d.rule != "determinism"));
        let bits = "pub fn f(x: f32) -> u32 { x.to_bits() }\n";
        // lowp owns bit twiddling; tests/benches are exempt from panic/precision
        assert!(analyze_file("rust/src/lowp/x.rs", bits).is_empty());
        assert!(analyze_file("rust/src/sac/x.rs", bits).iter().any(|d| d.rule == "precision"));
        assert!(analyze_file("rust/benches/x.rs", "fn f() { x.unwrap(); }\n").is_empty());
        // nn/simd.rs owns explicit SIMD; everywhere else in src it's flagged
        let vec_code = "pub fn f() -> bool { is_x86_feature_detected!(\"avx2\") }\n";
        assert!(analyze_file("rust/src/nn/simd.rs", vec_code).is_empty());
        assert!(analyze_file("rust/src/nn/gemm.rs", vec_code).iter().any(|d| d.rule == "simd"));
        assert!(analyze_file("rust/benches/x.rs", vec_code).is_empty());
        // ckpt-io fires only inside ckpt/ (the atomic-writer boundary);
        // the same write elsewhere is governed by the ordinary rules
        let w = "pub fn f(p: &str) { let _ = std::fs::write(p, b\"x\"); }\n";
        assert!(analyze_file("rust/src/ckpt/x.rs", w).iter().any(|d| d.rule == "ckpt-io"));
        assert!(analyze_file("rust/src/telemetry/x.rs", w).iter().all(|d| d.rule != "ckpt-io"));
    }

    #[test]
    fn allow_requires_reason_and_known_rule() {
        let d = analyze_file("rust/src/nn/x.rs", "let x = m.lock().unwrap(); // tidy-allow(panic):\n");
        assert!(d.iter().any(|d| d.rule == "allow-syntax"), "{d:?}");
        let d = analyze_file("rust/src/nn/x.rs", "let x = 1; // tidy-allow(safety): nope\n");
        assert!(d.iter().any(|d| d.rule == "allow-syntax"), "{d:?}");
        let d = analyze_file(
            "rust/src/nn/x.rs",
            "let x = m.lock().unwrap(); // tidy-allow(panic): poisoned lock means a task panicked\n",
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn parser_recovers_impl_and_fn_extents() {
        let text = "pub struct S;\n\
                    impl S {\n\
                    \x20   pub fn outer(&self, f: impl Fn(usize) -> usize) -> usize {\n\
                    \x20       fn helper(x: usize) -> usize {\n\
                    \x20           x + 1\n\
                    \x20       }\n\
                    \x20       helper(f(1))\n\
                    \x20   }\n\
                    }\n\
                    pub fn free() {}\n";
        let files = vec![SourceFile::new("rust/src/nn/x.rs", text)];
        let fns = parse::parse_fns(&files);
        let keys: Vec<String> = fns.iter().map(parse::FnItem::key).collect();
        // `impl Fn(usize)` in the signature must not open an impl scope
        assert_eq!(keys, ["S::outer", "S::helper", "::free"]);
        assert_eq!(fns[0].body_end, Some(7));
        assert_eq!(fns[1].body_end, Some(5));
    }

    #[test]
    fn call_extraction_shapes() {
        let calls = graph::calls_on_line(
            "let y = self.step(x) + Norm::apply(z) + helper(w); log!(y); Self::seed(s);",
            Some("SacAgent"),
        );
        assert_eq!(calls.len(), 4); // the macro is not a call
        assert!(matches!(&calls[0], graph::Call::Method(n) if n == "step"));
        assert!(
            matches!(&calls[1], graph::Call::Qualified(Some(t), n) if t == "Norm" && n == "apply")
        );
        assert!(matches!(&calls[2], graph::Call::Bare(n) if n == "helper"));
        // `Self::` resolves to the enclosing impl type
        assert!(
            matches!(&calls[3], graph::Call::Qualified(Some(t), n) if t == "SacAgent" && n == "seed")
        );
        // a fn signature is not a call site
        assert!(graph::calls_on_line("fn helper(x: usize) -> usize {", None).is_empty());
    }

    #[test]
    fn alloc_fixtures() {
        let (files, fns, edges) = semantic("rust/src/sac/x.rs", "bad_alloc.rs");
        let d = alloc::alloc_pass(&files, &fns, &edges);
        assert!(
            d.iter().any(|d| d.rule == "alloc" && d.msg.contains("with_capacity")),
            "{d:?}"
        );
        let (files, fns, edges) = semantic("rust/src/sac/x.rs", "good_alloc.rs");
        let d = alloc::alloc_pass(&files, &fns, &edges);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn lock_cycle_flagged() {
        let files = vec![SourceFile::new("rust/src/serve/x.rs", &fixture("bad_lock.rs"))];
        let fns = parse::parse_fns(&files);
        let (d, edges) = locks::lock_pass(&files, &fns);
        assert!(edges.contains_key(&("a".to_string(), "b".to_string())));
        assert!(edges.contains_key(&("b".to_string(), "a".to_string())));
        assert!(d.iter().any(|d| d.rule == "lock-order" && d.msg.contains("cycle")), "{d:?}");
    }

    #[test]
    fn condvar_in_lock_loop_flagged() {
        let files = vec![SourceFile::new("rust/src/serve/x.rs", &fixture("bad_lock_wait.rs"))];
        let fns = parse::parse_fns(&files);
        let (d, _) = locks::lock_pass(&files, &fns);
        assert!(d.iter().any(|d| d.rule == "lock-order" && d.msg.contains("condvar")), "{d:?}");
    }

    #[test]
    fn clean_lock_order_passes() {
        let files = vec![SourceFile::new("rust/src/serve/x.rs", &fixture("good_lock.rs"))];
        let fns = parse::parse_fns(&files);
        let (d, edges) = locks::lock_pass(&files, &fns);
        assert!(d.is_empty(), "{d:?}");
        // consistent order: a -> b present, reverse absent
        assert!(edges.contains_key(&("a".to_string(), "b".to_string())));
        assert!(!edges.contains_key(&("b".to_string(), "a".to_string())));
    }

    #[test]
    fn parity_fixtures() {
        let bad = vec![SourceFile::new("rust/tests/x.rs", &fixture("bad_parity.rs"))];
        let d = parity::parity_pass(&bad);
        assert!(d.iter().any(|d| d.rule == "parity" && d.msg.contains("fuse_group")), "{d:?}");
        // the f32 SIMD tier kernels are under the same contract
        assert!(d.iter().any(|d| d.rule == "parity" && d.msg.contains("gemm_bias_q_at")), "{d:?}");
        assert!(
            d.iter().any(|d| d.rule == "parity" && d.msg.contains("quantize_slice_rne_at")),
            "{d:?}"
        );
        let good = vec![SourceFile::new("rust/tests/x.rs", &fixture("good_parity.rs"))];
        let d = parity::parity_pass(&good);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn stale_allow_fixtures() {
        let sf = SourceFile::new("rust/src/nn/x.rs", &fixture("bad_stale.rs"));
        let d = stale::stale_pass(&sf.rel, &sf.lines);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().all(|d| d.rule == "stale-allow"));
        let sf = SourceFile::new("rust/src/nn/x.rs", &fixture("good_stale.rs"));
        let d = stale::stale_pass(&sf.rel, &sf.lines);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn json_output_escapes_and_orders() {
        let diags = vec![
            Diag { file: "a.rs".to_string(), line: 1, rule: "alloc", msg: "q\" b\\ n\n".to_string() },
        ];
        let out = render_json(&diags);
        assert!(out.contains(r#""file":"a.rs""#), "{out}");
        assert!(out.contains(r#"q\" b\\ n\n"#), "{out}");
        assert_eq!(render_json(&[]), "[]");
        // github annotations escape newlines in the message
        assert!(diags[0].github().contains("%0A"), "{}", diags[0].github());
        assert!(diags[0].github().starts_with("::error file=a.rs,line=1::[alloc]"));
    }

    #[test]
    fn tree_is_clean() {
        let diags = run_tidy(&repo_root());
        assert!(
            diags.is_empty(),
            "tidy violations:\n{}",
            diags.iter().map(Diag::render).collect::<Vec<_>>().join("\n")
        );
    }
}
