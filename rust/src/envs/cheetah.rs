//! Cheetah-run surrogate: a planar body propelled by six "paddle" legs.
//! Each leg is a damped torque-controlled joint; a leg produces forward
//! thrust while sweeping backwards through its ground-contact arc
//! (`cos q > 0`). Coordinated oscillation — the essence of the gait the
//! real half-cheetah must learn — maximizes speed; uncoordinated flailing
//! produces little net thrust. Reward is dm_control's `run`: linear in
//! forward speed up to a target velocity.

use super::render::Canvas;
use super::Env;
use crate::rngs::Pcg64;

const N_LEGS: usize = 6;
const DT: f64 = 0.01;
const SUBSTEPS: usize = 2;
const TORQUE: f64 = 12.0;
const JOINT_DAMP: f64 = 4.0;
const JOINT_SPRING: f64 = 6.0; // pulls legs back to neutral
const DRAG: f64 = 1.2;
const THRUST: f64 = 0.9;
const TARGET_SPEED: f64 = 3.0;

/// State: body velocity `v`, body x (for rendering), and per-leg `(q, q̇)`.
pub struct CheetahRun {
    v: f64,
    x: f64,
    q: [f64; N_LEGS],
    qd: [f64; N_LEGS],
}

impl CheetahRun {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        CheetahRun { v: 0.0, x: 0.0, q: [0.0; N_LEGS], qd: [0.0; N_LEGS] }
    }

    fn obs(&self) -> Vec<f32> {
        // tidy-allow(alloc): per-step obs crosses the Env trait boundary
        // as an owned Vec (collection path, not the learner loop)
        let mut o = Vec::with_capacity(1 + 2 * N_LEGS);
        o.push((self.v / TARGET_SPEED) as f32);
        for i in 0..N_LEGS {
            o.push(self.q[i] as f32);
            o.push((self.qd[i] / 10.0) as f32);
        }
        o
    }
}

impl Env for CheetahRun {
    fn name(&self) -> &'static str {
        "cheetah_run"
    }
    fn obs_dim(&self) -> usize {
        1 + 2 * N_LEGS
    }
    fn act_dim(&self) -> usize {
        N_LEGS
    }

    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        self.v = 0.0;
        self.x = 0.0;
        for i in 0..N_LEGS {
            self.q[i] = rng.uniform_in(-0.2, 0.2) as f64;
            self.qd[i] = 0.0;
        }
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32) {
        for _ in 0..SUBSTEPS {
            let mut thrust = 0.0;
            for i in 0..N_LEGS {
                let a = action[i].clamp(-1.0, 1.0) as f64 * TORQUE;
                let qdd = a - JOINT_DAMP * self.qd[i] - JOINT_SPRING * self.q[i];
                self.qd[i] += qdd * DT;
                self.q[i] = (self.q[i] + self.qd[i] * DT).clamp(-1.2, 1.2);
                // paddle model: backward sweep (q̇<0) while "grounded"
                // (cos q > 0.3) pushes the body forward
                let ground = (self.q[i].cos() - 0.3).max(0.0);
                thrust += THRUST * (-self.qd[i]).max(0.0) * ground / N_LEGS as f64;
            }
            self.v += (thrust - DRAG * self.v) * DT;
            self.x += self.v * DT;
        }
        self.v = self.v.clamp(-1.0, 2.0 * TARGET_SPEED);
        let r = (self.v / TARGET_SPEED).clamp(0.0, 1.0);
        (self.obs(), r as f32)
    }

    fn save_state(&self) -> Vec<f64> {
        let mut s = vec![self.v, self.x];
        s.extend_from_slice(&self.q);
        s.extend_from_slice(&self.qd);
        s
    }

    fn load_state(&mut self, s: &[f64]) {
        self.v = s[0];
        self.x = s[1];
        self.q.copy_from_slice(&s[2..2 + N_LEGS]);
        self.qd.copy_from_slice(&s[2 + N_LEGS..2 + 2 * N_LEGS]);
    }

    fn render(&self, c: &mut Canvas) {
        c.clear([0.9, 0.95, 1.0]);
        // ground
        c.rect(-1.0, -0.65, 1.0, -1.0, [0.5, 0.4, 0.3]);
        // body: a capsule whose texture scrolls with x
        let phase = (self.x * 2.0).rem_euclid(2.0) - 1.0;
        c.rect(-0.5, -0.2, 0.5, -0.45, [0.85, 0.6, 0.2]);
        c.disk(phase * 0.5, -0.325, 0.06, [0.4, 0.25, 0.1]);
        for (i, &q) in self.q.iter().enumerate() {
            let bx = -0.4 + 0.16 * i as f64;
            let (lx, ly) = (bx + 0.22 * q.sin(), -0.45 - 0.22 * q.cos());
            c.line(bx, -0.45, lx, ly, 1, [0.3, 0.2, 0.1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standing_still_no_reward() {
        let mut env = CheetahRun::new();
        env.reset(&mut Pcg64::seed(1));
        let (_, r) = env.step(&[0.0; 6]);
        assert!(r < 0.05, "r={r}");
    }

    #[test]
    fn coordinated_gait_moves_forward() {
        let mut env = CheetahRun::new();
        env.reset(&mut Pcg64::seed(2));
        // simple open-loop gait: square-wave kicks
        let mut total = 0.0;
        for i in 0..600 {
            let ph = (i / 15) % 2 == 0;
            let a: Vec<f32> = (0..6).map(|j| if (j % 2 == 0) == ph { 1.0 } else { -1.0 }).collect();
            let (_, r) = env.step(&a);
            total += r as f64;
        }
        assert!(env.v > 0.1, "gait should produce speed, v={}", env.v);
        assert!(total > 5.0, "return {total}");
    }

    #[test]
    fn gait_beats_constant_action() {
        let mut gait_env = CheetahRun::new();
        gait_env.reset(&mut Pcg64::seed(3));
        let mut const_env = CheetahRun::new();
        const_env.reset(&mut Pcg64::seed(3));
        let (mut rg, mut rc) = (0.0f64, 0.0f64);
        for i in 0..600 {
            let ph = (i / 15) % 2 == 0;
            let a: Vec<f32> = (0..6).map(|j| if (j % 2 == 0) == ph { 1.0 } else { -1.0 }).collect();
            rg += gait_env.step(&a).1 as f64;
            rc += const_env.step(&[1.0; 6]).1 as f64;
        }
        assert!(rg > rc, "coordination must matter: gait={rg} const={rc}");
    }

    #[test]
    fn speed_saturates_reward_at_one() {
        let mut env = CheetahRun::new();
        env.v = 10.0;
        let (_, r) = env.step(&[0.0; 6]);
        assert!(r <= 1.0);
    }
}
