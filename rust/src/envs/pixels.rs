//! Pixel observation adapter: renders the env state to RGB and maintains
//! the DRQ-style frame stack (3 frames × 3 channels). Lives in `envs`
//! so [`super::VecEnv`] can treat state and pixel streams uniformly.

use super::render::Canvas;
use super::Env;

/// Wraps an [`Env`] to produce stacked-frame pixel observations
/// `[stack*3, side, side]` flattened.
pub struct PixelEnvAdapter {
    pub env: Box<dyn Env>,
    pub side: usize,
    pub stack: usize,
    frames: Vec<Vec<f32>>, // most recent last
    canvas: Canvas,
}

impl PixelEnvAdapter {
    pub fn new(env: Box<dyn Env>, side: usize, stack: usize) -> Self {
        PixelEnvAdapter {
            env,
            side,
            stack,
            frames: Vec::new(),
            canvas: Canvas::new(side),
        }
    }

    pub fn obs_shape(&self) -> Vec<usize> {
        vec![self.stack * 3, self.side, self.side]
    }

    pub fn obs_len(&self) -> usize {
        self.stack * 3 * self.side * self.side
    }

    fn snap(&mut self) -> Vec<f32> {
        self.env.render(&mut self.canvas);
        // tidy-allow(alloc): per-step frame crosses into the stack as an
        // owned Vec (collection path, not the learner loop)
        self.canvas.data.clone()
    }

    fn stacked(&self) -> Vec<f32> {
        // tidy-allow(alloc): per-step stacked obs crosses the Env boundary
        // as an owned Vec (collection path, not the learner loop)
        let mut out = Vec::with_capacity(self.obs_len());
        for f in &self.frames {
            out.extend_from_slice(f);
        }
        out
    }

    /// Reset the env and fill the stack with the initial frame.
    pub fn reset(&mut self, rng: &mut crate::rngs::Pcg64) -> Vec<f32> {
        let _ = self.env.reset(rng);
        let frame = self.snap();
        self.frames = vec![frame; self.stack];
        self.stacked()
    }

    /// Step and return (stacked pixels, reward).
    pub fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32) {
        let (_, r) = self.env.step(action);
        let frame = self.snap();
        self.frames.remove(0);
        self.frames.push(frame);
        (self.stacked(), r)
    }

    /// Serialize the frame stack bitwise (checkpoint path). The canvas
    /// is transient scratch — it is fully rewritten by the next render —
    /// so only the frames need to survive a restart.
    pub fn ckpt_write(&self, enc: &mut crate::ckpt::Enc) {
        enc.u64(self.frames.len() as u64);
        for f in &self.frames {
            enc.f32s(f);
        }
    }

    /// Restore a [`PixelEnvAdapter::ckpt_write`] frame stack, validating
    /// the stack depth and frame size against this adapter's shape.
    pub fn ckpt_read(&mut self, dec: &mut crate::ckpt::Dec) -> anyhow::Result<()> {
        let n = dec.usize()?;
        anyhow::ensure!(
            n == self.stack,
            "checkpoint frame stack depth {n} != configured stack {}",
            self.stack
        );
        let frame_len = 3 * self.side * self.side;
        let mut frames = Vec::with_capacity(n);
        for _ in 0..n {
            let f = dec.f32s()?;
            anyhow::ensure!(
                f.len() == frame_len,
                "checkpoint frame has {} floats, expected {frame_len}",
                f.len()
            );
            frames.push(f);
        }
        self.frames = frames;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::make_env;
    use crate::rngs::Pcg64;

    #[test]
    fn stacking_shape_and_rotation() {
        let env = make_env("cartpole_swingup").unwrap();
        let mut px = PixelEnvAdapter::new(env, 16, 3);
        let mut rng = Pcg64::seed(1);
        let obs = px.reset(&mut rng);
        assert_eq!(obs.len(), 9 * 16 * 16);
        // initially all three frames identical
        let n = 3 * 16 * 16;
        assert_eq!(&obs[..n], &obs[n..2 * n]);
        let (obs2, _r) = px.step(&[1.0]);
        assert_eq!(obs2.len(), 9 * 16 * 16);
        // oldest two frames of obs2 are the newest two of obs
        assert_eq!(&obs2[..n], &obs[n..2 * n]);
    }

    #[test]
    fn frames_change_with_dynamics() {
        let env = make_env("pendulum_swingup").unwrap();
        let mut px = PixelEnvAdapter::new(env, 16, 3);
        let mut rng = Pcg64::seed(2);
        let _ = px.reset(&mut rng);
        let mut changed = false;
        let mut prev = px.stacked();
        for _ in 0..20 {
            let (obs, _) = px.step(&[1.0]);
            if obs != prev {
                changed = true;
            }
            prev = obs;
        }
        assert!(changed, "pixels must reflect motion");
    }
}
