//! Ball-in-cup catch: a ball hangs from the cup by an elastic string;
//! the cup moves in the plane under velocity control and must swing the
//! ball up and catch it. Reward is 1 while the ball is inside the cup
//! mouth (dm_control's binary catch reward, with a small smooth margin
//! so the scaled-down task stays learnable).

use super::render::Canvas;
use super::tolerance::tolerance;
use super::Env;
use crate::rngs::Pcg64;

const DT: f64 = 0.01;
const SUBSTEPS: usize = 2;
const G: f64 = 9.81;
const STRING_LEN: f64 = 0.35;
const STRING_K: f64 = 120.0; // spring constant when taut
const STRING_DAMP: f64 = 1.0;
const CUP_SPEED: f64 = 1.2;
const CUP_R: f64 = 0.06;
const WORKSPACE: f64 = 0.5;

/// State: cup `(cx, cy)`, ball `(bx, by, vx, vy)`.
pub struct BallInCup {
    cup: (f64, f64),
    ball: [f64; 4],
}

impl BallInCup {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        BallInCup { cup: (0.0, 0.2), ball: [0.0, -0.15, 0.0, 0.0] }
    }

    fn obs(&self) -> Vec<f32> {
        // tidy-allow(alloc): per-step obs crosses the Env trait boundary
        // as an owned Vec (collection path, not the learner loop)
        vec![
            (self.cup.0 / WORKSPACE) as f32,
            (self.cup.1 / WORKSPACE) as f32,
            (self.ball[0] / WORKSPACE) as f32,
            (self.ball[1] / WORKSPACE) as f32,
            (self.ball[2] / 3.0) as f32,
            (self.ball[3] / 3.0) as f32,
            ((self.ball[0] - self.cup.0) / STRING_LEN) as f32,
            ((self.ball[1] - self.cup.1) / STRING_LEN) as f32,
        ]
    }

    fn in_cup(&self) -> f64 {
        let dx = self.ball[0] - self.cup.0;
        let dy = self.ball[1] - self.cup.1;
        (dx * dx + dy * dy).sqrt()
    }
}

impl Env for BallInCup {
    fn name(&self) -> &'static str {
        "ball_in_cup_catch"
    }
    fn obs_dim(&self) -> usize {
        8
    }
    fn act_dim(&self) -> usize {
        2
    }

    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        self.cup = (rng.uniform_in(-0.1, 0.1) as f64, 0.2);
        self.ball = [
            self.cup.0 + rng.uniform_in(-0.05, 0.05) as f64,
            self.cup.1 - STRING_LEN,
            0.0,
            0.0,
        ];
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32) {
        let ax = action[0].clamp(-1.0, 1.0) as f64 * CUP_SPEED;
        let ay = action[1].clamp(-1.0, 1.0) as f64 * CUP_SPEED;
        for _ in 0..SUBSTEPS {
            self.cup.0 = (self.cup.0 + ax * DT).clamp(-WORKSPACE, WORKSPACE);
            self.cup.1 = (self.cup.1 + ay * DT).clamp(-0.1, WORKSPACE);
            // ballistic ball
            let (dx, dy) = (self.ball[0] - self.cup.0, self.ball[1] - self.cup.1);
            let dist = (dx * dx + dy * dy).sqrt().max(1e-9);
            let (mut fx, mut fy) = (0.0, -G * 0.1); // m = 0.1
            if dist > STRING_LEN {
                // taut string: spring + damping along the string direction
                let stretch = dist - STRING_LEN;
                let (ux, uy) = (dx / dist, dy / dist);
                let v_rad = self.ball[2] * ux + self.ball[3] * uy;
                let f = -STRING_K * stretch - STRING_DAMP * v_rad;
                fx += f * ux;
                fy += f * uy;
            }
            self.ball[2] += fx / 0.1 * DT;
            self.ball[3] += fy / 0.1 * DT;
            self.ball[0] += self.ball[2] * DT;
            self.ball[1] += self.ball[3] * DT;
            // mild velocity clamp for numerical sanity
            self.ball[2] = self.ball[2].clamp(-8.0, 8.0);
            self.ball[3] = self.ball[3].clamp(-8.0, 8.0);
        }
        let r = tolerance(self.in_cup(), 0.0, CUP_R, 0.08);
        (self.obs(), r as f32)
    }

    fn save_state(&self) -> Vec<f64> {
        let mut s = vec![self.cup.0, self.cup.1];
        s.extend_from_slice(&self.ball);
        s
    }

    fn load_state(&mut self, s: &[f64]) {
        self.cup = (s[0], s[1]);
        self.ball.copy_from_slice(&s[2..6]);
    }

    fn render(&self, c: &mut Canvas) {
        c.clear([0.95, 0.93, 0.9]);
        let s = 1.8;
        let (cx, cy) = (self.cup.0 * s, self.cup.1 * s);
        // cup: two walls
        c.line(cx - CUP_R * s, cy + 0.08, cx - CUP_R * s, cy - 0.05, 2, [0.2, 0.3, 0.8]);
        c.line(cx + CUP_R * s, cy + 0.08, cx + CUP_R * s, cy - 0.05, 2, [0.2, 0.3, 0.8]);
        c.line(cx - CUP_R * s, cy - 0.05, cx + CUP_R * s, cy - 0.05, 2, [0.2, 0.3, 0.8]);
        // string + ball
        c.line(cx, cy, self.ball[0] * s, self.ball[1] * s, 1, [0.5, 0.5, 0.5]);
        c.disk(self.ball[0] * s, self.ball[1] * s, 0.07, [0.85, 0.2, 0.2]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ball_hangs_below_cup_at_rest() {
        let mut env = BallInCup::new();
        env.reset(&mut Pcg64::seed(1));
        for _ in 0..400 {
            env.step(&[0.0, 0.0]);
        }
        assert!(env.ball[1] < env.cup.1, "ball must hang below");
        let hang = (env.cup.1 - env.ball[1]).abs();
        assert!((hang - STRING_LEN).abs() < 0.12, "hang={hang}");
    }

    #[test]
    fn ball_in_cup_full_reward() {
        let mut env = BallInCup::new();
        env.ball = [env.cup.0, env.cup.1, 0.0, 0.0];
        let (_, r) = env.step(&[0.0, 0.0]);
        assert!(r > 0.8, "r={r}");
    }

    #[test]
    fn hanging_ball_no_reward() {
        let mut env = BallInCup::new();
        env.reset(&mut Pcg64::seed(2));
        let (_, r) = env.step(&[0.0, 0.0]);
        assert!(r < 0.05, "r={r}");
    }

    #[test]
    fn cup_motion_swings_ball() {
        let mut env = BallInCup::new();
        env.reset(&mut Pcg64::seed(3));
        for i in 0..300 {
            let a = if (i / 25) % 2 == 0 { 1.0 } else { -1.0 };
            env.step(&[a, 0.0]);
        }
        let speed = (env.ball[2].powi(2) + env.ball[3].powi(2)).sqrt();
        assert!(speed > 0.2, "swinging should energize the ball: {speed}");
    }

    #[test]
    fn string_never_stretches_unboundedly() {
        let mut env = BallInCup::new();
        env.reset(&mut Pcg64::seed(4));
        let mut rng = Pcg64::seed(5);
        for _ in 0..1000 {
            let a = [rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0)];
            env.step(&a);
            assert!(env.in_cup() < STRING_LEN * 2.5, "dist={}", env.in_cup());
        }
    }
}
