//! Pendulum swing-up — the simplest task in the suite, used by the
//! quickstart example and the fast end-to-end tests (not part of the
//! six-task planet benchmark).

use super::render::Canvas;
use super::tolerance::tolerance;
use super::{rk4, Env};
use crate::rngs::Pcg64;

const G: f64 = 9.81;
const L: f64 = 1.0;
const M: f64 = 1.0;
const TORQUE: f64 = 2.0; // underactuated: max torque < m g l
const DT: f64 = 0.02;
const SUBSTEPS: usize = 2;

/// State `[θ, θ̇]`, θ = 0 is up.
pub struct PendulumSwingup {
    s: [f64; 2],
}

impl PendulumSwingup {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        PendulumSwingup { s: [std::f64::consts::PI, 0.0] }
    }

    fn obs(&self) -> Vec<f32> {
        // tidy-allow(alloc): per-step obs crosses the Env trait boundary
        // as an owned Vec (collection path, not the learner loop)
        vec![self.s[0].cos() as f32, self.s[0].sin() as f32, (self.s[1] / 8.0) as f32]
    }
}

impl Env for PendulumSwingup {
    fn name(&self) -> &'static str {
        "pendulum_swingup"
    }
    fn obs_dim(&self) -> usize {
        3
    }
    fn act_dim(&self) -> usize {
        1
    }

    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        self.s = [
            std::f64::consts::PI + rng.uniform_in(-0.1, 0.1) as f64,
            rng.uniform_in(-0.05, 0.05) as f64,
        ];
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32) {
        let tau = (action[0].clamp(-1.0, 1.0) as f64) * TORQUE;
        for _ in 0..SUBSTEPS {
            rk4(&mut self.s, DT, |s| {
                [s[1], (-G / L * s[0].sin() - 0.05 * s[1] + tau / (M * L * L))]
            });
        }
        self.s[1] = self.s[1].clamp(-12.0, 12.0);
        let r = tolerance(self.s[0].cos(), 0.95, 1.0, 0.6);
        (self.obs(), r as f32)
    }

    fn save_state(&self) -> Vec<f64> {
        self.s.to_vec()
    }

    fn load_state(&mut self, s: &[f64]) {
        self.s.copy_from_slice(s);
    }

    fn render(&self, c: &mut Canvas) {
        c.clear([0.95, 0.95, 0.9]);
        let (x, y) = (0.6 * self.s[0].sin(), 0.6 * self.s[0].cos());
        c.line(0.0, 0.0, x, y, 2, [0.3, 0.3, 0.3]);
        c.disk(x, y, 0.12, [0.8, 0.2, 0.2]);
        c.disk(0.0, 0.0, 0.05, [0.1, 0.1, 0.1]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_down_with_no_reward() {
        let mut env = PendulumSwingup::new();
        env.reset(&mut Pcg64::seed(1));
        let (_, r) = env.step(&[0.0]);
        assert!(r < 0.05, "r={r}");
    }

    #[test]
    fn up_position_is_rewarded() {
        let mut env = PendulumSwingup::new();
        env.s = [0.0, 0.0];
        let (_, r) = env.step(&[0.0]);
        assert!(r > 0.8, "r={r}");
    }

    #[test]
    fn torque_accelerates() {
        let mut env = PendulumSwingup::new();
        env.s = [std::f64::consts::PI, 0.0];
        for _ in 0..20 {
            env.step(&[1.0]);
        }
        assert!(env.s[1].abs() > 0.1);
    }
}
