//! Finger-spin surrogate: a two-joint "finger" can flick a free spinner;
//! the task is to keep the spinner's angular speed above a threshold
//! (dm_control rewards |ω| ≥ 15 rad/s; scaled here to the surrogate's
//! dynamics). Contact is modeled as a velocity-transfer band around the
//! spinner rim rather than rigid-body collision.

use super::render::Canvas;
use super::tolerance::tolerance;
use super::{rk4, Env};
use crate::rngs::Pcg64;

const DT: f64 = 0.02;
const TORQUE: f64 = 5.0;
const DAMP_FINGER: f64 = 3.0;
const DAMP_SPIN: f64 = 0.08;
const L1: f64 = 0.16;
const L2: f64 = 0.14;
const HUB: (f64, f64) = (0.22, -0.08); // spinner center relative to finger root
const RIM: f64 = 0.08;
const BAND: f64 = 0.06;
const TRANSFER: f64 = 8.0;
const TARGET_SPEED: f64 = 8.0;

/// State `[θ₁, θ̇₁, θ₂, θ̇₂, φ (spinner), ω]`.
pub struct FingerSpin {
    s: [f64; 6],
}

impl FingerSpin {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        FingerSpin { s: [0.0; 6] }
    }

    fn tip(&self) -> (f64, f64, f64, f64) {
        // returns tip position and velocity
        let (t1, w1, t2, w2) = (self.s[0], self.s[1], self.s[2], self.s[3]);
        let x = L1 * t1.cos() + L2 * (t1 + t2).cos();
        let y = L1 * t1.sin() + L2 * (t1 + t2).sin();
        let vx = -L1 * t1.sin() * w1 - L2 * (t1 + t2).sin() * (w1 + w2);
        let vy = L1 * t1.cos() * w1 + L2 * (t1 + t2).cos() * (w1 + w2);
        (x, y, vx, vy)
    }

    fn obs(&self) -> Vec<f32> {
        // tidy-allow(alloc): per-step obs crosses the Env trait boundary
        // as an owned Vec (collection path, not the learner loop)
        vec![
            self.s[0].cos() as f32,
            self.s[0].sin() as f32,
            self.s[2].cos() as f32,
            self.s[2].sin() as f32,
            (self.s[1] / 10.0) as f32,
            (self.s[3] / 10.0) as f32,
            self.s[4].cos() as f32,
            self.s[4].sin() as f32,
            (self.s[5] / 15.0) as f32,
        ]
    }
}

impl Env for FingerSpin {
    fn name(&self) -> &'static str {
        "finger_spin"
    }
    fn obs_dim(&self) -> usize {
        9
    }
    fn act_dim(&self) -> usize {
        2
    }

    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        self.s = [
            rng.uniform_in(-0.5, 0.5) as f64,
            0.0,
            rng.uniform_in(-0.5, 0.5) as f64,
            0.0,
            rng.uniform_in(-3.1, 3.1) as f64,
            0.0,
        ];
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32) {
        let a1 = action[0].clamp(-1.0, 1.0) as f64 * TORQUE;
        let a2 = action[1].clamp(-1.0, 1.0) as f64 * TORQUE;
        // finger joints + free spinner with friction
        rk4(&mut self.s, DT, |s| {
            [
                s[1],
                a1 - DAMP_FINGER * s[1],
                s[3],
                a2 - DAMP_FINGER * s[3],
                s[5],
                -DAMP_SPIN * s[5],
            ]
        });
        // contact band: if the fingertip is near the rim, transfer its
        // tangential velocity into spinner angular momentum
        let (x, y, vx, vy) = self.tip();
        let (dx, dy) = (x - HUB.0, y - HUB.1);
        let dist = (dx * dx + dy * dy).sqrt();
        if (dist - RIM).abs() < BAND && dist > 1e-6 {
            // tangential direction at the contact point (CCW)
            let (tx, ty) = (-dy / dist, dx / dist);
            let v_tan = vx * tx + vy * ty;
            self.s[5] += TRANSFER * v_tan * DT / RIM.max(1e-6);
        }
        self.s[1] = self.s[1].clamp(-25.0, 25.0);
        self.s[3] = self.s[3].clamp(-25.0, 25.0);
        self.s[5] = self.s[5].clamp(-40.0, 40.0);
        let r = tolerance(self.s[5].abs(), TARGET_SPEED, f64::INFINITY, TARGET_SPEED * 0.8);
        (self.obs(), r as f32)
    }

    fn save_state(&self) -> Vec<f64> {
        self.s.to_vec()
    }

    fn load_state(&mut self, s: &[f64]) {
        self.s.copy_from_slice(s);
    }

    fn render(&self, c: &mut Canvas) {
        c.clear([0.93, 0.93, 0.97]);
        let s = 2.2;
        let (t1, t2) = (self.s[0], self.s[2]);
        let j = (L1 * t1.cos() * s, L1 * t1.sin() * s);
        let (x, y, _, _) = self.tip();
        c.line(0.0, 0.0, j.0, j.1, 2, [0.3, 0.3, 0.7]);
        c.line(j.0, j.1, x * s, y * s, 2, [0.4, 0.4, 0.8]);
        // spinner with a marker to show rotation
        c.disk(HUB.0 * s, HUB.1 * s, RIM * s, [0.7, 0.7, 0.3]);
        let (mx, my) = (
            HUB.0 + RIM * 0.7 * self.s[4].cos(),
            HUB.1 + RIM * 0.7 * self.s[4].sin(),
        );
        c.disk(mx * s, my * s, 0.04, [0.9, 0.1, 0.1]);
        let _ = t2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spinner_friction_decays() {
        let mut env = FingerSpin::new();
        env.s[5] = 20.0;
        env.s[0] = -2.0; // finger far from the rim
        for _ in 0..100 {
            env.step(&[0.0, 0.0]);
        }
        assert!(env.s[5] < 20.0);
        assert!(env.s[5] > 0.0, "friction only decays, never reverses");
    }

    #[test]
    fn fast_spin_is_rewarded() {
        let mut env = FingerSpin::new();
        env.s[5] = 12.0;
        env.s[0] = -2.0;
        let (_, r) = env.step(&[0.0, 0.0]);
        assert!(r > 0.9, "r={r}");
    }

    #[test]
    fn still_spinner_no_reward() {
        let mut env = FingerSpin::new();
        env.reset(&mut Pcg64::seed(1));
        env.s[5] = 0.0;
        let (_, r) = env.step(&[0.0, 0.0]);
        assert!(r < 0.1, "r={r}");
    }

    #[test]
    fn flicking_transfers_momentum() {
        let mut env = FingerSpin::new();
        env.s = [0.0; 6];
        // wave the finger around energetically; over enough steps contact
        // should impart some angular velocity at least transiently
        let mut max_w: f64 = 0.0;
        for i in 0..400 {
            let a = if (i / 20) % 2 == 0 { 1.0 } else { -1.0 };
            env.step(&[a, -a]);
            max_w = max_w.max(env.s[5].abs());
        }
        assert!(max_w > 0.05, "no momentum transfer, max_w={max_w}");
    }
}
