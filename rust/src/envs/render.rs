//! Tiny software rasterizer for the RL-from-pixels setting: each task
//! draws its state as simple shapes onto an RGB canvas in `[0,1]`,
//! replacing dm_control's MuJoCo renderer.

/// RGB canvas `[3, side, side]`, channel-major (NCHW-compatible).
#[derive(Debug, Clone)]
pub struct Canvas {
    pub side: usize,
    pub data: Vec<f32>,
}

impl Canvas {
    pub fn new(side: usize) -> Self {
        Canvas { side, data: vec![0.0; 3 * side * side] }
    }

    /// Fill with a background color.
    pub fn clear(&mut self, rgb: [f32; 3]) {
        let n = self.side * self.side;
        for c in 0..3 {
            self.data[c * n..(c + 1) * n].iter_mut().for_each(|v| *v = rgb[c]);
        }
    }

    #[inline]
    fn put(&mut self, x: i64, y: i64, rgb: [f32; 3]) {
        let s = self.side as i64;
        if x < 0 || y < 0 || x >= s || y >= s {
            return;
        }
        let n = self.side * self.side;
        let idx = y as usize * self.side + x as usize;
        for c in 0..3 {
            self.data[c * n + idx] = rgb[c];
        }
    }

    /// World coordinates are `[-1, 1]²` with y up; convert to pixels.
    #[inline]
    fn to_px(&self, wx: f64, wy: f64) -> (i64, i64) {
        let s = self.side as f64;
        let x = ((wx + 1.0) * 0.5 * (s - 1.0)).round() as i64;
        let y = ((1.0 - (wy + 1.0) * 0.5) * (s - 1.0)).round() as i64;
        (x, y)
    }

    /// Filled disk at world position with world-units radius.
    pub fn disk(&mut self, wx: f64, wy: f64, wr: f64, rgb: [f32; 3]) {
        let (cx, cy) = self.to_px(wx, wy);
        let r = (wr * 0.5 * (self.side as f64 - 1.0)).max(0.5);
        let ri = r.ceil() as i64;
        for dy in -ri..=ri {
            for dx in -ri..=ri {
                if (dx * dx + dy * dy) as f64 <= r * r {
                    self.put(cx + dx, cy + dy, rgb);
                }
            }
        }
    }

    /// Line segment between world points, with thickness in pixels.
    pub fn line(&mut self, x0: f64, y0: f64, x1: f64, y1: f64, thick: i64, rgb: [f32; 3]) {
        let (px0, py0) = self.to_px(x0, y0);
        let (px1, py1) = self.to_px(x1, y1);
        let steps = (px1 - px0).abs().max((py1 - py0).abs()).max(1);
        for t in 0..=steps {
            let x = px0 + (px1 - px0) * t / steps;
            let y = py0 + (py1 - py0) * t / steps;
            for dy in -thick / 2..=thick / 2 {
                for dx in -thick / 2..=thick / 2 {
                    self.put(x + dx, y + dy, rgb);
                }
            }
        }
    }

    /// Axis-aligned filled rectangle in world coordinates.
    pub fn rect(&mut self, x0: f64, y0: f64, x1: f64, y1: f64, rgb: [f32; 3]) {
        let (px0, py0) = self.to_px(x0.min(x1), y0.max(y1));
        let (px1, py1) = self.to_px(x0.max(x1), y0.min(y1));
        for y in py0..=py1 {
            for x in px0..=px1 {
                self.put(x, y, rgb);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clear_sets_background() {
        let mut c = Canvas::new(8);
        c.clear([0.2, 0.4, 0.6]);
        assert_eq!(c.data[0], 0.2);
        assert_eq!(c.data[64], 0.4);
        assert_eq!(c.data[128], 0.6);
    }

    #[test]
    fn disk_draws_centered_pixels() {
        let mut c = Canvas::new(17);
        c.disk(0.0, 0.0, 0.2, [1.0, 0.0, 0.0]);
        // center pixel is red
        let center = 8 * 17 + 8;
        assert_eq!(c.data[center], 1.0);
        assert_eq!(c.data[17 * 17 + center], 0.0);
    }

    #[test]
    fn line_connects_endpoints() {
        let mut c = Canvas::new(9);
        c.line(-1.0, -1.0, 1.0, 1.0, 1, [0.0, 1.0, 0.0]);
        // both corners on the green channel
        let n = 81;
        assert_eq!(c.data[n + 8 * 9], 1.0); // bottom-left
        assert_eq!(c.data[n + 8], 1.0); // top-right
    }

    #[test]
    fn out_of_bounds_is_clipped() {
        let mut c = Canvas::new(4);
        c.disk(5.0, 5.0, 0.5, [1.0; 3]); // fully off-screen
        c.line(-3.0, 0.0, 3.0, 0.0, 1, [1.0; 3]); // crosses the canvas
        assert!(c.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rect_fills_area() {
        let mut c = Canvas::new(8);
        c.rect(-1.0, -1.0, 1.0, 0.0, [0.5; 3]);
        // bottom half filled
        let filled = c.data[..64].iter().filter(|&&v| v == 0.5).count();
        assert!(filled >= 24, "filled={filled}");
    }
}
