//! Cartpole swing-up: the classic underactuated cart-pole, dm_control
//! parameters and reward shape (upright × centered × small-control).

use super::render::Canvas;
use super::tolerance::tolerance;
use super::{rk4, Env};
use crate::rngs::Pcg64;

const GRAVITY: f64 = 9.81;
const M_CART: f64 = 1.0;
const M_POLE: f64 = 0.1;
const L_POLE: f64 = 0.5; // half-length
const FORCE: f64 = 10.0;
const DT: f64 = 0.01;
const SUBSTEPS: usize = 2;

/// State: `[x, ẋ, θ, θ̇]`, θ = 0 is **down** (swing-up starts hanging).
pub struct CartpoleSwingup {
    s: [f64; 4],
}

impl CartpoleSwingup {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        CartpoleSwingup { s: [0.0; 4] }
    }

    fn dynamics(s: &[f64; 4], f: f64) -> [f64; 4] {
        let (x_dot, th, th_dot) = (s[1], s[2], s[3]);
        let _ = x_dot;
        let (sin, cos) = th.sin_cos();
        let total = M_CART + M_POLE;
        let pm = M_POLE * L_POLE;
        // standard cart-pole equations (θ measured from the downward
        // vertical, so upright is θ = π)
        let tmp = (f + pm * th_dot * th_dot * sin) / total;
        let th_acc = (GRAVITY * sin - cos * tmp) / (L_POLE * (4.0 / 3.0 - M_POLE * cos * cos / total));
        let x_acc = tmp - pm * th_acc * cos / total;
        [s[1], x_acc, s[3], th_acc]
    }

    fn obs(&self) -> Vec<f32> {
        let th = self.s[2];
        // tidy-allow(alloc): per-step obs crosses the Env trait boundary
        // as an owned Vec (collection path, not the learner loop)
        vec![
            self.s[0] as f32,
            self.s[1] as f32,
            th.cos() as f32,
            th.sin() as f32,
            self.s[3] as f32,
        ]
    }
}

impl Env for CartpoleSwingup {
    fn name(&self) -> &'static str {
        "cartpole_swingup"
    }
    fn obs_dim(&self) -> usize {
        5
    }
    fn act_dim(&self) -> usize {
        1
    }

    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        self.s = [
            rng.uniform_in(-0.1, 0.1) as f64,
            0.0,
            rng.uniform_in(-0.1, 0.1) as f64, // hanging down
            0.0,
        ];
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32) {
        let f = (action[0].clamp(-1.0, 1.0) as f64) * FORCE;
        for _ in 0..SUBSTEPS {
            rk4(&mut self.s, DT, |s| Self::dynamics(s, f));
        }
        // keep the cart on the track
        self.s[0] = self.s[0].clamp(-2.5, 2.5);
        self.s[2] = wrap_pi(self.s[2]);
        // upright means θ = ±π (pole up)
        let upright = (1.0 - self.s[2].cos()) / 2.0;
        let centered = tolerance(self.s[0], -0.25, 0.25, 2.0);
        let small_vel = tolerance(self.s[3], -6.0, 6.0, 6.0);
        let r = upright * (1.0 + centered) / 2.0 * (0.5 + 0.5 * small_vel);
        (self.obs(), r.clamp(0.0, 1.0) as f32)
    }

    fn save_state(&self) -> Vec<f64> {
        self.s.to_vec()
    }

    fn load_state(&mut self, s: &[f64]) {
        self.s.copy_from_slice(s);
    }

    fn render(&self, c: &mut Canvas) {
        c.clear([0.9, 0.9, 0.95]);
        let x = (self.s[0] / 2.5) * 0.8;
        c.rect(x - 0.15, -0.05, x + 0.15, -0.2, [0.2, 0.2, 0.8]);
        // pole: θ = 0 is down
        let th = self.s[2];
        let (px, py) = (x + 0.5 * th.sin(), -0.1 - 0.5 * th.cos());
        c.line(x, -0.1, px, py, 2, [0.8, 0.3, 0.2]);
        c.disk(px, py, 0.08, [0.9, 0.5, 0.1]);
    }
}

fn wrap_pi(th: f64) -> f64 {
    let mut t = (th + std::f64::consts::PI).rem_euclid(2.0 * std::f64::consts::PI);
    t -= std::f64::consts::PI;
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hanging_pole_gives_low_reward() {
        let mut env = CartpoleSwingup::new();
        env.reset(&mut Pcg64::seed(1));
        let (_, r) = env.step(&[0.0]);
        assert!(r < 0.1, "hanging reward {r}");
    }

    #[test]
    fn upright_pole_gives_high_reward() {
        let mut env = CartpoleSwingup::new();
        env.s = [0.0, 0.0, std::f64::consts::PI, 0.0];
        let (_, r) = env.step(&[0.0]);
        assert!(r > 0.7, "upright reward {r}");
    }

    #[test]
    fn energy_injection_swings_pole() {
        let mut env = CartpoleSwingup::new();
        env.reset(&mut Pcg64::seed(2));
        // bang-bang roughly in phase with the pole
        for i in 0..400 {
            let a = if (i / 10) % 2 == 0 { 1.0 } else { -1.0 };
            env.step(&[a]);
        }
        // pole must have left the bottom neighbourhood at some point
        assert!(env.s[3].abs() > 0.01 || env.s[2].abs() > 0.3);
    }

    #[test]
    fn wrap_pi_bounds() {
        for i in -20..20 {
            let w = wrap_pi(i as f64);
            assert!((-std::f64::consts::PI..=std::f64::consts::PI).contains(&w));
        }
    }
}
