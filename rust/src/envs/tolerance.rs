//! dm_control-style smooth reward shaping.
//!
//! `tolerance(x, lo, hi, margin)` is 1 inside `[lo, hi]` and decays
//! smoothly (Gaussian sigmoid, value 0.1 at distance `margin`) outside —
//! the same shaping dm_control's `rewards.tolerance` applies, which keeps
//! every per-step reward in `[0, 1]` and episode returns ≤ 1000.

/// Smooth tolerance reward. 1 inside `[lo, hi]`, Gaussian falloff with
/// the given `margin` outside (value ≈ 0.1 at exactly `margin` away).
/// With `margin == 0` it is a hard indicator.
pub fn tolerance(x: f64, lo: f64, hi: f64, margin: f64) -> f64 {
    debug_assert!(lo <= hi);
    let d = if x < lo {
        lo - x
    } else if x > hi {
        x - hi
    } else {
        return 1.0;
    };
    if margin <= 0.0 {
        return 0.0;
    }
    // Gaussian with value 0.1 at d = margin
    let scale = (-2.0 * (0.1f64).ln()).sqrt(); // ≈ 2.146
    let z = d / margin * scale;
    (-0.5 * z * z).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inside_is_one() {
        assert_eq!(tolerance(0.5, 0.0, 1.0, 0.1), 1.0);
        assert_eq!(tolerance(0.0, 0.0, 1.0, 0.1), 1.0);
        assert_eq!(tolerance(1.0, 0.0, 1.0, 0.1), 1.0);
    }

    #[test]
    fn value_at_margin_is_point_one() {
        let v = tolerance(1.1, 0.0, 1.0, 0.1);
        assert!((v - 0.1).abs() < 1e-9, "v={v}");
        let v = tolerance(-0.2, 0.0, 1.0, 0.2);
        assert!((v - 0.1).abs() < 1e-9);
    }

    #[test]
    fn monotone_decay() {
        let mut prev = 1.0;
        for i in 1..20 {
            let v = tolerance(1.0 + 0.05 * i as f64, 0.0, 1.0, 0.3);
            assert!(v < prev);
            prev = v;
        }
    }

    #[test]
    fn zero_margin_is_indicator() {
        assert_eq!(tolerance(1.01, 0.0, 1.0, 0.0), 0.0);
        assert_eq!(tolerance(0.99, 0.0, 1.0, 0.0), 1.0);
    }

    #[test]
    fn bounded_in_unit_interval() {
        for i in -100..100 {
            let v = tolerance(i as f64 * 0.1, -1.0, 1.0, 0.5);
            assert!((0.0..=1.0).contains(&v));
        }
    }
}
