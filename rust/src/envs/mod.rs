//! Continuous-control environment suite — the stand-in for the
//! dm_control "planet benchmark" (Hafner et al., 2019) used throughout
//! the paper: finger_spin, cartpole_swingup, reacher_easy, cheetah_run,
//! walker_walk, ball_in_cup_catch.
//!
//! Substitution note (see README.md): the tasks are low-dimensional
//! rigid-body / ODE systems with the dm_control task *shape* — actions in
//! `[-1,1]^n`, per-step rewards in `[0,1]` via the same smooth
//! [`tolerance`] shaping dm_control uses, 1000-step episodes with the
//! paper's per-task action repeat (Table 8). Cheetah and walker use
//! planar locomotion surrogates instead of full contact dynamics.
//!
//! Every task also renders itself to small RGB images (see [`render`])
//! for the RL-from-pixels setting of paper §4.6, and [`VecEnv`] steps
//! any number of instances (state- or pixel-observed) in lockstep for
//! vectorized collection and batched evaluation.

mod ballcup;
mod cartpole;
mod cheetah;
mod finger;
mod pendulum;
mod pixels;
mod reacher;
pub mod render;
mod tolerance;
mod vec;
mod walker;

pub use ballcup::BallInCup;
pub use cartpole::CartpoleSwingup;
pub use cheetah::CheetahRun;
pub use finger::FingerSpin;
pub use pendulum::PendulumSwingup;
pub use pixels::PixelEnvAdapter;
pub use reacher::ReacherEasy;
pub use tolerance::tolerance;
pub use vec::VecEnv;
pub use walker::WalkerWalk;

use crate::rngs::Pcg64;

/// A continuous-control task. Episodes are time-limited by the caller
/// (dm_control style — `step` never terminates early).
pub trait Env: Send {
    fn name(&self) -> &'static str;
    fn obs_dim(&self) -> usize;
    fn act_dim(&self) -> usize;
    /// Reset to a (possibly random) initial state, return the observation.
    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32>;
    /// Advance one physics step with `action ∈ [-1,1]^act_dim`; returns
    /// `(obs, reward)` with reward in `[0, 1]`.
    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32);
    /// Draw the current state into an RGB canvas.
    fn render(&self, img: &mut render::Canvas);
    /// Serialize the complete physics state as raw `f64`s (checkpoint
    /// path — cold, not the step loop). Together with
    /// [`Env::load_state`] this must round-trip bitwise: a restored env
    /// continues exactly where the saved one left off.
    fn save_state(&self) -> Vec<f64>;
    /// Restore a [`Env::save_state`] snapshot. Callers must pass a slice
    /// of exactly `save_state().len()` values (the checkpoint decoder
    /// validates this before dispatching).
    fn load_state(&mut self, s: &[f64]);
}

/// The six planet-benchmark task names, in the paper's listing order.
pub const PLANET_TASKS: [&str; 6] = [
    "finger_spin",
    "cartpole_swingup",
    "reacher_easy",
    "cheetah_run",
    "walker_walk",
    "ball_in_cup_catch",
];

/// Every supported task: the planet benchmark plus the cheap
/// `pendulum_swingup` testbed task.
pub const SUPPORTED_TASKS: [&str; 7] = [
    "finger_spin",
    "cartpole_swingup",
    "reacher_easy",
    "cheetah_run",
    "walker_walk",
    "ball_in_cup_catch",
    "pendulum_swingup",
];

/// Paper Table 8 action-repeat per task (values from Hafner et al.
/// 2019); `pendulum_swingup` is not in the paper's suite and uses the
/// table's modal value 4. Every supported task has an explicit arm and
/// unknown names return `None` — configs are rejected up front
/// ([`crate::config::RunConfig::validate`]) instead of silently
/// training with a defaulted repeat.
pub fn try_action_repeat(task: &str) -> Option<usize> {
    Some(match task {
        "cartpole_swingup" => 8,
        "reacher_easy" => 4,
        "cheetah_run" => 4,
        "ball_in_cup_catch" => 4,
        "finger_spin" => 2,
        "walker_walk" => 2,
        "pendulum_swingup" => 4,
        _ => return None,
    })
}

/// Infallible [`try_action_repeat`] for call sites past config
/// validation; panics with the supported-task list on unknown names.
pub fn action_repeat(task: &str) -> usize {
    try_action_repeat(task).unwrap_or_else(|| {
        panic!("unknown task {task:?} — supported: {}", SUPPORTED_TASKS.join(" "))
    })
}

/// Instantiate a task by name.
pub fn make_env(task: &str) -> Option<Box<dyn Env>> {
    let env: Box<dyn Env> = match task {
        "finger_spin" => Box::new(FingerSpin::new()),
        "cartpole_swingup" => Box::new(CartpoleSwingup::new()),
        "reacher_easy" => Box::new(ReacherEasy::new()),
        "cheetah_run" => Box::new(CheetahRun::new()),
        "walker_walk" => Box::new(WalkerWalk::new()),
        "ball_in_cup_catch" => Box::new(BallInCup::new()),
        "pendulum_swingup" => Box::new(PendulumSwingup::new()),
        _ => return None,
    };
    Some(env)
}

/// Clamp an action slice into `[-1, 1]`, reporting whether every
/// component was finite (`false` = the paper's crash condition).
pub fn sanitize_action(a: &mut [f32]) -> bool {
    let mut finite = true;
    for v in a.iter_mut() {
        if !v.is_finite() {
            finite = false;
            *v = 0.0;
        }
        *v = v.clamp(-1.0, 1.0);
    }
    finite
}

/// Classic RK4 integrator over a fixed-size state vector.
pub(crate) fn rk4<const N: usize>(y: &mut [f64; N], dt: f64, f: impl Fn(&[f64; N]) -> [f64; N]) {
    let k1 = f(y);
    let mut y2 = *y;
    for i in 0..N {
        y2[i] = y[i] + 0.5 * dt * k1[i];
    }
    let k2 = f(&y2);
    for i in 0..N {
        y2[i] = y[i] + 0.5 * dt * k2[i];
    }
    let k3 = f(&y2);
    for i in 0..N {
        y2[i] = y[i] + dt * k3[i];
    }
    let k4 = f(&y2);
    for i in 0..N {
        y[i] += dt / 6.0 * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_all_planet_tasks() {
        for task in PLANET_TASKS {
            let mut env = make_env(task).unwrap_or_else(|| panic!("{task}"));
            assert_eq!(env.name(), task);
            let mut rng = Pcg64::seed(1);
            let obs = env.reset(&mut rng);
            assert_eq!(obs.len(), env.obs_dim(), "{task}");
            let act = vec![0.3; env.act_dim()];
            let (obs2, r) = env.step(&act);
            assert_eq!(obs2.len(), env.obs_dim());
            assert!((0.0..=1.0).contains(&r), "{task} reward {r}");
            assert!(obs2.iter().all(|v| v.is_finite()), "{task}");
        }
        assert!(make_env("nope").is_none());
    }

    #[test]
    fn rewards_stay_bounded_under_random_policy() {
        let mut rng = Pcg64::seed(2);
        for task in PLANET_TASKS {
            let mut env = make_env(task).unwrap();
            env.reset(&mut rng);
            for _ in 0..500 {
                let act: Vec<f32> =
                    (0..env.act_dim()).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
                let (obs, r) = env.step(&act);
                assert!((0.0..=1.0).contains(&r), "{task} r={r}");
                assert!(
                    obs.iter().all(|v| v.is_finite() && v.abs() < 1e4),
                    "{task} obs blew up"
                );
            }
        }
    }

    #[test]
    fn resets_are_randomized_but_seeded() {
        for task in PLANET_TASKS {
            let mut env = make_env(task).unwrap();
            let o1 = env.reset(&mut Pcg64::seed(5));
            let o2 = env.reset(&mut Pcg64::seed(5));
            assert_eq!(o1, o2, "{task}: same seed, same reset");
            let o3 = env.reset(&mut Pcg64::seed(6));
            assert_ne!(o1, o3, "{task}: different seed should differ");
        }
    }

    #[test]
    fn sanitize_action_flags_nonfinite() {
        let mut a = vec![0.5, f32::NAN, 2.0];
        assert!(!sanitize_action(&mut a));
        assert_eq!(a, vec![0.5, 0.0, 1.0]);
        let mut b = vec![-0.5, 0.2];
        assert!(sanitize_action(&mut b));
    }

    #[test]
    fn rk4_integrates_harmonic_oscillator() {
        // y'' = -y: one full period ≈ 2π returns to the start
        let mut y = [1.0f64, 0.0];
        let dt = 0.01;
        for _ in 0..628 {
            rk4(&mut y, dt, |s| [s[1], -s[0]]);
        }
        assert!((y[0] - 1.0).abs() < 1e-3, "y0={}", y[0]);
        assert!(y[1].abs() < 1e-2);
    }

    #[test]
    fn action_repeat_matches_table8() {
        assert_eq!(action_repeat("cartpole_swingup"), 8);
        assert_eq!(action_repeat("finger_spin"), 2);
        assert_eq!(action_repeat("cheetah_run"), 4);
        assert_eq!(action_repeat("pendulum_swingup"), 4);
    }

    #[test]
    fn every_supported_task_has_env_and_repeat() {
        for task in SUPPORTED_TASKS {
            assert!(make_env(task).is_some(), "{task}: no env");
            assert!(try_action_repeat(task).is_some(), "{task}: no action repeat");
        }
        assert_eq!(try_action_repeat("not_a_task"), None);
    }

    #[test]
    #[should_panic(expected = "unknown task")]
    fn action_repeat_panics_on_unknown_task() {
        let _ = action_repeat("warehouse_sort");
    }

    #[test]
    fn save_load_state_roundtrips_bitwise() {
        let mut rng = Pcg64::seed(8);
        for task in SUPPORTED_TASKS {
            let mut env = make_env(task).unwrap();
            env.reset(&mut rng);
            let act = vec![0.4; env.act_dim()];
            for _ in 0..17 {
                env.step(&act);
            }
            let saved = env.save_state();
            let mut twin = make_env(task).unwrap();
            twin.load_state(&saved);
            assert_eq!(twin.save_state(), saved, "{task}: state must round-trip");
            for t in 0..50 {
                let (o1, r1) = env.step(&act);
                let (o2, r2) = twin.step(&act);
                assert_eq!(o1, o2, "{task}: obs diverged at step {t}");
                assert_eq!(r1.to_bits(), r2.to_bits(), "{task}: reward diverged at step {t}");
            }
        }
    }

    #[test]
    fn render_produces_normalized_rgb() {
        let mut rng = Pcg64::seed(3);
        for task in PLANET_TASKS {
            let mut env = make_env(task).unwrap();
            env.reset(&mut rng);
            let mut canvas = render::Canvas::new(32);
            env.render(&mut canvas);
            assert!(canvas.data.iter().all(|&v| (0.0..=1.0).contains(&v)), "{task}");
            assert!(canvas.data.iter().any(|&v| v > 0.05), "{task} blank canvas");
        }
    }
}
