//! Vectorized environments: a lockstep vector of per-env instances that
//! the collector/learner trainer and the batched evaluator share.
//!
//! A [`VecEnv`] owns `n` independent env streams (state- or pixel-
//! observed — it subsumes the state/pixels dispatch that used to be
//! duplicated across the trainer and the two evaluators) and steps them
//! in lockstep: one batched policy forward produces one action row per
//! stream, and every stream advances by one agent step (action repeat
//! applied) per round. Episodes are fixed-length (dm_control style), so
//! lockstep is exact — no early termination, no ragged batches.
//!
//! RNG discipline: a `VecEnv` owns no RNG state. Every reset draws from
//! a caller-supplied [`Pcg64`], so the caller decides the stream layout
//! (the trainer keeps the legacy shared stream at `num_envs = 1` for
//! bitwise compatibility and independent per-env streams otherwise; the
//! evaluator seeds one stream per episode).
//!
//! Stepping can be fanned across a [`ThreadPool`]
//! ([`VecEnv::par_step_into`]): each env stream is stepped by exactly
//! one pool task and every output location is written by exactly one
//! stream, so the parallel path is bitwise identical to the serial
//! [`VecEnv::step_into`] loop — which is what lets the async collector
//! parallelize physics/rendering (the wall-time sink for pixel tasks)
//! without touching the determinism contract.

use super::pixels::PixelEnvAdapter;
use super::{make_env, sanitize_action, try_action_repeat, Env, SUPPORTED_TASKS};
use crate::config::RunConfig;
use crate::nn::pool::ThreadPool;
use crate::nn::Tensor;
use crate::rngs::Pcg64;

/// One environment stream: a raw state-observed [`Env`] or a pixel
/// adapter around it.
enum EnvObs {
    State(Box<dyn Env>),
    Pixels(PixelEnvAdapter),
}

impl EnvObs {
    /// Fallible construction — unknown task names become an `Err`
    /// naming the supported suite instead of a panic deep inside a run
    /// (the same contract as [`RunConfig::validate`]).
    fn build(cfg: &RunConfig) -> Result<EnvObs, String> {
        let env = make_env(&cfg.task).ok_or_else(|| {
            format!("unknown task {:?} (supported: {})", cfg.task, SUPPORTED_TASKS.join(" "))
        })?;
        Ok(if cfg.pixels {
            EnvObs::Pixels(PixelEnvAdapter::new(env, cfg.image_size, cfg.frame_stack))
        } else {
            EnvObs::State(env)
        })
    }

    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        match self {
            EnvObs::State(e) => e.reset(rng),
            EnvObs::Pixels(p) => p.reset(rng),
        }
    }

    fn step(&mut self, a: &[f32]) -> (Vec<f32>, f32) {
        match self {
            EnvObs::State(e) => e.step(a),
            EnvObs::Pixels(p) => p.step(a),
        }
    }

    fn act_dim(&self) -> usize {
        match self {
            EnvObs::State(e) => e.act_dim(),
            EnvObs::Pixels(p) => p.env.act_dim(),
        }
    }
}

/// A lockstep vector of `n` env streams sharing one task configuration.
pub struct VecEnv {
    envs: Vec<EnvObs>,
    obs_shape: Vec<usize>,
    obs_len: usize,
    act_dim: usize,
    repeat: usize,
}

/// `Sync` wrapper over the raw output/env pointers
/// [`VecEnv::par_step_into`] hands to the pool; each task touches only
/// its own index, so the shared pointer never aliases a write.
struct ParPtrs {
    envs: *mut EnvObs,
    next: *mut f32,
    rew: *mut f32,
}
// SAFETY: tasks access disjoint env slots / output rows (index i only),
// and `EnvObs` is `Send` (asserted below), so moving the exclusive
// access to a worker thread is sound.
unsafe impl Send for ParPtrs {}
// SAFETY: as above — every task touches only its own index i.
unsafe impl Sync for ParPtrs {}

#[allow(dead_code)]
fn assert_env_obs_is_send(e: EnvObs) -> impl Send {
    e
}

/// One agent step of a single env stream: `repeat` raw steps, reward
/// summed, only the final observation copied out. The single definition
/// both [`VecEnv::step_into`] and [`VecEnv::par_step_into`] execute —
/// which is what makes the pooled path bitwise identical to the serial
/// one by construction.
fn agent_step(env: &mut EnvObs, repeat: usize, a: &[f32], out: &mut [f32]) -> f32 {
    let mut rew = 0.0f32;
    // tidy-allow(alloc): `Vec::new` is capacity-0; the obs Vec moved in
    // from `step` is the (annotated) env-boundary allocation
    let mut last = Vec::new();
    for _ in 0..repeat {
        let (o, r) = env.step(a);
        last = o;
        rew += r;
    }
    out.copy_from_slice(&last);
    rew
}

impl VecEnv {
    /// Build `n` independent instances of the configured task. Unknown
    /// task names are an `Err` (the fallible path behind
    /// [`RunConfig::validate`]) — nothing here panics.
    pub fn new(cfg: &RunConfig, n: usize) -> Result<VecEnv, String> {
        let repeat = try_action_repeat(&cfg.task).ok_or_else(|| {
            format!("unknown task {:?} (supported: {})", cfg.task, SUPPORTED_TASKS.join(" "))
        })?;
        // env construction draws no RNG, so the dims probe doubles as
        // stream 0 instead of being thrown away
        let probe = EnvObs::build(cfg)?;
        let act_dim = probe.act_dim();
        let obs_shape: Vec<usize> = if cfg.pixels {
            vec![cfg.frame_stack * 3, cfg.image_size, cfg.image_size]
        } else {
            match &probe {
                EnvObs::State(e) => vec![e.obs_dim()],
                EnvObs::Pixels(_) => unreachable!(),
            }
        };
        let obs_len = obs_shape.iter().product();
        let mut envs = Vec::with_capacity(n);
        if n > 0 {
            envs.push(probe);
            for _ in 1..n {
                envs.push(EnvObs::build(cfg)?);
            }
        }
        Ok(VecEnv { envs, obs_shape, obs_len, act_dim, repeat })
    }

    pub fn num_envs(&self) -> usize {
        self.envs.len()
    }

    /// Flat f32 length of one observation (states: `obs_dim`; pixels:
    /// `stack·3·side²`).
    pub fn obs_len(&self) -> usize {
        self.obs_len
    }

    pub fn act_dim(&self) -> usize {
        self.act_dim
    }

    /// Per-observation shape (`[D]` states, `[C, H, W]` pixels) — what
    /// the replay buffer stores and the agent consumes.
    pub fn obs_shape(&self) -> &[usize] {
        &self.obs_shape
    }

    /// The task's paper action repeat; one agent step = `repeat` raw
    /// env steps.
    pub fn action_repeat(&self) -> usize {
        self.repeat
    }

    /// Reset env `i` with the caller's RNG, writing its observation into
    /// `out` (length [`VecEnv::obs_len`]).
    pub fn reset_into(&mut self, i: usize, rng: &mut Pcg64, out: &mut [f32]) {
        let o = self.envs[i].reset(rng);
        out.copy_from_slice(&o);
    }

    /// Advance env `i` one agent step (action repeat applied), writing
    /// the next observation into `out`; returns the reward summed over
    /// the repeated raw steps (the trainer's transition reward). Only
    /// the final repeated step's observation survives, so it alone is
    /// copied out.
    pub fn step_into(&mut self, i: usize, a: &[f32], out: &mut [f32]) -> f32 {
        agent_step(&mut self.envs[i], self.repeat, a, out)
    }

    /// Advance env streams `0..k` one agent step each, in parallel
    /// across `pool` (`grain` streams per claim — see
    /// [`ThreadPool::run_chunked`]): stream `i` consumes `acts.row(i)`
    /// and writes row `i` of `next_flat` plus `rew[i]`. Bitwise
    /// identical to `k` serial [`VecEnv::step_into`] calls — streams are
    /// independent and every output location has exactly one writer —
    /// so the collector can fan physics/rendering out without touching
    /// the determinism contract.
    pub fn par_step_into(
        &mut self,
        k: usize,
        acts: &Tensor,
        next_flat: &mut [f32],
        rew: &mut [f32],
        pool: &ThreadPool,
        grain: usize,
    ) {
        assert!(k <= self.envs.len());
        assert_eq!(acts.rows(), k);
        assert_eq!(next_flat.len(), k * self.obs_len);
        assert_eq!(rew.len(), k);
        let obs_len = self.obs_len;
        let repeat = self.repeat;
        let p = ParPtrs {
            envs: self.envs.as_mut_ptr(),
            next: next_flat.as_mut_ptr(),
            rew: rew.as_mut_ptr(),
        };
        pool.run_chunked(k, grain, |i| {
            // SAFETY: task i exclusively owns env slot i, output row i
            // and rew[i]; bounds are checked by the asserts above.
            unsafe {
                let env = &mut *p.envs.add(i);
                let out = std::slice::from_raw_parts_mut(p.next.add(i * obs_len), obs_len);
                *p.rew.add(i) = agent_step(env, repeat, acts.row(i), out);
            }
        });
    }

    /// Serialize the complete state of every env stream (physics f64s
    /// and, for pixel streams, the frame stacks) for a checkpoint.
    pub fn ckpt_write(&self, enc: &mut crate::ckpt::Enc) {
        enc.u64(self.envs.len() as u64);
        for e in &self.envs {
            match e {
                EnvObs::State(env) => {
                    enc.u8(0);
                    enc.f64s(&env.save_state());
                }
                EnvObs::Pixels(p) => {
                    enc.u8(1);
                    enc.f64s(&p.env.save_state());
                    p.ckpt_write(enc);
                }
            }
        }
    }

    /// Restore a [`VecEnv::ckpt_read`] snapshot into this (identically
    /// configured) vector: every stream continues bitwise where the
    /// saved one left off. Stream count, observation mode, and state
    /// sizes are all validated — a mismatched checkpoint is a typed
    /// error, never a panic.
    pub fn ckpt_read(&mut self, dec: &mut crate::ckpt::Dec) -> anyhow::Result<()> {
        let n = dec.usize()?;
        anyhow::ensure!(
            n == self.envs.len(),
            "checkpoint holds {n} env streams, this run has {}",
            self.envs.len()
        );
        for (i, e) in self.envs.iter_mut().enumerate() {
            let tag = dec.u8()?;
            let want_tag = match e {
                EnvObs::State(_) => 0,
                EnvObs::Pixels(_) => 1,
            };
            anyhow::ensure!(
                tag == want_tag,
                "env stream {i}: checkpoint observation mode tag {tag} != configured {want_tag}"
            );
            let state = dec.f64s()?;
            match e {
                EnvObs::State(env) => {
                    anyhow::ensure!(
                        state.len() == env.save_state().len(),
                        "env stream {i}: checkpoint physics state has {} values, expected {}",
                        state.len(),
                        env.save_state().len()
                    );
                    env.load_state(&state);
                }
                EnvObs::Pixels(p) => {
                    anyhow::ensure!(
                        state.len() == p.env.save_state().len(),
                        "env stream {i}: checkpoint physics state has {} values, expected {}",
                        state.len(),
                        p.env.save_state().len()
                    );
                    p.env.load_state(&state);
                    p.ckpt_read(dec)?;
                }
            }
        }
        Ok(())
    }

    /// Lockstep evaluation step: sanitize row `i` of `acts` in place,
    /// advance env `i` one agent step with it, overwrite row `i` of
    /// `obs_flat` with the next observation and accumulate each raw
    /// step's reward into `totals[i]`. Returns `false` as soon as any
    /// action row is non-finite (the paper's crash condition) — envs
    /// before that row have already stepped, matching the reference
    /// evaluator's early-out.
    pub fn step_lockstep(
        &mut self,
        acts: &mut Tensor,
        obs_flat: &mut [f32],
        totals: &mut [f64],
    ) -> bool {
        let n = self.envs.len();
        assert_eq!(acts.rows(), n);
        assert_eq!(obs_flat.len(), n * self.obs_len);
        assert_eq!(totals.len(), n);
        for i in 0..n {
            if !sanitize_action(acts.row_mut(i)) {
                return false;
            }
            let mut last = Vec::new();
            for _ in 0..self.repeat {
                let (o, r) = self.envs[i].step(acts.row(i));
                totals[i] += r as f64;
                last = o;
            }
            obs_flat[i * self.obs_len..(i + 1) * self.obs_len].copy_from_slice(&last);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::SUPPORTED_TASKS;

    fn cfg(task: &str) -> RunConfig {
        RunConfig { task: task.into(), ..Default::default() }
    }

    #[test]
    fn builds_every_supported_task() {
        for task in SUPPORTED_TASKS {
            let mut v = VecEnv::new(&cfg(task), 2).unwrap();
            assert_eq!(v.num_envs(), 2);
            assert_eq!(v.obs_shape().iter().product::<usize>(), v.obs_len());
            let mut rng = Pcg64::seed(1);
            let mut obs = vec![0.0f32; v.obs_len()];
            v.reset_into(0, &mut rng, &mut obs);
            assert!(obs.iter().all(|x| x.is_finite()), "{task}");
        }
    }

    #[test]
    fn unknown_task_is_an_error_not_a_panic() {
        let err = VecEnv::new(&cfg("warehouse_sort"), 1).unwrap_err();
        assert!(err.contains("unknown task"), "{err}");
        assert!(err.contains("pendulum_swingup"), "error lists the supported suite: {err}");
    }

    #[test]
    fn streams_match_raw_envs_in_lockstep() {
        // Each VecEnv stream must be indistinguishable from a standalone
        // env driven with the same RNG stream and actions.
        let c = cfg("cartpole_swingup");
        let n = 3;
        let mut v = VecEnv::new(&c, n).unwrap();
        let mut raw: Vec<Box<dyn Env>> =
            (0..n).map(|_| make_env(&c.task).unwrap()).collect();
        let repeat = v.action_repeat();
        let mut obs = vec![0.0f32; v.obs_len()];
        for i in 0..n {
            let mut rng = Pcg64::seed_stream(9, i as u64);
            v.reset_into(i, &mut rng, &mut obs);
            let want = raw[i].reset(&mut Pcg64::seed_stream(9, i as u64));
            assert_eq!(obs, want, "env {i} reset");
            let a = vec![0.25f32; v.act_dim()];
            let rew = v.step_into(i, &a, &mut obs);
            let mut want_rew = 0.0f32;
            let mut want_obs = Vec::new();
            for _ in 0..repeat {
                let (o, r) = raw[i].step(&a);
                want_obs = o;
                want_rew += r;
            }
            assert_eq!(obs, want_obs, "env {i} step obs");
            assert_eq!(rew, want_rew, "env {i} step reward");
        }
    }

    #[test]
    fn par_step_into_matches_serial_step_into_bitwise() {
        for (task, pixels) in [("cheetah_run", false), ("pendulum_swingup", true)] {
            let mut c = cfg(task);
            if pixels {
                c.pixels = true;
                c.image_size = 11;
                c.frame_stack = 3;
            }
            let n = 5;
            let mut serial = VecEnv::new(&c, n).unwrap();
            let mut par = VecEnv::new(&c, n).unwrap();
            let obs_len = serial.obs_len();
            let mut buf = vec![0.0f32; obs_len];
            for i in 0..n {
                let mut r1 = Pcg64::seed_stream(5, i as u64);
                let mut r2 = Pcg64::seed_stream(5, i as u64);
                serial.reset_into(i, &mut r1, &mut buf);
                par.reset_into(i, &mut r2, &mut buf);
            }
            let pool = ThreadPool::new(4);
            let mut acts = Tensor::zeros(&[n, serial.act_dim()]);
            let mut rng = Pcg64::seed(77);
            for round in 0..3 {
                for v in acts.data.iter_mut() {
                    *v = rng.uniform_in(-1.0, 1.0);
                }
                let mut want_next = vec![0.0f32; n * obs_len];
                let mut want_rew = vec![0.0f32; n];
                for i in 0..n {
                    want_rew[i] = serial
                        .step_into(i, acts.row(i), &mut want_next[i * obs_len..(i + 1) * obs_len]);
                }
                let mut got_next = vec![0.0f32; n * obs_len];
                let mut got_rew = vec![0.0f32; n];
                // stepping mutates the envs, so each round exercises one
                // grain; alternating rounds cover both grain values
                let grain = 1 + round % 2;
                par.par_step_into(n, &acts, &mut got_next, &mut got_rew, &pool, grain);
                assert_eq!(
                    want_next.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    got_next.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{task} round {round} next obs"
                );
                assert_eq!(
                    want_rew.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    got_rew.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{task} round {round} rewards"
                );
            }
        }
    }

    #[test]
    fn pixel_streams_have_stacked_shape() {
        let mut c = cfg("pendulum_swingup");
        c.pixels = true;
        c.image_size = 12;
        c.frame_stack = 3;
        let mut v = VecEnv::new(&c, 2).unwrap();
        assert_eq!(v.obs_shape(), &[9, 12, 12]);
        assert_eq!(v.obs_len(), 9 * 12 * 12);
        let mut rng = Pcg64::seed(4);
        let mut obs = vec![0.0f32; v.obs_len()];
        v.reset_into(1, &mut rng, &mut obs);
        assert!(obs.iter().all(|x| (0.0..=1.0).contains(x)));
    }

    #[test]
    fn ckpt_roundtrip_resumes_streams_bitwise() {
        for (task, pixels) in [("walker_walk", false), ("pendulum_swingup", true)] {
            let mut c = cfg(task);
            if pixels {
                c.pixels = true;
                c.image_size = 10;
                c.frame_stack = 3;
            }
            let n = 3;
            let mut v = VecEnv::new(&c, n).unwrap();
            let obs_len = v.obs_len();
            let mut buf = vec![0.0f32; obs_len];
            for i in 0..n {
                let mut r = Pcg64::seed_stream(3, i as u64);
                v.reset_into(i, &mut r, &mut buf);
            }
            let a = vec![0.3f32; v.act_dim()];
            for i in 0..n {
                v.step_into(i, &a, &mut buf);
            }
            let mut enc = crate::ckpt::Enc::new();
            v.ckpt_write(&mut enc);
            let bytes = enc.into_bytes();
            let mut twin = VecEnv::new(&c, n).unwrap();
            let mut dec = crate::ckpt::Dec::new(&bytes);
            twin.ckpt_read(&mut dec).unwrap();
            dec.finish().unwrap();
            let mut want = vec![0.0f32; obs_len];
            let mut got = vec![0.0f32; obs_len];
            for round in 0..5 {
                for i in 0..n {
                    let rw = v.step_into(i, &a, &mut want);
                    let rg = twin.step_into(i, &a, &mut got);
                    assert_eq!(
                        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{task} env {i} round {round}: obs diverged after resume"
                    );
                    assert_eq!(rw.to_bits(), rg.to_bits(), "{task} env {i} round {round}");
                }
            }
        }
    }

    #[test]
    fn ckpt_read_rejects_mismatched_shapes() {
        let c = cfg("pendulum_swingup");
        let mut v = VecEnv::new(&c, 2).unwrap();
        let mut rng = Pcg64::seed(1);
        let mut buf = vec![0.0f32; v.obs_len()];
        for i in 0..2 {
            v.reset_into(i, &mut rng, &mut buf);
        }
        let mut enc = crate::ckpt::Enc::new();
        v.ckpt_write(&mut enc);
        let bytes = enc.into_bytes();
        // wrong stream count
        let mut narrow = VecEnv::new(&c, 1).unwrap();
        let err = narrow.ckpt_read(&mut crate::ckpt::Dec::new(&bytes)).unwrap_err();
        assert!(format!("{err}").contains("env streams"), "{err}");
        // wrong observation mode
        let mut pc = cfg("pendulum_swingup");
        pc.pixels = true;
        pc.image_size = 8;
        let mut px = VecEnv::new(&pc, 2).unwrap();
        let err = px.ckpt_read(&mut crate::ckpt::Dec::new(&bytes)).unwrap_err();
        assert!(format!("{err}").contains("observation mode"), "{err}");
        // truncated payload is an error, not a panic
        let mut v2 = VecEnv::new(&c, 2).unwrap();
        assert!(v2.ckpt_read(&mut crate::ckpt::Dec::new(&bytes[..bytes.len() / 2])).is_err());
    }

    #[test]
    fn lockstep_flags_nonfinite_actions() {
        let c = cfg("pendulum_swingup");
        let mut v = VecEnv::new(&c, 2).unwrap();
        let mut rngs: Vec<Pcg64> = (0..2).map(|i| Pcg64::seed_stream(1, i)).collect();
        let mut obs = vec![0.0f32; 2 * v.obs_len()];
        for i in 0..2 {
            let (lo, hi) = (i * v.obs_len(), (i + 1) * v.obs_len());
            let mut row = vec![0.0f32; v.obs_len()];
            v.reset_into(i, &mut rngs[i], &mut row);
            obs[lo..hi].copy_from_slice(&row);
        }
        let mut totals = vec![0.0f64; 2];
        let mut good = Tensor::from_vec(&[2, 1], vec![0.1, -0.1]);
        assert!(v.step_lockstep(&mut good, &mut obs, &mut totals));
        assert!(totals.iter().all(|&t| t >= 0.0));
        let mut bad = Tensor::from_vec(&[2, 1], vec![0.1, f32::NAN]);
        assert!(!v.step_lockstep(&mut bad, &mut obs, &mut totals));
    }
}
