//! Vectorized environments: a lockstep vector of per-env instances that
//! the collector/learner trainer and the batched evaluator share.
//!
//! A [`VecEnv`] owns `n` independent env streams (state- or pixel-
//! observed — it subsumes the state/pixels dispatch that used to be
//! duplicated across the trainer and the two evaluators) and steps them
//! in lockstep: one batched policy forward produces one action row per
//! stream, and every stream advances by one agent step (action repeat
//! applied) per round. Episodes are fixed-length (dm_control style), so
//! lockstep is exact — no early termination, no ragged batches.
//!
//! RNG discipline: a `VecEnv` owns no RNG state. Every reset draws from
//! a caller-supplied [`Pcg64`], so the caller decides the stream layout
//! (the trainer keeps the legacy shared stream at `num_envs = 1` for
//! bitwise compatibility and independent per-env streams otherwise; the
//! evaluator seeds one stream per episode).

use super::pixels::PixelEnvAdapter;
use super::{action_repeat, make_env, sanitize_action, Env};
use crate::config::RunConfig;
use crate::nn::Tensor;
use crate::rngs::Pcg64;

/// One environment stream: a raw state-observed [`Env`] or a pixel
/// adapter around it.
enum EnvObs {
    State(Box<dyn Env>),
    Pixels(PixelEnvAdapter),
}

impl EnvObs {
    fn build(cfg: &RunConfig) -> EnvObs {
        let env = make_env(&cfg.task).unwrap_or_else(|| panic!("unknown task {}", cfg.task));
        if cfg.pixels {
            EnvObs::Pixels(PixelEnvAdapter::new(env, cfg.image_size, cfg.frame_stack))
        } else {
            EnvObs::State(env)
        }
    }

    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        match self {
            EnvObs::State(e) => e.reset(rng),
            EnvObs::Pixels(p) => p.reset(rng),
        }
    }

    fn step(&mut self, a: &[f32]) -> (Vec<f32>, f32) {
        match self {
            EnvObs::State(e) => e.step(a),
            EnvObs::Pixels(p) => p.step(a),
        }
    }

    fn act_dim(&self) -> usize {
        match self {
            EnvObs::State(e) => e.act_dim(),
            EnvObs::Pixels(p) => p.env.act_dim(),
        }
    }
}

/// A lockstep vector of `n` env streams sharing one task configuration.
pub struct VecEnv {
    envs: Vec<EnvObs>,
    obs_shape: Vec<usize>,
    obs_len: usize,
    act_dim: usize,
    repeat: usize,
}

impl VecEnv {
    /// Build `n` independent instances of the configured task. Panics on
    /// unknown task names — call sites sit behind
    /// [`RunConfig::validate`].
    pub fn new(cfg: &RunConfig, n: usize) -> VecEnv {
        // env construction draws no RNG, so the dims probe doubles as
        // stream 0 instead of being thrown away
        let probe = EnvObs::build(cfg);
        let act_dim = probe.act_dim();
        let obs_shape: Vec<usize> = if cfg.pixels {
            vec![cfg.frame_stack * 3, cfg.image_size, cfg.image_size]
        } else {
            match &probe {
                EnvObs::State(e) => vec![e.obs_dim()],
                EnvObs::Pixels(_) => unreachable!(),
            }
        };
        let obs_len = obs_shape.iter().product();
        let mut envs = Vec::with_capacity(n);
        if n > 0 {
            envs.push(probe);
            envs.extend((1..n).map(|_| EnvObs::build(cfg)));
        }
        VecEnv { envs, obs_shape, obs_len, act_dim, repeat: action_repeat(&cfg.task) }
    }

    pub fn num_envs(&self) -> usize {
        self.envs.len()
    }

    /// Flat f32 length of one observation (states: `obs_dim`; pixels:
    /// `stack·3·side²`).
    pub fn obs_len(&self) -> usize {
        self.obs_len
    }

    pub fn act_dim(&self) -> usize {
        self.act_dim
    }

    /// Per-observation shape (`[D]` states, `[C, H, W]` pixels) — what
    /// the replay buffer stores and the agent consumes.
    pub fn obs_shape(&self) -> &[usize] {
        &self.obs_shape
    }

    /// The task's paper action repeat; one agent step = `repeat` raw
    /// env steps.
    pub fn action_repeat(&self) -> usize {
        self.repeat
    }

    /// Reset env `i` with the caller's RNG, writing its observation into
    /// `out` (length [`VecEnv::obs_len`]).
    pub fn reset_into(&mut self, i: usize, rng: &mut Pcg64, out: &mut [f32]) {
        let o = self.envs[i].reset(rng);
        out.copy_from_slice(&o);
    }

    /// Advance env `i` one agent step (action repeat applied), writing
    /// the next observation into `out`; returns the reward summed over
    /// the repeated raw steps (the trainer's transition reward). Only
    /// the final repeated step's observation survives, so it alone is
    /// copied out.
    pub fn step_into(&mut self, i: usize, a: &[f32], out: &mut [f32]) -> f32 {
        let mut rew = 0.0f32;
        let mut last = Vec::new();
        for _ in 0..self.repeat {
            let (o, r) = self.envs[i].step(a);
            last = o;
            rew += r;
        }
        out.copy_from_slice(&last);
        rew
    }

    /// Lockstep evaluation step: sanitize row `i` of `acts` in place,
    /// advance env `i` one agent step with it, overwrite row `i` of
    /// `obs_flat` with the next observation and accumulate each raw
    /// step's reward into `totals[i]`. Returns `false` as soon as any
    /// action row is non-finite (the paper's crash condition) — envs
    /// before that row have already stepped, matching the reference
    /// evaluator's early-out.
    pub fn step_lockstep(
        &mut self,
        acts: &mut Tensor,
        obs_flat: &mut [f32],
        totals: &mut [f64],
    ) -> bool {
        let n = self.envs.len();
        assert_eq!(acts.rows(), n);
        assert_eq!(obs_flat.len(), n * self.obs_len);
        assert_eq!(totals.len(), n);
        for i in 0..n {
            if !sanitize_action(acts.row_mut(i)) {
                return false;
            }
            let mut last = Vec::new();
            for _ in 0..self.repeat {
                let (o, r) = self.envs[i].step(acts.row(i));
                totals[i] += r as f64;
                last = o;
            }
            obs_flat[i * self.obs_len..(i + 1) * self.obs_len].copy_from_slice(&last);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::SUPPORTED_TASKS;

    fn cfg(task: &str) -> RunConfig {
        RunConfig { task: task.into(), ..Default::default() }
    }

    #[test]
    fn builds_every_supported_task() {
        for task in SUPPORTED_TASKS {
            let mut v = VecEnv::new(&cfg(task), 2);
            assert_eq!(v.num_envs(), 2);
            assert_eq!(v.obs_shape().iter().product::<usize>(), v.obs_len());
            let mut rng = Pcg64::seed(1);
            let mut obs = vec![0.0f32; v.obs_len()];
            v.reset_into(0, &mut rng, &mut obs);
            assert!(obs.iter().all(|x| x.is_finite()), "{task}");
        }
    }

    #[test]
    fn streams_match_raw_envs_in_lockstep() {
        // Each VecEnv stream must be indistinguishable from a standalone
        // env driven with the same RNG stream and actions.
        let c = cfg("cartpole_swingup");
        let n = 3;
        let mut v = VecEnv::new(&c, n);
        let mut raw: Vec<Box<dyn Env>> =
            (0..n).map(|_| make_env(&c.task).unwrap()).collect();
        let repeat = v.action_repeat();
        let mut obs = vec![0.0f32; v.obs_len()];
        for i in 0..n {
            let mut rng = Pcg64::seed_stream(9, i as u64);
            v.reset_into(i, &mut rng, &mut obs);
            let want = raw[i].reset(&mut Pcg64::seed_stream(9, i as u64));
            assert_eq!(obs, want, "env {i} reset");
            let a = vec![0.25f32; v.act_dim()];
            let rew = v.step_into(i, &a, &mut obs);
            let mut want_rew = 0.0f32;
            let mut want_obs = Vec::new();
            for _ in 0..repeat {
                let (o, r) = raw[i].step(&a);
                want_obs = o;
                want_rew += r;
            }
            assert_eq!(obs, want_obs, "env {i} step obs");
            assert_eq!(rew, want_rew, "env {i} step reward");
        }
    }

    #[test]
    fn pixel_streams_have_stacked_shape() {
        let mut c = cfg("pendulum_swingup");
        c.pixels = true;
        c.image_size = 12;
        c.frame_stack = 3;
        let mut v = VecEnv::new(&c, 2);
        assert_eq!(v.obs_shape(), &[9, 12, 12]);
        assert_eq!(v.obs_len(), 9 * 12 * 12);
        let mut rng = Pcg64::seed(4);
        let mut obs = vec![0.0f32; v.obs_len()];
        v.reset_into(1, &mut rng, &mut obs);
        assert!(obs.iter().all(|x| (0.0..=1.0).contains(x)));
    }

    #[test]
    fn lockstep_flags_nonfinite_actions() {
        let c = cfg("pendulum_swingup");
        let mut v = VecEnv::new(&c, 2);
        let mut rngs: Vec<Pcg64> = (0..2).map(|i| Pcg64::seed_stream(1, i)).collect();
        let mut obs = vec![0.0f32; 2 * v.obs_len()];
        for i in 0..2 {
            let (lo, hi) = (i * v.obs_len(), (i + 1) * v.obs_len());
            let mut row = vec![0.0f32; v.obs_len()];
            v.reset_into(i, &mut rngs[i], &mut row);
            obs[lo..hi].copy_from_slice(&row);
        }
        let mut totals = vec![0.0f64; 2];
        let mut good = Tensor::from_vec(&[2, 1], vec![0.1, -0.1]);
        assert!(v.step_lockstep(&mut good, &mut obs, &mut totals));
        assert!(totals.iter().all(|&t| t >= 0.0));
        let mut bad = Tensor::from_vec(&[2, 1], vec![0.1, f32::NAN]);
        assert!(!v.step_lockstep(&mut bad, &mut obs, &mut totals));
    }
}
