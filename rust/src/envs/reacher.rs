//! Reacher (easy): a 2-link planar arm must put its fingertip on a
//! random target. Torque-controlled damped joints; dm_control-style
//! reward `tolerance(dist, 0, target_size)` with a margin that makes the
//! "easy" variant learnable.

use super::render::Canvas;
use super::tolerance::tolerance;
use super::{rk4, Env};
use crate::rngs::Pcg64;

const L1: f64 = 0.12;
const L2: f64 = 0.12;
const DT: f64 = 0.02;
const TORQUE: f64 = 4.0;
const DAMPING: f64 = 2.0;
const TARGET_SIZE: f64 = 0.05;

/// State `[θ₁, θ̇₁, θ₂, θ̇₂]` + target `(tx, ty)`.
pub struct ReacherEasy {
    s: [f64; 4],
    target: (f64, f64),
}

impl ReacherEasy {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        ReacherEasy { s: [0.0; 4], target: (0.1, 0.1) }
    }

    fn tip(&self) -> (f64, f64) {
        let (t1, t2) = (self.s[0], self.s[2]);
        (L1 * t1.cos() + L2 * (t1 + t2).cos(), L1 * t1.sin() + L2 * (t1 + t2).sin())
    }

    fn obs(&self) -> Vec<f32> {
        let (tx, ty) = self.target;
        let (px, py) = self.tip();
        // tidy-allow(alloc): per-step obs crosses the Env trait boundary
        // as an owned Vec (collection path, not the learner loop)
        vec![
            self.s[0].cos() as f32,
            self.s[0].sin() as f32,
            self.s[2].cos() as f32,
            self.s[2].sin() as f32,
            (self.s[1] / 10.0) as f32,
            (self.s[3] / 10.0) as f32,
            (tx / 0.24) as f32,
            (ty / 0.24) as f32,
            ((tx - px) / 0.48) as f32,
            ((ty - py) / 0.48) as f32,
        ]
    }
}

impl Env for ReacherEasy {
    fn name(&self) -> &'static str {
        "reacher_easy"
    }
    fn obs_dim(&self) -> usize {
        10
    }
    fn act_dim(&self) -> usize {
        2
    }

    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        self.s = [
            rng.uniform_in(-3.0, 3.0) as f64,
            0.0,
            rng.uniform_in(-3.0, 3.0) as f64,
            0.0,
        ];
        // target somewhere reachable
        let ang = rng.uniform_in(-3.14, 3.14) as f64;
        let rad = rng.uniform_in(0.08, 0.20) as f64;
        self.target = (rad * ang.cos(), rad * ang.sin());
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32) {
        let a1 = action[0].clamp(-1.0, 1.0) as f64 * TORQUE;
        let a2 = action[1].clamp(-1.0, 1.0) as f64 * TORQUE;
        rk4(&mut self.s, DT, |s| {
            [s[1], a1 - DAMPING * s[1], s[3], a2 - DAMPING * s[3]]
        });
        self.s[1] = self.s[1].clamp(-20.0, 20.0);
        self.s[3] = self.s[3].clamp(-20.0, 20.0);
        let (px, py) = self.tip();
        let d = ((px - self.target.0).powi(2) + (py - self.target.1).powi(2)).sqrt();
        let r = tolerance(d, 0.0, TARGET_SIZE, 0.12);
        (self.obs(), r as f32)
    }

    fn save_state(&self) -> Vec<f64> {
        let mut out = self.s.to_vec();
        out.push(self.target.0);
        out.push(self.target.1);
        out
    }

    fn load_state(&mut self, s: &[f64]) {
        self.s.copy_from_slice(&s[..4]);
        self.target = (s[4], s[5]);
    }

    fn render(&self, c: &mut Canvas) {
        c.clear([0.92, 0.92, 0.92]);
        let scale = 3.2; // arm world ±0.24 → canvas ±0.8
        let (t1, t2) = (self.s[0], self.s[2]);
        let j = (L1 * t1.cos() * scale, L1 * t1.sin() * scale);
        let (px, py) = self.tip();
        c.disk(self.target.0 * scale, self.target.1 * scale, 0.12, [0.9, 0.2, 0.2]);
        c.line(0.0, 0.0, j.0, j.1, 2, [0.2, 0.4, 0.8]);
        c.line(j.0, j.1, px * scale, py * scale, 2, [0.3, 0.5, 0.9]);
        c.disk(px * scale, py * scale, 0.07, [0.1, 0.7, 0.3]);
        let _ = t2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_target_full_reward() {
        let mut env = ReacherEasy::new();
        env.reset(&mut Pcg64::seed(1));
        let (px, py) = env.tip();
        env.target = (px, py);
        let (_, r) = env.step(&[0.0, 0.0]);
        assert!(r > 0.9, "r={r}");
    }

    #[test]
    fn far_from_target_low_reward() {
        let mut env = ReacherEasy::new();
        env.s = [0.0, 0.0, 0.0, 0.0]; // tip at (0.24, 0)
        env.target = (-0.2, 0.0);
        let (_, r) = env.step(&[0.0, 0.0]);
        assert!(r < 0.05, "r={r}");
    }

    #[test]
    fn torque_moves_arm() {
        let mut env = ReacherEasy::new();
        env.s = [0.0; 4];
        for _ in 0..10 {
            env.step(&[1.0, -0.5]);
        }
        assert!(env.s[0] > 0.01);
        assert!(env.s[2] < -0.005);
    }

    #[test]
    fn tip_is_reachable_distance() {
        let env = ReacherEasy::new();
        let (px, py) = env.tip();
        let d = (px * px + py * py).sqrt();
        assert!((d - (L1 + L2)).abs() < 1e-9);
    }
}
