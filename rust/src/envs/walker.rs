//! Walker-walk surrogate: a planar torso that must be held upright
//! against gravity by leg support while moving forward. Four leg joints
//! provide support (when planted near vertical) and thrust (when sweeping
//! back while planted). Reward follows dm_control walker_walk:
//! `stand × (1 + 5·move) / 6`.

use super::render::Canvas;
use super::tolerance::tolerance;
use super::Env;
use crate::rngs::Pcg64;

const N_LEGS: usize = 4;
const DT: f64 = 0.01;
const SUBSTEPS: usize = 2;
const TORQUE: f64 = 10.0;
const JOINT_DAMP: f64 = 4.0;
const JOINT_SPRING: f64 = 5.0;
const GRAV_PULL: f64 = 1.4;
const SUPPORT: f64 = 1.8;
const DRAG: f64 = 1.5;
const THRUST: f64 = 1.0;
const STAND_H: f64 = 0.75;
const TARGET_SPEED: f64 = 1.0;

/// State: height `h`, forward velocity `v`, x (render), legs `(q, q̇)`.
pub struct WalkerWalk {
    h: f64,
    v: f64,
    x: f64,
    q: [f64; N_LEGS],
    qd: [f64; N_LEGS],
}

impl WalkerWalk {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        WalkerWalk { h: 0.4, v: 0.0, x: 0.0, q: [0.0; N_LEGS], qd: [0.0; N_LEGS] }
    }

    fn obs(&self) -> Vec<f32> {
        // tidy-allow(alloc): per-step obs crosses the Env trait boundary
        // as an owned Vec (collection path, not the learner loop)
        let mut o = Vec::with_capacity(2 + 2 * N_LEGS);
        o.push(self.h as f32);
        o.push((self.v / TARGET_SPEED) as f32);
        for i in 0..N_LEGS {
            o.push(self.q[i] as f32);
            o.push((self.qd[i] / 10.0) as f32);
        }
        o
    }
}

impl Env for WalkerWalk {
    fn name(&self) -> &'static str {
        "walker_walk"
    }
    fn obs_dim(&self) -> usize {
        2 + 2 * N_LEGS
    }
    fn act_dim(&self) -> usize {
        N_LEGS
    }

    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        self.h = 0.35 + rng.uniform_in(0.0, 0.1) as f64;
        self.v = 0.0;
        self.x = 0.0;
        for i in 0..N_LEGS {
            self.q[i] = rng.uniform_in(-0.2, 0.2) as f64;
            self.qd[i] = 0.0;
        }
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32) {
        for _ in 0..SUBSTEPS {
            let mut support = 0.0;
            let mut thrust = 0.0;
            for i in 0..N_LEGS {
                let a = action[i].clamp(-1.0, 1.0) as f64 * TORQUE;
                let qdd = a - JOINT_DAMP * self.qd[i] - JOINT_SPRING * self.q[i];
                self.qd[i] += qdd * DT;
                self.q[i] = (self.q[i] + self.qd[i] * DT).clamp(-1.2, 1.2);
                // a leg supports when planted near vertical (normalized
                // so a neutral leg gives planted = 1)
                let planted = ((self.q[i].cos() - 0.3) / 0.7).max(0.0);
                support += SUPPORT * planted / N_LEGS as f64;
                thrust += THRUST * (-self.qd[i]).max(0.0) * planted / N_LEGS as f64;
            }
            // torso height: gravity pulls down, leg support pushes up
            self.h += (support - GRAV_PULL) * DT;
            self.h = self.h.clamp(0.0, 1.3);
            // falling kills forward mobility
            let mobility = if self.h > 0.3 { 1.0 } else { 0.2 };
            self.v += (thrust * mobility - DRAG * self.v) * DT;
            self.x += self.v * DT;
        }
        self.v = self.v.clamp(-0.5, 3.0);
        let stand = tolerance(self.h, STAND_H, f64::INFINITY, 0.4);
        let movement = (self.v / TARGET_SPEED).clamp(0.0, 1.0);
        let r = stand * (1.0 + 5.0 * movement) / 6.0;
        (self.obs(), r.clamp(0.0, 1.0) as f32)
    }

    fn save_state(&self) -> Vec<f64> {
        let mut s = vec![self.h, self.v, self.x];
        s.extend_from_slice(&self.q);
        s.extend_from_slice(&self.qd);
        s
    }

    fn load_state(&mut self, s: &[f64]) {
        self.h = s[0];
        self.v = s[1];
        self.x = s[2];
        self.q.copy_from_slice(&s[3..3 + N_LEGS]);
        self.qd.copy_from_slice(&s[3 + N_LEGS..3 + 2 * N_LEGS]);
    }

    fn render(&self, c: &mut Canvas) {
        c.clear([0.92, 0.96, 1.0]);
        c.rect(-1.0, -0.7, 1.0, -1.0, [0.45, 0.4, 0.3]);
        let top = -0.7 + self.h;
        let phase = (self.x * 2.0).rem_euclid(2.0) - 1.0;
        c.rect(-0.3, top, 0.3, top - 0.2, [0.7, 0.3, 0.5]);
        c.disk(phase * 0.3, top - 0.1, 0.05, [0.3, 0.1, 0.2]);
        for (i, &q) in self.q.iter().enumerate() {
            let bx = -0.25 + 0.16 * i as f64;
            let (lx, ly) = (bx + (self.h) * q.sin(), top - 0.2 - self.h * q.cos() * 0.9);
            c.line(bx, top - 0.2, lx, ly.max(-0.7), 1, [0.25, 0.1, 0.2]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collapses_without_support() {
        let mut env = WalkerWalk::new();
        env.reset(&mut Pcg64::seed(1));
        // bend all legs: no support
        for _ in 0..300 {
            env.step(&[1.0; N_LEGS]);
        }
        assert!(env.h < 0.3, "h={}", env.h);
    }

    #[test]
    fn neutral_legs_hold_height() {
        let mut env = WalkerWalk::new();
        env.reset(&mut Pcg64::seed(2));
        for _ in 0..300 {
            env.step(&[0.0; N_LEGS]);
        }
        assert!(env.h > 0.5, "h={}", env.h);
    }

    #[test]
    fn standing_tall_earns_base_reward() {
        let mut env = WalkerWalk::new();
        env.h = 1.0;
        env.v = 0.0;
        let (_, r) = env.step(&[0.0; N_LEGS]);
        assert!(r > 0.12 && r < 0.5, "r={r}");
    }

    #[test]
    fn walking_beats_standing() {
        let mut stand = WalkerWalk::new();
        stand.reset(&mut Pcg64::seed(3));
        let mut walk = WalkerWalk::new();
        walk.reset(&mut Pcg64::seed(3));
        let (mut rs, mut rw) = (0.0f64, 0.0f64);
        for i in 0..600 {
            rs += stand.step(&[0.0; N_LEGS]).1 as f64;
            // gentle alternating sweep keeps support while generating thrust
            let ph = (i / 20) % 2 == 0;
            let a: Vec<f32> =
                (0..N_LEGS).map(|j| if (j % 2 == 0) == ph { 0.25 } else { -0.25 }).collect();
            rw += walk.step(&a).1 as f64;
        }
        assert!(rw > rs, "walking {rw} must beat standing {rs}");
    }
}
