//! # lprl — Low-Precision Reinforcement Learning
//!
//! A Rust + JAX + Pallas reproduction of *"Low-Precision Reinforcement
//! Learning: Running Soft Actor-Critic in Half Precision"* (Bjorck, Chen,
//! De Sa, Gomes, Weinberger — ICML 2021).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the
//!   numeric-format hot spots (parameterized quantizer, hAdam update,
//!   Kahan step, tanh-Gaussian log-prob with the paper's fixes).
//! * **L2** — JAX model (`python/compile/model.py`): SAC forward/backward
//!   + optimizer as jitted functions, AOT-lowered to HLO-text artifacts.
//! * **L3** — this crate: environments, replay, training orchestration,
//!   the PJRT runtime that executes the artifacts, a native engine for
//!   large format sweeps, and the experiment harness reproducing every
//!   figure and table in the paper.
//!
//! ## Quickstart
//!
//! ```bash
//! make artifacts            # AOT-lower the L2/L1 python to artifacts/
//! cargo run --release --example quickstart
//! cargo run --release -- train --task cartpole_swingup --precision fp16_ours
//! cargo run --release -- exp fig3   # regenerate the ablation figure data
//! ```
//!
//! See `DESIGN.md` for the full systems inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod config;
pub mod coordinator;
pub mod envs;
pub mod experiments;
pub mod lowp;
pub mod nn;
pub mod optim;
pub mod replay;
pub mod rngs;
pub mod runtime;
pub mod sac;
pub mod telemetry;
