//! # lprl — Low-Precision Reinforcement Learning
//!
//! A Rust + JAX + Pallas reproduction of *"Low-Precision Reinforcement
//! Learning: Running Soft Actor-Critic in Half Precision"* (Bjorck, Chen,
//! De Sa, Gomes, Weinberger — ICML 2021).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the
//!   numeric-format hot spots (parameterized quantizer, hAdam update,
//!   Kahan step, tanh-Gaussian log-prob with the paper's fixes).
//! * **L2** — JAX model (`python/compile/model.py`): SAC forward/backward
//!   + optimizer as jitted functions, AOT-lowered to HLO-text artifacts.
//! * **L3** — this crate: environments, replay, training orchestration,
//!   a **native engine** (blocked GEMM backend, explicit backward, full
//!   format simulator) for large format sweeps, the experiment harness
//!   reproducing the paper's figures/tables, and a PJRT runtime for
//!   executing the AOT artifacts.
//!
//! Two execution paths, one computation:
//!
//! * The **native engine** is self-contained Rust and always available —
//!   training, experiments, examples and benches below all use it.
//! * The **PJRT artifact path** (`runtime::TrainSession`) needs
//!   artifacts from `python/compile/aot.py` plus real `xla` bindings;
//!   the offline build stubs those (see `runtime::xla`), and every
//!   artifact consumer skips or errors out cleanly without them.
//!
//! ## Training vs inference
//!
//! The forward-pass API is split end to end:
//!
//! * **Inference** — every layer `forward` is `&self` and cache-free
//!   ([`nn`]), so a frozen [`sac::Policy`] snapshot
//!   ([`sac::SacAgent::policy`]) is `Send + Sync` and serves any number
//!   of threads with batched [`sac::Policy::act_batch`].
//! * **Training** — `forward_train` writes activation caches into
//!   explicit caller-owned workspaces (`nn::LinearWorkspace`,
//!   `nn::MlpWorkspace`, …) that `backward` consumes; both paths are
//!   bitwise identical.
//!
//! On top of the split sits the [`serve`] subsystem: a micro-batching
//! policy server (`lprl serve --engine native|pjrt`) that unifies the
//! native engine and the PJRT artifact path behind one
//! [`serve::PolicyBackend`] request path.
//!
//! ## Module map
//!
//! | module | role |
//! |--------|------|
//! | [`nn`] | tensors, layers (&self forward / workspace backward), blocked GEMM + worker pool |
//! | [`lowp`] | precision formats + quantization policy |
//! | [`sac`] | the agent (training) and [`sac::Policy`] snapshots (inference) |
//! | [`optim`] | Adam/hAdam, loss scaling, Kahan accumulators |
//! | [`envs`] | the continuous-control task suite + lockstep [`envs::VecEnv`] |
//! | [`replay`] | replay buffer (f16/f32 storage, batch push / allocation-free sampling) |
//! | [`coordinator`] | strict + async collector/learner loops over vectorized envs, batched deterministic eval |
//! | [`ckpt`] | versioned crash-safe checkpoints: atomic writes, checksum validation, bitwise resume |
//! | [`serve`] | micro-batching policy server over [`serve::PolicyBackend`] |
//! | [`runtime`] | PJRT artifact execution (AOT path) |
//! | [`experiments`] / [`telemetry`] | paper exhibits + CSV/JSON reporting |
//!
//! ## Quickstart (what works out of the box — see also README.md)
//!
//! ```bash
//! cargo run --release --example quickstart
//! cargo run --release -- train task=cartpole_swingup preset=fp16_ours
//! cargo run --release -- exp fig3      # regenerate the ablation data
//! cargo run --release -- serve engine=native   # micro-batching policy server
//! cargo run --release -- train task=cheetah_run num_envs=8   # vectorized collection
//! cargo run --release -- train task=cheetah_run num_envs=8 sync_mode=async  # pipelined collector/learner
//! cargo bench --bench gemm_blocked     # GEMM backend vs seed baseline
//! cargo bench --bench serve_throughput # single vs micro-batched serving
//! cargo bench --bench collect_throughput # sync-vs-async collection matrix
//! cargo bench --bench learner_throughput # learner updates/sec + fused-parity gates
//! python -m pytest python/tests -q     # L1/L2 kernel + model tests
//! ```

// The numeric kernels and explicit-backward layers index heavily by
// design (parallel row ranges, transposed panels, micro-tiles), and the
// GEMM entry points carry shape + epilogue parameters; these two
// pedantic lints fight that style without making it safer.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]
// Unsafe hygiene (enforced by `cargo run -p xtask -- tidy`): raw ops
// inside an `unsafe fn` still need their own `unsafe {}` block, so
// every dereference is pinned to a written SAFETY argument.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod ckpt;
pub mod config;
pub mod coordinator;
pub mod envs;
pub mod experiments;
pub mod lowp;
pub mod nn;
pub mod optim;
pub mod replay;
pub mod rngs;
pub mod runtime;
pub mod sac;
pub mod serve;
pub mod telemetry;
