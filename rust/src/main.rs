//! `lprl` — launcher CLI for the Low-Precision RL framework.
//!
//! ```text
//! lprl train  [--config file.toml] [key=value ...]   train one agent
//! lprl eval   [key=value ...]                        evaluate (train + report)
//! lprl exp <fig1|fig2|...|table11|all> [key=value]   reproduce a paper exhibit
//! lprl serve  [--artifacts DIR] [--variant V]        PJRT artifact train loop
//! lprl info                                          build/feature summary
//! ```

use lprl::config::{parse_cli, RunConfig};
use lprl::coordinator::train;
use lprl::envs::PLANET_TASKS;
use lprl::telemetry::write_csv;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, kv) = parse_cli(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "train" | "eval" => cmd_train(&kv),
        "exp" => cmd_exp(pos.get(1).map(String::as_str).unwrap_or("all"), &kv),
        "serve" => cmd_serve(&kv),
        "info" => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e:#}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn print_help() {
    println!(
        "lprl — Low-Precision Reinforcement Learning (SAC in fp16), ICML 2021 reproduction

USAGE:
  lprl train [--config f.toml] [key=value ...]   e.g. task=cheetah_run preset=fp16_ours seed=1
  lprl exp <name> [key=value ...]                name: fig1..fig12, table2/3/7/10/11, all
  lprl serve [--artifacts artifacts] [--variant fp16_ours] [--steps N]
  lprl info

PRESETS: fp32 fp16_naive fp16_ours coerc loss_scale mixed amp cum0..cum6 loo1..loo6 e5mX_ours
TASKS:   {} pendulum_swingup",
        PLANET_TASKS.join(" ")
    );
}

fn cmd_train(kv: &[(String, String)]) -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    for (k, v) in kv {
        if k == "config" {
            let unknown = cfg.load_file(v)?;
            for u in unknown {
                eprintln!("warning: unknown config key {u}");
            }
        } else if !cfg.set(k, v) {
            anyhow::bail!("unknown option {k}");
        }
    }
    cfg.preset()
        .ok_or_else(|| anyhow::anyhow!("unknown preset {}", cfg.preset))?;
    eprintln!(
        "training {} / {} (seed {}, {} steps, hidden {}, batch {})",
        cfg.task, cfg.preset, cfg.seed, cfg.steps, cfg.hidden, cfg.batch
    );
    let out = train(&cfg);
    println!("task={} preset={} seed={}", cfg.task, cfg.preset, cfg.seed);
    for (x, y) in &out.eval_curve.points {
        println!("  env_step {x:>8} return {y:>8.1}");
    }
    println!(
        "final={:.1} crashed={} skipped_opt_steps={} wall={:.1}s",
        out.final_score, out.crashed, out.skipped_steps, out.wall_secs
    );
    let path = std::path::Path::new(&cfg.out_dir)
        .join("train")
        .join(format!("{}_{}_s{}.csv", cfg.task, cfg.preset, cfg.seed));
    write_csv(&path, &[out.eval_curve])?;
    eprintln!("wrote {}", path.display());
    Ok(())
}

fn cmd_exp(name: &str, kv: &[(String, String)]) -> anyhow::Result<()> {
    lprl::experiments::run(name, kv)
}

fn cmd_serve(kv: &[(String, String)]) -> anyhow::Result<()> {
    use lprl::rngs::Pcg64;
    use lprl::runtime::TrainSession;
    let mut dir = "artifacts".to_string();
    let mut variant = "fp16_ours".to_string();
    let mut steps = 50usize;
    for (k, v) in kv {
        match k.as_str() {
            "artifacts" => dir = v.clone(),
            "variant" => variant = v.clone(),
            "steps" => steps = v.parse()?,
            _ => anyhow::bail!("unknown option {k}"),
        }
    }
    let mut sess = TrainSession::new(&dir, &variant)?;
    let (o, a, b) = sess.dims();
    println!(
        "serving {variant} on {} (obs={o} act={a} batch={b})",
        sess.runtime.platform()
    );
    let mut rng = Pcg64::seed(0);
    let mut v = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal_f32()).collect() };
    for i in 0..steps {
        let (obs, act, next_obs) = (v(b * o), v(b * a), v(b * o));
        let (eps_n, eps_c) = (v(b * a), v(b * a));
        let rew: Vec<f32> = (0..b).map(|_| 0.5).collect();
        let nd = vec![1.0; b];
        let m = sess.step(&obs, &act, &rew, &next_obs, &nd, &eps_n, &eps_c)?;
        if i % 10 == 0 {
            println!(
                "step {i:>4}  critic_loss={:.4} q={:.3} logp={:.3} alpha={:.4}",
                m[0], m[1], m[2], m[3]
            );
        }
    }
    println!("ok: {} artifact steps executed, python never invoked", steps);
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("lprl {} — three-layer Rust+JAX+Pallas reproduction of", env!("CARGO_PKG_VERSION"));
    println!("  'Low-Precision RL: Running SAC in Half Precision' (ICML 2021)");
    println!("layers:");
    println!("  L1  python/compile/kernels/  Pallas: quantize, hAdam, Kahan, logprob");
    println!("  L2  python/compile/model.py  JAX SAC fwd/bwd+optimizer -> HLO text");
    println!("  L3  rust/src/                coordinator + native engine + PJRT runtime");
    println!("tasks: {} + pendulum_swingup", PLANET_TASKS.join(", "));
    let art = std::path::Path::new("artifacts/manifest.txt");
    println!(
        "artifacts: {}",
        if art.exists() {
            "present"
        } else {
            "missing (generate with `python python/compile/aot.py`; see README.md)"
        }
    );
    Ok(())
}
