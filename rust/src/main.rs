//! `lprl` — launcher CLI for the Low-Precision RL framework.
//!
//! ```text
//! lprl train  [--config file.toml] [key=value ...]   train one agent
//! lprl eval   [key=value ...]                        evaluate (train + report)
//! lprl exp <fig1|fig2|...|table11|all> [key=value]   reproduce a paper exhibit
//! lprl serve  [engine=native|pjrt] [key=value ...]   micro-batching policy server
//! lprl info                                          build/feature summary
//! ```

use lprl::config::{parse_cli, RunConfig};
use lprl::coordinator::train;
use lprl::envs::PLANET_TASKS;
use lprl::rngs::Pcg64;
use lprl::serve::{NativeBackend, PjrtBackend, PolicyBackend, PolicyServer, ServeConfig};
use lprl::telemetry::write_csv;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, kv) = parse_cli(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "train" | "eval" => cmd_train(&kv),
        "exp" => cmd_exp(pos.get(1).map(String::as_str).unwrap_or("all"), &kv),
        "serve" => cmd_serve(&kv),
        "info" => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e:#}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn print_help() {
    println!(
        "lprl — Low-Precision Reinforcement Learning (SAC in fp16), ICML 2021 reproduction

USAGE:
  lprl train [--config f.toml] [key=value ...]   e.g. task=cheetah_run preset=fp16_ours seed=1
       num_envs=N collects from N lockstep env streams (one shared
       forward per round; num_envs=1 == the reference single-env trainer)
       sync_mode=strict|async: async runs the collector in its own
       thread on lagged policy snapshots with pooled env stepping
       (seed-deterministic; queue_rounds=N bounds the transition queue)
       storage=f32|f16|bf16 keeps the read-only weights (target-network
       mirrors, policy snapshots) in native 16-bit storage, streamed
       through the SIMD widening GEMM kernels where the CPU supports it
       replay_storage=auto|f32|f16|u8 picks the replay-ring tier: auto
       pairs it with the compute precision; u8 byte-packs pixel
       observations onto the k/255 grid (4x smaller, actions stay f32)
       checkpoint_every=N writes a crash-safe checkpoint every N env
       steps to <out_dir>/ckpt (ckpt_keep=K generations retained);
       resume_from=DIR continues a run bitwise-identically from the
       newest valid checkpoint; faults=kill@S:round|eval|ckpt,torn@S:
       truncate|corrupt injects deterministic failures for testing
  lprl exp <name> [key=value ...]                name: fig1..fig12, table2/3/7/10/11, all
  lprl serve [engine=native|pjrt] [key=value ...]
       native: task= preset= hidden= seed= train_steps=    (policy source)
       pjrt:   artifacts= variant= [mode=train steps=N]    (artifact source)
       both:   clients= requests= max_batch= flush_us=     (serve demo load)
               overload=block|shed|deadline [deadline_us=N] (saturation policy)
  lprl info

PRESETS: fp32 fp16_naive fp16_ours coerc loss_scale mixed amp cum0..cum6 loo1..loo6 e5mX_ours
TASKS:   {} pendulum_swingup",
        PLANET_TASKS.join(" ")
    );
}

fn cmd_train(kv: &[(String, String)]) -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    for (k, v) in kv {
        if k == "config" {
            let unknown = cfg.load_file(v)?;
            for u in unknown {
                eprintln!("warning: unknown config key {u}");
            }
        } else if !cfg.set(k, v) {
            anyhow::bail!("unknown option {k}");
        }
    }
    // config-time validation: unknown tasks/presets fail here, not deep
    // inside a run with a silently defaulted action repeat
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    eprintln!(
        "training {} / {} (seed {}, {} steps, hidden {}, batch {}, num_envs {}, {})",
        cfg.task, cfg.preset, cfg.seed, cfg.steps, cfg.hidden, cfg.batch, cfg.num_envs, cfg.sync_mode
    );
    let out = train(&cfg);
    println!("task={} preset={} seed={}", cfg.task, cfg.preset, cfg.seed);
    for (x, y) in &out.eval_curve.points {
        println!("  env_step {x:>8} return {y:>8.1}");
    }
    println!(
        "final={:.1} crashed={} killed={} skipped_opt_steps={} wall={:.1}s",
        out.final_score, out.crashed, out.killed, out.skipped_steps, out.wall_secs
    );
    println!(
        "throughput: collect {:.0} steps/s ({} envs, {})  learner {:.1} updates/s ({} updates)",
        out.collect_steps_per_sec, cfg.num_envs, cfg.sync_mode, out.updates_per_sec, out.updates
    );
    if out.snapshot_refreshes > 0 {
        println!(
            "snapshots: {} refreshes, mean publish {:.1} us",
            out.snapshot_refreshes,
            out.snapshot_publish_secs * 1e6 / out.snapshot_refreshes as f64
        );
    }
    let path = std::path::Path::new(&cfg.out_dir)
        .join("train")
        .join(format!("{}_{}_s{}.csv", cfg.task, cfg.preset, cfg.seed));
    write_csv(&path, &[out.eval_curve])?;
    eprintln!("wrote {}", path.display());
    Ok(())
}

fn cmd_exp(name: &str, kv: &[(String, String)]) -> anyhow::Result<()> {
    lprl::experiments::run(name, kv)
}

/// `lprl serve`: start the micro-batching policy server over the chosen
/// engine and drive it with a multi-client demo workload.
///
/// * `engine=native` (default): snapshot a [`lprl::sac::Policy`] from a
///   fresh (optionally briefly trained — `train_steps=N`) native agent.
/// * `engine=pjrt`: serve the AOT `act_<variant>` artifact; `mode=train`
///   keeps the legacy artifact train-loop demo.
fn cmd_serve(kv: &[(String, String)]) -> anyhow::Result<()> {
    let mut engine = "native".to_string();
    let mut mode = "serve".to_string();
    // native-engine policy source
    let mut task = "cartpole_swingup".to_string();
    let mut preset = "fp16_ours".to_string();
    let mut hidden = 128usize;
    let mut seed = 0u64;
    let mut train_steps = 0usize;
    // pjrt artifact source
    let mut dir = "artifacts".to_string();
    let mut variant = "fp16_ours".to_string();
    let mut steps = 50usize;
    // serve demo load
    let mut clients = 8usize;
    let mut requests = 64usize;
    let mut max_batch = 32usize;
    let mut flush_us = 200u64;
    let mut overload = lprl::serve::OverloadPolicy::Block;
    let mut deadline_us = 10_000u64;
    for (k, v) in kv {
        match k.as_str() {
            "engine" => engine = v.clone(),
            "mode" => mode = v.clone(),
            "task" => task = v.clone(),
            "preset" => preset = v.clone(),
            "hidden" => hidden = v.parse()?,
            "seed" => seed = v.parse()?,
            "train_steps" => train_steps = v.parse()?,
            "artifacts" => dir = v.clone(),
            "variant" => variant = v.clone(),
            "steps" => steps = v.parse()?,
            "clients" => clients = v.parse()?,
            "requests" => requests = v.parse()?,
            "max_batch" => max_batch = v.parse()?,
            "flush_us" => flush_us = v.parse()?,
            "overload" => {
                overload = lprl::serve::OverloadPolicy::parse(v)
                    .map_err(|e| anyhow::anyhow!(e))?
            }
            "deadline_us" => deadline_us = v.parse()?,
            _ => anyhow::bail!("unknown option {k}"),
        }
    }
    let backend: Arc<dyn PolicyBackend> = match engine.as_str() {
        "native" => {
            let cfg = RunConfig {
                task,
                preset,
                hidden,
                seed,
                steps: train_steps,
                ..RunConfig::default()
            };
            cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
            let policy = native_policy(&cfg, train_steps)?;
            println!(
                "serving native policy: task={} preset={} obs={} act={} (trained {train_steps} steps)",
                cfg.task,
                cfg.preset,
                policy.obs_len(),
                policy.act_dim()
            );
            Arc::new(NativeBackend::new(policy))
        }
        "pjrt" if mode == "train" => return pjrt_train_loop(&dir, &variant, steps),
        "pjrt" => {
            let backend = PjrtBackend::new(&dir, &variant)?;
            println!(
                "serving pjrt artifact policy: variant={variant} obs={} act={}",
                backend.obs_dim(),
                backend.act_dim()
            );
            Arc::new(backend)
        }
        other => anyhow::bail!("unknown engine {other} (native|pjrt)"),
    };
    serve_demo(
        backend,
        clients,
        requests,
        ServeConfig { max_batch, flush_us, queue_cap: 1024, overload, deadline_us },
    )
}

/// Build the native policy source: a fresh agent (optionally trained
/// for a few steps so the served policy is not pure init noise).
fn native_policy(cfg: &RunConfig, train_steps: usize) -> anyhow::Result<lprl::sac::Policy> {
    use lprl::sac::{SacAgent, SacConfig};
    if train_steps > 0 {
        let mut cfg = cfg.clone();
        cfg.steps = train_steps;
        cfg.seed_steps = (train_steps / 4).max(1);
        cfg.eval_every = train_steps; // single final eval
        cfg.eval_episodes = 1;
        let out = train(&cfg);
        anyhow::ensure!(!out.crashed, "pre-serve training crashed");
        eprintln!("(pre-trained {} steps, final score {:.1})", train_steps, out.final_score);
        return out
            .policy
            .ok_or_else(|| anyhow::anyhow!("train() returned no policy snapshot"));
    }
    let env = lprl::envs::make_env(&cfg.task)
        .ok_or_else(|| anyhow::anyhow!("unknown task {}", cfg.task))?;
    let (prec, methods) = cfg
        .preset()
        .ok_or_else(|| anyhow::anyhow!("unknown preset {}", cfg.preset))?;
    let sac_cfg = SacConfig::states(env.obs_dim(), env.act_dim(), cfg.hidden);
    let agent = SacAgent::new(sac_cfg, methods, prec, cfg.seed);
    Ok(agent.policy())
}

/// Drive the server with `clients` threads × `requests` observations
/// each and report throughput + latency.
fn serve_demo(
    backend: Arc<dyn PolicyBackend>,
    clients: usize,
    requests: usize,
    cfg: ServeConfig,
) -> anyhow::Result<()> {
    let obs_len = backend.obs_dim();
    println!(
        "serve: {clients} clients x {requests} requests, max_batch={} flush={}us",
        cfg.max_batch, cfg.flush_us
    );
    let server = PolicyServer::start(backend, cfg);
    let t0 = std::time::Instant::now();
    let mut failed = 0usize;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let client = server.client();
            handles.push(s.spawn(move || -> Result<(), lprl::serve::ServeError> {
                let mut rng = Pcg64::seed_stream(0x5E17E, c as u64);
                for _ in 0..requests {
                    let obs: Vec<f32> = (0..obs_len).map(|_| rng.normal_f32()).collect();
                    let _ = client.act(&obs)?;
                }
                Ok(())
            }));
        }
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    eprintln!("client error: {e}");
                    failed += 1;
                }
                Err(_) => failed += 1,
            }
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    anyhow::ensure!(failed == 0, "{failed} client thread(s) failed");
    println!(
        "served {} requests in {wall:.3}s -> {:.0} req/s over {} batches (mean batch {:.1}, max {})",
        stats.requests,
        stats.requests as f64 / wall.max(1e-9),
        stats.batches,
        stats.mean_batch,
        stats.max_batch
    );
    println!(
        "latency p50 {:.2} ms  p99 {:.2} ms  backend busy {:.3}s  errors {}",
        stats.p50_us as f64 / 1000.0,
        stats.p99_us as f64 / 1000.0,
        stats.backend_us as f64 / 1e6,
        stats.errors
    );
    Ok(())
}

/// The legacy PJRT demo (`engine=pjrt mode=train`): run fused train
/// steps over the `train_<variant>` artifact.
fn pjrt_train_loop(dir: &str, variant: &str, steps: usize) -> anyhow::Result<()> {
    use lprl::runtime::TrainSession;
    let mut sess = TrainSession::new(dir, variant)?;
    let (o, a, b) = sess.dims();
    println!(
        "artifact train loop: {variant} on {} (obs={o} act={a} batch={b})",
        sess.runtime.platform()
    );
    let mut rng = Pcg64::seed(0);
    let mut v = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal_f32()).collect() };
    for i in 0..steps {
        let (obs, act, next_obs) = (v(b * o), v(b * a), v(b * o));
        let (eps_n, eps_c) = (v(b * a), v(b * a));
        let rew: Vec<f32> = (0..b).map(|_| 0.5).collect();
        let nd = vec![1.0; b];
        let m = sess.step(&obs, &act, &rew, &next_obs, &nd, &eps_n, &eps_c)?;
        if i % 10 == 0 {
            println!(
                "step {i:>4}  critic_loss={:.4} q={:.3} logp={:.3} alpha={:.4}",
                m[0], m[1], m[2], m[3]
            );
        }
    }
    println!("ok: {} artifact steps executed, python never invoked", steps);
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("lprl {} — three-layer Rust+JAX+Pallas reproduction of", env!("CARGO_PKG_VERSION"));
    println!("  'Low-Precision RL: Running SAC in Half Precision' (ICML 2021)");
    println!("layers:");
    println!("  L1  python/compile/kernels/  Pallas: quantize, hAdam, Kahan, logprob");
    println!("  L2  python/compile/model.py  JAX SAC fwd/bwd+optimizer -> HLO text");
    println!("  L3  rust/src/                coordinator + native engine + serve layer + PJRT runtime");
    println!("tasks: {} + pendulum_swingup", PLANET_TASKS.join(", "));
    println!("simd: {}", lprl::nn::simd::feature_summary());
    // which GEMM tier each storage format actually dispatches to on
    // this host (detection + per-format kernel availability)
    use lprl::lowp::HalfFormat;
    use lprl::nn::simd::dispatch_tier;
    println!(
        "gemm dispatch: f32={} f16={} bf16={}",
        dispatch_tier(None),
        dispatch_tier(Some(HalfFormat::F16)),
        dispatch_tier(Some(HalfFormat::Bf16))
    );
    let art = std::path::Path::new("artifacts/manifest.txt");
    println!(
        "artifacts: {}",
        if art.exists() {
            "present"
        } else {
            "missing (generate with `python python/compile/aot.py`; see README.md)"
        }
    );
    Ok(())
}
