//! Self-contained pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so the framework carries its own
//! PCG-family generator: [`Pcg64`] (PCG-XSL-RR 128/64), plus Gaussian
//! sampling via Box–Muller and a `split` operation for deterministic
//! per-worker seeding (the same discipline JAX keys give the L2 layer).

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
/// Deterministic, splittable, and fast enough for replay sampling and
/// exploration noise.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed, with the default stream.
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator from a seed and a stream id: different streams
    /// produce statistically independent sequences for the same seed.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let s = self.state;
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next 32 uniformly random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53 random bits.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform_f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform_f32()
    }

    /// Uniform integer in [0, n). Rejection-free via 128-bit multiply
    /// (Lemire's method); bias is negligible for n << 2^64 but we use the
    /// full widening multiply anyway.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal sample (Box–Muller; one value per call, the pair's
    /// second half is discarded to keep the generator stateless-simple).
    pub fn normal_f32(&mut self) -> f32 {
        loop {
            let u1 = self.uniform_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            return (r * theta.cos()) as f32;
        }
    }

    /// Fill a slice with standard normal samples.
    pub fn normal_fill(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Derive an independent generator (for a worker/task); deterministic
    /// in `self`'s state and `tag`.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let a = self.next_u64();
        Pcg64::seed_stream(a ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag | 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Expose the raw `(state, inc)` pair for checkpointing. Together with
    /// [`Pcg64::from_raw_state`] this round-trips the generator exactly:
    /// the restored stream continues bitwise where the saved one left off.
    pub fn raw_state(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg64::raw_state`] pair.
    pub fn from_raw_state(state: u128, inc: u128) -> Self {
        Pcg64 { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seed(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::seed(11);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal_f32() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seed(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let k = rng.below(7);
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::seed_stream(1, 1);
        let mut b = Pcg64::seed_stream(1, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_is_deterministic_and_independent() {
        let mut r1 = Pcg64::seed(3);
        let mut r2 = Pcg64::seed(3);
        let mut a = r1.split(7);
        let mut b = r2.split(7);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = Pcg64::seed(3).split(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn raw_state_roundtrip_continues_bitwise() {
        let mut a = Pcg64::seed_stream(42, 7);
        for _ in 0..13 {
            a.next_u64();
        }
        let (state, inc) = a.raw_state();
        let mut b = Pcg64::from_raw_state(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg64::seed(4);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
