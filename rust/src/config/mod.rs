//! Run configuration: typed config + a small TOML-subset loader + CLI
//! `key=value` override grammar (the offline build has no serde/clap, so
//! the framework carries its own).
//!
//! Precedence: defaults < config file < CLI overrides.

use crate::lowp::{HalfFormat, Precision};
use crate::sac::Methods;
use std::collections::BTreeMap;

/// A training/experiment run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Environment name (see `envs::make_env`).
    pub task: String,
    /// Precision+methods preset — see [`RunConfig::preset`].
    pub preset: String,
    pub seed: u64,
    /// Total *agent* steps (after action repeat).
    pub steps: usize,
    /// Random-action warmup steps before updates start.
    pub seed_steps: usize,
    pub batch: usize,
    pub hidden: usize,
    pub replay_capacity: usize,
    /// Parallel env streams the collector steps in lockstep (one shared
    /// policy forward per round; the SAC 1-update-per-transition
    /// schedule is preserved). `1` reproduces the single-env trainer
    /// bitwise; see `coordinator::train`'s determinism contract.
    pub num_envs: usize,
    /// Collector/learner interleave contract: `"strict"` runs the
    /// single-thread collect → update → eval loop (bitwise identical to
    /// the pre-async trainer); `"async"` runs the collector in its own
    /// thread on lag-2 [`crate::sac::Policy`] snapshots with pooled
    /// parallel env stepping, feeding the learner through a bounded
    /// transition queue. Async runs are seed-deterministic (two async
    /// runs match bitwise) but are *not* bitwise-equal to strict runs —
    /// see the README determinism table.
    pub sync_mode: String,
    /// Transition-queue capacity of the async pipeline, in collect
    /// rounds (backpressure bound: the collector blocks once this many
    /// unconsumed rounds are queued). Ignored in strict mode.
    pub queue_rounds: usize,
    /// Evaluate every this many agent steps.
    pub eval_every: usize,
    pub eval_episodes: usize,
    /// Train from pixels instead of states.
    pub pixels: bool,
    /// Image side for pixel runs (the paper uses 84; scaled default 21).
    pub image_size: usize,
    /// Conv filters for the pixel encoder.
    pub filters: usize,
    /// Frame stack for pixel runs.
    pub frame_stack: usize,
    /// Encoder feature dimension.
    pub feature_dim: usize,
    /// Learning-rate override (0 = use the paper default for the mode).
    pub lr: f32,
    /// Discount override (0 = paper default 0.99). Used by Table 7.
    pub gamma: f32,
    /// Target-update rate override (0 = paper default).
    pub tau: f32,
    /// Initial temperature override (0 = paper default).
    pub init_temp: f32,
    /// Lower log-σ bound override (0 = paper default).
    pub min_log_sig: f32,
    /// Storage tier for the read-only heavyweights (target-network
    /// mirrors and policy snapshots): `"f32"` keeps everything unpacked;
    /// `"f16"`/`"bf16"` keep those weights in native 16-bit storage,
    /// streamed through the SIMD widening GEMM kernels (see
    /// `SacAgent::set_half_storage` for the quantize-mirror semantics).
    pub storage: String,
    /// Storage tier for the replay ring: `"auto"` follows the compute
    /// tier (f16 rings under low-precision presets, f32 otherwise —
    /// the paper's Table 3 pairing); `"f32"`/`"f16"` force a tier;
    /// `"u8"` byte-packs observations onto the `k/255` pixel grid
    /// (4× smaller; exact for env-emitted pixels, actions stay f32).
    pub replay_storage: String,
    /// Output directory for CSV results.
    pub out_dir: String,
    /// Write a crash-safe checkpoint every this many agent steps
    /// (0 = checkpointing off). Checkpoints land in
    /// `<out_dir>/ckpt/` as `ckpt-<step>.lprl` generations.
    pub checkpoint_every: usize,
    /// Keep the last this many checkpoint generations (older ones are
    /// pruned after each successful write; clamped to >= 1).
    pub ckpt_keep: usize,
    /// Resume from a checkpoint store: a directory holding
    /// `ckpt-*.lprl` files (the newest valid generation is loaded,
    /// damaged ones skipped). Empty = fresh run.
    pub resume_from: String,
    /// Fault-injection plan for the crash harness (empty = none):
    /// comma-separated `kill@<step>:<round|eval|ckpt>` and/or
    /// `torn@<step>:<truncate|corrupt>` — see `ckpt::FaultPlan`.
    pub faults: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            task: "cartpole_swingup".into(),
            preset: "fp16_ours".into(),
            seed: 0,
            steps: 4000,
            seed_steps: 300,
            batch: 64,
            hidden: 128,
            replay_capacity: 100_000,
            num_envs: 1,
            sync_mode: "strict".into(),
            queue_rounds: 4,
            eval_every: 500,
            eval_episodes: 4,
            pixels: false,
            image_size: 21,
            filters: 8,
            frame_stack: 3,
            feature_dim: 20,
            lr: 0.0,
            gamma: 0.0,
            tau: 0.0,
            init_temp: 0.0,
            min_log_sig: 0.0,
            storage: "f32".into(),
            replay_storage: "auto".into(),
            out_dir: "results".into(),
            checkpoint_every: 0,
            ckpt_keep: 3,
            resume_from: String::new(),
            faults: String::new(),
        }
    }
}

impl RunConfig {
    /// Paper-scale configuration (hidden 1024, batch 1024, 500k steps) —
    /// provided for completeness; far beyond this CPU testbed's budget.
    pub fn paper_full() -> Self {
        RunConfig {
            steps: 500_000,
            seed_steps: 5000,
            batch: 1024,
            hidden: 1024,
            replay_capacity: 1_000_000,
            eval_every: 10_000,
            eval_episodes: 10,
            image_size: 84,
            filters: 32,
            feature_dim: 50,
            ..Default::default()
        }
    }

    /// Decode the preset into `(precision, methods)`.
    ///
    /// Presets: `fp32`, `fp16_naive`, `fp16_ours`, `coerc`, `loss_scale`,
    /// `mixed`, `amp`, `cum0..cum6` (Figure 3), `loo1..loo6` (Figure 7),
    /// and `<fmt>_ours` / `<fmt>_naive` for any format name `lowp`
    /// understands (e.g. `e5m7_ours` for Figure 4).
    pub fn preset(&self) -> Option<(Precision, Methods)> {
        parse_preset(&self.preset)
    }

    /// Decode the `storage` knob: `None` for the f32 tier, the packed
    /// format otherwise. Unknown spellings are caught by
    /// [`RunConfig::validate`]; here they fall back to f32.
    pub fn half_storage(&self) -> Option<HalfFormat> {
        HalfFormat::parse(&self.storage).flatten()
    }

    /// Decode the `replay_storage` knob for a run whose compute tier is
    /// `low_compute`: `"auto"` pairs the ring with the compute tier
    /// (f16 under low-precision compute, f32 otherwise); explicit
    /// values override. Unknown spellings are caught by
    /// [`RunConfig::validate`]; here they fall back to `"auto"`.
    pub fn replay_storage(&self, low_compute: bool) -> crate::replay::Storage {
        use crate::replay::Storage;
        match self.replay_storage.as_str() {
            "f32" => Storage::F32,
            "f16" => Storage::F16,
            "u8" => Storage::U8,
            _ => {
                if low_compute {
                    Storage::F16
                } else {
                    Storage::F32
                }
            }
        }
    }

    /// Validate the invariants that should fail at config time rather
    /// than deep inside a run: unknown task names (no silent
    /// action-repeat default — see `envs::try_action_repeat`) and
    /// unknown precision presets.
    pub fn validate(&self) -> Result<(), String> {
        if crate::envs::try_action_repeat(&self.task).is_none() {
            return Err(format!(
                "unknown task {:?} (supported: {})",
                self.task,
                crate::envs::SUPPORTED_TASKS.join(" ")
            ));
        }
        if self.preset().is_none() {
            return Err(format!("unknown preset {:?}", self.preset));
        }
        if self.num_envs == 0 {
            return Err("num_envs must be >= 1".into());
        }
        if self.sync_mode != "strict" && self.sync_mode != "async" {
            return Err(format!("unknown sync_mode {:?} (strict|async)", self.sync_mode));
        }
        if self.queue_rounds == 0 {
            return Err("queue_rounds must be >= 1".into());
        }
        if HalfFormat::parse(&self.storage).is_none() {
            return Err(format!("unknown storage {:?} (f32|f16|bf16)", self.storage));
        }
        if !matches!(self.replay_storage.as_str(), "auto" | "f32" | "f16" | "u8") {
            return Err(format!(
                "unknown replay_storage {:?} (auto|f32|f16|u8)",
                self.replay_storage
            ));
        }
        if self.eval_every == 0 {
            return Err("eval_every must be >= 1".into());
        }
        if self.ckpt_keep == 0 {
            return Err("ckpt_keep must be >= 1".into());
        }
        if let Err(e) = crate::ckpt::FaultPlan::parse(&self.faults) {
            return Err(format!("bad faults spec: {e}"));
        }
        Ok(())
    }

    /// Apply a `key=value` override; returns false for unknown keys.
    pub fn set(&mut self, key: &str, value: &str) -> bool {
        fn p<T: std::str::FromStr>(v: &str) -> Option<T> {
            v.parse().ok()
        }
        match key {
            "task" => self.task = value.into(),
            "preset" | "precision" => self.preset = value.into(),
            "seed" => self.seed = p(value).unwrap_or(self.seed),
            "steps" => self.steps = p(value).unwrap_or(self.steps),
            "seed_steps" => self.seed_steps = p(value).unwrap_or(self.seed_steps),
            "batch" => self.batch = p(value).unwrap_or(self.batch),
            "hidden" => self.hidden = p(value).unwrap_or(self.hidden),
            "replay_capacity" => self.replay_capacity = p(value).unwrap_or(self.replay_capacity),
            "num_envs" => self.num_envs = p(value).unwrap_or(self.num_envs),
            "sync_mode" => self.sync_mode = value.into(),
            "queue_rounds" => self.queue_rounds = p(value).unwrap_or(self.queue_rounds),
            "eval_every" => self.eval_every = p(value).unwrap_or(self.eval_every),
            "eval_episodes" => self.eval_episodes = p(value).unwrap_or(self.eval_episodes),
            "pixels" => self.pixels = value == "true" || value == "1",
            "image_size" => self.image_size = p(value).unwrap_or(self.image_size),
            "filters" => self.filters = p(value).unwrap_or(self.filters),
            "frame_stack" => self.frame_stack = p(value).unwrap_or(self.frame_stack),
            "feature_dim" => self.feature_dim = p(value).unwrap_or(self.feature_dim),
            "lr" => self.lr = p(value).unwrap_or(self.lr),
            "gamma" => self.gamma = p(value).unwrap_or(self.gamma),
            "tau" => self.tau = p(value).unwrap_or(self.tau),
            "init_temp" => self.init_temp = p(value).unwrap_or(self.init_temp),
            "min_log_sig" => self.min_log_sig = p(value).unwrap_or(self.min_log_sig),
            "storage" => self.storage = value.into(),
            "replay_storage" => self.replay_storage = value.into(),
            "out_dir" => self.out_dir = value.into(),
            "checkpoint_every" => self.checkpoint_every = p(value).unwrap_or(self.checkpoint_every),
            "ckpt_keep" => self.ckpt_keep = p(value).unwrap_or(self.ckpt_keep),
            "resume_from" => self.resume_from = value.into(),
            "faults" => self.faults = value.into(),
            _ => return false,
        }
        true
    }

    /// Load `key = value` lines (TOML subset: comments with `#`, strings
    /// optionally quoted, sections ignored).
    pub fn load_file(&mut self, path: &str) -> std::io::Result<Vec<String>> {
        let text = std::fs::read_to_string(path)?;
        let mut unknown = Vec::new();
        for (k, v) in parse_kv(&text) {
            if !self.set(&k, &v) {
                unknown.push(k);
            }
        }
        Ok(unknown)
    }
}

/// Parse a preset name into precision + methods.
pub fn parse_preset(name: &str) -> Option<(Precision, Methods)> {
    let fp16 = Precision::fp16();
    Some(match name {
        "fp32" => (Precision::Fp32, Methods::none()),
        "fp16_naive" | "fp16" => (fp16, Methods::none()),
        "fp16_ours" | "ours" => (fp16, Methods::ours()),
        "coerc" => (fp16, Methods::coerc_baseline()),
        "loss_scale" => (fp16, Methods::loss_scale_baseline()),
        "mixed" | "mixed_precision" => (fp16, Methods::mixed_precision_baseline()),
        // Appendix E baselines: amp-default scaler / 10x adam eps are
        // materialized by the experiment driver; preset-wise they are the
        // loss-scale baseline.
        "amp" => (fp16, Methods::loss_scale_baseline()),
        _ => {
            if let Some(k) = name.strip_prefix("cum") {
                let k: usize = k.parse().ok()?;
                if k > 6 {
                    return None;
                }
                (fp16, Methods::cumulative(k))
            } else if let Some(i) = name.strip_prefix("loo") {
                let i: usize = i.parse().ok()?;
                if !(1..=6).contains(&i) {
                    return None;
                }
                (fp16, Methods::leave_one_out(i))
            } else if let Some(fmt) = name.strip_suffix("_ours") {
                (Precision::parse(fmt)?, Methods::ours())
            } else if let Some(fmt) = name.strip_suffix("_naive") {
                (Precision::parse(fmt)?, Methods::none())
            } else {
                return None;
            }
        }
    })
}

/// Parse `key = value` pairs from a TOML-subset string.
pub fn parse_kv(text: &str) -> BTreeMap<String, String> {
    let mut map = BTreeMap::new();
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() || line.starts_with('[') {
            continue;
        }
        if let Some((k, v)) = line.split_once('=') {
            let v = v.trim().trim_matches('"').trim_matches('\'');
            map.insert(k.trim().to_string(), v.to_string());
        }
    }
    map
}

/// Parse CLI args of the form `--key value`, `--key=value`, `key=value`;
/// returns (positional, overrides).
pub fn parse_cli(args: &[String]) -> (Vec<String>, Vec<(String, String)>) {
    let mut pos = Vec::new();
    let mut kv = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                kv.push((k.to_string(), v.to_string()));
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                kv.push((stripped.to_string(), args[i + 1].clone()));
                i += 1;
            } else {
                kv.push((stripped.to_string(), "true".to_string()));
            }
        } else if let Some((k, v)) = a.split_once('=') {
            kv.push((k.to_string(), v.to_string()));
        } else {
            pos.push(a.clone());
        }
        i += 1;
    }
    (pos, kv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_decode() {
        assert_eq!(parse_preset("fp32").unwrap().1.count_enabled(), 0);
        let (p, m) = parse_preset("fp16_ours").unwrap();
        assert!(p.is_low());
        assert_eq!(m, Methods::ours());
        assert_eq!(parse_preset("cum3").unwrap().1.count_enabled(), 3);
        assert_eq!(parse_preset("loo2").unwrap().1.count_enabled(), 5);
        let (p, m) = parse_preset("e5m7_ours").unwrap();
        assert_eq!(p.name(), "e5m7");
        assert_eq!(m, Methods::ours());
        assert!(parse_preset("bogus").is_none());
        assert!(parse_preset("cum9").is_none());
        assert!(parse_preset("mixed").unwrap().1.mixed_precision);
    }

    #[test]
    fn overrides_apply() {
        let mut c = RunConfig::default();
        assert!(c.set("task", "cheetah_run"));
        assert!(c.set("steps", "123"));
        assert!(c.set("pixels", "true"));
        assert!(c.set("num_envs", "8"));
        assert!(c.set("sync_mode", "async"));
        assert!(c.set("queue_rounds", "2"));
        assert!(!c.set("bogus_key", "1"));
        assert_eq!(c.task, "cheetah_run");
        assert_eq!(c.steps, 123);
        assert!(c.pixels);
        assert_eq!(c.num_envs, 8);
        assert_eq!(c.sync_mode, "async");
        assert_eq!(c.queue_rounds, 2);
    }

    #[test]
    fn validate_rejects_degenerate_schedules() {
        let mut c = RunConfig { num_envs: 0, ..Default::default() };
        assert!(c.validate().unwrap_err().contains("num_envs"));
        c.num_envs = 4;
        c.eval_every = 0;
        assert!(c.validate().unwrap_err().contains("eval_every"));
        c.eval_every = 100;
        assert!(c.validate().is_ok());
        c.sync_mode = "eventually".into();
        assert!(c.validate().unwrap_err().contains("sync_mode"));
        c.sync_mode = "async".into();
        c.queue_rounds = 0;
        assert!(c.validate().unwrap_err().contains("queue_rounds"));
        c.queue_rounds = 1;
        assert!(c.validate().is_ok());
        c.storage = "f24".into();
        assert!(c.validate().unwrap_err().contains("storage"));
        c.storage = "bf16".into();
        assert!(c.validate().is_ok());
        c.replay_storage = "int4".into();
        assert!(c.validate().unwrap_err().contains("replay_storage"));
        c.replay_storage = "u8".into();
        assert!(c.validate().is_ok());
    }

    #[test]
    fn ckpt_knobs_apply_and_validate() {
        let mut c = RunConfig::default();
        assert_eq!(c.checkpoint_every, 0, "checkpointing defaults to off");
        assert!(c.set("checkpoint_every", "500"));
        assert!(c.set("ckpt_keep", "2"));
        assert!(c.set("resume_from", "results/ckpt"));
        assert!(c.set("faults", "kill@900:round,torn@500:truncate"));
        assert_eq!(c.checkpoint_every, 500);
        assert_eq!(c.ckpt_keep, 2);
        assert_eq!(c.resume_from, "results/ckpt");
        assert!(c.validate().is_ok());
        c.ckpt_keep = 0;
        assert!(c.validate().unwrap_err().contains("ckpt_keep"));
        c.ckpt_keep = 3;
        c.faults = "kill@bogus".into();
        assert!(c.validate().unwrap_err().contains("faults"));
    }

    #[test]
    fn storage_knob_decodes() {
        let mut c = RunConfig::default();
        assert_eq!(c.half_storage(), None, "default keeps the f32 tier");
        assert!(c.set("storage", "f16"));
        assert_eq!(c.half_storage(), Some(HalfFormat::F16));
        assert!(c.set("storage", "bf16"));
        assert_eq!(c.half_storage(), Some(HalfFormat::Bf16));
        assert!(c.set("storage", "f32"));
        assert_eq!(c.half_storage(), None);
    }

    #[test]
    fn replay_storage_knob_decodes() {
        use crate::replay::Storage;
        let mut c = RunConfig::default();
        // auto pairs the ring with the compute tier
        assert_eq!(c.replay_storage(false), Storage::F32);
        assert_eq!(c.replay_storage(true), Storage::F16);
        // explicit tiers override auto in both directions
        assert!(c.set("replay_storage", "f32"));
        assert_eq!(c.replay_storage(true), Storage::F32);
        assert!(c.set("replay_storage", "f16"));
        assert_eq!(c.replay_storage(false), Storage::F16);
        assert!(c.set("replay_storage", "u8"));
        assert_eq!(c.replay_storage(true), Storage::U8);
    }

    #[test]
    fn validate_rejects_unknown_task_and_preset() {
        let mut c = RunConfig::default();
        assert!(c.validate().is_ok());
        c.task = "pendulum_swingup".into();
        assert!(c.validate().is_ok(), "pendulum_swingup is a supported task");
        c.task = "warehouse_sort".into();
        let err = c.validate().unwrap_err();
        assert!(err.contains("unknown task"), "{err}");
        assert!(err.contains("pendulum_swingup"), "error lists supported tasks: {err}");
        c.task = "cheetah_run".into();
        c.preset = "fp17_ours".into();
        assert!(c.validate().unwrap_err().contains("unknown preset"));
    }

    #[test]
    fn kv_parser_handles_comments_and_quotes() {
        let m = parse_kv("a = 1 # comment\n[section]\nb = \"two\"\n\nc=3.5");
        assert_eq!(m["a"], "1");
        assert_eq!(m["b"], "two");
        assert_eq!(m["c"], "3.5");
    }

    #[test]
    fn cli_grammar() {
        let args: Vec<String> = ["train", "--task", "cheetah_run", "--steps=50", "seed=3", "--pixels"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let (pos, kv) = parse_cli(&args);
        assert_eq!(pos, vec!["train"]);
        assert_eq!(kv[0], ("task".into(), "cheetah_run".into()));
        assert_eq!(kv[1], ("steps".into(), "50".into()));
        assert_eq!(kv[2], ("seed".into(), "3".into()));
        assert_eq!(kv[3], ("pixels".into(), "true".into()));
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("lprl_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.toml");
        std::fs::write(&p, "task = \"walker_walk\"\nsteps = 77\nnope = 1\n").unwrap();
        let mut c = RunConfig::default();
        let unknown = c.load_file(p.to_str().unwrap()).unwrap();
        assert_eq!(c.task, "walker_walk");
        assert_eq!(c.steps, 77);
        assert_eq!(unknown, vec!["nope".to_string()]);
        let _ = std::fs::remove_dir_all(dir);
    }
}
