//! Metrics, CSV series output, and the log-scale histogram used for the
//! paper's Figure 6 gradient-distribution plot.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A named time series: `(x, y)` rows written as CSV.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// Mean of the y values (used for end-of-training scores).
    pub fn mean_y(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|p| p.1).sum::<f64>() / self.points.len() as f64
    }

    /// Last y value.
    pub fn last_y(&self) -> f64 {
        self.points.last().map(|p| p.1).unwrap_or(0.0)
    }
}

/// Write a set of series sharing an x-axis to one CSV file:
/// `x, <name1>, <name2>, ...` (rows joined on exact x; missing = empty).
pub fn write_csv(path: &Path, series: &[Series]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut xs: Vec<f64> = series.iter().flat_map(|s| s.points.iter().map(|p| p.0)).collect();
    xs.sort_by(|a, b| a.total_cmp(b));
    xs.dedup();
    let mut out = String::new();
    out.push('x');
    for s in series {
        let _ = write!(out, ",{}", s.name);
    }
    out.push('\n');
    for &x in &xs {
        let _ = write!(out, "{x}");
        for s in series {
            match s.points.iter().find(|p| p.0 == x) {
                Some(p) => {
                    let _ = write!(out, ",{}", p.1);
                }
                None => out.push(','),
            }
        }
        out.push('\n');
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(out.as_bytes())
}

/// Mean and (population) standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Histogram with logarithmically spaced bins — Figure 6's axes are both
/// logarithmic, so bins span decades of gradient magnitude.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// Left edge of the first bin, as a power of 10.
    pub min_exp: i32,
    /// Right edge of the last bin, as a power of 10.
    pub max_exp: i32,
    /// Bins per decade.
    pub per_decade: usize,
    pub counts: Vec<u64>,
    /// Values below `10^min_exp` (incl. exact zeros).
    pub underflow: u64,
    /// Values at or above `10^max_exp`.
    pub overflow: u64,
}

impl LogHistogram {
    pub fn new(min_exp: i32, max_exp: i32, per_decade: usize) -> Self {
        assert!(max_exp > min_exp);
        let nbins = ((max_exp - min_exp) as usize) * per_decade;
        LogHistogram { min_exp, max_exp, per_decade, counts: vec![0; nbins], underflow: 0, overflow: 0 }
    }

    /// Record |x|.
    pub fn record(&mut self, x: f32) {
        let a = x.abs() as f64;
        if a <= 0.0 || !a.is_finite() {
            self.underflow += u64::from(a <= 0.0);
            self.overflow += u64::from(a.is_infinite());
            return;
        }
        let pos = (a.log10() - self.min_exp as f64) * self.per_decade as f64;
        if pos < 0.0 {
            self.underflow += 1;
        } else if pos as usize >= self.counts.len() {
            self.overflow += 1;
        } else {
            self.counts[pos as usize] += 1;
        }
    }

    pub fn record_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.record(x);
        }
    }

    /// Bin centers (geometric) and counts, for plotting/CSV.
    pub fn bins(&self) -> Vec<(f64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let e = self.min_exp as f64 + (i as f64 + 0.5) / self.per_decade as f64;
                (10f64.powf(e), c)
            })
            .collect()
    }

    /// Number of decades spanned by non-empty bins — the "orders of
    /// magnitude of dynamic range" headline of Figure 6.
    pub fn occupied_decades(&self) -> f64 {
        let first = self.counts.iter().position(|&c| c > 0);
        let last = self.counts.iter().rposition(|&c| c > 0);
        match (first, last) {
            (Some(f), Some(l)) => (l - f + 1) as f64 / self.per_decade as f64,
            _ => 0.0,
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_bins_by_decade() {
        let mut h = LogHistogram::new(-8, 0, 1);
        h.record_all(&[1e-7, 2e-7, 1e-3, 0.5]);
        let bins = h.bins();
        assert_eq!(bins.len(), 8);
        // 1e-7 and 2e-7 fall in the [-7,-6) decade = index 1
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[5], 1); // 1e-3 ∈ [1e-3, 1e-2) = index 5
        assert_eq!(h.counts[7], 1); // 0.5
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn histogram_under_overflow() {
        let mut h = LogHistogram::new(-4, 0, 1);
        h.record(0.0);
        h.record(1e-9);
        h.record(10.0);
        h.record(f32::INFINITY);
        assert_eq!(h.underflow, 2);
        assert_eq!(h.overflow, 2);
    }

    #[test]
    fn occupied_decades() {
        let mut h = LogHistogram::new(-8, 0, 2);
        h.record(1e-7);
        h.record(1e-2);
        let d = h.occupied_decades();
        assert!(d >= 5.0, "d={d}");
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn csv_roundtrip() {
        let mut a = Series::new("fp32");
        a.push(0.0, 1.0);
        a.push(1.0, 2.0);
        let mut b = Series::new("fp16");
        b.push(0.0, 0.5);
        let dir = std::env::temp_dir().join("lprl_test_csv");
        let path = dir.join("t.csv");
        write_csv(&path, &[a, b]).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.starts_with("x,fp32,fp16\n"));
        assert!(s.contains("0,1,0.5"));
        assert!(s.contains("1,2,"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn series_stats() {
        let mut s = Series::new("s");
        s.push(0.0, 2.0);
        s.push(1.0, 4.0);
        assert_eq!(s.mean_y(), 3.0);
        assert_eq!(s.last_y(), 4.0);
    }
}
