//! Explicit SIMD micro-kernels and slice passes — the tree's whole
//! vector compute plane.
//!
//! This is the **only** module allowed to touch `std::arch` — the tidy
//! `simd` rule pins that boundary, the same way `to_bits` is pinned to
//! `lowp/`. It now covers four families:
//!
//! * **Packed-half GEMM tiles** (`kernel_4x16_half`): widen packed
//!   16-bit weights (f16 via F16C `cvtph`, bf16 via a 16-bit left
//!   shift) into f32 lanes and accumulate in f32.
//! * **f32 GEMM tiles** (`kernel_4x16_f32`): the same 4×16 register
//!   tile over unpacked f32 operands — the master/compute plane every
//!   forward, backward, and fp32 baseline funnels through.
//! * **Slice RNE quantizer** (`quantize_slice_rne`): the integer
//!   add-trick of `lowp::format::quantize_rne_bits`, eight lanes at a
//!   time, with every special-value lane redone by the scalar function.
//! * **Half pack/unpack** (`pack_half_slice` / `unpack_half_slice`)
//!   and the epilogue bias add (`add_slice`).
//!
//! Parity contract: every vector kernel vectorizes **across output
//! columns** — each output element is one SIMD lane accumulating its own
//! ascending-`k` chain with a separate multiply and add per step, which
//! is exactly the scalar kernel's schedule. Multiplies/adds are IEEE f32
//! in both paths and no FMA contraction is used (a fused multiply-add
//! would keep extra intermediate bits and break bitwise parity). The
//! slice passes are elementwise, so lane grouping cannot reorder
//! anything; where hardware semantics diverge from the scalar
//! converters (NaN payload handling in f16/bf16 conversion, the
//! quantizer's subnormal/overflow regions) the affected chunk is redone
//! by the scalar function. The scalar paths are therefore the *oracle*:
//! vector results are bitwise identical for every shape, format, and
//! feature level (property-tested here, in `tests/half_storage.rs`, and
//! in `tests/simd_f32.rs`).
//!
//! Dispatch is by a runtime-detected [`Level`], cached once per process;
//! `LPRL_SIMD=0` forces the scalar path (the bench/CI seam for timing
//! the oracle and for exercising parity on machines with the fast path).

use crate::lowp::HalfFormat;
use std::sync::OnceLock;

/// Micro-kernel rows — must match `gemm::MR`.
pub const MR: usize = 4;
/// Micro-kernel columns — must match `gemm::NR`.
pub const NR: usize = 16;

/// Available compute tiers for the GEMM micro-kernels and slice passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Portable scalar kernels — the bitwise oracle.
    Scalar,
    /// x86-64 AVX2 + F16C: 8-lane f32 vectors, hardware f16 widening.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// AArch64 NEON: 4-lane f32 vectors (f32 and bf16 GEMM tiles —
    /// stable Rust has no NEON f16 widening intrinsics, so packed-f16
    /// GEMM falls back to scalar; the quantizer and pack/unpack passes
    /// are scalar on this tier too).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Level {
    /// Knob/bench spelling of this level.
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Level::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Level::Neon => "neon",
        }
    }

    /// True if this level has a vector GEMM kernel for packed-half `fmt`
    /// (otherwise the half GEMM runs the scalar oracle for that format).
    pub fn accelerates(self, fmt: HalfFormat) -> bool {
        match self {
            Level::Scalar => false,
            #[cfg(target_arch = "x86_64")]
            Level::Avx2 => true,
            #[cfg(target_arch = "aarch64")]
            Level::Neon => matches!(fmt, HalfFormat::Bf16),
        }
    }
}

/// Detect the best available level, once per process. `LPRL_SIMD=0`
/// forces [`Level::Scalar`]. Detection never changes *results* — the
/// kernels are bitwise equal across levels — only throughput.
pub fn detect() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        if std::env::var("LPRL_SIMD").is_ok_and(|v| v == "0") {
            return Level::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("f16c") {
                return Level::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            return Level::Neon;
        }
        #[allow(unreachable_code)]
        Level::Scalar
    })
}

/// One-line description of the detected CPU features and chosen level —
/// logged by the bench smokes and the CI parity gate.
pub fn feature_summary() -> String {
    let level = detect();
    #[cfg(target_arch = "x86_64")]
    {
        format!(
            "arch=x86_64 level={} avx2={} f16c={}",
            level.name(),
            is_x86_feature_detected!("avx2"),
            is_x86_feature_detected!("f16c"),
        )
    }
    #[cfg(target_arch = "aarch64")]
    {
        format!("arch=aarch64 level={} neon=true", level.name())
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        format!("arch=other level={}", level.name())
    }
}

/// The kernel tier a GEMM over the given weight storage actually
/// dispatches to at the detected level (`None` = unpacked f32 weights,
/// which every vector level accelerates). `lprl info` reports this per
/// format so "detected avx2" is never confused with "this format runs
/// avx2".
pub fn dispatch_tier(fmt: Option<HalfFormat>) -> &'static str {
    let level = detect();
    match fmt {
        None => level.name(),
        Some(f) if level.accelerates(f) => level.name(),
        Some(_) => Level::Scalar.name(),
    }
}

/// Full-tile packed-half micro-kernel:
/// `c[r][j] += Σ_p a[r][p] · widen(b[p][j])` with MR×NR independent
/// accumulator chains — dispatched by `level`/`fmt` to a vector body or
/// the scalar oracle, all bitwise identical.
// SAFETY: callers pass `a` holding kl rows of MR live columns at stride
// `a_rs`, `b` holding kl rows of NR live packed columns at stride
// `b_rs`, and `c` writable for a full MR×NR tile at row stride `c_rs`
// that this call exclusively owns.
#[allow(clippy::too_many_arguments)]
pub unsafe fn kernel_4x16_half(
    level: Level,
    fmt: HalfFormat,
    a: *const f32,
    a_rs: usize,
    b: *const u16,
    b_rs: usize,
    c: *mut f32,
    c_rs: usize,
    kl: usize,
) {
    match (level, fmt) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by `detect()` after
        // runtime avx2+f16c checks; pointer contracts forwarded as-is.
        (Level::Avx2, HalfFormat::F16) => unsafe {
            x86::kernel_4x16_f16(a, a_rs, b, b_rs, c, c_rs, kl)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — avx2 verified at detection time.
        (Level::Avx2, HalfFormat::Bf16) => unsafe {
            x86::kernel_4x16_bf16(a, a_rs, b, b_rs, c, c_rs, kl)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; pointer contracts
        // forwarded as-is.
        (Level::Neon, HalfFormat::Bf16) => unsafe {
            neon::kernel_4x16_bf16(a, a_rs, b, b_rs, c, c_rs, kl)
        },
        // SAFETY: pointer contracts forwarded as-is.
        _ => unsafe { kernel_4x16_half_scalar(fmt, a, a_rs, b, b_rs, c, c_rs, kl) },
    }
}

/// Scalar oracle for the full packed-half tile — the exact structure of
/// [`kernel_4x16_f32_scalar`] with a widening load on the B operand.
// SAFETY: same contract as `kernel_4x16_half`.
#[allow(clippy::too_many_arguments)]
unsafe fn kernel_4x16_half_scalar(
    fmt: HalfFormat,
    a: *const f32,
    a_rs: usize,
    b: *const u16,
    b_rs: usize,
    c: *mut f32,
    c_rs: usize,
    kl: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    // SAFETY: every offset below stays inside the MR×kl / kl×NR panels
    // and the MR×NR output tile the caller contract grants.
    unsafe {
        for p in 0..kl {
            let bp = b.add(p * b_rs);
            let a0 = *a.add(p);
            let a1 = *a.add(a_rs + p);
            let a2 = *a.add(2 * a_rs + p);
            let a3 = *a.add(3 * a_rs + p);
            for j in 0..NR {
                let bv = fmt.decode(*bp.add(j));
                acc[0][j] += a0 * bv;
                acc[1][j] += a1 * bv;
                acc[2][j] += a2 * bv;
                acc[3][j] += a3 * bv;
            }
        }
        for (r, row) in acc.iter().enumerate() {
            let cr = c.add(r * c_rs);
            for (j, &v) in row.iter().enumerate() {
                *cr.add(j) += v;
            }
        }
    }
}

/// Edge-tile packed-half kernel (`mr ≤ MR`, `nr ≤ NR`) — always scalar
/// (edge tiles are a vanishing fraction of a bandwidth-bound product),
/// with the identical ascending-`p` accumulation order.
// SAFETY: callers pass `a`/`b` panels holding kl rows of mr/nr live
// columns at their strides, and `c` writable for an mr×nr tile at row
// stride `c_rs` that this call exclusively owns.
#[allow(clippy::too_many_arguments)]
pub unsafe fn kernel_edge_half(
    fmt: HalfFormat,
    a: *const f32,
    a_rs: usize,
    b: *const u16,
    b_rs: usize,
    c: *mut f32,
    c_rs: usize,
    mr: usize,
    nr: usize,
    kl: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    // SAFETY: every offset below stays inside the mr×kl / kl×nr panels
    // and the mr×nr output tile the caller contract grants.
    unsafe {
        for p in 0..kl {
            let bp = b.add(p * b_rs);
            for r in 0..mr {
                let av = *a.add(r * a_rs + p);
                for j in 0..nr {
                    acc[r][j] += av * fmt.decode(*bp.add(j));
                }
            }
        }
        for (r, row) in acc.iter().enumerate().take(mr) {
            let cr = c.add(r * c_rs);
            for (j, &v) in row.iter().enumerate().take(nr) {
                *cr.add(j) += v;
            }
        }
    }
}

/// Full-tile f32 micro-kernel:
/// `c[r][j] += Σ_p a[r][p] · b[p][j]` with MR×NR independent
/// accumulator chains — dispatched by `level` to a vector body or the
/// scalar oracle, all bitwise identical. This is the compute plane of
/// every f32 GEMM variant (`gemm`/`gemm_nt`/`gemm_tn` all reduce to
/// notrans·notrans jobs over packed panels).
// SAFETY: callers pass `a` holding kl rows of MR live columns at stride
// `a_rs`, `b` holding kl rows of NR live columns at stride `b_rs`, and
// `c` writable for a full MR×NR tile at row stride `c_rs` that this
// call exclusively owns.
#[allow(clippy::too_many_arguments)]
pub unsafe fn kernel_4x16_f32(
    level: Level,
    a: *const f32,
    a_rs: usize,
    b: *const f32,
    b_rs: usize,
    c: *mut f32,
    c_rs: usize,
    kl: usize,
) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by `detect()` after the
        // runtime avx2 check; pointer contracts forwarded as-is.
        Level::Avx2 => unsafe { x86::kernel_4x16_f32(a, a_rs, b, b_rs, c, c_rs, kl) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; pointer contracts
        // forwarded as-is.
        Level::Neon => unsafe { neon::kernel_4x16_f32(a, a_rs, b, b_rs, c, c_rs, kl) },
        // SAFETY: pointer contracts forwarded as-is.
        _ => unsafe { kernel_4x16_f32_scalar(a, a_rs, b, b_rs, c, c_rs, kl) },
    }
}

/// Scalar oracle for the full f32 tile — 64 independent accumulators
/// the compiler keeps in registers (formerly `gemm::kernel_4x16`).
// SAFETY: same contract as `kernel_4x16_f32`.
#[allow(clippy::too_many_arguments)]
unsafe fn kernel_4x16_f32_scalar(
    a: *const f32,
    a_rs: usize,
    b: *const f32,
    b_rs: usize,
    c: *mut f32,
    c_rs: usize,
    kl: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    // SAFETY: every offset below stays inside the MR×kl / kl×NR panels
    // and the MR×NR output tile the caller contract grants.
    unsafe {
        for p in 0..kl {
            let bp = b.add(p * b_rs);
            let a0 = *a.add(p);
            let a1 = *a.add(a_rs + p);
            let a2 = *a.add(2 * a_rs + p);
            let a3 = *a.add(3 * a_rs + p);
            for j in 0..NR {
                let bv = *bp.add(j);
                acc[0][j] += a0 * bv;
                acc[1][j] += a1 * bv;
                acc[2][j] += a2 * bv;
                acc[3][j] += a3 * bv;
            }
        }
        for (r, row) in acc.iter().enumerate() {
            let cr = c.add(r * c_rs);
            for (j, &v) in row.iter().enumerate() {
                *cr.add(j) += v;
            }
        }
    }
}

/// Slice RNE quantizer into `(exp_bits, man_bits)` with IEEE
/// overflow-to-∞ — the SIMD twin of looping
/// `lowp::format::quantize_rne_bits`, auto-dispatched at the detected
/// level. Bitwise equal to the scalar path everywhere: the vector body
/// reuses the exact integer add-trick (including its carry into the
/// exponent field), and every lane outside the normal-target fast
/// region (±0, ±∞, NaN, f32 subnormals, target subnormals) falls back
/// to the scalar function.
pub fn quantize_slice_rne(exp_bits: u8, man_bits: u8, xs: &mut [f32]) {
    quantize_slice_rne_at(detect(), exp_bits, man_bits, xs);
}

/// [`quantize_slice_rne`] pinned to an explicit [`Level`] — the seam
/// the parity tests and benches use to run the scalar oracle and the
/// vector path side by side on the same machine.
pub fn quantize_slice_rne_at(level: Level, exp_bits: u8, man_bits: u8, xs: &mut [f32]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // The vector add-trick only matches the scalar carry handling
        // for interior mantissa widths: m = 0 would read an exponent
        // bit as the round LSB and m = 23 has no bits to drop, so both
        // run the scalar loop at every level.
        Level::Avx2 if (1..=22).contains(&man_bits) => {
            // SAFETY: Level::Avx2 is only produced by `detect()` after
            // the runtime avx2 check.
            unsafe { x86::quantize_slice_rne(exp_bits, man_bits, xs) }
        }
        _ => {
            for v in xs.iter_mut() {
                *v = crate::lowp::format::quantize_rne_bits(*v, exp_bits, man_bits);
            }
        }
    }
}

/// Pack f32s into 16-bit `fmt` bits, slice-wise — the SIMD twin of the
/// per-element encode loop (hardware F16C conversion for f16, the
/// integer add-trick for bf16). NaN chunks are redone by the scalar
/// converters (hardware preserves payloads the scalar path
/// canonicalizes), so results are bitwise equal at every level.
pub fn pack_half_slice(fmt: HalfFormat, src: &[f32], dst: &mut [u16]) {
    pack_half_slice_at(detect(), fmt, src, dst);
}

/// [`pack_half_slice`] pinned to an explicit [`Level`].
pub fn pack_half_slice_at(level: Level, fmt: HalfFormat, src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len());
    match (level, fmt) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by `detect()` after
        // runtime avx2+f16c checks.
        (Level::Avx2, HalfFormat::F16) => unsafe { x86::pack_f16(src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — avx2 verified at detection time.
        (Level::Avx2, HalfFormat::Bf16) => unsafe { x86::pack_bf16(src, dst) },
        _ => {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = fmt.encode(s);
            }
        }
    }
}

/// Unpack 16-bit `fmt` bits into f32s, slice-wise — always exact. The
/// f16 vector body redoes NaN chunks scalar (hardware quiets signalling
/// payloads the scalar widener preserves); the bf16 body is a pure
/// 16-bit shift, exact for every bit pattern with no fallback.
pub fn unpack_half_slice(fmt: HalfFormat, src: &[u16], dst: &mut [f32]) {
    unpack_half_slice_at(detect(), fmt, src, dst);
}

/// [`unpack_half_slice`] pinned to an explicit [`Level`].
pub fn unpack_half_slice_at(level: Level, fmt: HalfFormat, src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    match (level, fmt) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by `detect()` after
        // runtime avx2+f16c checks.
        (Level::Avx2, HalfFormat::F16) => unsafe { x86::unpack_f16(src, dst) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — avx2 verified at detection time.
        (Level::Avx2, HalfFormat::Bf16) => unsafe { x86::unpack_bf16(src, dst) },
        _ => {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = fmt.decode(s);
            }
        }
    }
}

/// `dst[j] += src[j]` — the fused epilogue's bias add, vectorized.
/// Elementwise, so lane grouping cannot change results: each element is
/// one IEEE f32 add in both paths.
pub fn add_slice(dst: &mut [f32], src: &[f32]) {
    add_slice_at(detect(), dst, src);
}

/// [`add_slice`] pinned to an explicit [`Level`].
pub fn add_slice_at(level: Level, dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by `detect()` after the
        // runtime avx2 check.
        Level::Avx2 => unsafe { x86::add_slice(dst, src) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Level::Neon => unsafe { neon::add_slice(dst, src) },
        _ => {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::MR;
    use crate::lowp::format::{
        f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits, quantize_rne_bits,
    };
    use std::arch::x86_64::*;

    /// AVX2+F16C full tile, f16 weights: per `p`, two `cvtph` widening
    /// loads cover the NR=16 columns as two 8-lane vectors; each of the
    /// MR=4 rows broadcasts its `a` scalar and does a separate
    /// `mul` + `add` (no FMA — parity). Lane `j` of the accumulators is
    /// output element `c[r][j]`'s own ascending-`k` chain, bitwise equal
    /// to the scalar oracle's.
    // SAFETY: same pointer contract as `kernel_4x16_half`; callers must
    // have verified avx2+f16c at runtime.
    #[target_feature(enable = "avx2,f16c")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn kernel_4x16_f16(
        a: *const f32,
        a_rs: usize,
        b: *const u16,
        b_rs: usize,
        c: *mut f32,
        c_rs: usize,
        kl: usize,
    ) {
        // SAFETY: every pointer offset stays inside the MR×kl / kl×NR
        // panels and the MR×NR output tile the caller contract grants;
        // all loads/stores are the unaligned variants.
        unsafe {
            let mut acc = [[_mm256_setzero_ps(); 2]; MR];
            for p in 0..kl {
                let bp = b.add(p * b_rs);
                let blo = _mm256_cvtph_ps(_mm_loadu_si128(bp as *const __m128i));
                let bhi = _mm256_cvtph_ps(_mm_loadu_si128(bp.add(8) as *const __m128i));
                for r in 0..MR {
                    let av = _mm256_set1_ps(*a.add(r * a_rs + p));
                    acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(av, blo));
                    acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(av, bhi));
                }
            }
            for r in 0..MR {
                let cr = c.add(r * c_rs);
                let lo = _mm256_add_ps(_mm256_loadu_ps(cr), acc[r][0]);
                let hi = _mm256_add_ps(_mm256_loadu_ps(cr.add(8)), acc[r][1]);
                _mm256_storeu_ps(cr, lo);
                _mm256_storeu_ps(cr.add(8), hi);
            }
        }
    }

    /// AVX2 full tile, bf16 weights: widening is a zero-extend to u32
    /// and a 16-bit left shift (bf16 *is* the top half of f32), then the
    /// same per-row broadcast `mul` + `add` schedule as the f16 kernel.
    // SAFETY: same pointer contract as `kernel_4x16_half`; callers must
    // have verified avx2 at runtime.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn kernel_4x16_bf16(
        a: *const f32,
        a_rs: usize,
        b: *const u16,
        b_rs: usize,
        c: *mut f32,
        c_rs: usize,
        kl: usize,
    ) {
        // SAFETY: every pointer offset stays inside the MR×kl / kl×NR
        // panels and the MR×NR output tile the caller contract grants;
        // all loads/stores are the unaligned variants.
        unsafe {
            let widen = |ptr: *const u16| -> __m256 {
                let h = _mm_loadu_si128(ptr as *const __m128i);
                _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)))
            };
            let mut acc = [[_mm256_setzero_ps(); 2]; MR];
            for p in 0..kl {
                let bp = b.add(p * b_rs);
                let blo = widen(bp);
                let bhi = widen(bp.add(8));
                for r in 0..MR {
                    let av = _mm256_set1_ps(*a.add(r * a_rs + p));
                    acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(av, blo));
                    acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(av, bhi));
                }
            }
            for r in 0..MR {
                let cr = c.add(r * c_rs);
                let lo = _mm256_add_ps(_mm256_loadu_ps(cr), acc[r][0]);
                let hi = _mm256_add_ps(_mm256_loadu_ps(cr.add(8)), acc[r][1]);
                _mm256_storeu_ps(cr, lo);
                _mm256_storeu_ps(cr.add(8), hi);
            }
        }
    }

    /// AVX2 full tile, f32 weights: the f16 kernel's schedule with plain
    /// unaligned loads on the B rows — two 8-lane vectors per `p`, one
    /// broadcast `mul` + `add` per row (no FMA — parity with the scalar
    /// oracle's one-multiply-one-add chains).
    // SAFETY: same pointer contract as `kernel_4x16_f32`; callers must
    // have verified avx2 at runtime.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn kernel_4x16_f32(
        a: *const f32,
        a_rs: usize,
        b: *const f32,
        b_rs: usize,
        c: *mut f32,
        c_rs: usize,
        kl: usize,
    ) {
        // SAFETY: every pointer offset stays inside the MR×kl / kl×NR
        // panels and the MR×NR output tile the caller contract grants;
        // all loads/stores are the unaligned variants.
        unsafe {
            let mut acc = [[_mm256_setzero_ps(); 2]; MR];
            for p in 0..kl {
                let bp = b.add(p * b_rs);
                let blo = _mm256_loadu_ps(bp);
                let bhi = _mm256_loadu_ps(bp.add(8));
                for r in 0..MR {
                    let av = _mm256_set1_ps(*a.add(r * a_rs + p));
                    acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(av, blo));
                    acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(av, bhi));
                }
            }
            for r in 0..MR {
                let cr = c.add(r * c_rs);
                let lo = _mm256_add_ps(_mm256_loadu_ps(cr), acc[r][0]);
                let hi = _mm256_add_ps(_mm256_loadu_ps(cr.add(8)), acc[r][1]);
                _mm256_storeu_ps(cr, lo);
                _mm256_storeu_ps(cr.add(8), hi);
            }
        }
    }

    /// AVX2 slice RNE quantizer: the integer add-trick of
    /// `quantize_rne_bits` on eight magnitudes at a time. The fast
    /// region is normal-target lanes only — any lane that is ±0, ±∞,
    /// NaN, an f32 subnormal, or below the target's normal range sends
    /// the whole chunk back to the scalar function, so every special
    /// case shares the scalar code path. In the fast region the trick
    /// `r = abs + (half-1) + lsb` carries a mantissa overflow into the
    /// exponent field exactly like the scalar path's explicit carry
    /// (the kept mantissa bits are zero whenever the carry fires), and
    /// results past the largest finite encoding blend to ±∞.
    // SAFETY: callers must have verified avx2 at runtime; `man_bits`
    // must be in 1..=22 (the dispatcher's guard) so the shift amounts
    // below stay in range.
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_slice_rne(exp_bits: u8, man_bits: u8, xs: &mut [f32]) {
        debug_assert!((1..=22).contains(&man_bits));
        let bias = (1i32 << (exp_bits - 1)) - 1;
        let emin = 1 - bias;
        let m = man_bits as i32;
        let shift = 23 - m; // 1..=22
        let half_m1 = (1u32 << (shift - 1)) - 1;
        // largest finite target value, as its f32 bit pattern
        let max_finite = (((bias + 127) as u32) << 23) | (((1u32 << m) - 1) << shift);
        // below this magnitude the target is subnormal/zero (and every
        // f32-subnormal input sits below it too, since emin >= -126)
        let min_normal = ((emin + 127) as u32) << 23;
        // all compared bit patterns are < 2^31, so signed 32-bit
        // compares order them correctly
        let mut chunks = xs.chunks_exact_mut(8);
        for chunk in &mut chunks {
            // SAFETY: each chunk holds exactly 8 f32s; loads/stores are
            // the unaligned variants through the chunk's own pointer.
            unsafe {
                let ptr = chunk.as_mut_ptr();
                let bits = _mm256_loadu_si256(ptr as *const __m256i);
                let abs = _mm256_and_si256(bits, _mm256_set1_epi32(0x7fff_ffff));
                let too_big = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7f7f_ffff));
                let too_small = _mm256_cmpgt_epi32(_mm256_set1_epi32(min_normal as i32), abs);
                let special = _mm256_or_si256(too_big, too_small);
                if _mm256_movemask_epi8(special) != 0 {
                    for v in chunk.iter_mut() {
                        *v = quantize_rne_bits(*v, exp_bits, man_bits);
                    }
                    continue;
                }
                let sign = _mm256_andnot_si256(_mm256_set1_epi32(0x7fff_ffff), bits);
                let vshift = _mm_cvtsi32_si128(shift);
                let lsb = _mm256_and_si256(_mm256_srl_epi32(abs, vshift), _mm256_set1_epi32(1));
                let rounded = _mm256_add_epi32(
                    _mm256_add_epi32(abs, _mm256_set1_epi32(half_m1 as i32)),
                    lsb,
                );
                let keep = _mm256_set1_epi32(!((1u32 << shift) - 1) as i32);
                let kept = _mm256_and_si256(rounded, keep);
                let over = _mm256_cmpgt_epi32(kept, _mm256_set1_epi32(max_finite as i32));
                let out =
                    _mm256_blendv_epi8(kept, _mm256_set1_epi32(0x7f80_0000), over);
                _mm256_storeu_si256(ptr as *mut __m256i, _mm256_or_si256(sign, out));
            }
        }
        for v in chunks.into_remainder() {
            *v = quantize_rne_bits(*v, exp_bits, man_bits);
        }
    }

    /// AVX2+F16C slice pack f32 → f16 bits via hardware `cvtps2ph`
    /// (RNE). NaN chunks redo scalar: hardware preserves NaN payloads
    /// where the scalar converter canonicalizes them.
    // SAFETY: callers must have verified avx2+f16c at runtime.
    #[target_feature(enable = "avx2,f16c")]
    pub unsafe fn pack_f16(src: &[f32], dst: &mut [u16]) {
        let mut din = dst.chunks_exact_mut(8);
        let mut sin = src.chunks_exact(8);
        for (d, s) in (&mut din).zip(&mut sin) {
            // SAFETY: both chunks hold exactly 8 elements; loads/stores
            // are the unaligned variants.
            unsafe {
                let x = _mm256_loadu_ps(s.as_ptr());
                let unord = _mm256_cmp_ps::<_CMP_UNORD_Q>(x, x);
                if _mm256_movemask_ps(unord) != 0 {
                    for (dv, &sv) in d.iter_mut().zip(s) {
                        *dv = f32_to_f16_bits(sv);
                    }
                } else {
                    let h = _mm256_cvtps_ph::<_MM_FROUND_TO_NEAREST_INT>(x);
                    _mm_storeu_si128(d.as_mut_ptr() as *mut __m128i, h);
                }
            }
        }
        for (dv, &sv) in din.into_remainder().iter_mut().zip(sin.remainder()) {
            *dv = f32_to_f16_bits(sv);
        }
    }

    /// AVX2+F16C slice unpack f16 bits → f32 via hardware `cvtph2ps`.
    /// NaN chunks redo scalar (detected on the output, which flags
    /// exactly the NaN inputs): hardware quiets signalling payloads the
    /// scalar widener preserves bit-for-bit.
    // SAFETY: callers must have verified avx2+f16c at runtime.
    #[target_feature(enable = "avx2,f16c")]
    pub unsafe fn unpack_f16(src: &[u16], dst: &mut [f32]) {
        let mut din = dst.chunks_exact_mut(8);
        let mut sin = src.chunks_exact(8);
        for (d, s) in (&mut din).zip(&mut sin) {
            // SAFETY: both chunks hold exactly 8 elements; loads/stores
            // are the unaligned variants.
            unsafe {
                let h = _mm_loadu_si128(s.as_ptr() as *const __m128i);
                let x = _mm256_cvtph_ps(h);
                let unord = _mm256_cmp_ps::<_CMP_UNORD_Q>(x, x);
                if _mm256_movemask_ps(unord) != 0 {
                    for (dv, &sv) in d.iter_mut().zip(s) {
                        *dv = f16_bits_to_f32(sv);
                    }
                } else {
                    _mm256_storeu_ps(d.as_mut_ptr(), x);
                }
            }
        }
        for (dv, &sv) in din.into_remainder().iter_mut().zip(sin.remainder()) {
            *dv = f16_bits_to_f32(sv);
        }
    }

    /// AVX2 slice pack f32 → bf16 bits: the scalar converter's RNE
    /// add-trick on eight lanes. NaN chunks redo scalar (the scalar
    /// converter quiets the payload).
    // SAFETY: callers must have verified avx2 at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn pack_bf16(src: &[f32], dst: &mut [u16]) {
        let mut din = dst.chunks_exact_mut(8);
        let mut sin = src.chunks_exact(8);
        for (d, s) in (&mut din).zip(&mut sin) {
            // SAFETY: both chunks hold exactly 8 elements; loads/stores
            // are the unaligned variants.
            unsafe {
                let bits = _mm256_castps_si256(_mm256_loadu_ps(s.as_ptr()));
                let abs = _mm256_and_si256(bits, _mm256_set1_epi32(0x7fff_ffff));
                let nan = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7f80_0000));
                if _mm256_movemask_epi8(nan) != 0 {
                    for (dv, &sv) in d.iter_mut().zip(s) {
                        *dv = f32_to_bf16_bits(sv);
                    }
                    continue;
                }
                let lsb =
                    _mm256_and_si256(_mm256_srli_epi32::<16>(bits), _mm256_set1_epi32(1));
                let r = _mm256_add_epi32(
                    bits,
                    _mm256_add_epi32(_mm256_set1_epi32(0x7fff), lsb),
                );
                let h32 = _mm256_srli_epi32::<16>(r);
                // narrow the eight u32 lanes (each ≤ 0xffff) to u16
                let packed = _mm256_packus_epi32(h32, h32);
                let lo = _mm256_castsi256_si128(packed);
                let hi = _mm256_extracti128_si256::<1>(packed);
                let out = _mm_unpacklo_epi64(lo, hi);
                _mm_storeu_si128(d.as_mut_ptr() as *mut __m128i, out);
            }
        }
        for (dv, &sv) in din.into_remainder().iter_mut().zip(sin.remainder()) {
            *dv = f32_to_bf16_bits(sv);
        }
    }

    /// AVX2 slice unpack bf16 bits → f32: a pure zero-extend + 16-bit
    /// shift — exact for every bit pattern, NaN payloads included, so
    /// there is no fallback.
    // SAFETY: callers must have verified avx2 at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_bf16(src: &[u16], dst: &mut [f32]) {
        let mut din = dst.chunks_exact_mut(8);
        let mut sin = src.chunks_exact(8);
        for (d, s) in (&mut din).zip(&mut sin) {
            // SAFETY: both chunks hold exactly 8 elements; loads/stores
            // are the unaligned variants.
            unsafe {
                let h = _mm_loadu_si128(s.as_ptr() as *const __m128i);
                let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
                _mm256_storeu_ps(d.as_mut_ptr(), _mm256_castsi256_ps(w));
            }
        }
        for (dv, &sv) in din.into_remainder().iter_mut().zip(sin.remainder()) {
            *dv = crate::lowp::format::bf16_bits_to_f32(sv);
        }
    }

    /// AVX2 elementwise `dst += src` (the epilogue bias add).
    // SAFETY: callers must have verified avx2 at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_slice(dst: &mut [f32], src: &[f32]) {
        let mut din = dst.chunks_exact_mut(8);
        let mut sin = src.chunks_exact(8);
        for (d, s) in (&mut din).zip(&mut sin) {
            // SAFETY: both chunks hold exactly 8 elements; loads/stores
            // are the unaligned variants.
            unsafe {
                let sum =
                    _mm256_add_ps(_mm256_loadu_ps(d.as_ptr()), _mm256_loadu_ps(s.as_ptr()));
                _mm256_storeu_ps(d.as_mut_ptr(), sum);
            }
        }
        for (dv, &sv) in din.into_remainder().iter_mut().zip(sin.remainder()) {
            *dv += sv;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::MR;
    use std::arch::aarch64::*;

    /// NEON full tile, bf16 weights: NR=16 columns as four 4-lane f32
    /// vectors, widened by zero-extend + 16-bit shift; separate
    /// `vmulq`/`vaddq` per step (no `vfmaq` — parity with the scalar
    /// oracle's one-multiply-one-add chains).
    // SAFETY: same pointer contract as `kernel_4x16_half`; NEON is
    // baseline on aarch64.
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn kernel_4x16_bf16(
        a: *const f32,
        a_rs: usize,
        b: *const u16,
        b_rs: usize,
        c: *mut f32,
        c_rs: usize,
        kl: usize,
    ) {
        // SAFETY: every pointer offset stays inside the MR×kl / kl×NR
        // panels and the MR×NR output tile the caller contract grants.
        unsafe {
            let widen_pair = |h: uint16x8_t| -> (float32x4_t, float32x4_t) {
                let lo = vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vget_low_u16(h))));
                let hi = vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vget_high_u16(h))));
                (lo, hi)
            };
            let mut acc = [[vdupq_n_f32(0.0); 4]; MR];
            for p in 0..kl {
                let bp = b.add(p * b_rs);
                let (b0, b1) = widen_pair(vld1q_u16(bp));
                let (b2, b3) = widen_pair(vld1q_u16(bp.add(8)));
                let bv = [b0, b1, b2, b3];
                for r in 0..MR {
                    let av = vdupq_n_f32(*a.add(r * a_rs + p));
                    for q in 0..4 {
                        acc[r][q] = vaddq_f32(acc[r][q], vmulq_f32(av, bv[q]));
                    }
                }
            }
            for r in 0..MR {
                let cr = c.add(r * c_rs);
                for q in 0..4 {
                    let cur = vld1q_f32(cr.add(4 * q));
                    vst1q_f32(cr.add(4 * q), vaddq_f32(cur, acc[r][q]));
                }
            }
        }
    }

    /// NEON full tile, f32 weights: the bf16 kernel's schedule with
    /// plain `vld1q_f32` loads on the B rows — four 4-lane vectors per
    /// `p`, separate `vmulq`/`vaddq` per step (no `vfmaq` — parity).
    // SAFETY: same pointer contract as `kernel_4x16_f32`; NEON is
    // baseline on aarch64.
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn kernel_4x16_f32(
        a: *const f32,
        a_rs: usize,
        b: *const f32,
        b_rs: usize,
        c: *mut f32,
        c_rs: usize,
        kl: usize,
    ) {
        // SAFETY: every pointer offset stays inside the MR×kl / kl×NR
        // panels and the MR×NR output tile the caller contract grants.
        unsafe {
            let mut acc = [[vdupq_n_f32(0.0); 4]; MR];
            for p in 0..kl {
                let bp = b.add(p * b_rs);
                let bv = [
                    vld1q_f32(bp),
                    vld1q_f32(bp.add(4)),
                    vld1q_f32(bp.add(8)),
                    vld1q_f32(bp.add(12)),
                ];
                for r in 0..MR {
                    let av = vdupq_n_f32(*a.add(r * a_rs + p));
                    for q in 0..4 {
                        acc[r][q] = vaddq_f32(acc[r][q], vmulq_f32(av, bv[q]));
                    }
                }
            }
            for r in 0..MR {
                let cr = c.add(r * c_rs);
                for q in 0..4 {
                    let cur = vld1q_f32(cr.add(4 * q));
                    vst1q_f32(cr.add(4 * q), vaddq_f32(cur, acc[r][q]));
                }
            }
        }
    }

    /// NEON elementwise `dst += src` (the epilogue bias add).
    // SAFETY: NEON is baseline on aarch64.
    pub unsafe fn add_slice(dst: &mut [f32], src: &[f32]) {
        let mut din = dst.chunks_exact_mut(4);
        let mut sin = src.chunks_exact(4);
        for (d, s) in (&mut din).zip(&mut sin) {
            // SAFETY: both chunks hold exactly 4 elements.
            unsafe {
                let sum = vaddq_f32(vld1q_f32(d.as_ptr()), vld1q_f32(s.as_ptr()));
                vst1q_f32(d.as_mut_ptr(), sum);
            }
        }
        for (dv, &sv) in din.into_remainder().iter_mut().zip(sin.remainder()) {
            *dv += sv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Pcg64;

    /// Drive the full-tile half kernel at `level` over a kl-deep panel.
    fn run_tile(level: Level, fmt: HalfFormat, kl: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed(seed);
        let a: Vec<f32> = (0..MR * kl).map(|_| rng.normal_f32()).collect();
        let b: Vec<u16> = (0..kl * NR).map(|_| fmt.encode(rng.normal_f32())).collect();
        let mut c: Vec<f32> = (0..MR * NR).map(|_| rng.normal_f32()).collect();
        // SAFETY: a is [MR, kl] at stride kl, b is [kl, NR] at stride
        // NR, and c is an exclusively-owned MR×NR tile at stride NR.
        unsafe {
            kernel_4x16_half(level, fmt, a.as_ptr(), kl, b.as_ptr(), NR, c.as_mut_ptr(), NR, kl);
        }
        c
    }

    /// Drive the full-tile f32 kernel at `level` over a kl-deep panel.
    fn run_tile_f32(level: Level, kl: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed(seed);
        let a: Vec<f32> = (0..MR * kl).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..kl * NR).map(|_| rng.normal_f32()).collect();
        let mut c: Vec<f32> = (0..MR * NR).map(|_| rng.normal_f32()).collect();
        // SAFETY: a is [MR, kl] at stride kl, b is [kl, NR] at stride
        // NR, and c is an exclusively-owned MR×NR tile at stride NR.
        unsafe {
            kernel_4x16_f32(level, a.as_ptr(), kl, b.as_ptr(), NR, c.as_mut_ptr(), NR, kl);
        }
        c
    }

    #[test]
    fn detected_level_matches_scalar_oracle_bitwise() {
        let level = detect();
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            for kl in [0, 1, 3, 17, 256] {
                let fast = run_tile(level, fmt, kl, 7 + kl as u64);
                let slow = run_tile(Level::Scalar, fmt, kl, 7 + kl as u64);
                assert!(
                    fast.iter().zip(&slow).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} {} kl={kl}: vector tile must equal the scalar oracle",
                    level.name(),
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn detected_level_f32_tile_matches_scalar_oracle_bitwise() {
        let level = detect();
        for kl in [0, 1, 3, 17, 256] {
            let fast = run_tile_f32(level, kl, 31 + kl as u64);
            let slow = run_tile_f32(Level::Scalar, kl, 31 + kl as u64);
            assert!(
                fast.iter().zip(&slow).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{} kl={kl}: f32 vector tile must equal the scalar oracle",
                level.name()
            );
        }
    }

    /// Formats spanning the vector fast path (1..=22 mantissa bits) and
    /// the always-scalar widths (m = 0), across exponent ranges.
    const QFORMATS: &[(u8, u8)] =
        &[(5, 10), (8, 7), (5, 7), (5, 5), (4, 3), (8, 10), (2, 1), (5, 1), (8, 22), (5, 0)];

    fn quantizer_edge_values() -> Vec<f32> {
        let mut vals = vec![
            0.0,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::from_bits(0x7f80_0001), // signalling NaN payload
            f32::from_bits(0xffc0_1234), // negative quiet NaN payload
            65504.0,
            65519.0,
            65520.0,
            -65520.0,
            6.1035156e-5,
            5.9604645e-8,
            2.9802322e-8,
            1.0 + 4.8828125e-4,
            f32::MIN_POSITIVE,
            f32::from_bits(1),
            f32::from_bits(0x007f_ffff),
            3.389531e38,
            f32::MAX,
            1e-40,
            -1e-40,
            1.0,
            -1.0,
            std::f32::consts::PI,
        ];
        let mut rng = Pcg64::seed(23);
        vals.extend((0..4096).map(|_| f32::from_bits(rng.next_u32())));
        vals
    }

    #[test]
    fn quantize_slice_matches_scalar_oracle_bitwise() {
        let level = detect();
        let vals = quantizer_edge_values();
        for &(e, m) in QFORMATS {
            let mut fast = vals.clone();
            let mut slow = vals.clone();
            quantize_slice_rne_at(level, e, m, &mut fast);
            quantize_slice_rne_at(Level::Scalar, e, m, &mut slow);
            for (i, (x, y)) in fast.iter().zip(&slow).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "e{e}m{m} [{i}] in={:e} ({:#x}): fast={x:e} ({:#x}) slow={y:e} ({:#x})",
                    vals[i],
                    vals[i].to_bits(),
                    x.to_bits(),
                    y.to_bits()
                );
            }
        }
    }

    #[test]
    fn pack_slice_matches_scalar_oracle_bitwise() {
        let level = detect();
        let vals = quantizer_edge_values();
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            let mut fast = vec![0u16; vals.len()];
            let mut slow = vec![0u16; vals.len()];
            pack_half_slice_at(level, fmt, &vals, &mut fast);
            pack_half_slice_at(Level::Scalar, fmt, &vals, &mut slow);
            for (i, (x, y)) in fast.iter().zip(&slow).enumerate() {
                assert!(
                    x == y,
                    "{} [{i}] in={:e} ({:#x}): fast={x:#x} slow={y:#x}",
                    fmt.name(),
                    vals[i],
                    vals[i].to_bits()
                );
            }
        }
    }

    #[test]
    fn unpack_slice_matches_scalar_oracle_on_every_bit_pattern() {
        let level = detect();
        let src: Vec<u16> = (0..=u16::MAX).collect();
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            let mut fast = vec![0.0f32; src.len()];
            let mut slow = vec![0.0f32; src.len()];
            unpack_half_slice_at(level, fmt, &src, &mut fast);
            unpack_half_slice_at(Level::Scalar, fmt, &src, &mut slow);
            for (i, (x, y)) in fast.iter().zip(&slow).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "{} h={:#x}: fast={:#x} slow={:#x}",
                    fmt.name(),
                    src[i],
                    x.to_bits(),
                    y.to_bits()
                );
            }
        }
    }

    #[test]
    fn add_slice_matches_scalar_bitwise() {
        let level = detect();
        let mut rng = Pcg64::seed(41);
        for n in [0usize, 1, 7, 8, 9, 64, 130] {
            let src: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let mut fast = base.clone();
            let mut slow = base.clone();
            add_slice_at(level, &mut fast, &src);
            add_slice_at(Level::Scalar, &mut slow, &src);
            assert!(
                fast.iter().zip(&slow).all(|(x, y)| x.to_bits() == y.to_bits()),
                "n={n}: vector bias add must equal the scalar add"
            );
        }
    }

    #[test]
    fn detection_is_stable() {
        assert_eq!(detect(), detect());
        let s = feature_summary();
        assert!(s.contains("level="), "{s}");
    }

    #[test]
    fn dispatch_tier_reports_per_format_kernels() {
        let level = detect();
        // the f32 plane always runs the detected level
        assert_eq!(dispatch_tier(None), level.name());
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            let tier = dispatch_tier(Some(fmt));
            if level.accelerates(fmt) {
                assert_eq!(tier, level.name());
            } else {
                assert_eq!(tier, "scalar");
            }
        }
    }
}
