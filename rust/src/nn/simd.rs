//! Explicit SIMD micro-kernels for the packed-half GEMM path.
//!
//! This is the **only** module allowed to touch `std::arch` — the tidy
//! `simd` rule pins that boundary, the same way `to_bits` is pinned to
//! `lowp/`. Everything here widens packed 16-bit weights (f16 via F16C
//! `cvtph`, bf16 via a 16-bit left shift) into f32 lanes and accumulates
//! in f32.
//!
//! Parity contract: every vector kernel vectorizes **across output
//! columns** — each output element is one SIMD lane accumulating its own
//! ascending-`k` chain with a separate multiply and add per step, which
//! is exactly the scalar kernel's schedule. Widening `u16 -> f32` is
//! exact for both layouts, multiplies/adds are IEEE f32 in both paths,
//! and no FMA contraction is used (a fused multiply-add would keep extra
//! intermediate bits and break bitwise parity). The scalar kernels below
//! are therefore the *oracle*: vector results are bitwise identical for
//! every shape, format, and feature level (property-tested in
//! `tests/half_storage.rs`).
//!
//! Dispatch is by a runtime-detected [`Level`], cached once per process;
//! `LPRL_SIMD=0` forces the scalar path (the bench/CI seam for timing
//! the oracle and for exercising parity on machines with the fast path).

use crate::lowp::HalfFormat;
use std::sync::OnceLock;

/// Micro-kernel rows — must match `gemm::MR`.
pub const MR: usize = 4;
/// Micro-kernel columns — must match `gemm::NR`.
pub const NR: usize = 16;

/// Available compute tiers for the packed-half kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Portable scalar widening kernels — the bitwise oracle.
    Scalar,
    /// x86-64 AVX2 + F16C: 8-lane f32 vectors, hardware f16 widening.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// AArch64 NEON: 4-lane f32 vectors (bf16 only — stable Rust has no
    /// NEON f16 widening intrinsics, so f16 falls back to scalar).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Level {
    /// Knob/bench spelling of this level.
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Level::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Level::Neon => "neon",
        }
    }

    /// True if this level has a vector kernel for `fmt` (otherwise the
    /// half GEMM runs the scalar oracle for that format).
    pub fn accelerates(self, fmt: HalfFormat) -> bool {
        match self {
            Level::Scalar => false,
            #[cfg(target_arch = "x86_64")]
            Level::Avx2 => true,
            #[cfg(target_arch = "aarch64")]
            Level::Neon => matches!(fmt, HalfFormat::Bf16),
        }
    }
}

/// Detect the best available level, once per process. `LPRL_SIMD=0`
/// forces [`Level::Scalar`]. Detection never changes *results* — the
/// kernels are bitwise equal across levels — only throughput.
pub fn detect() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        if std::env::var("LPRL_SIMD").is_ok_and(|v| v == "0") {
            return Level::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("f16c") {
                return Level::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            return Level::Neon;
        }
        #[allow(unreachable_code)]
        Level::Scalar
    })
}

/// One-line description of the detected CPU features and chosen level —
/// logged by the bench smokes and the CI parity gate.
pub fn feature_summary() -> String {
    let level = detect();
    #[cfg(target_arch = "x86_64")]
    {
        format!(
            "arch=x86_64 level={} avx2={} f16c={}",
            level.name(),
            is_x86_feature_detected!("avx2"),
            is_x86_feature_detected!("f16c"),
        )
    }
    #[cfg(target_arch = "aarch64")]
    {
        format!("arch=aarch64 level={} neon=true", level.name())
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        format!("arch=other level={}", level.name())
    }
}

/// Full-tile packed-half micro-kernel:
/// `c[r][j] += Σ_p a[r][p] · widen(b[p][j])` with MR×NR independent
/// accumulator chains — dispatched by `level`/`fmt` to a vector body or
/// the scalar oracle, all bitwise identical.
// SAFETY: callers pass `a` holding kl rows of MR live columns at stride
// `a_rs`, `b` holding kl rows of NR live packed columns at stride
// `b_rs`, and `c` writable for a full MR×NR tile at row stride `c_rs`
// that this call exclusively owns.
#[allow(clippy::too_many_arguments)]
pub unsafe fn kernel_4x16_half(
    level: Level,
    fmt: HalfFormat,
    a: *const f32,
    a_rs: usize,
    b: *const u16,
    b_rs: usize,
    c: *mut f32,
    c_rs: usize,
    kl: usize,
) {
    match (level, fmt) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only produced by `detect()` after
        // runtime avx2+f16c checks; pointer contracts forwarded as-is.
        (Level::Avx2, HalfFormat::F16) => unsafe {
            x86::kernel_4x16_f16(a, a_rs, b, b_rs, c, c_rs, kl)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — avx2 verified at detection time.
        (Level::Avx2, HalfFormat::Bf16) => unsafe {
            x86::kernel_4x16_bf16(a, a_rs, b, b_rs, c, c_rs, kl)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; pointer contracts
        // forwarded as-is.
        (Level::Neon, HalfFormat::Bf16) => unsafe {
            neon::kernel_4x16_bf16(a, a_rs, b, b_rs, c, c_rs, kl)
        },
        // SAFETY: pointer contracts forwarded as-is.
        _ => unsafe { kernel_4x16_half_scalar(fmt, a, a_rs, b, b_rs, c, c_rs, kl) },
    }
}

/// Scalar oracle for the full packed-half tile — the exact structure of
/// `gemm::kernel_4x16` with a widening load on the B operand.
// SAFETY: same contract as `kernel_4x16_half`.
#[allow(clippy::too_many_arguments)]
unsafe fn kernel_4x16_half_scalar(
    fmt: HalfFormat,
    a: *const f32,
    a_rs: usize,
    b: *const u16,
    b_rs: usize,
    c: *mut f32,
    c_rs: usize,
    kl: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    // SAFETY: every offset below stays inside the MR×kl / kl×NR panels
    // and the MR×NR output tile the caller contract grants.
    unsafe {
        for p in 0..kl {
            let bp = b.add(p * b_rs);
            let a0 = *a.add(p);
            let a1 = *a.add(a_rs + p);
            let a2 = *a.add(2 * a_rs + p);
            let a3 = *a.add(3 * a_rs + p);
            for j in 0..NR {
                let bv = fmt.decode(*bp.add(j));
                acc[0][j] += a0 * bv;
                acc[1][j] += a1 * bv;
                acc[2][j] += a2 * bv;
                acc[3][j] += a3 * bv;
            }
        }
        for (r, row) in acc.iter().enumerate() {
            let cr = c.add(r * c_rs);
            for (j, &v) in row.iter().enumerate() {
                *cr.add(j) += v;
            }
        }
    }
}

/// Edge-tile packed-half kernel (`mr ≤ MR`, `nr ≤ NR`) — always scalar
/// (edge tiles are a vanishing fraction of a bandwidth-bound product),
/// with the identical ascending-`p` accumulation order.
// SAFETY: callers pass `a`/`b` panels holding kl rows of mr/nr live
// columns at their strides, and `c` writable for an mr×nr tile at row
// stride `c_rs` that this call exclusively owns.
#[allow(clippy::too_many_arguments)]
pub unsafe fn kernel_edge_half(
    fmt: HalfFormat,
    a: *const f32,
    a_rs: usize,
    b: *const u16,
    b_rs: usize,
    c: *mut f32,
    c_rs: usize,
    mr: usize,
    nr: usize,
    kl: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    // SAFETY: every offset below stays inside the mr×kl / kl×nr panels
    // and the mr×nr output tile the caller contract grants.
    unsafe {
        for p in 0..kl {
            let bp = b.add(p * b_rs);
            for r in 0..mr {
                let av = *a.add(r * a_rs + p);
                for j in 0..nr {
                    acc[r][j] += av * fmt.decode(*bp.add(j));
                }
            }
        }
        for (r, row) in acc.iter().enumerate().take(mr) {
            let cr = c.add(r * c_rs);
            for (j, &v) in row.iter().enumerate().take(nr) {
                *cr.add(j) += v;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::MR;
    use std::arch::x86_64::*;

    /// AVX2+F16C full tile, f16 weights: per `p`, two `cvtph` widening
    /// loads cover the NR=16 columns as two 8-lane vectors; each of the
    /// MR=4 rows broadcasts its `a` scalar and does a separate
    /// `mul` + `add` (no FMA — parity). Lane `j` of the accumulators is
    /// output element `c[r][j]`'s own ascending-`k` chain, bitwise equal
    /// to the scalar oracle's.
    // SAFETY: same pointer contract as `kernel_4x16_half`; callers must
    // have verified avx2+f16c at runtime.
    #[target_feature(enable = "avx2,f16c")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn kernel_4x16_f16(
        a: *const f32,
        a_rs: usize,
        b: *const u16,
        b_rs: usize,
        c: *mut f32,
        c_rs: usize,
        kl: usize,
    ) {
        // SAFETY: every pointer offset stays inside the MR×kl / kl×NR
        // panels and the MR×NR output tile the caller contract grants;
        // all loads/stores are the unaligned variants.
        unsafe {
            let mut acc = [[_mm256_setzero_ps(); 2]; MR];
            for p in 0..kl {
                let bp = b.add(p * b_rs);
                let blo = _mm256_cvtph_ps(_mm_loadu_si128(bp as *const __m128i));
                let bhi = _mm256_cvtph_ps(_mm_loadu_si128(bp.add(8) as *const __m128i));
                for r in 0..MR {
                    let av = _mm256_set1_ps(*a.add(r * a_rs + p));
                    acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(av, blo));
                    acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(av, bhi));
                }
            }
            for r in 0..MR {
                let cr = c.add(r * c_rs);
                let lo = _mm256_add_ps(_mm256_loadu_ps(cr), acc[r][0]);
                let hi = _mm256_add_ps(_mm256_loadu_ps(cr.add(8)), acc[r][1]);
                _mm256_storeu_ps(cr, lo);
                _mm256_storeu_ps(cr.add(8), hi);
            }
        }
    }

    /// AVX2 full tile, bf16 weights: widening is a zero-extend to u32
    /// and a 16-bit left shift (bf16 *is* the top half of f32), then the
    /// same per-row broadcast `mul` + `add` schedule as the f16 kernel.
    // SAFETY: same pointer contract as `kernel_4x16_half`; callers must
    // have verified avx2 at runtime.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn kernel_4x16_bf16(
        a: *const f32,
        a_rs: usize,
        b: *const u16,
        b_rs: usize,
        c: *mut f32,
        c_rs: usize,
        kl: usize,
    ) {
        // SAFETY: every pointer offset stays inside the MR×kl / kl×NR
        // panels and the MR×NR output tile the caller contract grants;
        // all loads/stores are the unaligned variants.
        unsafe {
            let widen = |ptr: *const u16| -> __m256 {
                let h = _mm_loadu_si128(ptr as *const __m128i);
                _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)))
            };
            let mut acc = [[_mm256_setzero_ps(); 2]; MR];
            for p in 0..kl {
                let bp = b.add(p * b_rs);
                let blo = widen(bp);
                let bhi = widen(bp.add(8));
                for r in 0..MR {
                    let av = _mm256_set1_ps(*a.add(r * a_rs + p));
                    acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(av, blo));
                    acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(av, bhi));
                }
            }
            for r in 0..MR {
                let cr = c.add(r * c_rs);
                let lo = _mm256_add_ps(_mm256_loadu_ps(cr), acc[r][0]);
                let hi = _mm256_add_ps(_mm256_loadu_ps(cr.add(8)), acc[r][1]);
                _mm256_storeu_ps(cr, lo);
                _mm256_storeu_ps(cr.add(8), hi);
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::MR;
    use std::arch::aarch64::*;

    /// NEON full tile, bf16 weights: NR=16 columns as four 4-lane f32
    /// vectors, widened by zero-extend + 16-bit shift; separate
    /// `vmulq`/`vaddq` per step (no `vfmaq` — parity with the scalar
    /// oracle's one-multiply-one-add chains).
    // SAFETY: same pointer contract as `kernel_4x16_half`; NEON is
    // baseline on aarch64.
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn kernel_4x16_bf16(
        a: *const f32,
        a_rs: usize,
        b: *const u16,
        b_rs: usize,
        c: *mut f32,
        c_rs: usize,
        kl: usize,
    ) {
        // SAFETY: every pointer offset stays inside the MR×kl / kl×NR
        // panels and the MR×NR output tile the caller contract grants.
        unsafe {
            let widen_pair = |h: uint16x8_t| -> (float32x4_t, float32x4_t) {
                let lo = vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vget_low_u16(h))));
                let hi = vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vget_high_u16(h))));
                (lo, hi)
            };
            let mut acc = [[vdupq_n_f32(0.0); 4]; MR];
            for p in 0..kl {
                let bp = b.add(p * b_rs);
                let (b0, b1) = widen_pair(vld1q_u16(bp));
                let (b2, b3) = widen_pair(vld1q_u16(bp.add(8)));
                let bv = [b0, b1, b2, b3];
                for r in 0..MR {
                    let av = vdupq_n_f32(*a.add(r * a_rs + p));
                    for q in 0..4 {
                        acc[r][q] = vaddq_f32(acc[r][q], vmulq_f32(av, bv[q]));
                    }
                }
            }
            for r in 0..MR {
                let cr = c.add(r * c_rs);
                for q in 0..4 {
                    let cur = vld1q_f32(cr.add(4 * q));
                    vst1q_f32(cr.add(4 * q), vaddq_f32(cur, acc[r][q]));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Pcg64;

    /// Drive the full-tile kernel at `level` over a kl-deep panel.
    fn run_tile(level: Level, fmt: HalfFormat, kl: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed(seed);
        let a: Vec<f32> = (0..MR * kl).map(|_| rng.normal_f32()).collect();
        let b: Vec<u16> = (0..kl * NR).map(|_| fmt.encode(rng.normal_f32())).collect();
        let mut c: Vec<f32> = (0..MR * NR).map(|_| rng.normal_f32()).collect();
        // SAFETY: a is [MR, kl] at stride kl, b is [kl, NR] at stride
        // NR, and c is an exclusively-owned MR×NR tile at stride NR.
        unsafe {
            kernel_4x16_half(level, fmt, a.as_ptr(), kl, b.as_ptr(), NR, c.as_mut_ptr(), NR, kl);
        }
        c
    }

    #[test]
    fn detected_level_matches_scalar_oracle_bitwise() {
        let level = detect();
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            for kl in [0, 1, 3, 17, 256] {
                let fast = run_tile(level, fmt, kl, 7 + kl as u64);
                let slow = run_tile(Level::Scalar, fmt, kl, 7 + kl as u64);
                assert!(
                    fast.iter().zip(&slow).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} {} kl={kl}: vector tile must equal the scalar oracle",
                    level.name(),
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn detection_is_stable() {
        assert_eq!(detect(), detect());
        let s = feature_summary();
        assert!(s.contains("level="), "{s}");
    }
}
