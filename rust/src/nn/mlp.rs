//! Multi-layer perceptron trunk: `Linear → ReLU → ... → Linear`.
//!
//! The SAC actor and critic of Yarats & Kostrikov (2020) are MLPs with
//! hidden depth 2; the output layer is linear (no activation). All layer
//! math routes through the blocked [`super::gemm`] backend via
//! [`Linear`], including its fused bias+quantize epilogue.
//!
//! Like [`Linear`], the trunk's `forward` is `&self` (inference,
//! shareable across threads); training caches live in an explicit
//! [`MlpWorkspace`].

use super::activations::{relu, relu_backward, relu_backward_in_place, relu_into};
use super::linear::{Linear, LinearWorkspace};
use super::param::Param;
use super::tensor::Tensor;
use crate::lowp::{HalfFormat, Precision};
use crate::rngs::Pcg64;

/// Training-time caches for one [`Mlp`]: per-layer [`LinearWorkspace`]s
/// plus the pre-activation inputs each hidden ReLU needs for backward.
/// The `act` slots hold the post-ReLU activations and `grad_a`/`grad_b`
/// ping-pong the backward gradient, so the `_into` walks reuse every
/// buffer across steps (zero steady-state allocations).
#[derive(Debug, Clone, Default)]
pub struct MlpWorkspace {
    layers: Vec<LinearWorkspace>,
    pre_relu: Vec<Tensor>,
    act: Vec<Tensor>,
    grad_a: Tensor,
    grad_b: Tensor,
}

impl MlpWorkspace {
    /// Size the per-layer slot vectors for an `n`-layer trunk. The slots
    /// themselves are grown lazily by `ensure_shape` inside the walks.
    fn ensure(&mut self, n: usize) {
        self.layers.resize_with(n, LinearWorkspace::default);
        self.pre_relu.resize_with(n.saturating_sub(1), Tensor::default);
        self.act.resize_with(n.saturating_sub(1), Tensor::default);
    }
}

/// An MLP with ReLU between layers and a linear head.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub layers: Vec<Linear>,
}

impl Mlp {
    /// `dims = [in, h1, h2, ..., out]`.
    pub fn new(name: &str, dims: &[usize], rng: &mut Pcg64) -> Self {
        assert!(dims.len() >= 2);
        let layers = (0..dims.len() - 1)
            .map(|i| Linear::new(&format!("{name}.{i}"), dims[i], dims[i + 1], rng))
            .collect();
        Mlp { layers }
    }

    /// Inference forward: `&self`, no caches. Bitwise identical to
    /// [`Mlp::forward_train`]. The input feeds the first layer directly
    /// (no staging clone).
    pub fn forward(&self, x: &Tensor, prec: Precision) -> Tensor {
        // allocating walk for cold/shared-`&self` callers — the learner
        // hot path uses `forward_into` (the allocations live inside the
        // individually-allowed `relu`/`Linear::forward` wrappers)
        let n = self.layers.len();
        let mut h = self.layers[0].forward(x, prec);
        for layer in &self.layers[1..n] {
            let a = relu(&h, prec);
            h = layer.forward(&a, prec);
        }
        h
    }

    /// Allocation-free twin of [`Mlp::forward`]: hidden activations go
    /// through the workspace slots and the head writes into `out`, all
    /// reused whenever the shapes repeat. Bitwise identical.
    pub fn forward_into(&self, x: &Tensor, prec: Precision, ws: &mut MlpWorkspace, out: &mut Tensor) {
        let n = self.layers.len();
        ws.ensure(n);
        if n == 1 {
            self.layers[0].forward_into(x, prec, out);
            return;
        }
        self.layers[0].forward_into(x, prec, &mut ws.pre_relu[0]);
        for i in 1..n {
            relu_into(&ws.pre_relu[i - 1], prec, &mut ws.act[i - 1]);
            if i == n - 1 {
                self.layers[i].forward_into(&ws.act[i - 1], prec, out);
            } else {
                self.layers[i].forward_into(&ws.act[i - 1], prec, &mut ws.pre_relu[i]);
            }
        }
    }

    /// Training forward: caches activations into `ws` for
    /// [`Mlp::backward`]. Bitwise identical to [`Mlp::forward`].
    pub fn forward_train(&self, x: &Tensor, prec: Precision, ws: &mut MlpWorkspace) -> Tensor {
        let mut y = Tensor::default();
        self.forward_train_into(x, prec, ws, &mut y);
        y
    }

    /// Allocation-free twin of [`Mlp::forward_train`]: the pre-ReLU
    /// caches, hidden activations, and the head output all reuse their
    /// buffers whenever the shapes repeat.
    pub fn forward_train_into(
        &self,
        x: &Tensor,
        prec: Precision,
        ws: &mut MlpWorkspace,
        out: &mut Tensor,
    ) {
        let n = self.layers.len();
        ws.ensure(n);
        if n == 1 {
            self.layers[0].forward_train_into(x, prec, &mut ws.layers[0], out);
            return;
        }
        {
            let (ws0, pre0) = (&mut ws.layers[0], &mut ws.pre_relu[0]);
            self.layers[0].forward_train_into(x, prec, ws0, pre0);
        }
        for i in 1..n {
            relu_into(&ws.pre_relu[i - 1], prec, &mut ws.act[i - 1]);
            if i == n - 1 {
                let (lws, a) = (&mut ws.layers[i], &ws.act[i - 1]);
                self.layers[i].forward_train_into(a, prec, lws, out);
            } else {
                let MlpWorkspace { layers, pre_relu, act, .. } = ws;
                self.layers[i].forward_train_into(&act[i - 1], prec, &mut layers[i], &mut pre_relu[i]);
            }
        }
    }

    /// Inference forwards of two same-architecture trunks walked in
    /// lockstep, each layer pair fused into one pool dispatch via
    /// [`Linear::forward_pair`] (the twin-critic fast path). Per-trunk
    /// outputs are bitwise identical to two [`Mlp::forward`] calls; any
    /// layer pair that cannot share a dispatch falls back to sequential
    /// inside [`Linear::forward_pair`].
    pub fn forward_pair(m1: &Mlp, m2: &Mlp, x: &Tensor, prec: Precision) -> (Tensor, Tensor) {
        // allocating walk for cold callers — the learner hot path uses
        // `forward_pair_into` / `forward_train_pair_into`
        if m1.layers.len() != m2.layers.len() {
            return (m1.forward(x, prec), m2.forward(x, prec));
        }
        let n = m1.layers.len();
        let (mut h1, mut h2) = Linear::forward_pair(&m1.layers[0], &m2.layers[0], x, x, prec);
        for (l1, l2) in m1.layers[1..n].iter().zip(&m2.layers[1..n]) {
            let a1 = relu(&h1, prec);
            let a2 = relu(&h2, prec);
            (h1, h2) = Linear::forward_pair(l1, l2, &a1, &a2, prec);
        }
        (h1, h2)
    }

    /// Allocation-free twin of [`Mlp::forward_pair`]: the hidden
    /// activations go through each trunk's workspace slots and the head
    /// outputs land in `y1`/`y2`, all reused whenever the shapes repeat.
    /// Bitwise identical.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_pair_into(
        m1: &Mlp,
        m2: &Mlp,
        x: &Tensor,
        prec: Precision,
        ws1: &mut MlpWorkspace,
        ws2: &mut MlpWorkspace,
        y1: &mut Tensor,
        y2: &mut Tensor,
    ) {
        if m1.layers.len() != m2.layers.len() {
            m1.forward_into(x, prec, ws1, y1);
            m2.forward_into(x, prec, ws2, y2);
            return;
        }
        let n = m1.layers.len();
        ws1.ensure(n);
        ws2.ensure(n);
        if n == 1 {
            Linear::forward_pair_into(&m1.layers[0], &m2.layers[0], x, x, prec, y1, y2);
            return;
        }
        Linear::forward_pair_into(
            &m1.layers[0],
            &m2.layers[0],
            x,
            x,
            prec,
            &mut ws1.pre_relu[0],
            &mut ws2.pre_relu[0],
        );
        for i in 1..n {
            relu_into(&ws1.pre_relu[i - 1], prec, &mut ws1.act[i - 1]);
            relu_into(&ws2.pre_relu[i - 1], prec, &mut ws2.act[i - 1]);
            if i == n - 1 {
                Linear::forward_pair_into(
                    &m1.layers[i],
                    &m2.layers[i],
                    &ws1.act[i - 1],
                    &ws2.act[i - 1],
                    prec,
                    y1,
                    y2,
                );
            } else {
                let MlpWorkspace { pre_relu: pa, act: aa, .. } = ws1;
                let MlpWorkspace { pre_relu: pb, act: ab, .. } = ws2;
                Linear::forward_pair_into(
                    &m1.layers[i],
                    &m2.layers[i],
                    &aa[i - 1],
                    &ab[i - 1],
                    prec,
                    &mut pa[i],
                    &mut pb[i],
                );
            }
        }
    }

    /// Training twin of [`Mlp::forward_pair`]: fills each trunk's
    /// workspace exactly as [`Mlp::forward_train`] would.
    pub fn forward_train_pair(
        m1: &Mlp,
        m2: &Mlp,
        x: &Tensor,
        prec: Precision,
        ws1: &mut MlpWorkspace,
        ws2: &mut MlpWorkspace,
    ) -> (Tensor, Tensor) {
        let (mut y1, mut y2) = (Tensor::default(), Tensor::default());
        Self::forward_train_pair_into(m1, m2, x, prec, ws1, ws2, &mut y1, &mut y2);
        (y1, y2)
    }

    /// Allocation-free twin of [`Mlp::forward_train_pair`]: both trunks'
    /// caches, hidden activations, and head outputs reuse their buffers
    /// whenever the shapes repeat.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_train_pair_into(
        m1: &Mlp,
        m2: &Mlp,
        x: &Tensor,
        prec: Precision,
        ws1: &mut MlpWorkspace,
        ws2: &mut MlpWorkspace,
        y1: &mut Tensor,
        y2: &mut Tensor,
    ) {
        if m1.layers.len() != m2.layers.len() {
            m1.forward_train_into(x, prec, ws1, y1);
            m2.forward_train_into(x, prec, ws2, y2);
            return;
        }
        let n = m1.layers.len();
        ws1.ensure(n);
        ws2.ensure(n);
        if n == 1 {
            Linear::forward_train_pair_into(
                &m1.layers[0],
                &m2.layers[0],
                x,
                x,
                prec,
                &mut ws1.layers[0],
                &mut ws2.layers[0],
                y1,
                y2,
            );
            return;
        }
        {
            let MlpWorkspace { layers: la, pre_relu: pa, .. } = ws1;
            let MlpWorkspace { layers: lb, pre_relu: pb, .. } = ws2;
            Linear::forward_train_pair_into(
                &m1.layers[0],
                &m2.layers[0],
                x,
                x,
                prec,
                &mut la[0],
                &mut lb[0],
                &mut pa[0],
                &mut pb[0],
            );
        }
        for i in 1..n {
            relu_into(&ws1.pre_relu[i - 1], prec, &mut ws1.act[i - 1]);
            relu_into(&ws2.pre_relu[i - 1], prec, &mut ws2.act[i - 1]);
            if i == n - 1 {
                let MlpWorkspace { layers: la, act: aa, .. } = ws1;
                let MlpWorkspace { layers: lb, act: ab, .. } = ws2;
                Linear::forward_train_pair_into(
                    &m1.layers[i],
                    &m2.layers[i],
                    &aa[i - 1],
                    &ab[i - 1],
                    prec,
                    &mut la[i],
                    &mut lb[i],
                    y1,
                    y2,
                );
            } else {
                let MlpWorkspace { layers: la, pre_relu: pa, act: aa, .. } = ws1;
                let MlpWorkspace { layers: lb, pre_relu: pb, act: ab, .. } = ws2;
                Linear::forward_train_pair_into(
                    &m1.layers[i],
                    &m2.layers[i],
                    &aa[i - 1],
                    &ab[i - 1],
                    prec,
                    &mut la[i],
                    &mut lb[i],
                    &mut pa[i],
                    &mut pb[i],
                );
            }
        }
    }

    /// Backward from `dy` at the head, through the workspace filled by
    /// the matching `forward_train`; returns the gradient w.r.t. the
    /// input.
    pub fn backward(&mut self, dy: &Tensor, prec: Precision, ws: &MlpWorkspace) -> Tensor {
        // allocating walk for tests/cold callers — the learner hot path
        // uses `backward_into` (ping-pong workspace buffers)
        let n = self.layers.len();
        assert_eq!(ws.layers.len(), n, "forward_train workspace missing");
        // tidy-allow(alloc): allocating wrapper; hot callers use backward_into
        let mut g = dy.clone();
        for i in (0..n).rev() {
            g = self.layers[i].backward(&g, prec, &ws.layers[i]);
            if i > 0 {
                g = relu_backward(&g, &ws.pre_relu[i - 1], prec);
            }
        }
        g
    }

    /// Allocation-free twin of [`Mlp::backward`]: the gradient ping-pongs
    /// between two workspace buffers (hidden ReLU masks are applied in
    /// place) and the input gradient lands in `dx`. Bitwise identical —
    /// same per-layer ops in the same order.
    pub fn backward_into(
        &mut self,
        dy: &Tensor,
        prec: Precision,
        ws: &mut MlpWorkspace,
        dx: &mut Tensor,
    ) {
        let n = self.layers.len();
        assert_eq!(ws.layers.len(), n, "forward_train workspace missing");
        if n == 1 {
            self.layers[0].backward_into(dy, prec, &mut ws.layers[0], dx);
            return;
        }
        {
            let MlpWorkspace { layers, pre_relu, grad_a, .. } = ws;
            self.layers[n - 1].backward_into(dy, prec, &mut layers[n - 1], grad_a);
            relu_backward_in_place(grad_a, &pre_relu[n - 2], prec);
        }
        for i in (1..n - 1).rev() {
            {
                let MlpWorkspace { layers, pre_relu, grad_a, grad_b, .. } = ws;
                self.layers[i].backward_into(grad_a, prec, &mut layers[i], grad_b);
                relu_backward_in_place(grad_b, &pre_relu[i - 1], prec);
            }
            std::mem::swap(&mut ws.grad_a, &mut ws.grad_b);
        }
        let MlpWorkspace { layers, grad_a, .. } = ws;
        self.layers[0].backward_into(grad_a, prec, &mut layers[0], dx);
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        self.layers.iter_mut().flat_map(|l| l.params_mut()).collect()
    }

    /// Visit the parameters in [`Mlp::params_mut`] order without
    /// materializing a `Vec`.
    pub fn for_each_param(&self, f: &mut impl FnMut(&Param)) {
        for l in &self.layers {
            l.for_each_param(f);
        }
    }

    /// Mutable twin of [`Mlp::for_each_param`], same order.
    pub fn for_each_param_mut(&mut self, f: &mut impl FnMut(&mut Param)) {
        for l in self.layers.iter_mut() {
            l.for_each_param_mut(f);
        }
    }

    pub fn zero_grad(&mut self) {
        for l in self.layers.iter_mut() {
            l.zero_grad();
        }
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_params()).sum()
    }

    /// Quantize all parameters (entering a low-precision run).
    pub fn quantize_params(&mut self, prec: Precision) {
        for l in self.layers.iter_mut() {
            l.w.quantize(prec);
            l.b.quantize(prec);
        }
    }

    /// Pack every layer's weights into 16-bit storage
    /// ([`Linear::pack_weights`] — quantize-mirrors the masters).
    pub fn pack_weights(&mut self, fmt: HalfFormat) {
        for l in self.layers.iter_mut() {
            l.pack_weights(fmt);
        }
    }

    /// Drop every layer's f32 weight master ([`Linear::drop_master`]) —
    /// frozen-snapshot tier only.
    pub fn drop_masters(&mut self) {
        for l in self.layers.iter_mut() {
            l.drop_master();
        }
    }

    /// Refresh every packed mirror from its master, allocation-free
    /// ([`Linear::repack_weights`]).
    pub fn repack_weights(&mut self) {
        for l in self.layers.iter_mut() {
            l.repack_weights();
        }
    }

    /// Resident weight bytes across storage tiers.
    pub fn weight_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.weight_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_compose() {
        let mut rng = Pcg64::seed(1);
        let mlp = Mlp::new("m", &[10, 32, 32, 4], &mut rng);
        let x = Tensor::from_vec(&[3, 10], (0..30).map(|_| rng.normal_f32()).collect());
        let y = mlp.forward(&x, Precision::Fp32);
        assert_eq!(y.shape, vec![3, 4]);
        assert_eq!(mlp.n_params(), 10 * 32 + 32 + 32 * 32 + 32 + 32 * 4 + 4);
    }

    #[test]
    fn gradcheck_through_two_hidden_layers() {
        let mut rng = Pcg64::seed(2);
        let mut mlp = Mlp::new("m", &[4, 8, 8, 2], &mut rng);
        let x = Tensor::from_vec(&[2, 4], (0..8).map(|_| rng.normal_f32()).collect());
        let prec = Precision::Fp32;
        let mut ws = MlpWorkspace::default();
        let y = mlp.forward_train(&x, prec, &mut ws);
        mlp.zero_grad();
        let dx = mlp.backward(&y.clone(), prec, &ws);

        let eps = 1e-3f32;
        let loss = |m: &Mlp, x: &Tensor| -> f32 {
            m.forward(x, prec).data.iter().map(|v| v * v / 2.0).sum()
        };
        let mut x2 = x.clone();
        for idx in 0..8 {
            let o = x2.data[idx];
            x2.data[idx] = o + eps;
            let lp = loss(&mlp, &x2);
            x2.data[idx] = o - eps;
            let lm = loss(&mlp, &x2);
            x2.data[idx] = o;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - dx.data[idx]).abs() < 2e-2 * (1.0 + num.abs()), "x[{idx}]");
        }
        // spot-check a weight in the middle layer
        mlp.zero_grad();
        let y2 = mlp.forward_train(&x, prec, &mut ws);
        let _ = mlp.backward(&y2.clone(), prec, &ws);
        let g = mlp.layers[1].w.g[5];
        let orig = mlp.layers[1].w.w[5];
        mlp.layers[1].w.w[5] = orig + eps;
        let lp = loss(&mlp, &x);
        mlp.layers[1].w.w[5] = orig - eps;
        let lm = loss(&mlp, &x);
        mlp.layers[1].w.w[5] = orig;
        let num = (lp - lm) / (2.0 * eps);
        assert!((num - g).abs() < 2e-2 * (1.0 + num.abs()), "{num} vs {g}");
    }

    #[test]
    fn fp16_params_quantize() {
        let mut rng = Pcg64::seed(3);
        let mut mlp = Mlp::new("m", &[4, 8, 2], &mut rng);
        mlp.quantize_params(Precision::fp16());
        for l in &mlp.layers {
            for &v in &l.w.w {
                assert!(crate::lowp::FP16.is_representable(v));
            }
        }
    }

    #[test]
    fn pair_walk_matches_sequential_bitwise() {
        let mut rng = Pcg64::seed(5);
        let m1 = Mlp::new("q1", &[7, 24, 24, 1], &mut rng);
        let m2 = Mlp::new("q2", &[7, 24, 24, 1], &mut rng);
        let x = Tensor::from_vec(&[6, 7], (0..42).map(|_| rng.normal_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let s1 = m1.forward(&x, prec);
            let s2 = m2.forward(&x, prec);
            let (y1, y2) = Mlp::forward_pair(&m1, &m2, &x, prec);
            assert!(y1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            assert!(y2.data.iter().zip(&s2.data).all(|(u, v)| u.to_bits() == v.to_bits()));

            let (mut wa, mut wb) = (MlpWorkspace::default(), MlpWorkspace::default());
            let (t1, t2) = Mlp::forward_train_pair(&m1, &m2, &x, prec, &mut wa, &mut wb);
            assert!(t1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            assert!(t2.data.iter().zip(&s2.data).all(|(u, v)| u.to_bits() == v.to_bits()));

            // the cached workspaces must match what forward_train fills,
            // so the existing backward path stays valid after a pair walk
            let (mut ra, mut rb) = (MlpWorkspace::default(), MlpWorkspace::default());
            let _ = m1.forward_train(&x, prec, &mut ra);
            let _ = m2.forward_train(&x, prec, &mut rb);
            for (w, r) in wa.pre_relu.iter().zip(&ra.pre_relu) {
                assert!(w.data.iter().zip(&r.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            }
            for (w, r) in wb.pre_relu.iter().zip(&rb.pre_relu) {
                assert!(w.data.iter().zip(&r.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            }
        }
    }

    #[test]
    fn packed_trunk_matches_master_and_halves_weight_bytes() {
        let mut rng = Pcg64::seed(6);
        let mut mlp = Mlp::new("m", &[7, 24, 24, 3], &mut rng);
        // fp16-representable params make the f16 pack lossless — the
        // packed trunk must then be bitwise identical to the master
        mlp.quantize_params(Precision::fp16());
        let x = Tensor::from_vec(&[5, 7], (0..35).map(|_| rng.normal_f32()).collect());
        let base = mlp.forward(&x, Precision::fp16());
        let mut packed = mlp.clone();
        packed.pack_weights(HalfFormat::F16);
        let y = packed.forward(&x, Precision::fp16());
        assert!(y.data.iter().zip(&base.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        packed.drop_masters();
        let y2 = packed.forward(&x, Precision::fp16());
        assert!(y2.data.iter().zip(&base.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        let w_elems: usize = mlp.layers.iter().map(|l| l.w.w.len()).sum();
        assert_eq!(
            packed.weight_bytes() + 2 * w_elems,
            mlp.weight_bytes(),
            "dropping the masters must halve the weight payload"
        );
    }

    #[test]
    fn inference_and_train_forward_agree_bitwise() {
        let mut rng = Pcg64::seed(4);
        let mlp = Mlp::new("m", &[6, 16, 16, 3], &mut rng);
        let x = Tensor::from_vec(&[5, 6], (0..30).map(|_| rng.normal_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let mut ws = MlpWorkspace::default();
            let a = mlp.forward(&x, prec);
            let b = mlp.forward_train(&x, prec, &mut ws);
            assert!(a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
    }
}
