//! Native neural-network engine: tensors, layers with explicit
//! forward/backward, all routed through a [`crate::lowp::Precision`]
//! policy.
//!
//! ## Simulation semantics
//!
//! Quantization is applied at **tensor granularity**: an op computes in
//! f32 and its *output tensor* is rounded into the target format. This is
//! the same model as qtorch (which the paper uses for Figure 4) and as
//! V100 fp16 hardware for GEMMs (tensor cores accumulate partial products
//! in f32 and store fp16 results). Elementwise trouble spots the paper
//! targets (squares in Adam and layer-norm, log-prob intermediates,
//! EMA increments) are quantized at the granularity where the paper
//! observed the failure — see the respective modules.
//!
//! The engine is deliberately dependency-free and deterministic; the same
//! SAC computation is also AOT-lowered from JAX (L2) and the two are
//! cross-validated in `rust/tests/artifact_parity.rs`.
//!
//! ## Train/inference split
//!
//! Every layer's `forward` is `&self` and cache-free, so a frozen layer
//! (or a whole [`crate::sac::Policy`] snapshot) is `Send + Sync` and can
//! serve many threads at once. Training uses `forward_train`, which
//! writes the activation caches the explicit `backward` needs into a
//! caller-owned `*Workspace` ([`LinearWorkspace`], [`MlpWorkspace`],
//! [`Conv2dWorkspace`], [`LayerNormWorkspace`]); both paths produce
//! bitwise-identical outputs.

mod activations;
mod conv;
pub mod gemm;
mod init;
mod layernorm;
mod linear;
mod memory;
mod mlp;
mod param;
pub mod pool;
pub mod simd;
mod tensor;

pub use activations::{
    relu, relu_backward, relu_backward_in_place, relu_into, tanh_backward, tanh_forward,
};
pub use conv::{Conv2d, Conv2dWorkspace};
pub use gemm::{
    gemm, gemm_bias_q, gemm_nt, gemm_nt_bias_q, gemm_nt_bias_q_half, gemm_nt_bias_q_half_at,
    gemm_nt_bias_q_pair, gemm_nt_bias_q_pair_half, gemm_tn, gemm_tn_bias_q,
};
pub use init::{orthogonal_init, uniform_fan_in};
pub use layernorm::{LayerNorm, LayerNormWorkspace};
pub use linear::{Linear, LinearWorkspace};
pub use memory::{pixels_model, states_model, MemoryModel};
pub use mlp::{Mlp, MlpWorkspace};
pub use param::Param;
pub use tensor::Tensor;
