//! Weight initializers.
//!
//! The reference SAC codebase (Yarats & Kostrikov, 2020) uses orthogonal
//! initialization for every linear layer; convolutions use the same
//! scheme applied to the flattened (out, in·kh·kw) matrix.

use crate::rngs::Pcg64;

/// Orthogonal initialization with gain: fill a `[rows, cols]` matrix with
/// a (semi-)orthogonal matrix scaled by `gain`. Implemented as modified
/// Gram–Schmidt on a Gaussian matrix — plenty for the layer sizes here.
pub fn orthogonal_init(rng: &mut Pcg64, rows: usize, cols: usize, gain: f32) -> Vec<f32> {
    // Work with the wide orientation so rows are orthonormalizable.
    let (r, c, transpose) = if rows <= cols { (rows, cols, false) } else { (cols, rows, true) };
    let mut m: Vec<f32> = (0..r * c).map(|_| rng.normal_f32()).collect();
    for i in 0..r {
        // subtract projections onto previous rows
        for j in 0..i {
            let mut dot = 0.0f64;
            for k in 0..c {
                dot += m[i * c + k] as f64 * m[j * c + k] as f64;
            }
            for k in 0..c {
                m[i * c + k] -= (dot as f32) * m[j * c + k];
            }
        }
        let norm = (0..c).map(|k| (m[i * c + k] as f64).powi(2)).sum::<f64>().sqrt();
        let inv = if norm > 1e-12 { 1.0 / norm as f32 } else { 0.0 };
        for k in 0..c {
            m[i * c + k] *= inv * gain;
        }
    }
    if !transpose {
        m
    } else {
        let mut out = vec![0.0; rows * cols];
        for i in 0..r {
            for k in 0..c {
                out[k * cols + i] = m[i * c + k];
            }
        }
        out
    }
}

/// PyTorch default `Linear` init: U(-1/√fan_in, 1/√fan_in).
pub fn uniform_fan_in(rng: &mut Pcg64, fan_in: usize, n: usize) -> Vec<f32> {
    let bound = 1.0 / (fan_in as f32).sqrt();
    (0..n).map(|_| rng.uniform_in(-bound, bound)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row_dot(m: &[f32], c: usize, i: usize, j: usize) -> f64 {
        (0..c).map(|k| m[i * c + k] as f64 * m[j * c + k] as f64).sum()
    }

    #[test]
    fn orthogonal_rows_are_orthonormal_wide() {
        let mut rng = Pcg64::seed(1);
        let (r, c) = (8, 32);
        let m = orthogonal_init(&mut rng, r, c, 1.0);
        for i in 0..r {
            for j in 0..r {
                let d = row_dot(&m, c, i, j);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-4, "({i},{j}) dot={d}");
            }
        }
    }

    #[test]
    fn orthogonal_cols_are_orthonormal_tall() {
        let mut rng = Pcg64::seed(2);
        let (r, c) = (32, 8);
        let m = orthogonal_init(&mut rng, r, c, 1.0);
        // columns orthonormal
        for i in 0..c {
            for j in 0..c {
                let mut d = 0.0f64;
                for k in 0..r {
                    d += m[k * c + i] as f64 * m[k * c + j] as f64;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-4, "({i},{j}) dot={d}");
            }
        }
    }

    #[test]
    fn gain_scales_norms() {
        let mut rng = Pcg64::seed(3);
        let m = orthogonal_init(&mut rng, 4, 16, 2.0);
        let d = row_dot(&m, 16, 0, 0);
        assert!((d - 4.0).abs() < 1e-3, "norm²={d}");
    }

    #[test]
    fn uniform_fan_in_bounds() {
        let mut rng = Pcg64::seed(4);
        let v = uniform_fan_in(&mut rng, 100, 10_000);
        let bound = 0.1;
        assert!(v.iter().all(|x| x.abs() <= bound));
        let frac_outer = v.iter().filter(|x| x.abs() > bound * 0.5).count() as f64 / v.len() as f64;
        assert!((frac_outer - 0.5).abs() < 0.05);
    }
}
