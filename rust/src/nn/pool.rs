//! Persistent worker pool for the GEMM backend.
//!
//! The seed engine spawned a fresh `std::thread::scope` for every GEMM
//! call (`par_rows`), which costs one spawn+join per thread per call —
//! measurable at SAC minibatch sizes where a training step issues dozens
//! of GEMMs. This pool spawns its workers **once** (first use) and reuses
//! them for every subsequent call.
//!
//! Design:
//! * One job at a time. [`ThreadPool::run`] publishes a job (a task count
//!   plus a `Fn(usize)` body), wakes the workers, participates in the
//!   work itself, and returns only when every task index has finished —
//!   which is what makes the lifetime-erased closure pointer sound.
//! * Tasks are claimed with an atomic counter, so scheduling is dynamic,
//!   but *what* each task computes is a pure function of its index —
//!   results are bitwise identical for any worker count (including the
//!   serial fallback).
//! * If a second thread calls [`ThreadPool::run`] while a job is active
//!   (e.g. `run_many` training several agents in parallel), it simply
//!   runs its own tasks inline instead of queueing — no blocking, no
//!   nested-parallelism deadlock, same results.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A published job: a lifetime-erased task body plus claim/finish counters.
struct Job {
    /// Borrow of the caller's closure, valid until `completed == total`
    /// (the submitter blocks in [`ThreadPool::run`] until then).
    f: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    completed: AtomicUsize,
    total: usize,
    /// Set when any task body panicked; the submitter re-raises after
    /// every task has been accounted for.
    poisoned: AtomicBool,
}

// Safety: `f` points at a `Sync` closure that outlives every dereference
// (the submitting thread waits for `completed == total` before returning),
// and the counters are atomics.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and run tasks until none are left; notify the submitter when
    /// the last task finishes.
    ///
    /// Task panics are caught at the boundary so a claimed task always
    /// increments `completed` — otherwise a panicking worker would leave
    /// the submitter waiting forever, and a panicking submitter would
    /// unwind (freeing the closure and output buffers) while workers
    /// still execute through the raw pointer. The panic is re-raised on
    /// the submitting thread once the job is fully drained.
    fn run(&self, shared: &Shared) {
        loop {
            let t = self.next.fetch_add(1, Ordering::Relaxed);
            if t >= self.total {
                return;
            }
            let f = unsafe { &*self.f };
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(t))).is_err() {
                self.poisoned.store(true, Ordering::Release);
            }
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
                // take the lock so the submitter cannot miss the wakeup
                let _g = shared.done_mx.lock().unwrap();
                shared.done_cv.notify_all();
            }
        }
    }
}

struct Shared {
    job: Mutex<Option<Arc<Job>>>,
    work_cv: Condvar,
    done_mx: Mutex<()>,
    done_cv: Condvar,
}

/// A fixed set of worker threads executing one indexed job at a time.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Number of background workers (the submitter is an extra worker).
    pub workers: usize,
    submit: Mutex<()>,
}

impl ThreadPool {
    /// Pool with `threads` total lanes (`threads - 1` background workers;
    /// the submitting thread is the last lane).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            job: Mutex::new(None),
            work_cv: Condvar::new(),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        let workers = threads.saturating_sub(1);
        for i in 0..workers {
            let sh = shared.clone();
            std::thread::Builder::new()
                .name(format!("lprl-gemm-{i}"))
                .spawn(move || worker_loop(sh))
                .expect("spawning pool worker");
        }
        ThreadPool { shared, workers, submit: Mutex::new(()) }
    }

    /// Run `f(0..total)` across the pool; returns when all tasks finished.
    ///
    /// Falls back to inline serial execution when the pool has no
    /// workers, the job is trivial, or another job is already running —
    /// all three paths execute the identical per-task code, so the output
    /// is bitwise independent of which path was taken.
    pub fn run(&self, total: usize, f: impl Fn(usize) + Sync) {
        if total == 0 {
            return;
        }
        if self.workers == 0 || total == 1 {
            for t in 0..total {
                f(t);
            }
            return;
        }
        let guard = match self.submit.try_lock() {
            Ok(g) => g,
            Err(_) => {
                // pool busy (another training thread): run inline
                for t in 0..total {
                    f(t);
                }
                return;
            }
        };
        let fat: &(dyn Fn(usize) + Sync) = &f;
        // Safety: erase the borrow's lifetime; `run` does not return until
        // every task completed, so workers never touch `f` after it dies.
        let fat: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(fat) };
        let job = Arc::new(Job {
            f: fat,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            total,
            poisoned: AtomicBool::new(false),
        });
        {
            let mut g = self.shared.job.lock().unwrap();
            *g = Some(job.clone());
            self.shared.work_cv.notify_all();
        }
        // participate instead of just waiting
        job.run(&self.shared);
        let mut g = self.shared.done_mx.lock().unwrap();
        while job.completed.load(Ordering::Acquire) < total {
            g = self.shared.done_cv.wait(g).unwrap();
        }
        drop(g);
        *self.shared.job.lock().unwrap() = None;
        drop(guard);
        if job.poisoned.load(Ordering::Acquire) {
            // the original message + backtrace were already printed by
            // the panicking thread's hook
            panic!("a thread-pool task panicked (see output above)");
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut g = shared.job.lock().unwrap();
            loop {
                if let Some(j) = g.as_ref() {
                    if j.next.load(Ordering::Relaxed) < j.total {
                        break j.clone();
                    }
                }
                g = shared.work_cv.wait(g).unwrap();
            }
        };
        job.run(&shared);
    }
}

/// Total parallel lanes: `LPRL_THREADS` env override, else host
/// parallelism capped at 16 (same cap the seed engine used).
fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LPRL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// The process-wide pool, spawned on first use and reused forever.
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        for total in [0usize, 1, 2, 7, 64, 1000] {
            let hits: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
            pool.run(total, |t| {
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "total={total}");
        }
    }

    #[test]
    fn pool_is_reusable_many_times() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        for _ in 0..200 {
            pool.run(17, |t| {
                sum.fetch_add(t as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 200 * (16 * 17 / 2));
    }

    #[test]
    fn zero_worker_pool_runs_serially() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.workers, 0);
        let sum = AtomicU64::new(0);
        pool.run(10, |t| {
            sum.fetch_add(t as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 55);
    }

    #[test]
    fn concurrent_submitters_fall_back_inline() {
        // two threads hammer the same pool; the busy one must run inline
        // rather than deadlock, and both must complete all tasks.
        let pool = ThreadPool::new(4);
        let sum = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        pool.run(33, |t| {
                            sum.fetch_add(t as u64, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4 * 50 * (32 * 33 / 2));
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(64, |t| {
                if t == 13 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "submitter must re-raise the task panic");
        // the pool must remain fully usable afterwards
        let sum = AtomicU64::new(0);
        pool.run(16, |t| {
            sum.fetch_add(t as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 120);
    }

    #[test]
    fn global_pool_exists() {
        let p = global();
        let sum = AtomicU64::new(0);
        p.run(8, |t| {
            sum.fetch_add(t as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 28);
    }
}
