//! Persistent worker pool for the GEMM backend and the vectorized
//! env-stepping collector.
//!
//! The seed engine spawned a fresh `std::thread::scope` for every GEMM
//! call (`par_rows`), which costs one spawn+join per thread per call —
//! measurable at SAC minibatch sizes where a training step issues dozens
//! of GEMMs. This pool spawns its workers **once** (first use) and reuses
//! them for every subsequent call.
//!
//! Design:
//! * One job at a time. [`ThreadPool::run`] publishes a job (a task count
//!   plus a `Fn(usize)` body), wakes the workers, participates in the
//!   work itself, and returns only when every task index has finished
//!   *and* every worker has left the claim loop — which is what makes
//!   the lifetime-erased closure pointer sound. The job descriptor
//!   lives inline in the shared state (`Copy`, no `Arc`), so
//!   publishing a job performs **zero allocations** — the learner's
//!   counting-allocator gate covers every GEMM dispatch.
//! * Tasks are claimed with an atomic counter, so scheduling is dynamic,
//!   but *what* each task computes is a pure function of its index —
//!   results are bitwise identical for any worker count (including the
//!   serial fallback).
//! * Claiming is **chunked** ([`ThreadPool::run_chunked`]): workers claim
//!   `grain` consecutive indices per atomic RMW, so jobs made of many
//!   tiny tasks (per-env physics stepping, thin GEMM rows) don't pay one
//!   contended `fetch_add` per index. `run` is the `grain = 1` special
//!   case. Chunking only changes how indices are *batched onto* workers,
//!   never what an index computes, so the thread-count/grain bitwise
//!   invariance is preserved.
//! * If a second thread calls [`ThreadPool::run`] while a job is active
//!   (e.g. `run_many` training several agents in parallel), it simply
//!   runs its own tasks inline instead of queueing — no blocking, no
//!   nested-parallelism deadlock, same results.
//! * Dropping a pool shuts its workers down and joins them, so
//!   short-lived pools (the async collector builds one per training run,
//!   sized to `num_envs`) don't leak parked threads. The [`global`] pool
//!   is never dropped.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Elements claimed per span by the pooled elementwise kernels
/// ([`ThreadPool::run_spans`] callers: Adam/hAdam steps, the Kahan EMA,
/// non-finite coercion, the grad-probe pass). The decomposition depends
/// only on the element count — never on the thread count — so pooled
/// results stay bitwise identical to the serial loop.
pub const ELEMWISE_SPAN: usize = 8192;

/// Raw mutable pointer that may cross the pool boundary. Used by
/// elementwise span kernels whose tasks write disjoint index ranges, so
/// aliasing is impossible (same contract as the GEMM backend's output
/// pointer).
#[derive(Clone, Copy)]
pub struct SendMut<T>(*mut T);

// SAFETY: callers hand every pool task a disjoint index range, so no
// two threads touch the same element; the `T: Send` bound keeps the
// wrapper from smuggling non-thread-safe types (Rc, thread-local
// handles) across the pool boundary.
unsafe impl<T: Send> Send for SendMut<T> {}
// SAFETY: as above — concurrent access is always to disjoint elements.
unsafe impl<T: Send> Sync for SendMut<T> {}

impl<T> SendMut<T> {
    pub fn new(p: *mut T) -> Self {
        SendMut(p)
    }

    /// Accessor instead of field access: under Rust 2021 disjoint
    /// capture, a closure touching the field would capture the bare
    /// `*mut T` (which is `!Sync`) rather than this `Sync` wrapper.
    #[inline]
    pub fn get(self) -> *mut T {
        self.0
    }
}

/// The published job's shape: a lifetime-erased task body plus the
/// claim geometry. `Copy`, and stored inline in [`Shared`] — publishing
/// a job allocates nothing, which keeps the learner's per-update GEMM
/// dispatches off the allocator entirely (the counting-allocator gate
/// in `BENCH_learner.json` measures worker threads too).
#[derive(Clone, Copy)]
struct JobDesc {
    /// Borrow of the caller's closure, valid until the submitter
    /// retires the job (see [`ThreadPool::run_chunked`]).
    f: *const (dyn Fn(usize) + Sync),
    /// Number of claim units: `ceil(total / grain)`.
    units: usize,
    /// Total task-index count.
    total: usize,
    /// Indices claimed per atomic RMW (chunk `u` covers
    /// `u*grain .. min((u+1)*grain, total)`).
    grain: usize,
}

// SAFETY: `f` points at a `Sync` closure that outlives every
// dereference — the submitter blocks until the job is drained *and*
// every registered worker has left `run_job` before returning — and
// the remaining fields are plain sizes.
unsafe impl Send for JobDesc {}

/// Claim and run chunks of the published job until none are left;
/// notify the submitter when the last chunk finishes.
///
/// Task panics are caught at the boundary so a claimed chunk always
/// increments `completed` — otherwise a panicking worker would leave
/// the submitter waiting forever, and a panicking submitter would
/// unwind (freeing the closure and output buffers) while workers still
/// execute through the raw pointer. The panic is re-raised on the
/// submitting thread once the job is fully drained.
fn run_job(shared: &Shared, d: JobDesc) {
    loop {
        let u = shared.next.fetch_add(1, Ordering::Relaxed);
        if u >= d.units {
            return;
        }
        let lo = u * d.grain;
        let hi = (lo + d.grain).min(d.total);
        // SAFETY: the submitter keeps the closure alive until the job
        // is drained and every registered worker has left this loop,
        // and we only reach here while chunks remain unclaimed.
        let f = unsafe { &*d.f };
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for t in lo..hi {
                f(t);
            }
        }))
        .is_err()
        {
            shared.poisoned.store(true, Ordering::Release);
        }
        if shared.completed.fetch_add(1, Ordering::AcqRel) + 1 == d.units {
            // take the lock so the submitter cannot miss the wakeup
            // tidy-allow(panic): lock poisoning means another task
            // already panicked — propagating is correct
            let _g = shared.done_mx.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

struct Shared {
    /// The active job, `None` when idle. Workers snapshot the
    /// descriptor under this lock and register in `active` *before*
    /// releasing it, so the submitter can retire the job soundly:
    /// clear the slot (no new entrants), then wait for `active == 0`.
    job: Mutex<Option<JobDesc>>,
    work_cv: Condvar,
    done_mx: Mutex<()>,
    done_cv: Condvar,
    /// Next chunk of the active job to claim. Reset by the submitter at
    /// publish time — sound because the previous job's retire proved no
    /// worker was still inside `run_job`.
    next: AtomicUsize,
    /// Chunks of the active job fully executed.
    completed: AtomicUsize,
    /// Workers currently inside [`run_job`] (entered under the `job`
    /// lock; the submitter's own participation is not counted — it is
    /// sequenced by construction).
    active: AtomicUsize,
    /// Set when any task body of the active job panicked.
    poisoned: AtomicBool,
    /// Tells the workers to exit (set by [`ThreadPool::drop`]).
    shutdown: AtomicBool,
}

/// A fixed set of worker threads executing one indexed job at a time.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Number of background workers (the submitter is an extra worker).
    pub workers: usize,
    submit: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool with `threads` total lanes (`threads - 1` background workers;
    /// the submitting thread is the last lane).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            job: Mutex::new(None),
            work_cv: Condvar::new(),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        });
        let workers = threads.saturating_sub(1);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = shared.clone();
            handles.push(
                // tidy-allow(determinism): this pool IS the sanctioned
                // parallelism primitive; worker count never changes what
                // an index computes
                std::thread::Builder::new()
                    .name(format!("lprl-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawning pool worker"), // tidy-allow(panic): cannot run without workers — fail loudly at startup
            );
        }
        ThreadPool { shared, workers, submit: Mutex::new(()), handles }
    }

    /// Run `f(0..total)` across the pool; returns when all tasks finished.
    /// One claim per index — see [`ThreadPool::run_chunked`] for jobs
    /// made of many tiny tasks.
    pub fn run(&self, total: usize, f: impl Fn(usize) + Sync) {
        self.run_chunked(total, 1, f)
    }

    /// Fan an elementwise kernel over `0..total` as half-open spans:
    /// `f(lo, hi)` with `hi - lo ≤ span`, one pool task (and one dynamic
    /// dispatch) per span instead of one per element. The span
    /// decomposition is a pure function of `total` and `span`, so when
    /// every element's result depends only on its own index the output
    /// is bitwise identical for any worker count — including the serial
    /// inline fallbacks `run_chunked` takes for tiny jobs or a busy
    /// pool.
    pub fn run_spans(&self, total: usize, span: usize, f: impl Fn(usize, usize) + Sync) {
        let span = span.max(1);
        let units = total.div_ceil(span);
        self.run_chunked(units, 1, |u| {
            let lo = u * span;
            f(lo, (lo + span).min(total));
        });
    }

    /// Run `f(0..total)` with workers claiming `grain` consecutive
    /// indices per atomic RMW; returns when all tasks finished.
    ///
    /// Falls back to inline serial execution when the pool has no
    /// workers, the job fits a single claim unit, or another job is
    /// already running — all paths execute the identical per-index code
    /// in ascending order within a chunk, so the output is bitwise
    /// independent of which path (and which grain) was taken.
    pub fn run_chunked(&self, total: usize, grain: usize, f: impl Fn(usize) + Sync) {
        if total == 0 {
            return;
        }
        let grain = grain.max(1);
        let units = total.div_ceil(grain);
        if self.workers == 0 || units == 1 {
            for t in 0..total {
                f(t);
            }
            return;
        }
        let guard = match self.submit.try_lock() {
            Ok(g) => g,
            Err(_) => {
                // pool busy (another training thread): run inline
                for t in 0..total {
                    f(t);
                }
                return;
            }
        };
        let fat: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: erase the borrow's lifetime; `run_chunked` does not
        // return until every chunk completed and every registered
        // worker has left `run_job`, so workers never touch `f` after
        // it dies.
        let fat: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(fat) };
        let desc = JobDesc { f: fat, units, total, grain };
        {
            // tidy-allow(panic): lock poisoning means another task
            // already panicked — propagating is correct (applies to
            // every pool lock/wait below)
            let mut g = self.shared.job.lock().unwrap();
            // the previous job's retire waited for `active == 0`, so
            // the counters are exclusively ours to reset here
            self.shared.next.store(0, Ordering::Relaxed);
            self.shared.completed.store(0, Ordering::Relaxed);
            self.shared.poisoned.store(false, Ordering::Relaxed);
            *g = Some(desc);
            self.shared.work_cv.notify_all();
        }
        // participate instead of just waiting
        run_job(&self.shared, desc);
        {
            let mut g = self.shared.done_mx.lock().unwrap(); // tidy-allow(panic): poisoned lock — see above
            while self.shared.completed.load(Ordering::Acquire) < units {
                g = self.shared.done_cv.wait(g).unwrap(); // tidy-allow(panic): poisoned lock — see above
            }
        }
        // retire: clear the slot so no new worker can register, then
        // wait for the registered ones to leave `run_job` — after that
        // nothing can touch `f` or the counters
        *self.shared.job.lock().unwrap() = None; // tidy-allow(panic): poisoned lock — see above
        {
            let mut g = self.shared.done_mx.lock().unwrap(); // tidy-allow(panic): poisoned lock — see above
            while self.shared.active.load(Ordering::Acquire) > 0 {
                g = self.shared.done_cv.wait(g).unwrap(); // tidy-allow(panic): poisoned lock — see above
            }
        }
        drop(guard);
        if self.shared.poisoned.load(Ordering::Acquire) {
            // the original message + backtrace were already printed by
            // the panicking thread's hook
            panic!("a thread-pool task panicked (see output above)");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // No job can be active here: `run_chunked` borrows `&self` and
        // blocks until its job drains, so reaching Drop means the pool
        // is idle. Wake the parked workers and join them.
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _g = self.shared.job.lock().unwrap(); // tidy-allow(panic): poisoned lock means a task panicked — propagating is correct
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let d = {
            let mut g = shared.job.lock().unwrap(); // tidy-allow(panic): poisoned lock means a task panicked — propagating is correct
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some(d) = *g {
                    if shared.next.load(Ordering::Relaxed) < d.units {
                        // register under the lock so the submitter
                        // cannot retire the job while we're unaccounted
                        shared.active.fetch_add(1, Ordering::AcqRel);
                        break d;
                    }
                }
                g = shared.work_cv.wait(g).unwrap(); // tidy-allow(panic): poisoned lock — see above
            }
        };
        run_job(&shared, d);
        if shared.active.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last one out: wake a submitter waiting in retire
            // tidy-allow(panic): lock poisoning means a task panicked —
            // propagating is correct
            let _g = shared.done_mx.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

/// Total parallel lanes: `LPRL_THREADS` env override, else host
/// parallelism capped at 16 (same cap the seed engine used). Governs
/// both the [`global`] GEMM pool and the size of per-run env-stepping
/// pools (`min(num_envs, default_threads())`).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LPRL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    // tidy-allow(determinism): machine shape only sizes the sanctioned
    // pool; every pooled kernel is thread-count invariant by contract
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// The process-wide pool, spawned on first use and reused forever.
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        for total in [0usize, 1, 2, 7, 64, 1000] {
            let hits: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
            pool.run(total, |t| {
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "total={total}");
        }
    }

    #[test]
    fn chunked_claiming_runs_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        for total in [1usize, 2, 7, 64, 1000] {
            for grain in [1usize, 2, 3, 16, 1000, 5000] {
                let hits: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
                pool.run_chunked(total, grain, |t| {
                    hits[t].fetch_add(1, Ordering::Relaxed);
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                    "total={total} grain={grain}"
                );
            }
        }
    }

    #[test]
    fn chunked_results_are_grain_and_thread_count_invariant() {
        // every (pool size, grain) combination must produce bitwise the
        // same per-index outputs: an index's result is a pure function
        // of the index, never of the batching
        let total = 257usize;
        let compute = |t: usize| (t as f64 + 0.5).sqrt().to_bits();
        let reference: Vec<u64> = (0..total).map(compute).collect();
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for grain in [1usize, 3, 64, 300] {
                let out: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
                pool.run_chunked(total, grain, |t| {
                    out[t].store(compute(t), Ordering::Relaxed);
                });
                let got: Vec<u64> = out.iter().map(|v| v.load(Ordering::Relaxed)).collect();
                assert_eq!(got, reference, "threads={threads} grain={grain}");
            }
        }
    }

    #[test]
    fn run_spans_covers_every_index_once_and_is_invariant() {
        let compute = |t: usize| (t as f64 + 0.25).sqrt().to_bits();
        for total in [0usize, 1, 7, 100, 1000] {
            let reference: Vec<u64> = (0..total).map(compute).collect();
            for threads in [1usize, 4] {
                let pool = ThreadPool::new(threads);
                for span in [1usize, 3, 64, 5000] {
                    let out: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
                    let max_len = AtomicU64::new(0);
                    pool.run_spans(total, span, |lo, hi| {
                        assert!(lo < hi && hi <= total);
                        max_len.fetch_max((hi - lo) as u64, Ordering::Relaxed);
                        for t in lo..hi {
                            out[t].store(compute(t), Ordering::Relaxed);
                        }
                    });
                    let got: Vec<u64> = out.iter().map(|v| v.load(Ordering::Relaxed)).collect();
                    assert_eq!(got, reference, "threads={threads} span={span} total={total}");
                    assert!(max_len.load(Ordering::Relaxed) as usize <= span.max(1));
                }
            }
        }
    }

    #[test]
    fn grain_zero_is_treated_as_one() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        pool.run_chunked(10, 0, |t| {
            sum.fetch_add(t as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 55);
    }

    #[test]
    fn pool_is_reusable_many_times() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        for _ in 0..200 {
            pool.run(17, |t| {
                sum.fetch_add(t as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 200 * (16 * 17 / 2));
    }

    #[test]
    fn zero_worker_pool_runs_serially() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.workers, 0);
        let sum = AtomicU64::new(0);
        pool.run(10, |t| {
            sum.fetch_add(t as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 55);
    }

    #[test]
    fn concurrent_submitters_fall_back_inline() {
        // two threads hammer the same pool; the busy one must run inline
        // rather than deadlock, and both must complete all tasks.
        let pool = ThreadPool::new(4);
        let sum = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        pool.run_chunked(33, 4, |t| {
                            sum.fetch_add(t as u64, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4 * 50 * (32 * 33 / 2));
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(64, |t| {
                if t == 13 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "submitter must re-raise the task panic");
        // the pool must remain fully usable afterwards
        let sum = AtomicU64::new(0);
        pool.run(16, |t| {
            sum.fetch_add(t as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 120);
    }

    #[test]
    fn dropping_a_pool_joins_its_workers() {
        // short-lived pools (async collector) must not leak parked
        // threads: build, use, drop many pools in a row
        for _ in 0..8 {
            let pool = ThreadPool::new(3);
            let sum = AtomicU64::new(0);
            pool.run_chunked(20, 4, |t| {
                sum.fetch_add(t as u64, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 190);
            drop(pool);
        }
    }

    #[test]
    fn global_pool_exists() {
        let p = global();
        let sum = AtomicU64::new(0);
        p.run(8, |t| {
            sum.fetch_add(t as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 28);
    }
}
