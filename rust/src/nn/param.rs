//! Learnable parameter storage.
//!
//! Every layer owns its parameters as [`Param`]s: a value buffer, a
//! gradient accumulator, and a shape. Optimizers operate on a
//! `Vec<&mut Param>` collected from a network (see [`crate::optim`]), so
//! parameter layout stays local to the layers while optimizer state is
//! keyed positionally.

use crate::lowp::Precision;
use crate::rngs::Pcg64;

/// One learnable tensor: values + gradient accumulator.
#[derive(Debug, Clone)]
pub struct Param {
    /// Human-readable name ("actor.trunk.0.w") for telemetry/checkpoints.
    pub name: String,
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
    /// Current values.
    pub w: Vec<f32>,
    /// Gradient accumulated by the last backward pass.
    pub g: Vec<f32>,
}

impl Param {
    pub fn new(name: impl Into<String>, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Param {
            name: name.into(),
            shape: shape.to_vec(),
            w: vec![0.0; n],
            g: vec![0.0; n],
        }
    }

    /// Initialize from an explicit vector (used by tests / checkpoints).
    pub fn from_values(name: impl Into<String>, shape: &[usize], w: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, w.len());
        Param { name: name.into(), shape: shape.to_vec(), g: vec![0.0; n], w }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.w.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.w.is_empty()
    }

    /// Zero the gradient accumulator.
    pub fn zero_grad(&mut self) {
        self.g.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Quantize values into the given precision (used when entering a
    /// low-precision run so the starting point is representable).
    pub fn quantize(&mut self, prec: Precision) {
        prec.q_slice(&mut self.w);
    }

    /// Fill with uniform values in [-bound, bound].
    pub fn fill_uniform(&mut self, rng: &mut Pcg64, bound: f32) {
        for v in self.w.iter_mut() {
            *v = rng.uniform_in(-bound, bound);
        }
    }

    /// True if values or grads contain NaN/Inf.
    pub fn has_nonfinite_grad(&self) -> bool {
        self.g.iter().any(|v| !v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_is_zeroed_with_shape() {
        let p = Param::new("w", &[3, 4]);
        assert_eq!(p.len(), 12);
        assert!(p.w.iter().all(|&v| v == 0.0));
        assert_eq!(p.shape, vec![3, 4]);
    }

    #[test]
    fn zero_grad_clears() {
        let mut p = Param::new("w", &[2]);
        p.g = vec![1.0, 2.0];
        p.zero_grad();
        assert_eq!(p.g, vec![0.0, 0.0]);
    }

    #[test]
    fn quantize_rounds_values() {
        let mut p = Param::from_values("w", &[2], vec![1.0, 1e-9]);
        p.quantize(Precision::fp16());
        assert_eq!(p.w, vec![1.0, 0.0]);
    }

    #[test]
    fn nonfinite_grad_detection() {
        let mut p = Param::new("w", &[2]);
        assert!(!p.has_nonfinite_grad());
        p.g[1] = f32::INFINITY;
        assert!(p.has_nonfinite_grad());
    }
}
