//! 2-D convolution (NCHW, valid padding) via im2col + GEMM, with explicit
//! backward. Used by the pixel encoder (paper §4.6: four 3×3 conv layers,
//! first stride 2, rest stride 1).
//!
//! `forward` is `&self` (inference, shareable); the im2col panel the
//! backward pass reuses is cached in an explicit [`Conv2dWorkspace`] by
//! `forward_train`.

use super::gemm::{gemm, gemm_nt_bias_q, gemm_tn_bias_q};
use super::param::Param;
use super::tensor::Tensor;
use crate::lowp::Precision;
use crate::rngs::Pcg64;

/// Training-time caches for one [`Conv2d`]: the im2col panel of the last
/// `forward_train` input and its shape.
#[derive(Debug, Clone, Default)]
pub struct Conv2dWorkspace {
    cols: Vec<f32>, // im2col of last input [B*Ho*Wo, Cin*k*k]
    in_shape: [usize; 4],
}

/// Conv2d: input `[B, Cin, H, W]` → output `[B, Cout, Ho, Wo]`,
/// `Ho = (H - k)/stride + 1`, valid padding.
#[derive(Debug, Clone)]
pub struct Conv2d {
    pub w: Param, // [Cout, Cin*k*k]
    pub b: Param, // [Cout]
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
}

impl Conv2d {
    pub fn new(name: &str, cin: usize, cout: usize, k: usize, stride: usize, rng: &mut Pcg64) -> Self {
        let fan = cin * k * k;
        let mut w = Param::new(format!("{name}.w"), &[cout, fan]);
        w.w = super::init::orthogonal_init(rng, cout, fan, 1.0);
        let b = Param::new(format!("{name}.b"), &[cout]);
        Conv2d { w, b, cin, cout, k, stride }
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        ((h - self.k) / self.stride + 1, (w - self.k) / self.stride + 1)
    }

    /// im2col: `[B, Cin, H, W]` → `[B*Ho*Wo, Cin*k*k]` rows of receptive
    /// fields.
    fn im2col(&self, x: &Tensor) -> (Vec<f32>, usize, usize) {
        let [b, c, h, w] = [x.shape[0], x.shape[1], x.shape[2], x.shape[3]];
        let (ho, wo) = self.out_hw(h, w);
        let fan = c * self.k * self.k;
        // tidy-allow(alloc): pixels-path im2col panel; threading a caller
        // workspace through the encoder is a ROADMAP carryover
        let mut cols = vec![0.0f32; b * ho * wo * fan];
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((bi * ho + oy) * wo + ox) * fan;
                    let iy0 = oy * self.stride;
                    let ix0 = ox * self.stride;
                    let mut p = row;
                    for ci in 0..c {
                        let base = ((bi * c + ci) * h + iy0) * w + ix0;
                        for ky in 0..self.k {
                            let src = base + ky * w;
                            cols[p..p + self.k].copy_from_slice(&x.data[src..src + self.k]);
                            p += self.k;
                        }
                    }
                }
            }
        }
        (cols, ho, wo)
    }

    /// GEMM over a prepared im2col panel + transpose to NCHW, with the
    /// bias add + quantize fused into the GEMM epilogue.
    fn forward_from_cols(
        &self,
        cols: &[f32],
        b: usize,
        ho: usize,
        wo: usize,
        prec: Precision,
    ) -> Tensor {
        let fan = self.cin * self.k * self.k;
        let rows = b * ho * wo;
        // y_rows[rows, cout] = cols[rows, fan] @ w[cout, fan]ᵀ
        // tidy-allow(alloc): pixels-path activation buffer (states preset
        // never reaches conv); workspace reuse is a ROADMAP carryover
        let mut yrows = vec![0.0f32; rows * self.cout];
        gemm_nt_bias_q(cols, &self.w.w, &mut yrows, rows, fan, self.cout, Some(&self.b.w), prec);
        // transpose the finished rows to [B, Cout, Ho, Wo]
        let mut y = Tensor::zeros(&[b, self.cout, ho, wo]);
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let r = ((bi * ho + oy) * wo + ox) * self.cout;
                    for co in 0..self.cout {
                        y.data[((bi * self.cout + co) * ho + oy) * wo + ox] = yrows[r + co];
                    }
                }
            }
        }
        y
    }

    /// Inference forward; output quantized. Bitwise identical to
    /// [`Conv2d::forward_train`].
    pub fn forward(&self, x: &Tensor, prec: Precision) -> Tensor {
        assert_eq!(x.shape.len(), 4);
        assert_eq!(x.shape[1], self.cin);
        let (cols, ho, wo) = self.im2col(x);
        self.forward_from_cols(&cols, x.shape[0], ho, wo, prec)
    }

    /// Training forward: keeps the im2col panel in `ws` for
    /// [`Conv2d::backward`].
    pub fn forward_train(&self, x: &Tensor, prec: Precision, ws: &mut Conv2dWorkspace) -> Tensor {
        assert_eq!(x.shape.len(), 4);
        assert_eq!(x.shape[1], self.cin);
        let (cols, ho, wo) = self.im2col(x);
        let y = self.forward_from_cols(&cols, x.shape[0], ho, wo, prec);
        ws.cols = cols;
        ws.in_shape = [x.shape[0], self.cin, x.shape[2], x.shape[3]];
        y
    }

    /// Backward; accumulates dW/db, returns dx `[B, Cin, H, W]`.
    pub fn backward(&mut self, dy: &Tensor, prec: Precision, ws: &Conv2dWorkspace) -> Tensor {
        let [b, cin, h, w] = ws.in_shape;
        assert!(b > 0, "forward_train workspace missing");
        let (ho, wo) = self.out_hw(h, w);
        assert_eq!(dy.shape, [b, self.cout, ho, wo]);
        let fan = cin * self.k * self.k;
        let rows = b * ho * wo;

        // dy as rows [rows, cout]
        // tidy-allow(alloc): pixels-path gradient scratch; workspace reuse
        // is a ROADMAP carryover
        let mut dyr = vec![0.0f32; rows * self.cout];
        for bi in 0..b {
            for co in 0..self.cout {
                for oy in 0..ho {
                    for ox in 0..wo {
                        dyr[((bi * ho + oy) * wo + ox) * self.cout + co] =
                            dy.data[((bi * self.cout + co) * ho + oy) * wo + ox];
                    }
                }
            }
        }
        // db
        for r in 0..rows {
            for co in 0..self.cout {
                self.b.g[co] += dyr[r * self.cout + co];
            }
        }
        prec.q_slice(&mut self.b.g);
        // dW[cout, fan] = dyrᵀ @ cols (quantize fused into the epilogue)
        // tidy-allow(alloc): pixels-path gradient scratch; workspace reuse
        // is a ROADMAP carryover
        let mut dw = vec![0.0f32; self.cout * fan];
        gemm_tn_bias_q(&dyr, &ws.cols, &mut dw, self.cout, rows, fan, None, prec);
        for (acc, d) in self.w.g.iter_mut().zip(&dw) {
            *acc += d;
        }
        prec.q_slice(&mut self.w.g);
        // dcols[rows, fan] = dyr @ w
        // tidy-allow(alloc): pixels-path gradient scratch; workspace reuse
        // is a ROADMAP carryover
        let mut dcols = vec![0.0f32; rows * fan];
        gemm(&dyr, &self.w.w, &mut dcols, rows, self.cout, fan);
        // col2im scatter-add
        let mut dx = Tensor::zeros(&[b, cin, h, w]);
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((bi * ho + oy) * wo + ox) * fan;
                    let iy0 = oy * self.stride;
                    let ix0 = ox * self.stride;
                    let mut p = row;
                    for ci in 0..cin {
                        let base = ((bi * cin + ci) * h + iy0) * w + ix0;
                        for ky in 0..self.k {
                            let dst = base + ky * w;
                            for kx in 0..self.k {
                                dx.data[dst + kx] += dcols[p];
                                p += 1;
                            }
                        }
                    }
                }
            }
        }
        dx.quantize(prec);
        dx
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.b]
    }

    /// Visit the parameters in [`Conv2d::params_mut`] order without
    /// materializing a `Vec`.
    pub fn for_each_param(&self, f: &mut impl FnMut(&Param)) {
        f(&self.w);
        f(&self.b);
    }

    /// Mutable twin of [`Conv2d::for_each_param`], same order.
    pub fn for_each_param_mut(&mut self, f: &mut impl FnMut(&mut Param)) {
        f(&mut self.w);
        f(&mut self.b);
    }

    pub fn zero_grad(&mut self) {
        self.w.zero_grad();
        self.b.zero_grad();
    }

    pub fn n_params(&self) -> usize {
        self.w.len() + self.b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loss(y: &Tensor) -> f32 {
        y.data.iter().map(|v| v * v / 2.0).sum()
    }

    #[test]
    fn output_shape_and_identity_kernel() {
        let mut rng = Pcg64::seed(1);
        let mut conv = Conv2d::new("c", 1, 1, 3, 1, &mut rng);
        // delta kernel: picks out the center pixel
        conv.w.w.iter_mut().for_each(|v| *v = 0.0);
        conv.w.w[4] = 1.0;
        let x = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let y = conv.forward(&x, Precision::Fp32);
        assert_eq!(y.shape, vec![1, 1, 2, 2]);
        // centers of each 3x3 window in a 4x4 grid: (1,1),(1,2),(2,1),(2,2)
        assert_eq!(y.data, vec![5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn stride_two_shape() {
        let mut rng = Pcg64::seed(2);
        let conv = Conv2d::new("c", 3, 8, 3, 2, &mut rng);
        let x = Tensor::zeros(&[2, 3, 21, 21]);
        let y = conv.forward(&x, Precision::Fp32);
        assert_eq!(y.shape, vec![2, 8, 10, 10]);
    }

    #[test]
    fn gradcheck_fp32() {
        let mut rng = Pcg64::seed(3);
        let mut conv = Conv2d::new("c", 2, 3, 3, 1, &mut rng);
        let x = Tensor::from_vec(&[1, 2, 5, 5], (0..50).map(|_| rng.normal_f32()).collect());
        let prec = Precision::Fp32;
        let mut ws = Conv2dWorkspace::default();
        let y = conv.forward_train(&x, prec, &mut ws);
        conv.zero_grad();
        let dx = conv.backward(&y.clone(), prec, &ws);

        let eps = 1e-3f32;
        for &idx in &[0usize, 7, 20, 49] {
            let mut x2 = x.clone();
            x2.data[idx] += eps;
            let lp = loss(&conv.forward(&x2, prec));
            x2.data[idx] -= 2.0 * eps;
            let lm = loss(&conv.forward(&x2, prec));
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - dx.data[idx]).abs() < 3e-2 * (1.0 + num.abs()), "x[{idx}]: {num} vs {}", dx.data[idx]);
        }
        conv.zero_grad();
        let yy = conv.forward_train(&x, prec, &mut ws);
        let _ = conv.backward(&yy.clone(), prec, &ws);
        for &idx in &[0usize, 11, 30] {
            let orig = conv.w.w[idx];
            conv.w.w[idx] = orig + eps;
            let lp = loss(&conv.forward(&x, prec));
            conv.w.w[idx] = orig - eps;
            let lm = loss(&conv.forward(&x, prec));
            conv.w.w[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - conv.w.g[idx]).abs() < 3e-2 * (1.0 + num.abs()), "w[{idx}]");
        }
    }

    #[test]
    fn bias_grad_is_sum_over_positions() {
        let mut rng = Pcg64::seed(4);
        let mut conv = Conv2d::new("c", 1, 2, 3, 1, &mut rng);
        let x = Tensor::zeros(&[1, 1, 3, 3]); // single output position
        let mut ws = Conv2dWorkspace::default();
        let y = conv.forward_train(&x, Precision::Fp32, &mut ws);
        assert_eq!(y.shape, vec![1, 2, 1, 1]);
        conv.zero_grad();
        let dy = Tensor::from_vec(&[1, 2, 1, 1], vec![2.0, -3.0]);
        let _ = conv.backward(&dy, Precision::Fp32, &ws);
        assert_eq!(conv.b.g, vec![2.0, -3.0]);
    }

    #[test]
    fn inference_and_train_forward_agree_bitwise() {
        let mut rng = Pcg64::seed(5);
        let conv = Conv2d::new("c", 2, 4, 3, 2, &mut rng);
        let x = Tensor::from_vec(&[2, 2, 9, 9], (0..2 * 2 * 81).map(|_| rng.normal_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let mut ws = Conv2dWorkspace::default();
            let a = conv.forward(&x, prec);
            let b = conv.forward_train(&x, prec, &mut ws);
            assert!(a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
    }
}
