//! 2-D convolution (NCHW, valid padding) via im2col + GEMM, with explicit
//! backward. Used by the pixel encoder (paper §4.6: four 3×3 conv layers,
//! first stride 2, rest stride 1).
//!
//! `forward` is `&self` (inference, shareable); the im2col panel, the
//! GEMM row buffer, and the backward scratch all live in an explicit
//! [`Conv2dWorkspace`] owned by the caller, so the `_into` walks are
//! allocation-free once warm (the pixels-preset update loop runs them at
//! zero allocations per round, same as the states-preset MLP path).

use super::gemm::{gemm, gemm_nt_bias_q, gemm_nt_bias_q_half, gemm_tn_bias_q};
use super::param::Param;
use super::tensor::Tensor;
use crate::lowp::{HalfFormat, HalfTensor, Precision};
use crate::rngs::Pcg64;

/// Caller-owned scratch for one [`Conv2d`]: the im2col panel of the last
/// `forward_train` input (read by backward), the GEMM row buffer the
/// forwards assemble into, and the backward's row/weight/column gradient
/// scratch. All buffers are grown once and reused.
#[derive(Debug, Clone, Default)]
pub struct Conv2dWorkspace {
    cols: Vec<f32>, // im2col of last input [B*Ho*Wo, Cin*k*k]
    in_shape: [usize; 4],
    yrows: Vec<f32>, // forward GEMM output rows [B*Ho*Wo, Cout]
    dyr: Vec<f32>,   // dy transposed to rows [B*Ho*Wo, Cout]
    dw: Vec<f32>,    // dW scratch [Cout, Cin*k*k]
    dcols: Vec<f32>, // dcols scratch [B*Ho*Wo, Cin*k*k]
}

/// Conv2d: input `[B, Cin, H, W]` → output `[B, Cout, Ho, Wo]`,
/// `Ho = (H - k)/stride + 1`, valid padding.
#[derive(Debug, Clone)]
pub struct Conv2d {
    pub w: Param, // [Cout, Cin*k*k]
    pub b: Param, // [Cout]
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    /// Packed 16-bit weight storage (see [`Linear::pack_weights`]
    /// for the quantize-mirror contract) — read by the inference
    /// forwards through the widening half-GEMM when present.
    ///
    /// [`Linear::pack_weights`]: super::Linear::pack_weights
    pub w_half: Option<HalfTensor>,
}

impl Conv2d {
    pub fn new(name: &str, cin: usize, cout: usize, k: usize, stride: usize, rng: &mut Pcg64) -> Self {
        let fan = cin * k * k;
        let mut w = Param::new(format!("{name}.w"), &[cout, fan]);
        w.w = super::init::orthogonal_init(rng, cout, fan, 1.0);
        let b = Param::new(format!("{name}.b"), &[cout]);
        Conv2d { w, b, cin, cout, k, stride, w_half: None }
    }

    /// Pack the kernel weights into 16-bit storage, quantize-mirroring
    /// the f32 master (same contract as `Linear::pack_weights`).
    pub fn pack_weights(&mut self, fmt: HalfFormat) {
        let packed = HalfTensor::pack(fmt, &self.w.shape, &self.w.w);
        packed.unpack_into(&mut self.w.w);
        self.w_half = Some(packed);
    }

    /// Drop the f32 weight master + gradient buffer (frozen snapshots).
    pub fn drop_master(&mut self) {
        assert!(self.w_half.is_some(), "{}: pack_weights before drop_master", self.w.name);
        let _ = std::mem::take(&mut self.w.w);
        let _ = std::mem::take(&mut self.w.g);
    }

    /// Refresh the packed mirror from the master, allocation-free, and
    /// quantize-mirror the master back. No-op when unpacked.
    pub fn repack_weights(&mut self) {
        if let Some(h) = &mut self.w_half {
            h.repack_from(&self.w.w);
            h.unpack_into(&mut self.w.w);
        }
    }

    /// Resident weight bytes across storage tiers.
    pub fn weight_bytes(&self) -> usize {
        let f32s = std::mem::size_of::<f32>();
        self.w.w.len() * f32s
            + self.w_half.as_ref().map_or(0, |h| h.bytes())
            + self.b.w.len() * f32s
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        ((h - self.k) / self.stride + 1, (w - self.k) / self.stride + 1)
    }

    /// im2col: `[B, Cin, H, W]` → `[B*Ho*Wo, Cin*k*k]` rows of receptive
    /// fields, written into `cols` (grown once, reused — every element
    /// is overwritten).
    fn im2col_into(&self, x: &Tensor, cols: &mut Vec<f32>) -> (usize, usize) {
        let [b, c, h, w] = [x.shape[0], x.shape[1], x.shape[2], x.shape[3]];
        let (ho, wo) = self.out_hw(h, w);
        let fan = c * self.k * self.k;
        cols.resize(b * ho * wo * fan, 0.0);
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((bi * ho + oy) * wo + ox) * fan;
                    let iy0 = oy * self.stride;
                    let ix0 = ox * self.stride;
                    let mut p = row;
                    for ci in 0..c {
                        let base = ((bi * c + ci) * h + iy0) * w + ix0;
                        for ky in 0..self.k {
                            let src = base + ky * w;
                            cols[p..p + self.k].copy_from_slice(&x.data[src..src + self.k]);
                            p += self.k;
                        }
                    }
                }
            }
        }
        (ho, wo)
    }

    /// GEMM over a prepared im2col panel + transpose to NCHW, with the
    /// bias add + quantize fused into the GEMM epilogue. The row buffer
    /// comes from the workspace and `out` is shape-ensured — both reused
    /// across calls. Reads the packed weight tier when present
    /// (bitwise identical by the quantize-mirror contract).
    fn forward_from_cols_into(
        &self,
        cols: &[f32],
        b: usize,
        ho: usize,
        wo: usize,
        prec: Precision,
        yrows: &mut Vec<f32>,
        out: &mut Tensor,
    ) {
        let fan = self.cin * self.k * self.k;
        let rows = b * ho * wo;
        yrows.resize(rows * self.cout, 0.0);
        // the GEMM accumulates — zero the reused rows so results match a
        // fresh buffer bitwise
        yrows.fill(0.0);
        // y_rows[rows, cout] = cols[rows, fan] @ w[cout, fan]ᵀ
        if let Some(h) = &self.w_half {
            gemm_nt_bias_q_half(
                cols,
                &h.data,
                h.fmt,
                yrows,
                rows,
                fan,
                self.cout,
                Some(&self.b.w),
                prec,
            );
        } else {
            gemm_nt_bias_q(cols, &self.w.w, yrows, rows, fan, self.cout, Some(&self.b.w), prec);
        }
        // transpose the finished rows to [B, Cout, Ho, Wo]
        out.ensure_shape(&[b, self.cout, ho, wo]);
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let r = ((bi * ho + oy) * wo + ox) * self.cout;
                    for co in 0..self.cout {
                        out.data[((bi * self.cout + co) * ho + oy) * wo + ox] = yrows[r + co];
                    }
                }
            }
        }
    }

    /// Inference forward; output quantized. Bitwise identical to
    /// [`Conv2d::forward_train`]. Allocating wrapper for cold callers —
    /// the encoder walks use [`Conv2d::forward_into`] with shared scratch.
    pub fn forward(&self, x: &Tensor, prec: Precision) -> Tensor {
        let mut ws = Conv2dWorkspace::default();
        let mut y = Tensor::default();
        self.forward_into(x, prec, &mut ws, &mut y);
        y
    }

    /// Allocation-free twin of [`Conv2d::forward`]: im2col panel and GEMM
    /// rows live in `ws`, the output in `out`, all reused when shapes
    /// repeat.
    pub fn forward_into(
        &self,
        x: &Tensor,
        prec: Precision,
        ws: &mut Conv2dWorkspace,
        out: &mut Tensor,
    ) {
        assert_eq!(x.shape.len(), 4);
        assert_eq!(x.shape[1], self.cin);
        let Conv2dWorkspace { cols, yrows, .. } = ws;
        let (ho, wo) = self.im2col_into(x, cols);
        self.forward_from_cols_into(cols, x.shape[0], ho, wo, prec, yrows, out);
    }

    /// Training forward: keeps the im2col panel in `ws` for
    /// [`Conv2d::backward`].
    pub fn forward_train(&self, x: &Tensor, prec: Precision, ws: &mut Conv2dWorkspace) -> Tensor {
        let mut y = Tensor::default();
        self.forward_train_into(x, prec, ws, &mut y);
        y
    }

    /// Allocation-free twin of [`Conv2d::forward_train`].
    pub fn forward_train_into(
        &self,
        x: &Tensor,
        prec: Precision,
        ws: &mut Conv2dWorkspace,
        out: &mut Tensor,
    ) {
        self.forward_into(x, prec, ws, out);
        ws.in_shape = [x.shape[0], self.cin, x.shape[2], x.shape[3]];
    }

    /// Backward; accumulates dW/db, returns dx `[B, Cin, H, W]`.
    /// Allocating wrapper — the encoder walk uses
    /// [`Conv2d::backward_into`] with shared scratch.
    pub fn backward(&mut self, dy: &Tensor, prec: Precision, ws: &mut Conv2dWorkspace) -> Tensor {
        let mut dx = Tensor::default();
        self.backward_into(dy, prec, ws, &mut dx);
        dx
    }

    /// Allocation-free twin of [`Conv2d::backward`]: the dy-rows, dW and
    /// dcols scratch live in `ws` and `dx` is written into a caller
    /// buffer, all reused when shapes repeat.
    pub fn backward_into(
        &mut self,
        dy: &Tensor,
        prec: Precision,
        ws: &mut Conv2dWorkspace,
        dx: &mut Tensor,
    ) {
        let [b, cin, h, w] = ws.in_shape;
        assert!(b > 0, "forward_train workspace missing");
        let (ho, wo) = self.out_hw(h, w);
        assert_eq!(dy.shape, [b, self.cout, ho, wo]);
        let fan = cin * self.k * self.k;
        let rows = b * ho * wo;
        let Conv2dWorkspace { cols, dyr, dw, dcols, .. } = ws;

        // dy as rows [rows, cout] (every element overwritten)
        dyr.resize(rows * self.cout, 0.0);
        for bi in 0..b {
            for co in 0..self.cout {
                for oy in 0..ho {
                    for ox in 0..wo {
                        dyr[((bi * ho + oy) * wo + ox) * self.cout + co] =
                            dy.data[((bi * self.cout + co) * ho + oy) * wo + ox];
                    }
                }
            }
        }
        // db
        for r in 0..rows {
            for co in 0..self.cout {
                self.b.g[co] += dyr[r * self.cout + co];
            }
        }
        prec.q_slice(&mut self.b.g);
        // dW[cout, fan] = dyrᵀ @ cols (quantize fused into the epilogue);
        // the GEMM accumulates — zero the reused scratch
        dw.resize(self.cout * fan, 0.0);
        dw.fill(0.0);
        gemm_tn_bias_q(dyr, cols, dw, self.cout, rows, fan, None, prec);
        for (acc, d) in self.w.g.iter_mut().zip(dw.iter()) {
            *acc += d;
        }
        prec.q_slice(&mut self.w.g);
        // dcols[rows, fan] = dyr @ w — accumulating GEMM, zero first
        dcols.resize(rows * fan, 0.0);
        dcols.fill(0.0);
        gemm(dyr, &self.w.w, dcols, rows, self.cout, fan);
        // col2im scatter-add
        dx.ensure_shape(&[b, cin, h, w]);
        dx.data.fill(0.0);
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((bi * ho + oy) * wo + ox) * fan;
                    let iy0 = oy * self.stride;
                    let ix0 = ox * self.stride;
                    let mut p = row;
                    for ci in 0..cin {
                        let base = ((bi * cin + ci) * h + iy0) * w + ix0;
                        for ky in 0..self.k {
                            let dst = base + ky * w;
                            for kx in 0..self.k {
                                dx.data[dst + kx] += dcols[p];
                                p += 1;
                            }
                        }
                    }
                }
            }
        }
        dx.quantize(prec);
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.b]
    }

    /// Visit the parameters in [`Conv2d::params_mut`] order without
    /// materializing a `Vec`.
    pub fn for_each_param(&self, f: &mut impl FnMut(&Param)) {
        f(&self.w);
        f(&self.b);
    }

    /// Mutable twin of [`Conv2d::for_each_param`], same order.
    pub fn for_each_param_mut(&mut self, f: &mut impl FnMut(&mut Param)) {
        f(&mut self.w);
        f(&mut self.b);
    }

    pub fn zero_grad(&mut self) {
        self.w.zero_grad();
        self.b.zero_grad();
    }

    pub fn n_params(&self) -> usize {
        self.w.len() + self.b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loss(y: &Tensor) -> f32 {
        y.data.iter().map(|v| v * v / 2.0).sum()
    }

    #[test]
    fn output_shape_and_identity_kernel() {
        let mut rng = Pcg64::seed(1);
        let mut conv = Conv2d::new("c", 1, 1, 3, 1, &mut rng);
        // delta kernel: picks out the center pixel
        conv.w.w.iter_mut().for_each(|v| *v = 0.0);
        conv.w.w[4] = 1.0;
        let x = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let y = conv.forward(&x, Precision::Fp32);
        assert_eq!(y.shape, vec![1, 1, 2, 2]);
        // centers of each 3x3 window in a 4x4 grid: (1,1),(1,2),(2,1),(2,2)
        assert_eq!(y.data, vec![5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn stride_two_shape() {
        let mut rng = Pcg64::seed(2);
        let conv = Conv2d::new("c", 3, 8, 3, 2, &mut rng);
        let x = Tensor::zeros(&[2, 3, 21, 21]);
        let y = conv.forward(&x, Precision::Fp32);
        assert_eq!(y.shape, vec![2, 8, 10, 10]);
    }

    #[test]
    fn gradcheck_fp32() {
        let mut rng = Pcg64::seed(3);
        let mut conv = Conv2d::new("c", 2, 3, 3, 1, &mut rng);
        let x = Tensor::from_vec(&[1, 2, 5, 5], (0..50).map(|_| rng.normal_f32()).collect());
        let prec = Precision::Fp32;
        let mut ws = Conv2dWorkspace::default();
        let y = conv.forward_train(&x, prec, &mut ws);
        conv.zero_grad();
        let dx = conv.backward(&y.clone(), prec, &mut ws);

        let eps = 1e-3f32;
        for &idx in &[0usize, 7, 20, 49] {
            let mut x2 = x.clone();
            x2.data[idx] += eps;
            let lp = loss(&conv.forward(&x2, prec));
            x2.data[idx] -= 2.0 * eps;
            let lm = loss(&conv.forward(&x2, prec));
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - dx.data[idx]).abs() < 3e-2 * (1.0 + num.abs()), "x[{idx}]: {num} vs {}", dx.data[idx]);
        }
        conv.zero_grad();
        let yy = conv.forward_train(&x, prec, &mut ws);
        let _ = conv.backward(&yy.clone(), prec, &mut ws);
        for &idx in &[0usize, 11, 30] {
            let orig = conv.w.w[idx];
            conv.w.w[idx] = orig + eps;
            let lp = loss(&conv.forward(&x, prec));
            conv.w.w[idx] = orig - eps;
            let lm = loss(&conv.forward(&x, prec));
            conv.w.w[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - conv.w.g[idx]).abs() < 3e-2 * (1.0 + num.abs()), "w[{idx}]");
        }
    }

    #[test]
    fn bias_grad_is_sum_over_positions() {
        let mut rng = Pcg64::seed(4);
        let mut conv = Conv2d::new("c", 1, 2, 3, 1, &mut rng);
        let x = Tensor::zeros(&[1, 1, 3, 3]); // single output position
        let mut ws = Conv2dWorkspace::default();
        let y = conv.forward_train(&x, Precision::Fp32, &mut ws);
        assert_eq!(y.shape, vec![1, 2, 1, 1]);
        conv.zero_grad();
        let dy = Tensor::from_vec(&[1, 2, 1, 1], vec![2.0, -3.0]);
        let _ = conv.backward(&dy, Precision::Fp32, &mut ws);
        assert_eq!(conv.b.g, vec![2.0, -3.0]);
    }

    #[test]
    fn inference_and_train_forward_agree_bitwise() {
        let mut rng = Pcg64::seed(5);
        let conv = Conv2d::new("c", 2, 4, 3, 2, &mut rng);
        let x = Tensor::from_vec(&[2, 2, 9, 9], (0..2 * 2 * 81).map(|_| rng.normal_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let mut ws = Conv2dWorkspace::default();
            let a = conv.forward(&x, prec);
            let b = conv.forward_train(&x, prec, &mut ws);
            assert!(a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
    }

    #[test]
    fn workspace_buffers_are_reused_across_calls() {
        let mut rng = Pcg64::seed(7);
        let mut conv = Conv2d::new("c", 2, 4, 3, 2, &mut rng);
        let x = Tensor::from_vec(&[2, 2, 9, 9], (0..2 * 2 * 81).map(|_| rng.normal_f32()).collect());
        let mut ws = Conv2dWorkspace::default();
        let (mut y, mut dx) = (Tensor::default(), Tensor::default());
        conv.forward_train_into(&x, Precision::Fp32, &mut ws, &mut y);
        conv.backward_into(&y.clone(), Precision::Fp32, &mut ws, &mut dx);
        let ptrs = (
            ws.cols.as_ptr(),
            ws.yrows.as_ptr(),
            ws.dyr.as_ptr(),
            ws.dw.as_ptr(),
            ws.dcols.as_ptr(),
            y.data.as_ptr(),
            dx.data.as_ptr(),
        );
        conv.forward_train_into(&x, Precision::Fp32, &mut ws, &mut y);
        conv.backward_into(&y.clone(), Precision::Fp32, &mut ws, &mut dx);
        assert_eq!(ptrs.0, ws.cols.as_ptr(), "im2col panel must be reused");
        assert_eq!(ptrs.1, ws.yrows.as_ptr(), "GEMM rows must be reused");
        assert_eq!(ptrs.2, ws.dyr.as_ptr(), "dy rows must be reused");
        assert_eq!(ptrs.3, ws.dw.as_ptr(), "dW scratch must be reused");
        assert_eq!(ptrs.4, ws.dcols.as_ptr(), "dcols scratch must be reused");
        assert_eq!(ptrs.5, y.data.as_ptr(), "output tensor must be reused");
        assert_eq!(ptrs.6, dx.data.as_ptr(), "dx tensor must be reused");
    }

    #[test]
    fn packed_conv_matches_master_bitwise() {
        let mut rng = Pcg64::seed(8);
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            let mut conv = Conv2d::new("c", 2, 4, 3, 2, &mut rng);
            let x =
                Tensor::from_vec(&[2, 2, 9, 9], (0..2 * 2 * 81).map(|_| rng.normal_f32()).collect());
            let mut packed = conv.clone();
            packed.pack_weights(fmt);
            // quantize-mirror contract: sync the reference to the
            // rewritten master
            conv.w.w.clone_from(&packed.w.w);
            for prec in [Precision::Fp32, Precision::fp16()] {
                let a = conv.forward(&x, prec);
                let b = packed.forward(&x, prec);
                assert!(
                    a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()),
                    "{fmt:?}/{prec:?}: packed conv must match the master bitwise"
                );
            }
            let before = packed.forward(&x, Precision::Fp32);
            packed.drop_master();
            let after = packed.forward(&x, Precision::Fp32);
            assert_eq!(before.data, after.data);
        }
    }
}
