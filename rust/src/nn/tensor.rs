//! Minimal dense tensor plus the three GEMM variants backprop needs.
//!
//! All heavy math in the native engine funnels through [`gemm`] /
//! [`gemm_nt`] / [`gemm_tn`], so the performance pass has a single hot
//! spot to optimize (blocked micro-kernel + thread parallelism over rows).

use crate::lowp::Precision;

/// A dense row-major f32 tensor with a dynamic shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of rows when viewed as 2-D `[rows, cols]` (product of all
    /// but the last dim).
    #[inline]
    pub fn rows(&self) -> usize {
        self.len() / self.cols()
    }

    /// Size of the last dimension.
    #[inline]
    pub fn cols(&self) -> usize {
        *self.shape.last().expect("tensor has no shape")
    }

    /// Reinterpret the shape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    /// Quantize all elements under the precision policy.
    #[inline]
    pub fn quantize(&mut self, prec: Precision) {
        prec.q_slice(&mut self.data);
    }

    /// True if any element is NaN or ±∞ — the paper's "crash" detector.
    pub fn has_nonfinite(&self) -> bool {
        self.data.iter().any(|v| !v.is_finite())
    }

    /// Mean of all elements (f64 accumulation).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&v| v as f64).sum::<f64>() / self.data.len() as f64) as f32
    }

    /// L2 norm (f64 accumulation).
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
    }
}

/// Number of threads the GEMMs fan out over. Chosen once from the host.
fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Run `f(r)` for each row index in `0..rows`, splitting rows across
/// threads when the work is large enough to amortize spawning.
fn par_rows(rows: usize, min_serial: usize, f: impl Fn(usize) + Sync) {
    let nt = num_threads();
    if rows * 2 < min_serial || nt <= 1 || rows < 2 * nt {
        for r in 0..rows {
            f(r);
        }
        return;
    }
    let chunk = rows.div_ceil(nt);
    std::thread::scope(|s| {
        for t in 0..nt {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(rows);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for r in lo..hi {
                    f(r);
                }
            });
        }
    });
}

/// `c[m,n] += a[m,k] * b[k,n]` (notrans, notrans). `c` must be zeroed by
/// the caller if accumulation is not wanted.
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let cptr = SendPtr(c.as_mut_ptr());
    par_rows(m, 64, |i| {
        // safety: each row of c is touched by exactly one closure call
        let crow = unsafe { std::slice::from_raw_parts_mut(cptr.at(i * n), n) };
        let arow = &a[i * k..(i + 1) * k];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    });
}

/// `c[m,n] += a[m,k] * b[n,k]ᵀ` (notrans, trans) — used for `y = x Wᵀ`
/// with PyTorch-layout weights and for `dx = dy W`... see `linear.rs`.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    let cptr = SendPtr(c.as_mut_ptr());
    par_rows(m, 64, |i| {
        let crow = unsafe { std::slice::from_raw_parts_mut(cptr.at(i * n), n) };
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] += acc;
        }
    });
}

/// `c[m,n] += a[k,m]ᵀ * b[k,n]` (trans, notrans) — used for weight
/// gradients `dW = dyᵀ x`.
pub fn gemm_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let cptr = SendPtr(c.as_mut_ptr());
    par_rows(m, 64, |i| {
        let crow = unsafe { std::slice::from_raw_parts_mut(cptr.at(i * n), n) };
        for p in 0..k {
            let av = a[p * m + i];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    });
}

/// Raw pointer wrapper so disjoint row slices can cross the thread-scope
/// boundary. Each row index is processed by exactly one thread.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// Pointer to `self.0 + off`. Callers guarantee disjoint row ranges.
    #[inline]
    fn at(&self, off: usize) -> *mut f32 {
        unsafe { self.0.add(off) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Pcg64;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Pcg64::seed(1);
        for &(m, k, n) in &[(1, 1, 1), (2, 3, 4), (5, 7, 3), (17, 33, 9), (64, 64, 64), (130, 40, 70)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
            let mut c = vec![0.0; m * n];
            gemm(&a, &b, &mut c, m, k, n);
            let want = naive_gemm(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{m}x{k}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_nt_is_b_transposed() {
        let mut rng = Pcg64::seed(2);
        let (m, k, n) = (6, 5, 4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal_f32()).collect();
        // b_t[k,n]
        let mut bt = vec![0.0; k * n];
        for j in 0..n {
            for p in 0..k {
                bt[p * n + j] = b[j * k + p];
            }
        }
        let mut c1 = vec![0.0; m * n];
        gemm_nt(&a, &b, &mut c1, m, k, n);
        let c2 = naive_gemm(&a, &bt, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_tn_is_a_transposed() {
        let mut rng = Pcg64::seed(3);
        let (m, k, n) = (4, 7, 3);
        let a: Vec<f32> = (0..k * m).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut at = vec![0.0; m * k];
        for i in 0..m {
            for p in 0..k {
                at[i * k + p] = a[p * m + i];
            }
        }
        let mut c1 = vec![0.0; m * n];
        gemm_tn(&a, &b, &mut c1, m, k, n);
        let c2 = naive_gemm(&at, &b, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_accumulates_into_c() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![2.0, 0.0, 0.0, 2.0];
        let mut c = vec![1.0; 4];
        gemm(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![3.0, 1.0, 1.0, 3.0]);
    }

    #[test]
    fn tensor_basics() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert!((t.mean() - 3.5).abs() < 1e-6);
        assert!(!t.has_nonfinite());
        let mut t2 = t.clone();
        t2.data[0] = f32::NAN;
        assert!(t2.has_nonfinite());
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.cols(), 2);
    }

    #[test]
    fn tensor_quantize_applies_policy() {
        let mut t = Tensor::from_vec(&[1, 3], vec![1.0, 1e-9, 1e9]);
        t.quantize(Precision::fp16());
        assert_eq!(t.data, vec![1.0, 0.0, f32::INFINITY]);
    }
}
