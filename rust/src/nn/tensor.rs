//! Minimal dense tensor. The three GEMM variants all heavy math funnels
//! through live in [`super::gemm`] (blocked micro-kernel + persistent
//! thread pool); the seed's scalar versions survive as
//! [`super::gemm::reference`].

use crate::lowp::Precision;

/// A dense row-major f32 tensor with a dynamic shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Default for Tensor {
    /// The empty sentinel (`zeros(&[0])`) training workspaces start from.
    fn default() -> Self {
        Tensor::zeros(&[0])
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        // tidy-allow(alloc): the constructor — hot paths reach this only
        // through `ensure_shape` on a shape change (warm-up, not steady state)
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        // tidy-allow(alloc): constructor owns its shape by definition;
        // hot paths only build tensors during warm-up
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Stage `batch` flat rows of shape `row_shape` into this tensor as
    /// `[batch, row_shape…]`, reallocating only when the target shape
    /// changes — the shared allocation-free staging path behind the
    /// collectors' and evaluators' per-step forwards (one definition;
    /// `sac::Policy::stage_obs` and the trainers delegate here).
    pub fn stage_rows(&mut self, flat: &[f32], batch: usize, row_shape: &[usize]) -> &Tensor {
        let row_len: usize = row_shape.iter().product();
        assert_eq!(flat.len(), batch * row_len, "staging buffer: want {} floats", batch * row_len);
        // steady state: same [batch, row_shape…] target — no shape build
        let same = self.shape.len() == row_shape.len() + 1
            && self.shape[0] == batch
            && self.shape[1..] == *row_shape;
        if !same {
            // tidy-allow(alloc): shape change only — steady-state staging reuses the buffer
            let mut shape = Vec::with_capacity(row_shape.len() + 1);
            shape.push(batch);
            shape.extend_from_slice(row_shape);
            self.ensure_shape(&shape);
        }
        self.data.copy_from_slice(flat);
        self
    }

    /// Make this tensor hold `shape`, reallocating only when the shape
    /// actually changes (fresh zeros then). When the shape is unchanged
    /// the existing contents are kept — callers that rely on this are
    /// expected to overwrite every element. The workspace-reuse
    /// primitive behind the allocation-free learner buffers.
    pub fn ensure_shape(&mut self, shape: &[usize]) {
        if self.shape != shape {
            *self = Tensor::zeros(shape);
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of rows when viewed as 2-D `[rows, cols]` (product of all
    /// but the last dim). An empty tensor (e.g. the `Tensor::zeros(&[0])`
    /// cache sentinel) has zero rows rather than dividing by zero.
    #[inline]
    pub fn rows(&self) -> usize {
        let c = self.cols();
        if c == 0 {
            0
        } else {
            self.len() / c
        }
    }

    /// Size of the last dimension.
    ///
    /// Panics with the offending shape if the tensor is scalar-shaped
    /// (`shape == []`) — a 2-D view of it is meaningless.
    #[inline]
    pub fn cols(&self) -> usize {
        assert!(
            !self.shape.is_empty(),
            "Tensor::cols() needs at least one dimension, got scalar shape {:?} ({} elems)",
            self.shape,
            self.data.len()
        );
        *self.shape.last().unwrap() // tidy-allow(panic): non-empty asserted directly above
    }

    /// Reinterpret the shape in place (same element count) without
    /// touching the data buffer — the allocation-free twin of
    /// [`Tensor::reshape`] for workspace tensors that flip between views
    /// (e.g. conv NCHW ↔ flattened im2col rows in the update loop).
    pub fn set_shape_in_place(&mut self, shape: &[usize]) {
        assert_eq!(shape.iter().product::<usize>(), self.data.len(), "shape/data mismatch");
        self.shape.clear();
        self.shape.extend_from_slice(shape);
    }

    /// Reinterpret the shape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        // tidy-allow(alloc): shape metadata only (a handful of usizes),
        // reached on pixels-path view changes, not the states loop
        self.shape = shape.to_vec();
        self
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    /// Quantize all elements under the precision policy.
    #[inline]
    pub fn quantize(&mut self, prec: Precision) {
        prec.q_slice(&mut self.data);
    }

    /// True if any element is NaN or ±∞ — the paper's "crash" detector.
    pub fn has_nonfinite(&self) -> bool {
        self.data.iter().any(|v| !v.is_finite())
    }

    /// Mean of all elements (f64 accumulation).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&v| v as f64).sum::<f64>() / self.data.len() as f64) as f32
    }

    /// L2 norm (f64 accumulation).
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_basics() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert!((t.mean() - 3.5).abs() < 1e-6);
        assert!(!t.has_nonfinite());
        let mut t2 = t.clone();
        t2.data[0] = f32::NAN;
        assert!(t2.has_nonfinite());
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.cols(), 2);
    }

    #[test]
    fn tensor_quantize_applies_policy() {
        let mut t = Tensor::from_vec(&[1, 3], vec![1.0, 1e-9, 1e9]);
        t.quantize(Precision::fp16());
        assert_eq!(t.data, vec![1.0, 0.0, f32::INFINITY]);
    }

    #[test]
    fn empty_sentinel_has_zero_rows() {
        // the `x_cache` sentinel layers use before the first forward
        let t = Tensor::zeros(&[0]);
        assert_eq!(t.cols(), 0);
        assert_eq!(t.rows(), 0, "must not divide by zero");
        assert!(t.is_empty());
    }

    #[test]
    #[should_panic(expected = "scalar shape []")]
    fn scalar_shape_cols_panics_with_shape_in_message() {
        let t = Tensor { shape: vec![], data: vec![1.0] };
        let _ = t.cols();
    }
}
