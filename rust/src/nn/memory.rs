//! Memory accounting for the paper's Table 3 / Table 11.
//!
//! The V100 measurements in the paper count peak CUDA bytes; here we model
//! the same quantities analytically from the layer dimensions: parameter
//! storage, gradient storage, optimizer state (Adam m/w, Kahan
//! compensation buffers), and activation storage for a training step at a
//! given batch size. Under fp16 every tensor halves; Kahan adds one
//! model-sized buffer per compensated quantity, which is what makes the
//! paper's improvement ≈1.87× instead of 2×.

/// Memory model of one training step.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemoryModel {
    /// Total learnable parameters across actor+critic+target (elements).
    pub params: usize,
    /// Activation elements stored for backward at batch size 1.
    pub activations_per_sample: usize,
    /// Number of parameter elements carrying Kahan compensation
    /// (critic + α under the paper's method 6, plus target-net momentum
    /// compensation under method 4).
    pub kahan_elems: usize,
}

impl MemoryModel {
    /// Peak training bytes under a storage width (4 = fp32, 2 = fp16).
    ///
    /// params + grads + Adam(m, w) + Kahan compensation + activations.
    pub fn training_bytes(&self, batch: usize, bytes_per_elem: usize) -> usize {
        let param_like = self.params       // parameters
            + self.params                  // gradients
            + 2 * self.params              // Adam m and v/w
            + self.kahan_elems;            // compensation buffers
        let act = self.activations_per_sample * batch;
        (param_like + act) * bytes_per_elem
    }

    /// The fp32-over-fp16 improvement factor the paper's Table 3 reports.
    /// The fp32 baseline carries no Kahan buffers; the fp16 run carries
    /// them when `kahan_in_fp16` (the paper's full method).
    pub fn improvement(&self, batch: usize, kahan_in_fp16: bool) -> f64 {
        let fp32_model = MemoryModel { kahan_elems: 0, ..*self };
        let m16 = if kahan_in_fp16 { *self } else { fp32_model };
        fp32_model.training_bytes(batch, 4) as f64 / m16.training_bytes(batch, 2) as f64
    }
}

/// Build the memory model for the paper's state-based SAC at a hidden
/// width (Table 10/11 sweep widths 1024/4096).
pub fn states_model(obs_dim: usize, act_dim: usize, hidden: usize) -> MemoryModel {
    // actor: obs -> h -> h -> 2*act ; critic: 2 x (obs+act -> h -> h -> 1)
    let actor = (obs_dim * hidden + hidden)
        + (hidden * hidden + hidden)
        + (hidden * 2 * act_dim + 2 * act_dim);
    let qin = obs_dim + act_dim;
    let critic1 = (qin * hidden + hidden) + (hidden * hidden + hidden) + (hidden + 1);
    let critic = 2 * critic1;
    let target = critic;
    let params = actor + critic + target;
    // activations per sample: the hidden vectors kept for backward
    let actor_act = hidden * 2 + 2 * act_dim + obs_dim;
    let critic_act = 2 * (hidden * 2 + 1 + qin);
    MemoryModel {
        params,
        activations_per_sample: actor_act + critic_act,
        // Kahan on critic params (method 6) + target momentum comp (method 4)
        kahan_elems: critic + target,
    }
}

/// Memory model for the pixel encoder + SAC heads (Table 3 sweep:
/// `filters` ∈ {32, 64}).
pub fn pixels_model(img: usize, frames: usize, filters: usize, feature_dim: usize, hidden: usize, act_dim: usize) -> MemoryModel {
    // encoder: conv(frames->f, s2) + 3x conv(f->f, s1) + linear(flat->feat) + LN
    let mut h = (img - 3) / 2 + 1;
    let conv1 = frames * 9 * filters + filters;
    let mut convs = conv1;
    let mut acts = frames * img * img + filters * h * h;
    for _ in 0..3 {
        convs += filters * filters * 9 + filters;
        h -= 2;
        acts += filters * h * h;
    }
    let flat = filters * h * h;
    let head = flat * feature_dim + feature_dim + 2 * feature_dim; // linear + LN affine
    let enc = convs + head;
    acts += feature_dim * 3;
    let m = states_model(feature_dim, act_dim, hidden);
    MemoryModel {
        params: m.params + 2 * enc, // encoder shared by actor/critic + target copy
        activations_per_sample: m.activations_per_sample + acts,
        kahan_elems: m.kahan_elems + 2 * enc,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp16_roughly_halves_memory() {
        let m = states_model(17, 6, 1024);
        let imp = m.improvement(1024, true);
        // paper Table 11: 1.53–1.73x (Kahan comp buffers cost something)
        assert!(imp > 1.4 && imp < 2.0, "imp={imp}");
    }

    #[test]
    fn no_kahan_gives_exactly_two_x() {
        let mut m = states_model(17, 6, 1024);
        m.kahan_elems = 0;
        let imp = m.improvement(1024, true);
        assert!((imp - 2.0).abs() < 1e-9, "imp={imp}");
    }

    #[test]
    fn activations_scale_with_batch() {
        let m = states_model(17, 6, 256);
        let b1 = m.training_bytes(1, 4);
        let b2 = m.training_bytes(1025, 4);
        assert!(b2 > b1 + 1024 * m.activations_per_sample * 4 - 1);
    }

    #[test]
    fn pixels_model_bigger_than_states() {
        let s = states_model(50, 6, 1024);
        let p = pixels_model(84, 9, 32, 50, 1024, 6);
        assert!(p.params > s.params);
        assert!(p.activations_per_sample > s.activations_per_sample);
    }

    #[test]
    fn wider_filters_cost_more() {
        let a = pixels_model(84, 9, 32, 50, 1024, 6);
        let b = pixels_model(84, 9, 64, 50, 1024, 6);
        assert!(b.training_bytes(512, 2) > a.training_bytes(512, 2));
    }
}
