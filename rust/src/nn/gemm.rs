//! Blocked, register-tiled, persistently-threaded GEMM backend — the hot
//! path every SAC forward/backward funnels through.
//!
//! The seed engine computed all three GEMM variants with row-parallel
//! scalar loops (kept verbatim in [`reference`] as the perf baseline and
//! test oracle). This backend replaces them with:
//!
//! * **Cache blocking**: `KC`-deep panels of the reduction dimension and
//!   `MC`-row task blocks keep the working set in L1/L2; transposed
//!   operands (`gemm_nt`'s B, `gemm_tn`'s A) are packed once per call —
//!   on the submitting thread, into a reusable thread-local scratch —
//!   so the inner kernel always streams unit-stride and the steady-state
//!   hot path performs zero allocations.
//! * **Register tiling**: a 4×16 micro-kernel accumulates into a fixed
//!   `[[f32; NR]; MR]` block — 64 independent FMA chains the compiler
//!   keeps in vector registers (the scalar seed loop was one chain).
//! * **Persistent threading**: row blocks are fanned out over the
//!   process-wide [`super::pool`] worker pool instead of spawning a
//!   `thread::scope` per call.
//! * **Fused epilogue**: the `*_bias_q` entry points add a per-column
//!   bias and quantize into a [`Precision`] while the output block is
//!   still cache-hot, collapsing `Linear::forward`'s three passes
//!   (GEMM, bias, quantize) into one.
//!
//! Determinism: every output element is accumulated in ascending-`k`
//! order within fixed `KC` panels, and the task decomposition depends
//! only on the shape — results are **bitwise identical** for any thread
//! count, including the serial fallback (covered by tests).
//!
//! Non-finite semantics: unlike the seed loops (which skipped `a == 0`
//! terms as a sparsity shortcut), the kernels accumulate every term, so
//! `0 × ∞ = NaN` propagates exactly as IEEE GEMM semantics dictate.
//! This only matters in the overflow regimes the paper *studies*
//! (fp16-naive runs that are already diverging); the amp-style
//! skip-on-nonfinite optimizer step handles it identically either way.

use super::{pool, simd};
use crate::lowp::{HalfFormat, Precision};
use std::cell::RefCell;

thread_local! {
    /// Submitting-thread scratch holding the transposed operand of the
    /// `nt`/`tn` variants, packed once per call *before* the fan-out so
    /// worker tasks stream it read-only (a packed product is exactly a
    /// [`task_nn`] job). Reused across calls: it grows to the
    /// high-water size during warm-up and the steady-state learner
    /// never allocates here. Every element in the used prefix is
    /// overwritten before the kernels read it, so reuse cannot change
    /// results.
    static PACK: RefCell<Vec<f32>> = RefCell::new(Vec::new());
    /// Same-lifecycle scratch for the packed-half path: the Bᵀ pack
    /// stays in u16, so the transpose moves (and the kernels then
    /// stream) half the bytes of the f32 pack — the storage tier's
    /// bandwidth win applies to the packing pass itself.
    static PACK_U16: RefCell<Vec<u16>> = RefCell::new(Vec::new());
}

/// Run `f` on this thread's packing scratch, sized to `len` elements.
fn with_pack<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    PACK.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < len {
            // scratch grows to the high-water mark once per thread
            // (warm-up), then is reused forever
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// Run `f` on this thread's u16 packing scratch, sized to `len` elements.
fn with_pack_u16<R>(len: usize, f: impl FnOnce(&mut [u16]) -> R) -> R {
    PACK_U16.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < len {
            // scratch grows to the high-water mark once per thread
            // (warm-up), then is reused forever
            buf.resize(len, 0);
        }
        f(&mut buf[..len])
    })
}

/// Pack `b[n][k]` (row-major) into its transpose `bt[k][n]`.
fn pack_bt(b: &[f32], bt: &mut [f32], k: usize, n: usize) {
    for j in 0..n {
        let src = &b[j * k..(j + 1) * k];
        for (p, &v) in src.iter().enumerate() {
            bt[p * n + j] = v;
        }
    }
}

/// Pack `b[n][k]` (row-major, packed-half bits) into its transpose
/// `bt[k][n]` — a pure u16 move, no widening.
fn pack_bt_u16(b: &[u16], bt: &mut [u16], k: usize, n: usize) {
    for j in 0..n {
        let src = &b[j * k..(j + 1) * k];
        for (p, &v) in src.iter().enumerate() {
            bt[p * n + j] = v;
        }
    }
}

/// Pack `a[k][m]` (row-major) into its transpose `at[m][k]`.
fn pack_at(a: &[f32], at: &mut [f32], m: usize, k: usize) {
    for p in 0..k {
        let src = &a[p * m..(p + 1) * m];
        for (i, &v) in src.iter().enumerate() {
            at[i * k + p] = v;
        }
    }
}

/// Micro-kernel rows (register tile height).
const MR: usize = 4;
/// Micro-kernel columns (register tile width; 2×8-wide vector lanes).
const NR: usize = 16;
/// Rows per parallel task block.
const MC: usize = 64;
/// Reduction-dimension panel depth kept cache-resident.
const KC: usize = 256;
/// Minimum multiply-accumulate count before fanning out to the pool.
const PAR_MIN_MACS: usize = 1 << 16;

/// Raw output pointer that may cross the pool boundary. Tasks write
/// disjoint row ranges, so aliasing is impossible.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: every dispatch hands each task a disjoint i0..i1 row range of
// the output, so no two threads ever touch the same element through
// this pointer.
unsafe impl Send for SendPtr {}
// SAFETY: as above — concurrent access is always to disjoint rows.
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// Accessor instead of field access: under Rust 2021 disjoint
    /// capture, a closure touching `cp.0` would capture the bare
    /// `*mut f32` (which is `!Sync`) rather than this `Sync` wrapper.
    #[inline]
    fn get(self) -> *mut f32 {
        self.0
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Exec {
    /// Fan out over the global pool when the job is large enough.
    Auto,
    /// Always run tasks inline, in order (tests: thread-count invariance).
    #[cfg_attr(not(test), allow(dead_code))]
    Serial,
}

/// `c[m,n] += a[m,k] · b[k,n]` (both row-major, no transpose).
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_bias_q(a, b, c, m, k, n, None, Precision::Fp32);
}

/// `c[m,n] += a[m,k] · b[n,k]ᵀ` — `y = x Wᵀ` with PyTorch-layout weights.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_nt_bias_q(a, b, c, m, k, n, None, Precision::Fp32);
}

/// `c[m,n] += a[k,m]ᵀ · b[k,n]` — weight gradients `dW = dyᵀ x`.
pub fn gemm_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_tn_bias_q(a, b, c, m, k, n, None, Precision::Fp32);
}

/// [`gemm`] with a fused epilogue: after the product is fully
/// accumulated, add `bias[j]` to every column (when given) and quantize
/// the rows into `prec` — one cache-hot pass instead of three.
pub fn gemm_bias_q(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) {
    gemm_nn_impl(a, b, c, m, k, n, bias, prec, Exec::Auto, simd::detect());
}

/// [`gemm_bias_q`] pinned to an explicit SIMD [`simd::Level`] — the
/// seam the parity tests and benches use to run the scalar oracle and
/// the vector path side by side on the same machine.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_q_at(
    level: simd::Level,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) {
    gemm_nn_impl(a, b, c, m, k, n, bias, prec, Exec::Auto, level);
}

/// [`gemm_nt`] with the fused bias+quantize epilogue.
pub fn gemm_nt_bias_q(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) {
    gemm_nt_impl(a, b, c, m, k, n, bias, prec, Exec::Auto, simd::detect());
}

/// [`gemm_nt_bias_q`] pinned to an explicit SIMD [`simd::Level`] (see
/// [`gemm_bias_q_at`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_bias_q_at(
    level: simd::Level,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) {
    gemm_nt_impl(a, b, c, m, k, n, bias, prec, Exec::Auto, level);
}

/// [`gemm_tn`] with the fused bias+quantize epilogue.
pub fn gemm_tn_bias_q(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) {
    gemm_tn_impl(a, b, c, m, k, n, bias, prec, Exec::Auto, simd::detect());
}

/// [`gemm_tn_bias_q`] pinned to an explicit SIMD [`simd::Level`] (see
/// [`gemm_bias_q_at`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_bias_q_at(
    level: simd::Level,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) {
    gemm_tn_impl(a, b, c, m, k, n, bias, prec, Exec::Auto, level);
}

/// Two same-shape [`gemm_nt_bias_q`] products under a **single** pool
/// dispatch — the twin-critic fast path. SAC's `q1`/`q2` heads always
/// share layer shapes, so batching both heads' row-block tasks into one
/// fan-out halves the GEMM dispatches per critic forward (6 → 3 for the
/// standard 2-hidden-layer critic).
///
/// Each head's blocks run the unchanged [`task_nt`] + [`epilogue`]
/// bodies over the same `MC` decomposition as a standalone call, so the
/// per-head results are **bitwise identical** to two separate
/// [`gemm_nt_bias_q`] calls — the thread-count-invariance contract of
/// the single-product entries carries over (covered by tests).
pub fn gemm_nt_bias_q_pair(
    a1: &[f32],
    b1: &[f32],
    c1: &mut [f32],
    bias1: Option<&[f32]>,
    a2: &[f32],
    b2: &[f32],
    c2: &mut [f32],
    bias2: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    prec: Precision,
) {
    gemm_nt_pair_impl(a1, b1, c1, bias1, a2, b2, c2, bias2, m, k, n, prec, Exec::Auto, simd::detect());
}

#[allow(clippy::too_many_arguments)]
fn gemm_nt_pair_impl(
    a1: &[f32],
    b1: &[f32],
    c1: &mut [f32],
    bias1: Option<&[f32]>,
    a2: &[f32],
    b2: &[f32],
    c2: &mut [f32],
    bias2: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    prec: Precision,
    exec: Exec,
    level: simd::Level,
) {
    assert_eq!(a1.len(), m * k);
    assert_eq!(a2.len(), m * k);
    assert_eq!(b1.len(), n * k);
    assert_eq!(b2.len(), n * k);
    check_cb(c1, m, n, bias1);
    check_cb(c2, m, n, bias2);
    if m == 0 {
        return;
    }
    // Task t < nb is head 1's row block t; task t >= nb is head 2's
    // block t - nb. Each block is the exact body a standalone
    // `gemm_nt_impl` would run, so scheduling cannot change results.
    let nb = m.div_ceil(MC);
    let ntasks = 2 * nb;
    let c1p = SendPtr(c1.as_mut_ptr());
    let c2p = SendPtr(c2.as_mut_ptr());
    // Both heads' Bᵀ packs share the submitting thread's scratch (see
    // `gemm_nt_impl` — same pack-once rationale, bitwise-identical).
    with_pack(2 * k * n, |pack| {
        let (bt1, bt2) = pack.split_at_mut(k * n);
        pack_bt(b1, bt1, k, n);
        pack_bt(b2, bt2, k, n);
        let (bt1, bt2): (&[f32], &[f32]) = (bt1, bt2);
        let body = |t: usize| {
            let (blk, a, bt, cp, bias) = if t < nb {
                (t, a1, bt1, c1p, bias1)
            } else {
                (t - nb, a2, bt2, c2p, bias2)
            };
            let i0 = blk * MC;
            let i1 = (i0 + MC).min(m);
            // SAFETY: this task exclusively owns rows i0..i1 of its own
            // head's output; the two heads write through distinct buffers.
            unsafe { task_nn(a, bt, level, cp.get(), i0, i1, k, n) };
            epilogue(level, cp.get(), i0, i1, n, bias, prec);
        };
        // The combined job: both products count toward the pool threshold.
        let parallel = exec == Exec::Auto && ntasks > 1 && 2 * m * k * n >= PAR_MIN_MACS;
        if parallel {
            pool::global().run(ntasks, body);
        } else {
            for t in 0..ntasks {
                body(t);
            }
        }
    });
}

// The tiling constants are shared with the simd micro-kernels by
// contract; a drift here would silently mis-tile the half path.
const _: () = assert!(MR == simd::MR && NR == simd::NR);

/// [`gemm_nt_bias_q`] with a **packed-half** B operand: `b` holds `n·k`
/// 16-bit weights in `fmt` layout, widened to f32 inside the micro-
/// kernels — half the B-operand bytes packed and streamed per call.
/// Accumulation is f32 in the exact scalar order, so the result is
/// bitwise identical to [`gemm_nt_bias_q`] on the widened weights,
/// at every SIMD level (see [`super::simd`]).
pub fn gemm_nt_bias_q_half(
    a: &[f32],
    b: &[u16],
    fmt: HalfFormat,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) {
    gemm_nt_half_impl(a, b, fmt, c, m, k, n, bias, prec, Exec::Auto, simd::detect());
}

/// [`gemm_nt_bias_q_half`] pinned to an explicit SIMD [`simd::Level`] —
/// the seam the parity tests and benches use to run the scalar oracle
/// and the vector path side by side on the same machine.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_bias_q_half_at(
    level: simd::Level,
    a: &[f32],
    b: &[u16],
    fmt: HalfFormat,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) {
    gemm_nt_half_impl(a, b, fmt, c, m, k, n, bias, prec, Exec::Auto, level);
}

/// Two same-shape [`gemm_nt_bias_q_half`] products under a single pool
/// dispatch — the twin-critic fast path for packed target/serve weights
/// (same decomposition and bitwise contract as [`gemm_nt_bias_q_pair`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_bias_q_pair_half(
    a1: &[f32],
    b1: &[u16],
    c1: &mut [f32],
    bias1: Option<&[f32]>,
    a2: &[f32],
    b2: &[u16],
    c2: &mut [f32],
    bias2: Option<&[f32]>,
    fmt: HalfFormat,
    m: usize,
    k: usize,
    n: usize,
    prec: Precision,
) {
    let level = simd::detect();
    assert_eq!(a1.len(), m * k);
    assert_eq!(a2.len(), m * k);
    assert_eq!(b1.len(), n * k);
    assert_eq!(b2.len(), n * k);
    check_cb(c1, m, n, bias1);
    check_cb(c2, m, n, bias2);
    if m == 0 {
        return;
    }
    // Task decomposition mirrors `gemm_nt_pair_impl`: task t < nb is
    // head 1's row block t; t >= nb is head 2's block t - nb.
    let nb = m.div_ceil(MC);
    let ntasks = 2 * nb;
    let c1p = SendPtr(c1.as_mut_ptr());
    let c2p = SendPtr(c2.as_mut_ptr());
    with_pack_u16(2 * k * n, |pack| {
        let (bt1, bt2) = pack.split_at_mut(k * n);
        pack_bt_u16(b1, bt1, k, n);
        pack_bt_u16(b2, bt2, k, n);
        let (bt1, bt2): (&[u16], &[u16]) = (bt1, bt2);
        let body = |t: usize| {
            let (blk, a, bt, cp, bias) = if t < nb {
                (t, a1, bt1, c1p, bias1)
            } else {
                (t - nb, a2, bt2, c2p, bias2)
            };
            let i0 = blk * MC;
            let i1 = (i0 + MC).min(m);
            // SAFETY: this task exclusively owns rows i0..i1 of its own
            // head's output; the two heads write through distinct buffers.
            unsafe { task_nn_half(a, bt, fmt, level, cp.get(), i0, i1, k, n) };
            epilogue(level, cp.get(), i0, i1, n, bias, prec);
        };
        let parallel = ntasks > 1 && 2 * m * k * n >= PAR_MIN_MACS;
        if parallel {
            pool::global().run(ntasks, body);
        } else {
            for t in 0..ntasks {
                body(t);
            }
        }
    });
}

#[allow(clippy::too_many_arguments)]
fn gemm_nt_half_impl(
    a: &[f32],
    b: &[u16],
    fmt: HalfFormat,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
    exec: Exec,
    level: simd::Level,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    check_cb(c, m, n, bias);
    let cp = SendPtr(c.as_mut_ptr());
    // Pack Bᵀ once on the submitting thread — in u16, so the pack pass
    // moves half the bytes of the f32 path (see `gemm_nt_impl` for the
    // pack-once rationale; results are bitwise level- and
    // thread-count-invariant).
    with_pack_u16(k * n, |bt| {
        pack_bt_u16(b, bt, k, n);
        let bt: &[u16] = bt;
        run_row_blocks(m, m * k * n, exec, |i0, i1| {
            // SAFETY: this task exclusively owns output rows i0..i1;
            // the operand slices are only read.
            unsafe { task_nn_half(a, bt, fmt, level, cp.get(), i0, i1, k, n) };
            epilogue(level, cp.get(), i0, i1, n, bias, prec);
        });
    });
}

#[allow(clippy::too_many_arguments)]
fn gemm_nn_impl(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
    exec: Exec,
    level: simd::Level,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    check_cb(c, m, n, bias);
    let cp = SendPtr(c.as_mut_ptr());
    run_row_blocks(m, m * k * n, exec, |i0, i1| {
        // SAFETY: this task exclusively owns output rows i0..i1; the
        // operand slices are only read.
        unsafe { task_nn(a, b, level, cp.get(), i0, i1, k, n) };
        epilogue(level, cp.get(), i0, i1, n, bias, prec);
    });
}

#[allow(clippy::too_many_arguments)]
fn gemm_nt_impl(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
    exec: Exec,
    level: simd::Level,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    check_cb(c, m, n, bias);
    let cp = SendPtr(c.as_mut_ptr());
    // Pack Bᵀ once on the submitting thread, then run the product as a
    // notrans·notrans job: every task used to pack its own copy of the
    // same panel, so this is both less copy work and allocation-free.
    // The kernels read identical values in the identical ascending-k
    // order, so results are bitwise unchanged.
    with_pack(k * n, |bt| {
        pack_bt(b, bt, k, n);
        let bt: &[f32] = bt;
        run_row_blocks(m, m * k * n, exec, |i0, i1| {
            // SAFETY: this task exclusively owns output rows i0..i1;
            // the operand slices are only read.
            unsafe { task_nn(a, bt, level, cp.get(), i0, i1, k, n) };
            epilogue(level, cp.get(), i0, i1, n, bias, prec);
        });
    });
}

#[allow(clippy::too_many_arguments)]
fn gemm_tn_impl(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
    exec: Exec,
    level: simd::Level,
) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    check_cb(c, m, n, bias);
    let cp = SendPtr(c.as_mut_ptr());
    // Pack Aᵀ once on the submitting thread (see `gemm_nt_impl` — same
    // pack-once rationale, bitwise-identical results).
    with_pack(m * k, |at| {
        pack_at(a, at, m, k);
        let at: &[f32] = at;
        run_row_blocks(m, m * k * n, exec, |i0, i1| {
            // SAFETY: this task exclusively owns output rows i0..i1;
            // the operand slices are only read.
            unsafe { task_nn(at, b, level, cp.get(), i0, i1, k, n) };
            epilogue(level, cp.get(), i0, i1, n, bias, prec);
        });
    });
}

fn check_cb(c: &[f32], m: usize, n: usize, bias: Option<&[f32]>) {
    assert_eq!(c.len(), m * n);
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "bias length must equal the output width");
    }
}

/// Split rows into `MC` blocks and run `f(i0, i1)` per block, via the
/// pool when the job is worth it. The decomposition depends only on `m`.
fn run_row_blocks(m: usize, macs: usize, exec: Exec, f: impl Fn(usize, usize) + Sync) {
    if m == 0 {
        return;
    }
    let ntasks = m.div_ceil(MC);
    let body = |t: usize| {
        let i0 = t * MC;
        let i1 = (i0 + MC).min(m);
        f(i0, i1);
    };
    let parallel = exec == Exec::Auto && ntasks > 1 && macs >= PAR_MIN_MACS;
    if parallel {
        pool::global().run(ntasks, body);
    } else {
        for t in 0..ntasks {
            body(t);
        }
    }
}

/// Post-accumulation pass over one task's rows: bias add + quantize,
/// both vectorized at `level`. The bias add is elementwise (lane
/// grouping cannot change results) and the quantizer's vector body is
/// bitwise-pinned to its scalar oracle, so the fused epilogue stays
/// level-invariant. The RNE quantize inside `q_slice` dispatches at the
/// *detected* level (the `_at` seams pin only the kernels; quantizer
/// levels are pinned by their own parity tests).
fn epilogue(
    level: simd::Level,
    c: *mut f32,
    i0: usize,
    i1: usize,
    n: usize,
    bias: Option<&[f32]>,
    prec: Precision,
) {
    if bias.is_none() && !prec.is_low() {
        return;
    }
    for i in i0..i1 {
        // SAFETY: this task exclusively owns rows i0..i1.
        let row = unsafe { std::slice::from_raw_parts_mut(c.add(i * n), n) };
        if let Some(bs) = bias {
            simd::add_slice_at(level, row, bs);
        }
        prec.q_slice(row);
    }
}

// ---------------------------------------------------------------------
// per-task bodies
// ---------------------------------------------------------------------

/// notrans · notrans: stream B panels directly (rows are unit-stride).
// SAFETY: callers pass `c` valid for writes over rows i0..i1 of an
// i1×n row-major output, grant this task exclusive access to those
// rows, and size `a` as [≥i1, k] and `b` as [k, n].
#[allow(clippy::too_many_arguments)]
unsafe fn task_nn(
    a: &[f32],
    b: &[f32],
    level: simd::Level,
    c: *mut f32,
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
) {
    let mut kc = 0;
    while kc < k {
        let kl = KC.min(k - kc);
        // SAFETY: panel bases stay inside `a`/`b` (kc < k), and the
        // caller contract covers every write through `c`.
        unsafe {
            inner_tiles(
                level,
                a.as_ptr().add(i0 * k + kc),
                k,
                b.as_ptr().add(kc * n),
                n,
                c,
                i0,
                i1,
                n,
                kl,
            );
        }
        kc += KC;
    }
}

/// notrans · notrans over a packed-half B: KC panels, widening kernels.
// SAFETY: callers pass `c` valid for writes over rows i0..i1 of an
// i1×n row-major output, grant this task exclusive access to those
// rows, and size `a` as [≥i1, k] and `b` as [k, n] packed bits.
#[allow(clippy::too_many_arguments)]
unsafe fn task_nn_half(
    a: &[f32],
    b: &[u16],
    fmt: HalfFormat,
    level: simd::Level,
    c: *mut f32,
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
) {
    let mut kc = 0;
    while kc < k {
        let kl = KC.min(k - kc);
        // SAFETY: panel bases stay inside `a`/`b` (kc < k), and the
        // caller contract covers every write through `c`.
        unsafe {
            inner_tiles_half(
                fmt,
                level,
                a.as_ptr().add(i0 * k + kc),
                k,
                b.as_ptr().add(kc * n),
                n,
                c,
                i0,
                i1,
                n,
                kl,
            );
        }
        kc += KC;
    }
}

/// Packed-half twin of [`inner_tiles`]: same micro-tile sweep, with the
/// full tile dispatched to the (level-selected) widening kernel and the
/// edges to the scalar widening kernel.
// SAFETY: callers pass `a`/`b` panels holding kl full rows from their
// bases at the given strides, and `c` writable over rows i0..i1 of an
// i1×n row-major output that this call exclusively owns.
#[allow(clippy::too_many_arguments)]
unsafe fn inner_tiles_half(
    fmt: HalfFormat,
    level: simd::Level,
    a: *const f32,
    a_rs: usize,
    b: *const u16,
    b_rs: usize,
    c: *mut f32,
    i0: usize,
    i1: usize,
    n: usize,
    kl: usize,
) {
    let mut j0 = 0;
    while j0 < n {
        let nr = NR.min(n - j0);
        let mut i = i0;
        while i < i1 {
            let mr = MR.min(i1 - i);
            // SAFETY: tile bases stay inside the panels / output rows
            // the caller contract grants (i < i1, j0 < n), and the
            // kernels only touch mr×nr elements from those bases.
            unsafe {
                let ap = a.add((i - i0) * a_rs);
                let bp = b.add(j0);
                let cp = c.add(i * n + j0);
                if mr == MR && nr == NR {
                    simd::kernel_4x16_half(level, fmt, ap, a_rs, bp, b_rs, cp, n, kl);
                } else {
                    simd::kernel_edge_half(fmt, ap, a_rs, bp, b_rs, cp, n, mr, nr, kl);
                }
            }
            i += MR;
        }
        j0 += NR;
    }
}

/// Sweep the (row, column) micro-tiles of one task block for one panel.
/// `a` points at the panel base for row `i0` with row stride `a_rs`;
/// `b` points at the panel base with row stride `b_rs`. Full tiles
/// dispatch to the (level-selected) f32 kernel in [`simd`]; edges stay
/// on the scalar edge kernel.
// SAFETY: callers pass `a`/`b` panels holding kl full rows from their
// bases at the given strides, and `c` writable over rows i0..i1 of an
// i1×n row-major output that this call exclusively owns.
#[allow(clippy::too_many_arguments)]
unsafe fn inner_tiles(
    level: simd::Level,
    a: *const f32,
    a_rs: usize,
    b: *const f32,
    b_rs: usize,
    c: *mut f32,
    i0: usize,
    i1: usize,
    n: usize,
    kl: usize,
) {
    let mut j0 = 0;
    while j0 < n {
        let nr = NR.min(n - j0);
        let mut i = i0;
        while i < i1 {
            let mr = MR.min(i1 - i);
            // SAFETY: tile bases stay inside the panels / output rows
            // the caller contract grants (i < i1, j0 < n), and the
            // kernels only touch mr×nr elements from those bases.
            unsafe {
                let ap = a.add((i - i0) * a_rs);
                let bp = b.add(j0);
                let cp = c.add(i * n + j0);
                if mr == MR && nr == NR {
                    simd::kernel_4x16_f32(level, ap, a_rs, bp, b_rs, cp, n, kl);
                } else {
                    kernel_edge(ap, a_rs, bp, b_rs, cp, n, mr, nr, kl);
                }
            }
            i += MR;
        }
        j0 += NR;
    }
}

/// Edge-tile kernel (`mr ≤ MR`, `nr ≤ NR`) with the identical
/// ascending-`p` accumulation order as [`kernel_4x16`].
// SAFETY: callers pass `a`/`b` panels holding kl rows of mr/nr live
// columns at their strides, and `c` writable for an mr×nr tile at row
// stride `c_rs` that this call exclusively owns.
#[allow(clippy::too_many_arguments)]
unsafe fn kernel_edge(
    a: *const f32,
    a_rs: usize,
    b: *const f32,
    b_rs: usize,
    c: *mut f32,
    c_rs: usize,
    mr: usize,
    nr: usize,
    kl: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    // SAFETY: every offset below stays inside the mr×kl / kl×nr panels
    // and the mr×nr output tile the caller contract grants.
    unsafe {
        for p in 0..kl {
            let bp = b.add(p * b_rs);
            for r in 0..mr {
                let av = *a.add(r * a_rs + p);
                for j in 0..nr {
                    acc[r][j] += av * *bp.add(j);
                }
            }
        }
        for (r, row) in acc.iter().enumerate().take(mr) {
            let cr = c.add(r * c_rs);
            for (j, &v) in row.iter().enumerate().take(nr) {
                *cr.add(j) += v;
            }
        }
    }
}

/// The seed engine's row-parallel scalar GEMMs, kept verbatim: the perf
/// baseline `benches/gemm_blocked.rs` measures against, and a second
/// oracle for the property tests.
pub mod reference {
    /// Threads the reference path fans out over (seed behaviour).
    fn num_threads() -> usize {
        // tidy-allow(determinism): seed baseline kept verbatim — the
        // thread count only picks the row split, never the results.
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
    }

    /// Seed `par_rows`: per-call `thread::scope` spawning.
    fn par_rows(rows: usize, min_serial: usize, f: impl Fn(usize) + Sync) {
        let nt = num_threads();
        if rows * 2 < min_serial || nt <= 1 || rows < 2 * nt {
            for r in 0..rows {
                f(r);
            }
            return;
        }
        let chunk = rows.div_ceil(nt);
        // tidy-allow(determinism): seed baseline kept verbatim — each
        // row is computed independently, so the thread split cannot
        // change results.
        std::thread::scope(|s| {
            for t in 0..nt {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(rows);
                if lo >= hi {
                    break;
                }
                let f = &f;
                s.spawn(move || {
                    for r in lo..hi {
                        f(r);
                    }
                });
            }
        });
    }

    struct SendPtr(*mut f32);
    // SAFETY: par_rows hands every spawned thread a disjoint row range,
    // so all access through this pointer is to disjoint elements.
    unsafe impl Send for SendPtr {}
    // SAFETY: as above — concurrent access is always to disjoint rows.
    unsafe impl Sync for SendPtr {}

    impl SendPtr {
        #[inline]
        fn at(&self, off: usize) -> *mut f32 {
            // SAFETY: callers pass offsets inside the m×n output buffer.
            unsafe { self.0.add(off) }
        }
    }

    /// Seed `gemm`: `c += a·b`, scalar row loop.
    pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        let cptr = SendPtr(c.as_mut_ptr());
        par_rows(m, 64, |i| {
            // SAFETY: row i is exclusively owned by this task.
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.at(i * n), n) };
            let arow = &a[i * k..(i + 1) * k];
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        });
    }

    /// Seed `gemm_nt`: `c += a·bᵀ`, scalar dot products.
    pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), n * k);
        assert_eq!(c.len(), m * n);
        let cptr = SendPtr(c.as_mut_ptr());
        par_rows(m, 64, |i| {
            // SAFETY: row i is exclusively owned by this task.
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.at(i * n), n) };
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += arow[p] * brow[p];
                }
                crow[j] += acc;
            }
        });
    }

    /// Seed `gemm_tn`: `c += aᵀ·b`, scalar row loop.
    pub fn gemm_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), k * m);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        let cptr = SendPtr(c.as_mut_ptr());
        par_rows(m, 64, |i| {
            // SAFETY: row i is exclusively owned by this task.
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.at(i * n), n) };
            for p in 0..k {
                let av = a[p * m + i];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::{OverflowMode, RoundMode, FP16};
    use crate::rngs::Pcg64;

    /// f64 oracle for `c = a[m,k]·b[k,n]` (row-major, no transpose).
    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    fn randn(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    fn close(got: &[f32], want: &[f32], tag: &str) {
        assert_eq!(got.len(), want.len());
        for (i, (x, y)) in got.iter().zip(want).enumerate() {
            assert!(
                (x - y).abs() < 1e-3 * (1.0 + y.abs()),
                "{tag}[{i}]: {x} vs {y}"
            );
        }
    }

    /// Odd shapes: unit, primes, tall-skinny, wide, and sizes that cross
    /// the MR/NR/MC/KC tile boundaries.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (2, 3, 4),
        (5, 7, 3),
        (17, 33, 9),
        (4, 16, 16),
        (64, 64, 64),
        (65, 64, 17),
        (257, 8, 3),   // tall-skinny
        (3, 8, 257),   // wide
        (13, 300, 40), // crosses the KC panel boundary
        (130, 40, 70),
    ];

    #[test]
    fn gemm_matches_f64_oracle() {
        let mut rng = Pcg64::seed(1);
        for &(m, k, n) in SHAPES {
            let a = randn(m * k, &mut rng);
            let b = randn(k * n, &mut rng);
            let mut c = vec![0.0; m * n];
            gemm(&a, &b, &mut c, m, k, n);
            close(&c, &naive_gemm(&a, &b, m, k, n), &format!("nn {m}x{k}x{n}"));
        }
    }

    #[test]
    fn gemm_nt_matches_f64_oracle() {
        let mut rng = Pcg64::seed(2);
        for &(m, k, n) in SHAPES {
            let a = randn(m * k, &mut rng);
            let b = randn(n * k, &mut rng);
            // bt[k,n]
            let mut bt = vec![0.0; k * n];
            for j in 0..n {
                for p in 0..k {
                    bt[p * n + j] = b[j * k + p];
                }
            }
            let mut c = vec![0.0; m * n];
            gemm_nt(&a, &b, &mut c, m, k, n);
            close(&c, &naive_gemm(&a, &bt, m, k, n), &format!("nt {m}x{k}x{n}"));
        }
    }

    #[test]
    fn gemm_tn_matches_f64_oracle() {
        let mut rng = Pcg64::seed(3);
        for &(m, k, n) in SHAPES {
            let a = randn(k * m, &mut rng);
            let b = randn(k * n, &mut rng);
            let mut at = vec![0.0; m * k];
            for i in 0..m {
                for p in 0..k {
                    at[i * k + p] = a[p * m + i];
                }
            }
            let mut c = vec![0.0; m * n];
            gemm_tn(&a, &b, &mut c, m, k, n);
            close(&c, &naive_gemm(&at, &b, m, k, n), &format!("tn {m}x{k}x{n}"));
        }
    }

    #[test]
    fn blocked_matches_seed_reference() {
        let mut rng = Pcg64::seed(4);
        let (m, k, n) = (70, 90, 50);
        let a = randn(m * k, &mut rng);
        let b = randn(k * n, &mut rng);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        gemm(&a, &b, &mut c1, m, k, n);
        reference::gemm(&a, &b, &mut c2, m, k, n);
        close(&c1, &c2, "vs seed nn");

        let bt = randn(n * k, &mut rng);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        gemm_nt(&a, &bt, &mut c1, m, k, n);
        reference::gemm_nt(&a, &bt, &mut c2, m, k, n);
        close(&c1, &c2, "vs seed nt");

        let at = randn(k * m, &mut rng);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        gemm_tn(&at, &b, &mut c1, m, k, n);
        reference::gemm_tn(&at, &b, &mut c2, m, k, n);
        close(&c1, &c2, "vs seed tn");
    }

    #[test]
    fn gemm_accumulates_into_c() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![2.0, 0.0, 0.0, 2.0];
        let mut c = vec![1.0; 4];
        gemm(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![3.0, 1.0, 1.0, 3.0]);
    }

    #[test]
    fn pooled_and_serial_execution_are_bitwise_identical() {
        // large enough to clear PAR_MIN_MACS and span several MC blocks
        let mut rng = Pcg64::seed(5);
        let (m, k, n) = (300, 80, 70);
        let a = randn(m * k, &mut rng);
        let b = randn(k * n, &mut rng);
        let lv = simd::detect();
        let mut c_pool = vec![0.0; m * n];
        let mut c_serial = vec![0.0; m * n];
        gemm_nn_impl(&a, &b, &mut c_pool, m, k, n, None, Precision::Fp32, Exec::Auto, lv);
        gemm_nn_impl(&a, &b, &mut c_serial, m, k, n, None, Precision::Fp32, Exec::Serial, lv);
        assert!(
            c_pool.iter().zip(&c_serial).all(|(x, y)| x.to_bits() == y.to_bits()),
            "pooled vs serial results must be bitwise identical"
        );

        let bt = randn(n * k, &mut rng);
        let mut c_pool = vec![0.0; m * n];
        let mut c_serial = vec![0.0; m * n];
        gemm_nt_impl(&a, &bt, &mut c_pool, m, k, n, None, Precision::fp16(), Exec::Auto, lv);
        gemm_nt_impl(&a, &bt, &mut c_serial, m, k, n, None, Precision::fp16(), Exec::Serial, lv);
        assert!(c_pool.iter().zip(&c_serial).all(|(x, y)| x.to_bits() == y.to_bits()));

        let at = randn(k * m, &mut rng);
        let mut c_pool = vec![0.0; m * n];
        let mut c_serial = vec![0.0; m * n];
        gemm_tn_impl(&at, &b, &mut c_pool, m, k, n, None, Precision::Fp32, Exec::Auto, lv);
        gemm_tn_impl(&at, &b, &mut c_serial, m, k, n, None, Precision::Fp32, Exec::Serial, lv);
        assert!(c_pool.iter().zip(&c_serial).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn repeated_runs_are_bitwise_identical() {
        let mut rng = Pcg64::seed(6);
        let (m, k, n) = (200, 128, 96);
        let a = randn(m * k, &mut rng);
        let b = randn(n * k, &mut rng);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        gemm_nt(&a, &b, &mut c1, m, k, n);
        gemm_nt(&a, &b, &mut c2, m, k, n);
        assert!(c1.iter().zip(&c2).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn fused_epilogue_is_bitwise_equal_to_separate_passes() {
        let mut rng = Pcg64::seed(7);
        for &(m, k, n) in &[(5, 7, 3), (33, 20, 17), (64, 64, 64)] {
            let a = randn(m * k, &mut rng);
            let b = randn(n * k, &mut rng);
            let bias = randn(n, &mut rng);
            let prec = Precision::fp16();

            let mut fused = vec![0.0; m * n];
            gemm_nt_bias_q(&a, &b, &mut fused, m, k, n, Some(&bias), prec);

            let mut sep = vec![0.0; m * n];
            gemm_nt(&a, &b, &mut sep, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    sep[i * n + j] += bias[j];
                }
            }
            prec.q_slice(&mut sep);

            assert!(
                fused.iter().zip(&sep).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{m}x{k}x{n}: fused epilogue must match gemm+bias+quantize exactly"
            );
            for &v in &fused {
                assert!(FP16.is_representable(v));
            }
        }
    }

    #[test]
    fn fused_quantize_respects_round_and_overflow_modes() {
        let mut rng = Pcg64::seed(8);
        let (m, k, n) = (9, 11, 6);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32() * 200.0).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal_f32() * 200.0).collect();
        let prec = Precision::Sim {
            fmt: FP16,
            round: RoundMode::TowardZero,
            overflow: OverflowMode::Saturate,
        };
        let mut fused = vec![0.0; m * n];
        gemm_nt_bias_q(&a, &b, &mut fused, m, k, n, None, prec);
        let mut sep = vec![0.0; m * n];
        gemm_nt(&a, &b, &mut sep, m, k, n);
        prec.q_slice(&mut sep);
        assert!(fused.iter().zip(&sep).all(|(x, y)| x.to_bits() == y.to_bits()));
        // saturate mode must never emit infinities
        assert!(fused.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn degenerate_shapes_are_safe() {
        // m = 0: no-op
        gemm(&[], &[0.0; 12], &mut [], 0, 3, 4);
        // k = 0: product is zero, epilogue still applies bias+quantize
        let mut c = vec![0.0; 6];
        gemm_nt_bias_q(&[], &[], &mut c, 2, 0, 3, Some(&[1.0, 2.0, 1e-9]), Precision::fp16());
        assert_eq!(c, vec![1.0, 2.0, 0.0, 1.0, 2.0, 0.0]);
        // n = 0: no columns
        gemm(&[1.0, 2.0], &[], &mut [], 2, 1, 0);
    }

    #[test]
    fn paired_dispatch_is_bitwise_equal_to_two_calls() {
        let mut rng = Pcg64::seed(9);
        for &(m, k, n) in &[(1, 1, 1), (5, 7, 3), (33, 20, 17), (130, 64, 96)] {
            let a1 = randn(m * k, &mut rng);
            let a2 = randn(m * k, &mut rng);
            let b1 = randn(n * k, &mut rng);
            let b2 = randn(n * k, &mut rng);
            let bias1 = randn(n, &mut rng);
            let bias2 = randn(n, &mut rng);
            let prec = Precision::fp16();

            let mut p1 = vec![0.0; m * n];
            let mut p2 = vec![0.0; m * n];
            gemm_nt_bias_q_pair(
                &a1,
                &b1,
                &mut p1,
                Some(&bias1),
                &a2,
                &b2,
                &mut p2,
                Some(&bias2),
                m,
                k,
                n,
                prec,
            );

            let mut s1 = vec![0.0; m * n];
            let mut s2 = vec![0.0; m * n];
            gemm_nt_bias_q(&a1, &b1, &mut s1, m, k, n, Some(&bias1), prec);
            gemm_nt_bias_q(&a2, &b2, &mut s2, m, k, n, Some(&bias2), prec);

            assert!(
                p1.iter().zip(&s1).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{m}x{k}x{n}: paired head 1 must match a standalone call bitwise"
            );
            assert!(
                p2.iter().zip(&s2).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{m}x{k}x{n}: paired head 2 must match a standalone call bitwise"
            );
        }
    }

    #[test]
    fn packed_half_b_matches_f32_path_on_widened_weights() {
        // the packed path widens exactly and accumulates in the same
        // order, so for any packed B the result equals the f32 GEMM run
        // on the widened weights — bitwise, for every shape
        let mut rng = Pcg64::seed(11);
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            for &(m, k, n) in SHAPES {
                let a = randn(m * k, &mut rng);
                let bh: Vec<u16> = (0..n * k).map(|_| fmt.encode(rng.normal_f32())).collect();
                let mut bw = vec![0.0f32; n * k];
                fmt.unpack_slice(&bh, &mut bw);
                let bias = randn(n, &mut rng);
                let prec = Precision::fp16();

                let mut ch = vec![0.0; m * n];
                gemm_nt_bias_q_half(&a, &bh, fmt, &mut ch, m, k, n, Some(&bias), prec);
                let mut cf = vec![0.0; m * n];
                gemm_nt_bias_q(&a, &bw, &mut cf, m, k, n, Some(&bias), prec);
                assert!(
                    ch.iter().zip(&cf).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} {m}x{k}x{n}: half-B GEMM must match f32 GEMM on widened weights",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn packed_half_pair_matches_two_standalone_calls() {
        let mut rng = Pcg64::seed(12);
        let fmt = HalfFormat::F16;
        for &(m, k, n) in &[(1, 1, 1), (5, 7, 3), (33, 20, 17), (130, 64, 96)] {
            let a1 = randn(m * k, &mut rng);
            let a2 = randn(m * k, &mut rng);
            let b1: Vec<u16> = (0..n * k).map(|_| fmt.encode(rng.normal_f32())).collect();
            let b2: Vec<u16> = (0..n * k).map(|_| fmt.encode(rng.normal_f32())).collect();
            let bias1 = randn(n, &mut rng);
            let bias2 = randn(n, &mut rng);
            let prec = Precision::fp16();

            let mut p1 = vec![0.0; m * n];
            let mut p2 = vec![0.0; m * n];
            gemm_nt_bias_q_pair_half(
                &a1,
                &b1,
                &mut p1,
                Some(&bias1),
                &a2,
                &b2,
                &mut p2,
                Some(&bias2),
                fmt,
                m,
                k,
                n,
                prec,
            );

            let mut s1 = vec![0.0; m * n];
            let mut s2 = vec![0.0; m * n];
            gemm_nt_bias_q_half(&a1, &b1, fmt, &mut s1, m, k, n, Some(&bias1), prec);
            gemm_nt_bias_q_half(&a2, &b2, fmt, &mut s2, m, k, n, Some(&bias2), prec);

            assert!(p1.iter().zip(&s1).all(|(x, y)| x.to_bits() == y.to_bits()), "{m}x{k}x{n}");
            assert!(p2.iter().zip(&s2).all(|(x, y)| x.to_bits() == y.to_bits()), "{m}x{k}x{n}");
        }
        // m = 0 degenerate pair: no-op
        let bz = [0u16; 12];
        gemm_nt_bias_q_pair_half(
            &[],
            &bz,
            &mut [],
            None,
            &[],
            &bz,
            &mut [],
            None,
            fmt,
            0,
            3,
            4,
            Precision::fp16(),
        );
    }

    #[test]
    fn paired_pool_and_serial_are_bitwise_identical() {
        // large enough to clear the combined PAR_MIN_MACS threshold
        let mut rng = Pcg64::seed(10);
        let (m, k, n) = (300, 80, 70);
        let a1 = randn(m * k, &mut rng);
        let a2 = randn(m * k, &mut rng);
        let b1 = randn(n * k, &mut rng);
        let b2 = randn(n * k, &mut rng);
        let mut p1 = vec![0.0; m * n];
        let mut p2 = vec![0.0; m * n];
        let mut s1 = vec![0.0; m * n];
        let mut s2 = vec![0.0; m * n];
        let prec = Precision::fp16();
        let lv = simd::detect();
        gemm_nt_pair_impl(
            &a1, &b1, &mut p1, None, &a2, &b2, &mut p2, None, m, k, n, prec, Exec::Auto, lv,
        );
        gemm_nt_pair_impl(
            &a1, &b1, &mut s1, None, &a2, &b2, &mut s2, None, m, k, n, prec, Exec::Serial, lv,
        );
        assert!(p1.iter().zip(&s1).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(p2.iter().zip(&s2).all(|(x, y)| x.to_bits() == y.to_bits()));

        // m = 0 degenerate pair: no-op
        let bz = [0.0; 12];
        gemm_nt_bias_q_pair(&[], &bz, &mut [], None, &[], &bz, &mut [], None, 0, 3, 4, prec);
    }
}
