//! Fully-connected layer with explicit backward and optional weight
//! standardization (the paper's fix for layer-norm overflow in the pixel
//! encoder, §4.6 / Appendix G).
//!
//! Layout follows PyTorch: `w` is `[out, in]`, `y = x wᵀ + b`.
//!
//! The forward pass is `&self` — a layer can be shared across threads
//! for inference. Training-time activation caches live in an explicit
//! [`LinearWorkspace`] owned by the caller: `forward_train` fills it,
//! `backward` consumes it.

use super::gemm::{
    gemm_bias_q, gemm_nt_bias_q, gemm_nt_bias_q_half, gemm_nt_bias_q_pair,
    gemm_nt_bias_q_pair_half, gemm_tn_bias_q,
};
use super::param::Param;
use super::tensor::Tensor;
use crate::lowp::{HalfFormat, HalfTensor, Precision};
use crate::rngs::Pcg64;

/// Training-time caches for one [`Linear`]: the forward input plus the
/// standardized weights (and their per-row statistics) when
/// `weight_std` is on. Populated by [`Linear::forward_train`], read by
/// [`Linear::backward`].
#[derive(Debug, Clone, Default)]
pub struct LinearWorkspace {
    x: Tensor,
    what: Vec<f32>,    // standardized weights used in the last forward
    row_std: Vec<f32>, // per-row 1/std used by backward
    row_mean: Vec<f32>,
    dwhat: Vec<f32>, // dŴ scratch for `backward_into` (grown once, reused)
}

/// A linear layer `y = x Ŵᵀ + b`, where `Ŵ = w` normally, or the
/// row-standardized weights when `weight_std` is on.
#[derive(Debug, Clone)]
pub struct Linear {
    pub w: Param,
    pub b: Param,
    pub in_dim: usize,
    pub out_dim: usize,
    /// Weight standardization (Qiao et al., 2019): each output row of `w`
    /// is normalized to zero mean / unit std before use. Combined with
    /// layer-norm's rescaling invariance this prevents the fp16 overflow
    /// the paper saw in the encoder head.
    pub weight_std: bool,
    /// Packed 16-bit weight storage (see [`HalfTensor`]). When set, the
    /// inference forwards read these bits through the widening GEMM path
    /// instead of the f32 master — half the weight traffic. Kept bitwise
    /// consistent with `w` by the quantize-mirror in
    /// [`Linear::pack_weights`] / [`Linear::repack_weights`].
    pub w_half: Option<HalfTensor>,
}

impl Linear {
    pub fn new(name: &str, in_dim: usize, out_dim: usize, rng: &mut Pcg64) -> Self {
        let mut w = Param::new(format!("{name}.w"), &[out_dim, in_dim]);
        w.w = super::init::orthogonal_init(rng, out_dim, in_dim, 1.0);
        let b = Param::new(format!("{name}.b"), &[out_dim]);
        Linear { w, b, in_dim, out_dim, weight_std: false, w_half: None }
    }

    /// Pack the weights into 16-bit storage. The f32 master is
    /// *quantize-mirrored* — overwritten with `decode(encode(w))` — so the
    /// master and the packed bits name the exact same values and every
    /// forward is bitwise identical whichever tier the dispatch reads.
    /// No-op for live weight-std layers (their GEMM reads the
    /// re-standardized `Ŵ`, not `w`; bake first — see
    /// [`Linear::bake_weight_std`]).
    pub fn pack_weights(&mut self, fmt: HalfFormat) {
        if self.weight_std {
            return;
        }
        let packed = HalfTensor::pack(fmt, &self.w.shape, &self.w.w);
        packed.unpack_into(&mut self.w.w);
        self.w_half = Some(packed);
    }

    /// Drop the f32 weight master and its gradient buffer, leaving only
    /// the packed tier resident — the true 2× weight-memory reduction for
    /// frozen snapshots that will never train or repack again. Requires
    /// [`Linear::pack_weights`] first.
    pub fn drop_master(&mut self) {
        assert!(self.w_half.is_some(), "{}: pack_weights before drop_master", self.w.name);
        let _ = std::mem::take(&mut self.w.w);
        let _ = std::mem::take(&mut self.w.g);
    }

    /// Refresh the packed mirror from the (EMA-updated) f32 master,
    /// allocation-free, then quantize-mirror the master back so both
    /// tiers agree bitwise again. No-op when the layer is not packed.
    pub fn repack_weights(&mut self) {
        if let Some(h) = &mut self.w_half {
            h.repack_from(&self.w.w);
            h.unpack_into(&mut self.w.w);
        }
    }

    /// Resident weight bytes across storage tiers (f32 master if still
    /// held, packed mirror if present, plus the bias).
    pub fn weight_bytes(&self) -> usize {
        let f32s = std::mem::size_of::<f32>();
        self.w.w.len() * f32s
            + self.w_half.as_ref().map_or(0, |h| h.bytes())
            + self.b.w.len() * f32s
    }

    pub fn with_weight_std(mut self) -> Self {
        self.weight_std = true;
        self
    }

    /// Freeze the weight standardization into the stored weights: `w`
    /// becomes the standardized `Ŵ` (computed in `prec`, exactly as the
    /// forward would) and `weight_std` turns off. For frozen snapshots
    /// (policies that will never train again) this removes the
    /// per-forward re-standardization from the inference hot path while
    /// keeping every output bitwise identical — the GEMM sees the same
    /// `Ŵ` either way. No-op for plain layers.
    pub fn bake_weight_std(&mut self, prec: Precision) {
        if !self.weight_std {
            return;
        }
        let (mut what, mut mean, mut std) = (Vec::new(), Vec::new(), Vec::new());
        self.standardize_into(prec, &mut what, &mut mean, &mut std);
        self.w.w = what;
        self.weight_std = false;
    }

    /// Row-standardize `w` into `what` (resized in place — no per-call
    /// allocation once warm); `row_mean`/`row_std` get the per-row mean
    /// and 1/std the weight-std backward chain rule needs.
    /// Standardization arithmetic is done in the compute precision.
    fn standardize_into(
        &self,
        prec: Precision,
        what: &mut Vec<f32>,
        row_mean: &mut Vec<f32>,
        row_std: &mut Vec<f32>,
    ) {
        let (o, i) = (self.out_dim, self.in_dim);
        what.resize(o * i, 0.0);
        row_std.resize(o, 0.0);
        row_mean.resize(o, 0.0);
        for r in 0..o {
            let row = &self.w.w[r * i..(r + 1) * i];
            let mean = prec.q(row.iter().sum::<f32>() / i as f32);
            let var = prec.q(
                row.iter().map(|&v| prec.q((v - mean) * (v - mean))).sum::<f32>() / i as f32,
            );
            let std = prec.q((var + 1e-5).sqrt());
            let inv = prec.q(1.0 / std);
            row_mean[r] = mean;
            row_std[r] = inv;
            for c in 0..i {
                what[r * i + c] = prec.q((row[c] - mean) * inv);
            }
        }
    }

    /// Shared GEMM core: `y = x weffᵀ + b` written into `out` (buffer
    /// reused when shapes repeat), with the bias add + quantize fused
    /// into the GEMM epilogue — a single pass over `y` instead of
    /// three. The weights are read in place (no per-call clone).
    fn forward_with_into(&self, x: &Tensor, weff: &[f32], prec: Precision, out: &mut Tensor) {
        assert_eq!(x.cols(), self.in_dim, "{}: bad input dim", self.w.name);
        let bsz = x.rows();
        out.ensure_shape(&[bsz, self.out_dim]);
        // the GEMM accumulates into its output — zero the reused buffer
        // so results match a fresh `Tensor::zeros` bitwise
        out.data.fill(0.0);
        gemm_nt_bias_q(
            &x.data,
            weff,
            &mut out.data,
            bsz,
            self.in_dim,
            self.out_dim,
            Some(&self.b.w),
            prec,
        );
    }

    fn forward_with(&self, x: &Tensor, weff: &[f32], prec: Precision) -> Tensor {
        let mut y = Tensor::default();
        self.forward_with_into(x, weff, prec, &mut y);
        y
    }

    /// Inference forward: `y = x Ŵᵀ + b`, output quantized into `prec`.
    /// `&self` and cache-free — safe to call from many threads at once.
    /// Bitwise identical to [`Linear::forward_train`].
    pub fn forward(&self, x: &Tensor, prec: Precision) -> Tensor {
        let mut y = Tensor::default();
        self.forward_into(x, prec, &mut y);
        y
    }

    /// Allocation-free twin of [`Linear::forward`]: writes into `out`,
    /// reusing its buffer whenever the output shape repeats.
    pub fn forward_into(&self, x: &Tensor, prec: Precision, out: &mut Tensor) {
        if self.weight_std {
            // tidy-allow(alloc): weight-std layers only sit in the pixel
            // encoder head — the states-preset hot path never takes this
            // branch, and the trainers reach it via `forward_train_into`
            // (workspace-cached) instead
            let (mut what, mut mean, mut std) = (Vec::new(), Vec::new(), Vec::new());
            self.standardize_into(prec, &mut what, &mut mean, &mut std);
            self.forward_with_into(x, &what, prec, out);
        } else if let Some(h) = &self.w_half {
            self.forward_half_into(x, h, prec, out);
        } else {
            self.forward_with_into(x, &self.w.w, prec, out);
        }
    }

    /// Packed-tier forward body: same shape checks and epilogue as
    /// [`Linear::forward_with_into`], but the weights stream through the
    /// widening half-GEMM — bitwise identical by the quantize-mirror
    /// contract, half the weight bytes read.
    fn forward_half_into(&self, x: &Tensor, h: &HalfTensor, prec: Precision, out: &mut Tensor) {
        assert_eq!(x.cols(), self.in_dim, "{}: bad input dim", self.w.name);
        let bsz = x.rows();
        out.ensure_shape(&[bsz, self.out_dim]);
        // the GEMM accumulates — zero the reused buffer so results match
        // a fresh `Tensor::zeros` bitwise
        out.data.fill(0.0);
        gemm_nt_bias_q_half(
            &x.data,
            &h.data,
            h.fmt,
            &mut out.data,
            bsz,
            self.in_dim,
            self.out_dim,
            Some(&self.b.w),
            prec,
        );
    }

    /// Training forward: same numbers as [`Linear::forward`], but caches
    /// the input (and standardization buffers) into `ws` for
    /// [`Linear::backward`].
    pub fn forward_train(&self, x: &Tensor, prec: Precision, ws: &mut LinearWorkspace) -> Tensor {
        let mut y = Tensor::default();
        self.forward_train_into(x, prec, ws, &mut y);
        y
    }

    /// Allocation-free twin of [`Linear::forward_train`]: writes into
    /// `out`, reusing its buffer whenever the output shape repeats.
    pub fn forward_train_into(
        &self,
        x: &Tensor,
        prec: Precision,
        ws: &mut LinearWorkspace,
        out: &mut Tensor,
    ) {
        // clone_from reuses the cached tensor's allocation when shapes
        // repeat — the steady-state training loop caches without
        // allocating
        ws.x.shape.clone_from(&x.shape);
        ws.x.data.clone_from(&x.data);
        if self.weight_std {
            self.standardize_into(prec, &mut ws.what, &mut ws.row_mean, &mut ws.row_std);
            self.forward_with_into(x, &ws.what, prec, out);
        } else {
            self.forward_with_into(x, &self.w.w, prec, out);
        }
    }

    /// Inference forwards of two same-shape layers fused into a single
    /// pool dispatch (the twin-critic fast path, see
    /// [`gemm_nt_bias_q_pair`]). Per-layer outputs are bitwise identical
    /// to two [`Linear::forward`] calls, and the method falls back to
    /// exactly those when the layers cannot share a dispatch
    /// (weight standardization, or mismatched shapes).
    pub fn forward_pair(
        l1: &Linear,
        l2: &Linear,
        x1: &Tensor,
        x2: &Tensor,
        prec: Precision,
    ) -> (Tensor, Tensor) {
        let (mut y1, mut y2) = (Tensor::default(), Tensor::default());
        Self::forward_pair_into(l1, l2, x1, x2, prec, &mut y1, &mut y2);
        (y1, y2)
    }

    /// Allocation-free twin of [`Linear::forward_pair`]: writes into
    /// `y1`/`y2`, reusing their buffers whenever the shapes repeat.
    pub fn forward_pair_into(
        l1: &Linear,
        l2: &Linear,
        x1: &Tensor,
        x2: &Tensor,
        prec: Precision,
        y1: &mut Tensor,
        y2: &mut Tensor,
    ) {
        if l1.weight_std
            || l2.weight_std
            || l1.in_dim != l2.in_dim
            || l1.out_dim != l2.out_dim
            || x1.rows() != x2.rows()
        {
            l1.forward_into(x1, prec, y1);
            l2.forward_into(x2, prec, y2);
            return;
        }
        assert_eq!(x1.cols(), l1.in_dim, "{}: bad input dim", l1.w.name);
        assert_eq!(x2.cols(), l2.in_dim, "{}: bad input dim", l2.w.name);
        let bsz = x1.rows();
        y1.ensure_shape(&[bsz, l1.out_dim]);
        y2.ensure_shape(&[bsz, l2.out_dim]);
        // the GEMM accumulates — zero the reused buffers so results
        // match fresh `Tensor::zeros` bitwise
        y1.data.fill(0.0);
        y2.data.fill(0.0);
        match (&l1.w_half, &l2.w_half) {
            (Some(h1), Some(h2)) if h1.fmt == h2.fmt => gemm_nt_bias_q_pair_half(
                &x1.data,
                &h1.data,
                &mut y1.data,
                Some(&l1.b.w),
                &x2.data,
                &h2.data,
                &mut y2.data,
                Some(&l2.b.w),
                h1.fmt,
                bsz,
                l1.in_dim,
                l1.out_dim,
                prec,
            ),
            (None, None) => gemm_nt_bias_q_pair(
                &x1.data,
                &l1.w.w,
                &mut y1.data,
                Some(&l1.b.w),
                &x2.data,
                &l2.w.w,
                &mut y2.data,
                Some(&l2.b.w),
                bsz,
                l1.in_dim,
                l1.out_dim,
                prec,
            ),
            // mixed storage tiers cannot share a dispatch — per-layer
            // forwards, still bitwise identical
            _ => {
                l1.forward_into(x1, prec, y1);
                l2.forward_into(x2, prec, y2);
            }
        }
    }

    /// Training twin of [`Linear::forward_pair`]: fills each layer's
    /// workspace exactly as [`Linear::forward_train`] would.
    pub fn forward_train_pair(
        l1: &Linear,
        l2: &Linear,
        x1: &Tensor,
        x2: &Tensor,
        prec: Precision,
        ws1: &mut LinearWorkspace,
        ws2: &mut LinearWorkspace,
    ) -> (Tensor, Tensor) {
        let (mut y1, mut y2) = (Tensor::default(), Tensor::default());
        Self::forward_train_pair_into(l1, l2, x1, x2, prec, ws1, ws2, &mut y1, &mut y2);
        (y1, y2)
    }

    /// Allocation-free twin of [`Linear::forward_train_pair`].
    #[allow(clippy::too_many_arguments)]
    pub fn forward_train_pair_into(
        l1: &Linear,
        l2: &Linear,
        x1: &Tensor,
        x2: &Tensor,
        prec: Precision,
        ws1: &mut LinearWorkspace,
        ws2: &mut LinearWorkspace,
        y1: &mut Tensor,
        y2: &mut Tensor,
    ) {
        if l1.weight_std || l2.weight_std {
            // standardized layers also cache Ŵ and its row statistics —
            // let the plain path fill everything
            l1.forward_train_into(x1, prec, ws1, y1);
            l2.forward_train_into(x2, prec, ws2, y2);
            return;
        }
        ws1.x.shape.clone_from(&x1.shape);
        ws1.x.data.clone_from(&x1.data);
        ws2.x.shape.clone_from(&x2.shape);
        ws2.x.data.clone_from(&x2.data);
        Self::forward_pair_into(l1, l2, x1, x2, prec, y1, y2);
    }

    /// Backward: consumes `dy` and the workspace filled by the matching
    /// `forward_train`, accumulates `dw`/`db`, returns `dx`. Gradients
    /// are quantized into `prec` (tensor-level), matching the all-fp16
    /// training regime of the paper.
    pub fn backward(&mut self, dy: &Tensor, prec: Precision, ws: &LinearWorkspace) -> Tensor {
        // tidy-allow(alloc): allocating wrapper for tests/cold callers —
        // the learner hot path uses `backward_into` (workspace scratch)
        let mut dwhat = vec![0.0f32; self.out_dim * self.in_dim];
        let mut dx = Tensor::default();
        self.backward_core(dy, prec, &ws.x, &ws.what, &ws.row_std, &mut dwhat, &mut dx);
        dx
    }

    /// Allocation-free twin of [`Linear::backward`]: the dŴ scratch
    /// lives in the workspace and `dx` is written into a caller buffer,
    /// both reused whenever the shapes repeat.
    pub fn backward_into(
        &mut self,
        dy: &Tensor,
        prec: Precision,
        ws: &mut LinearWorkspace,
        dx: &mut Tensor,
    ) {
        let (o, i) = (self.out_dim, self.in_dim);
        ws.dwhat.resize(o * i, 0.0);
        // the dŴ GEMM accumulates — zero the reused scratch so results
        // match the fresh zeroed buffer `backward` starts from
        ws.dwhat.fill(0.0);
        let LinearWorkspace { x, what, row_std, dwhat, .. } = ws;
        self.backward_core(dy, prec, x, what, row_std, dwhat, dx);
    }

    /// Shared backward body; `dwhat` must arrive zeroed and sized `o*i`.
    fn backward_core(
        &mut self,
        dy: &Tensor,
        prec: Precision,
        x: &Tensor,
        ws_what: &[f32],
        ws_row_std: &[f32],
        dwhat: &mut [f32],
        dx: &mut Tensor,
    ) {
        let bsz = dy.rows();
        assert_eq!(dy.cols(), self.out_dim);
        assert_eq!(x.rows(), bsz, "forward_train workspace missing");
        let (o, i) = (self.out_dim, self.in_dim);

        // db = sum_b dy
        for r in 0..bsz {
            let row = dy.row(r);
            for c in 0..o {
                self.b.g[c] += row[c];
            }
        }
        prec.q_slice(&mut self.b.g);

        // dŴ = dyᵀ x  (into the scratch if standardized, else straight
        // in); the quantize pass is fused into the GEMM epilogue
        gemm_tn_bias_q(&dy.data, &x.data, dwhat, o, bsz, i, None, prec);

        if self.weight_std {
            // chain rule through Ŵ = (w - μ_r) * inv_r, per output row.
            // dμ and d(inv) terms: dW = inv * (dŴ - mean(dŴ) - Ŵ * mean(dŴ ⊙ Ŵ))
            for r in 0..o {
                let inv = ws_row_std[r];
                let what = &ws_what[r * i..(r + 1) * i];
                let dwr = &dwhat[r * i..(r + 1) * i];
                let mean_d = prec.q(dwr.iter().sum::<f32>() / i as f32);
                let mean_dw = prec.q(
                    dwr.iter().zip(what).map(|(&d, &h)| prec.q(d * h)).sum::<f32>() / i as f32,
                );
                for c in 0..i {
                    let d = prec.q(prec.q(dwr[c] - mean_d) - prec.q(what[c] * mean_dw));
                    self.w.g[r * i + c] += prec.q(inv * d);
                }
            }
        } else {
            for (gacc, d) in self.w.g.iter_mut().zip(dwhat.iter()) {
                *gacc += d;
            }
        }
        prec.q_slice(&mut self.w.g);

        // dx = dy Ŵ (quantize fused into the epilogue)
        dx.ensure_shape(&[bsz, i]);
        // the GEMM accumulates — zero the reused buffer so results
        // match a fresh `Tensor::zeros` bitwise
        dx.data.fill(0.0);
        {
            let weff = if self.weight_std { ws_what } else { &self.w.w[..] };
            // dx[b,i] = Σ_o dy[b,o] Ŵ[o,i]  — this is gemm notrans with Ŵ as [o,i]
            gemm_bias_q(&dy.data, weff, &mut dx.data, bsz, o, i, None, prec);
        }
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.b]
    }

    /// Visit the parameters in [`Linear::params_mut`] order without
    /// materializing a `Vec` (the allocation-free hot-path walk).
    pub fn for_each_param(&self, f: &mut impl FnMut(&Param)) {
        f(&self.w);
        f(&self.b);
    }

    /// Mutable twin of [`Linear::for_each_param`], same order.
    pub fn for_each_param_mut(&mut self, f: &mut impl FnMut(&mut Param)) {
        f(&mut self.w);
        f(&mut self.b);
    }

    pub fn zero_grad(&mut self) {
        self.w.zero_grad();
        self.b.zero_grad();
    }

    pub fn n_params(&self) -> usize {
        self.w.len() + self.b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::Precision;
    use crate::rngs::Pcg64;

    /// Finite-difference check of the full layer gradient in fp32.
    #[test]
    fn gradcheck_fp32() {
        let mut rng = Pcg64::seed(1);
        let mut lin = Linear::new("t", 5, 3, &mut rng);
        let x = Tensor::from_vec(&[2, 5], (0..10).map(|_| rng.normal_f32()).collect());
        let prec = Precision::Fp32;

        // loss = sum(y²)/2 ; dy = y
        let mut ws = LinearWorkspace::default();
        let y = lin.forward_train(&x, prec, &mut ws);
        let dy = y.clone();
        lin.zero_grad();
        let dx = lin.backward(&dy, prec, &ws);

        let eps = 1e-3f32;
        // check dw on a few entries
        for &idx in &[0usize, 3, 7, 14] {
            let orig = lin.w.w[idx];
            lin.w.w[idx] = orig + eps;
            let yp = lin.forward(&x, prec);
            lin.w.w[idx] = orig - eps;
            let ym = lin.forward(&x, prec);
            lin.w.w[idx] = orig;
            let lp: f32 = yp.data.iter().map(|v| v * v / 2.0).sum();
            let lm: f32 = ym.data.iter().map(|v| v * v / 2.0).sum();
            let num = (lp - lm) / (2.0 * eps);
            let ana = lin.w.g[idx];
            assert!((num - ana).abs() < 2e-2 * (1.0 + num.abs()), "w[{idx}]: {num} vs {ana}");
        }
        // check dx entries
        let mut x2 = x.clone();
        for &idx in &[0usize, 4, 9] {
            let orig = x2.data[idx];
            x2.data[idx] = orig + eps;
            let lp: f32 = lin.forward(&x2, prec).data.iter().map(|v| v * v / 2.0).sum();
            x2.data[idx] = orig - eps;
            let lm: f32 = lin.forward(&x2, prec).data.iter().map(|v| v * v / 2.0).sum();
            x2.data[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - dx.data[idx]).abs() < 2e-2 * (1.0 + num.abs()));
        }
    }

    #[test]
    fn gradcheck_weight_std() {
        let mut rng = Pcg64::seed(2);
        let mut lin = Linear::new("t", 6, 4, &mut rng).with_weight_std();
        let x = Tensor::from_vec(&[3, 6], (0..18).map(|_| rng.normal_f32()).collect());
        let prec = Precision::Fp32;
        let mut ws = LinearWorkspace::default();
        let y = lin.forward_train(&x, prec, &mut ws);
        lin.zero_grad();
        let _ = lin.backward(&y.clone(), prec, &ws);

        let eps = 1e-3f32;
        for &idx in &[0usize, 5, 11, 23] {
            let orig = lin.w.w[idx];
            lin.w.w[idx] = orig + eps;
            let lp: f32 = lin.forward(&x, prec).data.iter().map(|v| v * v / 2.0).sum();
            lin.w.w[idx] = orig - eps;
            let lm: f32 = lin.forward(&x, prec).data.iter().map(|v| v * v / 2.0).sum();
            lin.w.w[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            let ana = lin.w.g[idx];
            assert!(
                (num - ana).abs() < 3e-2 * (1.0 + num.abs()),
                "w[{idx}]: num={num} ana={ana}"
            );
        }
    }

    #[test]
    fn weight_std_rows_are_standardized() {
        let mut rng = Pcg64::seed(3);
        let mut lin = Linear::new("t", 64, 4, &mut rng).with_weight_std();
        // blow up one row; standardization must tame it
        for v in lin.w.w[0..64].iter_mut() {
            *v *= 1000.0;
        }
        let (mut w, mut mean, mut std) = (Vec::new(), Vec::new(), Vec::new());
        lin.standardize_into(Precision::Fp32, &mut w, &mut mean, &mut std);
        for r in 0..4 {
            let row = &w[r * 64..(r + 1) * 64];
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "row {r} var {var}");
        }
    }

    #[test]
    fn fp16_forward_quantizes_output() {
        let mut rng = Pcg64::seed(4);
        let lin = Linear::new("t", 8, 8, &mut rng);
        let x = Tensor::from_vec(&[1, 8], (0..8).map(|_| rng.normal_f32()).collect());
        let y = lin.forward(&x, Precision::fp16());
        for &v in &y.data {
            assert!(crate::lowp::FP16.is_representable(v));
        }
    }

    #[test]
    fn baked_weight_std_forward_is_bitwise_identical() {
        let mut rng = Pcg64::seed(6);
        let lin = Linear::new("t", 10, 6, &mut rng).with_weight_std();
        let x = Tensor::from_vec(&[4, 10], (0..40).map(|_| rng.normal_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let live = lin.forward(&x, prec);
            let mut frozen = lin.clone();
            frozen.bake_weight_std(prec);
            assert!(!frozen.weight_std);
            let baked = frozen.forward(&x, prec);
            assert!(live.data.iter().zip(&baked.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
    }

    #[test]
    fn pair_forwards_match_sequential_bitwise() {
        let mut rng = Pcg64::seed(7);
        let l1 = Linear::new("q1", 9, 5, &mut rng);
        let l2 = Linear::new("q2", 9, 5, &mut rng);
        let x1 = Tensor::from_vec(&[4, 9], (0..36).map(|_| rng.normal_f32()).collect());
        let x2 = Tensor::from_vec(&[4, 9], (0..36).map(|_| rng.normal_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let (y1, y2) = Linear::forward_pair(&l1, &l2, &x1, &x2, prec);
            let s1 = l1.forward(&x1, prec);
            let s2 = l2.forward(&x2, prec);
            assert!(y1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            assert!(y2.data.iter().zip(&s2.data).all(|(u, v)| u.to_bits() == v.to_bits()));

            let (mut wa, mut wb) = (LinearWorkspace::default(), LinearWorkspace::default());
            let (t1, t2) = Linear::forward_train_pair(&l1, &l2, &x1, &x2, prec, &mut wa, &mut wb);
            assert!(t1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            assert!(t2.data.iter().zip(&s2.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            // the workspaces must be filled exactly as forward_train fills them
            assert_eq!(wa.x.data, x1.data);
            assert_eq!(wb.x.data, x2.data);
        }

        // weight-std layers take the sequential fallback — still identical
        let l1 = Linear::new("q1", 6, 4, &mut rng).with_weight_std();
        let l2 = Linear::new("q2", 6, 4, &mut rng).with_weight_std();
        let x = Tensor::from_vec(&[2, 6], (0..12).map(|_| rng.normal_f32()).collect());
        let prec = Precision::fp16();
        let (y1, y2) = Linear::forward_pair(&l1, &l2, &x, &x, prec);
        let s1 = l1.forward(&x, prec);
        let s2 = l2.forward(&x, prec);
        assert!(y1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        assert!(y2.data.iter().zip(&s2.data).all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn packed_forward_matches_master_bitwise() {
        let mut rng = Pcg64::seed(8);
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            let mut lin = Linear::new("t", 33, 17, &mut rng);
            let x = Tensor::from_vec(&[5, 33], (0..165).map(|_| rng.normal_f32()).collect());
            let mut packed = lin.clone();
            packed.pack_weights(fmt);
            // quantize-mirror contract: the pack rewrote the master to
            // decode(encode(w)) — sync the reference layer to it
            lin.w.w.clone_from(&packed.w.w);
            for prec in [Precision::Fp32, Precision::fp16()] {
                let a = lin.forward(&x, prec);
                let b = packed.forward(&x, prec);
                assert!(
                    a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()),
                    "{fmt:?}/{prec:?}: packed dispatch must be bitwise identical"
                );
            }
            // dropping the master must not change the packed path
            let before = packed.forward(&x, Precision::Fp32);
            packed.drop_master();
            let after = packed.forward(&x, Precision::Fp32);
            assert_eq!(before.data, after.data);
            assert_eq!(packed.weight_bytes(), 17 * 33 * 2 + 17 * 4, "half weights + f32 bias");
        }
    }

    #[test]
    fn repack_refreshes_the_mirror_bitwise() {
        let mut rng = Pcg64::seed(9);
        let mut lin = Linear::new("t", 12, 6, &mut rng);
        lin.pack_weights(HalfFormat::F16);
        // simulate an EMA sync rewriting the master
        for v in lin.w.w.iter_mut() {
            *v = 0.37 * *v + 0.1;
        }
        let mut fresh = lin.clone();
        fresh.w_half = None;
        fresh.pack_weights(HalfFormat::F16);
        lin.repack_weights();
        let h1 = lin.w_half.as_ref().expect("packed");
        let h2 = fresh.w_half.as_ref().expect("packed");
        assert_eq!(h1.data, h2.data, "repack must equal a fresh pack");
        assert_eq!(lin.w.w, fresh.w.w, "masters must be mirrored back identically");
    }

    #[test]
    fn packed_pair_matches_sequential_bitwise() {
        let mut rng = Pcg64::seed(10);
        let mut l1 = Linear::new("q1", 9, 5, &mut rng);
        let mut l2 = Linear::new("q2", 9, 5, &mut rng);
        l1.pack_weights(HalfFormat::Bf16);
        l2.pack_weights(HalfFormat::Bf16);
        let x1 = Tensor::from_vec(&[4, 9], (0..36).map(|_| rng.normal_f32()).collect());
        let x2 = Tensor::from_vec(&[4, 9], (0..36).map(|_| rng.normal_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let (y1, y2) = Linear::forward_pair(&l1, &l2, &x1, &x2, prec);
            let s1 = l1.forward(&x1, prec);
            let s2 = l2.forward(&x2, prec);
            assert!(y1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            assert!(y2.data.iter().zip(&s2.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
        // mixed tiers fall back to per-layer dispatch — still identical
        let mut l3 = Linear::new("q3", 9, 5, &mut rng);
        l3.w.w.clone_from(&l2.w.w);
        l3.b.w.clone_from(&l2.b.w);
        let (y1, y3) = Linear::forward_pair(&l1, &l3, &x1, &x2, Precision::fp16());
        let s1 = l1.forward(&x1, Precision::fp16());
        let s3 = l3.forward(&x2, Precision::fp16());
        assert!(y1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        assert!(y3.data.iter().zip(&s3.data).all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn forward_and_forward_train_are_bitwise_identical() {
        let mut rng = Pcg64::seed(5);
        for weight_std in [false, true] {
            let mut lin = Linear::new("t", 12, 7, &mut rng);
            if weight_std {
                lin = lin.with_weight_std();
            }
            let x = Tensor::from_vec(&[3, 12], (0..36).map(|_| rng.normal_f32()).collect());
            for prec in [Precision::Fp32, Precision::fp16()] {
                let mut ws = LinearWorkspace::default();
                let a = lin.forward(&x, prec);
                let b = lin.forward_train(&x, prec, &mut ws);
                assert!(
                    a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()),
                    "weight_std={weight_std}"
                );
            }
        }
    }
}
