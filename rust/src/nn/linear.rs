//! Fully-connected layer with explicit backward and optional weight
//! standardization (the paper's fix for layer-norm overflow in the pixel
//! encoder, §4.6 / Appendix G).
//!
//! Layout follows PyTorch: `w` is `[out, in]`, `y = x wᵀ + b`.

use super::gemm::{gemm_bias_q, gemm_nt_bias_q, gemm_tn_bias_q};
use super::param::Param;
use super::tensor::Tensor;
use crate::lowp::Precision;
use crate::rngs::Pcg64;

/// A linear layer `y = x Ŵᵀ + b`, where `Ŵ = w` normally, or the
/// row-standardized weights when `weight_std` is on.
#[derive(Debug, Clone)]
pub struct Linear {
    pub w: Param,
    pub b: Param,
    pub in_dim: usize,
    pub out_dim: usize,
    /// Weight standardization (Qiao et al., 2019): each output row of `w`
    /// is normalized to zero mean / unit std before use. Combined with
    /// layer-norm's rescaling invariance this prevents the fp16 overflow
    /// the paper saw in the encoder head.
    pub weight_std: bool,
    // forward cache
    x_cache: Tensor,
    what_cache: Vec<f32>, // standardized weights used in forward
    row_std: Vec<f32>,    // per-row 1/std used by backward
    row_mean: Vec<f32>,
}

impl Linear {
    pub fn new(name: &str, in_dim: usize, out_dim: usize, rng: &mut Pcg64) -> Self {
        let mut w = Param::new(format!("{name}.w"), &[out_dim, in_dim]);
        w.w = super::init::orthogonal_init(rng, out_dim, in_dim, 1.0);
        let b = Param::new(format!("{name}.b"), &[out_dim]);
        Linear {
            w,
            b,
            in_dim,
            out_dim,
            weight_std: false,
            x_cache: Tensor::zeros(&[0]),
            what_cache: Vec::new(),
            row_std: Vec::new(),
            row_mean: Vec::new(),
        }
    }

    pub fn with_weight_std(mut self) -> Self {
        self.weight_std = true;
        self
    }

    /// Effective weights: standardized if `weight_std`, raw otherwise.
    /// Standardization arithmetic is done in the compute precision.
    /// (The forward path reads `what_cache` directly; this accessor is
    /// kept for the standardization unit tests.)
    #[cfg(test)]
    fn effective_weights(&mut self, prec: Precision) -> &[f32] {
        if !self.weight_std {
            return &self.w.w;
        }
        self.refresh_weight_std(prec);
        &self.what_cache
    }

    /// Recompute the row-standardized weights into the persistent
    /// `what_cache` buffer (resized in place — no per-forward allocation
    /// once warm, and the GEMM reads it without copying).
    fn refresh_weight_std(&mut self, prec: Precision) {
        let (o, i) = (self.out_dim, self.in_dim);
        self.what_cache.resize(o * i, 0.0);
        self.row_std.resize(o, 0.0);
        self.row_mean.resize(o, 0.0);
        for r in 0..o {
            let row = &self.w.w[r * i..(r + 1) * i];
            let mean = prec.q(row.iter().sum::<f32>() / i as f32);
            let var = prec.q(
                row.iter().map(|&v| prec.q((v - mean) * (v - mean))).sum::<f32>() / i as f32,
            );
            let std = prec.q((var + 1e-5).sqrt());
            let inv = prec.q(1.0 / std);
            self.row_mean[r] = mean;
            self.row_std[r] = inv;
            for c in 0..i {
                self.what_cache[r * i + c] = prec.q((row[c] - mean) * inv);
            }
        }
    }

    /// Forward: `y = x Ŵᵀ + b`, output quantized into `prec`.
    ///
    /// The GEMM reads the weights in place (no per-call clone of the
    /// weight matrix) and fuses the bias add + quantize into its epilogue
    /// — a single pass over `y` instead of three.
    pub fn forward(&mut self, x: &Tensor, prec: Precision) -> Tensor {
        assert_eq!(x.cols(), self.in_dim, "{}: bad input dim", self.w.name);
        let bsz = x.rows();
        self.x_cache = x.clone();
        if self.weight_std {
            self.refresh_weight_std(prec);
        }
        let mut y = Tensor::zeros(&[bsz, self.out_dim]);
        let weff: &[f32] = if self.weight_std { &self.what_cache } else { &self.w.w };
        gemm_nt_bias_q(
            &x.data,
            weff,
            &mut y.data,
            bsz,
            self.in_dim,
            self.out_dim,
            Some(&self.b.w),
            prec,
        );
        y
    }

    /// Backward: consumes `dy`, accumulates `dw`/`db`, returns `dx`.
    /// Gradients are quantized into `prec` (tensor-level), matching the
    /// all-fp16 training regime of the paper.
    pub fn backward(&mut self, dy: &Tensor, prec: Precision) -> Tensor {
        let bsz = dy.rows();
        assert_eq!(dy.cols(), self.out_dim);
        assert_eq!(self.x_cache.rows(), bsz, "forward cache missing");
        let (o, i) = (self.out_dim, self.in_dim);

        // db = sum_b dy
        for r in 0..bsz {
            let row = dy.row(r);
            for c in 0..o {
                self.b.g[c] += row[c];
            }
        }
        prec.q_slice(&mut self.b.g);

        // dŴ = dyᵀ x  (into a temp if standardized, else straight in);
        // the quantize pass is fused into the GEMM epilogue
        let mut dwhat = vec![0.0f32; o * i];
        gemm_tn_bias_q(&dy.data, &self.x_cache.data, &mut dwhat, o, bsz, i, None, prec);

        if self.weight_std {
            // chain rule through Ŵ = (w - μ_r) * inv_r, per output row.
            // dμ and d(inv) terms: dW = inv * (dŴ - mean(dŴ) - Ŵ * mean(dŴ ⊙ Ŵ))
            for r in 0..o {
                let inv = self.row_std[r];
                let what = &self.what_cache[r * i..(r + 1) * i];
                let dwr = &dwhat[r * i..(r + 1) * i];
                let mean_d = prec.q(dwr.iter().sum::<f32>() / i as f32);
                let mean_dw = prec.q(
                    dwr.iter().zip(what).map(|(&d, &h)| prec.q(d * h)).sum::<f32>() / i as f32,
                );
                for c in 0..i {
                    let d = prec.q(prec.q(dwr[c] - mean_d) - prec.q(what[c] * mean_dw));
                    self.w.g[r * i + c] += prec.q(inv * d);
                }
            }
        } else {
            for (gacc, d) in self.w.g.iter_mut().zip(&dwhat) {
                *gacc += d;
            }
        }
        prec.q_slice(&mut self.w.g);

        // dx = dy Ŵ (quantize fused into the epilogue)
        let mut dx = Tensor::zeros(&[bsz, i]);
        {
            let weff = if self.weight_std { &self.what_cache[..] } else { &self.w.w[..] };
            // dx[b,i] = Σ_o dy[b,o] Ŵ[o,i]  — this is gemm notrans with Ŵ as [o,i]
            gemm_bias_q(&dy.data, weff, &mut dx.data, bsz, o, i, None, prec);
        }
        dx
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.b]
    }

    pub fn zero_grad(&mut self) {
        self.w.zero_grad();
        self.b.zero_grad();
    }

    pub fn n_params(&self) -> usize {
        self.w.len() + self.b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::Precision;
    use crate::rngs::Pcg64;

    /// Finite-difference check of the full layer gradient in fp32.
    #[test]
    fn gradcheck_fp32() {
        let mut rng = Pcg64::seed(1);
        let mut lin = Linear::new("t", 5, 3, &mut rng);
        let x = Tensor::from_vec(&[2, 5], (0..10).map(|_| rng.normal_f32()).collect());
        let prec = Precision::Fp32;

        // loss = sum(y²)/2 ; dy = y
        let y = lin.forward(&x, prec);
        let dy = y.clone();
        lin.zero_grad();
        let dx = lin.backward(&dy, prec);

        let eps = 1e-3f32;
        // check dw on a few entries
        for &idx in &[0usize, 3, 7, 14] {
            let orig = lin.w.w[idx];
            lin.w.w[idx] = orig + eps;
            let yp = lin.forward(&x, prec);
            lin.w.w[idx] = orig - eps;
            let ym = lin.forward(&x, prec);
            lin.w.w[idx] = orig;
            let lp: f32 = yp.data.iter().map(|v| v * v / 2.0).sum();
            let lm: f32 = ym.data.iter().map(|v| v * v / 2.0).sum();
            let num = (lp - lm) / (2.0 * eps);
            let ana = lin.w.g[idx];
            assert!((num - ana).abs() < 2e-2 * (1.0 + num.abs()), "w[{idx}]: {num} vs {ana}");
        }
        // check dx entries
        let mut x2 = x.clone();
        for &idx in &[0usize, 4, 9] {
            let orig = x2.data[idx];
            x2.data[idx] = orig + eps;
            let lp: f32 = lin.forward(&x2, prec).data.iter().map(|v| v * v / 2.0).sum();
            x2.data[idx] = orig - eps;
            let lm: f32 = lin.forward(&x2, prec).data.iter().map(|v| v * v / 2.0).sum();
            x2.data[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - dx.data[idx]).abs() < 2e-2 * (1.0 + num.abs()));
        }
        // re-run forward to restore cache consistency (hygiene)
        let _ = lin.forward(&x, prec);
    }

    #[test]
    fn gradcheck_weight_std() {
        let mut rng = Pcg64::seed(2);
        let mut lin = Linear::new("t", 6, 4, &mut rng).with_weight_std();
        let x = Tensor::from_vec(&[3, 6], (0..18).map(|_| rng.normal_f32()).collect());
        let prec = Precision::Fp32;
        let y = lin.forward(&x, prec);
        lin.zero_grad();
        let _ = lin.backward(&y.clone(), prec);

        let eps = 1e-3f32;
        for &idx in &[0usize, 5, 11, 23] {
            let orig = lin.w.w[idx];
            lin.w.w[idx] = orig + eps;
            let lp: f32 = lin.forward(&x, prec).data.iter().map(|v| v * v / 2.0).sum();
            lin.w.w[idx] = orig - eps;
            let lm: f32 = lin.forward(&x, prec).data.iter().map(|v| v * v / 2.0).sum();
            lin.w.w[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            let ana = lin.w.g[idx];
            assert!(
                (num - ana).abs() < 3e-2 * (1.0 + num.abs()),
                "w[{idx}]: num={num} ana={ana}"
            );
        }
    }

    #[test]
    fn weight_std_rows_are_standardized() {
        let mut rng = Pcg64::seed(3);
        let mut lin = Linear::new("t", 64, 4, &mut rng).with_weight_std();
        // blow up one row; standardization must tame it
        for v in lin.w.w[0..64].iter_mut() {
            *v *= 1000.0;
        }
        let w = lin.effective_weights(Precision::Fp32).to_vec();
        for r in 0..4 {
            let row = &w[r * 64..(r + 1) * 64];
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "row {r} var {var}");
        }
    }

    #[test]
    fn fp16_forward_quantizes_output() {
        let mut rng = Pcg64::seed(4);
        let mut lin = Linear::new("t", 8, 8, &mut rng);
        let x = Tensor::from_vec(&[1, 8], (0..8).map(|_| rng.normal_f32()).collect());
        let y = lin.forward(&x, Precision::fp16());
        for &v in &y.data {
            assert!(crate::lowp::FP16.is_representable(v));
        }
    }
}
