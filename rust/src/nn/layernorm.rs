//! Layer normalization over the last dimension, with the fp16 overflow
//! behaviour the paper describes (§4.6): the internal variance is a mean
//! of *squares*, and in fp16 a pre-activation of magnitude ≳ 256 squares
//! past 65504 → ∞. We quantize the squared deviations at element level so
//! the failure (and the weight-standardization fix) reproduce faithfully.
//!
//! `forward` is `&self` (inference); the normalized activations the
//! backward pass reuses are cached in a [`LayerNormWorkspace`] by
//! `forward_train`.

use super::param::Param;
use super::tensor::Tensor;
use crate::lowp::Precision;

/// Training-time caches for one [`LayerNorm`]: normalized activations,
/// per-row inverse std, and the backward's per-row γ⊙dy scratch. All
/// buffers are grown once and reused across steps.
#[derive(Debug, Clone, Default)]
pub struct LayerNormWorkspace {
    xhat: Tensor,
    inv_std: Vec<f32>,
    gdy: Vec<f32>,
}

/// LayerNorm with learnable affine (γ, β), over the last dim.
#[derive(Debug, Clone)]
pub struct LayerNorm {
    pub gamma: Param,
    pub beta: Param,
    pub dim: usize,
    pub eps: f32,
}

impl LayerNorm {
    pub fn new(name: &str, dim: usize) -> Self {
        let mut gamma = Param::new(format!("{name}.gamma"), &[dim]);
        gamma.w.iter_mut().for_each(|v| *v = 1.0);
        let beta = Param::new(format!("{name}.beta"), &[dim]);
        LayerNorm { gamma, beta, dim, eps: 1e-5 }
    }

    /// Inference forward: `&self`, genuinely cache-free (no workspace
    /// tensor is materialized). The per-element op sequence is the same
    /// as [`LayerNorm::forward_train`], so outputs are bitwise
    /// identical.
    pub fn forward(&self, x: &Tensor, prec: Precision) -> Tensor {
        let mut y = Tensor::default();
        self.forward_into(x, prec, &mut y);
        y
    }

    /// Allocation-free twin of [`LayerNorm::forward`]: writes into `out`,
    /// reusing its buffer whenever the shape repeats.
    pub fn forward_into(&self, x: &Tensor, prec: Precision, out: &mut Tensor) {
        assert_eq!(x.cols(), self.dim);
        let rows = x.rows();
        let d = self.dim;
        let y = out;
        y.ensure_shape(&[rows, d]);
        for r in 0..rows {
            let xr = x.row(r);
            let mean = prec.q(xr.iter().sum::<f32>() / d as f32);
            let var = prec.q(
                xr.iter().map(|&v| prec.q(prec.q(v - mean) * prec.q(v - mean))).sum::<f32>()
                    / d as f32,
            );
            let inv = prec.q(1.0 / prec.q((var + self.eps).sqrt()));
            let yr = y.row_mut(r);
            for c in 0..d {
                let xh = prec.q(prec.q(xr[c] - mean) * inv);
                yr[c] = prec.q(self.gamma.w[c] * xh + self.beta.w[c]);
            }
        }
    }

    /// Training forward. Mean/variance are computed with per-element
    /// quantized squares (where the paper's overflow lives) and f32
    /// accumulation (as a warp-level tree reduction would give on
    /// hardware). Caches into `ws` for [`LayerNorm::backward`].
    pub fn forward_train(&self, x: &Tensor, prec: Precision, ws: &mut LayerNormWorkspace) -> Tensor {
        let mut y = Tensor::default();
        self.forward_train_into(x, prec, ws, &mut y);
        y
    }

    /// Allocation-free twin of [`LayerNorm::forward_train`]: the
    /// normalized-activation cache, per-row stats, and output all reuse
    /// their buffers whenever the shapes repeat.
    pub fn forward_train_into(
        &self,
        x: &Tensor,
        prec: Precision,
        ws: &mut LayerNormWorkspace,
        out: &mut Tensor,
    ) {
        assert_eq!(x.cols(), self.dim);
        let rows = x.rows();
        let d = self.dim;
        let y = out;
        y.ensure_shape(&[rows, d]);
        ws.xhat.ensure_shape(&[rows, d]);
        ws.inv_std.resize(rows, 0.0);
        for r in 0..rows {
            let xr = x.row(r);
            let mean = prec.q(xr.iter().sum::<f32>() / d as f32);
            // squared deviations, quantized per element — overflow site
            let var = prec.q(
                xr.iter().map(|&v| prec.q(prec.q(v - mean) * prec.q(v - mean))).sum::<f32>()
                    / d as f32,
            );
            let inv = prec.q(1.0 / prec.q((var + self.eps).sqrt()));
            ws.inv_std[r] = inv;
            let xh = ws.xhat.row_mut(r);
            for c in 0..d {
                xh[c] = prec.q(prec.q(xr[c] - mean) * inv);
            }
            let yr = y.row_mut(r);
            for c in 0..d {
                yr[c] = prec.q(self.gamma.w[c] * xh[c] + self.beta.w[c]);
            }
        }
    }

    /// Backward; accumulates dγ/dβ, returns dx. Allocating wrapper —
    /// the encoder walk uses [`LayerNorm::backward_into`].
    pub fn backward(&mut self, dy: &Tensor, prec: Precision, ws: &mut LayerNormWorkspace) -> Tensor {
        let mut dx = Tensor::default();
        self.backward_into(dy, prec, ws, &mut dx);
        dx
    }

    /// Allocation-free twin of [`LayerNorm::backward`]: the per-row γ⊙dy
    /// scratch lives in `ws` and `dx` is written into a caller buffer,
    /// both reused whenever the shapes repeat.
    pub fn backward_into(
        &mut self,
        dy: &Tensor,
        prec: Precision,
        ws: &mut LayerNormWorkspace,
        dx: &mut Tensor,
    ) {
        let rows = dy.rows();
        let d = self.dim;
        assert_eq!(ws.xhat.rows(), rows, "forward_train workspace missing");
        dx.ensure_shape(&[rows, d]);
        ws.gdy.resize(d, 0.0);
        let gdy = &mut ws.gdy;
        for r in 0..rows {
            let dyr = dy.row(r);
            let xh = ws.xhat.row(r);
            // parameter grads
            for c in 0..d {
                self.gamma.g[c] += dyr[c] * xh[c];
                self.beta.g[c] += dyr[c];
            }
            // dx = inv/d * (d*g⊙dy - sum(g⊙dy) - xhat*sum(g⊙dy⊙xhat))
            let mut s1 = 0.0f32;
            let mut s2 = 0.0f32;
            for c in 0..d {
                gdy[c] = prec.q(self.gamma.w[c] * dyr[c]);
                s1 += gdy[c];
                s2 += prec.q(gdy[c] * xh[c]);
            }
            let (s1, s2) = (prec.q(s1), prec.q(s2));
            let inv = ws.inv_std[r];
            let dn = d as f32;
            let dxr = dx.row_mut(r);
            for c in 0..d {
                let t = prec.q(dn * gdy[c] - s1 - prec.q(xh[c] * s2));
                dxr[c] = prec.q(inv / dn * t);
            }
        }
        prec.q_slice(&mut self.gamma.g);
        prec.q_slice(&mut self.beta.g);
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.gamma, &mut self.beta]
    }

    /// Visit the parameters in [`LayerNorm::params_mut`] order without
    /// materializing a `Vec`.
    pub fn for_each_param(&self, f: &mut impl FnMut(&Param)) {
        f(&self.gamma);
        f(&self.beta);
    }

    /// Mutable twin of [`LayerNorm::for_each_param`], same order.
    pub fn for_each_param_mut(&mut self, f: &mut impl FnMut(&mut Param)) {
        f(&mut self.gamma);
        f(&mut self.beta);
    }

    pub fn n_params(&self) -> usize {
        self.gamma.len() + self.beta.len()
    }

    pub fn zero_grad(&mut self) {
        self.gamma.zero_grad();
        self.beta.zero_grad();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Pcg64;

    #[test]
    fn output_is_normalized() {
        let mut rng = Pcg64::seed(1);
        let ln = LayerNorm::new("ln", 50);
        let x = Tensor::from_vec(&[4, 50], (0..200).map(|_| rng.normal_f32() * 3.0 + 1.0).collect());
        let y = ln.forward(&x, Precision::Fp32);
        for r in 0..4 {
            let row = y.row(r);
            let mean: f32 = row.iter().sum::<f32>() / 50.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 50.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn gradcheck_fp32() {
        let mut rng = Pcg64::seed(2);
        let d = 6;
        let mut ln = LayerNorm::new("ln", d);
        // non-trivial gamma
        for (i, g) in ln.gamma.w.iter_mut().enumerate() {
            *g = 1.0 + 0.1 * i as f32;
        }
        let x = Tensor::from_vec(&[2, d], (0..2 * d).map(|_| rng.normal_f32()).collect());
        let mut ws = LayerNormWorkspace::default();
        let y = ln.forward_train(&x, Precision::Fp32, &mut ws);
        ln.zero_grad();
        let dx = ln.backward(&y.clone(), Precision::Fp32, &mut ws); // loss = sum(y²)/2

        let eps = 1e-3f32;
        let loss = |ln: &LayerNorm, x: &Tensor| -> f32 {
            ln.forward(x, Precision::Fp32).data.iter().map(|v| v * v / 2.0).sum()
        };
        let mut x2 = x.clone();
        for idx in [0usize, 3, 7, 11] {
            let orig = x2.data[idx];
            x2.data[idx] = orig + eps;
            let lp = loss(&ln, &x2);
            x2.data[idx] = orig - eps;
            let lm = loss(&ln, &x2);
            x2.data[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - dx.data[idx]).abs() < 2e-2 * (1.0 + num.abs()), "x[{idx}]");
        }
        // gamma grads
        for idx in [0usize, 2, 5] {
            let orig = ln.gamma.w[idx];
            ln.gamma.w[idx] = orig + eps;
            let lp = loss(&ln, &x);
            ln.gamma.w[idx] = orig - eps;
            let lm = loss(&ln, &x);
            ln.gamma.w[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - ln.gamma.g[idx]).abs() < 2e-2 * (1.0 + num.abs()), "g[{idx}]");
        }
    }

    #[test]
    fn fp16_variance_overflows_for_large_inputs() {
        // pre-activation deviations of magnitude ~350: 350² = 122500 >
        // 65504 → ∞, reproducing the failure the paper's weight-std fix
        // addresses (§4.6).
        let ln = LayerNorm::new("ln", 8);
        let x = Tensor::from_vec(&[1, 8], (0..8).map(|i| 100.0 * i as f32).collect());
        let y = ln.forward(&x, Precision::fp16());
        assert!(y.has_nonfinite() || y.data.iter().all(|&v| v == 0.0), "y={:?}", y.data);
    }

    #[test]
    fn fp16_is_fine_for_moderate_inputs() {
        let mut rng = Pcg64::seed(3);
        let ln = LayerNorm::new("ln", 16);
        let x = Tensor::from_vec(&[2, 16], (0..32).map(|_| rng.normal_f32() * 5.0).collect());
        let y = ln.forward(&x, Precision::fp16());
        assert!(!y.has_nonfinite());
    }

    #[test]
    fn inference_and_train_forward_agree_bitwise() {
        let mut rng = Pcg64::seed(4);
        let mut ln = LayerNorm::new("ln", 12);
        for (i, g) in ln.gamma.w.iter_mut().enumerate() {
            *g = 1.0 + 0.05 * i as f32;
        }
        let x = Tensor::from_vec(&[3, 12], (0..36).map(|_| rng.normal_f32() * 4.0).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let mut ws = LayerNormWorkspace::default();
            let a = ln.forward(&x, prec);
            let b = ln.forward_train(&x, prec, &mut ws);
            assert!(a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
    }
}
