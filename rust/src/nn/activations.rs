//! Elementwise activations with explicit backward, quantized per tensor.

use super::tensor::Tensor;
use crate::lowp::Precision;

/// ReLU forward. Returns the activated tensor (quantized).
pub fn relu(x: &Tensor, prec: Precision) -> Tensor {
    // tidy-allow(alloc): allocating wrapper for cold/inference callers —
    // the learner hot path uses `relu_into` with a workspace buffer
    let mut y = x.clone();
    relu_in_place(&mut y, prec);
    y
}

/// Allocation-free ReLU forward: write `relu(x)` into `out`, reusing
/// `out`'s buffer whenever the shape already matches. Bitwise identical
/// to [`relu`] (same zeroing condition, same quantize pass).
pub fn relu_into(x: &Tensor, prec: Precision, out: &mut Tensor) {
    out.ensure_shape(&x.shape);
    out.data.copy_from_slice(&x.data);
    relu_in_place(out, prec);
}

fn relu_in_place(y: &mut Tensor, prec: Precision) {
    for v in y.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    y.quantize(prec);
}

/// ReLU backward: `dx = dy ⊙ 1[x > 0]`, where `x` is the forward *input*.
pub fn relu_backward(dy: &Tensor, x: &Tensor, prec: Precision) -> Tensor {
    // tidy-allow(alloc): allocating wrapper for cold callers — the
    // learner hot path masks its gradient buffer with `relu_backward_in_place`
    let mut dx = dy.clone();
    relu_backward_in_place(&mut dx, x, prec);
    dx
}

/// Allocation-free ReLU backward: mask the gradient `g` in place by the
/// forward input's sign, then quantize. Bitwise identical to
/// [`relu_backward`] on the same values (same mask, same quantize pass).
pub fn relu_backward_in_place(g: &mut Tensor, x: &Tensor, prec: Precision) {
    assert_eq!(g.len(), x.len());
    for (d, &xv) in g.data.iter_mut().zip(&x.data) {
        if xv <= 0.0 {
            *d = 0.0;
        }
    }
    g.quantize(prec);
}

/// tanh forward (quantized).
pub fn tanh_forward(x: &Tensor, prec: Precision) -> Tensor {
    let mut y = x.clone();
    for v in y.data.iter_mut() {
        *v = v.tanh();
    }
    y.quantize(prec);
    y
}

/// tanh backward given the forward *output* `y`: `dx = dy (1 - y²)`.
/// In fp16, `1 - y²` rounds to 0 once |y| is within ~5e-4 of 1 — exactly
/// the saturation the paper's log-prob rewrite avoids; for the plain
/// activation this is harmless (the true gradient is ~0 there anyway).
pub fn tanh_backward(dy: &Tensor, y: &Tensor, prec: Precision) -> Tensor {
    assert_eq!(dy.len(), y.len());
    let mut dx = dy.clone();
    for (d, &yv) in dx.data.iter_mut().zip(&y.data) {
        let one_m = prec.q(1.0 - prec.q(yv * yv));
        *d *= one_m;
    }
    dx.quantize(prec);
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Pcg64;

    #[test]
    fn relu_forward_backward() {
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 0.0, 0.5, 2.0]);
        let y = relu(&x, Precision::Fp32);
        assert_eq!(y.data, vec![0.0, 0.0, 0.5, 2.0]);
        let dy = Tensor::filled(&[1, 4], 1.0);
        let dx = relu_backward(&dy, &x, Precision::Fp32);
        assert_eq!(dx.data, vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn tanh_gradcheck() {
        let mut rng = Pcg64::seed(1);
        let x = Tensor::from_vec(&[1, 8], (0..8).map(|_| rng.normal_f32() * 2.0).collect());
        let y = tanh_forward(&x, Precision::Fp32);
        let dy = Tensor::filled(&[1, 8], 1.0);
        let dx = tanh_backward(&dy, &y, Precision::Fp32);
        let eps = 1e-3f32;
        for i in 0..8 {
            let num = (((x.data[i] + eps).tanh()) - ((x.data[i] - eps).tanh())) / (2.0 * eps);
            assert!((num - dx.data[i]).abs() < 1e-3, "i={i}");
        }
    }

    #[test]
    fn tanh_saturates_in_fp16() {
        // |x| large => y rounds to ±1 in fp16 and (1-y²) underflows to 0.
        let x = Tensor::from_vec(&[1, 1], vec![6.0]);
        let y = tanh_forward(&x, Precision::fp16());
        assert_eq!(y.data[0], 1.0);
        let dx = tanh_backward(&Tensor::filled(&[1, 1], 1.0), &y, Precision::fp16());
        assert_eq!(dx.data[0], 0.0);
    }
}
