//! Twin-Q critic (clipped double-Q, as in the reference SAC codebase):
//! two independent MLPs over `concat(obs, action)`, each with hidden
//! depth 2 and a scalar head.

use crate::lowp::{HalfFormat, Precision};
use crate::nn::{Mlp, MlpWorkspace, Param, Tensor};
use crate::rngs::Pcg64;

/// Training-time caches for one [`Critic`] (one [`MlpWorkspace`] per
/// head). Populated by [`Critic::forward_train`], read by the backward
/// passes. The `join`/`dx1`/`dx2` slots are staging scratch for the
/// allocation-free `_into` walks, reused across update rounds.
#[derive(Debug, Clone, Default)]
pub struct CriticWorkspace {
    q1: MlpWorkspace,
    q2: MlpWorkspace,
    /// `[obs | act]` staging rows for the `_into` forwards.
    join: Tensor,
    /// Per-head input-gradient sinks for the `_into` backwards.
    dx1: Tensor,
    dx2: Tensor,
}

/// Twin Q-networks.
#[derive(Debug, Clone)]
pub struct Critic {
    pub q1: Mlp,
    pub q2: Mlp,
    pub obs_dim: usize,
    pub act_dim: usize,
}

impl Critic {
    pub fn new(name: &str, obs_dim: usize, act_dim: usize, hidden: usize, rng: &mut Pcg64) -> Self {
        let dims = [obs_dim + act_dim, hidden, hidden, 1];
        Critic {
            q1: Mlp::new(&format!("{name}.q1"), &dims, rng),
            q2: Mlp::new(&format!("{name}.q2"), &dims, rng),
            obs_dim,
            act_dim,
        }
    }

    /// Concatenate `[obs | act]` rows.
    pub fn join(obs: &Tensor, act: &Tensor) -> Tensor {
        // allocating wrapper for tests/cold callers — the learner hot
        // path stages into `CriticWorkspace::join` via `join_into`
        let mut x = Tensor::default();
        Self::join_into(obs, act, &mut x);
        x
    }

    /// Allocation-free twin of [`Critic::join`]: every element of the
    /// `[B, obs+act]` output is overwritten, so reusing the buffer is
    /// bitwise identical to filling a fresh zeros tensor.
    pub fn join_into(obs: &Tensor, act: &Tensor, out: &mut Tensor) {
        let b = obs.rows();
        assert_eq!(act.rows(), b);
        let (od, ad) = (obs.cols(), act.cols());
        out.ensure_shape(&[b, od + ad]);
        for r in 0..b {
            out.row_mut(r)[..od].copy_from_slice(obs.row(r));
            out.row_mut(r)[od..].copy_from_slice(act.row(r));
        }
    }

    /// Inference forward of both heads (`&self`, cache-free — used for
    /// target values and Q probes). Returns `(q1, q2)`, each `[B, 1]`.
    ///
    /// The twin trunks share every layer shape, so the walk fuses each
    /// layer pair into one GEMM dispatch ([`Mlp::forward_pair`]) —
    /// halving pool round-trips per critic forward while staying
    /// bitwise identical to two sequential head forwards.
    pub fn forward(&self, obs: &Tensor, act: &Tensor, prec: Precision) -> (Tensor, Tensor) {
        // allocating walk for cold/shared-`&self` callers — the learner
        // hot path uses `forward_into` (workspace staging)
        let x = Self::join(obs, act);
        Mlp::forward_pair(&self.q1, &self.q2, &x, prec)
    }

    /// Allocation-free twin of [`Critic::forward`]: joins into the
    /// workspace staging buffer and walks both heads via the paired
    /// inference dispatch, the outputs landing in `q1`/`q2`. Bitwise
    /// identical.
    pub fn forward_into(
        &self,
        obs: &Tensor,
        act: &Tensor,
        prec: Precision,
        ws: &mut CriticWorkspace,
        q1: &mut Tensor,
        q2: &mut Tensor,
    ) {
        let CriticWorkspace { q1: w1, q2: w2, join, .. } = ws;
        Self::join_into(obs, act, join);
        Mlp::forward_pair_into(&self.q1, &self.q2, join, prec, w1, w2, q1, q2);
    }

    /// Training forward: caches activations into `ws` for the backward
    /// passes. Bitwise identical to [`Critic::forward`], with the same
    /// paired-dispatch walk ([`Mlp::forward_train_pair`]).
    pub fn forward_train(
        &self,
        obs: &Tensor,
        act: &Tensor,
        prec: Precision,
        ws: &mut CriticWorkspace,
    ) -> (Tensor, Tensor) {
        let (mut q1, mut q2) = (Tensor::default(), Tensor::default());
        self.forward_train_into(obs, act, prec, ws, &mut q1, &mut q2);
        (q1, q2)
    }

    /// Allocation-free twin of [`Critic::forward_train`]: the staging
    /// join, both heads' caches, and the outputs all reuse their buffers
    /// whenever the shapes repeat.
    pub fn forward_train_into(
        &self,
        obs: &Tensor,
        act: &Tensor,
        prec: Precision,
        ws: &mut CriticWorkspace,
        q1: &mut Tensor,
        q2: &mut Tensor,
    ) {
        let CriticWorkspace { q1: w1, q2: w2, join, .. } = ws;
        Self::join_into(obs, act, join);
        Mlp::forward_train_pair_into(&self.q1, &self.q2, join, prec, w1, w2, q1, q2);
    }

    /// Backward from per-head output grads; returns the gradient w.r.t.
    /// the *action* part of the joined input (the policy path), discarding
    /// the obs part.
    pub fn backward(
        &mut self,
        dq1: &Tensor,
        dq2: &Tensor,
        prec: Precision,
        ws: &CriticWorkspace,
    ) -> Tensor {
        // allocating walk for tests/cold callers — the learner hot path
        // uses `backward_into` (workspace gradient sinks)
        let dx1 = self.q1.backward(dq1, prec, &ws.q1);
        let dx2 = self.q2.backward(dq2, prec, &ws.q2);
        let b = dx1.rows();
        let mut da = Tensor::zeros(&[b, self.act_dim]);
        Self::sum_action_slice(&dx1, &dx2, self.obs_dim, self.act_dim, prec, &mut da);
        da
    }

    /// Allocation-free twin of [`Critic::backward`]: per-head input
    /// gradients land in workspace scratch and the summed action-slice
    /// gradient lands in `da` (every element overwritten). Bitwise
    /// identical.
    pub fn backward_into(
        &mut self,
        dq1: &Tensor,
        dq2: &Tensor,
        prec: Precision,
        ws: &mut CriticWorkspace,
        da: &mut Tensor,
    ) {
        let CriticWorkspace { q1: w1, q2: w2, dx1, dx2, .. } = ws;
        self.q1.backward_into(dq1, prec, w1, dx1);
        self.q2.backward_into(dq2, prec, w2, dx2);
        da.ensure_shape(&[dx1.rows(), self.act_dim]);
        Self::sum_action_slice(dx1, dx2, self.obs_dim, self.act_dim, prec, da);
    }

    /// `da[r,i] = q(dx1[r, obs+i] + dx2[r, obs+i])` — the action slice of
    /// the summed joined-input gradients.
    fn sum_action_slice(
        dx1: &Tensor,
        dx2: &Tensor,
        obs_dim: usize,
        act_dim: usize,
        prec: Precision,
        da: &mut Tensor,
    ) {
        for r in 0..dx1.rows() {
            for i in 0..act_dim {
                da.data[r * act_dim + i] =
                    prec.q(dx1.row(r)[obs_dim + i] + dx2.row(r)[obs_dim + i]);
            }
        }
    }

    /// Like [`Critic::backward`], but also returns the gradient w.r.t.
    /// the obs part (needed to backprop into a shared pixel encoder).
    pub fn backward_full(
        &mut self,
        dq1: &Tensor,
        dq2: &Tensor,
        prec: Precision,
        ws: &CriticWorkspace,
    ) -> (Tensor, Tensor) {
        // allocating walk for tests/cold callers — the pixels learner
        // uses `backward_full_into` (workspace gradient sinks)
        let dx1 = self.q1.backward(dq1, prec, &ws.q1);
        let dx2 = self.q2.backward(dq2, prec, &ws.q2);
        let b = dx1.rows();
        let mut dobs = Tensor::zeros(&[b, self.obs_dim]);
        let mut da = Tensor::zeros(&[b, self.act_dim]);
        Self::split_joined_grads(&dx1, &dx2, self.obs_dim, self.act_dim, prec, &mut dobs, &mut da);
        (dobs, da)
    }

    /// Allocation-free twin of [`Critic::backward_full`]: both output
    /// gradients land in caller buffers (every element overwritten).
    /// Bitwise identical.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_full_into(
        &mut self,
        dq1: &Tensor,
        dq2: &Tensor,
        prec: Precision,
        ws: &mut CriticWorkspace,
        dobs: &mut Tensor,
        da: &mut Tensor,
    ) {
        let CriticWorkspace { q1: w1, q2: w2, dx1, dx2, .. } = ws;
        self.q1.backward_into(dq1, prec, w1, dx1);
        self.q2.backward_into(dq2, prec, w2, dx2);
        let b = dx1.rows();
        dobs.ensure_shape(&[b, self.obs_dim]);
        da.ensure_shape(&[b, self.act_dim]);
        Self::split_joined_grads(dx1, dx2, self.obs_dim, self.act_dim, prec, dobs, da);
    }

    /// Split the summed joined-input gradients into their obs and action
    /// slices: `dobs[r,i] = q(dx1[r,i]+dx2[r,i])`, `da` as in
    /// [`Critic::sum_action_slice`].
    #[allow(clippy::too_many_arguments)]
    fn split_joined_grads(
        dx1: &Tensor,
        dx2: &Tensor,
        obs_dim: usize,
        act_dim: usize,
        prec: Precision,
        dobs: &mut Tensor,
        da: &mut Tensor,
    ) {
        for r in 0..dx1.rows() {
            for i in 0..obs_dim {
                dobs.data[r * obs_dim + i] = prec.q(dx1.row(r)[i] + dx2.row(r)[i]);
            }
            for i in 0..act_dim {
                da.data[r * act_dim + i] =
                    prec.q(dx1.row(r)[obs_dim + i] + dx2.row(r)[obs_dim + i]);
            }
        }
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        let mut v = self.q1.params_mut();
        v.extend(self.q2.params_mut());
        v
    }

    /// Visit the parameters in [`Critic::params_mut`] order without
    /// materializing a `Vec` — the walk the learner hot loop (optimizer
    /// scratch fill, coercion, grad probe, in-place target EMA) uses.
    pub fn for_each_param(&self, f: &mut impl FnMut(&Param)) {
        self.q1.for_each_param(f);
        self.q2.for_each_param(f);
    }

    /// Mutable twin of [`Critic::for_each_param`], same order.
    pub fn for_each_param_mut(&mut self, f: &mut impl FnMut(&mut Param)) {
        self.q1.for_each_param_mut(f);
        self.q2.for_each_param_mut(f);
    }

    /// Flatten all parameter values (target-net EMA operates on this).
    pub fn flat_params(&mut self) -> Vec<f32> {
        let mut out = Vec::new();
        for p in self.params_mut() {
            out.extend_from_slice(&p.w);
        }
        out
    }

    /// Load flat parameter values (inverse of [`Critic::flat_params`]).
    pub fn load_flat(&mut self, flat: &[f32]) {
        let mut off = 0;
        for p in self.params_mut() {
            let n = p.len();
            p.w.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len());
    }

    pub fn zero_grad(&mut self) {
        self.q1.zero_grad();
        self.q2.zero_grad();
    }

    pub fn n_params(&self) -> usize {
        self.q1.n_params() + self.q2.n_params()
    }

    pub fn quantize_params(&mut self, prec: Precision) {
        self.q1.quantize_params(prec);
        self.q2.quantize_params(prec);
    }

    /// Pack both heads' weights into 16-bit storage (the target-critic
    /// tier — see [`Mlp::pack_weights`] for the quantize-mirror
    /// contract).
    pub fn pack_weights(&mut self, fmt: HalfFormat) {
        self.q1.pack_weights(fmt);
        self.q2.pack_weights(fmt);
    }

    /// Refresh both heads' packed mirrors from their masters,
    /// allocation-free (called after every target EMA sync).
    pub fn repack_weights(&mut self) {
        self.q1.repack_weights();
        self.q2.repack_weights();
    }

    /// Resident weight bytes across storage tiers.
    pub fn weight_bytes(&self) -> usize {
        self.q1.weight_bytes() + self.q2.weight_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twin_heads_differ() {
        let mut rng = Pcg64::seed(1);
        let c = Critic::new("c", 4, 2, 16, &mut rng);
        let obs = Tensor::from_vec(&[2, 4], (0..8).map(|_| rng.normal_f32()).collect());
        let act = Tensor::from_vec(&[2, 2], (0..4).map(|_| rng.normal_f32()).collect());
        let (q1, q2) = c.forward(&obs, &act, Precision::Fp32);
        assert_eq!(q1.shape, vec![2, 1]);
        assert_ne!(q1.data, q2.data);
    }

    #[test]
    fn action_gradient_matches_finite_difference() {
        let mut rng = Pcg64::seed(2);
        let mut c = Critic::new("c", 3, 2, 12, &mut rng);
        let obs = Tensor::from_vec(&[1, 3], vec![0.1, -0.4, 0.7]);
        let act = Tensor::from_vec(&[1, 2], vec![0.2, -0.1]);
        let prec = Precision::Fp32;
        // loss = q1 + q2 summed
        let mut ws = CriticWorkspace::default();
        let (q1, q2) = c.forward_train(&obs, &act, prec, &mut ws);
        let _ = (q1, q2);
        c.zero_grad();
        let ones = Tensor::filled(&[1, 1], 1.0);
        let da = c.backward(&ones, &ones, prec, &ws);
        let eps = 1e-3f32;
        for i in 0..2 {
            let mut a2 = act.clone();
            a2.data[i] += eps;
            let (p1, p2) = c.forward(&obs, &a2, prec);
            a2.data[i] -= 2.0 * eps;
            let (m1, m2) = c.forward(&obs, &a2, prec);
            let num = (p1.data[0] + p2.data[0] - m1.data[0] - m2.data[0]) / (2.0 * eps);
            assert!((num - da.data[i]).abs() < 2e-2 * (1.0 + num.abs()), "i={i}");
        }
    }

    #[test]
    fn paired_forward_matches_explicit_sequential_heads() {
        let mut rng = Pcg64::seed(9);
        let c = Critic::new("c", 5, 3, 24, &mut rng);
        let obs = Tensor::from_vec(&[4, 5], (0..20).map(|_| rng.normal_f32()).collect());
        let act = Tensor::from_vec(&[4, 3], (0..12).map(|_| rng.normal_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let x = Critic::join(&obs, &act);
            let s1 = c.q1.forward(&x, prec);
            let s2 = c.q2.forward(&x, prec);
            let (q1, q2) = c.forward(&obs, &act, prec);
            assert!(q1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            assert!(q2.data.iter().zip(&s2.data).all(|(u, v)| u.to_bits() == v.to_bits()));

            let mut ws = CriticWorkspace::default();
            let (t1, t2) = c.forward_train(&obs, &act, prec, &mut ws);
            assert!(t1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            assert!(t2.data.iter().zip(&s2.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
    }

    #[test]
    fn flat_roundtrip() {
        let mut rng = Pcg64::seed(3);
        let mut c = Critic::new("c", 3, 2, 8, &mut rng);
        let flat = c.flat_params();
        assert_eq!(flat.len(), c.n_params());
        let mut c2 = Critic::new("c2", 3, 2, 8, &mut rng);
        c2.load_flat(&flat);
        assert_eq!(c2.flat_params(), flat);
    }

    #[test]
    fn visitor_order_matches_params_mut() {
        // positional optimizer state depends on the two walks agreeing
        let mut rng = Pcg64::seed(7);
        let mut c = Critic::new("c", 3, 2, 8, &mut rng);
        let mut names = Vec::new();
        c.for_each_param(&mut |p: &Param| names.push(p.name.clone()));
        let want: Vec<String> = c.params_mut().iter().map(|p| p.name.clone()).collect();
        assert_eq!(names, want);
        let mut names_mut = Vec::new();
        c.for_each_param_mut(&mut |p: &mut Param| names_mut.push(p.name.clone()));
        assert_eq!(names_mut, want);
    }

    #[test]
    fn join_layout() {
        let obs = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let act = Tensor::from_vec(&[2, 1], vec![9., 8.]);
        let x = Critic::join(&obs, &act);
        assert_eq!(x.data, vec![1., 2., 9., 3., 4., 8.]);
    }
}
