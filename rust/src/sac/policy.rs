//! The tanh-Gaussian policy head and its log-probability — the site of
//! the paper's methods **2 (softplus-fix)** and **3 (normal-fix)**.
//!
//! Actions are `a = tanh(u)`, `u = μ + ε⊙σ`, `ε ~ N(0,1)` (paper eq. 1).
//! The log-probability needs the change-of-variables correction
//!
//! ```text
//! log π(a|s) = log N(u; μ, σ) − Σᵢ log(1 − tanh²(uᵢ))
//!            = log N(u; μ, σ) − Σᵢ 2[log 2 − uᵢ − log(1 + exp(−2uᵢ))]
//! ```
//!
//! * Without the **softplus-fix**, `exp(−2u)` overflows fp16 once
//!   `u < −5.54`; the forward yields ∞ and the backward `e/(1+e)`
//!   yields NaN — the PyTorch failure the paper describes.
//! * Without the **normal-fix**, the quadratic term is computed as
//!   `(u−μ)²/σ²`; both numerator and denominator underflow for small σ
//!   even when the ratio is O(1).
//!
//! Every scalar operation here is quantized into the working precision so
//! the failures (and the fixes) reproduce bit-faithfully.

use crate::lowp::Precision;
use crate::nn::Tensor;

const HALF_LOG_2PI: f32 = 0.918_938_5;
const LOG_2: f32 = std::f32::consts::LN_2;

/// Configuration of the policy head numerics.
#[derive(Debug, Clone, Copy)]
pub struct PolicyCfg {
    /// Bounds for log σ (paper Table 4: [-5, 2] states; [-10, 2] pixels).
    pub log_sig_lo: f32,
    pub log_sig_hi: f32,
    /// Method 2 on/off.
    pub softplus_fix: bool,
    /// Method 3 on/off.
    pub normal_fix: bool,
    /// Additive ε on σ (paper Appendix G uses 1e-4 for pixels where the
    /// wider log-σ range would otherwise underflow σ itself).
    pub sigma_eps: f32,
    /// Linearization threshold K of eq. (2) (paper: 10).
    pub k_threshold: f32,
}

impl Default for PolicyCfg {
    fn default() -> Self {
        PolicyCfg {
            log_sig_lo: -5.0,
            log_sig_hi: 2.0,
            softplus_fix: true,
            normal_fix: true,
            sigma_eps: 0.0,
            k_threshold: 10.0,
        }
    }
}

/// Stable softplus of `x = −2u` (eq. 2 of the paper): linear for `x > K`.
#[inline]
pub fn softplus_neg2u(x: f32, fix: bool, k: f32, p: Precision) -> f32 {
    if fix && x > k {
        x
    } else {
        let e = p.q(x.exp()); // overflows in fp16 for x > 11.09 without fix
        p.q(p.q(1.0 + e).ln())
    }
}

/// Derivative of [`softplus_neg2u`] w.r.t. `x`: 1 in the linear region,
/// `e/(1+e)` otherwise. Without the fix the division ∞/∞ = NaN is the
/// backward overflow the paper pinpoints.
#[inline]
pub fn softplus_neg2u_grad(x: f32, fix: bool, k: f32, p: Precision) -> f32 {
    if fix && x > k {
        1.0
    } else {
        let e = p.q(x.exp());
        p.q(e / p.q(1.0 + e))
    }
}

/// Forward result + caches of the tanh-Gaussian head over a batch.
/// `Default` gives an empty sample cache for
/// [`TanhGaussian::forward_into`] to fill in place.
#[derive(Debug, Clone, Default)]
pub struct TanhGaussian {
    /// Pre-squash sample `u = μ + ε σ`, shape `[B, A]`.
    pub u: Tensor,
    /// Action `a = tanh(u)`, shape `[B, A]`.
    pub a: Tensor,
    /// Per-sample log-probability `log π(a|s)`, length `B`.
    pub logp: Vec<f32>,
    cfg: PolicyCfg,
    prec: Precision,
    act_dim: usize,
    // caches for backward
    mu: Vec<f32>,
    eps: Vec<f32>,
    sigma: Vec<f32>,
    exp_ls: Vec<f32>, // dσ/d(log σ)
    t_bound: Vec<f32>, // tanh(raw log σ) for the bound backward
    ls: Vec<f32>, // log σ forward scratch
}

impl TanhGaussian {
    /// `head` is the trunk output `[B, 2A]` = `[μ | raw log σ]`;
    /// `eps` is standard normal noise `[B, A]`.
    pub fn forward(head: &Tensor, eps: &Tensor, cfg: PolicyCfg, prec: Precision) -> Self {
        let mut tg = TanhGaussian::default();
        tg.forward_into(head, eps, cfg, prec);
        tg
    }

    /// Allocation-free twin of [`TanhGaussian::forward`]: refills this
    /// sample cache in place, reusing every buffer whenever the batch
    /// shape repeats (each slot is resized then fully overwritten).
    /// Bitwise identical — same per-element ops in the same order.
    pub fn forward_into(&mut self, head: &Tensor, eps: &Tensor, cfg: PolicyCfg, prec: Precision) {
        let b = head.rows();
        let two_a = head.cols();
        assert_eq!(two_a % 2, 0);
        let a_dim = two_a / 2;
        assert_eq!(eps.shape, [b, a_dim]);
        let p = prec;
        self.cfg = cfg;
        self.prec = prec;
        self.act_dim = a_dim;

        let n = b * a_dim;
        self.mu.resize(n, 0.0);
        self.sigma.resize(n, 0.0);
        self.exp_ls.resize(n, 0.0);
        self.t_bound.resize(n, 0.0);
        self.ls.resize(n, 0.0);
        self.eps.clone_from(&eps.data);
        let half_range = p.q(0.5 * (cfg.log_sig_hi - cfg.log_sig_lo));
        for r in 0..b {
            let row = head.row(r);
            for i in 0..a_dim {
                let idx = r * a_dim + i;
                self.mu[idx] = row[i];
                let raw = row[a_dim + i];
                let t = p.q(raw.tanh());
                self.t_bound[idx] = t;
                // log σ = lo + (hi-lo)/2 · (tanh(raw)+1)
                self.ls[idx] = p.q(cfg.log_sig_lo + half_range * p.q(t + 1.0));
                let e = p.q(self.ls[idx].exp());
                self.exp_ls[idx] = e;
                self.sigma[idx] = p.q(e + cfg.sigma_eps);
            }
        }

        self.u.ensure_shape(&[b, a_dim]);
        self.a.ensure_shape(&[b, a_dim]);
        self.logp.resize(b, 0.0);
        for r in 0..b {
            let mut acc = 0.0f32;
            for i in 0..a_dim {
                let idx = r * a_dim + i;
                let ev = eps.data[idx];
                let uv = p.q(self.mu[idx] + p.q(ev * self.sigma[idx]));
                self.u.data[idx] = uv;
                self.a.data[idx] = p.q(uv.tanh());

                // Normal log-density (up to the constant)
                let nl = if cfg.normal_fix {
                    let rr = p.q(p.q(uv - self.mu[idx]) / self.sigma[idx]);
                    let r2 = p.q(rr * rr);
                    p.q(-0.5 * r2 - self.ls[idx] - HALF_LOG_2PI)
                } else {
                    let d = p.q(uv - self.mu[idx]);
                    let d2 = p.q(d * d);
                    let s2 = p.q(self.sigma[idx] * self.sigma[idx]);
                    let r2 = p.q(d2 / s2);
                    p.q(-0.5 * r2 - self.ls[idx] - HALF_LOG_2PI)
                };

                // tanh correction: log(1-a²) = 2(log2 - u - softplus(-2u))
                let x = p.q(-2.0 * uv);
                let sp = softplus_neg2u(x, cfg.softplus_fix, cfg.k_threshold, p);
                let tc = p.q(2.0 * p.q(LOG_2 - uv - sp));

                acc += p.q(nl - tc);
            }
            self.logp[r] = p.q(acc);
        }
    }

    /// Backward pass. `coef_logp[b]` is ∂loss/∂logp[b]; `da` (if present)
    /// is ∂loss/∂a (the Q-value path of the actor loss). Returns the
    /// gradient w.r.t. the trunk head `[B, 2A]`.
    pub fn backward(&self, coef_logp: &[f32], da: Option<&Tensor>) -> Tensor {
        // allocating wrapper for tests/cold callers — the learner hot
        // path uses `backward_into` (workspace gradient buffer)
        let mut dhead = Tensor::default();
        self.backward_into(coef_logp, da, &mut dhead);
        dhead
    }

    /// Allocation-free twin of [`TanhGaussian::backward`]: the head
    /// gradient lands in `dhead` (every element overwritten). Bitwise
    /// identical.
    pub fn backward_into(&self, coef_logp: &[f32], da: Option<&Tensor>, dhead: &mut Tensor) {
        let p = self.prec;
        let b = self.logp.len();
        let a_dim = self.act_dim;
        assert_eq!(coef_logp.len(), b);
        let cfg = &self.cfg;
        let half_range = p.q(0.5 * (cfg.log_sig_hi - cfg.log_sig_lo));
        dhead.ensure_shape(&[b, 2 * a_dim]);

        for r in 0..b {
            let coef = coef_logp[r];
            for i in 0..a_dim {
                let idx = r * a_dim + i;
                let uv = self.u.data[idx];
                let av = self.a.data[idx];
                let sg = self.sigma[idx];
                let muv = self.mu[idx];
                let ev = self.eps[idx];

                // -- logπ partials --------------------------------------
                // normal part
                let (dnl_du, dnl_dmu, dnl_dsigma) = if cfg.normal_fix {
                    let rr = p.q(p.q(uv - muv) / sg);
                    let inv_s = p.q(1.0 / sg);
                    let dnl_du = p.q(-rr * inv_s);
                    let dnl_dmu = p.q(rr * inv_s);
                    let dnl_dsigma = p.q(p.q(rr * rr) * inv_s);
                    (dnl_du, dnl_dmu, dnl_dsigma)
                } else {
                    let d = p.q(uv - muv);
                    let s2 = p.q(sg * sg);
                    let dd = p.q(-d / s2); // ∂nl/∂d
                    let d2 = p.q(d * d);
                    // ∂nl/∂σ = d²/σ³ = (d²/σ²)·(1/σ)
                    let dnl_dsigma = p.q(p.q(d2 / s2) / sg);
                    (dd, p.q(-dd), dnl_dsigma)
                };
                // tanh-correction part: tc = 2(log2 - u - sp(x)), x = -2u
                // ∂tc/∂u = 2(-1 - sp'(x)·(-2)) = 2(-1 + 2 sp'(x))
                let x = p.q(-2.0 * uv);
                let spg = softplus_neg2u_grad(x, cfg.softplus_fix, cfg.k_threshold, p);
                let dtc_du = p.q(2.0 * p.q(-1.0 + 2.0 * spg));

                // logp = Σ (nl - tc)
                let dlogp_du = p.q(dnl_du - dtc_du);

                // -- assemble total gradients ---------------------------
                // action path: da/du = 1 - a²
                let mut gu = p.q(coef * dlogp_du);
                if let Some(dat) = da {
                    let one_m_a2 = p.q(1.0 - p.q(av * av));
                    gu = p.q(gu + p.q(dat.data[idx] * one_m_a2));
                }
                // μ: direct + through u (du/dμ = 1)
                let gmu = p.q(gu + p.q(coef * dnl_dmu));
                // σ: through u (du/dσ = ε) + direct
                let gsigma = p.q(p.q(gu * ev) + p.q(coef * dnl_dsigma));
                // log σ: dσ/d(logσ) = exp(logσ); direct ∂nl/∂lsσ = -1
                let gls = p.q(p.q(gsigma * self.exp_ls[idx]) - coef);
                // through the tanh bound: d ls / d raw = half_range·(1-t²)
                let t = self.t_bound[idx];
                let dbound = p.q(half_range * p.q(1.0 - p.q(t * t)));
                let graw = p.q(gls * dbound);

                dhead.data[r * 2 * a_dim + i] = gmu;
                dhead.data[r * 2 * a_dim + a_dim + i] = graw;
            }
        }
    }

    /// Deterministic action `tanh(μ)` (evaluation-time policy).
    pub fn mean_action(head: &Tensor, prec: Precision) -> Tensor {
        // allocating wrapper for cold callers — the serving hot path
        // uses `mean_action_into` (reused action buffer)
        let mut a = Tensor::default();
        Self::mean_action_into(head, prec, &mut a);
        a
    }

    /// Allocation-free twin of [`TanhGaussian::mean_action`]: the action
    /// lands in `a` (every element overwritten). Bitwise identical.
    pub fn mean_action_into(head: &Tensor, prec: Precision, a: &mut Tensor) {
        let b = head.rows();
        let a_dim = head.cols() / 2;
        a.ensure_shape(&[b, a_dim]);
        for r in 0..b {
            for i in 0..a_dim {
                a.data[r * a_dim + i] = prec.q(head.row(r)[i].tanh());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Pcg64;

    fn make_head(b: usize, a: usize, rng: &mut Pcg64, mu_scale: f32, ls_raw: f32) -> (Tensor, Tensor) {
        let mut head = Tensor::zeros(&[b, 2 * a]);
        for r in 0..b {
            for i in 0..a {
                head.data[r * 2 * a + i] = rng.normal_f32() * mu_scale;
                head.data[r * 2 * a + a + i] = ls_raw + rng.normal_f32() * 0.3;
            }
        }
        let mut eps = Tensor::zeros(&[b, a]);
        rng.normal_fill(&mut eps.data);
        (head, eps)
    }

    /// f64 reference density for a single element.
    fn ref_logp(mu: f64, ls: f64, eps: f64) -> f64 {
        let sigma = ls.exp();
        let u = mu + eps * sigma;
        let nl = -0.5 * eps * eps - ls - 0.918938533204672_f64;
        let tc = 2.0 * ((2.0f64).ln() - u - (-2.0 * u).exp().ln_1p());
        nl - tc
    }

    #[test]
    fn fp32_logp_matches_f64_reference() {
        let mut rng = Pcg64::seed(1);
        let cfg = PolicyCfg::default();
        let (head, eps) = make_head(16, 4, &mut rng, 1.0, 0.0);
        let tg = TanhGaussian::forward(&head, &eps, cfg, Precision::Fp32);
        for r in 0..16 {
            let mut want = 0.0f64;
            for i in 0..4 {
                let mu = head.data[r * 8 + i] as f64;
                let raw = head.data[r * 8 + 4 + i] as f64;
                let ls = -5.0 + 3.5 * (raw.tanh() + 1.0);
                want += ref_logp(mu, ls, eps.data[r * 4 + i] as f64);
            }
            let got = tg.logp[r] as f64;
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "r={r}: {got} vs {want}");
        }
    }

    #[test]
    fn fix_and_nofix_agree_in_fp32() {
        // Statement 1: the rewrites are identities in high precision.
        let mut rng = Pcg64::seed(2);
        let (head, eps) = make_head(8, 3, &mut rng, 1.5, 0.5);
        let f = TanhGaussian::forward(&head, &eps, PolicyCfg::default(), Precision::Fp32);
        let nofix = PolicyCfg { softplus_fix: false, normal_fix: false, ..Default::default() };
        let g = TanhGaussian::forward(&head, &eps, nofix, Precision::Fp32);
        for r in 0..8 {
            assert!((f.logp[r] - g.logp[r]).abs() < 1e-3 * (1.0 + f.logp[r].abs()));
        }
        // gradients agree too
        let coef = vec![1.0f32; 8];
        let df = f.backward(&coef, None);
        let dg = g.backward(&coef, None);
        for (x, y) in df.data.iter().zip(&dg.data) {
            assert!((x - y).abs() < 2e-3 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn gradcheck_logp_fp32() {
        let mut rng = Pcg64::seed(3);
        let (head, eps) = make_head(2, 3, &mut rng, 0.8, 0.2);
        let cfg = PolicyCfg::default();
        let prec = Precision::Fp32;
        let tg = TanhGaussian::forward(&head, &eps, cfg, prec);
        let coef = vec![1.0f32, 1.0];
        let dhead = tg.backward(&coef, None);

        let delta = 1e-3f32;
        let mut h2 = head.clone();
        for idx in 0..h2.len() {
            let o = h2.data[idx];
            h2.data[idx] = o + delta;
            let lp: f32 = TanhGaussian::forward(&h2, &eps, cfg, prec).logp.iter().sum();
            h2.data[idx] = o - delta;
            let lm: f32 = TanhGaussian::forward(&h2, &eps, cfg, prec).logp.iter().sum();
            h2.data[idx] = o;
            let num = (lp - lm) / (2.0 * delta);
            let ana = dhead.data[idx];
            assert!(
                (num - ana).abs() < 3e-2 * (1.0 + num.abs()),
                "idx={idx}: num={num} ana={ana}"
            );
        }
    }

    #[test]
    fn gradcheck_action_path_fp32() {
        // loss = sum(a²)/2 → da = a, no logp term
        let mut rng = Pcg64::seed(4);
        let (head, eps) = make_head(2, 2, &mut rng, 0.5, 0.0);
        let cfg = PolicyCfg::default();
        let prec = Precision::Fp32;
        let tg = TanhGaussian::forward(&head, &eps, cfg, prec);
        let coef = vec![0.0f32; 2];
        let dhead = tg.backward(&coef, Some(&tg.a.clone()));

        let delta = 1e-3f32;
        let mut h2 = head.clone();
        let loss = |h: &Tensor| -> f32 {
            TanhGaussian::forward(h, &eps, cfg, prec).a.data.iter().map(|v| v * v / 2.0).sum()
        };
        for idx in 0..h2.len() {
            let o = h2.data[idx];
            h2.data[idx] = o + delta;
            let lp = loss(&h2);
            h2.data[idx] = o - delta;
            let lm = loss(&h2);
            h2.data[idx] = o;
            let num = (lp - lm) / (2.0 * delta);
            assert!(
                (num - dhead.data[idx]).abs() < 3e-2 * (1.0 + num.abs()),
                "idx={idx}"
            );
        }
    }

    #[test]
    fn no_softplus_fix_overflows_fp16_backward() {
        // Large positive μ → u ≈ 8 → x = -2u = -16: fine this side.
        // Large NEGATIVE u → x = -2u = +16 → exp(x) overflows fp16.
        let mut head = Tensor::zeros(&[1, 2]);
        head.data[0] = -8.0; // μ → u ≈ -8
        head.data[1] = -3.0; // small σ
        let eps = Tensor::zeros(&[1, 1]);
        let prec = Precision::fp16();
        let nofix = PolicyCfg { softplus_fix: false, normal_fix: true, ..Default::default() };
        let tg = TanhGaussian::forward(&head, &eps, nofix, prec);
        assert!(
            !tg.logp[0].is_finite(),
            "forward should already blow up: logp={}",
            tg.logp[0]
        );
        let d = tg.backward(&[1.0], None);
        assert!(d.has_nonfinite(), "backward must produce NaN/∞");

        // with the fix everything is finite
        let fix = PolicyCfg::default();
        let tg = TanhGaussian::forward(&head, &eps, fix, prec);
        assert!(tg.logp[0].is_finite());
        let d = tg.backward(&[1.0], None);
        assert!(!d.has_nonfinite());
    }

    #[test]
    fn normal_fix_survives_small_sigma_in_fp16() {
        // raw log σ → lower bound: σ = e^-5 ≈ 6.7e-3 → σ² ≈ 4.5e-5 is
        // subnormal fp16 (min normal 6.1e-5): (u-μ)²/σ² loses most bits,
        // and with the pixels bound (lo = -10) σ² underflows to 0
        // entirely → ±∞ ratios.
        let mut head = Tensor::zeros(&[1, 2]);
        head.data[0] = 0.3;
        head.data[1] = -20.0; // tanh → -1 → log σ at the lower bound
        let mut eps = Tensor::zeros(&[1, 1]);
        eps.data[0] = 1.5;
        let prec = Precision::fp16();
        let pix_nofix = PolicyCfg {
            log_sig_lo: -10.0,
            normal_fix: false,
            softplus_fix: true,
            ..Default::default()
        };
        let tg = TanhGaussian::forward(&head, &eps, pix_nofix, prec);
        assert!(
            !tg.logp[0].is_finite(),
            "σ² underflow should give non-finite logp, got {}",
            tg.logp[0]
        );
        let pix_fix = PolicyCfg { log_sig_lo: -10.0, normal_fix: true, softplus_fix: true, ..Default::default() };
        let tg = TanhGaussian::forward(&head, &eps, pix_fix, prec);
        assert!(tg.logp[0].is_finite(), "normal-fix must survive: {}", tg.logp[0]);
    }

    #[test]
    fn mean_action_is_tanh_mu() {
        let head = Tensor::from_vec(&[1, 4], vec![0.5, -2.0, 0.0, 0.0]);
        let a = TanhGaussian::mean_action(&head, Precision::Fp32);
        assert!((a.data[0] - 0.5f32.tanh()).abs() < 1e-6);
        assert!((a.data[1] - (-2.0f32).tanh()).abs() < 1e-6);
    }

    #[test]
    fn actions_are_bounded() {
        let mut rng = Pcg64::seed(5);
        let (head, eps) = make_head(32, 6, &mut rng, 5.0, 1.0);
        let tg = TanhGaussian::forward(&head, &eps, PolicyCfg::default(), Precision::fp16());
        for &v in &tg.a.data {
            assert!((-1.0..=1.0).contains(&v));
        }
    }
}
