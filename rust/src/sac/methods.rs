//! The configuration lattice of the paper's experiments: the six proposed
//! methods (Table 1) plus the supervised-learning baseline tricks of
//! Figure 1, as independent switches.

/// Which numerical-stability methods are active. The fields mirror the
/// paper's Table 1 (methods 1–6) plus the baseline tricks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Methods {
    /// Method 1: hAdam — store √v, update with stable hypot.
    pub hadam: bool,
    /// Method 2: softplus-fix — linearize `log(1+exp(-2u))` for large
    /// `-2u` so its backward cannot overflow.
    pub softplus_fix: bool,
    /// Method 3: normal-fix — compute the Normal log-density via
    /// `((x-μ)/σ)²` instead of `(x-μ)²/σ²`.
    pub normal_fix: bool,
    /// Method 4: Kahan-momentum — compensated, scaled target-net EMA.
    pub kahan_momentum: bool,
    /// Method 5: compound loss scaling — keep the γ factor inside the
    /// Adam buffers instead of unscaling gradients.
    pub compound_scaling: bool,
    /// Method 6: Kahan-gradients — compensated parameter updates for the
    /// critic and α.
    pub kahan_gradients: bool,
    /// Baseline trick: dynamic loss scaling (Micikevicius et al., 2017).
    /// Implied by `compound_scaling`.
    pub loss_scaling: bool,
    /// Baseline trick: coerce NaN→0, ±∞→±max after backward ("coerc").
    pub coerce: bool,
    /// Baseline trick: mixed precision — fp32 master weights and fp32
    /// optimizer arithmetic, fp16 forward/backward.
    pub mixed_precision: bool,
}

impl Methods {
    /// Everything off — plain training (the fp32 reference, or the
    /// "fp16 naive" run when paired with a low-precision policy).
    pub const fn none() -> Self {
        Methods {
            hadam: false,
            softplus_fix: false,
            normal_fix: false,
            kahan_momentum: false,
            compound_scaling: false,
            kahan_gradients: false,
            loss_scaling: false,
            coerce: false,
            mixed_precision: false,
        }
    }

    /// The paper's full recipe (all six methods).
    pub const fn ours() -> Self {
        Methods {
            hadam: true,
            softplus_fix: true,
            normal_fix: true,
            kahan_momentum: true,
            compound_scaling: true,
            kahan_gradients: true,
            loss_scaling: true,
            coerce: false,
            mixed_precision: false,
        }
    }

    /// Figure 1 baseline: numeric coercion only.
    pub const fn coerc_baseline() -> Self {
        Methods { coerce: true, ..Methods::none() }
    }

    /// Figure 1 baseline: plain dynamic loss scaling.
    pub const fn loss_scale_baseline() -> Self {
        Methods { loss_scaling: true, ..Methods::none() }
    }

    /// Figure 1 baseline: mixed precision + loss scaling.
    pub const fn mixed_precision_baseline() -> Self {
        Methods { loss_scaling: true, mixed_precision: true, ..Methods::none() }
    }

    /// The cumulative ablation of Figure 3: the first `k` methods of
    /// Table 1 enabled (k = 0 → naive fp16, k = 6 → full recipe).
    /// Compound scaling implies loss scaling is active.
    pub fn cumulative(k: usize) -> Self {
        let mut m = Methods::none();
        if k >= 1 {
            m.hadam = true;
        }
        if k >= 2 {
            m.softplus_fix = true;
        }
        if k >= 3 {
            m.normal_fix = true;
        }
        if k >= 4 {
            m.kahan_momentum = true;
        }
        if k >= 5 {
            m.compound_scaling = true;
            m.loss_scaling = true;
        }
        if k >= 6 {
            m.kahan_gradients = true;
        }
        m
    }

    /// The leave-one-out ablation of Figure 7: all methods except the
    /// `i`-th (1-based, following Table 1 numbering).
    pub fn leave_one_out(i: usize) -> Self {
        let mut m = Methods::ours();
        match i {
            1 => m.hadam = false,
            2 => m.softplus_fix = false,
            3 => m.normal_fix = false,
            4 => m.kahan_momentum = false,
            5 => {
                m.compound_scaling = false;
                // loss scaling itself stays on (it is a baseline trick,
                // not one of the six); removing method 5 reverts to the
                // plain unscale-then-Adam behaviour.
            }
            6 => m.kahan_gradients = false,
            _ => panic!("method index must be 1..=6"),
        }
        m
    }

    /// Short label for the cumulative ablation axis (Figure 3 x-axis).
    pub fn cumulative_label(k: usize) -> &'static str {
        match k {
            0 => "fp16",
            1 => "+hAdam",
            2 => "+softplus",
            3 => "+normal",
            4 => "+kahan mom",
            5 => "+comp scale",
            6 => "+kahan grad",
            _ => "?",
        }
    }

    /// Number of the six paper methods that are enabled.
    pub fn count_enabled(&self) -> usize {
        [self.hadam, self.softplus_fix, self.normal_fix, self.kahan_momentum, self.compound_scaling, self.kahan_gradients]
            .iter()
            .filter(|&&b| b)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cumulative_is_monotone() {
        for k in 0..6 {
            assert_eq!(Methods::cumulative(k).count_enabled(), k);
        }
        assert_eq!(Methods::cumulative(6), Methods::ours());
    }

    #[test]
    fn leave_one_out_drops_exactly_one() {
        for i in 1..=6 {
            let m = Methods::leave_one_out(i);
            assert_eq!(m.count_enabled(), 5, "i={i}");
            assert_ne!(m, Methods::ours());
        }
    }

    #[test]
    fn baselines_enable_expected_tricks() {
        assert!(Methods::coerc_baseline().coerce);
        assert!(Methods::loss_scale_baseline().loss_scaling);
        let mp = Methods::mixed_precision_baseline();
        assert!(mp.mixed_precision && mp.loss_scaling);
        assert_eq!(Methods::none().count_enabled(), 0);
    }

    #[test]
    fn labels_exist() {
        for k in 0..=6 {
            assert!(!Methods::cumulative_label(k).is_empty());
        }
    }
}
