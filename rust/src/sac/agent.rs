//! The SAC agent: actor, twin critic, target critic, automatic entropy
//! temperature, optional pixel encoder — with every one of the paper's
//! six numerical methods switchable (see [`super::Methods`]).
//!
//! Update structure follows Yarats & Kostrikov (2020):
//! 1. critic step — `L = MSE(Q₁, y) + MSE(Q₂, y)`,
//!    `y = r + γ·(min Q̂(s', a') − α log π(a'|s'))`, `a' ~ π(s')`;
//! 2. actor step (every `actor_update_freq`) —
//!    `L = E[α log π(a|s) − min Q(s, a)]`, reparameterized;
//! 3. temperature step — `L = −α·E[log π + H̄]`, on `log α`;
//! 4. target soft update (every `target_update_freq`) —
//!    `ψ̂ ← ψ̂ + τ(ψ − ψ̂)` (Kahan-momentum when enabled).
//!
//! Train/inference split: gradient-producing forwards go through
//! `forward_train` + the agent-owned workspaces; everything that needs
//! no backward (target values, the detached actor features, action
//! selection) uses the cache-free `&self` forwards. A frozen, shareable
//! snapshot of the action path is available via [`SacAgent::policy`].

use super::critic::{Critic, CriticWorkspace};
use super::encoder::{Encoder, EncoderWorkspace};
use super::methods::Methods;
use super::policy::{PolicyCfg, TanhGaussian};
use super::snapshot::Policy;
use crate::lowp::{HalfFormat, Precision};
use crate::nn::pool::{self, SendMut, ELEMWISE_SPAN};
use crate::nn::{Mlp, MlpWorkspace, Param, Tensor};
use crate::optim::{coerce_nonfinite, Adam, AdamConfig, GradScaler, ScaledKahanEma, ScalerConfig, SecondMoment, UpdateMode};
use crate::rngs::Pcg64;

/// Append `|g|` for every element of `g` to `probe` (Figure 6
/// telemetry), filling the freshly-reserved tail over the worker pool.
/// Values land in the same order as a serial `extend`, and `|·|` is
/// elementwise, so the result is bitwise thread-count-invariant.
fn append_abs_pooled(probe: &mut Vec<f32>, g: &[f32]) {
    let start = probe.len();
    probe.reserve(g.len());
    // SAFETY: raw writes straight into the reserved tail — one pass
    // over the memory instead of zero-fill + overwrite, and no
    // reference to uninitialized elements is ever formed.
    let dst = SendMut::new(unsafe { probe.as_mut_ptr().add(start) });
    pool::global().run_spans(g.len(), ELEMWISE_SPAN, |lo, hi| {
        for (i, v) in g[lo..hi].iter().enumerate() {
            // SAFETY: spans are disjoint — each index is written
            // exactly once, inside the reserved tail.
            unsafe { dst.get().add(lo + i).write(v.abs()) };
        }
    });
    // SAFETY: every element of the reserved tail was written above.
    unsafe { probe.set_len(start + g.len()) };
}

/// Decode one `f32s` sequence per parameter into a module's parameter
/// walk (checkpoint path). A length mismatch or truncated payload
/// surfaces as the first error instead of a panic.
fn read_params_into(
    dec: &mut crate::ckpt::Dec,
    for_each: impl FnOnce(&mut dyn FnMut(&mut Param)),
) -> anyhow::Result<()> {
    let mut err: Option<anyhow::Error> = None;
    for_each(&mut |p: &mut Param| {
        if err.is_some() {
            return;
        }
        if let Err(e) = dec.f32s_into(&mut p.w) {
            err = Some(e);
        }
    });
    err.map_or(Ok(()), Err)
}

/// Reusable positional parameter list for the optimizer step: the
/// parameter walk collects raw pointers into a persistent `Vec` whose
/// capacity survives across updates (the old code built a fresh
/// `Vec<&mut Param>` — plus one `Vec` per layer — on every update),
/// then hands them back out as the `&mut [&mut Param]` the optimizer
/// expects.
#[derive(Default)]
struct ParamScratch {
    ptrs: Vec<*mut Param>,
}

// SAFETY: the pointers are transient scratch — refilled from live
// `&mut Param`s at the start of every optimizer step and only
// dereferenced inside that step, while the owning agent is exclusively
// borrowed. Between updates they are never read.
unsafe impl Send for ParamScratch {}

impl ParamScratch {
    fn clear(&mut self) {
        self.ptrs.clear();
    }

    fn push(&mut self, p: &mut Param) {
        self.ptrs.push(p);
    }

    /// View the collected pointers as an optimizer parameter list.
    /// Sound because every pointer was collected from a distinct live
    /// `&mut Param` during this update and nothing else touches those
    /// params while the returned borrow lives.
    fn as_params(&mut self) -> &mut [&mut Param] {
        // SAFETY: every pointer was collected from a distinct live
        // `&mut Param` during this update, and nothing else touches
        // those params while the returned borrow lives.
        unsafe { &mut *(self.ptrs.as_mut_slice() as *mut [*mut Param] as *mut [&mut Param]) }
    }
}

/// Persistent buffers for the learner hot loop: every per-update
/// scratch the old `update_*` bodies allocated fresh — the noise
/// tensor, TD targets, output gradients, α-path coefficients, the
/// optimizer parameter list and the fused target-encoder staging — now
/// lives here and is reused round after round (zero steady-state
/// allocations on the update driver path).
#[derive(Default)]
struct UpdateWorkspace {
    /// Reparameterization noise `[B, A]`.
    eps: Tensor,
    /// TD targets, length B.
    y: Vec<f32>,
    dq1: Tensor,
    dq2: Tensor,
    /// Per-row `α·coef` for the actor's logπ backward.
    coefs: Vec<f32>,
    /// Optimizer parameter list (critic [+ encoder] / actor).
    params: ParamScratch,
    /// Per-update `[B, feature_dim]` staging of fused target features.
    feat_tgt: Tensor,
    /// Concatenated `[G·B, C, H, W]` next-obs staging for a fused group.
    fused_stage: Tensor,
    /// The current fused group's target-encoder output `[G·B, feat]`.
    fused_feat: Tensor,
    /// Per-update row offset into the update's group `fused_feat`
    /// (`usize::MAX` = unfused).
    fused_off: Vec<usize>,
    /// Scratch `(start, end)` group list for the round partition.
    fused_groups: Vec<(usize, usize)>,
    /// Shape scratch for staging a fused group.
    fused_shape: Vec<usize>,
    /// Actor head `[B, 2A]` output staging (critic- and actor-step
    /// forwards).
    head: Tensor,
    /// Inference walk scratch for the critic-step actor forward (the
    /// actor's training caches live in `SacAgent::ws_actor`).
    actor_inf: MlpWorkspace,
    /// Reusable tanh-Gaussian sample cache (`forward_into` refill).
    tg: TanhGaussian,
    /// Target critic outputs `[B, 1]` and its inference walk scratch.
    tq1: Tensor,
    tq2: Tensor,
    tgt_critic: CriticWorkspace,
    /// Online critic outputs `[B, 1]`.
    q1: Tensor,
    q2: Tensor,
    /// Critic input-gradient sinks (action slice / obs slice).
    da: Tensor,
    dobs: Tensor,
    /// Actor-head gradient and its (discarded) feature-gradient sink.
    dhead: Tensor,
    dfeat: Tensor,
    /// Inference-walk scratch for the batch-B encoder forwards (the
    /// actor's next-obs encode, the unfused target encode, the actor
    /// step's detached encode). Distinct from the training
    /// `SacAgent::ws_encoder` — inference walks overwrite the cached
    /// activations `backward` reads — and from `enc_fused`, whose
    /// buffers hold the larger `[G·B, …]` group shapes.
    enc_inf: EncoderWorkspace,
    /// Dedicated scratch for the fused target-encoder group forward.
    enc_fused: EncoderWorkspace,
    /// Online-encoder features for the actor path `[B, feature_dim]`.
    actor_feat: Tensor,
    /// Unfused target-encoder features `[B, feature_dim]`.
    tgt_feat: Tensor,
    /// Training-path online-encoder features `[B, feature_dim]`.
    online_feat: Tensor,
}

/// A replay minibatch. `obs`/`next_obs` are `[B, D]` states or
/// `[B, C, H, W]` images (when the agent has an encoder). `Default`
/// gives an empty staging batch for the allocation-free
/// `ReplayBuffer::sample_into` path (filled/resized on first use).
#[derive(Debug, Clone, Default)]
pub struct Batch {
    pub obs: Tensor,
    pub act: Tensor,
    pub rew: Vec<f32>,
    pub next_obs: Tensor,
    pub not_done: Vec<f32>,
}

/// Agent hyperparameters (paper Tables 4, 5, 9).
#[derive(Debug, Clone, Copy)]
pub struct SacConfig {
    pub obs_dim: usize,
    pub act_dim: usize,
    pub hidden: usize,
    pub gamma: f32,
    pub tau: f32,
    pub init_temperature: f32,
    pub lr: f32,
    pub adam_eps: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub target_update_freq: u64,
    pub actor_update_freq: u64,
    pub log_sig_lo: f32,
    pub log_sig_hi: f32,
    /// σ += this after exp (pixels runs use 1e-4; states 0).
    pub sigma_eps: f32,
    /// Kahan-momentum buffer scale C (1e4 states, 100 pixels).
    pub kahan_momentum_scale: f32,
    /// Target entropy H̄; the SAC convention is −|A|.
    pub target_entropy: f32,
}

impl SacConfig {
    /// Paper Table 4 defaults (states).
    pub fn states(obs_dim: usize, act_dim: usize, hidden: usize) -> Self {
        SacConfig {
            obs_dim,
            act_dim,
            hidden,
            gamma: 0.99,
            tau: 0.005,
            init_temperature: 0.1,
            lr: 1e-4,
            adam_eps: 1e-8,
            beta1: 0.9,
            beta2: 0.999,
            target_update_freq: 2,
            actor_update_freq: 1,
            log_sig_lo: -5.0,
            log_sig_hi: 2.0,
            sigma_eps: 0.0,
            kahan_momentum_scale: 1e4,
            target_entropy: -(act_dim as f32),
        }
    }

    /// Paper Table 9 deltas for pixels (`obs_dim` = encoder feature dim).
    pub fn pixels(feature_dim: usize, act_dim: usize, hidden: usize) -> Self {
        SacConfig {
            tau: 0.01,
            lr: 1e-3,
            actor_update_freq: 2,
            log_sig_lo: -10.0,
            sigma_eps: 1e-4,
            kahan_momentum_scale: 100.0,
            ..SacConfig::states(feature_dim, act_dim, hidden)
        }
    }
}

/// Per-update diagnostics.
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateStats {
    pub critic_loss: f32,
    pub actor_loss: f32,
    pub alpha_loss: f32,
    pub alpha: f32,
    pub q_mean: f32,
    pub logp_mean: f32,
    pub scale: f32,
    pub skipped_steps: u64,
}

/// The agent.
pub struct SacAgent {
    pub cfg: SacConfig,
    pub methods: Methods,
    /// Forward/backward (activation & gradient) precision.
    pub compute: Precision,
    /// Parameter & optimizer-state precision (fp32 under mixed precision).
    pub store: Precision,
    pub actor: Mlp,
    pub critic: Critic,
    pub target: Critic,
    target_ema: ScaledKahanEma,
    pub encoder: Option<Encoder>,
    pub target_encoder: Option<Encoder>,
    encoder_ema: Option<ScaledKahanEma>,
    pub log_alpha: Param,
    opt_actor: Adam,
    opt_critic: Adam,
    opt_alpha: Adam,
    sc_actor: GradScaler,
    sc_critic: GradScaler,
    sc_alpha: GradScaler,
    // training-time activation workspaces (see nn::*Workspace)
    ws_actor: MlpWorkspace,
    ws_critic: CriticWorkspace,
    ws_encoder: EncoderWorkspace,
    /// Persistent per-update scratch (noise, targets, grads, optimizer
    /// parameter list, fused target staging) — see [`UpdateWorkspace`].
    update_ws: UpdateWorkspace,
    /// Reusable `[1, …]` staging buffer for single-observation `act`.
    act_buf: Tensor,
    pub updates: u64,
    pub rng: Pcg64,
    /// Set once a non-finite action was produced (the paper scores such
    /// runs as 0).
    pub crashed: bool,
    /// Gradient magnitude telemetry for Figure 6 (filled by experiments).
    pub grad_probe: Option<Vec<f32>>,
    /// `(channels, side)` of pixel observations, if this is a pixel agent.
    pixel_shape: Option<(usize, usize)>,
    /// When set, the read-only weight tiers — target critic/encoder
    /// mirrors and [`SacAgent::policy`] snapshots — live in 16-bit
    /// storage (see [`SacAgent::set_half_storage`]).
    half_storage: Option<HalfFormat>,
}

impl SacAgent {
    /// Build a state-based agent.
    pub fn new(cfg: SacConfig, methods: Methods, precision: Precision, seed: u64) -> Self {
        Self::build(cfg, methods, precision, seed, None)
    }

    /// Build a pixel-based agent; `enc_proto` describes the encoder
    /// (frames, image side, filters). `cfg.obs_dim` must equal the
    /// encoder feature dim.
    pub fn new_pixels(
        cfg: SacConfig,
        methods: Methods,
        precision: Precision,
        seed: u64,
        frames: usize,
        img: usize,
        filters: usize,
    ) -> Self {
        let mut rng = Pcg64::seed(seed ^ 0xE11C0DE);
        // The paper applies weight-std + downscale in its fp16 pixel agent.
        let low = precision.is_low();
        let enc = Encoder::new(
            "enc",
            frames,
            img,
            filters,
            cfg.obs_dim,
            low,
            if low { Some(10.0) } else { None },
            &mut rng,
        );
        let mut agent = Self::build(cfg, methods, precision, seed, Some(enc));
        agent.pixel_shape = Some((frames, img));
        agent
    }

    fn build(
        cfg: SacConfig,
        methods: Methods,
        precision: Precision,
        seed: u64,
        encoder: Option<Encoder>,
    ) -> Self {
        let mut rng = Pcg64::seed(seed);
        let compute = precision;
        let store = if methods.mixed_precision { Precision::Fp32 } else { precision };

        let mut actor = Mlp::new(
            "actor",
            &[cfg.obs_dim, cfg.hidden, cfg.hidden, 2 * cfg.act_dim],
            &mut rng,
        );
        let mut critic = Critic::new("critic", cfg.obs_dim, cfg.act_dim, cfg.hidden, &mut rng);
        if store.is_low() {
            actor.quantize_params(store);
            critic.quantize_params(store);
        }
        let mut target = Critic::new("target", cfg.obs_dim, cfg.act_dim, cfg.hidden, &mut rng);
        let flat = critic.flat_params();
        target.load_flat(&flat);
        let target_ema = ScaledKahanEma::new(
            &flat,
            cfg.kahan_momentum_scale,
            store,
            methods.kahan_momentum,
        );

        let mut encoder = encoder;
        let (target_encoder, encoder_ema) = if let Some(enc) = encoder.as_mut() {
            if store.is_low() {
                enc.quantize_params(store);
            }
            let flat = enc.flat_params();
            let mut tgt = enc.clone();
            tgt.load_flat(&flat);
            let ema = ScaledKahanEma::new(
                &flat,
                cfg.kahan_momentum_scale,
                store,
                methods.kahan_momentum,
            );
            (Some(tgt), Some(ema))
        } else {
            (None, None)
        };

        let mut log_alpha = Param::from_values("log_alpha", &[1], vec![cfg.init_temperature.ln()]);
        log_alpha.quantize(store);

        let adam_cfg = AdamConfig { lr: cfg.lr, beta1: cfg.beta1, beta2: cfg.beta2, eps: cfg.adam_eps };
        let second = if methods.hadam { SecondMoment::Hypot } else { SecondMoment::Variance };
        let kahan_cr = if methods.kahan_gradients { UpdateMode::Kahan } else { UpdateMode::Plain };
        // paper: Kahan-gradients on critic + α, not on the actor
        let opt_actor = Adam::new(adam_cfg, store, second, UpdateMode::Plain, methods.compound_scaling);
        let opt_critic = Adam::new(adam_cfg, store, second, kahan_cr, methods.compound_scaling);
        let opt_alpha = Adam::new(
            AdamConfig { lr: cfg.lr, ..adam_cfg },
            store,
            second,
            kahan_cr,
            methods.compound_scaling,
        );

        let mk_scaler = || {
            if methods.loss_scaling {
                GradScaler::new(ScalerConfig::paper())
            } else {
                GradScaler::disabled()
            }
        };

        SacAgent {
            cfg,
            methods,
            compute,
            store,
            actor,
            critic,
            target,
            target_ema,
            encoder,
            target_encoder,
            encoder_ema,
            log_alpha,
            opt_actor,
            opt_critic,
            opt_alpha,
            sc_actor: mk_scaler(),
            sc_critic: mk_scaler(),
            sc_alpha: mk_scaler(),
            ws_actor: MlpWorkspace::default(),
            ws_critic: CriticWorkspace::default(),
            ws_encoder: EncoderWorkspace::default(),
            update_ws: UpdateWorkspace::default(),
            act_buf: Tensor::default(),
            updates: 0,
            rng,
            crashed: false,
            grad_probe: None,
            pixel_shape: None,
            half_storage: None,
        }
    }

    fn policy_cfg(&self) -> PolicyCfg {
        PolicyCfg {
            log_sig_lo: self.cfg.log_sig_lo,
            log_sig_hi: self.cfg.log_sig_hi,
            softplus_fix: self.methods.softplus_fix,
            normal_fix: self.methods.normal_fix,
            sigma_eps: self.cfg.sigma_eps,
            k_threshold: 10.0,
        }
    }

    /// Snapshot the action path (actor + pixel encoder) into an
    /// immutable, `Send + Sync` [`Policy`]: weights only — no optimizer
    /// state, activation caches or RNG. Later agent updates do not
    /// affect an existing snapshot.
    pub fn policy(&self) -> Policy {
        let obs_len = match self.pixel_shape {
            Some((c, h)) => c * h * h,
            None => self.cfg.obs_dim,
        };
        // The snapshot never trains again, so weight standardization can
        // be baked into the frozen weights (bitwise-identical forward,
        // no per-request re-standardization on the serve hot path).
        let encoder = self.encoder.clone().map(|mut enc| {
            enc.bake_weight_std(self.compute);
            enc
        });
        let mut policy = Policy::new(
            self.actor.clone(),
            encoder,
            self.policy_cfg(),
            self.compute,
            obs_len,
            self.cfg.act_dim,
            self.pixel_shape,
        );
        if let Some(fmt) = self.half_storage {
            policy.pack_weights(fmt);
        }
        policy
    }

    /// Route the read-only heavyweights through 16-bit storage: the
    /// target critic (and target-encoder conv stack) keep a packed
    /// `fmt` mirror of their EMA masters — refreshed allocation-free at
    /// every target sync — and every [`SacAgent::policy`] snapshot is
    /// packed with its f32 masters dropped. Inference GEMMs over those
    /// weights then stream half the bytes, through the SIMD widening
    /// kernels when the CPU supports them.
    ///
    /// Packing quantize-mirrors the masters (master := decode(packed)),
    /// so when the training store is already the same 16-bit grid (an
    /// fp16 run with `f16` storage) the packed tier is lossless and
    /// training trajectories are bitwise unchanged; other combinations
    /// round the read-only tier to `fmt` deterministically.
    pub fn set_half_storage(&mut self, fmt: HalfFormat) {
        self.half_storage = Some(fmt);
        self.target.pack_weights(fmt);
        if let Some(tenc) = self.target_encoder.as_mut() {
            tenc.pack_weights(fmt);
        }
    }

    /// The configured read-only storage format, if any.
    pub fn half_storage(&self) -> Option<HalfFormat> {
        self.half_storage
    }

    /// Current temperature α = exp(log α).
    pub fn alpha(&self) -> f32 {
        self.compute.q(self.log_alpha.w[0].exp())
    }

    /// Select an action for a single observation. `stochastic` samples
    /// from π; otherwise uses tanh(μ). Returns `None` (and flags
    /// `crashed`) if the action is non-finite, mirroring the paper's
    /// crash accounting.
    ///
    /// This is [`SacAgent::act_batch`] with batch 1, staged through a
    /// reusable buffer — no per-call observation allocation.
    pub fn act(&mut self, obs: &[f32], stochastic: bool) -> Option<Vec<f32>> {
        // re-grow the staging buffer only on a shape change (first call)
        match self.pixel_shape {
            // caller passes a flattened [C, H, W] image
            Some((c, h)) => {
                if self.act_buf.shape != [1, c, h, h] {
                    self.act_buf = Tensor::zeros(&[1, c, h, h]);
                }
            }
            None => {
                if self.act_buf.shape != [1, obs.len()] {
                    self.act_buf = Tensor::zeros(&[1, obs.len()]);
                }
            }
        }
        self.act_buf.data.copy_from_slice(obs);
        // temporarily take the buffer so act_batch can borrow &mut self
        let buf = std::mem::take(&mut self.act_buf);
        let out = self.act_batch(&buf, stochastic);
        self.act_buf = buf;
        out.map(|a| a.data)
    }

    /// Batched action selection: `[B, D]` states (or `[B, C, H, W]`
    /// images) → `[B, act_dim]`, one shared GEMM per layer for all B
    /// observations. In deterministic mode (`stochastic = false`) row
    /// `r` is bitwise identical to [`SacAgent::act`] on observation `r`
    /// alone (the GEMM backend accumulates output rows independently of
    /// the batch size); in stochastic mode the rows draw consecutive
    /// slices of the agent's RNG stream, so only batch 1 reproduces a
    /// single `act` call exactly. Returns `None` (and flags `crashed`)
    /// if any action is non-finite.
    pub fn act_batch(&mut self, obs: &Tensor, stochastic: bool) -> Option<Tensor> {
        let p = self.compute;
        // pixel agents encode first; state agents feed obs straight in
        let enc_feat;
        let feat: &Tensor = match self.encoder.as_ref() {
            Some(enc) => {
                enc_feat = enc.forward(obs, p);
                &enc_feat
            }
            None => obs,
        };
        let head = self.actor.forward(feat, p);
        let a = if stochastic {
            let b = head.rows();
            let mut eps = Tensor::zeros(&[b, self.cfg.act_dim]);
            self.rng.normal_fill(&mut eps.data);
            TanhGaussian::forward(&head, &eps, self.policy_cfg(), p).a
        } else {
            TanhGaussian::mean_action(&head, p)
        };
        self.guard_actions(a)
    }

    /// Stochastic batched action selection over vectorized env streams:
    /// one shared forward for all rows, with row `i`'s exploration noise
    /// drawn from `rngs[i]` instead of the agent's own stream (the same
    /// noise layout as `ActMode::SamplePerEnv`). Each env stream
    /// therefore owns an independent noise sequence, which makes an
    /// N-env rollout bitwise reproducible and row results invariant to
    /// how streams are batched (the GEMM backend accumulates rows
    /// independently). Crash semantics match [`SacAgent::act_batch`].
    pub fn act_batch_envs(&mut self, obs: &Tensor, rngs: &mut [Pcg64]) -> Option<Tensor> {
        let p = self.compute;
        // obs is [B, D] or [B, C, H, W]: the batch is the leading dim.
        // Drawing (and shape-checking) the noise first keeps a
        // mismatched rngs slice from wasting the forward.
        let eps = super::snapshot::per_env_eps(obs.shape[0], self.cfg.act_dim, rngs);
        let enc_feat;
        let feat: &Tensor = match self.encoder.as_ref() {
            Some(enc) => {
                enc_feat = enc.forward(obs, p);
                &enc_feat
            }
            None => obs,
        };
        let head = self.actor.forward(feat, p);
        let a = TanhGaussian::forward(&head, &eps, self.policy_cfg(), p).a;
        self.guard_actions(a)
    }

    /// Shared crash guard: a non-finite action flags the agent as
    /// crashed (the paper's accounting) and yields `None`.
    fn guard_actions(&mut self, a: Tensor) -> Option<Tensor> {
        if a.has_nonfinite() {
            self.crashed = true;
            return None;
        }
        Some(a)
    }

    /// One gradient update from a replay batch — a round of one (see
    /// [`SacAgent::update_round`]).
    pub fn update(&mut self, batch: &Batch) -> UpdateStats {
        self.update_round(std::slice::from_ref(batch))
    }

    /// Run one gradient update per batch, in order, over a round of
    /// pre-sampled minibatches. Bitwise identical to calling
    /// [`SacAgent::update`] once per batch: the only cross-update work
    /// is the fused target-encoder forward, which groups consecutive
    /// updates that *read the same target weights* (boundaries are cut
    /// wherever a target sync lands) and relies on the GEMM backend's
    /// row invariance — so every preset, including `batches.len() == 1`,
    /// reproduces the per-update path exactly. Returns the last update's
    /// stats.
    pub fn update_round(&mut self, batches: &[Batch]) -> UpdateStats {
        let mut ws = std::mem::take(&mut self.update_ws);
        self.plan_fused_groups(batches, &mut ws);
        let mut last = UpdateStats::default();
        let mut next_group = 0usize;
        for (j, batch) in batches.iter().enumerate() {
            // A fused group's forward runs exactly when its first update
            // is reached: the previous update (and its target sync, which
            // is what cut the boundary) has fully executed, so the
            // weights the whole group reads are current here.
            while next_group < ws.fused_groups.len() && ws.fused_groups[next_group].0 == j {
                let (a, b) = ws.fused_groups[next_group];
                next_group += 1;
                if b - a >= 2 {
                    self.fuse_group(&batches[a..b], a, &mut ws);
                }
            }
            let fused = ws.fused_off[j] != usize::MAX;
            if fused {
                // stage this update's precomputed [B, feature_dim] rows
                let fd = self.cfg.obs_dim;
                let off = ws.fused_off[j] * fd;
                let rows = batch.rew.len();
                ws.feat_tgt.stage_rows(&ws.fused_feat.data[off..off + rows * fd], rows, &[fd]);
            }
            last = self.update_one(batch, fused, &mut ws);
        }
        self.update_ws = ws;
        last
    }

    /// Partition a round into maximal runs of updates that read the same
    /// target-network weights. Update `c` syncs the target after its own
    /// step iff `c % target_update_freq == 0`, so a boundary falls
    /// before local update `j > 0` iff update `updates + j - 1` syncs.
    /// Only the *boundaries* are computed here — each multi-update
    /// group's fused forward runs lazily at the group's first update
    /// ([`SacAgent::fuse_group`]), after every preceding sync has
    /// landed. The target *critic* forward cannot be fused the same
    /// way: its input `a'` comes from the actor (through the online
    /// encoder), and both step inside the group (see the README's
    /// learner-throughput notes).
    fn plan_fused_groups(&self, batches: &[Batch], ws: &mut UpdateWorkspace) {
        ws.fused_off.clear();
        ws.fused_off.resize(batches.len(), usize::MAX);
        ws.fused_groups.clear();
        if self.target_encoder.is_none() {
            return;
        }
        let n = batches.len();
        if n < 2 {
            return;
        }
        let freq = self.cfg.target_update_freq.max(1);
        let c0 = self.updates;
        let mut start = 0usize;
        for j in 1..=n {
            if j == n || (c0 + j as u64 - 1) % freq == 0 {
                ws.fused_groups.push((start, j));
                start = j;
            }
        }
    }

    /// Run ONE target-encoder forward for a whole group of updates
    /// (`[G·B, C, H, W]` instead of G separate `[B, …]` forwards —
    /// shared im2col GEMMs), and record each update's row offset into
    /// the fused output. Rows are bitwise equal to the per-batch
    /// forwards (row-invariant GEMM backend), so consuming a staged
    /// slice reproduces the unfused path exactly.
    fn fuse_group(&self, group: &[Batch], base_j: usize, ws: &mut UpdateWorkspace) {
        let Some(tenc) = self.target_encoder.as_ref() else { return };
        let p = self.compute;
        let rows: usize = group.iter().map(|bt| bt.rew.len()).sum();
        // stage the group's next-obs rows contiguously (shape scratch
        // reused round after round)
        let UpdateWorkspace { fused_stage, fused_shape, enc_fused, fused_feat, .. } = &mut *ws;
        fused_shape.clear();
        fused_shape.push(rows);
        fused_shape.extend_from_slice(&group[0].next_obs.shape[1..]);
        fused_stage.ensure_shape(fused_shape);
        let mut off = 0usize;
        for bt in group {
            let nfl = bt.next_obs.data.len();
            fused_stage.data[off..off + nfl].copy_from_slice(&bt.next_obs.data);
            off += nfl;
        }
        // the group forward runs in its own workspace: group rows (G·B)
        // and per-update rows (B) differ, so sharing `enc_inf` would
        // bounce every buffer between the two shapes each round
        tenc.forward_into(fused_stage, p, enc_fused, fused_feat);
        let mut r = 0usize;
        for (jj, bt) in group.iter().enumerate() {
            ws.fused_off[base_j + jj] = r;
            r += bt.rew.len();
        }
    }

    /// The per-update body shared by [`SacAgent::update`] and
    /// [`SacAgent::update_round`]; `fused_tgt` means the round
    /// precomputed this update's target features into the workspace.
    fn update_one(&mut self, batch: &Batch, fused_tgt: bool, ws: &mut UpdateWorkspace) -> UpdateStats {
        let mut stats = UpdateStats { alpha: self.alpha(), ..Default::default() };
        self.update_critic(batch, fused_tgt, ws, &mut stats);
        if self.updates % self.cfg.actor_update_freq == 0 {
            self.update_actor_alpha(batch, ws, &mut stats);
        }
        if self.updates % self.cfg.target_update_freq == 0 {
            self.update_target();
        }
        self.updates += 1;
        stats.scale = self.sc_critic.scale();
        stats.skipped_steps =
            self.sc_critic.skipped + self.sc_actor.skipped + self.sc_alpha.skipped;
        stats
    }

    fn update_critic(
        &mut self,
        batch: &Batch,
        fused_tgt: bool,
        ws: &mut UpdateWorkspace,
        stats: &mut UpdateStats,
    ) {
        let p = self.compute;
        let b = batch.rew.len();
        let alpha = self.alpha();

        // -- target value (no gradients kept anywhere: inference path) --
        // DRQ convention: the *actor* uses the online encoder (detached).
        // State agents feed the raw observations straight through — no
        // staging clone.
        {
            let UpdateWorkspace { enc_inf, actor_feat, actor_inf, head, eps, tg, .. } = &mut *ws;
            let feat_next_actor: &Tensor = match self.encoder.as_ref() {
                Some(enc) => {
                    enc.forward_into(&batch.next_obs, p, enc_inf, actor_feat);
                    actor_feat
                }
                None => &batch.next_obs,
            };
            self.actor.forward_into(feat_next_actor, p, actor_inf, head);
            eps.ensure_shape(&[b, self.cfg.act_dim]);
            self.rng.normal_fill(&mut eps.data);
            tg.forward_into(head, eps, self.policy_cfg(), p);
        }
        {
            let UpdateWorkspace { feat_tgt, tg, tgt_critic, tq1, tq2, enc_inf, tgt_feat, .. } =
                &mut *ws;
            let feat_next_tgt: &Tensor = if fused_tgt {
                feat_tgt
            } else {
                match self.target_encoder.as_ref() {
                    Some(enc) => {
                        enc.forward_into(&batch.next_obs, p, enc_inf, tgt_feat);
                        tgt_feat
                    }
                    None => &batch.next_obs,
                }
            };
            self.target.forward_into(feat_next_tgt, &tg.a, p, tgt_critic, tq1, tq2);
        }
        ws.y.resize(b, 0.0);
        for r in 0..b {
            let tq = ws.tq1.data[r].min(ws.tq2.data[r]);
            let v = p.q(tq - p.q(alpha * ws.tg.logp[r]));
            ws.y[r] = p.q(batch.rew[r] + p.q(self.cfg.gamma * batch.not_done[r]) * v);
        }

        // -- online critic (training path: fills the workspaces) --------
        {
            let UpdateWorkspace { online_feat, q1, q2, .. } = &mut *ws;
            let feat: &Tensor = match self.encoder.as_ref() {
                Some(enc) => {
                    enc.forward_train_into(&batch.obs, p, &mut self.ws_encoder, online_feat);
                    online_feat
                }
                None => &batch.obs,
            };
            self.critic.forward_train_into(feat, &batch.act, p, &mut self.ws_critic, q1, q2);
        }
        let scale = self.sc_critic.scale();
        let mut loss = 0.0f64;
        ws.dq1.ensure_shape(&[b, 1]);
        ws.dq2.ensure_shape(&[b, 1]);
        for r in 0..b {
            let e1 = ws.q1.data[r] - ws.y[r];
            let e2 = ws.q2.data[r] - ws.y[r];
            loss += (e1 as f64).powi(2) + (e2 as f64).powi(2);
            ws.dq1.data[r] = p.q(2.0 * e1 / b as f32 * scale);
            ws.dq2.data[r] = p.q(2.0 * e2 / b as f32 * scale);
        }
        stats.critic_loss = (loss / b as f64) as f32;
        stats.q_mean = ws.q1.mean();

        self.critic.zero_grad();
        if let Some(enc) = self.encoder.as_mut() {
            enc.zero_grad();
        }
        if self.encoder.is_some() {
            let UpdateWorkspace { dq1, dq2, dobs, da, .. } = &mut *ws;
            self.critic.backward_full_into(dq1, dq2, p, &mut self.ws_critic, dobs, da);
            // tidy-allow(panic): guarded by the `is_some()` check directly above.
            self.encoder.as_mut().unwrap().backward(dobs, p, &mut self.ws_encoder);
        } else {
            let UpdateWorkspace { dq1, dq2, da, .. } = &mut *ws;
            self.critic.backward_into(dq1, dq2, p, &mut self.ws_critic, da);
        }

        if self.methods.coerce {
            let mx = p.max_value();
            self.critic.for_each_param_mut(&mut |prm: &mut Param| {
                coerce_nonfinite(&mut prm.g, mx);
            });
        }
        // probe gradients for Figure 6 telemetry (pooled |g| append)
        if let Some(probe) = self.grad_probe.as_mut() {
            self.critic.for_each_param(&mut |prm: &Param| {
                append_abs_pooled(probe, &prm.g);
            });
        }
        // optimizer step (critic + encoder parameters together), through
        // the persistent pointer scratch — no per-update Vec builds
        ws.params.clear();
        self.critic.for_each_param_mut(&mut |prm: &mut Param| ws.params.push(prm));
        if let Some(enc) = self.encoder.as_mut() {
            enc.for_each_param_mut(&mut |prm: &mut Param| ws.params.push(prm));
        }
        self.opt_critic.step(ws.params.as_params(), &mut self.sc_critic);
    }

    fn update_actor_alpha(&mut self, batch: &Batch, ws: &mut UpdateWorkspace, stats: &mut UpdateStats) {
        let p = self.compute;
        let b = batch.rew.len();
        let alpha = self.alpha();

        // actor loss: E[α logπ - min Q], encoder features detached
        // (inference encode — no gradient flows into the encoder here)
        {
            let UpdateWorkspace { enc_inf, actor_feat, head, eps, tg, q1, q2, .. } = &mut *ws;
            let feat: &Tensor = match self.encoder.as_ref() {
                Some(enc) => {
                    enc.forward_into(&batch.obs, p, enc_inf, actor_feat);
                    actor_feat
                }
                None => &batch.obs,
            };
            self.actor.forward_train_into(feat, p, &mut self.ws_actor, head);
            eps.ensure_shape(&[b, self.cfg.act_dim]);
            self.rng.normal_fill(&mut eps.data);
            tg.forward_into(head, eps, self.policy_cfg(), p);
            self.critic.forward_train_into(feat, &tg.a, p, &mut self.ws_critic, q1, q2);
        }

        let scale = self.sc_actor.scale();
        let mut loss = 0.0f64;
        ws.dq1.ensure_shape(&[b, 1]);
        ws.dq2.ensure_shape(&[b, 1]);
        ws.dq1.data.fill(0.0);
        ws.dq2.data.fill(0.0);
        let coef = p.q(scale / b as f32);
        for r in 0..b {
            let qmin = ws.q1.data[r].min(ws.q2.data[r]);
            loss += (alpha * ws.tg.logp[r] - qmin) as f64;
            // d(-qmin)/dq: route to the smaller head
            if ws.q1.data[r] <= ws.q2.data[r] {
                ws.dq1.data[r] = -coef;
            } else {
                ws.dq2.data[r] = -coef;
            }
        }
        stats.actor_loss = (loss / b as f64) as f32;
        stats.logp_mean =
            ws.tg.logp.iter().map(|&v| v as f64).sum::<f64>() as f32 / b as f32;

        // dQ/da through the critic (param grads discarded afterwards)
        self.critic.zero_grad();
        {
            let UpdateWorkspace { dq1, dq2, da, .. } = &mut *ws;
            self.critic.backward_into(dq1, dq2, p, &mut self.ws_critic, da);
        }
        ws.coefs.clear();
        ws.coefs.resize(b, p.q(alpha * coef));
        {
            let UpdateWorkspace { tg, coefs, da, dhead, .. } = &mut *ws;
            tg.backward_into(coefs, Some(&*da), dhead);
        }
        self.actor.zero_grad();
        {
            let UpdateWorkspace { dhead, dfeat, .. } = &mut *ws;
            self.actor.backward_into(dhead, p, &mut self.ws_actor, dfeat);
        }
        self.critic.zero_grad(); // discard critic grads from this pass

        if self.methods.coerce {
            let mx = p.max_value();
            self.actor.for_each_param_mut(&mut |prm: &mut Param| {
                coerce_nonfinite(&mut prm.g, mx);
            });
        }
        if let Some(probe) = self.grad_probe.as_mut() {
            self.actor.for_each_param(&mut |prm: &Param| {
                append_abs_pooled(probe, &prm.g);
            });
        }
        ws.params.clear();
        self.actor.for_each_param_mut(&mut |prm: &mut Param| ws.params.push(prm));
        self.opt_actor.step(ws.params.as_params(), &mut self.sc_actor);

        // -- temperature ------------------------------------------------
        // L(α) = −α · mean(logπ + H̄)  (logπ detached)
        let mean_term = ws
            .tg
            .logp
            .iter()
            .map(|&lp| (lp + self.cfg.target_entropy) as f64)
            .sum::<f64>() as f32
            / b as f32;
        stats.alpha_loss = -alpha * mean_term;
        let ascale = self.sc_alpha.scale();
        // d/d logα of −exp(logα)·mean_term
        self.log_alpha.g[0] = p.q(-alpha * mean_term * ascale);
        if self.methods.coerce {
            coerce_nonfinite(&mut self.log_alpha.g, p.max_value());
        }
        self.opt_alpha.step(&mut [&mut self.log_alpha], &mut self.sc_alpha);
    }

    /// Soft-update the target critic (and target encoder) toward the
    /// online weights. The EMA reads ψ straight out of the per-layer
    /// parameter slices and the target parameters copy straight from the
    /// refreshed view — the old `flat_params()` → `update` → `load_flat`
    /// path materialized a fresh flattened copy of every critic weight
    /// on each sync; now the only data movement is the EMA math itself
    /// (pooled) plus one memcpy per layer into the target.
    fn update_target(&mut self) {
        let tau = self.cfg.tau;
        let ema = &mut self.target_ema;
        let mut off = 0usize;
        self.critic.for_each_param(&mut |prm: &Param| {
            ema.update_span(off, &prm.w, tau);
            off += prm.len();
        });
        debug_assert_eq!(off, ema.len(), "EMA must cover every critic weight");
        let view = ema.weights();
        let mut off = 0usize;
        self.target.for_each_param_mut(&mut |prm: &mut Param| {
            prm.w.copy_from_slice(&view[off..off + prm.len()]);
            off += prm.len();
        });
        if let (Some(enc), Some(ema), Some(tgt)) = (
            self.encoder.as_ref(),
            self.encoder_ema.as_mut(),
            self.target_encoder.as_mut(),
        ) {
            let mut off = 0usize;
            enc.for_each_param(&mut |prm: &Param| {
                ema.update_span(off, &prm.w, tau);
                off += prm.len();
            });
            debug_assert_eq!(off, ema.len(), "EMA must cover every encoder weight");
            let view = ema.weights();
            let mut off = 0usize;
            tgt.for_each_param_mut(&mut |prm: &mut Param| {
                prm.w.copy_from_slice(&view[off..off + prm.len()]);
                off += prm.len();
            });
        }
        // refresh the packed read-only mirrors from the synced masters
        if self.half_storage.is_some() {
            self.target.repack_weights();
            if let Some(tenc) = self.target_encoder.as_mut() {
                tenc.repack_weights();
            }
        }
    }

    /// Total learnable parameters (actor + critic [+ encoder]) — a
    /// read-only count.
    pub fn n_params(&self) -> usize {
        let mut n = self.actor.n_params() + self.critic.n_params();
        if let Some(enc) = self.encoder.as_ref() {
            n += enc.n_params();
        }
        n
    }

    /// Flatten the actor (and encoder) weight masters — the pre-round
    /// capture the async trainer checkpoints so a resumed run can
    /// rebuild the lag-window's *previous* policy snapshot bitwise (see
    /// [`SacAgent::policy_from_flats`]).
    pub fn actor_flats(&self) -> (Vec<f32>, Option<Vec<f32>>) {
        let mut a = Vec::with_capacity(self.actor.n_params());
        self.actor.for_each_param(&mut |p: &Param| a.extend_from_slice(&p.w));
        let e = self.encoder.as_ref().map(|enc| {
            let mut v = Vec::with_capacity(enc.n_params());
            enc.for_each_param(&mut |p: &Param| v.extend_from_slice(&p.w));
            v
        });
        (a, e)
    }

    /// [`SacAgent::policy`] over an explicit weight capture instead of
    /// the live masters: the same clone → bake-weight-std → pack
    /// transform, so a snapshot rebuilt from an
    /// [`SacAgent::actor_flats`] capture is bitwise identical to the one
    /// the original run published from those weights.
    pub fn policy_from_flats(&self, actor_flat: &[f32], enc_flat: Option<&[f32]>) -> Policy {
        let obs_len = match self.pixel_shape {
            Some((c, h)) => c * h * h,
            None => self.cfg.obs_dim,
        };
        let mut actor = self.actor.clone();
        let mut off = 0usize;
        actor.for_each_param_mut(&mut |p: &mut Param| {
            p.w.copy_from_slice(&actor_flat[off..off + p.len()]);
            off += p.len();
        });
        assert_eq!(off, actor_flat.len(), "actor capture must cover every weight");
        let encoder = self.encoder.clone().map(|mut enc| {
            if let Some(flat) = enc_flat {
                enc.load_flat(flat);
            }
            enc.bake_weight_std(self.compute);
            enc
        });
        let mut policy = Policy::new(
            actor,
            encoder,
            self.policy_cfg(),
            self.compute,
            obs_len,
            self.cfg.act_dim,
            self.pixel_shape,
        );
        if let Some(fmt) = self.half_storage {
            policy.pack_weights(fmt);
        }
        policy
    }

    /// Serialize every piece of learner state a bitwise resume needs:
    /// weight masters (actor, critic, encoder), the target EMAs
    /// (scaled buffer + compensation + view), all three optimizers and
    /// scalers, log α, the update counter, the agent RNG position, the
    /// crash flag, and the Figure 6 gradient probe. Workspaces,
    /// activation caches and packed read-only mirrors are transient —
    /// rebuilt on demand / repacked from the restored masters.
    pub fn ckpt_write(&self, enc: &mut crate::ckpt::Enc) {
        enc.u64(self.updates);
        enc.bool(self.crashed);
        let (state, inc) = self.rng.raw_state();
        enc.u128(state);
        enc.u128(inc);
        enc.f32s(&self.log_alpha.w);
        self.actor.for_each_param(&mut |p: &Param| enc.f32s(&p.w));
        self.critic.for_each_param(&mut |p: &Param| enc.f32s(&p.w));
        enc.bool(self.encoder.is_some());
        if let Some(e) = self.encoder.as_ref() {
            e.for_each_param(&mut |p: &Param| enc.f32s(&p.w));
        }
        self.target_ema.ckpt_write(enc);
        if let Some(ema) = self.encoder_ema.as_ref() {
            ema.ckpt_write(enc);
        }
        self.opt_actor.ckpt_write(enc);
        self.opt_critic.ckpt_write(enc);
        self.opt_alpha.ckpt_write(enc);
        self.sc_actor.ckpt_write(enc);
        self.sc_critic.ckpt_write(enc);
        self.sc_alpha.ckpt_write(enc);
        enc.bool(self.grad_probe.is_some());
        if let Some(p) = self.grad_probe.as_ref() {
            enc.f32s(p);
        }
    }

    /// Restore a [`SacAgent::ckpt_write`] snapshot into this
    /// (identically configured) agent. Target networks are rebuilt from
    /// the restored EMA views — exactly how a live target sync refreshes
    /// them — and the packed half-storage mirrors are repacked from the
    /// restored masters, so the resumed agent is bitwise
    /// indistinguishable from one that never stopped.
    pub fn ckpt_read(&mut self, dec: &mut crate::ckpt::Dec) -> anyhow::Result<()> {
        self.updates = dec.u64()?;
        self.crashed = dec.bool()?;
        let state = dec.u128()?;
        let inc = dec.u128()?;
        self.rng = Pcg64::from_raw_state(state, inc);
        dec.f32s_into(&mut self.log_alpha.w)?;
        read_params_into(dec, |mut f| self.actor.for_each_param_mut(&mut f))?;
        read_params_into(dec, |mut f| self.critic.for_each_param_mut(&mut f))?;
        let has_encoder = dec.bool()?;
        anyhow::ensure!(
            has_encoder == self.encoder.is_some(),
            "checkpoint {} an encoder but this agent {}",
            if has_encoder { "carries" } else { "lacks" },
            if self.encoder.is_some() { "has one" } else { "does not" }
        );
        if let Some(e) = self.encoder.as_mut() {
            read_params_into(dec, |mut f| e.for_each_param_mut(&mut f))?;
        }
        self.target_ema.ckpt_read(dec)?;
        {
            let view = self.target_ema.weights();
            let mut off = 0usize;
            self.target.for_each_param_mut(&mut |p: &mut Param| {
                p.w.copy_from_slice(&view[off..off + p.len()]);
                off += p.len();
            });
        }
        if let (Some(ema), Some(tgt)) = (self.encoder_ema.as_mut(), self.target_encoder.as_mut()) {
            ema.ckpt_read(dec)?;
            let view = ema.weights();
            let mut off = 0usize;
            tgt.for_each_param_mut(&mut |p: &mut Param| {
                p.w.copy_from_slice(&view[off..off + p.len()]);
                off += p.len();
            });
        }
        self.opt_actor.ckpt_read(dec)?;
        self.opt_critic.ckpt_read(dec)?;
        self.opt_alpha.ckpt_read(dec)?;
        self.sc_actor.ckpt_read(dec)?;
        self.sc_critic.ckpt_read(dec)?;
        self.sc_alpha.ckpt_read(dec)?;
        self.grad_probe = if dec.bool()? { Some(dec.f32s()?) } else { None };
        if self.half_storage.is_some() {
            self.target.repack_weights();
            if let Some(tenc) = self.target_encoder.as_mut() {
                tenc.repack_weights();
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch(b: usize, obs_dim: usize, act_dim: usize, rng: &mut Pcg64) -> Batch {
        let mut obs = Tensor::zeros(&[b, obs_dim]);
        rng.normal_fill(&mut obs.data);
        let mut next_obs = Tensor::zeros(&[b, obs_dim]);
        rng.normal_fill(&mut next_obs.data);
        let mut act = Tensor::zeros(&[b, act_dim]);
        for v in act.data.iter_mut() {
            *v = rng.uniform_in(-1.0, 1.0);
        }
        Batch {
            obs,
            act,
            rew: (0..b).map(|_| rng.uniform_f32()).collect(),
            next_obs,
            not_done: vec![1.0; b],
        }
    }

    #[test]
    fn fp32_update_runs_and_changes_params() {
        let mut rng = Pcg64::seed(1);
        let cfg = SacConfig::states(6, 2, 32);
        let mut agent = SacAgent::new(cfg, Methods::none(), Precision::Fp32, 7);
        let before = agent.critic.flat_params();
        for _ in 0..5 {
            let b = toy_batch(16, 6, 2, &mut rng);
            let s = agent.update(&b);
            assert!(s.critic_loss.is_finite());
        }
        let after = agent.critic.flat_params();
        assert_ne!(before, after);
    }

    #[test]
    fn act_returns_bounded_actions() {
        let cfg = SacConfig::states(4, 3, 16);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 3);
        let a = agent.act(&[0.1, -0.2, 0.3, 0.4], true).unwrap();
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|v| (-1.0..=1.0).contains(v)));
        let d = agent.act(&[0.1, -0.2, 0.3, 0.4], false).unwrap();
        assert!(d.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn act_batch_rows_equal_single_act() {
        let mut rng = Pcg64::seed(9);
        let cfg = SacConfig::states(5, 2, 24);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 4);
        let b = 7;
        let mut obs = Tensor::zeros(&[b, 5]);
        rng.normal_fill(&mut obs.data);
        let batched = agent.act_batch(&obs, false).unwrap();
        for r in 0..b {
            let single = agent.act(obs.row(r), false).unwrap();
            for (x, y) in single.iter().zip(batched.row(r)) {
                assert_eq!(x.to_bits(), y.to_bits(), "row {r}");
            }
        }
    }

    #[test]
    fn act_batch_envs_matches_policy_per_env_sampling() {
        // The live agent's per-env sampling path and a Policy snapshot's
        // SamplePerEnv mode run the same weights and the same per-row
        // noise streams — their actions must agree bitwise, and the
        // agent's own RNG must stay untouched.
        use crate::sac::ActMode;
        let cfg = SacConfig::states(5, 2, 24);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 8);
        let policy = agent.policy();
        let before = agent.rng.clone().next_u64();
        let n = 4;
        let mut obs = Tensor::zeros(&[n, 5]);
        Pcg64::seed(6).normal_fill(&mut obs.data);
        let mut r1: Vec<Pcg64> = (0..n).map(|i| Pcg64::seed_stream(3, i as u64)).collect();
        let mut r2 = r1.clone();
        let live = agent.act_batch_envs(&obs, &mut r1).unwrap();
        let snap = policy.act_batch(&obs, ActMode::SamplePerEnv(&mut r2));
        assert!(live.data.iter().zip(&snap.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert_eq!(agent.rng.clone().next_u64(), before, "agent RNG untouched");
        // deterministic in the streams: fresh clones reproduce exactly
        let mut r3: Vec<Pcg64> = (0..n).map(|i| Pcg64::seed_stream(3, i as u64)).collect();
        let again = agent.act_batch_envs(&obs, &mut r3).unwrap();
        assert_eq!(live.data, again.data);
    }

    #[test]
    fn fp16_ours_stays_finite_over_many_updates() {
        let mut rng = Pcg64::seed(2);
        let cfg = SacConfig::states(6, 2, 32);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 11);
        for i in 0..30 {
            let b = toy_batch(8, 6, 2, &mut rng);
            let s = agent.update(&b);
            assert!(
                s.critic_loss.is_finite(),
                "update {i}: critic loss {}",
                s.critic_loss
            );
        }
        assert!(!agent.crashed);
        for prm in agent.critic.params_mut() {
            assert!(prm.w.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn target_tracks_critic() {
        let mut rng = Pcg64::seed(3);
        let cfg = SacConfig { tau: 0.5, ..SacConfig::states(4, 2, 16) };
        let mut agent = SacAgent::new(cfg, Methods::none(), Precision::Fp32, 5);
        let c0 = agent.critic.flat_params();
        let t0 = agent.target.flat_params();
        assert_eq!(c0, t0, "target initialized to critic");
        for _ in 0..10 {
            let b = toy_batch(8, 4, 2, &mut rng);
            agent.update(&b);
        }
        let c = agent.critic.flat_params();
        let t = agent.target.flat_params();
        assert_ne!(t, t0, "target must move");
        // target lags the critic: distance(t, c) > 0 but should be modest
        let d: f32 = c.iter().zip(&t).map(|(a, b)| (a - b).abs()).sum();
        assert!(d > 0.0);
    }

    #[test]
    fn alpha_adapts_toward_target_entropy() {
        let mut rng = Pcg64::seed(4);
        let cfg = SacConfig { lr: 1e-2, ..SacConfig::states(4, 2, 16) };
        let mut agent = SacAgent::new(cfg, Methods::none(), Precision::Fp32, 6);
        let a0 = agent.alpha();
        for _ in 0..50 {
            let b = toy_batch(16, 4, 2, &mut rng);
            agent.update(&b);
        }
        assert_ne!(agent.alpha(), a0, "temperature must adapt");
        assert!(agent.alpha() > 0.0);
    }

    #[test]
    fn pixel_agent_update_runs() {
        let mut rng = Pcg64::seed(5);
        let cfg = SacConfig::pixels(8, 2, 24); // feature_dim 8
        let mut agent = SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
        let b = 4;
        let mut obs = Tensor::zeros(&[b, 3, 21, 21]);
        for v in obs.data.iter_mut() {
            *v = rng.uniform_f32();
        }
        let mut next_obs = obs.clone();
        for v in next_obs.data.iter_mut() {
            *v = (*v + 0.01).min(1.0);
        }
        let mut act = Tensor::zeros(&[b, 2]);
        for v in act.data.iter_mut() {
            *v = rng.uniform_in(-1.0, 1.0);
        }
        let batch = Batch {
            obs,
            act,
            rew: vec![0.5; b],
            next_obs,
            not_done: vec![1.0; b],
        };
        for _ in 0..3 {
            let s = agent.update(&batch);
            assert!(s.critic_loss.is_finite(), "loss={}", s.critic_loss);
        }
    }

    #[test]
    fn update_workspace_buffers_are_reused_steady_state() {
        // after the first update warms the workspace, further updates of
        // the same batch shape must not reallocate any driver buffer
        let mut rng = Pcg64::seed(21);
        let cfg = SacConfig::states(6, 2, 32);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 13);
        let b = toy_batch(16, 6, 2, &mut rng);
        agent.update(&b);
        let ptrs = (
            agent.update_ws.eps.data.as_ptr(),
            agent.update_ws.y.as_ptr(),
            agent.update_ws.dq1.data.as_ptr(),
            agent.update_ws.dq2.data.as_ptr(),
            agent.update_ws.coefs.as_ptr(),
            agent.update_ws.params.ptrs.as_ptr(),
        );
        for _ in 0..3 {
            let b = toy_batch(16, 6, 2, &mut rng);
            agent.update(&b);
            let now = (
                agent.update_ws.eps.data.as_ptr(),
                agent.update_ws.y.as_ptr(),
                agent.update_ws.dq1.data.as_ptr(),
                agent.update_ws.dq2.data.as_ptr(),
                agent.update_ws.coefs.as_ptr(),
                agent.update_ws.params.ptrs.as_ptr(),
            );
            assert_eq!(ptrs, now, "steady-state update must not reallocate the workspace");
        }
    }

    #[test]
    fn pixel_update_reuses_feature_buffers_steady_state() {
        // pixels path: after the first update warms the encoder walks,
        // further updates of the same batch shape must not reallocate
        // the feature staging tensors (the inference/training encoder
        // workspaces behind them are pointer-checked in encoder.rs)
        let mut rng = Pcg64::seed(23);
        let cfg = SacConfig::pixels(8, 2, 24);
        let mut agent = SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
        let b = 4;
        let mut obs = Tensor::zeros(&[b, 3, 21, 21]);
        for v in obs.data.iter_mut() {
            *v = rng.uniform_f32();
        }
        let batch = Batch {
            obs: obs.clone(),
            act: Tensor::zeros(&[b, 2]),
            rew: vec![0.1; b],
            next_obs: obs,
            not_done: vec![1.0; b],
        };
        // two warm-ups: the actor step runs every other update (pixels
        // actor_update_freq = 2), so both bodies must have filled their
        // buffers before pinning pointers
        agent.update(&batch);
        agent.update(&batch);
        let ptrs = (
            agent.update_ws.actor_feat.data.as_ptr(),
            agent.update_ws.tgt_feat.data.as_ptr(),
            agent.update_ws.online_feat.data.as_ptr(),
            agent.update_ws.head.data.as_ptr(),
        );
        for _ in 0..4 {
            agent.update(&batch);
            let now = (
                agent.update_ws.actor_feat.data.as_ptr(),
                agent.update_ws.tgt_feat.data.as_ptr(),
                agent.update_ws.online_feat.data.as_ptr(),
                agent.update_ws.head.data.as_ptr(),
            );
            assert_eq!(ptrs, now, "pixels steady state must not reallocate feature staging");
        }
    }

    #[test]
    fn f16_half_storage_is_bitwise_invisible_under_fp16_store() {
        // With an fp16 training store every target weight sits on the
        // f16 grid, so packing the target mirror is lossless and the
        // half-storage GEMM path (SIMD or scalar) must reproduce the
        // f32-master trajectory bitwise, update after update.
        let mut rng = Pcg64::seed(51);
        let cfg = SacConfig::states(6, 2, 24);
        let mut plain = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 19);
        let mut packed = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 19);
        packed.set_half_storage(HalfFormat::F16);
        assert_eq!(packed.half_storage(), Some(HalfFormat::F16));
        for _ in 0..12 {
            let b = toy_batch(8, 6, 2, &mut rng);
            plain.update(&b);
            packed.update(&b);
        }
        let (ta, tb) = (plain.target.flat_params(), packed.target.flat_params());
        assert!(ta.iter().zip(&tb).all(|(x, y)| x.to_bits() == y.to_bits()));
        let (ca, cb) = (plain.critic.flat_params(), packed.critic.flat_params());
        assert!(ca.iter().zip(&cb).all(|(x, y)| x.to_bits() == y.to_bits()));
        let mut obs = Tensor::zeros(&[4, 6]);
        Pcg64::seed(8).normal_fill(&mut obs.data);
        let aa = plain.act_batch(&obs, false).unwrap();
        let ab = packed.act_batch(&obs, false).unwrap();
        assert!(aa.data.iter().zip(&ab.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        // and the published snapshot really dropped its masters
        let snap_plain = plain.policy();
        let snap_packed = packed.policy();
        assert!(snap_packed.weight_bytes() < snap_plain.weight_bytes() * 3 / 4);
    }

    #[test]
    fn pixel_half_storage_stays_bitwise_under_fp16_store() {
        // Same invariant through the conv/fused-group path: a pixels
        // round with a packed target encoder + critic must reproduce
        // the unpacked trajectory bitwise (fp16 store, f16 pack).
        let mut rng = Pcg64::seed(61);
        let cfg = SacConfig::pixels(8, 2, 24);
        let mut plain = SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
        let mut packed =
            SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
        packed.set_half_storage(HalfFormat::F16);
        let mk = |rng: &mut Pcg64| {
            let b = 2;
            let mut obs = Tensor::zeros(&[b, 3, 21, 21]);
            for v in obs.data.iter_mut() {
                *v = rng.uniform_f32();
            }
            let mut next_obs = obs.clone();
            for v in next_obs.data.iter_mut() {
                *v = (*v + 0.01).min(1.0);
            }
            Batch {
                obs,
                act: Tensor::zeros(&[b, 2]),
                rew: vec![0.5; b],
                next_obs,
                not_done: vec![1.0; b],
            }
        };
        for _ in 0..2 {
            let batches: Vec<Batch> = (0..3).map(|_| mk(&mut rng)).collect();
            plain.update_round(&batches);
            packed.update_round(&batches);
        }
        let (ta, tb) = (plain.target.flat_params(), packed.target.flat_params());
        assert!(ta.iter().zip(&tb).all(|(x, y)| x.to_bits() == y.to_bits()));
        let (ea, eb) = (
            plain.encoder.as_mut().unwrap().flat_params(),
            packed.encoder.as_mut().unwrap().flat_params(),
        );
        assert!(ea.iter().zip(&eb).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn update_round_matches_sequential_updates_states() {
        // a round of per-update calls vs one update_round call over the
        // same batches: bitwise-identical weights and RNG position
        let mut rng = Pcg64::seed(31);
        let cfg = SacConfig::states(6, 2, 24);
        let mut a = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 17);
        let mut b = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 17);
        for _ in 0..4 {
            let batches: Vec<Batch> = (0..3).map(|_| toy_batch(8, 6, 2, &mut rng)).collect();
            for bt in &batches {
                a.update(bt);
            }
            b.update_round(&batches);
        }
        assert_eq!(a.updates, b.updates);
        let (ca, cb) = (a.critic.flat_params(), b.critic.flat_params());
        assert!(ca.iter().zip(&cb).all(|(x, y)| x.to_bits() == y.to_bits()));
        let (ta, tb) = (a.target.flat_params(), b.target.flat_params());
        assert!(ta.iter().zip(&tb).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert_eq!(a.rng.clone().next_u64(), b.rng.clone().next_u64(), "same RNG position");
    }

    #[test]
    fn fused_target_groups_cut_at_sync_boundaries() {
        // pixels agent, target_update_freq = 2: starting from updates = 0
        // the groups must be {0}, {1,2}, {3,4}, ... — update 0 syncs the
        // target right after its own step
        let mut rng = Pcg64::seed(41);
        let cfg = SacConfig::pixels(8, 2, 24);
        assert_eq!(cfg.target_update_freq, 2);
        let agent = SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
        let mut ws = UpdateWorkspace::default();
        let batches: Vec<Batch> = (0..5)
            .map(|_| {
                let mut obs = Tensor::zeros(&[2, 3, 21, 21]);
                for v in obs.data.iter_mut() {
                    *v = rng.uniform_f32();
                }
                Batch {
                    obs: obs.clone(),
                    act: Tensor::zeros(&[2, 2]),
                    rew: vec![0.0; 2],
                    next_obs: obs,
                    not_done: vec![1.0; 2],
                }
            })
            .collect();
        agent.plan_fused_groups(&batches, &mut ws);
        assert_eq!(ws.fused_groups, vec![(0, 1), (1, 3), (3, 5)]);
        assert!(ws.fused_off.iter().all(|&o| o == usize::MAX), "plan runs no forwards");
        // fuse the (1, 3) group: the rows must equal the per-batch
        // target-encoder forwards, and offsets must be consecutive
        agent.fuse_group(&batches[1..3], 1, &mut ws);
        assert_eq!(ws.fused_off[0], usize::MAX, "singleton group stays unfused");
        assert_eq!(ws.fused_off[1], 0);
        assert_eq!(ws.fused_off[2], 2, "consecutive rows inside a group");
        let p = agent.compute;
        let tenc = agent.target_encoder.as_ref().unwrap();
        for j in 1..3 {
            let want = tenc.forward(&batches[j].next_obs, p);
            let off = ws.fused_off[j] * 8;
            let got = &ws.fused_feat.data[off..off + want.data.len()];
            assert!(
                want.data.iter().zip(got).all(|(x, y)| x.to_bits() == y.to_bits()),
                "fused rows for update {j} must match the unfused forward"
            );
        }
    }

    #[test]
    fn ckpt_roundtrip_continues_bitwise_states() {
        // checkpoint mid-training, restore into a freshly built agent,
        // and both runs must stay bitwise identical forever after
        let mut rng = Pcg64::seed(71);
        let cfg = SacConfig::states(6, 2, 24);
        let mut a = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 17);
        a.grad_probe = Some(Vec::new());
        for _ in 0..6 {
            let b = toy_batch(8, 6, 2, &mut rng);
            a.update(&b);
        }
        let mut enc = crate::ckpt::Enc::new();
        a.ckpt_write(&mut enc);
        let bytes = enc.into_bytes();

        let mut b = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 99);
        let mut dec = crate::ckpt::Dec::new(&bytes);
        b.ckpt_read(&mut dec).unwrap();
        dec.finish().unwrap();
        assert_eq!(b.updates, a.updates);
        for _ in 0..6 {
            let bt = toy_batch(8, 6, 2, &mut rng);
            a.update(&bt);
            b.update(&bt);
        }
        let (ca, cb) = (a.critic.flat_params(), b.critic.flat_params());
        assert!(ca.iter().zip(&cb).all(|(x, y)| x.to_bits() == y.to_bits()), "critic diverged");
        let (ta, tb) = (a.target.flat_params(), b.target.flat_params());
        assert!(ta.iter().zip(&tb).all(|(x, y)| x.to_bits() == y.to_bits()), "target diverged");
        assert_eq!(a.alpha().to_bits(), b.alpha().to_bits());
        assert_eq!(a.rng.clone().next_u64(), b.rng.clone().next_u64(), "RNG diverged");
        assert_eq!(a.grad_probe, b.grad_probe, "grad probe diverged");
        let mut obs = Tensor::zeros(&[3, 6]);
        Pcg64::seed(5).normal_fill(&mut obs.data);
        let (x, y) = (a.act_batch(&obs, false).unwrap(), b.act_batch(&obs, false).unwrap());
        assert!(x.data.iter().zip(&y.data).all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn ckpt_roundtrip_repacks_half_storage_mirrors() {
        // a half-storage agent restored from a checkpoint must continue
        // the packed-tier trajectory bitwise (mirrors repacked on load)
        let mut rng = Pcg64::seed(73);
        let cfg = SacConfig::states(6, 2, 24);
        let mut a = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 19);
        a.set_half_storage(HalfFormat::F16);
        for _ in 0..5 {
            let b = toy_batch(8, 6, 2, &mut rng);
            a.update(&b);
        }
        let mut enc = crate::ckpt::Enc::new();
        a.ckpt_write(&mut enc);
        let bytes = enc.into_bytes();
        let mut b = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 19);
        b.set_half_storage(HalfFormat::F16);
        b.ckpt_read(&mut crate::ckpt::Dec::new(&bytes)).unwrap();
        for _ in 0..5 {
            let bt = toy_batch(8, 6, 2, &mut rng);
            a.update(&bt);
            b.update(&bt);
        }
        let (ta, tb) = (a.target.flat_params(), b.target.flat_params());
        assert!(ta.iter().zip(&tb).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn ckpt_roundtrip_pixels_restores_encoder_state() {
        let mut rng = Pcg64::seed(75);
        let cfg = SacConfig::pixels(8, 2, 24);
        let mk = |rng: &mut Pcg64| {
            let b = 2;
            let mut obs = Tensor::zeros(&[b, 3, 21, 21]);
            for v in obs.data.iter_mut() {
                *v = rng.uniform_f32();
            }
            Batch {
                obs: obs.clone(),
                act: Tensor::zeros(&[b, 2]),
                rew: vec![0.2; b],
                next_obs: obs,
                not_done: vec![1.0; b],
            }
        };
        let mut a = SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
        for _ in 0..3 {
            a.update(&mk(&mut rng));
        }
        let mut enc = crate::ckpt::Enc::new();
        a.ckpt_write(&mut enc);
        let bytes = enc.into_bytes();
        let mut b = SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
        b.ckpt_read(&mut crate::ckpt::Dec::new(&bytes)).unwrap();
        for _ in 0..3 {
            let bt = mk(&mut rng);
            a.update(&bt);
            b.update(&bt);
        }
        let (ea, eb) = (
            a.encoder.as_mut().unwrap().flat_params(),
            b.encoder.as_mut().unwrap().flat_params(),
        );
        assert!(ea.iter().zip(&eb).all(|(x, y)| x.to_bits() == y.to_bits()), "encoder diverged");
        let (ta, tb) = (a.target.flat_params(), b.target.flat_params());
        assert!(ta.iter().zip(&tb).all(|(x, y)| x.to_bits() == y.to_bits()));

        // a state-agent checkpoint must be rejected by a pixel agent
        let mut state_agent = SacAgent::new(SacConfig::states(6, 2, 24), Methods::ours(), Precision::fp16(), 1);
        let mut senc = crate::ckpt::Enc::new();
        state_agent.ckpt_write(&mut senc);
        let sbytes = senc.into_bytes();
        let mut pix = SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
        assert!(pix.ckpt_read(&mut crate::ckpt::Dec::new(&sbytes)).is_err());
    }

    #[test]
    fn policy_from_flats_matches_live_policy() {
        use crate::sac::ActMode;
        let mut rng = Pcg64::seed(81);
        let cfg = SacConfig::states(5, 2, 24);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 23);
        agent.set_half_storage(HalfFormat::F16);
        for _ in 0..4 {
            let b = toy_batch(8, 5, 2, &mut rng);
            agent.update(&b);
        }
        let (af, ef) = agent.actor_flats();
        let rebuilt = agent.policy_from_flats(&af, ef.as_deref());
        let live = agent.policy();
        let mut obs = Tensor::zeros(&[4, 5]);
        Pcg64::seed(7).normal_fill(&mut obs.data);
        let x = live.act_batch(&obs, ActMode::Deterministic);
        let y = rebuilt.act_batch(&obs, ActMode::Deterministic);
        assert!(x.data.iter().zip(&y.data).all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn n_params_is_read_only() {
        fn count(a: &SacAgent) -> usize {
            a.n_params() // &self receiver: callable on a shared reference
        }
        let cfg = SacConfig::states(4, 2, 16);
        let agent = SacAgent::new(cfg, Methods::none(), Precision::Fp32, 5);
        assert_eq!(count(&agent), agent.actor.n_params() + agent.critic.n_params());
    }

    #[test]
    fn mixed_precision_keeps_fp32_master_weights() {
        let cfg = SacConfig::states(4, 2, 16);
        let agent = SacAgent::new(
            cfg,
            Methods::mixed_precision_baseline(),
            Precision::fp16(),
            2,
        );
        assert_eq!(agent.store, Precision::Fp32);
        assert_eq!(agent.compute, Precision::fp16());
    }
}
