//! The SAC agent: actor, twin critic, target critic, automatic entropy
//! temperature, optional pixel encoder — with every one of the paper's
//! six numerical methods switchable (see [`super::Methods`]).
//!
//! Update structure follows Yarats & Kostrikov (2020):
//! 1. critic step — `L = MSE(Q₁, y) + MSE(Q₂, y)`,
//!    `y = r + γ·(min Q̂(s', a') − α log π(a'|s'))`, `a' ~ π(s')`;
//! 2. actor step (every `actor_update_freq`) —
//!    `L = E[α log π(a|s) − min Q(s, a)]`, reparameterized;
//! 3. temperature step — `L = −α·E[log π + H̄]`, on `log α`;
//! 4. target soft update (every `target_update_freq`) —
//!    `ψ̂ ← ψ̂ + τ(ψ − ψ̂)` (Kahan-momentum when enabled).
//!
//! Train/inference split: gradient-producing forwards go through
//! `forward_train` + the agent-owned workspaces; everything that needs
//! no backward (target values, the detached actor features, action
//! selection) uses the cache-free `&self` forwards. A frozen, shareable
//! snapshot of the action path is available via [`SacAgent::policy`].

use super::critic::{Critic, CriticWorkspace};
use super::encoder::{Encoder, EncoderWorkspace};
use super::methods::Methods;
use super::policy::{PolicyCfg, TanhGaussian};
use super::snapshot::Policy;
use crate::lowp::Precision;
use crate::nn::{Mlp, MlpWorkspace, Param, Tensor};
use crate::optim::{coerce_nonfinite, Adam, AdamConfig, GradScaler, ScaledKahanEma, ScalerConfig, SecondMoment, UpdateMode};
use crate::rngs::Pcg64;

/// A replay minibatch. `obs`/`next_obs` are `[B, D]` states or
/// `[B, C, H, W]` images (when the agent has an encoder). `Default`
/// gives an empty staging batch for the allocation-free
/// `ReplayBuffer::sample_into` path (filled/resized on first use).
#[derive(Debug, Clone, Default)]
pub struct Batch {
    pub obs: Tensor,
    pub act: Tensor,
    pub rew: Vec<f32>,
    pub next_obs: Tensor,
    pub not_done: Vec<f32>,
}

/// Agent hyperparameters (paper Tables 4, 5, 9).
#[derive(Debug, Clone, Copy)]
pub struct SacConfig {
    pub obs_dim: usize,
    pub act_dim: usize,
    pub hidden: usize,
    pub gamma: f32,
    pub tau: f32,
    pub init_temperature: f32,
    pub lr: f32,
    pub adam_eps: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub target_update_freq: u64,
    pub actor_update_freq: u64,
    pub log_sig_lo: f32,
    pub log_sig_hi: f32,
    /// σ += this after exp (pixels runs use 1e-4; states 0).
    pub sigma_eps: f32,
    /// Kahan-momentum buffer scale C (1e4 states, 100 pixels).
    pub kahan_momentum_scale: f32,
    /// Target entropy H̄; the SAC convention is −|A|.
    pub target_entropy: f32,
}

impl SacConfig {
    /// Paper Table 4 defaults (states).
    pub fn states(obs_dim: usize, act_dim: usize, hidden: usize) -> Self {
        SacConfig {
            obs_dim,
            act_dim,
            hidden,
            gamma: 0.99,
            tau: 0.005,
            init_temperature: 0.1,
            lr: 1e-4,
            adam_eps: 1e-8,
            beta1: 0.9,
            beta2: 0.999,
            target_update_freq: 2,
            actor_update_freq: 1,
            log_sig_lo: -5.0,
            log_sig_hi: 2.0,
            sigma_eps: 0.0,
            kahan_momentum_scale: 1e4,
            target_entropy: -(act_dim as f32),
        }
    }

    /// Paper Table 9 deltas for pixels (`obs_dim` = encoder feature dim).
    pub fn pixels(feature_dim: usize, act_dim: usize, hidden: usize) -> Self {
        SacConfig {
            tau: 0.01,
            lr: 1e-3,
            actor_update_freq: 2,
            log_sig_lo: -10.0,
            sigma_eps: 1e-4,
            kahan_momentum_scale: 100.0,
            ..SacConfig::states(feature_dim, act_dim, hidden)
        }
    }
}

/// Per-update diagnostics.
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateStats {
    pub critic_loss: f32,
    pub actor_loss: f32,
    pub alpha_loss: f32,
    pub alpha: f32,
    pub q_mean: f32,
    pub logp_mean: f32,
    pub scale: f32,
    pub skipped_steps: u64,
}

/// The agent.
pub struct SacAgent {
    pub cfg: SacConfig,
    pub methods: Methods,
    /// Forward/backward (activation & gradient) precision.
    pub compute: Precision,
    /// Parameter & optimizer-state precision (fp32 under mixed precision).
    pub store: Precision,
    pub actor: Mlp,
    pub critic: Critic,
    pub target: Critic,
    target_ema: ScaledKahanEma,
    pub encoder: Option<Encoder>,
    pub target_encoder: Option<Encoder>,
    encoder_ema: Option<ScaledKahanEma>,
    pub log_alpha: Param,
    opt_actor: Adam,
    opt_critic: Adam,
    opt_alpha: Adam,
    sc_actor: GradScaler,
    sc_critic: GradScaler,
    sc_alpha: GradScaler,
    // training-time activation workspaces (see nn::*Workspace)
    ws_actor: MlpWorkspace,
    ws_critic: CriticWorkspace,
    ws_encoder: EncoderWorkspace,
    /// Reusable `[1, …]` staging buffer for single-observation `act`.
    act_buf: Tensor,
    pub updates: u64,
    pub rng: Pcg64,
    /// Set once a non-finite action was produced (the paper scores such
    /// runs as 0).
    pub crashed: bool,
    /// Gradient magnitude telemetry for Figure 6 (filled by experiments).
    pub grad_probe: Option<Vec<f32>>,
    /// `(channels, side)` of pixel observations, if this is a pixel agent.
    pixel_shape: Option<(usize, usize)>,
}

impl SacAgent {
    /// Build a state-based agent.
    pub fn new(cfg: SacConfig, methods: Methods, precision: Precision, seed: u64) -> Self {
        Self::build(cfg, methods, precision, seed, None)
    }

    /// Build a pixel-based agent; `enc_proto` describes the encoder
    /// (frames, image side, filters). `cfg.obs_dim` must equal the
    /// encoder feature dim.
    pub fn new_pixels(
        cfg: SacConfig,
        methods: Methods,
        precision: Precision,
        seed: u64,
        frames: usize,
        img: usize,
        filters: usize,
    ) -> Self {
        let mut rng = Pcg64::seed(seed ^ 0xE11C0DE);
        // The paper applies weight-std + downscale in its fp16 pixel agent.
        let low = precision.is_low();
        let enc = Encoder::new(
            "enc",
            frames,
            img,
            filters,
            cfg.obs_dim,
            low,
            if low { Some(10.0) } else { None },
            &mut rng,
        );
        let mut agent = Self::build(cfg, methods, precision, seed, Some(enc));
        agent.pixel_shape = Some((frames, img));
        agent
    }

    fn build(
        cfg: SacConfig,
        methods: Methods,
        precision: Precision,
        seed: u64,
        encoder: Option<Encoder>,
    ) -> Self {
        let mut rng = Pcg64::seed(seed);
        let compute = precision;
        let store = if methods.mixed_precision { Precision::Fp32 } else { precision };

        let mut actor = Mlp::new(
            "actor",
            &[cfg.obs_dim, cfg.hidden, cfg.hidden, 2 * cfg.act_dim],
            &mut rng,
        );
        let mut critic = Critic::new("critic", cfg.obs_dim, cfg.act_dim, cfg.hidden, &mut rng);
        if store.is_low() {
            actor.quantize_params(store);
            critic.quantize_params(store);
        }
        let mut target = Critic::new("target", cfg.obs_dim, cfg.act_dim, cfg.hidden, &mut rng);
        let flat = critic.flat_params();
        target.load_flat(&flat);
        let target_ema = ScaledKahanEma::new(
            &flat,
            cfg.kahan_momentum_scale,
            store,
            methods.kahan_momentum,
        );

        let mut encoder = encoder;
        let (target_encoder, encoder_ema) = if let Some(enc) = encoder.as_mut() {
            if store.is_low() {
                enc.quantize_params(store);
            }
            let flat = enc.flat_params();
            let mut tgt = enc.clone();
            tgt.load_flat(&flat);
            let ema = ScaledKahanEma::new(
                &flat,
                cfg.kahan_momentum_scale,
                store,
                methods.kahan_momentum,
            );
            (Some(tgt), Some(ema))
        } else {
            (None, None)
        };

        let mut log_alpha = Param::from_values("log_alpha", &[1], vec![cfg.init_temperature.ln()]);
        log_alpha.quantize(store);

        let adam_cfg = AdamConfig { lr: cfg.lr, beta1: cfg.beta1, beta2: cfg.beta2, eps: cfg.adam_eps };
        let second = if methods.hadam { SecondMoment::Hypot } else { SecondMoment::Variance };
        let kahan_cr = if methods.kahan_gradients { UpdateMode::Kahan } else { UpdateMode::Plain };
        // paper: Kahan-gradients on critic + α, not on the actor
        let opt_actor = Adam::new(adam_cfg, store, second, UpdateMode::Plain, methods.compound_scaling);
        let opt_critic = Adam::new(adam_cfg, store, second, kahan_cr, methods.compound_scaling);
        let opt_alpha = Adam::new(
            AdamConfig { lr: cfg.lr, ..adam_cfg },
            store,
            second,
            kahan_cr,
            methods.compound_scaling,
        );

        let mk_scaler = || {
            if methods.loss_scaling {
                GradScaler::new(ScalerConfig::paper())
            } else {
                GradScaler::disabled()
            }
        };

        SacAgent {
            cfg,
            methods,
            compute,
            store,
            actor,
            critic,
            target,
            target_ema,
            encoder,
            target_encoder,
            encoder_ema,
            log_alpha,
            opt_actor,
            opt_critic,
            opt_alpha,
            sc_actor: mk_scaler(),
            sc_critic: mk_scaler(),
            sc_alpha: mk_scaler(),
            ws_actor: MlpWorkspace::default(),
            ws_critic: CriticWorkspace::default(),
            ws_encoder: EncoderWorkspace::default(),
            act_buf: Tensor::default(),
            updates: 0,
            rng,
            crashed: false,
            grad_probe: None,
            pixel_shape: None,
        }
    }

    fn policy_cfg(&self) -> PolicyCfg {
        PolicyCfg {
            log_sig_lo: self.cfg.log_sig_lo,
            log_sig_hi: self.cfg.log_sig_hi,
            softplus_fix: self.methods.softplus_fix,
            normal_fix: self.methods.normal_fix,
            sigma_eps: self.cfg.sigma_eps,
            k_threshold: 10.0,
        }
    }

    /// Snapshot the action path (actor + pixel encoder) into an
    /// immutable, `Send + Sync` [`Policy`]: weights only — no optimizer
    /// state, activation caches or RNG. Later agent updates do not
    /// affect an existing snapshot.
    pub fn policy(&self) -> Policy {
        let obs_len = match self.pixel_shape {
            Some((c, h)) => c * h * h,
            None => self.cfg.obs_dim,
        };
        // The snapshot never trains again, so weight standardization can
        // be baked into the frozen weights (bitwise-identical forward,
        // no per-request re-standardization on the serve hot path).
        let encoder = self.encoder.clone().map(|mut enc| {
            enc.bake_weight_std(self.compute);
            enc
        });
        Policy::new(
            self.actor.clone(),
            encoder,
            self.policy_cfg(),
            self.compute,
            obs_len,
            self.cfg.act_dim,
            self.pixel_shape,
        )
    }

    /// Current temperature α = exp(log α).
    pub fn alpha(&self) -> f32 {
        self.compute.q(self.log_alpha.w[0].exp())
    }

    /// Encode a pixel batch with the online encoder (identity for state
    /// agents). Inference-only: no gradient caches.
    fn encode(&self, obs: &Tensor, prec: Precision) -> Tensor {
        match self.encoder.as_ref() {
            Some(enc) => enc.forward(obs, prec),
            None => obs.clone(),
        }
    }

    fn encode_target(&self, obs: &Tensor, prec: Precision) -> Tensor {
        match self.target_encoder.as_ref() {
            Some(enc) => enc.forward(obs, prec),
            None => obs.clone(),
        }
    }

    /// Select an action for a single observation. `stochastic` samples
    /// from π; otherwise uses tanh(μ). Returns `None` (and flags
    /// `crashed`) if the action is non-finite, mirroring the paper's
    /// crash accounting.
    ///
    /// This is [`SacAgent::act_batch`] with batch 1, staged through a
    /// reusable buffer — no per-call observation allocation.
    pub fn act(&mut self, obs: &[f32], stochastic: bool) -> Option<Vec<f32>> {
        let shape: Vec<usize> = match self.pixel_shape {
            // caller passes a flattened [C, H, W] image
            Some((c, h)) => vec![1, c, h, h],
            None => vec![1, obs.len()],
        };
        if self.act_buf.shape != shape {
            self.act_buf = Tensor::zeros(&shape);
        }
        self.act_buf.data.copy_from_slice(obs);
        // temporarily take the buffer so act_batch can borrow &mut self
        let buf = std::mem::take(&mut self.act_buf);
        let out = self.act_batch(&buf, stochastic);
        self.act_buf = buf;
        out.map(|a| a.data)
    }

    /// Batched action selection: `[B, D]` states (or `[B, C, H, W]`
    /// images) → `[B, act_dim]`, one shared GEMM per layer for all B
    /// observations. In deterministic mode (`stochastic = false`) row
    /// `r` is bitwise identical to [`SacAgent::act`] on observation `r`
    /// alone (the GEMM backend accumulates output rows independently of
    /// the batch size); in stochastic mode the rows draw consecutive
    /// slices of the agent's RNG stream, so only batch 1 reproduces a
    /// single `act` call exactly. Returns `None` (and flags `crashed`)
    /// if any action is non-finite.
    pub fn act_batch(&mut self, obs: &Tensor, stochastic: bool) -> Option<Tensor> {
        let p = self.compute;
        let feat = self.encode(obs, p);
        let head = self.actor.forward(&feat, p);
        let a = if stochastic {
            let b = head.rows();
            let mut eps = Tensor::zeros(&[b, self.cfg.act_dim]);
            self.rng.normal_fill(&mut eps.data);
            TanhGaussian::forward(&head, &eps, self.policy_cfg(), p).a
        } else {
            TanhGaussian::mean_action(&head, p)
        };
        self.guard_actions(a)
    }

    /// Stochastic batched action selection over vectorized env streams:
    /// one shared forward for all rows, with row `i`'s exploration noise
    /// drawn from `rngs[i]` instead of the agent's own stream (the same
    /// noise layout as `ActMode::SamplePerEnv`). Each env stream
    /// therefore owns an independent noise sequence, which makes an
    /// N-env rollout bitwise reproducible and row results invariant to
    /// how streams are batched (the GEMM backend accumulates rows
    /// independently). Crash semantics match [`SacAgent::act_batch`].
    pub fn act_batch_envs(&mut self, obs: &Tensor, rngs: &mut [Pcg64]) -> Option<Tensor> {
        let p = self.compute;
        // obs is [B, D] or [B, C, H, W]: the batch is the leading dim.
        // Drawing (and shape-checking) the noise first keeps a
        // mismatched rngs slice from wasting the forward.
        let eps = super::snapshot::per_env_eps(obs.shape[0], self.cfg.act_dim, rngs);
        let feat = self.encode(obs, p);
        let head = self.actor.forward(&feat, p);
        let a = TanhGaussian::forward(&head, &eps, self.policy_cfg(), p).a;
        self.guard_actions(a)
    }

    /// Shared crash guard: a non-finite action flags the agent as
    /// crashed (the paper's accounting) and yields `None`.
    fn guard_actions(&mut self, a: Tensor) -> Option<Tensor> {
        if a.has_nonfinite() {
            self.crashed = true;
            return None;
        }
        Some(a)
    }

    /// One gradient update from a replay batch.
    pub fn update(&mut self, batch: &Batch) -> UpdateStats {
        let mut stats = UpdateStats { alpha: self.alpha(), ..Default::default() };
        self.update_critic(batch, &mut stats);
        if self.updates % self.cfg.actor_update_freq == 0 {
            self.update_actor_alpha(batch, &mut stats);
        }
        if self.updates % self.cfg.target_update_freq == 0 {
            self.update_target();
        }
        self.updates += 1;
        stats.scale = self.sc_critic.scale();
        stats.skipped_steps =
            self.sc_critic.skipped + self.sc_actor.skipped + self.sc_alpha.skipped;
        stats
    }

    fn update_critic(&mut self, batch: &Batch, stats: &mut UpdateStats) {
        let p = self.compute;
        let b = batch.rew.len();
        let alpha = self.alpha();

        // -- target value (no gradients kept anywhere: inference path) --
        // DRQ convention: the *actor* uses the online encoder (detached)
        let feat_next_actor = self.encode(&batch.next_obs, p);
        let head = self.actor.forward(&feat_next_actor, p);
        let mut eps = Tensor::zeros(&[b, self.cfg.act_dim]);
        self.rng.normal_fill(&mut eps.data);
        let tg = TanhGaussian::forward(&head, &eps, self.policy_cfg(), p);
        let feat_next_tgt = self.encode_target(&batch.next_obs, p);
        let (tq1, tq2) = self.target.forward(&feat_next_tgt, &tg.a, p);
        let mut y = vec![0.0f32; b];
        for r in 0..b {
            let tq = tq1.data[r].min(tq2.data[r]);
            let v = p.q(tq - p.q(alpha * tg.logp[r]));
            y[r] = p.q(batch.rew[r] + p.q(self.cfg.gamma * batch.not_done[r]) * v);
        }

        // -- online critic (training path: fills the workspaces) --------
        let feat = match self.encoder.as_ref() {
            Some(enc) => enc.forward_train(&batch.obs, p, &mut self.ws_encoder),
            None => batch.obs.clone(),
        };
        let (q1, q2) = self.critic.forward_train(&feat, &batch.act, p, &mut self.ws_critic);
        let scale = self.sc_critic.scale();
        let mut loss = 0.0f64;
        let mut dq1 = Tensor::zeros(&[b, 1]);
        let mut dq2 = Tensor::zeros(&[b, 1]);
        for r in 0..b {
            let e1 = q1.data[r] - y[r];
            let e2 = q2.data[r] - y[r];
            loss += (e1 as f64).powi(2) + (e2 as f64).powi(2);
            dq1.data[r] = p.q(2.0 * e1 / b as f32 * scale);
            dq2.data[r] = p.q(2.0 * e2 / b as f32 * scale);
        }
        stats.critic_loss = (loss / b as f64) as f32;
        stats.q_mean = q1.mean();

        self.critic.zero_grad();
        if let Some(enc) = self.encoder.as_mut() {
            enc.zero_grad();
        }
        if self.encoder.is_some() {
            let (dobs, _da) = self.critic.backward_full(&dq1, &dq2, p, &self.ws_critic);
            self.encoder.as_mut().unwrap().backward(&dobs, p, &self.ws_encoder);
        } else {
            let _ = self.critic.backward(&dq1, &dq2, p, &self.ws_critic);
        }

        if self.methods.coerce {
            let mx = p.max_value();
            for prm in self.critic.params_mut() {
                coerce_nonfinite(&mut prm.g, mx);
            }
        }
        // probe gradients for Figure 6 telemetry
        if let Some(probe) = self.grad_probe.as_mut() {
            for prm in self.critic.params_mut() {
                probe.extend(prm.g.iter().map(|g| g.abs()));
            }
        }
        // optimizer step (critic + encoder parameters together)
        let mut params = self.critic.params_mut();
        if let Some(enc) = self.encoder.as_mut() {
            params.extend(enc.params_mut());
        }
        self.opt_critic.step(&mut params, &mut self.sc_critic);
    }

    fn update_actor_alpha(&mut self, batch: &Batch, stats: &mut UpdateStats) {
        let p = self.compute;
        let b = batch.rew.len();
        let alpha = self.alpha();

        // actor loss: E[α logπ - min Q], encoder features detached
        // (inference encode — no gradient flows into the encoder here)
        let feat = self.encode(&batch.obs, p);
        let head = self.actor.forward_train(&feat, p, &mut self.ws_actor);
        let mut eps = Tensor::zeros(&[b, self.cfg.act_dim]);
        self.rng.normal_fill(&mut eps.data);
        let tg = TanhGaussian::forward(&head, &eps, self.policy_cfg(), p);
        let (q1, q2) = self.critic.forward_train(&feat, &tg.a, p, &mut self.ws_critic);

        let scale = self.sc_actor.scale();
        let mut loss = 0.0f64;
        let mut dq1 = Tensor::zeros(&[b, 1]);
        let mut dq2 = Tensor::zeros(&[b, 1]);
        let coef = p.q(scale / b as f32);
        for r in 0..b {
            let qmin = q1.data[r].min(q2.data[r]);
            loss += (alpha * tg.logp[r] - qmin) as f64;
            // d(-qmin)/dq: route to the smaller head
            if q1.data[r] <= q2.data[r] {
                dq1.data[r] = -coef;
            } else {
                dq2.data[r] = -coef;
            }
        }
        stats.actor_loss = (loss / b as f64) as f32;
        stats.logp_mean =
            tg.logp.iter().map(|&v| v as f64).sum::<f64>() as f32 / b as f32;

        // dQ/da through the critic (param grads discarded afterwards)
        self.critic.zero_grad();
        let da = self.critic.backward(&dq1, &dq2, p, &self.ws_critic);
        let coefs = vec![p.q(alpha * coef); b];
        let dhead = tg.backward(&coefs, Some(&da));
        self.actor.zero_grad();
        let _ = self.actor.backward(&dhead, p, &self.ws_actor);
        self.critic.zero_grad(); // discard critic grads from this pass

        if self.methods.coerce {
            let mx = p.max_value();
            for prm in self.actor.params_mut() {
                coerce_nonfinite(&mut prm.g, mx);
            }
        }
        if let Some(probe) = self.grad_probe.as_mut() {
            for prm in self.actor.params_mut() {
                probe.extend(prm.g.iter().map(|g| g.abs()));
            }
        }
        let mut params = self.actor.params_mut();
        self.opt_actor.step(&mut params, &mut self.sc_actor);

        // -- temperature ------------------------------------------------
        // L(α) = −α · mean(logπ + H̄)  (logπ detached)
        let mean_term = tg
            .logp
            .iter()
            .map(|&lp| (lp + self.cfg.target_entropy) as f64)
            .sum::<f64>() as f32
            / b as f32;
        stats.alpha_loss = -alpha * mean_term;
        let ascale = self.sc_alpha.scale();
        // d/d logα of −exp(logα)·mean_term
        self.log_alpha.g[0] = p.q(-alpha * mean_term * ascale);
        if self.methods.coerce {
            coerce_nonfinite(&mut self.log_alpha.g, p.max_value());
        }
        let mut aparams = vec![&mut self.log_alpha];
        self.opt_alpha.step(&mut aparams, &mut self.sc_alpha);
    }

    fn update_target(&mut self) {
        let flat = self.critic.flat_params();
        self.target_ema.update(&flat, self.cfg.tau);
        self.target.load_flat(self.target_ema.weights());
        if let (Some(enc), Some(ema), Some(tgt)) = (
            self.encoder.as_mut(),
            self.encoder_ema.as_mut(),
            self.target_encoder.as_mut(),
        ) {
            let flat = enc.flat_params();
            ema.update(&flat, self.cfg.tau);
            tgt.load_flat(ema.weights());
        }
    }

    /// Total learnable parameters (actor + critic [+ encoder]).
    pub fn n_params(&mut self) -> usize {
        let mut n = self.actor.n_params() + self.critic.n_params();
        if let Some(enc) = self.encoder.as_mut() {
            n += enc.n_params();
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch(b: usize, obs_dim: usize, act_dim: usize, rng: &mut Pcg64) -> Batch {
        let mut obs = Tensor::zeros(&[b, obs_dim]);
        rng.normal_fill(&mut obs.data);
        let mut next_obs = Tensor::zeros(&[b, obs_dim]);
        rng.normal_fill(&mut next_obs.data);
        let mut act = Tensor::zeros(&[b, act_dim]);
        for v in act.data.iter_mut() {
            *v = rng.uniform_in(-1.0, 1.0);
        }
        Batch {
            obs,
            act,
            rew: (0..b).map(|_| rng.uniform_f32()).collect(),
            next_obs,
            not_done: vec![1.0; b],
        }
    }

    #[test]
    fn fp32_update_runs_and_changes_params() {
        let mut rng = Pcg64::seed(1);
        let cfg = SacConfig::states(6, 2, 32);
        let mut agent = SacAgent::new(cfg, Methods::none(), Precision::Fp32, 7);
        let before = agent.critic.flat_params();
        for _ in 0..5 {
            let b = toy_batch(16, 6, 2, &mut rng);
            let s = agent.update(&b);
            assert!(s.critic_loss.is_finite());
        }
        let after = agent.critic.flat_params();
        assert_ne!(before, after);
    }

    #[test]
    fn act_returns_bounded_actions() {
        let cfg = SacConfig::states(4, 3, 16);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 3);
        let a = agent.act(&[0.1, -0.2, 0.3, 0.4], true).unwrap();
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|v| (-1.0..=1.0).contains(v)));
        let d = agent.act(&[0.1, -0.2, 0.3, 0.4], false).unwrap();
        assert!(d.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn act_batch_rows_equal_single_act() {
        let mut rng = Pcg64::seed(9);
        let cfg = SacConfig::states(5, 2, 24);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 4);
        let b = 7;
        let mut obs = Tensor::zeros(&[b, 5]);
        rng.normal_fill(&mut obs.data);
        let batched = agent.act_batch(&obs, false).unwrap();
        for r in 0..b {
            let single = agent.act(obs.row(r), false).unwrap();
            for (x, y) in single.iter().zip(batched.row(r)) {
                assert_eq!(x.to_bits(), y.to_bits(), "row {r}");
            }
        }
    }

    #[test]
    fn act_batch_envs_matches_policy_per_env_sampling() {
        // The live agent's per-env sampling path and a Policy snapshot's
        // SamplePerEnv mode run the same weights and the same per-row
        // noise streams — their actions must agree bitwise, and the
        // agent's own RNG must stay untouched.
        use crate::sac::ActMode;
        let cfg = SacConfig::states(5, 2, 24);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 8);
        let policy = agent.policy();
        let before = agent.rng.clone().next_u64();
        let n = 4;
        let mut obs = Tensor::zeros(&[n, 5]);
        Pcg64::seed(6).normal_fill(&mut obs.data);
        let mut r1: Vec<Pcg64> = (0..n).map(|i| Pcg64::seed_stream(3, i as u64)).collect();
        let mut r2 = r1.clone();
        let live = agent.act_batch_envs(&obs, &mut r1).unwrap();
        let snap = policy.act_batch(&obs, ActMode::SamplePerEnv(&mut r2));
        assert!(live.data.iter().zip(&snap.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert_eq!(agent.rng.clone().next_u64(), before, "agent RNG untouched");
        // deterministic in the streams: fresh clones reproduce exactly
        let mut r3: Vec<Pcg64> = (0..n).map(|i| Pcg64::seed_stream(3, i as u64)).collect();
        let again = agent.act_batch_envs(&obs, &mut r3).unwrap();
        assert_eq!(live.data, again.data);
    }

    #[test]
    fn fp16_ours_stays_finite_over_many_updates() {
        let mut rng = Pcg64::seed(2);
        let cfg = SacConfig::states(6, 2, 32);
        let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 11);
        for i in 0..30 {
            let b = toy_batch(8, 6, 2, &mut rng);
            let s = agent.update(&b);
            assert!(
                s.critic_loss.is_finite(),
                "update {i}: critic loss {}",
                s.critic_loss
            );
        }
        assert!(!agent.crashed);
        for prm in agent.critic.params_mut() {
            assert!(prm.w.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn target_tracks_critic() {
        let mut rng = Pcg64::seed(3);
        let cfg = SacConfig { tau: 0.5, ..SacConfig::states(4, 2, 16) };
        let mut agent = SacAgent::new(cfg, Methods::none(), Precision::Fp32, 5);
        let c0 = agent.critic.flat_params();
        let t0 = agent.target.flat_params();
        assert_eq!(c0, t0, "target initialized to critic");
        for _ in 0..10 {
            let b = toy_batch(8, 4, 2, &mut rng);
            agent.update(&b);
        }
        let c = agent.critic.flat_params();
        let t = agent.target.flat_params();
        assert_ne!(t, t0, "target must move");
        // target lags the critic: distance(t, c) > 0 but should be modest
        let d: f32 = c.iter().zip(&t).map(|(a, b)| (a - b).abs()).sum();
        assert!(d > 0.0);
    }

    #[test]
    fn alpha_adapts_toward_target_entropy() {
        let mut rng = Pcg64::seed(4);
        let cfg = SacConfig { lr: 1e-2, ..SacConfig::states(4, 2, 16) };
        let mut agent = SacAgent::new(cfg, Methods::none(), Precision::Fp32, 6);
        let a0 = agent.alpha();
        for _ in 0..50 {
            let b = toy_batch(16, 4, 2, &mut rng);
            agent.update(&b);
        }
        assert_ne!(agent.alpha(), a0, "temperature must adapt");
        assert!(agent.alpha() > 0.0);
    }

    #[test]
    fn pixel_agent_update_runs() {
        let mut rng = Pcg64::seed(5);
        let cfg = SacConfig::pixels(8, 2, 24); // feature_dim 8
        let mut agent = SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
        let b = 4;
        let mut obs = Tensor::zeros(&[b, 3, 21, 21]);
        for v in obs.data.iter_mut() {
            *v = rng.uniform_f32();
        }
        let mut next_obs = obs.clone();
        for v in next_obs.data.iter_mut() {
            *v = (*v + 0.01).min(1.0);
        }
        let mut act = Tensor::zeros(&[b, 2]);
        for v in act.data.iter_mut() {
            *v = rng.uniform_in(-1.0, 1.0);
        }
        let batch = Batch {
            obs,
            act,
            rew: vec![0.5; b],
            next_obs,
            not_done: vec![1.0; b],
        };
        for _ in 0..3 {
            let s = agent.update(&batch);
            assert!(s.critic_loss.is_finite(), "loss={}", s.critic_loss);
        }
    }

    #[test]
    fn mixed_precision_keeps_fp32_master_weights() {
        let cfg = SacConfig::states(4, 2, 16);
        let agent = SacAgent::new(
            cfg,
            Methods::mixed_precision_baseline(),
            Precision::fp16(),
            2,
        );
        assert_eq!(agent.store, Precision::Fp32);
        assert_eq!(agent.compute, Precision::fp16());
    }
}
