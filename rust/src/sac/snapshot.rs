//! Immutable policy snapshots — the inference half of the
//! train/inference API split.
//!
//! A [`Policy`] is cloned out of a live [`super::SacAgent`] (weights
//! only: no optimizer state, no activation caches, no RNG) and is
//! `Send + Sync` because every layer forward is `&self`. One snapshot
//! can therefore be shared by any number of threads, and
//! [`Policy::act_batch`] lets N concurrent observations share a single
//! GEMM per layer — the native backend of the [`crate::serve`]
//! micro-batching server, and the engine behind the trainer's batched
//! deterministic evaluation.

use super::encoder::Encoder;
use super::policy::{PolicyCfg, TanhGaussian};
use crate::lowp::{HalfFormat, Precision};
use crate::nn::{Mlp, Tensor};
use crate::rngs::Pcg64;

/// How [`Policy::act_batch`] turns the actor head into actions.
pub enum ActMode<'a> {
    /// Evaluation-time policy `tanh(μ)`.
    Deterministic,
    /// Exploration policy `a = tanh(μ + ε σ)`, with the Gaussian noise
    /// drawn from the caller's RNG (the snapshot itself stays immutable
    /// and shareable).
    Sample(&'a mut Pcg64),
    /// Exploration policy over vectorized env streams: row `i` draws its
    /// noise from `rngs[i]`, so each stream owns an independent noise
    /// sequence and a row's action depends only on its observation and
    /// its own stream — not on how rows are batched together.
    SamplePerEnv(&'a mut [Pcg64]),
}

/// An immutable snapshot of a SAC actor (and pixel encoder, when
/// present), detached from training.
#[derive(Debug, Clone)]
pub struct Policy {
    actor: Mlp,
    encoder: Option<Encoder>,
    cfg: PolicyCfg,
    compute: Precision,
    /// Flat length of one observation (states: `obs_dim`; pixels:
    /// `C·H·W`).
    obs_len: usize,
    act_dim: usize,
    /// `(channels, side)` when this policy consumes images.
    pixel_shape: Option<(usize, usize)>,
}

impl Policy {
    pub(crate) fn new(
        actor: Mlp,
        encoder: Option<Encoder>,
        cfg: PolicyCfg,
        compute: Precision,
        obs_len: usize,
        act_dim: usize,
        pixel_shape: Option<(usize, usize)>,
    ) -> Self {
        Policy { actor, encoder, cfg, compute, obs_len, act_dim, pixel_shape }
    }

    /// Flat f32 length of one observation.
    pub fn obs_len(&self) -> usize {
        self.obs_len
    }

    pub fn act_dim(&self) -> usize {
        self.act_dim
    }

    /// The compute precision the snapshot runs its forward passes in.
    pub fn precision(&self) -> Precision {
        self.compute
    }

    pub fn is_pixels(&self) -> bool {
        self.pixel_shape.is_some()
    }

    /// Pack every weight matrix into 16-bit storage and drop the f32
    /// masters. A snapshot is frozen — it never trains and never
    /// repacks — so after this call only the u16 tier stays resident
    /// (roughly half the weight bytes) and every forward streams the
    /// packed operand through the SIMD widening kernels.
    ///
    /// Semantics: packing quantize-mirrors the weights, so a packed
    /// snapshot acts exactly like one whose masters were rounded to
    /// `fmt` first. When the training store already keeps weights on
    /// the fp16 grid (the paper's half-precision runs), an f16 pack is
    /// lossless and the packed snapshot is bitwise identical to the
    /// unpacked one.
    pub fn pack_weights(&mut self, fmt: HalfFormat) {
        self.actor.pack_weights(fmt);
        self.actor.drop_masters();
        if let Some(enc) = self.encoder.as_mut() {
            enc.pack_weights(fmt);
            enc.drop_masters();
        }
    }

    /// Resident weight bytes across storage tiers (f32 masters that
    /// were dropped by [`Policy::pack_weights`] no longer count).
    pub fn weight_bytes(&self) -> usize {
        self.actor.weight_bytes() + self.encoder.as_ref().map_or(0, Encoder::weight_bytes)
    }

    /// Shape a flat buffer of `batch` concatenated observations into the
    /// tensor [`Policy::act_batch`] expects (`[B, obs]` for states,
    /// `[B, C, H, W]` for pixels).
    pub fn obs_tensor(&self, flat: &[f32], batch: usize) -> Tensor {
        assert_eq!(
            flat.len(),
            batch * self.obs_len,
            "obs buffer: want {} floats for batch {batch}",
            batch * self.obs_len
        );
        match self.pixel_shape {
            // tidy-allow(alloc): allocating wrapper; hot callers use stage_obs
            Some((c, h)) => Tensor::from_vec(&[batch, c, h, h], flat.to_vec()),
            // tidy-allow(alloc): allocating wrapper; hot callers use stage_obs
            None => Tensor::from_vec(&[batch, self.obs_len], flat.to_vec()),
        }
    }

    /// Allocation-free [`Policy::obs_tensor`]: stage `batch`
    /// concatenated observations into a caller-owned tensor, resizing it
    /// only when the batch size changes (delegates to
    /// [`Tensor::stage_rows`]). Hot paths that act every step on the
    /// same batch shape (the async collector, the lockstep evaluator)
    /// reuse one staging tensor instead of allocating a copy of the
    /// observation buffer per forward.
    pub fn stage_obs<'a>(&self, stage: &'a mut Tensor, flat: &[f32], batch: usize) -> &'a Tensor {
        match self.pixel_shape {
            Some((c, h)) => stage.stage_rows(flat, batch, &[c, h, h]),
            None => stage.stage_rows(flat, batch, &[self.obs_len]),
        }
    }

    /// Batched action selection: `[B, …] → [B, act_dim]`.
    ///
    /// In [`ActMode::Deterministic`], row `r` of the result is bitwise
    /// identical to a batch-1 call on observation `r` alone: the GEMM
    /// backend accumulates every output row independently in the same
    /// ascending-k panel order regardless of the batch size, so
    /// micro-batching is a pure throughput win. In [`ActMode::Sample`]
    /// the rows consume consecutive slices of the caller's RNG stream,
    /// so batching changes which noise lands on which row.
    pub fn act_batch(&self, obs: &Tensor, mode: ActMode) -> Tensor {
        let p = self.compute;
        let head = match self.encoder.as_ref() {
            Some(enc) => {
                let feat = enc.forward(obs, p);
                self.actor.forward(&feat, p)
            }
            None => self.actor.forward(obs, p),
        };
        match mode {
            ActMode::Deterministic => TanhGaussian::mean_action(&head, p),
            ActMode::Sample(rng) => {
                let b = head.rows();
                let mut eps = Tensor::zeros(&[b, self.act_dim]);
                rng.normal_fill(&mut eps.data);
                TanhGaussian::forward(&head, &eps, self.cfg, p).a
            }
            ActMode::SamplePerEnv(rngs) => {
                let eps = per_env_eps(head.rows(), self.act_dim, rngs);
                TanhGaussian::forward(&head, &eps, self.cfg, p).a
            }
        }
    }
}

/// Fill a `[B, A]` exploration-noise tensor with one row per env
/// stream, row `i` drawn from `rngs[i]` — the single definition of the
/// per-env noise layout, shared by [`Policy::act_batch`]'s
/// [`ActMode::SamplePerEnv`] and `SacAgent::act_batch_envs`.
pub(crate) fn per_env_eps(b: usize, act_dim: usize, rngs: &mut [Pcg64]) -> Tensor {
    assert_eq!(rngs.len(), b, "one RNG stream per observation row");
    let mut eps = Tensor::zeros(&[b, act_dim]);
    for (i, rng) in rngs.iter_mut().enumerate() {
        rng.normal_fill(&mut eps.data[i * act_dim..(i + 1) * act_dim]);
    }
    eps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sac::{Methods, SacAgent, SacConfig};

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn policy_is_send_sync() {
        assert_send_sync::<Policy>();
    }

    #[test]
    fn snapshot_matches_live_agent_deterministically() {
        let mut rng = Pcg64::seed(1);
        let mut agent =
            SacAgent::new(SacConfig::states(5, 2, 16), Methods::ours(), Precision::fp16(), 3);
        let policy = agent.policy();
        assert_eq!(policy.obs_len(), 5);
        assert_eq!(policy.act_dim(), 2);
        let mut obs = Tensor::zeros(&[3, 5]);
        rng.normal_fill(&mut obs.data);
        let live = agent.act_batch(&obs, false).unwrap();
        let snap = policy.act_batch(&obs, ActMode::Deterministic);
        assert!(live.data.iter().zip(&snap.data).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn sampled_actions_are_bounded_and_deterministic_in_the_rng() {
        let mut agent =
            SacAgent::new(SacConfig::states(4, 3, 16), Methods::ours(), Precision::fp16(), 5);
        let policy = agent.policy();
        let mut obs = Tensor::zeros(&[8, 4]);
        Pcg64::seed(2).normal_fill(&mut obs.data);
        let mut r1 = Pcg64::seed(7);
        let mut r2 = Pcg64::seed(7);
        let a1 = policy.act_batch(&obs, ActMode::Sample(&mut r1));
        let a2 = policy.act_batch(&obs, ActMode::Sample(&mut r2));
        assert_eq!(a1.data, a2.data, "same RNG stream, same sample");
        assert!(a1.data.iter().all(|v| (-1.0..=1.0).contains(v)));
        // the agent itself was not consulted — its RNG is untouched
        let _ = agent.act(&[0.1, 0.2, 0.3, 0.4], false);
    }

    #[test]
    fn stage_obs_matches_obs_tensor_and_reuses_the_buffer() {
        let agent =
            SacAgent::new(SacConfig::states(4, 2, 16), Methods::ours(), Precision::fp16(), 1);
        let policy = agent.policy();
        let flat: Vec<f32> = (0..12).map(|i| i as f32 * 0.1).collect();
        let want = policy.obs_tensor(&flat, 3);
        let mut stage = Tensor::default();
        let got = policy.stage_obs(&mut stage, &flat, 3);
        assert_eq!(want.shape, got.shape);
        assert_eq!(want.data, got.data);
        let ptr = stage.data.as_ptr();
        let flat2: Vec<f32> = (0..12).map(|i| i as f32 * 0.3).collect();
        policy.stage_obs(&mut stage, &flat2, 3);
        assert_eq!(ptr, stage.data.as_ptr(), "same batch shape must not reallocate");
        assert_eq!(stage.data, flat2);
    }

    #[test]
    fn packed_snapshot_matches_f32_snapshot_bitwise_and_shrinks() {
        // fp16 store keeps every weight on the f16 grid, so an f16 pack
        // is lossless and the packed snapshot (running through the SIMD
        // widening GEMM path) must act bitwise identically to the
        // unpacked one — while dropping the f32 masters roughly halves
        // the resident weight bytes.
        let agent =
            SacAgent::new(SacConfig::states(5, 2, 16), Methods::ours(), Precision::fp16(), 4);
        let plain = agent.policy();
        let mut packed = agent.policy();
        let before = packed.weight_bytes();
        packed.pack_weights(crate::lowp::HalfFormat::F16);
        let after = packed.weight_bytes();
        assert!(
            after < before * 3 / 4,
            "dropping masters must shrink resident bytes: {before} -> {after}"
        );
        let mut obs = Tensor::zeros(&[6, 5]);
        Pcg64::seed(9).normal_fill(&mut obs.data);
        let a = plain.act_batch(&obs, ActMode::Deterministic);
        let b = packed.act_batch(&obs, ActMode::Deterministic);
        assert!(a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        let mut r1 = Pcg64::seed(3);
        let mut r2 = Pcg64::seed(3);
        let s1 = plain.act_batch(&obs, ActMode::Sample(&mut r1));
        let s2 = packed.act_batch(&obs, ActMode::Sample(&mut r2));
        assert!(s1.data.iter().zip(&s2.data).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn per_env_sampling_rows_are_batch_invariant() {
        // Row i of a SamplePerEnv batch must be bitwise identical to a
        // batch-1 call on (obs row i, rng stream i): the GEMM backend is
        // row-invariant and the noise comes from the row's own stream.
        let agent =
            SacAgent::new(SacConfig::states(6, 3, 16), Methods::ours(), Precision::fp16(), 2);
        let policy = agent.policy();
        let n = 5;
        let mut obs = Tensor::zeros(&[n, 6]);
        Pcg64::seed(3).normal_fill(&mut obs.data);
        let mut rngs: Vec<Pcg64> =
            (0..n).map(|i| Pcg64::seed_stream(11, 100 + i as u64)).collect();
        let batched = policy.act_batch(&obs, ActMode::SamplePerEnv(&mut rngs));
        for i in 0..n {
            let one = Tensor::from_vec(&[1, 6], obs.row(i).to_vec());
            let mut solo = vec![Pcg64::seed_stream(11, 100 + i as u64)];
            let a = policy.act_batch(&one, ActMode::SamplePerEnv(&mut solo));
            for (x, y) in a.data.iter().zip(batched.row(i)) {
                assert_eq!(x.to_bits(), y.to_bits(), "row {i}");
            }
        }
    }
}
