//! Pixel encoder for RL from images (paper §4.6 / Appendix G):
//! four 3×3 conv layers (first stride 2, rest stride 1, ReLU between),
//! a linear head to `feature_dim` (50), and layer-normalization.
//!
//! The paper's fp16 fix: the head linear layer gets **weight
//! standardization** and its output is **down-scaled to max-norm 10**
//! before layer-norm (layer-norm is invariant to both, so the semantics
//! are unchanged in exact arithmetic, but the variance no longer
//! overflows).
//!
//! `forward` is `&self` and cache-free, so an encoder inside a frozen
//! [`super::Policy`] snapshot can serve many threads; training caches
//! live in an explicit [`EncoderWorkspace`].

use crate::lowp::Precision;
use crate::nn::{
    relu, relu_backward, Conv2d, Conv2dWorkspace, LayerNorm, LayerNormWorkspace, Linear,
    LinearWorkspace, Param, Tensor,
};
use crate::rngs::Pcg64;

/// Training-time caches for one [`Encoder`]: per-conv im2col panels,
/// pre-ReLU activations, the head/layer-norm workspaces and the
/// per-sample downscale factors.
#[derive(Debug, Clone, Default)]
pub struct EncoderWorkspace {
    convs: Vec<Conv2dWorkspace>,
    pre_relu: Vec<Tensor>,
    head: LinearWorkspace,
    ln: LayerNormWorkspace,
    scale: Vec<f32>,
}

/// Convolutional encoder: `[B, C, H, W] → [B, feature_dim]`.
#[derive(Debug, Clone)]
pub struct Encoder {
    pub convs: Vec<Conv2d>,
    pub head: Linear,
    pub ln: LayerNorm,
    /// The paper's overflow guard: per-sample rescale of the head output
    /// so `max|out| ≤ clip` before layer-norm (with stop-gradient on the
    /// scale, valid because layer-norm is scale-invariant).
    pub downscale_clip: Option<f32>,
    pub feature_dim: usize,
}

impl Encoder {
    /// `frames` input channels (stacked frames × RGB), `filters` per conv
    /// layer, image of side `img`.
    pub fn new(
        name: &str,
        frames: usize,
        img: usize,
        filters: usize,
        feature_dim: usize,
        weight_std: bool,
        downscale_clip: Option<f32>,
        rng: &mut Pcg64,
    ) -> Self {
        let mut convs = Vec::new();
        convs.push(Conv2d::new(&format!("{name}.conv0"), frames, filters, 3, 2, rng));
        for i in 1..4 {
            convs.push(Conv2d::new(&format!("{name}.conv{i}"), filters, filters, 3, 1, rng));
        }
        // spatial size after the stack
        let mut h = (img - 3) / 2 + 1;
        for _ in 1..4 {
            h -= 2;
        }
        let flat = filters * h * h;
        let mut head = Linear::new(&format!("{name}.head"), flat, feature_dim, rng);
        if weight_std {
            head = head.with_weight_std();
        }
        let ln = LayerNorm::new(&format!("{name}.ln"), feature_dim);
        Encoder { convs, head, ln, downscale_clip, feature_dim }
    }

    /// Per-sample stop-grad downscale of the pre-LN activations;
    /// `scales` (when given) records the factor each row used, for the
    /// backward pass.
    fn apply_downscale(&self, z: &mut Tensor, prec: Precision, mut scales: Option<&mut Vec<f32>>) {
        let b = z.rows();
        if let Some(s) = scales.as_mut() {
            s.clear();
            s.resize(b, 1.0);
        }
        if let Some(clip) = self.downscale_clip {
            for r in 0..b {
                let mx = z.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                if mx > clip {
                    let sc = prec.q(clip / mx); // stop-grad scale
                    if let Some(s) = scales.as_mut() {
                        s[r] = sc;
                    }
                    for v in z.row_mut(r) {
                        *v = prec.q(*v * sc);
                    }
                }
            }
        }
    }

    /// Inference forward `[B, C, H, W] → [B, feature_dim]` (`&self`,
    /// cache-free). Bitwise identical to [`Encoder::forward_train`].
    /// The image feeds the first conv directly (no staging clone).
    pub fn forward(&self, img: &Tensor, prec: Precision) -> Tensor {
        assert_eq!(img.shape.len(), 4);
        let mut h = relu(&self.convs[0].forward(img, prec), prec);
        for conv in &self.convs[1..] {
            let z = conv.forward(&h, prec);
            h = relu(&z, prec);
        }
        let b = h.shape[0];
        let flat = h.len() / b;
        let hflat = h.reshape(&[b, flat]);
        let mut z = self.head.forward(&hflat, prec);
        self.apply_downscale(&mut z, prec, None);
        self.ln.forward(&z, prec)
    }

    /// Training forward: caches everything [`Encoder::backward`] needs
    /// into `ws`. The pre-ReLU conv outputs move into the workspace (no
    /// per-layer clone) and the image feeds the first conv directly —
    /// bitwise identical to the allocating layout.
    pub fn forward_train(&self, img: &Tensor, prec: Precision, ws: &mut EncoderWorkspace) -> Tensor {
        assert_eq!(img.shape.len(), 4);
        let n = self.convs.len();
        ws.convs.resize_with(n, Conv2dWorkspace::default);
        ws.pre_relu.clear();
        let mut h = {
            let z = self.convs[0].forward_train(img, prec, &mut ws.convs[0]);
            let a = relu(&z, prec);
            ws.pre_relu.push(z);
            a
        };
        for (i, conv) in self.convs.iter().enumerate().skip(1) {
            let z = conv.forward_train(&h, prec, &mut ws.convs[i]);
            let a = relu(&z, prec);
            ws.pre_relu.push(z);
            h = a;
        }
        let b = h.shape[0];
        let flat = h.len() / b;
        let hflat = h.reshape(&[b, flat]);
        let mut z = self.head.forward_train(&hflat, prec, &mut ws.head);
        self.apply_downscale(&mut z, prec, Some(&mut ws.scale));
        self.ln.forward_train(&z, prec, &mut ws.ln)
    }

    /// Backward from `dfeat` `[B, feature_dim]`; accumulates all encoder
    /// grads, returns nothing (images need no gradient).
    pub fn backward(&mut self, dfeat: &Tensor, prec: Precision, ws: &EncoderWorkspace) {
        let mut g = self.ln.backward(dfeat, prec, &ws.ln);
        // through the stop-grad downscale: dy/dz = s per sample
        for r in 0..g.rows() {
            let s = ws.scale[r];
            if s != 1.0 {
                for v in g.row_mut(r) {
                    *v = prec.q(*v * s);
                }
            }
        }
        let g = self.head.backward(&g, prec, &ws.head);
        // reshape to conv output shape
        let n = self.convs.len();
        // tidy-allow(alloc): pixels-path shape metadata (4 usizes);
        // workspace reuse is a ROADMAP carryover
        let last_shape = ws.pre_relu[n - 1].shape.clone();
        let mut g = g.reshape(&last_shape);
        for i in (0..n).rev() {
            g = relu_backward(&g, &ws.pre_relu[i], prec);
            g = self.convs[i].backward(&g, prec, &ws.convs[i]);
        }
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        let mut v: Vec<&mut Param> = Vec::new();
        for c in self.convs.iter_mut() {
            v.extend(c.params_mut());
        }
        v.extend(self.head.params_mut());
        v.extend(self.ln.params_mut());
        v
    }

    /// Visit the parameters in [`Encoder::params_mut`] order without
    /// materializing a `Vec`.
    pub fn for_each_param(&self, f: &mut impl FnMut(&Param)) {
        for c in &self.convs {
            c.for_each_param(f);
        }
        self.head.for_each_param(f);
        self.ln.for_each_param(f);
    }

    /// Mutable twin of [`Encoder::for_each_param`], same order.
    pub fn for_each_param_mut(&mut self, f: &mut impl FnMut(&mut Param)) {
        for c in self.convs.iter_mut() {
            c.for_each_param_mut(f);
        }
        self.head.for_each_param_mut(f);
        self.ln.for_each_param_mut(f);
    }

    pub fn flat_params(&mut self) -> Vec<f32> {
        let mut out = Vec::new();
        for p in self.params_mut() {
            out.extend_from_slice(&p.w);
        }
        out
    }

    pub fn load_flat(&mut self, flat: &[f32]) {
        let mut off = 0;
        for p in self.params_mut() {
            let n = p.len();
            p.w.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len());
    }

    pub fn zero_grad(&mut self) {
        for c in self.convs.iter_mut() {
            c.zero_grad();
        }
        self.head.zero_grad();
        self.ln.zero_grad();
    }

    /// Total learnable parameters (a read-only count — no `&mut self`).
    pub fn n_params(&self) -> usize {
        self.convs.iter().map(|c| c.n_params()).sum::<usize>()
            + self.head.n_params()
            + self.ln.n_params()
    }

    pub fn quantize_params(&mut self, prec: Precision) {
        for p in self.params_mut() {
            p.quantize(prec);
        }
    }

    /// Freeze the head's weight standardization into its stored weights
    /// (see [`Linear::bake_weight_std`]) — used when snapshotting an
    /// encoder into an immutable policy, where re-standardizing
    /// never-changing weights on every forward would be pure waste.
    pub fn bake_weight_std(&mut self, prec: Precision) {
        self.head.bake_weight_std(prec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_encoder(rng: &mut Pcg64) -> Encoder {
        // 21x21 image → conv s2: 10 → 8 → 6 → 4 → flat 16*filters
        Encoder::new("e", 3, 21, 4, 10, true, Some(10.0), rng)
    }

    #[test]
    fn forward_shape() {
        let mut rng = Pcg64::seed(1);
        let e = tiny_encoder(&mut rng);
        let img = Tensor::from_vec(&[2, 3, 21, 21], (0..2 * 3 * 21 * 21).map(|_| rng.uniform_f32()).collect());
        let f = e.forward(&img, Precision::Fp32);
        assert_eq!(f.shape, vec![2, 10]);
        assert!(!f.has_nonfinite());
    }

    #[test]
    fn backward_runs_and_populates_grads() {
        let mut rng = Pcg64::seed(2);
        let mut e = tiny_encoder(&mut rng);
        let img = Tensor::from_vec(&[1, 3, 21, 21], (0..3 * 21 * 21).map(|_| rng.uniform_f32()).collect());
        let mut ws = EncoderWorkspace::default();
        let f = e.forward_train(&img, Precision::Fp32, &mut ws);
        e.zero_grad();
        e.backward(&f.clone(), Precision::Fp32, &ws);
        let nonzero = e
            .params_mut()
            .iter()
            .flat_map(|p| p.g.iter())
            .filter(|&&g| g != 0.0)
            .count();
        assert!(nonzero > 100, "only {nonzero} nonzero grads");
    }

    #[test]
    fn gradcheck_through_whole_encoder() {
        let mut rng = Pcg64::seed(3);
        let mut e = Encoder::new("e", 1, 17, 2, 4, false, None, &mut rng);
        let img = Tensor::from_vec(&[1, 1, 17, 17], (0..289).map(|_| rng.normal_f32()).collect());
        let prec = Precision::Fp32;
        let mut ws = EncoderWorkspace::default();
        let f = e.forward_train(&img, prec, &mut ws);
        e.zero_grad();
        e.backward(&f.clone(), prec, &ws); // loss = sum(f²)/2
        let g = e.convs[0].w.g[3];
        let eps = 1e-3f32;
        let orig = e.convs[0].w.w[3];
        e.convs[0].w.w[3] = orig + eps;
        let lp: f32 = e.forward(&img, prec).data.iter().map(|v| v * v / 2.0).sum();
        e.convs[0].w.w[3] = orig - eps;
        let lm: f32 = e.forward(&img, prec).data.iter().map(|v| v * v / 2.0).sum();
        e.convs[0].w.w[3] = orig;
        let num = (lp - lm) / (2.0 * eps);
        assert!((num - g).abs() < 5e-2 * (1.0 + num.abs()), "num={num} ana={g}");
    }

    #[test]
    fn downscale_prevents_layernorm_overflow_in_fp16() {
        let mut rng = Pcg64::seed(4);
        // Deterministic, ReLU-alive conv stack: small positive weights and
        // biases keep every activation positive, so the failure is
        // isolated to the head/layer-norm numerics the paper discusses.
        let build = |clip: Option<f32>, rng: &mut Pcg64| {
            let mut e = Encoder::new("e", 1, 17, 4, 8, false, clip, rng);
            for c in e.convs.iter_mut() {
                for v in c.w.w.iter_mut() {
                    *v = 0.03;
                }
                for v in c.b.w.iter_mut() {
                    *v = 0.1;
                }
            }
            // large alternating head weights -> pre-LN outputs in the
            // hundreds, whose squared deviations overflow fp16
            for (i, v) in e.head.w.w.iter_mut().enumerate() {
                *v = if i % 2 == 0 { 2.0 } else { -2.0 };
            }
            for (i, v) in e.head.b.w.iter_mut().enumerate() {
                *v = 300.0 * (i % 3) as f32;
            }
            e
        };
        let bad = build(None, &mut rng);
        let good = build(Some(10.0), &mut rng);
        let img = Tensor::from_vec(
            &[1, 1, 17, 17],
            (0..289).map(|_| rng.uniform_f32() + 0.5).collect(),
        );
        let f_bad = bad.forward(&img, Precision::fp16());
        let f_good = good.forward(&img, Precision::fp16());
        // sanity: in fp32 the same network is healthy
        let f_ref = bad.forward(&img, Precision::Fp32);
        assert!(!f_ref.has_nonfinite());
        assert!(f_ref.data.iter().any(|&v| v.abs() > 0.1));
        // The unguarded fp16 variance overflows to ∞; downstream that is
        // either non-finite features or (∞ in the denominator) an
        // all-zero, information-free feature vector. Both are failures.
        let bad_degenerate =
            f_bad.has_nonfinite() || f_bad.data.iter().all(|&v| v == 0.0);
        assert!(bad_degenerate, "unguarded encoder should break: {:?}", &f_bad.data[..4]);
        assert!(!f_good.has_nonfinite(), "guarded encoder must stay finite");
        assert!(
            f_good.data.iter().any(|&v| v != 0.0),
            "guarded encoder must carry signal"
        );
    }

    #[test]
    fn flat_roundtrip() {
        let mut rng = Pcg64::seed(5);
        let mut e = tiny_encoder(&mut rng);
        let flat = e.flat_params();
        assert_eq!(flat.len(), e.n_params());
        let mut e2 = tiny_encoder(&mut rng);
        e2.load_flat(&flat);
        assert_eq!(e2.flat_params(), flat);
    }

    #[test]
    fn inference_and_train_forward_agree_bitwise() {
        let mut rng = Pcg64::seed(6);
        let e = tiny_encoder(&mut rng);
        let img = Tensor::from_vec(&[2, 3, 21, 21], (0..2 * 3 * 21 * 21).map(|_| rng.uniform_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let mut ws = EncoderWorkspace::default();
            let a = e.forward(&img, prec);
            let b = e.forward_train(&img, prec, &mut ws);
            assert!(a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
    }
}
