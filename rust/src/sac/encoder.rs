//! Pixel encoder for RL from images (paper §4.6 / Appendix G):
//! four 3×3 conv layers (first stride 2, rest stride 1, ReLU between),
//! a linear head to `feature_dim` (50), and layer-normalization.
//!
//! The paper's fp16 fix: the head linear layer gets **weight
//! standardization** and its output is **down-scaled to max-norm 10**
//! before layer-norm (layer-norm is invariant to both, so the semantics
//! are unchanged in exact arithmetic, but the variance no longer
//! overflows).
//!
//! `forward` is `&self` and cache-free, so an encoder inside a frozen
//! [`super::Policy`] snapshot can serve many threads; training caches
//! live in an explicit [`EncoderWorkspace`].

use crate::lowp::{HalfFormat, Precision};
use crate::nn::{
    relu, relu_backward_in_place, relu_into, Conv2d, Conv2dWorkspace, LayerNorm,
    LayerNormWorkspace, Linear, LinearWorkspace, Param, Tensor,
};
use crate::rngs::Pcg64;

/// Caller-owned caches and scratch for one [`Encoder`]: per-conv
/// workspaces, pre-ReLU activations, post-ReLU activations, the
/// head/layer-norm workspaces, per-sample downscale factors, and the
/// backward's gradient buffers. Every buffer is grown once and reused,
/// so the `_into` walks are allocation-free in steady state.
#[derive(Debug, Clone, Default)]
pub struct EncoderWorkspace {
    convs: Vec<Conv2dWorkspace>,
    pre_relu: Vec<Tensor>,
    act: Vec<Tensor>,
    head: LinearWorkspace,
    ln: LayerNormWorkspace,
    scale: Vec<f32>,
    z: Tensor, // pre-LN head output
    grad_ln: Tensor,
    grad_head: Tensor,
    dxs: Vec<Tensor>, // per-conv input gradients (stable shapes per slot)
}

/// Convolutional encoder: `[B, C, H, W] → [B, feature_dim]`.
#[derive(Debug, Clone)]
pub struct Encoder {
    pub convs: Vec<Conv2d>,
    pub head: Linear,
    pub ln: LayerNorm,
    /// The paper's overflow guard: per-sample rescale of the head output
    /// so `max|out| ≤ clip` before layer-norm (with stop-gradient on the
    /// scale, valid because layer-norm is scale-invariant).
    pub downscale_clip: Option<f32>,
    pub feature_dim: usize,
}

impl Encoder {
    /// `frames` input channels (stacked frames × RGB), `filters` per conv
    /// layer, image of side `img`.
    pub fn new(
        name: &str,
        frames: usize,
        img: usize,
        filters: usize,
        feature_dim: usize,
        weight_std: bool,
        downscale_clip: Option<f32>,
        rng: &mut Pcg64,
    ) -> Self {
        let mut convs = Vec::new();
        convs.push(Conv2d::new(&format!("{name}.conv0"), frames, filters, 3, 2, rng));
        for i in 1..4 {
            convs.push(Conv2d::new(&format!("{name}.conv{i}"), filters, filters, 3, 1, rng));
        }
        // spatial size after the stack
        let mut h = (img - 3) / 2 + 1;
        for _ in 1..4 {
            h -= 2;
        }
        let flat = filters * h * h;
        let mut head = Linear::new(&format!("{name}.head"), flat, feature_dim, rng);
        if weight_std {
            head = head.with_weight_std();
        }
        let ln = LayerNorm::new(&format!("{name}.ln"), feature_dim);
        Encoder { convs, head, ln, downscale_clip, feature_dim }
    }

    /// Per-sample stop-grad downscale of the pre-LN activations;
    /// `scales` (when given) records the factor each row used, for the
    /// backward pass.
    fn apply_downscale(&self, z: &mut Tensor, prec: Precision, mut scales: Option<&mut Vec<f32>>) {
        let b = z.rows();
        if let Some(s) = scales.as_mut() {
            s.clear();
            s.resize(b, 1.0);
        }
        if let Some(clip) = self.downscale_clip {
            for r in 0..b {
                let mx = z.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                if mx > clip {
                    let sc = prec.q(clip / mx); // stop-grad scale
                    if let Some(s) = scales.as_mut() {
                        s[r] = sc;
                    }
                    for v in z.row_mut(r) {
                        *v = prec.q(*v * sc);
                    }
                }
            }
        }
    }

    /// Inference forward `[B, C, H, W] → [B, feature_dim]` (`&self`,
    /// cache-free). Bitwise identical to [`Encoder::forward_train`].
    /// The image feeds the first conv directly (no staging clone).
    pub fn forward(&self, img: &Tensor, prec: Precision) -> Tensor {
        assert_eq!(img.shape.len(), 4);
        let mut h = relu(&self.convs[0].forward(img, prec), prec);
        for conv in &self.convs[1..] {
            let z = conv.forward(&h, prec);
            h = relu(&z, prec);
        }
        let b = h.shape[0];
        let flat = h.len() / b;
        let hflat = h.reshape(&[b, flat]);
        let mut z = self.head.forward(&hflat, prec);
        self.apply_downscale(&mut z, prec, None);
        self.ln.forward(&z, prec)
    }

    /// Shared conv-stack + head walk for the `_into` forwards: leaves the
    /// post-ReLU activations in `ws.act` and the (downscaled) pre-LN head
    /// output in `ws.z`. `train` decides whether the head fills its
    /// backward caches (either way the numbers are bitwise identical —
    /// `forward_train_into` ≡ `forward_into` per layer).
    fn trunk_into(&self, img: &Tensor, prec: Precision, ws: &mut EncoderWorkspace, train: bool) {
        assert_eq!(img.shape.len(), 4);
        let n = self.convs.len();
        ws.convs.resize_with(n, Conv2dWorkspace::default);
        ws.pre_relu.resize_with(n, Tensor::default);
        ws.act.resize_with(n, Tensor::default);
        {
            let EncoderWorkspace { convs, pre_relu, act, .. } = ws;
            if train {
                self.convs[0].forward_train_into(img, prec, &mut convs[0], &mut pre_relu[0]);
            } else {
                self.convs[0].forward_into(img, prec, &mut convs[0], &mut pre_relu[0]);
            }
            relu_into(&pre_relu[0], prec, &mut act[0]);
            for i in 1..n {
                if train {
                    self.convs[i].forward_train_into(
                        &act[i - 1],
                        prec,
                        &mut convs[i],
                        &mut pre_relu[i],
                    );
                } else {
                    self.convs[i].forward_into(&act[i - 1], prec, &mut convs[i], &mut pre_relu[i]);
                }
                relu_into(&pre_relu[i], prec, &mut act[i]);
            }
        }
        // flatten the last activation for the head, restoring the 4-D
        // view afterwards so the workspace slot keeps a stable shape
        // (no realloc next round)
        let top = &ws.act[n - 1];
        let shape4 = [top.shape[0], top.shape[1], top.shape[2], top.shape[3]];
        let b = shape4[0];
        let flat = top.len() / b;
        ws.act[n - 1].set_shape_in_place(&[b, flat]);
        {
            let EncoderWorkspace { act, head, z, .. } = ws;
            // the head always walks through its workspace: a live
            // weight-std head re-standardizes into ws buffers instead of
            // allocating per call, and the cached input is only read by
            // an explicit `backward`
            self.head.forward_train_into(&act[n - 1], prec, head, z);
        }
        ws.act[n - 1].set_shape_in_place(&shape4);
        {
            let EncoderWorkspace { z, scale, .. } = ws;
            self.apply_downscale(z, prec, Some(&mut *scale));
        }
    }

    /// Allocation-free inference twin of [`Encoder::forward`]: all
    /// intermediates live in `ws`, the features in `out`, reused when
    /// shapes repeat. Bitwise identical. Use a workspace distinct from
    /// the training one — this walk overwrites the cached activations
    /// [`Encoder::backward`] reads.
    pub fn forward_into(
        &self,
        img: &Tensor,
        prec: Precision,
        ws: &mut EncoderWorkspace,
        out: &mut Tensor,
    ) {
        self.trunk_into(img, prec, ws, false);
        let EncoderWorkspace { z, .. } = ws;
        self.ln.forward_into(z, prec, out);
    }

    /// Training forward: caches everything [`Encoder::backward`] needs
    /// into `ws`. Bitwise identical to [`Encoder::forward`].
    pub fn forward_train(&self, img: &Tensor, prec: Precision, ws: &mut EncoderWorkspace) -> Tensor {
        let mut y = Tensor::default();
        self.forward_train_into(img, prec, ws, &mut y);
        y
    }

    /// Allocation-free twin of [`Encoder::forward_train`].
    pub fn forward_train_into(
        &self,
        img: &Tensor,
        prec: Precision,
        ws: &mut EncoderWorkspace,
        out: &mut Tensor,
    ) {
        self.trunk_into(img, prec, ws, true);
        let EncoderWorkspace { z, ln, .. } = ws;
        self.ln.forward_train_into(z, prec, ln, out);
    }

    /// Backward from `dfeat` `[B, feature_dim]`; accumulates all encoder
    /// grads, returns nothing (images need no gradient). All gradient
    /// scratch lives in `ws` (allocation-free once warm).
    pub fn backward(&mut self, dfeat: &Tensor, prec: Precision, ws: &mut EncoderWorkspace) {
        let n = self.convs.len();
        ws.dxs.resize_with(n, Tensor::default);
        {
            let EncoderWorkspace { ln, grad_ln, .. } = ws;
            self.ln.backward_into(dfeat, prec, ln, grad_ln);
        }
        // through the stop-grad downscale: dy/dz = s per sample
        {
            let EncoderWorkspace { grad_ln, scale, .. } = ws;
            for r in 0..grad_ln.rows() {
                let s = scale[r];
                if s != 1.0 {
                    for v in grad_ln.row_mut(r) {
                        *v = prec.q(*v * s);
                    }
                }
            }
        }
        let (b, flat) = {
            let EncoderWorkspace { grad_ln, head, grad_head, .. } = ws;
            self.head.backward_into(grad_ln, prec, head, grad_head);
            (grad_head.rows(), grad_head.cols())
        };
        // view the head input gradient in the conv output shape, walk the
        // stack, then restore the 2-D view so the buffer's shape is
        // stable across rounds
        {
            let EncoderWorkspace { pre_relu, grad_head, .. } = ws;
            let s = &pre_relu[n - 1].shape;
            let shape4 = [s[0], s[1], s[2], s[3]];
            grad_head.set_shape_in_place(&shape4);
        }
        {
            let EncoderWorkspace { convs, pre_relu, grad_head, dxs, .. } = ws;
            relu_backward_in_place(grad_head, &pre_relu[n - 1], prec);
            self.convs[n - 1].backward_into(grad_head, prec, &mut convs[n - 1], &mut dxs[n - 1]);
            for i in (0..n - 1).rev() {
                let (lo, hi) = dxs.split_at_mut(i + 1);
                relu_backward_in_place(&mut hi[0], &pre_relu[i], prec);
                self.convs[i].backward_into(&hi[0], prec, &mut convs[i], &mut lo[i]);
            }
        }
        ws.grad_head.set_shape_in_place(&[b, flat]);
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        let mut v: Vec<&mut Param> = Vec::new();
        for c in self.convs.iter_mut() {
            v.extend(c.params_mut());
        }
        v.extend(self.head.params_mut());
        v.extend(self.ln.params_mut());
        v
    }

    /// Visit the parameters in [`Encoder::params_mut`] order without
    /// materializing a `Vec`.
    pub fn for_each_param(&self, f: &mut impl FnMut(&Param)) {
        for c in &self.convs {
            c.for_each_param(f);
        }
        self.head.for_each_param(f);
        self.ln.for_each_param(f);
    }

    /// Mutable twin of [`Encoder::for_each_param`], same order.
    pub fn for_each_param_mut(&mut self, f: &mut impl FnMut(&mut Param)) {
        for c in self.convs.iter_mut() {
            c.for_each_param_mut(f);
        }
        self.head.for_each_param_mut(f);
        self.ln.for_each_param_mut(f);
    }

    pub fn flat_params(&mut self) -> Vec<f32> {
        let mut out = Vec::new();
        for p in self.params_mut() {
            out.extend_from_slice(&p.w);
        }
        out
    }

    pub fn load_flat(&mut self, flat: &[f32]) {
        let mut off = 0;
        for p in self.params_mut() {
            let n = p.len();
            p.w.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len());
    }

    pub fn zero_grad(&mut self) {
        for c in self.convs.iter_mut() {
            c.zero_grad();
        }
        self.head.zero_grad();
        self.ln.zero_grad();
    }

    /// Total learnable parameters (a read-only count — no `&mut self`).
    pub fn n_params(&self) -> usize {
        self.convs.iter().map(|c| c.n_params()).sum::<usize>()
            + self.head.n_params()
            + self.ln.n_params()
    }

    pub fn quantize_params(&mut self, prec: Precision) {
        for p in self.params_mut() {
            p.quantize(prec);
        }
    }

    /// Freeze the head's weight standardization into its stored weights
    /// (see [`Linear::bake_weight_std`]) — used when snapshotting an
    /// encoder into an immutable policy, where re-standardizing
    /// never-changing weights on every forward would be pure waste.
    pub fn bake_weight_std(&mut self, prec: Precision) {
        self.head.bake_weight_std(prec);
    }

    /// Pack the conv kernels and (if its standardization is baked) the
    /// head weights into 16-bit storage — quantize-mirroring the
    /// masters, see [`Linear::pack_weights`]. A live weight-std head is
    /// left unpacked (its GEMM reads the re-standardized `Ŵ`, not `w`),
    /// which is why target encoders stay on the f32 tier. Layer-norm
    /// γ/β stay f32: they are tiny and read elementwise, not streamed
    /// through a GEMM.
    pub fn pack_weights(&mut self, fmt: HalfFormat) {
        for c in self.convs.iter_mut() {
            c.pack_weights(fmt);
        }
        self.head.pack_weights(fmt);
    }

    /// Refresh every packed mirror from its (EMA-updated) master,
    /// allocation-free — the target-encoder sync hook. Layers that were
    /// never packed (the live weight-std head) are untouched.
    pub fn repack_weights(&mut self) {
        for c in self.convs.iter_mut() {
            c.repack_weights();
        }
        self.head.repack_weights();
    }

    /// Drop the f32 masters of every packed layer (frozen snapshots).
    pub fn drop_masters(&mut self) {
        for c in self.convs.iter_mut() {
            c.drop_master();
        }
        if self.head.w_half.is_some() {
            self.head.drop_master();
        }
    }

    /// Resident weight bytes across storage tiers (convs + head + γ/β).
    pub fn weight_bytes(&self) -> usize {
        let f32s = std::mem::size_of::<f32>();
        self.convs.iter().map(|c| c.weight_bytes()).sum::<usize>()
            + self.head.weight_bytes()
            + (self.ln.gamma.w.len() + self.ln.beta.w.len()) * f32s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_encoder(rng: &mut Pcg64) -> Encoder {
        // 21x21 image → conv s2: 10 → 8 → 6 → 4 → flat 16*filters
        Encoder::new("e", 3, 21, 4, 10, true, Some(10.0), rng)
    }

    #[test]
    fn forward_shape() {
        let mut rng = Pcg64::seed(1);
        let e = tiny_encoder(&mut rng);
        let img = Tensor::from_vec(&[2, 3, 21, 21], (0..2 * 3 * 21 * 21).map(|_| rng.uniform_f32()).collect());
        let f = e.forward(&img, Precision::Fp32);
        assert_eq!(f.shape, vec![2, 10]);
        assert!(!f.has_nonfinite());
    }

    #[test]
    fn backward_runs_and_populates_grads() {
        let mut rng = Pcg64::seed(2);
        let mut e = tiny_encoder(&mut rng);
        let img = Tensor::from_vec(&[1, 3, 21, 21], (0..3 * 21 * 21).map(|_| rng.uniform_f32()).collect());
        let mut ws = EncoderWorkspace::default();
        let f = e.forward_train(&img, Precision::Fp32, &mut ws);
        e.zero_grad();
        e.backward(&f.clone(), Precision::Fp32, &mut ws);
        let nonzero = e
            .params_mut()
            .iter()
            .flat_map(|p| p.g.iter())
            .filter(|&&g| g != 0.0)
            .count();
        assert!(nonzero > 100, "only {nonzero} nonzero grads");
    }

    #[test]
    fn gradcheck_through_whole_encoder() {
        let mut rng = Pcg64::seed(3);
        let mut e = Encoder::new("e", 1, 17, 2, 4, false, None, &mut rng);
        let img = Tensor::from_vec(&[1, 1, 17, 17], (0..289).map(|_| rng.normal_f32()).collect());
        let prec = Precision::Fp32;
        let mut ws = EncoderWorkspace::default();
        let f = e.forward_train(&img, prec, &mut ws);
        e.zero_grad();
        e.backward(&f.clone(), prec, &mut ws); // loss = sum(f²)/2
        let g = e.convs[0].w.g[3];
        let eps = 1e-3f32;
        let orig = e.convs[0].w.w[3];
        e.convs[0].w.w[3] = orig + eps;
        let lp: f32 = e.forward(&img, prec).data.iter().map(|v| v * v / 2.0).sum();
        e.convs[0].w.w[3] = orig - eps;
        let lm: f32 = e.forward(&img, prec).data.iter().map(|v| v * v / 2.0).sum();
        e.convs[0].w.w[3] = orig;
        let num = (lp - lm) / (2.0 * eps);
        assert!((num - g).abs() < 5e-2 * (1.0 + num.abs()), "num={num} ana={g}");
    }

    #[test]
    fn downscale_prevents_layernorm_overflow_in_fp16() {
        let mut rng = Pcg64::seed(4);
        // Deterministic, ReLU-alive conv stack: small positive weights and
        // biases keep every activation positive, so the failure is
        // isolated to the head/layer-norm numerics the paper discusses.
        let build = |clip: Option<f32>, rng: &mut Pcg64| {
            let mut e = Encoder::new("e", 1, 17, 4, 8, false, clip, rng);
            for c in e.convs.iter_mut() {
                for v in c.w.w.iter_mut() {
                    *v = 0.03;
                }
                for v in c.b.w.iter_mut() {
                    *v = 0.1;
                }
            }
            // large alternating head weights -> pre-LN outputs in the
            // hundreds, whose squared deviations overflow fp16
            for (i, v) in e.head.w.w.iter_mut().enumerate() {
                *v = if i % 2 == 0 { 2.0 } else { -2.0 };
            }
            for (i, v) in e.head.b.w.iter_mut().enumerate() {
                *v = 300.0 * (i % 3) as f32;
            }
            e
        };
        let bad = build(None, &mut rng);
        let good = build(Some(10.0), &mut rng);
        let img = Tensor::from_vec(
            &[1, 1, 17, 17],
            (0..289).map(|_| rng.uniform_f32() + 0.5).collect(),
        );
        let f_bad = bad.forward(&img, Precision::fp16());
        let f_good = good.forward(&img, Precision::fp16());
        // sanity: in fp32 the same network is healthy
        let f_ref = bad.forward(&img, Precision::Fp32);
        assert!(!f_ref.has_nonfinite());
        assert!(f_ref.data.iter().any(|&v| v.abs() > 0.1));
        // The unguarded fp16 variance overflows to ∞; downstream that is
        // either non-finite features or (∞ in the denominator) an
        // all-zero, information-free feature vector. Both are failures.
        let bad_degenerate =
            f_bad.has_nonfinite() || f_bad.data.iter().all(|&v| v == 0.0);
        assert!(bad_degenerate, "unguarded encoder should break: {:?}", &f_bad.data[..4]);
        assert!(!f_good.has_nonfinite(), "guarded encoder must stay finite");
        assert!(
            f_good.data.iter().any(|&v| v != 0.0),
            "guarded encoder must carry signal"
        );
    }

    #[test]
    fn flat_roundtrip() {
        let mut rng = Pcg64::seed(5);
        let mut e = tiny_encoder(&mut rng);
        let flat = e.flat_params();
        assert_eq!(flat.len(), e.n_params());
        let mut e2 = tiny_encoder(&mut rng);
        e2.load_flat(&flat);
        assert_eq!(e2.flat_params(), flat);
    }

    #[test]
    fn inference_and_train_forward_agree_bitwise() {
        let mut rng = Pcg64::seed(6);
        let e = tiny_encoder(&mut rng);
        let img = Tensor::from_vec(&[2, 3, 21, 21], (0..2 * 3 * 21 * 21).map(|_| rng.uniform_f32()).collect());
        for prec in [Precision::Fp32, Precision::fp16()] {
            let mut ws = EncoderWorkspace::default();
            let mut wsi = EncoderWorkspace::default();
            let mut f = Tensor::default();
            let a = e.forward(&img, prec);
            let b = e.forward_train(&img, prec, &mut ws);
            e.forward_into(&img, prec, &mut wsi, &mut f);
            assert!(a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()));
            assert!(a.data.iter().zip(&f.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
    }

    #[test]
    fn workspace_walks_reuse_buffers_across_rounds() {
        let mut rng = Pcg64::seed(7);
        let mut e = tiny_encoder(&mut rng);
        let img = Tensor::from_vec(
            &[2, 3, 21, 21],
            (0..2 * 3 * 21 * 21).map(|_| rng.uniform_f32()).collect(),
        );
        let mut ws = EncoderWorkspace::default();
        let mut f = Tensor::default();
        e.forward_train_into(&img, Precision::Fp32, &mut ws, &mut f);
        e.backward(&f.clone(), Precision::Fp32, &mut ws);
        let n = e.convs.len();
        let ptrs: Vec<*const f32> = ws
            .pre_relu
            .iter()
            .chain(ws.act.iter())
            .chain(ws.dxs.iter())
            .map(|t| t.data.as_ptr() as *const f32)
            .collect();
        let (zp, glp, ghp, fp) =
            (ws.z.data.as_ptr(), ws.grad_ln.data.as_ptr(), ws.grad_head.data.as_ptr(), f.data.as_ptr());
        e.forward_train_into(&img, Precision::Fp32, &mut ws, &mut f);
        e.backward(&f.clone(), Precision::Fp32, &mut ws);
        let after: Vec<*const f32> = ws
            .pre_relu
            .iter()
            .chain(ws.act.iter())
            .chain(ws.dxs.iter())
            .map(|t| t.data.as_ptr() as *const f32)
            .collect();
        assert_eq!(ptrs, after, "conv activations/gradients must reuse their buffers");
        assert_eq!(zp, ws.z.data.as_ptr(), "pre-LN buffer must be reused");
        assert_eq!(glp, ws.grad_ln.data.as_ptr(), "LN gradient must be reused");
        assert_eq!(ghp, ws.grad_head.data.as_ptr(), "head gradient must be reused");
        assert_eq!(fp, f.data.as_ptr(), "feature tensor must be reused");
        // the act slots must be back in 4-D view for the next round
        assert_eq!(ws.act[n - 1].shape.len(), 4, "flattened view must be restored");
    }

    #[test]
    fn packed_snapshot_encoder_matches_master_bitwise() {
        let mut rng = Pcg64::seed(8);
        let mut e = tiny_encoder(&mut rng);
        // snapshot recipe: bake the weight-std head, then pack
        e.bake_weight_std(Precision::fp16());
        let img = Tensor::from_vec(
            &[2, 3, 21, 21],
            (0..2 * 3 * 21 * 21).map(|_| rng.uniform_f32()).collect(),
        );
        let mut packed = e.clone();
        packed.pack_weights(HalfFormat::F16);
        // quantize-mirror: sync the reference masters
        for (c, pc) in e.convs.iter_mut().zip(&packed.convs) {
            c.w.w.clone_from(&pc.w.w);
        }
        e.head.w.w.clone_from(&packed.head.w.w);
        let a = e.forward(&img, Precision::fp16());
        let b = packed.forward(&img, Precision::fp16());
        assert!(a.data.iter().zip(&b.data).all(|(u, v)| u.to_bits() == v.to_bits()));
        let full = packed.weight_bytes();
        packed.drop_masters();
        let lean = packed.weight_bytes();
        assert!(lean < full, "dropping masters must shrink resident bytes");
        let c = packed.forward(&img, Precision::fp16());
        assert!(a.data.iter().zip(&c.data).all(|(u, v)| u.to_bits() == v.to_bits()));
    }
}
