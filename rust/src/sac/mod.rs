//! Soft Actor-Critic (Haarnoja et al., 2018), faithful to the reference
//! implementation of Yarats & Kostrikov (2020) that the paper builds on,
//! with the paper's six numerical-stability modifications as independent
//! switches (see [`Methods`]).
//!
//! The agent runs identically under fp32, fp16 and any simulated
//! [`crate::lowp::FloatFormat`]; the *only* difference between the
//! paper's configurations is which of the six methods are enabled and
//! which supervised-learning baseline tricks are applied.
//!
//! Training and inference are split: [`SacAgent`] owns the optimizers
//! and training workspaces, while [`Policy`] is an immutable
//! `Send + Sync` snapshot of the action path ([`SacAgent::policy`])
//! with batched `act_batch` — the type the serve layer and the
//! deterministic evaluator consume.

mod agent;
mod critic;
mod encoder;
mod methods;
mod policy;
mod snapshot;

pub use agent::{Batch, SacAgent, SacConfig, UpdateStats};
pub use critic::{Critic, CriticWorkspace};
pub use encoder::{Encoder, EncoderWorkspace};
pub use methods::Methods;
pub use policy::{softplus_neg2u, softplus_neg2u_grad, PolicyCfg, TanhGaussian};
pub use snapshot::{ActMode, Policy};
