//! Figure 3 (+ per-task Figure 9): the cumulative ablation — start from
//! naive fp16 and add the six methods one by one.
//! Figure 7: leave-one-out — all methods minus one.

use super::helpers::{run_grid_and_report, ExpOpts};
use crate::sac::Methods;

pub fn run(opts: &ExpOpts, leave_one_out: bool) -> anyhow::Result<()> {
    if leave_one_out {
        let presets = ["fp16_ours", "loo1", "loo2", "loo3", "loo4", "loo5", "loo6"];
        run_grid_and_report(
            opts,
            "fig7",
            &presets,
            "Figure 7 — remove one method from the full agent (paper: every removal hurts):",
        )?;
        return Ok(());
    }
    let presets = ["cum0", "cum1", "cum2", "cum3", "cum4", "cum5", "cum6", "fp32"];
    let outs = run_grid_and_report(
        opts,
        "fig3",
        &presets,
        "Figure 3 — cumulative ablation (add methods one by one):",
    )?;
    println!("\ncumulative labels:");
    for k in 0..=6 {
        println!("  cum{k} = {}", Methods::cumulative_label(k));
    }
    // Figure 9 = per-task breakdown of the same runs
    println!("\nFigure 9 — per-task breakdown:");
    println!("{:<20} {}", "task", presets.join("  "));
    for task in &opts.tasks {
        let t = [task.clone()];
        let s = super::helpers::summarize(&outs, &presets, &t);
        let row: Vec<String> = s.iter().map(|(_, m, _)| format!("{m:>6.0}")).collect();
        println!("{task:<20} {}", row.join("  "));
    }
    Ok(())
}
