//! Figure 4: simulate training in e5mX formats (X significand bits,
//! 5 exponent bits) with all our methods on — the qtorch sweep. The
//! paper's shape: performance degrades monotonically as bits shrink,
//! gracefully at first, then collapses around 5 significand bits.

use super::helpers::{run_grid_and_report, summarize, ExpOpts};

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let presets = [
        "e5m10_ours", // == fp16
        "e5m9_ours",
        "e5m8_ours",
        "e5m7_ours",
        "e5m6_ours",
        "e5m5_ours",
    ];
    let outs = run_grid_and_report(
        opts,
        "fig4",
        &presets,
        "Figure 4 — significand-bit sweep (all methods on):",
    )?;
    println!("\n{:<6} {:>10} {:>8}", "bits", "return", "std");
    let s = summarize(&outs, &presets, &opts.tasks);
    for (i, (p, m, sd)) in s.iter().enumerate() {
        let bits = 10 - i;
        println!("{bits:<6} {m:>10.1} {sd:>8.1}   ({p})");
    }
    Ok(())
}
