//! Figure 2: learning curves for fp32 vs fp16+ours on the six planet
//! tasks (states). The paper's claim: the curves coincide.

use super::helpers::{run_grid_and_report, summarize, ExpOpts};

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let presets = ["fp32", "fp16_ours"];
    let outs = run_grid_and_report(
        opts,
        "fig2",
        &presets,
        "Figure 2 — fp32 vs fp16(ours) final returns per task:",
    )?;
    println!("\n{:<20} {:>10} {:>10} {:>8}", "task", "fp32", "fp16_ours", "gap%");
    for task in &opts.tasks {
        let t = [task.clone()];
        let s = summarize(&outs, &presets, &t);
        let (f32_, f16_) = (s[0].1, s[1].1);
        let gap = if f32_.abs() > 1e-9 { 100.0 * (f32_ - f16_) / f32_ } else { 0.0 };
        println!("{task:<20} {f32_:>10.1} {f16_:>10.1} {gap:>7.1}%");
    }
    Ok(())
}
