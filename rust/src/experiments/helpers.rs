//! Shared experiment plumbing: option parsing, config grids, aggregation
//! across seeds/tasks, and paper-shaped printing.

use crate::config::RunConfig;
use crate::coordinator::{run_many, TrainOutcome};
use crate::envs::PLANET_TASKS;
use crate::telemetry::{mean_std, write_csv, Series};
use std::path::PathBuf;

/// Options shared by all experiment drivers.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    pub base: RunConfig,
    pub seeds: usize,
    pub tasks: Vec<String>,
}

impl ExpOpts {
    pub fn from_kv(kv: &[(String, String)]) -> anyhow::Result<Self> {
        let mut base = RunConfig::default();
        let mut seeds = 3usize;
        let mut tasks: Vec<String> = PLANET_TASKS.iter().map(|s| s.to_string()).collect();
        for (k, v) in kv {
            match k.as_str() {
                "seeds" => seeds = v.parse()?,
                "tasks" => tasks = v.split(',').map(|s| s.trim().to_string()).collect(),
                "paper_full" => {
                    if v == "true" {
                        base = RunConfig::paper_full();
                    }
                }
                _ => {
                    if !base.set(k, v) {
                        anyhow::bail!("unknown option {k}");
                    }
                }
            }
        }
        Ok(ExpOpts { base, seeds, tasks })
    }

    pub fn out(&self, exp: &str) -> PathBuf {
        PathBuf::from(&self.base.out_dir).join(exp)
    }
}

/// Build the (preset × task × seed) config grid.
pub fn grid(opts: &ExpOpts, presets: &[&str]) -> Vec<RunConfig> {
    let mut cfgs = Vec::new();
    for preset in presets {
        for task in &opts.tasks {
            for seed in 0..opts.seeds {
                let mut c = opts.base.clone();
                c.preset = preset.to_string();
                c.task = task.clone();
                c.seed = seed as u64;
                cfgs.push(c);
            }
        }
    }
    cfgs
}

/// Aggregate outcomes by preset: mean/std of the final score across
/// tasks and seeds (the paper's cross-task averaging: std per task, then
/// averaged).
pub fn summarize(outs: &[TrainOutcome], presets: &[&str], tasks: &[String]) -> Vec<(String, f64, f64)> {
    presets
        .iter()
        .map(|p| {
            let mut task_means = Vec::new();
            let mut task_stds = Vec::new();
            for task in tasks {
                let scores: Vec<f64> = outs
                    .iter()
                    .filter(|o| &o.cfg.preset == p && &o.cfg.task == task)
                    .map(|o| o.final_score)
                    .collect();
                if !scores.is_empty() {
                    let (m, s) = mean_std(&scores);
                    task_means.push(m);
                    task_stds.push(s);
                }
            }
            let (mm, _) = mean_std(&task_means);
            let (sm, _) = mean_std(&task_stds);
            (p.to_string(), mm, sm)
        })
        .collect()
}

/// Average learning curves for one preset across seeds (per task).
pub fn mean_curve(outs: &[TrainOutcome], preset: &str, task: &str) -> Series {
    let curves: Vec<&Series> = outs
        .iter()
        .filter(|o| o.cfg.preset == preset && o.cfg.task == task)
        .map(|o| &o.eval_curve)
        .collect();
    let mut s = Series::new(format!("{task}:{preset}"));
    if curves.is_empty() {
        return s;
    }
    let xs: Vec<f64> = curves[0].points.iter().map(|p| p.0).collect();
    for (i, &x) in xs.iter().enumerate() {
        let ys: Vec<f64> = curves.iter().filter_map(|c| c.points.get(i).map(|p| p.1)).collect();
        let (m, _) = mean_std(&ys);
        s.push(x, m);
    }
    s
}

/// Run the grid, print a summary table, dump per-preset curves.
pub fn run_grid_and_report(
    opts: &ExpOpts,
    exp: &str,
    presets: &[&str],
    header: &str,
) -> anyhow::Result<Vec<TrainOutcome>> {
    let cfgs = grid(opts, presets);
    eprintln!(
        "[{exp}] running {} configs ({} presets x {} tasks x {} seeds) ...",
        cfgs.len(),
        presets.len(),
        opts.tasks.len(),
        opts.seeds
    );
    let outs = run_many(&cfgs);
    println!("\n{header}");
    println!("{:<16} {:>10} {:>8} {:>8}", "preset", "return", "std", "crashed");
    let summary = summarize(&outs, presets, &opts.tasks);
    for (p, m, s) in &summary {
        let crashes = outs.iter().filter(|o| &o.cfg.preset == p && o.crashed).count();
        println!("{p:<16} {m:>10.1} {s:>8.1} {crashes:>8}");
    }
    // CSVs: per task curves
    let dir = opts.out(exp);
    for task in &opts.tasks {
        let series: Vec<Series> = presets.iter().map(|p| mean_curve(&outs, p, task)).collect();
        write_csv(&dir.join(format!("{task}.csv")), &series)?;
    }
    // summary csv
    let mut sum_series = Vec::new();
    for (i, (p, m, s)) in summary.iter().enumerate() {
        let mut a = Series::new(format!("{p}_mean"));
        a.push(i as f64, *m);
        let mut b = Series::new(format!("{p}_std"));
        b.push(i as f64, *s);
        sum_series.push(a);
        sum_series.push(b);
    }
    write_csv(&dir.join("summary.csv"), &sum_series)?;
    eprintln!("[{exp}] wrote CSVs to {}", dir.display());
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opts_parse_and_grid() {
        let kv = vec![
            ("seeds".to_string(), "2".to_string()),
            ("tasks".to_string(), "cartpole_swingup,cheetah_run".to_string()),
            ("steps".to_string(), "10".to_string()),
        ];
        let opts = ExpOpts::from_kv(&kv).unwrap();
        assert_eq!(opts.seeds, 2);
        assert_eq!(opts.tasks.len(), 2);
        assert_eq!(opts.base.steps, 10);
        let g = grid(&opts, &["fp32", "fp16_ours"]);
        assert_eq!(g.len(), 2 * 2 * 2);
        assert!(ExpOpts::from_kv(&[("bogus".into(), "1".into())]).is_err());
    }

    #[test]
    fn summarize_empty_is_safe() {
        let s = summarize(&[], &["fp32"], &["cartpole_swingup".to_string()]);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].1, 0.0);
    }
}
