//! Figure 6: histogram of |gradient| for the actor+critic networks of an
//! fp32 run (cheetah, mid-training). Both axes log-scale; the paper's
//! point is the many-decade dynamic range, which squares past fp16's
//! range inside Adam.

use super::helpers::ExpOpts;
use crate::coordinator::train;
use crate::lowp::FP16;
use crate::telemetry::{write_csv, Series};

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let mut cfg = opts.base.clone();
    cfg.task = opts
        .tasks
        .iter()
        .find(|t| t.contains("cheetah"))
        .cloned()
        .unwrap_or_else(|| opts.tasks[0].clone());
    cfg.preset = "fp32".into();
    eprintln!("[fig6] training fp32 on {} to probe gradients ...", cfg.task);
    let out = train(&cfg);
    let h = &out.grad_hist;
    println!("Figure 6 — |grad| histogram ({}, fp32):", cfg.task);
    println!("{:<14} {:>12}", "magnitude", "count");
    let mut series = Series::new("count");
    for (center, count) in h.bins() {
        if count > 0 {
            println!("{center:<14.3e} {count:>12}");
        }
        series.push(center, count as f64);
    }
    println!("zeros/underflow: {}   overflow: {}", h.underflow, h.overflow);
    let decades = h.occupied_decades();
    println!("dynamic range: {decades:.1} decades (paper: 'many orders of magnitude')");
    // what fraction of gradients would square below fp16's tiny?
    let sub_sq: u64 = h
        .bins()
        .iter()
        .filter(|(c, _)| c * c < FP16.min_subnormal() as f64)
        .map(|(_, n)| n)
        .sum();
    let frac = sub_sq as f64 / h.total().max(1) as f64;
    println!("fraction whose square underflows fp16 (Adam v): {:.1}%", 100.0 * frac);
    write_csv(&opts.out("fig6").join("grad_hist.csv"), &[series])?;
    Ok(())
}
