//! Figures 11 & 12: train fp32/fp16 twins from the same seed and track
//! (11) the mean L1 distance between their critic/actor weights and
//! (12) the mean |ΔQ| on a fixed probe set of states, over training.

use super::helpers::ExpOpts;
use anyhow::Context;
use crate::envs::{action_repeat, make_env, sanitize_action};
use crate::nn::Tensor;
use crate::replay::{ReplayBuffer, Storage};
use crate::rngs::Pcg64;
use crate::sac::{Methods, SacAgent, SacConfig};
use crate::telemetry::{write_csv, Series};

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let task = opts.tasks[0].clone();
    let steps = opts.base.steps.min(3000);
    let checkpoints = 8usize;
    println!("Figures 11/12 — fp32 vs fp16 twin divergence on {task} ({steps} steps):");

    let mut env32 = make_env(&task).with_context(|| format!("unknown task {task}"))?;
    let mut env16 = make_env(&task).with_context(|| format!("unknown task {task}"))?;
    let repeat = action_repeat(&task);
    let mut rng = Pcg64::seed(opts.base.seed);
    let obs_dim = env32.obs_dim();
    let act_dim = env32.act_dim();
    let cfg = SacConfig::states(obs_dim, act_dim, opts.base.hidden);
    let mut a32 = SacAgent::new(cfg, Methods::none(), crate::lowp::Precision::Fp32, opts.base.seed);
    let mut a16 =
        SacAgent::new(cfg, Methods::ours(), crate::lowp::Precision::fp16(), opts.base.seed);
    let mut rp32 = ReplayBuffer::new(opts.base.replay_capacity, &[obs_dim], act_dim, Storage::F32);
    let mut rp16 = ReplayBuffer::new(opts.base.replay_capacity, &[obs_dim], act_dim, Storage::F16);

    // fixed probe states for |ΔQ| (Figure 12), as in the paper: states
    // encountered during training
    let mut probe = Vec::new();

    let mut obs32 = env32.reset(&mut Pcg64::seed(1));
    let mut obs16 = env16.reset(&mut Pcg64::seed(1));
    let mut l1_series = Series::new("weight_l1");
    let mut dq_series = Series::new("abs_dq");

    for step in 0..steps {
        for (agent, env, rp, obs) in [
            (&mut a32, &mut env32, &mut rp32, &mut obs32),
            (&mut a16, &mut env16, &mut rp16, &mut obs16),
        ] {
            let mut a = if step < opts.base.seed_steps {
                let mut r = rng.split(step as u64);
                (0..act_dim).map(|_| r.uniform_in(-1.0, 1.0)).collect::<Vec<f32>>()
            } else {
                agent.act(obs, true).unwrap_or_else(|| vec![0.0; act_dim])
            };
            sanitize_action(&mut a);
            let mut rew = 0.0;
            let mut next = obs.clone();
            for _ in 0..repeat {
                let (o, r) = env.step(&a);
                next = o;
                rew += r;
            }
            rp.push(obs, &a, rew, &next, false);
            *obs = next;
            if step >= opts.base.seed_steps && rp.len() >= opts.base.batch {
                let mut brng = Pcg64::seed_stream(42, step as u64);
                let batch = rp.sample(opts.base.batch, &mut brng);
                agent.update(&batch);
            }
        }
        if probe.len() < 128 {
            probe.push(obs32.clone());
        }
        if (step + 1) % (steps / checkpoints).max(1) == 0 {
            // Figure 11: mean L1 distance across critic+actor weights
            let w32: Vec<f32> = a32.critic.flat_params();
            let w16: Vec<f32> = a16.critic.flat_params();
            let l1: f64 = w32
                .iter()
                .zip(&w16)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / w32.len() as f64;
            l1_series.push((step + 1) as f64, l1);
            // Figure 12: |ΔQ| on probe states with the fp32 agent's action
            let mut dq_sum = 0.0f64;
            let mut n = 0usize;
            for s in probe.iter().take(32) {
                if let Some(mut a) = a32.act(s, false) {
                    sanitize_action(&mut a);
                    let obs_t = Tensor::from_vec(&[1, obs_dim], s.clone());
                    let act_t = Tensor::from_vec(&[1, act_dim], a);
                    let (q32, _) = a32.critic.forward(&obs_t, &act_t, a32.compute);
                    let (q16, _) = a16.critic.forward(&obs_t, &act_t, a16.compute);
                    if q32.data[0].is_finite() && q16.data[0].is_finite() {
                        dq_sum += (q32.data[0] - q16.data[0]).abs() as f64;
                        n += 1;
                    }
                }
            }
            dq_series.push((step + 1) as f64, dq_sum / n.max(1) as f64);
        }
    }

    println!("{:<10} {:>14} {:>12}", "step", "weight L1", "|dQ|");
    for (p, q) in l1_series.points.iter().zip(&dq_series.points) {
        println!("{:<10} {:>14.5} {:>12.4}", p.0, p.1, q.1);
    }
    println!(
        "(paper: weight distance grows with training; |dQ| grows then plateaus — \
         twins diverge but remain functionally close)"
    );
    write_csv(&opts.out("fig11").join("divergence.csv"), &[l1_series, dq_series])?;
    Ok(())
}
