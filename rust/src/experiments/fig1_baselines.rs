//! Figure 1: supervised-learning low-precision baselines fail on SAC.
//! Presets: fp16 (naive), coerc, loss-scale, mixed precision — compared
//! against fp32 and (for context) our method.
//!
//! Figure 8 (appendix): the `amp` scaler-schedule variant and the
//! `eps` (10× Adam ε) variant.

use super::helpers::{run_grid_and_report, ExpOpts};

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let presets = ["fp32", "fp16_naive", "coerc", "loss_scale", "mixed", "fp16_ours"];
    let outs = run_grid_and_report(
        opts,
        "fig1",
        &presets,
        "Figure 1 — returns after training, averaged across tasks (paper: baselines \
         fail, fp16-naive crashes to 0):",
    )?;
    // the paper's headline: naive fp16 crashes
    let naive_crashes = outs
        .iter()
        .filter(|o| o.cfg.preset == "fp16_naive")
        .filter(|o| o.crashed || o.final_score == 0.0)
        .count();
    let naive_total = outs.iter().filter(|o| o.cfg.preset == "fp16_naive").count();
    println!("fp16_naive crashed/zero-scored: {naive_crashes}/{naive_total}");
    Ok(())
}

/// Figure 8: amp-default scaler and 10x-eps baselines.
pub fn run_appendix_variants(opts: &ExpOpts) -> anyhow::Result<()> {
    // `amp` preset = loss scaling with the amp default schedule; the
    // schedule itself differs only in constants, so we reuse the preset
    // and note the schedule substitution in EXPERIMENTS.md.
    let presets = ["fp32", "amp", "loss_scale", "fp16_ours"];
    run_grid_and_report(
        opts,
        "fig8",
        &presets,
        "Figure 8 — appendix baselines (amp schedule; none match fp32):",
    )?;
    Ok(())
}
