//! Experiment harness: one driver per figure/table of the paper's
//! evaluation (`lprl exp <name>`; list below in [`run`]). Every driver prints
//! the paper-shaped rows/series to stdout and writes CSVs under
//! `<out_dir>/<exp>/`.
//!
//! Common overrides (CLI `key=value`): `steps`, `seeds`, `tasks`
//! (comma-separated), plus everything `RunConfig::set` accepts.

mod fig11_divergence;
mod fig1_baselines;
mod fig2_curves;
mod fig3_ablation;
mod fig4_formats;
mod fig5_pixels;
mod fig6_gradhist;
mod helpers;
mod table7_random;
mod tables_perf;

pub use helpers::{grid, summarize, ExpOpts};

/// Run an experiment by name. `kv` are CLI overrides.
pub fn run(name: &str, kv: &[(String, String)]) -> anyhow::Result<()> {
    let opts = ExpOpts::from_kv(kv)?;
    match name {
        "fig1" => fig1_baselines::run(&opts),
        "fig2" => fig2_curves::run(&opts),
        "fig3" | "fig9" => fig3_ablation::run(&opts, false),
        "fig7" => fig3_ablation::run(&opts, true),
        "fig8" => fig1_baselines::run_appendix_variants(&opts),
        "fig4" => fig4_formats::run(&opts),
        "fig5" | "fig10" => fig5_pixels::run(&opts),
        "fig6" => fig6_gradhist::run(&opts),
        "fig11" | "fig12" => fig11_divergence::run(&opts),
        "table2" => tables_perf::run_speed(&opts, true),
        "table10" => tables_perf::run_speed(&opts, false),
        "table3" => tables_perf::run_memory(&opts, true),
        "table11" => tables_perf::run_memory(&opts, false),
        "table7" => table7_random::run(&opts),
        "all" => {
            for e in [
                "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig11", "table2",
                "table3", "table7", "table10", "table11",
            ] {
                println!("\n================ {e} ================");
                run(e, kv)?;
            }
            Ok(())
        }
        _ => anyhow::bail!(
            "unknown experiment {name}; try fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig11|table2|table3|table7|table10|table11|all"
        ),
    }
}
