//! Table 7: random hyperparameters (Table 6's distributions) — fp32 vs
//! fp16+ours must stay close for every draw.

use super::helpers::{grid, summarize, ExpOpts};
use crate::coordinator::run_many;
use crate::rngs::Pcg64;
use crate::telemetry::write_csv;
use crate::telemetry::Series;

/// Draw one hyperparameter set from the paper's Table 6 distributions.
fn draw(rng: &mut Pcg64) -> (f32, f32, f32, f32, f32, usize) {
    let log_u = |rng: &mut Pcg64, lo: f32, hi: f32| -> f32 {
        (rng.uniform_in(lo.ln(), hi.ln())).exp()
    };
    let gamma = rng.uniform_in(0.9, 0.99);
    let lr = log_u(rng, 1e-5, 1e-3);
    let min_ls = rng.uniform_in(-7.0, -3.0);
    let tau = rng.uniform_in(0.0025, 0.01);
    let t0 = log_u(rng, 1e-2, 1e-1);
    let batch = [32usize, 64, 128][rng.below(3)]; // scaled-down analogue
    (gamma, lr, min_ls, tau, t0, batch)
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let mut rng = Pcg64::seed(2021);
    let presets = ["fp32", "fp16_ours"];
    println!("Table 7 — random hyperparameters, fp32 vs fp16(ours):");
    println!(
        "{:<8} {:>7} {:>9} {:>7} {:>7} {:>6} {:>6} | {:>9} {:>11}",
        "params", "gamma", "lr", "minls", "tau", "T0", "bsize", "fp32", "fp16(ours)"
    );
    let mut rows = Vec::new();
    for p in 0..5 {
        let (g, lr, mls, tau, t0, batch) = draw(&mut rng);
        let mut o = opts.clone();
        o.base.gamma = g;
        o.base.lr = lr;
        o.base.min_log_sig = mls;
        o.base.tau = tau;
        o.base.init_temp = t0;
        o.base.batch = batch;
        let cfgs = grid(&o, &presets);
        let outs = run_many(&cfgs);
        let s = summarize(&outs, &presets, &o.tasks);
        println!(
            "{:<8} {g:>7.3} {lr:>9.2e} {mls:>7.2} {tau:>7.4} {t0:>6.3} {batch:>6} | {:>6.0}±{:<3.0} {:>7.0}±{:<3.0}",
            format!("params{}", p + 1),
            s[0].1, s[0].2, s[1].1, s[1].2
        );
        rows.push((p as f64, s[0].1, s[1].1));
    }
    let mut a = Series::new("fp32");
    let mut b = Series::new("fp16_ours");
    for (x, f32_, f16_) in rows {
        a.push(x, f32_);
        b.push(x, f16_);
    }
    write_csv(&opts.out("table7").join("random_hparams.csv"), &[a, b])?;
    Ok(())
}
