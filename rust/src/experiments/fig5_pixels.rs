//! Figure 5 (+ Figure 10): RL from pixels — fp32 vs fp16+ours with the
//! convolutional encoder, weight standardization, and the layer-norm
//! downscale guard. (Figure 10's fp32-without-weight-std baseline is the
//! same fp32 preset: the fp32 agent never enables the guard.)

use super::helpers::{run_grid_and_report, ExpOpts};

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let mut opts = opts.clone();
    opts.base.pixels = true;
    // scaled-down pixel defaults unless the caller overrode them
    if opts.base.steps == crate::config::RunConfig::default().steps {
        opts.base.steps = 1500;
        opts.base.eval_every = 500;
    }
    let presets = ["fp32", "fp16_ours", "fp16_naive"];
    run_grid_and_report(
        &opts,
        "fig5",
        &presets,
        "Figure 5 — RL from pixels, fp32 vs fp16(ours) (naive shown for contrast):",
    )?;
    Ok(())
}
