//! Tables 2/10 (time per minibatch) and Tables 3/11 (memory), as a
//! function of network width and batch size, fp32 vs fp16(ours).
//!
//! Substitution note (README.md): the paper measures V100 CUDA
//! kernels where fp16 halves both time and memory. Here fp16 is
//! *software-simulated* on CPU, so wall-clock cannot reproduce literally;
//! we report (a) measured CPU ms (simulation overhead called out), (b)
//! the analytic byte model (real ~2× savings — Table 3 reproduces), and
//! (c) an arithmetic-cost model (bytes moved per MAC) whose ratio
//! recovers the paper's ≥2× speedup trend on bandwidth-bound hardware.

use super::helpers::ExpOpts;
use crate::lowp::Precision;
use crate::nn::{pixels_model, states_model};
use crate::rngs::Pcg64;
use crate::sac::{Batch, Methods, SacAgent, SacConfig};
use crate::nn::Tensor;
use std::time::Instant;

fn synth_batch(b: usize, obs_shape: &[usize], a: usize, rng: &mut Pcg64) -> Batch {
    let mut shape = vec![b];
    shape.extend_from_slice(obs_shape);
    let mut obs = Tensor::zeros(&shape);
    rng.normal_fill(&mut obs.data);
    let mut next_obs = obs.clone();
    rng.normal_fill(&mut next_obs.data);
    let mut act = Tensor::zeros(&[b, a]);
    for v in act.data.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    Batch {
        obs,
        act,
        rew: (0..b).map(|_| rng.uniform_f32()).collect(),
        next_obs,
        not_done: vec![1.0; b],
    }
}

fn time_updates(agent: &mut SacAgent, batch: &Batch, iters: usize) -> f64 {
    // warm start (paper: 500 warm + 500 timed; scaled down)
    for _ in 0..iters / 4 {
        agent.update(batch);
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        agent.update(batch);
    }
    t0.elapsed().as_secs_f64() * 1000.0 / iters as f64
}

/// Cost model: ms ∝ bytes touched per update (bandwidth-bound regime the
/// paper's V100 numbers live in at these sizes).
fn model_ratio(params: usize, acts_per_sample: usize, batch: usize) -> f64 {
    let fp32 = (4 * (4 * params) + 4 * acts_per_sample * batch) as f64;
    let fp16 = (2 * (4 * params + 2 * params) + 2 * acts_per_sample * batch) as f64;
    fp32 / fp16
}

pub fn run_speed(opts: &ExpOpts, pixels: bool) -> anyhow::Result<()> {
    let (name, combos): (&str, Vec<(usize, usize)>) = if pixels {
        // (filters, batch) — scaled from the paper's 32/64 × 512/1024
        ("Table 2 (pixels)", vec![(4, 8), (4, 16), (8, 8), (8, 16)])
    } else {
        // (hidden, batch) — scaled from 1024/4096 × 1024/4096
        ("Table 10 (states)", vec![(128, 64), (128, 256), (512, 64), (512, 256)])
    };
    let iters = if pixels { 4 } else { 20 };
    println!("{name} — ms per minibatch (CPU; fp16 is software-simulated, see README.md):");
    println!(
        "{:<14} {:>10} {:>12} {:>10} {:>12}",
        "width/bsize", "fp32 ms", "fp16sim ms", "meas.ratio", "model.ratio"
    );
    for (width, bsize) in combos {
        let mut rng = Pcg64::seed(1);
        let mk = |prec: Precision, methods: Methods, rng_seed: u64| -> (SacAgent, Batch) {
            let mut r = Pcg64::seed(rng_seed);
            if pixels {
                let cfg = SacConfig::pixels(opts.base.feature_dim, 2, opts.base.hidden);
                let img = opts.base.image_size;
                let agent = SacAgent::new_pixels(cfg, methods, prec, 3, 9, img, width);
                let b = synth_batch(bsize, &[9, img, img], 2, &mut r);
                (agent, b)
            } else {
                let cfg = SacConfig::states(17, 6, width);
                let agent = SacAgent::new(cfg, methods, prec, 3);
                let b = synth_batch(bsize, &[17], 6, &mut r);
                (agent, b)
            }
        };
        let (mut a32, b32) = mk(Precision::Fp32, Methods::none(), 5);
        let ms32 = time_updates(&mut a32, &b32, iters);
        let (mut a16, b16) = mk(Precision::fp16(), Methods::ours(), 5);
        let ms16 = time_updates(&mut a16, &b16, iters);
        let mm = if pixels {
            pixels_model(opts.base.image_size, 9, width, opts.base.feature_dim, opts.base.hidden, 2)
        } else {
            states_model(17, 6, width)
        };
        let mr = model_ratio(mm.params, mm.activations_per_sample, bsize);
        println!(
            "{:<14} {ms32:>10.2} {ms16:>12.2} {:>10.2} {mr:>12.2}",
            format!("{width}/{bsize}"),
            ms32 / ms16
        );
        let _ = rng.next_u64();
    }
    println!(
        "(paper Table 2: 1.22–2.18x on V100; Table 10: 0.96–4.43x — the model.ratio \
         column reproduces that regime; measured CPU ratios < 1 are the simulation tax)"
    );
    Ok(())
}

pub fn run_memory(opts: &ExpOpts, pixels: bool) -> anyhow::Result<()> {
    let (name, combos): (&str, Vec<(usize, usize)>) = if pixels {
        ("Table 3 (pixels)", vec![(32, 512), (32, 1024), (64, 512), (64, 1024)])
    } else {
        ("Table 11 (states)", vec![(1024, 1024), (1024, 4096), (4096, 1024), (4096, 4096)])
    };
    println!("{name} — training bytes (analytic model at PAPER scale):");
    println!(
        "{:<14} {:>12} {:>12} {:>12}",
        "width/bsize", "fp32 MB", "fp16 MB", "improvement"
    );
    for (width, bsize) in combos {
        let m = if pixels {
            pixels_model(84, 9, width, 50, 1024, 6)
        } else {
            states_model(17, 6, width)
        };
        let f32_mb = m.training_bytes(bsize, 4) as f64 / 1e6;
        let mut m16 = m;
        let f16_mb = m16.training_bytes(bsize, 2) as f64 / 1e6;
        let imp = m16.improvement(bsize, true);
        let f32_nb = {
            m16.kahan_elems = 0;
            m16.training_bytes(bsize, 4) as f64 / 1e6
        };
        println!("{:<14} {f32_nb:>12.1} {f16_mb:>12.1} {imp:>12.2}", format!("{width}/{bsize}"));
        let _ = f32_mb;
    }
    println!(
        "(paper Table 3: 1.86–1.89x; Table 11: 1.53–1.73x — the Kahan compensation \
         buffers are what keeps it below 2x, exactly as the model shows)"
    );
    let _ = opts;
    Ok(())
}
