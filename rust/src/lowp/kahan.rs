//! Kahan (compensated) summation in simulated low precision.
//!
//! Used by two of the paper's six methods:
//! * **Kahan-momentum** (§3, method 4): the target network's EMA update
//!   `ψ̂ ← ψ̂ + (1-β)(ψ - ψ̂)` adds a tiny increment to a large
//!   accumulator every step — fp16 swallows it. The paper additionally
//!   scales the accumulated buffer by a constant `C` (1e4) to keep the
//!   increments out of the subnormal range.
//! * **Kahan-gradients** (§3, method 6): the parameter update
//!   `θ ← θ + Δθ` has the same structure.
//!
//! Algorithm 2 of the paper, with every operation rounded into the target
//! format:
//! ```text
//! y = delta - c;  t = s + y;  c = (t - s) - y;  s = t
//! ```

use super::precision::Precision;

/// A single compensated accumulator (used for scalar state like the
/// entropy temperature α).
#[derive(Debug, Clone)]
pub struct KahanScalar {
    sum: f32,
    comp: f32,
    prec: Precision,
}

impl KahanScalar {
    pub fn new(init: f32, prec: Precision) -> Self {
        KahanScalar { sum: prec.q(init), comp: 0.0, prec }
    }

    #[inline]
    pub fn value(&self) -> f32 {
        self.sum
    }

    /// Overwrite the accumulated value, resetting compensation.
    pub fn set(&mut self, v: f32) {
        self.sum = self.prec.q(v);
        self.comp = 0.0;
    }

    /// Add `delta` with compensation; all arithmetic in the target format.
    #[inline]
    pub fn add(&mut self, delta: f32) {
        let p = self.prec;
        let y = p.q(delta - self.comp);
        let t = p.q(self.sum + y);
        self.comp = p.q(p.q(t - self.sum) - y);
        self.sum = t;
    }
}

/// A vector of compensated accumulators sharing one compensation buffer —
/// the shape the paper's Kahan-gradients / Kahan-momentum take over
/// network parameter tensors.
#[derive(Debug, Clone)]
pub struct KahanVec {
    sum: Vec<f32>,
    comp: Vec<f32>,
    prec: Precision,
}

impl KahanVec {
    /// Wrap an existing parameter vector. `prec` governs the rounding of
    /// every internal operation.
    pub fn new(init: &[f32], prec: Precision) -> Self {
        let mut sum = init.to_vec();
        prec.q_slice(&mut sum);
        KahanVec { comp: vec![0.0; init.len()], sum, prec }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.sum.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.sum.is_empty()
    }

    /// The accumulated values.
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.sum
    }

    /// Mutable access for checkpoint restore; resets compensation.
    pub fn restore(&mut self, values: &[f32], comp: &[f32]) {
        self.sum.copy_from_slice(values);
        self.comp.copy_from_slice(comp);
    }

    /// The compensation buffer (for checkpointing).
    pub fn compensation(&self) -> &[f32] {
        &self.comp
    }

    /// Compensated `sum[i] += delta[i]` for all i.
    pub fn add(&mut self, delta: &[f32]) {
        assert_eq!(delta.len(), self.sum.len());
        let p = self.prec;
        for i in 0..self.sum.len() {
            let y = p.q(delta[i] - self.comp[i]);
            let t = p.q(self.sum[i] + y);
            self.comp[i] = p.q(p.q(t - self.sum[i]) - y);
            self.sum[i] = t;
        }
    }

    /// Plain (uncompensated) add in the same precision — the baseline the
    /// ablation (paper Fig. 3 "kahan grad" step) compares against.
    pub fn add_uncompensated(&mut self, delta: &[f32]) {
        assert_eq!(delta.len(), self.sum.len());
        let p = self.prec;
        for i in 0..self.sum.len() {
            self.sum[i] = p.q(self.sum[i] + delta[i]);
        }
    }

    /// Memory footprint in bytes under the given storage width (the
    /// compensation buffer is what Kahan costs; the paper notes this is
    /// offset by halving the parameter storage).
    pub fn footprint_bytes(&self, bytes_per_elem: usize) -> usize {
        2 * self.sum.len() * bytes_per_elem
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::FP16;
    use crate::rngs::Pcg64;

    #[test]
    fn scalar_kahan_beats_plain_summation() {
        // add 1e-3 to 10.0 four thousand times in fp16: plain summation
        // stalls (10 + 0.001 rounds back to ~10 once the ulp at 16 is
        // 0.0156 > 2*delta... it actually stalls at 10.24), Kahan tracks.
        let prec = Precision::sim(FP16);
        let mut k = KahanScalar::new(10.0, prec);
        let mut plain = 10.0f32;
        let delta = 1e-3f32;
        for _ in 0..4000 {
            k.add(delta);
            plain = FP16.quantize(plain + delta);
        }
        let truth = 10.0 + 4000.0 * 1e-3; // 14.0
        assert!((k.value() - truth).abs() < 0.05, "kahan={}", k.value());
        assert!((plain - truth).abs() > 1.0, "plain={plain} unexpectedly good");
    }

    #[test]
    fn vector_kahan_tracks_ema_target_update() {
        // the exact computation from the paper: psi_hat += (1-beta)(psi-psi_hat)
        // with beta=0.995 tau-style increments, in fp16.
        let prec = Precision::sim(FP16);
        let tau = 0.005f32;
        let psi = vec![1.0f32; 64];
        let mut hat = KahanVec::new(&vec![0.0f32; 64], prec);
        let mut plain = vec![0.0f32; 64];
        for _ in 0..3000 {
            let delta: Vec<f32> = hat
                .values()
                .iter()
                .zip(&psi)
                .map(|(&h, &p)| FP16.quantize(tau * FP16.quantize(p - h)))
                .collect();
            hat.add(&delta);
            for i in 0..plain.len() {
                let d = FP16.quantize(tau * FP16.quantize(psi[i] - plain[i]));
                plain[i] = FP16.quantize(plain[i] + d);
            }
        }
        // after 3000 steps of tau=0.005 the EMA should be ~1 - (1-tau)^3000 ≈ 1
        let k_err = (hat.values()[0] - 1.0).abs();
        let p_err = (plain[0] - 1.0).abs();
        assert!(k_err < 0.01, "kahan err {k_err}");
        assert!(p_err > k_err, "plain err {p_err} vs kahan {k_err}");
    }

    #[test]
    fn fp32_kahan_matches_f64_reference() {
        let prec = Precision::Fp32;
        let mut rng = Pcg64::seed(1);
        let mut k = KahanScalar::new(0.0, prec);
        let mut truth = 0.0f64;
        for _ in 0..100_000 {
            let d = rng.uniform_in(-1e-4, 1e-4);
            k.add(d);
            truth += d as f64;
        }
        assert!((k.value() as f64 - truth).abs() < 1e-6);
    }

    #[test]
    fn uncompensated_matches_manual_loop() {
        let prec = Precision::sim(FP16);
        let mut v = KahanVec::new(&[1.0, 2.0], prec);
        v.add_uncompensated(&[0.5, -0.5]);
        assert_eq!(v.values(), &[1.5, 1.5]);
    }

    #[test]
    fn footprint_accounts_for_compensation() {
        let v = KahanVec::new(&vec![0.0; 100], Precision::sim(FP16));
        assert_eq!(v.footprint_bytes(2), 400); // sum + comp at 2 bytes each
    }

    #[test]
    fn restore_roundtrip() {
        let prec = Precision::sim(FP16);
        let mut v = KahanVec::new(&[1.0, 2.0, 3.0], prec);
        v.add(&[0.1, 0.1, 0.1]);
        let (vals, comp) = (v.values().to_vec(), v.compensation().to_vec());
        let mut w = KahanVec::new(&[0.0, 0.0, 0.0], prec);
        w.restore(&vals, &comp);
        v.add(&[0.01; 3]);
        w.add(&[0.01; 3]);
        assert_eq!(v.values(), w.values());
    }
}
