//! Low-precision numeric formats and numerics utilities.
//!
//! This module is the foundation of the reproduction: a software simulator
//! for reduced-precision floating point (the role qtorch plays in the
//! paper), plus the numerically careful primitives the paper's six
//! modifications rely on (`hypot`, Kahan summation).
//!
//! Simulation model: values are carried in `f32`, and every simulated
//! operation rounds its result into the target [`FloatFormat`] — i.e.
//! "compute high, round after each op", exactly the semantics qtorch
//! (Zhang et al., 2019) implements and the paper uses for Figure 4. For
//! the IEEE binary16 format this matches true fp16 arithmetic for every
//! individual operation (each f32 op result rounded to fp16 equals the
//! correctly-rounded fp16 op result, since f32 carries more than 2×(10+2)
//! bits of precision — Figueroa, 1995).

pub mod format;
pub mod half;
mod hypot;
mod kahan;
mod precision;

pub use format::{
    bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits, FloatFormat,
    OverflowMode, RoundMode,
};
pub use half::{HalfFormat, HalfTensor};
pub use hypot::{hypot_naive, hypot_stable};
pub use kahan::{KahanScalar, KahanVec};
pub use precision::Precision;

/// IEEE binary16 (half precision): 5 exponent bits, 10 significand bits.
pub const FP16: FloatFormat = FloatFormat::new(5, 10);
/// bfloat16: 8 exponent bits, 7 significand bits.
pub const BF16: FloatFormat = FloatFormat::new(8, 7);

/// The e5mX family swept in the paper's Figure 4 (5 exponent bits, X
/// significand bits, X ∈ {5, ..., 10}).
pub const fn e5m(man_bits: u8) -> FloatFormat {
    FloatFormat::new(5, man_bits)
}
