//! The precision policy threaded through every numeric component.
//!
//! `Precision::Fp32` is native IEEE single (the paper's baseline);
//! `Precision::Sim(fmt)` rounds the result of every simulated operation
//! into `fmt` — fp16 for the paper's main experiments, e5mX for Figure 4.

use super::format::{FloatFormat, OverflowMode, RoundMode};

/// Precision policy for a computation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Precision {
    /// Native f32: quantization is the identity.
    Fp32,
    /// Simulated low precision: round every op result into the format.
    Sim {
        fmt: FloatFormat,
        round: RoundMode,
        overflow: OverflowMode,
    },
}

impl Precision {
    /// Simulated precision with IEEE defaults (RNE, overflow→∞).
    pub const fn sim(fmt: FloatFormat) -> Self {
        Precision::Sim {
            fmt,
            round: RoundMode::NearestEven,
            overflow: OverflowMode::Infinity,
        }
    }

    /// The fp16 policy used throughout the paper's main experiments.
    pub const fn fp16() -> Self {
        Precision::sim(crate::lowp::FP16)
    }

    /// True if this policy actually rounds (i.e. is not plain f32).
    #[inline]
    pub fn is_low(&self) -> bool {
        !matches!(self, Precision::Fp32)
    }

    /// The underlying format, if simulated.
    pub fn format(&self) -> Option<FloatFormat> {
        match self {
            Precision::Fp32 => None,
            Precision::Sim { fmt, .. } => Some(*fmt),
        }
    }

    /// Bytes used to *store* one element under this policy (what the
    /// memory tables count): 4 for f32, 2 for any simulated 16-or-fewer
    /// bit format (stored as 16-bit words, as fp16 hardware would).
    pub fn storage_bytes(&self) -> usize {
        match self {
            Precision::Fp32 => 4,
            Precision::Sim { .. } => 2,
        }
    }

    /// Quantize a scalar under this policy.
    #[inline]
    pub fn q(&self, x: f32) -> f32 {
        match self {
            Precision::Fp32 => x,
            Precision::Sim { fmt, round, overflow } => {
                debug_assert!(
                    !matches!(round, RoundMode::Stochastic),
                    "stochastic rounding needs q_with_rng"
                );
                fmt.quantize_with(x, *round, *overflow, None)
            }
        }
    }

    /// Quantize a slice in place under this policy.
    pub fn q_slice(&self, xs: &mut [f32]) {
        match self {
            Precision::Fp32 => {}
            Precision::Sim { fmt, round, overflow } => {
                for v in xs.iter_mut() {
                    *v = fmt.quantize_with(*v, *round, *overflow, None);
                }
            }
        }
    }

    /// Smallest positive subnormal of the policy's format (f32's if none).
    pub fn tiny(&self) -> f32 {
        match self {
            Precision::Fp32 => f32::from_bits(1),
            Precision::Sim { fmt, .. } => fmt.min_subnormal(),
        }
    }

    /// Largest finite value of the policy's format.
    pub fn max_value(&self) -> f32 {
        match self {
            Precision::Fp32 => f32::MAX,
            Precision::Sim { fmt, .. } => fmt.max_value(),
        }
    }

    /// Machine epsilon of the policy's format.
    pub fn epsilon(&self) -> f32 {
        match self {
            Precision::Fp32 => f32::EPSILON,
            Precision::Sim { fmt, .. } => fmt.epsilon(),
        }
    }

    /// A short name for configs/telemetry ("fp32", "e5m10", ...).
    pub fn name(&self) -> String {
        match self {
            Precision::Fp32 => "fp32".to_string(),
            Precision::Sim { fmt, .. } => {
                if (fmt.exp_bits, fmt.man_bits) == (5, 10) {
                    "fp16".to_string()
                } else if (fmt.exp_bits, fmt.man_bits) == (8, 7) {
                    "bf16".to_string()
                } else {
                    format!("e{}m{}", fmt.exp_bits, fmt.man_bits)
                }
            }
        }
    }

    /// Parse a precision name ("fp32", "fp16", "bf16", "e5m7", ...).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "fp32" | "f32" => Some(Precision::Fp32),
            "fp16" | "f16" | "half" => Some(Precision::fp16()),
            "bf16" => Some(Precision::sim(crate::lowp::BF16)),
            _ => {
                // eXmY grammar
                let s = s.strip_prefix('e')?;
                let (e, m) = s.split_once('m')?;
                let e: u8 = e.parse().ok()?;
                let m: u8 = m.parse().ok()?;
                if (2..=8).contains(&e) && m <= 23 {
                    Some(Precision::sim(FloatFormat::new(e, m)))
                } else {
                    None
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::{FP16, e5m};

    #[test]
    fn fp32_is_identity() {
        let p = Precision::Fp32;
        assert_eq!(p.q(1e-30), 1e-30);
        assert!(!p.is_low());
        assert_eq!(p.storage_bytes(), 4);
    }

    #[test]
    fn fp16_policy_rounds() {
        let p = Precision::fp16();
        assert_eq!(p.q(1e-9), 0.0);
        assert_eq!(p.q(1e6), f32::INFINITY);
        assert!(p.is_low());
        assert_eq!(p.storage_bytes(), 2);
        assert_eq!(p.format(), Some(FP16));
    }

    #[test]
    fn names_roundtrip() {
        for s in ["fp32", "fp16", "bf16", "e5m7", "e5m5", "e4m3"] {
            let p = Precision::parse(s).unwrap();
            assert_eq!(p.name(), s, "{s}");
        }
        assert!(Precision::parse("garbage").is_none());
        assert!(Precision::parse("e9m2").is_none());
    }

    #[test]
    fn e5m_matches_sim() {
        let p = Precision::sim(e5m(7));
        assert_eq!(p.name(), "e5m7");
        // e5m7 epsilon = 2^-7
        assert_eq!(p.epsilon(), 0.0078125);
    }

    #[test]
    fn q_slice_applies_elementwise() {
        let p = Precision::fp16();
        let mut xs = vec![1.0, 1e-9, 1e9, -2.5];
        p.q_slice(&mut xs);
        assert_eq!(xs, vec![1.0, 0.0, f32::INFINITY, -2.5]);
    }
}
