//! The precision policy threaded through every numeric component.
//!
//! `Precision::Fp32` is native IEEE single (the paper's baseline);
//! `Precision::Sim(fmt)` rounds the result of every simulated operation
//! into `fmt` — fp16 for the paper's main experiments, e5mX for Figure 4.

use super::format::{FloatFormat, OverflowMode, RoundMode};

/// Precision policy for a computation.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Precision {
    /// Native f32: quantization is the identity.
    #[default]
    Fp32,
    /// Simulated low precision: round every op result into the format.
    Sim {
        fmt: FloatFormat,
        round: RoundMode,
        overflow: OverflowMode,
    },
}

impl Precision {
    /// Simulated precision with IEEE defaults (RNE, overflow→∞).
    pub const fn sim(fmt: FloatFormat) -> Self {
        Precision::Sim {
            fmt,
            round: RoundMode::NearestEven,
            overflow: OverflowMode::Infinity,
        }
    }

    /// The fp16 policy used throughout the paper's main experiments.
    pub const fn fp16() -> Self {
        Precision::sim(crate::lowp::FP16)
    }

    /// True if this policy actually rounds (i.e. is not plain f32).
    #[inline]
    pub fn is_low(&self) -> bool {
        !matches!(self, Precision::Fp32)
    }

    /// The underlying format, if simulated.
    pub fn format(&self) -> Option<FloatFormat> {
        match self {
            Precision::Fp32 => None,
            Precision::Sim { fmt, .. } => Some(*fmt),
        }
    }

    /// Bytes used to *store* one element under this policy (what the
    /// memory tables count): 4 for f32, 2 for any simulated 16-or-fewer
    /// bit format (stored as 16-bit words, as fp16 hardware would).
    pub fn storage_bytes(&self) -> usize {
        match self {
            Precision::Fp32 => 4,
            Precision::Sim { .. } => 2,
        }
    }

    /// Quantize a scalar under this policy.
    #[inline]
    pub fn q(&self, x: f32) -> f32 {
        match self {
            Precision::Fp32 => x,
            Precision::Sim { fmt, round, overflow } => {
                debug_assert!(
                    !matches!(round, RoundMode::Stochastic),
                    "stochastic rounding needs q_with_rng"
                );
                fmt.quantize_with(x, *round, *overflow, None)
            }
        }
    }

    /// Quantize a slice in place under this policy.
    ///
    /// The round/overflow-mode dispatch happens **once per slice**, not
    /// once per element: the IEEE-default combination (round-to-nearest-
    /// even, overflow→∞) — which every paper configuration uses — runs
    /// the pure-integer [`FloatFormat::quantize_slice`] bit path over the
    /// whole slice; only the exotic combinations take the general f64
    /// route. Elementwise results are bitwise identical to calling
    /// [`Precision::q`] / `quantize_with` per element (tested).
    pub fn q_slice(&self, xs: &mut [f32]) {
        match self {
            Precision::Fp32 => {}
            Precision::Sim {
                fmt,
                round: RoundMode::NearestEven,
                overflow: OverflowMode::Infinity,
            } => fmt.quantize_slice(xs),
            Precision::Sim { fmt, round, overflow } => {
                debug_assert!(
                    !matches!(round, RoundMode::Stochastic),
                    "stochastic rounding needs an RNG; use quantize_with per element"
                );
                for v in xs.iter_mut() {
                    *v = fmt.quantize_with(*v, *round, *overflow, None);
                }
            }
        }
    }

    /// Smallest positive subnormal of the policy's format (f32's if none).
    pub fn tiny(&self) -> f32 {
        match self {
            Precision::Fp32 => f32::from_bits(1),
            Precision::Sim { fmt, .. } => fmt.min_subnormal(),
        }
    }

    /// Largest finite value of the policy's format.
    pub fn max_value(&self) -> f32 {
        match self {
            Precision::Fp32 => f32::MAX,
            Precision::Sim { fmt, .. } => fmt.max_value(),
        }
    }

    /// Machine epsilon of the policy's format.
    pub fn epsilon(&self) -> f32 {
        match self {
            Precision::Fp32 => f32::EPSILON,
            Precision::Sim { fmt, .. } => fmt.epsilon(),
        }
    }

    /// A short name for configs/telemetry ("fp32", "e5m10", ...).
    pub fn name(&self) -> String {
        match self {
            Precision::Fp32 => "fp32".to_string(),
            Precision::Sim { fmt, .. } => {
                if (fmt.exp_bits, fmt.man_bits) == (5, 10) {
                    "fp16".to_string()
                } else if (fmt.exp_bits, fmt.man_bits) == (8, 7) {
                    "bf16".to_string()
                } else {
                    format!("e{}m{}", fmt.exp_bits, fmt.man_bits)
                }
            }
        }
    }

    /// Parse a precision name ("fp32", "fp16", "bf16", "e5m7", ...).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "fp32" | "f32" => Some(Precision::Fp32),
            "fp16" | "f16" | "half" => Some(Precision::fp16()),
            "bf16" => Some(Precision::sim(crate::lowp::BF16)),
            _ => {
                // eXmY grammar
                let s = s.strip_prefix('e')?;
                let (e, m) = s.split_once('m')?;
                let e: u8 = e.parse().ok()?;
                let m: u8 = m.parse().ok()?;
                if (2..=8).contains(&e) && m <= 23 {
                    Some(Precision::sim(FloatFormat::new(e, m)))
                } else {
                    None
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::{FP16, e5m};

    #[test]
    fn fp32_is_identity() {
        let p = Precision::Fp32;
        assert_eq!(p.q(1e-30), 1e-30);
        assert!(!p.is_low());
        assert_eq!(p.storage_bytes(), 4);
    }

    #[test]
    fn fp16_policy_rounds() {
        let p = Precision::fp16();
        assert_eq!(p.q(1e-9), 0.0);
        assert_eq!(p.q(1e6), f32::INFINITY);
        assert!(p.is_low());
        assert_eq!(p.storage_bytes(), 2);
        assert_eq!(p.format(), Some(FP16));
    }

    #[test]
    fn names_roundtrip() {
        for s in ["fp32", "fp16", "bf16", "e5m7", "e5m5", "e4m3"] {
            let p = Precision::parse(s).unwrap();
            assert_eq!(p.name(), s, "{s}");
        }
        assert!(Precision::parse("garbage").is_none());
        assert!(Precision::parse("e9m2").is_none());
    }

    #[test]
    fn e5m_matches_sim() {
        let p = Precision::sim(e5m(7));
        assert_eq!(p.name(), "e5m7");
        // e5m7 epsilon = 2^-7
        assert_eq!(p.epsilon(), 0.0078125);
    }

    #[test]
    fn q_slice_applies_elementwise() {
        let p = Precision::fp16();
        let mut xs = vec![1.0, 1e-9, 1e9, -2.5];
        p.q_slice(&mut xs);
        assert_eq!(xs, vec![1.0, 0.0, f32::INFINITY, -2.5]);
    }

    /// Values that stress every quantizer branch: ties, subnormals,
    /// near-overflow, signed zero, infinities.
    fn edge_values(rng: &mut crate::rngs::Pcg64, n: usize) -> Vec<f32> {
        let mut xs = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            65504.0,
            65519.0,
            65520.0,
            1e6,
            -1e6,
            1e-9,
            -1e-9,
            6.1035156e-5,
            5.9604645e-8,
            2.9802322e-8,
            1.0 + 4.8828125e-4,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            f32::from_bits(1),
            1e-40,
            -1e-40,
        ];
        for _ in 0..n {
            let v = f32::from_bits(rng.next_u32());
            if !v.is_nan() {
                xs.push(v);
            }
        }
        xs
    }

    /// Acceptance check: fp16 `q_slice` (slice bit path) is bitwise
    /// identical to per-element `quantize` / `q`.
    #[test]
    fn q_slice_bitwise_matches_per_element_quantize_fp16() {
        let mut rng = crate::rngs::Pcg64::seed(17);
        let xs = edge_values(&mut rng, 50_000);
        let p = Precision::fp16();
        let mut got = xs.clone();
        p.q_slice(&mut got);
        for (x, g) in xs.iter().zip(&got) {
            let per_elem = FP16.quantize(*x);
            assert_eq!(
                g.to_bits(),
                per_elem.to_bits(),
                "x={x:e}: slice={g:e} elem={per_elem:e}"
            );
            assert_eq!(g.to_bits(), p.q(*x).to_bits(), "x={x:e} vs Precision::q");
        }
    }

    /// `q_slice` agrees with per-element `quantize_with` for every
    /// deterministic round/overflow combination and several formats.
    #[test]
    fn q_slice_matches_quantize_with_across_modes() {
        use crate::lowp::BF16;
        let mut rng = crate::rngs::Pcg64::seed(23);
        let xs = edge_values(&mut rng, 20_000);
        let rounds = [RoundMode::NearestEven, RoundMode::TowardZero];
        let overflows = [OverflowMode::Infinity, OverflowMode::Saturate];
        for fmt in [FP16, BF16, e5m(7), e5m(5), FloatFormat::new(4, 3)] {
            for round in rounds {
                for overflow in overflows {
                    let p = Precision::Sim { fmt, round, overflow };
                    let mut got = xs.clone();
                    p.q_slice(&mut got);
                    for (x, g) in xs.iter().zip(&got) {
                        let want = fmt.quantize_with(*x, round, overflow, None);
                        assert_eq!(
                            g.to_bits(),
                            want.to_bits(),
                            "fmt=e{}m{} {round:?}/{overflow:?} x={x:e}: {g:e} vs {want:e}",
                            fmt.exp_bits,
                            fmt.man_bits
                        );
                    }
                }
            }
        }
    }
}
