//! Parameterized floating-point formats with bit-exact quantization.
//!
//! A [`FloatFormat`] describes a binary floating-point format in the IEEE
//! 754 style: 1 sign bit, `exp_bits` exponent bits (biased by
//! `2^(exp_bits-1) - 1`), and `man_bits` explicit significand bits, with
//! gradual underflow (subnormals), signed zero, ±∞ and NaN.
//! [`FloatFormat::quantize`] rounds an `f32` to the nearest value
//! representable in the format, which is the primitive the whole
//! low-precision simulation is built on.

use crate::rngs::Pcg64;

/// Rounding mode used when quantizing into a [`FloatFormat`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Round to nearest, ties to even — IEEE default, used everywhere in
    /// the paper unless stated otherwise.
    NearestEven,
    /// Round toward zero (truncation).
    TowardZero,
    /// Stochastic rounding: round up with probability equal to the
    /// fractional position between the two neighbouring representables.
    Stochastic,
}

/// What to do when a value exceeds the format's largest finite value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverflowMode {
    /// IEEE behaviour: overflow to ±∞ (what fp16 hardware does and what
    /// the paper's "overflow" failures are about).
    Infinity,
    /// Saturate to ±max finite value (the "numeric coercion" baseline of
    /// the paper's Figure 1 coerces ∞ to the largest representable value).
    Saturate,
}

/// A binary floating-point format: 1 sign bit, `exp_bits` exponent bits,
/// `man_bits` explicit significand bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FloatFormat {
    /// Number of exponent bits (2..=8).
    pub exp_bits: u8,
    /// Number of explicit significand (mantissa) bits (0..=23).
    pub man_bits: u8,
}

impl FloatFormat {
    /// Construct a format. `exp_bits` must be in 2..=8 and `man_bits` in
    /// 0..=23 (checked in debug builds; `quantize` is only meaningful in
    /// that range because values are carried in `f32`).
    pub const fn new(exp_bits: u8, man_bits: u8) -> Self {
        FloatFormat { exp_bits, man_bits }
    }

    /// Exponent bias: `2^(exp_bits-1) - 1` (15 for fp16, 127 for fp32).
    #[inline]
    pub const fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Largest unbiased exponent of a normal number (15 for fp16).
    #[inline]
    pub const fn emax(&self) -> i32 {
        self.bias()
    }

    /// Smallest unbiased exponent of a normal number (-14 for fp16).
    #[inline]
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }

    /// Largest finite value: `2^emax * (2 - 2^-man_bits)` (65504 for fp16).
    #[inline]
    pub fn max_value(&self) -> f32 {
        let ulp = (self.emax() - self.man_bits as i32) as f64;
        ((2f64.powi(self.emax() + 1)) - 2f64.powi(ulp as i32)) as f32
    }

    /// Smallest positive normal value: `2^emin` (6.1035e-5 for fp16).
    #[inline]
    pub fn min_normal(&self) -> f32 {
        2f64.powi(self.emin()) as f32
    }

    /// Smallest positive subnormal value: `2^(emin - man_bits)`
    /// (5.96e-8 for fp16).
    #[inline]
    pub fn min_subnormal(&self) -> f32 {
        2f64.powi(self.emin() - self.man_bits as i32) as f32
    }

    /// Machine epsilon: spacing between 1.0 and the next representable
    /// value, `2^-man_bits` (9.77e-4 for fp16).
    #[inline]
    pub fn epsilon(&self) -> f32 {
        2f64.powi(-(self.man_bits as i32)) as f32
    }

    /// Round `x` into this format with round-to-nearest-even and IEEE
    /// overflow-to-infinity. The result is returned as the exactly
    /// representable `f32`.
    ///
    /// This is the hot path of the whole low-precision simulation (every
    /// tensor op ends here), so it uses a pure integer bit-manipulation
    /// RNE — no f64, no transcendentals. The slower, more general f64
    /// reference path lives in [`FloatFormat::quantize_with`] and the two
    /// are cross-checked exhaustively in the tests.
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        quantize_rne_bits(x, self.exp_bits, self.man_bits)
    }

    /// Round `x` into this format with explicit rounding and overflow
    /// behaviour. `rng` is required only for [`RoundMode::Stochastic`].
    ///
    /// The RNE + overflow-to-∞ combination dispatches to the fast bit
    /// path; everything else takes the general f64 route.
    pub fn quantize_with(
        &self,
        x: f32,
        round: RoundMode,
        overflow: OverflowMode,
        rng: Option<&mut Pcg64>,
    ) -> f32 {
        if matches!(round, RoundMode::NearestEven) && matches!(overflow, OverflowMode::Infinity) {
            return quantize_rne_bits(x, self.exp_bits, self.man_bits);
        }
        if x == 0.0 || x.is_nan() {
            return x; // preserves signed zero and NaN
        }
        if x.is_infinite() {
            return match overflow {
                OverflowMode::Infinity => x,
                OverflowMode::Saturate => self.max_value().copysign(x),
            };
        }

        // Work in f64: the f32 -> f64 conversion is exact, and f64 has
        // enough precision that `(ax / ulp)` below is exact for every
        // format with man_bits <= 23.
        let xd = x as f64;
        let ax = xd.abs();

        // Unbiased exponent of ax. f32 subnormals become normal f64s, so
        // reading the f64 exponent field is always correct here.
        let bits = ax.to_bits();
        let e = ((bits >> 52) & 0x7ff) as i32 - 1023;

        // Spacing of representables around ax: 2^(e - man) in the normal
        // range, flat 2^(emin - man) in the subnormal range.
        let ulp_exp = if e < self.emin() {
            self.emin() - self.man_bits as i32
        } else {
            e - self.man_bits as i32
        };
        let ulp = 2f64.powi(ulp_exp);

        let steps = ax / ulp; // exact: ax has <= 53 significant bits
        let rounded_steps = match round {
            RoundMode::NearestEven => round_ties_even(steps),
            RoundMode::TowardZero => steps.floor(),
            RoundMode::Stochastic => {
                let lo = steps.floor();
                let frac = steps - lo;
                // tidy-allow(panic): misconfiguration — stochastic rounding
                // without an RNG stream cannot produce a defined result.
                let u = rng.expect("stochastic rounding requires an RNG").uniform_f64();
                if u < frac {
                    lo + 1.0
                } else {
                    lo
                }
            }
        };
        let q = rounded_steps * ulp;

        // Overflow check: the largest finite magnitude is
        // 2^emax * (2 - 2^-man). Anything that rounded past it becomes
        // ±inf (IEEE) or saturates.
        let maxv = (2f64.powi(self.emax() + 1)) - 2f64.powi(self.emax() - self.man_bits as i32);
        let out = if q > maxv {
            match overflow {
                OverflowMode::Infinity => f64::INFINITY,
                OverflowMode::Saturate => maxv,
            }
        } else {
            q
        };
        (out.copysign(xd)) as f32
    }

    /// Quantize a slice in place (round-to-nearest-even, IEEE overflow).
    ///
    /// Routed through the SIMD compute plane: on AVX2 hosts this runs the
    /// integer RNE bit-path vectorized 8 lanes at a time (bitwise equal to
    /// the scalar `quantize_rne_bits` oracle); elsewhere it falls back to
    /// the scalar loop. `LPRL_SIMD=0` forces scalar.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        crate::nn::simd::quantize_slice_rne(self.exp_bits, self.man_bits, xs);
    }

    /// True if `x` (an `f32`) is exactly representable in this format.
    pub fn is_representable(&self, x: f32) -> bool {
        x.is_nan() || self.quantize(x) == x
    }

    /// Number of finite representable values >= 0 (for diagnostics).
    pub fn finite_count_nonneg(&self) -> u64 {
        // exponent field values 0..2^e-1 are finite (all-ones = inf/nan)
        let exps = (1u64 << self.exp_bits) - 1;
        exps * (1u64 << self.man_bits)
    }
}

/// f64 round-half-to-even. (`f64::round_ties_even` is stable, but spelled
/// out here so the rounding rule is auditable against Appendix-style
/// numerics discussions.)
#[inline]
fn round_ties_even(x: f64) -> f64 {
    x.round_ties_even()
}

/// Fast RNE quantization of an f32 into `(exp_bits, man_bits)` with IEEE
/// overflow-to-∞ and gradual underflow — pure integer ops on the f32 bit
/// pattern (generalization of the classic f32→f16 conversion).
///
/// Exhaustively cross-checked against the f64 ULP-grid reference path in
/// the tests below.
#[inline]
pub fn quantize_rne_bits(x: f32, exp_bits: u8, man_bits: u8) -> f32 {
    let bits = x.to_bits();
    let sign = bits & 0x8000_0000;
    let exp_f = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;

    if exp_f == 0xff {
        return x; // ±inf and NaN pass through
    }
    if (bits & 0x7fff_ffff) == 0 {
        return x; // ±0
    }

    let bias = (1i32 << (exp_bits - 1)) - 1;
    let emax = bias;
    let emin = 1 - bias;
    let m = man_bits as i32;
    // f32 subnormal input (exp field 0): value = man · 2^-149. For narrow
    // exponent formats this is far below half the smallest target
    // subnormal (→ ±0), but e8 formats (bf16) have subnormals that
    // overlap f32's — snap onto the 2^(emin-m) grid by shifting.
    if exp_f == 0 {
        let shift2 = emin - m + 149;
        if shift2 >= 24 {
            return f32::from_bits(sign); // man < 2^23 ⇒ below half a step
        }
        if shift2 <= 0 {
            return x; // target grid is finer than f32 subnormals: exact
        }
        let half_m1 = (1u32 << (shift2 - 1)) - 1;
        let lsb = (man >> shift2) & 1;
        let rounded = (man + half_m1 + lsb) >> shift2;
        // value = rounded · 2^(emin-m) = (rounded << shift2) · 2^-149,
        // which is exactly the f32 bit pattern (incl. the carry into the
        // normal range when rounded << shift2 == 2^23).
        return f32::from_bits(sign | (rounded << shift2));
    }
    let e = exp_f - 127; // unbiased input exponent

    if e >= emin {
        // normal target range: RNE on the low (23 - m) mantissa bits
        let shift = 23 - m;
        // round-half-to-even trick: add (half - 1) + lsb-of-kept
        let half_m1 = (1u32 << (shift - 1)) - 1;
        let lsb = (man >> shift) & 1;
        let rounded = man + half_m1 + lsb;
        let carry = (rounded >> 23) & 1; // mantissa overflowed into exponent
        let new_man = (rounded >> shift) << shift & 0x7f_ffff;
        let new_e = e + carry as i32;
        if new_e > emax {
            return f32::from_bits(sign | 0x7f80_0000); // ±inf
        }
        let new_exp_f = (new_e + 127) as u32;
        f32::from_bits(sign | (new_exp_f << 23) | if carry == 1 { 0 } else { new_man })
    } else {
        // subnormal target range: effective shift grows as e drops
        let extra = emin - e; // >= 1
        let shift = 23 - m + extra;
        if shift > 24 {
            return f32::from_bits(sign); // below half the smallest subnormal
        }
        // make the implicit leading 1 explicit (24-bit significand)
        let full = man | 0x80_0000;
        if shift == 24 {
            // result is 0 or the smallest subnormal; tie at exactly 0.5
            // rounds to even (= 0)
            let half = 1u32 << 23;
            let rem = full; // everything below the kept (zero) bits
            return if rem > half {
                // smallest subnormal: 2^(emin - m)
                let v = exp2_f32(emin - m);
                f32::from_bits(sign | v.to_bits())
            } else {
                f32::from_bits(sign)
            };
        }
        let half_m1 = (1u32 << (shift - 1)) - 1;
        let lsb = (full >> shift) & 1;
        let rounded = (full + half_m1 + lsb) >> shift; // kept significand
        if rounded == 0 {
            return f32::from_bits(sign);
        }
        // value = rounded * 2^(emin - m); rounded < 2^(m+1) so this is an
        // exact integer scaled by a power of two
        let v = rounded as f32 * exp2_f32(emin - m);
        f32::from_bits(sign | v.to_bits())
    }
}

/// 2^k as f32 for k in the normal range (built via the exponent field).
#[inline]
fn exp2_f32(k: i32) -> f32 {
    debug_assert!((-126..=127).contains(&k));
    f32::from_bits(((k + 127) as u32) << 23)
}

/// Bit-exact conversion f32 -> IEEE binary16 bit pattern (RNE). Used only
/// in tests to prove `FloatFormat::new(5, 10).quantize` agrees with true
/// IEEE half precision, and by the replay buffer's compact storage.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;

    if exp == 0xff {
        // inf / nan
        return sign | 0x7c00 | if man != 0 { 0x200 } else { 0 };
    }
    exp -= 127; // unbias

    if exp > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp >= -14 {
        // normal half
        let mut m = man >> 13; // keep 10 bits
        let rem = man & 0x1fff;
        // RNE on the dropped 13 bits
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut e16 = (exp + 15) as u32;
        if m == 0x400 {
            m = 0;
            e16 += 1;
            if e16 >= 31 {
                return sign | 0x7c00;
            }
        }
        sign | ((e16 as u16) << 10) | (m as u16)
    } else if exp >= -25 {
        // subnormal half: implicit 1 becomes explicit, shifted right
        let full = man | 0x80_0000; // 24-bit significand
        let shift = (-14 - exp) + 13; // how many bits to drop
        let m = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m = m;
        if rem > half || (rem == half && (m & 1) == 1) {
            m += 1;
        }
        if m == 0x400 {
            // rounded up into the normal range
            return sign | (1 << 10);
        }
        sign | (m as u16)
    } else {
        sign // underflow to zero
    }
}

/// Bit-exact conversion f32 -> bfloat16 bit pattern (RNE). Because bf16
/// is f32 with the low 16 significand bits dropped (same exponent range,
/// so even f32 subnormals sit on the same grid), round-to-nearest-even
/// is one integer add on the f32 bit pattern; overflow lands on the
/// infinity encoding exactly as IEEE demands. NaNs are quieted so the
/// truncation cannot turn a signalling payload into an infinity.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7fff + lsb)) >> 16) as u16
}

/// Bit-exact conversion bfloat16 bit pattern -> f32 (always exact): the
/// bf16 pattern *is* the top half of the f32 pattern.
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Bit-exact conversion IEEE binary16 bit pattern -> f32 (always exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -14i32;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((e + 127) as u32) << 23) | (m << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::{BF16, FP16};
    use crate::rngs::Pcg64;

    #[test]
    fn fp16_constants_match_ieee() {
        assert_eq!(FP16.bias(), 15);
        assert_eq!(FP16.emax(), 15);
        assert_eq!(FP16.emin(), -14);
        assert_eq!(FP16.max_value(), 65504.0);
        assert!((FP16.min_normal() - 6.1035e-5).abs() < 1e-9);
        assert!((FP16.min_subnormal() - 5.9605e-8).abs() < 1e-12);
        assert!((FP16.epsilon() - 9.7656e-4).abs() < 1e-8);
    }

    #[test]
    fn bf16_constants() {
        assert_eq!(BF16.bias(), 127);
        assert_eq!(BF16.emax(), 127);
        // bf16 max = 3.3895e38
        assert!((BF16.max_value() - 3.3895314e38).abs() / 3.39e38 < 1e-4);
    }

    #[test]
    fn quantize_identity_on_representable() {
        for &v in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, 6.1035156e-5, 2.0, 1.5] {
            assert_eq!(FP16.quantize(v), v, "v={v}");
        }
    }

    #[test]
    fn quantize_agrees_with_bit_exact_f16_exhaustive_samples() {
        // Cross-check the generic simulator against the dedicated
        // bit-manipulation converter across a dense sample of magnitudes,
        // including subnormals, ties, and near-overflow values.
        let mut rng = Pcg64::seed(7);
        for _ in 0..200_000 {
            let x = f32::from_bits(rng.next_u32());
            if x.is_nan() {
                continue;
            }
            let via_bits = f16_bits_to_f32(f32_to_f16_bits(x));
            let via_fmt = FP16.quantize(x);
            assert!(
                via_bits == via_fmt || (via_bits == 0.0 && via_fmt == 0.0),
                "x={x:e} bits={via_bits:e} fmt={via_fmt:e}"
            );
        }
    }

    #[test]
    fn quantize_ties_to_even() {
        // 1 + eps/2 is a tie between 1.0 and 1+eps -> even mantissa (1.0)
        let eps = FP16.epsilon();
        assert_eq!(FP16.quantize(1.0 + eps / 2.0), 1.0);
        // 1 + 3*eps/2 ties between 1+eps and 1+2eps -> 1+2eps (even)
        assert_eq!(FP16.quantize(1.0 + 1.5 * eps), 1.0 + 2.0 * eps);
    }

    #[test]
    fn quantize_underflow_and_subnormals() {
        let sub = FP16.min_subnormal();
        // below half the smallest subnormal -> 0
        assert_eq!(FP16.quantize(sub * 0.49), 0.0);
        // between: rounds to the subnormal
        assert_eq!(FP16.quantize(sub * 0.75), sub);
        // the paper's motivating example: (1e-7)^2 underflows
        assert_eq!(FP16.quantize(1e-7f32 * 1e-7f32), 0.0);
        // sign is preserved on underflow-to-zero
        assert_eq!(FP16.quantize(-(sub * 0.25)).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn quantize_overflow_modes() {
        assert_eq!(FP16.quantize(1e6), f32::INFINITY);
        assert_eq!(FP16.quantize(-1e6), f32::NEG_INFINITY);
        let s = FP16.quantize_with(1e6, RoundMode::NearestEven, OverflowMode::Saturate, None);
        assert_eq!(s, 65504.0);
        // 65520 is the tie between 65504 and 65536(=inf): RNE -> inf
        assert_eq!(FP16.quantize(65520.0), f32::INFINITY);
        assert_eq!(FP16.quantize(65519.0), 65504.0);
    }

    #[test]
    fn toward_zero_truncates() {
        let eps = FP16.epsilon();
        let x = 1.0 + 1.9 * eps;
        assert_eq!(
            FP16.quantize_with(x, RoundMode::TowardZero, OverflowMode::Infinity, None),
            1.0 + eps
        );
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        let mut rng = Pcg64::seed(42);
        let eps = FP16.epsilon();
        let x = 1.0 + 0.25 * eps; // 25% of the way to the next value
        let n = 20_000;
        let mut ups = 0;
        for _ in 0..n {
            let q = FP16.quantize_with(x, RoundMode::Stochastic, OverflowMode::Infinity, Some(&mut rng));
            if q > 1.0 {
                ups += 1;
            }
        }
        let p = ups as f64 / n as f64;
        assert!((p - 0.25).abs() < 0.02, "p={p}");
    }

    #[test]
    fn narrower_formats_lose_values_monotonically() {
        // every value representable in e5m(k) is representable in e5m(k+1)
        let mut rng = Pcg64::seed(3);
        for _ in 0..20_000 {
            let x = (rng.uniform_f64() as f32 - 0.5) * 100.0;
            for m in 2..10u8 {
                let narrow = crate::lowp::e5m(m).quantize(x);
                assert!(
                    crate::lowp::e5m(m + 1).is_representable(narrow),
                    "m={m} x={x} narrow={narrow}"
                );
            }
        }
    }

    #[test]
    fn f16_roundtrip_all_bit_patterns() {
        // every finite f16 bit pattern must round-trip exactly
        for h in 0..=0xffffu16 {
            let f = f16_bits_to_f32(h);
            if f.is_nan() {
                continue;
            }
            let back = f32_to_f16_bits(f);
            assert_eq!(back, h, "h={h:#x} f={f:e}");
        }
    }

    #[test]
    fn finite_counts() {
        assert_eq!(FP16.finite_count_nonneg(), 31 * 1024);
    }

    /// Slow f64 ULP-grid reference (the algorithm the fast bit path
    /// replaced) — kept here as the oracle for the cross-check below.
    fn quantize_f64_ref(fmt: FloatFormat, x: f32) -> f32 {
        if x == 0.0 || !x.is_finite() {
            return x;
        }
        let xd = x as f64;
        let ax = xd.abs();
        let bits = ax.to_bits();
        let e = ((bits >> 52) & 0x7ff) as i32 - 1023;
        let ulp_exp = if e < fmt.emin() {
            fmt.emin() - fmt.man_bits as i32
        } else {
            e - fmt.man_bits as i32
        };
        let ulp = 2f64.powi(ulp_exp);
        let q = (ax / ulp).round_ties_even() * ulp;
        let maxv =
            (2f64.powi(fmt.emax() + 1)) - 2f64.powi(fmt.emax() - fmt.man_bits as i32);
        let out = if q > maxv { f64::INFINITY } else { q };
        (out.copysign(xd)) as f32
    }

    #[test]
    fn bit_path_matches_f64_reference_across_formats() {
        let mut rng = Pcg64::seed(99);
        let formats = [
            FloatFormat::new(5, 10),
            FloatFormat::new(8, 7),
            FloatFormat::new(5, 7),
            FloatFormat::new(5, 5),
            FloatFormat::new(4, 3),
            FloatFormat::new(8, 10),
            FloatFormat::new(6, 9),
            FloatFormat::new(2, 1),
        ];
        for _ in 0..300_000 {
            let x = f32::from_bits(rng.next_u32());
            if x.is_nan() {
                continue;
            }
            for fmt in formats {
                let fast = quantize_rne_bits(x, fmt.exp_bits, fmt.man_bits);
                let slow = quantize_f64_ref(fmt, x);
                assert!(
                    fast == slow || (fast == 0.0 && slow == 0.0),
                    "fmt=e{}m{} x={x:e} ({:#x}) fast={fast:e} slow={slow:e}",
                    fmt.exp_bits,
                    fmt.man_bits,
                    x.to_bits()
                );
            }
        }
        // targeted edge cases: ties, boundaries, f32 subnormals, near-max
        let edges: Vec<f32> = vec![
            65519.0, 65520.0, 65504.0, 6.1035156e-5, 5.9604645e-8, 2.9802322e-8,
            2.9802326e-8, 1.0 + 4.8828125e-4, f32::MIN_POSITIVE, f32::from_bits(1),
            f32::from_bits(0x007f_ffff), 3.389531e38, 1e-40, -1e-40,
        ];
        for x in edges {
            for fmt in formats {
                let fast = quantize_rne_bits(x, fmt.exp_bits, fmt.man_bits);
                let slow = quantize_f64_ref(fmt, x);
                assert!(
                    fast == slow || (fast == 0.0 && slow == 0.0),
                    "edge fmt=e{}m{} x={x:e} fast={fast:e} slow={slow:e}",
                    fmt.exp_bits,
                    fmt.man_bits
                );
            }
        }
    }

    #[test]
    fn bf16_bit_conversion_matches_quantizer() {
        // the packed bf16 path must agree with the generic simulator on
        // every value class: normals, subnormals, ties, near-overflow
        let mut rng = Pcg64::seed(17);
        for _ in 0..200_000 {
            let x = f32::from_bits(rng.next_u32());
            if x.is_nan() {
                continue;
            }
            let via_bits = bf16_bits_to_f32(f32_to_bf16_bits(x));
            let via_fmt = BF16.quantize(x);
            assert!(
                via_bits == via_fmt || (via_bits == 0.0 && via_fmt == 0.0),
                "x={x:e} ({:#x}) bits={via_bits:e} fmt={via_fmt:e}",
                x.to_bits()
            );
        }
        // NaN stays NaN (and stays quiet, never an infinity encoding)
        let q = f32_to_bf16_bits(f32::NAN);
        assert!(bf16_bits_to_f32(q).is_nan());
    }

    #[test]
    fn bf16_roundtrip_all_bit_patterns() {
        // every finite bf16 bit pattern must round-trip exactly
        for h in 0..=0xffffu16 {
            let f = bf16_bits_to_f32(h);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_bf16_bits(f), h, "h={h:#x} f={f:e}");
        }
    }

    #[test]
    fn bf16_keeps_f32_subnormal_overlap() {
        // 2^-130 is a bf16 subnormal (emin-m = -133): must survive, not
        // flush to zero.
        let x = 2f32.powi(-130);
        let q = BF16.quantize(x);
        assert_eq!(q, x, "bf16 subnormal must round-trip");
        // below half of 2^-133 -> 0
        assert_eq!(BF16.quantize(2f32.powi(-135)), 0.0);
    }
}
