//! `hypot(a, b) = sqrt(a² + b²)` in simulated low precision.
//!
//! The paper's hAdam (§3, method 1) replaces Adam's second-moment update
//! `v ← β₂ v + (1-β₂) g²` with an update on `w = √v` driven by `hypot`,
//! because `g²` underflows in fp16 for |g| < 2^-12 or so. The *naive*
//! hypot squares its arguments and hits exactly that underflow; the
//! *stable* form (the one the paper writes out) factors out `max(|a|,|b|)`
//! first so no intermediate leaves the representable range.

use super::format::FloatFormat;

/// Naive `sqrt(a² + b²)`, with every intermediate rounded into `fmt`.
/// Underflows/overflows exactly like a real low-precision implementation —
/// kept as the baseline the stable version is tested against.
pub fn hypot_naive(a: f32, b: f32, fmt: FloatFormat) -> f32 {
    let a2 = fmt.quantize(a * a);
    let b2 = fmt.quantize(b * b);
    let s = fmt.quantize(a2 + b2);
    fmt.quantize(s.sqrt())
}

/// Numerically stable hypot, every intermediate rounded into `fmt`:
///
/// ```text
/// hypot(a, b) = max * sqrt(1 + (min / (max + eps))²)
/// ```
///
/// with `max = max(|a|, |b|)`, `min = min(|a|, |b|)` and `eps` the
/// smallest positive subnormal of `fmt` (the paper's "add a numerical ε to
/// the denominator" so a = b = 0 is well-defined).
pub fn hypot_stable(a: f32, b: f32, fmt: FloatFormat) -> f32 {
    let aa = fmt.quantize(a.abs());
    let ab = fmt.quantize(b.abs());
    let (mx, mn) = if aa >= ab { (aa, ab) } else { (ab, aa) };
    if mx == 0.0 {
        return 0.0;
    }
    let denom = fmt.quantize(mx + fmt.min_subnormal());
    let r = fmt.quantize(mn / denom);
    let r2 = fmt.quantize(r * r);
    let s = fmt.quantize(1.0 + r2);
    let root = fmt.quantize(s.sqrt());
    fmt.quantize(mx * root)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::FP16;
    use crate::rngs::Pcg64;

    #[test]
    fn matches_true_hypot_in_normal_range() {
        let mut rng = Pcg64::seed(1);
        for _ in 0..10_000 {
            let a = rng.uniform_in(-100.0, 100.0);
            let b = rng.uniform_in(-100.0, 100.0);
            let h = hypot_stable(a, b, FP16);
            let t = (a as f64).hypot(b as f64) as f32;
            let rel = ((h - t) / t.max(1e-6)).abs();
            assert!(rel < 5e-3, "a={a} b={b} h={h} t={t}");
        }
    }

    #[test]
    fn naive_underflows_where_stable_does_not() {
        // |g| = 1e-3 is representable in fp16, but g² = 1e-6 is well below
        // the smallest subnormal (6e-8)? No: 1e-6 > 6e-8 — use 1e-4:
        // (1e-4)² = 1e-8 < 6e-8 underflows.
        let g = 1e-4f32;
        assert_eq!(hypot_naive(0.0, g, FP16), 0.0, "naive must underflow");
        let h = hypot_stable(0.0, g, FP16);
        let rel = ((h - g) / g).abs();
        assert!(rel < 1e-3, "stable hypot got {h}");
    }

    #[test]
    fn stable_does_not_overflow_for_large_inputs() {
        let a = 60000.0f32; // near fp16 max
        let h = hypot_stable(a, a, FP16);
        // true answer ~84852 overflows fp16 -> inf is correct IEEE result
        assert!(h.is_infinite());
        // but hypot(a, small) must NOT overflow the way a*a would
        let h2 = hypot_stable(a, 1.0, FP16);
        assert!((h2 - a).abs() / a < 1e-3, "h2={h2}");
        assert_eq!(hypot_naive(a, 1.0, FP16), f32::INFINITY);
    }

    #[test]
    fn zero_cases() {
        assert_eq!(hypot_stable(0.0, 0.0, FP16), 0.0);
        assert_eq!(hypot_stable(-0.0, 0.0, FP16), 0.0);
        let s = FP16.min_subnormal();
        // smallest subnormal survives
        assert!(hypot_stable(s, 0.0, FP16) > 0.0);
    }

    #[test]
    fn symmetric_and_sign_invariant() {
        let mut rng = Pcg64::seed(2);
        for _ in 0..2_000 {
            let a = rng.normal_f32() * 10.0;
            let b = rng.normal_f32() * 0.01;
            let h1 = hypot_stable(a, b, FP16);
            let h2 = hypot_stable(b, a, FP16);
            let h3 = hypot_stable(-a, b, FP16);
            assert_eq!(h1, h2);
            assert_eq!(h1, h3);
            assert!(h1 >= a.abs().max(b.abs()) * 0.999);
        }
    }
}
