//! Packed half-precision storage: the *native* memory tier the rest of
//! `lowp/` only simulates.
//!
//! [`Precision::Sim`](super::Precision) quantizes values but stores them
//! as f32, so the paper's memory/bandwidth win never materializes.
//! [`HalfTensor`] stores the bits themselves — one `u16` per element, in
//! either IEEE binary16 or bfloat16 layout — halving resident bytes and
//! memory traffic for the read-only heavyweights (frozen policy
//! snapshots, target-network parameters, packed GEMM B-operands).
//!
//! The contract that keeps this tier compatible with the simulated one:
//! `decode(encode(x))` equals `FloatFormat::quantize(x)` for the
//! matching format (property-tested in `format.rs`), widening
//! `u16 -> f32` is always exact, and a pack → unpack round trip is the
//! identity on format-representable values. Packing a tensor whose
//! values are already on the format grid is therefore lossless.

use super::format::{bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits};
use super::{FloatFormat, BF16, FP16};

/// The two 16-bit storage layouts (mirrors `replay::Storage`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HalfFormat {
    /// IEEE binary16: 5 exponent bits, 10 significand bits.
    F16,
    /// bfloat16: 8 exponent bits, 7 significand bits.
    Bf16,
}

impl HalfFormat {
    /// Parse a storage-knob value. `"f32"` is valid but names the
    /// unpacked tier, hence `None` inside `Some`.
    pub fn parse(s: &str) -> Option<Option<HalfFormat>> {
        match s {
            "f32" => Some(None),
            "f16" => Some(Some(HalfFormat::F16)),
            "bf16" => Some(Some(HalfFormat::Bf16)),
            _ => None,
        }
    }

    /// Knob spelling of this format.
    pub fn name(self) -> &'static str {
        match self {
            HalfFormat::F16 => "f16",
            HalfFormat::Bf16 => "bf16",
        }
    }

    /// The simulated format whose value grid this layout stores.
    pub fn format(self) -> FloatFormat {
        match self {
            HalfFormat::F16 => FP16,
            HalfFormat::Bf16 => BF16,
        }
    }

    /// Round `x` into this format and return the 16 stored bits (RNE,
    /// IEEE overflow-to-infinity).
    #[inline]
    pub fn encode(self, x: f32) -> u16 {
        match self {
            HalfFormat::F16 => f32_to_f16_bits(x),
            HalfFormat::Bf16 => f32_to_bf16_bits(x),
        }
    }

    /// Widen 16 stored bits back to f32 — always exact.
    #[inline]
    pub fn decode(self, h: u16) -> f32 {
        match self {
            HalfFormat::F16 => f16_bits_to_f32(h),
            HalfFormat::Bf16 => bf16_bits_to_f32(h),
        }
    }

    /// Pack `src` into `dst` element-wise (`dst.len() == src.len()`).
    ///
    /// Routed through the SIMD compute plane (F16C/AVX2 on x86_64 hosts,
    /// bitwise equal to the scalar encode loop; `LPRL_SIMD=0` forces
    /// scalar).
    pub fn pack_slice(self, src: &[f32], dst: &mut [u16]) {
        assert_eq!(src.len(), dst.len());
        crate::nn::simd::pack_half_slice(self, src, dst);
    }

    /// Unpack `src` into `dst` element-wise (`dst.len() == src.len()`).
    ///
    /// Routed through the SIMD compute plane — widening is exact at every
    /// tier, and each tier is pinned bitwise against the scalar decode.
    pub fn unpack_slice(self, src: &[u16], dst: &mut [f32]) {
        assert_eq!(src.len(), dst.len());
        crate::nn::simd::unpack_half_slice(self, src, dst);
    }
}

/// A u16-backed tensor: the packed storage for read-only weights.
#[derive(Debug, Clone)]
pub struct HalfTensor {
    pub fmt: HalfFormat,
    pub shape: Vec<usize>,
    pub data: Vec<u16>,
}

impl HalfTensor {
    /// Pack `src` (row-major, `shape.iter().product()` elements).
    pub fn pack(fmt: HalfFormat, shape: &[usize], src: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), src.len());
        // tidy-allow(alloc): constructor — packing happens at snapshot
        // publish / storage-knob setup; update loops refresh through the
        // allocation-free `repack_from`
        let mut data = vec![0u16; src.len()];
        fmt.pack_slice(src, &mut data);
        // tidy-allow(alloc): constructor owns its shape (a few usizes)
        HalfTensor { fmt, shape: shape.to_vec(), data }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Resident bytes of the packed payload.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<u16>()
    }

    /// Widen every element into `dst` (exact).
    pub fn unpack_into(&self, dst: &mut [f32]) {
        self.fmt.unpack_slice(&self.data, dst);
    }

    /// Re-pack from `src` in place — allocation-free (target-network
    /// mirrors refresh through this after every EMA sync).
    pub fn repack_from(&mut self, src: &[f32]) {
        self.fmt.pack_slice(src, &mut self.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Pcg64;

    #[test]
    fn pack_unpack_roundtrip_is_identity_on_representable_values() {
        let mut rng = Pcg64::seed(5);
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            let f = fmt.format();
            let vals: Vec<f32> = (0..4096).map(|_| f.quantize(rng.normal_f32() * 3.0)).collect();
            let t = HalfTensor::pack(fmt, &[64, 64], &vals);
            assert_eq!(t.bytes(), 64 * 64 * 2);
            let mut back = vec![0.0f32; vals.len()];
            t.unpack_into(&mut back);
            assert!(
                vals.iter().zip(&back).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{}: pack→unpack must be the identity on representable values",
                fmt.name()
            );
        }
    }

    #[test]
    fn encode_decode_agrees_with_quantize() {
        let mut rng = Pcg64::seed(6);
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            let f = fmt.format();
            for _ in 0..50_000 {
                let x = f32::from_bits(rng.next_u32());
                if x.is_nan() {
                    continue;
                }
                let via_pack = fmt.decode(fmt.encode(x));
                let via_fmt = f.quantize(x);
                assert!(
                    via_pack == via_fmt || (via_pack == 0.0 && via_fmt == 0.0),
                    "{}: x={x:e} pack={via_pack:e} fmt={via_fmt:e}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn repack_reuses_the_buffer() {
        let vals = [1.0f32, 2.5, -0.75, 65504.0];
        let mut t = HalfTensor::pack(HalfFormat::F16, &[4], &vals);
        let ptr = t.data.as_ptr();
        t.repack_from(&[0.5, -1.0, 3.0, 0.0]);
        assert_eq!(t.data.as_ptr(), ptr, "repack must not reallocate");
        let mut back = [0.0f32; 4];
        t.unpack_into(&mut back);
        assert_eq!(back, [0.5, -1.0, 3.0, 0.0]);
    }

    #[test]
    fn parse_knob_values() {
        assert_eq!(HalfFormat::parse("f32"), Some(None));
        assert_eq!(HalfFormat::parse("f16"), Some(Some(HalfFormat::F16)));
        assert_eq!(HalfFormat::parse("bf16"), Some(Some(HalfFormat::Bf16)));
        assert_eq!(HalfFormat::parse("int8"), None);
    }
}
