//! Versioned crash-safe checkpoints.
//!
//! A checkpoint is one self-contained binary file capturing *all* run
//! state — agent parameters (f32 masters; packed half-storage mirrors
//! are rebuilt by re-quantizing on load, which is exact because stored
//! masters are already on the storage grid), Adam moments,
//! `ScaledKahanEma` shadow+compensation state, loss-scaler state, every
//! RNG stream, replay-buffer contents, env physics state, and the
//! schedule counters — so that a resumed run continues **bitwise
//! identical** to one that never stopped (see `INVARIANTS.md` §8 and
//! the `ckpt_resume` integration tests).
//!
//! Layout of a checkpoint file:
//!
//! ```text
//! magic   b"LPRLCKPT"          8 bytes
//! version u32 LE               format generation (CKPT_VERSION)
//! len     u64 LE               payload byte count
//! payload [u8; len]            Enc-encoded run state
//! sum     u64 LE               FNV-1a-64 over everything above
//! ```
//!
//! Durability discipline ([`CkptStore`]): payloads are written to a
//! sibling `*.tmp` file, fsync'd, then atomically renamed into place —
//! a crash mid-write can only ever leave a stale temp (removed on the
//! next [`CkptStore::open`]) or a previous complete generation. The
//! trailing checksum turns torn/corrupted survivors into detected
//! errors: [`CkptStore::load_latest`] walks generations newest-first
//! and falls back past any file that fails validation. Transient write
//! errors are retried with backoff; a keep-last-K policy bounds disk
//! use.
//!
//! The I/O hygiene here is machine-enforced: the `ckpt-io` tidy rule
//! bans bare `File::create`/`fs::write` on final paths and `.unwrap()`
//! on I/O results inside this module (see `INVARIANTS.md`).

mod codec;
mod fault;
mod store;

pub use codec::{Dec, Enc};
pub use fault::{FaultPlan, KillPhase, TornMode};
pub use store::{CkptStore, CKPT_MAGIC, CKPT_VERSION};
