//! Deterministic fault injection for the crash-safety tests.
//!
//! A [`FaultPlan`] is parsed from the `faults` config knob — a
//! comma-separated list of:
//!
//! * `kill@<step>:<phase>` — abort the trainer at the first boundary of
//!   `phase` (`round` = after a collect/update round, `eval` = after an
//!   evaluation, `ckpt` = right after a checkpoint write) whose step
//!   count has reached `<step>`. Fires at most once. The learner stops
//!   exactly where a SIGKILL would leave the on-disk state: no further
//!   checkpoint writes happen.
//! * `torn@<step>:<mode>` — damage the first checkpoint file written at
//!   or after `<step>` (`truncate` cuts it in half, `corrupt` flips a
//!   payload byte), simulating a torn write that slipped past the
//!   atomic-rename discipline. Applied by [`super::CkptStore`].
//!
//! Both faults are pure functions of the schedule — no wall clock, no
//! signals — so a "crash" is exactly reproducible, which is what lets
//! the resume tests assert bitwise equality against an undisturbed run.

/// Which schedule boundary a `kill@` fault fires at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KillPhase {
    /// After a collect/update round completes (before any eval/ckpt).
    Round,
    /// After an evaluation point.
    Eval,
    /// Immediately after a checkpoint write.
    Ckpt,
}

/// How a `torn@` fault damages a checkpoint file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TornMode {
    /// Cut the file to half its length (simulated partial flush).
    Truncate,
    /// Flip one payload byte (simulated media corruption).
    Corrupt,
}

/// A parsed `faults` spec: at most one kill point and one torn-write.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    kill: Option<(usize, KillPhase)>,
    /// Consumed by [`super::CkptStore::arm_torn`].
    pub torn: Option<(u64, TornMode)>,
}

impl FaultPlan {
    /// Parse a `faults` config string; empty means no faults.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(rest) = part.strip_prefix("kill@") {
                let (step, phase) = rest
                    .split_once(':')
                    .ok_or_else(|| format!("fault {part:?}: expected kill@<step>:<phase>"))?;
                let step: usize = step
                    .parse()
                    .map_err(|_| format!("fault {part:?}: bad step {step:?}"))?;
                let phase = match phase {
                    "round" => KillPhase::Round,
                    "eval" => KillPhase::Eval,
                    "ckpt" => KillPhase::Ckpt,
                    _ => return Err(format!("fault {part:?}: phase must be round|eval|ckpt")),
                };
                plan.kill = Some((step, phase));
            } else if let Some(rest) = part.strip_prefix("torn@") {
                let (step, mode) = rest
                    .split_once(':')
                    .ok_or_else(|| format!("fault {part:?}: expected torn@<step>:<mode>"))?;
                let step: u64 = step
                    .parse()
                    .map_err(|_| format!("fault {part:?}: bad step {step:?}"))?;
                let mode = match mode {
                    "truncate" => TornMode::Truncate,
                    "corrupt" => TornMode::Corrupt,
                    _ => return Err(format!("fault {part:?}: mode must be truncate|corrupt")),
                };
                plan.torn = Some((step, mode));
            } else {
                return Err(format!(
                    "unknown fault {part:?} (kill@<step>:<round|eval|ckpt> | \
                     torn@<step>:<truncate|corrupt>)"
                ));
            }
        }
        Ok(plan)
    }

    pub fn is_none(&self) -> bool {
        self.kill.is_none() && self.torn.is_none()
    }

    /// Check-and-disarm the kill point: returns true exactly once, at
    /// the first `phase` boundary whose `step` has reached the armed
    /// threshold.
    pub fn kill_due(&mut self, step: usize, phase: KillPhase) -> bool {
        if let Some((at, ph)) = self.kill {
            if ph == phase && step >= at {
                self.kill = None;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_kill_and_torn() {
        let p = FaultPlan::parse("kill@300:round, torn@200:truncate").unwrap();
        assert_eq!(p.torn, Some((200, TornMode::Truncate)));
        let mut p = p;
        assert!(!p.kill_due(299, KillPhase::Round));
        assert!(!p.kill_due(300, KillPhase::Eval), "phase must match");
        assert!(p.kill_due(300, KillPhase::Round));
        assert!(!p.kill_due(301, KillPhase::Round), "fires once then disarms");
    }

    #[test]
    fn empty_spec_is_no_faults() {
        assert!(FaultPlan::parse("").unwrap().is_none());
        assert!(FaultPlan::parse("  ").unwrap().is_none());
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("kill@x:round").is_err());
        assert!(FaultPlan::parse("kill@10:sometime").is_err());
        assert!(FaultPlan::parse("torn@10:melt").is_err());
        assert!(FaultPlan::parse("explode@10").is_err());
        assert!(FaultPlan::parse("kill@10").is_err());
    }

    #[test]
    fn ckpt_phase_parses() {
        let mut p = FaultPlan::parse("kill@5:ckpt").unwrap();
        assert!(p.kill_due(7, KillPhase::Ckpt));
    }
}
