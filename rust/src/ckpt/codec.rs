//! Little-endian binary codec for checkpoint payloads.
//!
//! The offline build has no serde, so the checkpoint format is a
//! hand-rolled length-prefixed encoding: fixed-width scalars in
//! little-endian byte order, sequences as a `u64` count followed by the
//! raw elements. [`Enc`] appends to a growable buffer; [`Dec`] walks a
//! borrowed slice and returns an error — never panics — on truncated or
//! oversized input, so a partially-written file that slipped past the
//! checksum (or a hand-damaged test fixture) degrades into a typed
//! decode error with offset context.

use anyhow::{ensure, Context, Result};

/// Append-only checkpoint payload encoder.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    /// Consume the encoder, yielding the payload bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string (config fingerprints).
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 sequence, each element bitwise.
    pub fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed f64 sequence, each element bitwise.
    pub fn f64s(&mut self, xs: &[f64]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed u16 sequence (packed half-precision words).
    pub fn u16s(&mut self, xs: &[u16]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed u8 sequence (byte-packed pixel replay rows).
    pub fn u8s(&mut self, xs: &[u8]) {
        self.u64(xs.len() as u64);
        self.buf.extend_from_slice(xs);
    }

    /// Append pre-encoded bytes verbatim (no length prefix) — splices a
    /// section another `Enc` produced (the async trainer's
    /// collector-serialized state) into this payload. The decoder must
    /// read the spliced fields in their original order.
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed u64 sequence (histogram counters).
    pub fn u64s(&mut self, xs: &[u64]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Checkpoint payload decoder over a borrowed byte slice. Every read is
/// bounds-checked: truncation is a typed error, and sequence lengths are
/// validated against the remaining bytes *before* any allocation, so a
/// corrupted length prefix cannot request an absurd buffer.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Assert the payload was fully consumed — trailing garbage means
    /// the reader and writer disagree about the format.
    pub fn finish(self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "checkpoint payload has {} unread trailing bytes (format mismatch)",
            self.buf.len() - self.pos
        );
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.buf.len() - self.pos,
            "checkpoint payload truncated: need {n} bytes at offset {}, only {} remain",
            self.pos,
            self.buf.len() - self.pos
        );
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Read a sequence length prefix and pre-validate that `len * size`
    /// element bytes actually remain.
    fn seq_len(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.u64()?;
        let n = usize::try_from(n).context("sequence length overflows usize")?;
        let bytes = n.checked_mul(elem_size).context("sequence byte count overflows")?;
        ensure!(
            bytes <= self.remaining(),
            "checkpoint payload truncated: sequence claims {n} elements ({bytes} bytes) \
             at offset {} but only {} bytes remain",
            self.pos,
            self.remaining()
        );
        Ok(n)
    }

    fn fixed<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        let v = self.u8()?;
        ensure!(v <= 1, "invalid bool byte {v}");
        Ok(v == 1)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.fixed()?))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.fixed()?))
    }

    pub fn u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.fixed()?))
    }

    /// `u64` on the wire, converted to `usize` (counters, indices).
    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).context("u64 value overflows usize")
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.fixed()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.fixed()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.seq_len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).context("checkpoint string is not UTF-8")
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.seq_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f32::from_le_bytes(self.fixed()?));
        }
        Ok(out)
    }

    /// Decode an f32 sequence into an existing buffer, validating that
    /// the stored length matches exactly (shape agreement between the
    /// checkpoint and the live object).
    pub fn f32s_into(&mut self, out: &mut [f32]) -> Result<()> {
        let n = self.seq_len(4)?;
        ensure!(
            n == out.len(),
            "checkpoint tensor length mismatch: stored {n}, expected {}",
            out.len()
        );
        for v in out.iter_mut() {
            *v = f32::from_le_bytes(self.fixed()?);
        }
        Ok(())
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.seq_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f64::from_le_bytes(self.fixed()?));
        }
        Ok(out)
    }

    pub fn u16s(&mut self) -> Result<Vec<u16>> {
        let n = self.seq_len(2)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(u16::from_le_bytes(self.fixed()?));
        }
        Ok(out)
    }

    pub fn u8s(&mut self) -> Result<Vec<u8>> {
        let n = self.seq_len(1)?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.seq_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(u64::from_le_bytes(self.fixed()?));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_roundtrip_bitwise() {
        let mut e = Enc::new();
        e.u8(7);
        e.bool(true);
        e.u32(0xdead_beef);
        e.u64(u64::MAX - 3);
        e.u128(u128::MAX / 7);
        e.f32(-0.0);
        e.f64(f64::MIN_POSITIVE);
        e.str("fp16_ours");
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert!(d.bool().unwrap());
        assert_eq!(d.u32().unwrap(), 0xdead_beef);
        assert_eq!(d.u64().unwrap(), u64::MAX - 3);
        assert_eq!(d.u128().unwrap(), u128::MAX / 7);
        assert_eq!(d.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(d.f64().unwrap().to_bits(), f64::MIN_POSITIVE.to_bits());
        assert_eq!(d.str().unwrap(), "fp16_ours");
        d.finish().unwrap();
    }

    #[test]
    fn sequences_roundtrip_bitwise() {
        let f32s = vec![1.5f32, -0.0, f32::NAN, 3.25e-30];
        let f64s = vec![0.1f64, -1e300];
        let u16s = vec![0u16, 0x7c00, 0xffff];
        let u8s = vec![0u8, 1, 127, 255];
        let u64s = vec![1u64, 2, 3];
        let mut e = Enc::new();
        e.f32s(&f32s);
        e.f64s(&f64s);
        e.u16s(&u16s);
        e.u8s(&u8s);
        e.u64s(&u64s);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        let got = d.f32s().unwrap();
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            f32s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "NaN payloads survive bitwise"
        );
        assert_eq!(d.f64s().unwrap(), f64s);
        assert_eq!(d.u16s().unwrap(), u16s);
        assert_eq!(d.u8s().unwrap(), u8s);
        assert_eq!(d.u64s().unwrap(), u64s);
        d.finish().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut e = Enc::new();
        e.u64(42);
        e.f32s(&[1.0, 2.0, 3.0]);
        let bytes = e.into_bytes();
        for cut in 0..bytes.len() {
            let mut d = Dec::new(&bytes[..cut]);
            let mut ok = true;
            ok = ok && d.u64().is_ok();
            ok = ok && d.f32s().is_ok();
            assert!(!ok, "cut at {cut} must fail somewhere");
        }
    }

    #[test]
    fn absurd_length_prefix_is_rejected_before_allocation() {
        let mut e = Enc::new();
        e.u64(u64::MAX / 2); // claims ~2^62 elements
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        let err = d.f32s().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated") || msg.contains("overflow"), "{msg}");
    }

    #[test]
    fn trailing_bytes_are_flagged() {
        let mut e = Enc::new();
        e.u32(1);
        e.u32(2);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        d.u32().unwrap();
        assert!(d.finish().is_err());
    }

    #[test]
    fn f32s_into_validates_shape() {
        let mut e = Enc::new();
        e.f32s(&[1.0, 2.0]);
        let bytes = e.into_bytes();
        let mut out = [0.0f32; 3];
        let err = Dec::new(&bytes).f32s_into(&mut out).unwrap_err();
        assert!(format!("{err}").contains("mismatch"));
        let mut out2 = [0.0f32; 2];
        Dec::new(&bytes).f32s_into(&mut out2).unwrap();
        assert_eq!(out2, [1.0, 2.0]);
    }
}
