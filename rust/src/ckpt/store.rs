//! Crash-safe checkpoint persistence: atomic temp+fsync+rename writes,
//! checksum-validated reads with fallback to older generations,
//! stale-temp cleanup, retry-with-backoff, and keep-last-K retention.

use super::TornMode;
use anyhow::{bail, ensure, Context, Result};
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Leading magic of every checkpoint file.
pub const CKPT_MAGIC: &[u8; 8] = b"LPRLCKPT";
/// Format generation; bumped on any incompatible payload change.
/// v2: `replay_storage` joined the pinned run header.
pub const CKPT_VERSION: u32 = 2;

/// magic + version + payload-len header bytes before the payload.
const HEADER_LEN: usize = 8 + 4 + 8;
/// Trailing FNV-1a-64 checksum bytes after the payload.
const SUM_LEN: usize = 8;
/// Write attempts before a transient I/O error becomes fatal.
const WRITE_ATTEMPTS: u32 = 3;

/// FNV-1a 64-bit content hash (same family as the replay fingerprint —
/// fast, dependency-free, and plenty for torn-write detection).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A directory of checkpoint generations, one file per checkpointed
/// step: `ckpt-<step, zero-padded>.lprl`. Zero-padding makes
/// lexicographic order equal numeric order, but [`CkptStore`] parses and
/// sorts by step anyway — directory iteration order is OS-dependent and
/// must never influence behavior.
pub struct CkptStore {
    dir: PathBuf,
    keep: usize,
    /// Armed torn-write fault: damage the first checkpoint written at or
    /// after this step (fault-injection harness; see `super::FaultPlan`).
    torn: Option<(u64, TornMode)>,
}

impl CkptStore {
    /// Open (creating if needed) a checkpoint directory, removing any
    /// stale `*.tmp` files a previous crash may have left behind.
    /// `keep` is the retention depth (`0` is clamped to 1 — a store that
    /// retains nothing could never be resumed from).
    pub fn open(dir: impl Into<PathBuf>, keep: usize) -> Result<CkptStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let entries = fs::read_dir(&dir)
            .with_context(|| format!("listing checkpoint dir {}", dir.display()))?;
        for entry in entries {
            let entry =
                entry.with_context(|| format!("listing checkpoint dir {}", dir.display()))?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) == Some("tmp") {
                fs::remove_file(&path)
                    .with_context(|| format!("removing stale temp {}", path.display()))?;
            }
        }
        Ok(CkptStore { dir, keep: keep.max(1), torn: None })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Arm a torn-write fault (test harness): the first `save` at
    /// `step >= at` damages its own output file after the atomic write.
    pub fn arm_torn(&mut self, fault: Option<(u64, TornMode)>) {
        self.torn = fault;
    }

    fn file_name(step: u64) -> String {
        format!("ckpt-{step:020}.lprl")
    }

    /// Every on-disk generation as `(step, path)`, sorted ascending by
    /// step. Non-checkpoint files are ignored.
    pub fn generations(&self) -> Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        let entries = fs::read_dir(&self.dir)
            .with_context(|| format!("listing checkpoint dir {}", self.dir.display()))?;
        for entry in entries {
            let entry = entry
                .with_context(|| format!("listing checkpoint dir {}", self.dir.display()))?;
            let path = entry.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            let Some(step) = name
                .strip_prefix("ckpt-")
                .and_then(|s| s.strip_suffix(".lprl"))
                .and_then(|s| s.parse::<u64>().ok())
            else {
                continue;
            };
            out.push((step, path));
        }
        out.sort_by_key(|&(step, _)| step);
        Ok(out)
    }

    /// Write one checkpoint generation crash-safely and apply retention.
    /// The payload goes into a sibling temp file, is fsync'd, then
    /// atomically renamed to its final name — a crash at any point
    /// leaves either the complete new generation or the previous state
    /// plus (at worst) a stale temp cleaned up by the next `open`.
    /// Transient I/O errors are retried with backoff.
    pub fn save(&mut self, step: u64, payload: &[u8]) -> Result<PathBuf> {
        let path = self.dir.join(Self::file_name(step));
        let tmp = self.dir.join(format!("{}.tmp", Self::file_name(step)));

        let mut bytes = Vec::with_capacity(HEADER_LEN + payload.len() + SUM_LEN);
        bytes.extend_from_slice(CKPT_MAGIC);
        bytes.extend_from_slice(&CKPT_VERSION.to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(payload);
        let sum = fnv1a64(&bytes);
        bytes.extend_from_slice(&sum.to_le_bytes());

        let mut attempt = 0;
        loop {
            attempt += 1;
            match write_atomic(&tmp, &path, &bytes) {
                Ok(()) => break,
                Err(_) if attempt < WRITE_ATTEMPTS => {
                    // transient I/O error: clean the temp, back off, retry
                    let _ = fs::remove_file(&tmp);
                    std::thread::sleep(Duration::from_millis(10 << attempt));
                }
                Err(e) => {
                    let _ = fs::remove_file(&tmp);
                    return Err(e).with_context(|| {
                        format!(
                            "writing checkpoint {} ({} attempts)",
                            path.display(),
                            attempt
                        )
                    });
                }
            }
        }

        if let Some((at, mode)) = self.torn {
            if step >= at {
                self.torn = None;
                apply_torn(&path, mode)?;
            }
        }

        self.prune()?;
        Ok(path)
    }

    /// Drop all but the newest `keep` generations.
    fn prune(&self) -> Result<()> {
        let gens = self.generations()?;
        if gens.len() > self.keep {
            for (_, path) in &gens[..gens.len() - self.keep] {
                fs::remove_file(path)
                    .with_context(|| format!("pruning old checkpoint {}", path.display()))?;
            }
        }
        Ok(())
    }

    /// Validate and decode one checkpoint file into its payload bytes.
    /// Truncation, magic/version mismatch, and checksum failure are all
    /// typed errors with the file path attached — never panics.
    pub fn read_file(path: &Path) -> Result<Vec<u8>> {
        let bytes = fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::decode(&bytes).with_context(|| format!("validating checkpoint {}", path.display()))
    }

    fn decode(bytes: &[u8]) -> Result<Vec<u8>> {
        ensure!(
            bytes.len() >= HEADER_LEN + SUM_LEN,
            "file too short ({} bytes) to hold a checkpoint header",
            bytes.len()
        );
        ensure!(&bytes[..8] == CKPT_MAGIC, "bad magic (not a checkpoint file)");
        let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
        ensure!(
            version == CKPT_VERSION,
            "unsupported checkpoint version {version} (this build reads {CKPT_VERSION})"
        );
        let mut len = [0u8; 8];
        len.copy_from_slice(&bytes[12..20]);
        let payload_len = u64::from_le_bytes(len) as usize;
        ensure!(
            bytes.len() == HEADER_LEN + payload_len + SUM_LEN,
            "truncated checkpoint: header claims {payload_len} payload bytes, file holds {}",
            bytes.len().saturating_sub(HEADER_LEN + SUM_LEN)
        );
        let body = &bytes[..HEADER_LEN + payload_len];
        let mut sum = [0u8; 8];
        sum.copy_from_slice(&bytes[HEADER_LEN + payload_len..]);
        let want = u64::from_le_bytes(sum);
        let got = fnv1a64(body);
        ensure!(got == want, "checksum mismatch (stored {want:#018x}, computed {got:#018x})");
        Ok(body[HEADER_LEN..].to_vec())
    }

    /// Load the newest *valid* generation, walking backwards past any
    /// corrupted or truncated survivors (each one fails its checksum and
    /// is skipped — the crash-recovery contract). Returns `None` when no
    /// valid checkpoint exists.
    pub fn load_latest(&self) -> Result<Option<(u64, Vec<u8>)>> {
        let gens = self.generations()?;
        for (step, path) in gens.iter().rev() {
            match Self::read_file(path) {
                Ok(payload) => return Ok(Some((*step, payload))),
                Err(_) => continue, // damaged generation: fall back to the previous one
            }
        }
        Ok(None)
    }

    /// True if any `*.tmp` file is present (test probe for temp leaks).
    pub fn has_stale_temps(&self) -> Result<bool> {
        let entries = fs::read_dir(&self.dir)
            .with_context(|| format!("listing checkpoint dir {}", self.dir.display()))?;
        for entry in entries {
            let entry = entry
                .with_context(|| format!("listing checkpoint dir {}", self.dir.display()))?;
            if entry.path().extension().and_then(|e| e.to_str()) == Some("tmp") {
                return Ok(true);
            }
        }
        Ok(false)
    }
}

/// The single place a checkpoint file is born: write the full image to
/// `tmp`, flush it to stable storage, then atomically rename over the
/// final path (and best-effort fsync the directory so the rename itself
/// is durable).
fn write_atomic(tmp: &Path, path: &Path, bytes: &[u8]) -> Result<()> {
    // tidy-allow(ckpt-io): this IS the atomic writer — the create targets
    // the temp path, which is renamed over the final path below
    let mut f = File::create(tmp).with_context(|| format!("creating temp {}", tmp.display()))?;
    f.write_all(bytes).with_context(|| format!("writing temp {}", tmp.display()))?;
    f.sync_all().with_context(|| format!("fsync temp {}", tmp.display()))?;
    drop(f);
    fs::rename(tmp, path).with_context(|| {
        format!("renaming temp {} over {}", tmp.display(), path.display())
    })?;
    if let Some(dir) = path.parent() {
        // directory fsync makes the rename durable; best-effort because
        // not every platform supports opening a directory for sync
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Damage a just-written checkpoint in place (fault injection): the
/// result simulates what the atomic-write discipline is there to
/// prevent, so the recovery path can be tested against real torn files.
fn apply_torn(path: &Path, mode: TornMode) -> Result<()> {
    let mut bytes =
        fs::read(path).with_context(|| format!("reading checkpoint {}", path.display()))?;
    match mode {
        TornMode::Truncate => bytes.truncate(bytes.len() / 2),
        TornMode::Corrupt => {
            if bytes.is_empty() {
                bail!("cannot corrupt empty checkpoint {}", path.display());
            }
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x55;
        }
    }
    // tidy-allow(ckpt-io): deliberate fault injection — this function
    // exists to produce the torn final file the checksum must catch
    let mut f = File::create(path)
        .with_context(|| format!("rewriting torn checkpoint {}", path.display()))?;
    f.write_all(&bytes)
        .with_context(|| format!("rewriting torn checkpoint {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lprl_ckpt_store_{tag}"));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn save_load_roundtrip_and_retention() {
        let dir = tmp_dir("roundtrip");
        let mut store = CkptStore::open(&dir, 2).unwrap();
        for step in [100u64, 200, 300] {
            store.save(step, format!("payload-{step}").as_bytes()).unwrap();
        }
        let gens = store.generations().unwrap();
        assert_eq!(gens.iter().map(|g| g.0).collect::<Vec<_>>(), vec![200, 300], "keep-last-2");
        let (step, payload) = store.load_latest().unwrap().unwrap();
        assert_eq!(step, 300);
        assert_eq!(payload, b"payload-300");
        assert!(!store.has_stale_temps().unwrap());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_store_loads_none() {
        let dir = tmp_dir("empty");
        let store = CkptStore::open(&dir, 3).unwrap();
        assert!(store.load_latest().unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_latest_falls_back_to_previous_generation() {
        let dir = tmp_dir("corrupt");
        let mut store = CkptStore::open(&dir, 4).unwrap();
        store.save(100, b"good-100").unwrap();
        store.arm_torn(Some((200, TornMode::Corrupt)));
        store.save(200, b"good-200").unwrap();
        let (step, payload) = store.load_latest().unwrap().unwrap();
        assert_eq!((step, payload.as_slice()), (100, b"good-100".as_slice()));
        // the damaged file itself is a typed error, not a panic
        let bad = dir.join(CkptStore::file_name(200));
        let err = CkptStore::read_file(&bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("checksum"), "{msg}");
        assert!(msg.contains("ckpt-"), "error names the file: {msg}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_latest_falls_back_to_previous_generation() {
        let dir = tmp_dir("truncate");
        let mut store = CkptStore::open(&dir, 4).unwrap();
        store.save(100, b"good-100").unwrap();
        store.arm_torn(Some((0, TornMode::Truncate)));
        store.save(200, b"good-200").unwrap();
        let (step, _) = store.load_latest().unwrap().unwrap();
        assert_eq!(step, 100);
        let bad = dir.join(CkptStore::file_name(200));
        let msg = format!("{:#}", CkptStore::read_file(&bad).unwrap_err());
        assert!(msg.contains("truncated") || msg.contains("too short"), "{msg}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_temps_are_cleaned_on_open() {
        let dir = tmp_dir("stale");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("ckpt-00000000000000000100.lprl.tmp"), b"half-written").unwrap();
        let store = CkptStore::open(&dir, 2).unwrap();
        assert!(!store.has_stale_temps().unwrap());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_files_are_ignored() {
        let dir = tmp_dir("foreign");
        let mut store = CkptStore::open(&dir, 2).unwrap();
        fs::write(dir.join("notes.txt"), b"hello").unwrap();
        fs::write(dir.join("ckpt-abc.lprl"), b"not numeric").unwrap();
        store.save(7, b"p").unwrap();
        let gens = store.generations().unwrap();
        assert_eq!(gens.len(), 1);
        assert_eq!(gens[0].0, 7);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn wrong_magic_and_version_are_typed_errors() {
        let dir = tmp_dir("magic");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ckpt-00000000000000000001.lprl");
        fs::write(&p, b"GARBAGEGARBAGEGARBAGEGARBAGE").unwrap();
        let msg = format!("{:#}", CkptStore::read_file(&p).unwrap_err());
        assert!(msg.contains("magic"), "{msg}");
        let _ = fs::remove_dir_all(&dir);
    }
}
