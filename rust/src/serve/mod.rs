//! Native serve layer: low-precision policy inference as a product
//! surface, not a training by-product.
//!
//! The paper's pitch is that fp16 SAC halves memory and compute; this
//! module is where that pays off at request time. It is built on the
//! train/inference API split:
//!
//! * [`crate::sac::Policy`] — an immutable, `Send + Sync` snapshot of a
//!   trained actor with batched `act_batch` (every layer forward is
//!   `&self`; training caches live in explicit workspaces).
//! * [`PolicyBackend`] — one deterministic batched-inference trait over
//!   both execution engines: [`NativeBackend`] (the blocked-GEMM native
//!   engine) and [`PjrtBackend`] (the AOT artifact runtime). `lprl
//!   serve --engine native|pjrt` picks one; the request path is shared.
//! * [`PolicyServer`] — a micro-batching server: a bounded request
//!   queue, one batcher thread that flushes at max-batch-or-deadline,
//!   one batched forward per flush (on the process-wide GEMM worker
//!   pool), per-request replies, and throughput/latency counters
//!   ([`ServeStats`]).
//!
//! Because the GEMM backend accumulates output rows independently of
//! the batch size, a micro-batched reply is **bitwise identical** to a
//! serial one — batching is purely a throughput optimization
//! (`benches/serve_throughput.rs` measures it; `tests/policy_serve.rs`
//! proves the equivalence).

mod backend;
mod metrics;
mod server;

pub use backend::{NativeBackend, PjrtBackend, PolicyBackend};
pub use metrics::ServeStats;
pub use server::{OverloadPolicy, PolicyServer, ServeClient, ServeConfig, ServeError};
