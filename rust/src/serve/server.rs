//! The micro-batching policy server.
//!
//! N client threads each submit one observation at a time; a single
//! batcher thread coalesces whatever is queued into one batched forward
//! (flushing at `max_batch` rows or when the oldest request has waited
//! `flush_us`), then fans the per-row actions back out to the waiting
//! clients. Because the backend's batched forward is row-invariant
//! (see [`crate::sac::Policy::act_batch`]), every client receives
//! bitwise the same action it would have gotten from a serial call —
//! micro-batching is a pure throughput optimization.
//!
//! The request queue is bounded (`queue_cap`); what happens at
//! saturation is the `overload` knob ([`OverloadPolicy`]): `block`
//! (default) exerts backpressure by blocking senders, `shed` fails a
//! request immediately with [`ServeError::Overloaded`] when the queue
//! is full, and `deadline` additionally sheds requests that are already
//! stale when their batch flushes. On shutdown the in-flight batch is
//! still served and everything queued behind the stop message is failed
//! with [`ServeError::Closed`] — every accepted request gets exactly
//! one reply.

use super::backend::PolicyBackend;
use super::metrics::{Metrics, ServeStats};
use std::fmt;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What happens to a request when the server is saturated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Saturated queue blocks the sender — the default backpressure
    /// story: the queue cannot grow without limit ahead of a slow
    /// backend, and no request is ever dropped.
    Block,
    /// Saturated queue fails the request immediately with
    /// [`ServeError::Overloaded`] instead of blocking the caller.
    Shed,
    /// Like [`OverloadPolicy::Shed`] on a full queue, and additionally
    /// the batcher sheds requests that have already waited longer than
    /// `deadline_us` when their batch flushes — a staleness bound for
    /// callers whose action is useless once the control tick passed.
    Deadline,
}

impl OverloadPolicy {
    /// Parse the `overload` knob (`block|shed|deadline`).
    pub fn parse(s: &str) -> Result<OverloadPolicy, String> {
        match s {
            "block" => Ok(OverloadPolicy::Block),
            "shed" => Ok(OverloadPolicy::Shed),
            "deadline" => Ok(OverloadPolicy::Deadline),
            _ => Err(format!("unknown overload policy {s:?} (block|shed|deadline)")),
        }
    }
}

/// Tuning knobs for [`PolicyServer`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// … or when the oldest queued request has waited this long (µs).
    pub flush_us: u64,
    /// Bound on the request queue (backpressure: senders block).
    pub queue_cap: usize,
    /// Saturation behaviour (see [`OverloadPolicy`]).
    pub overload: OverloadPolicy,
    /// Staleness bound (µs) for [`OverloadPolicy::Deadline`]: requests
    /// older than this at flush time are shed. Ignored otherwise.
    pub deadline_us: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            flush_us: 200,
            queue_cap: 1024,
            overload: OverloadPolicy::Block,
            deadline_us: 10_000,
        }
    }
}

/// Errors a [`ServeClient`] can observe.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The observation had the wrong flat length.
    BadObsLen { want: usize, got: usize },
    /// The server has shut down.
    Closed,
    /// The backend rejected the batch.
    Backend(String),
    /// The policy produced a non-finite action for this observation
    /// (the paper's crash condition, surfaced per request).
    NonFinite,
    /// The server shed this request under load (`overload=shed` on a
    /// full queue, or `overload=deadline` past the staleness bound).
    Overloaded,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::BadObsLen { want, got } => {
                write!(f, "bad observation length: want {want} floats, got {got}")
            }
            ServeError::Closed => write!(f, "policy server is shut down"),
            ServeError::Backend(e) => write!(f, "backend error: {e}"),
            ServeError::NonFinite => write!(f, "policy produced a non-finite action"),
            ServeError::Overloaded => write!(f, "server overloaded: request shed"),
        }
    }
}

impl std::error::Error for ServeError {}

struct Request {
    obs: Vec<f32>,
    enqueued: Instant,
    reply: mpsc::SyncSender<Result<Vec<f32>, ServeError>>,
}

enum Msg {
    Req(Request),
    Stop,
}

/// A micro-batching inference server over any [`PolicyBackend`].
/// Create with [`PolicyServer::start`], hand [`ServeClient`]s to
/// request threads, and call [`PolicyServer::shutdown`] for the final
/// stats.
pub struct PolicyServer {
    tx: mpsc::SyncSender<Msg>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    overload: OverloadPolicy,
    obs_dim: usize,
    act_dim: usize,
}

impl PolicyServer {
    /// Spawn the batcher thread and start serving.
    pub fn start(backend: Arc<dyn PolicyBackend>, cfg: ServeConfig) -> Self {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        assert!(cfg.queue_cap >= 1, "queue_cap must be at least 1");
        let (tx, rx) = mpsc::sync_channel(cfg.queue_cap);
        let metrics = Arc::new(Metrics::default());
        let obs_dim = backend.obs_dim();
        let act_dim = backend.act_dim();
        let m = Arc::clone(&metrics);
        let worker = std::thread::spawn(move || batch_loop(backend, rx, cfg, m));
        PolicyServer { tx, worker: Some(worker), metrics, overload: cfg.overload, obs_dim, act_dim }
    }

    /// A handle request threads use to submit observations. Clone one
    /// per thread.
    pub fn client(&self) -> ServeClient {
        ServeClient {
            tx: self.tx.clone(),
            metrics: Arc::clone(&self.metrics),
            overload: self.overload,
            obs_dim: self.obs_dim,
            act_dim: self.act_dim,
        }
    }

    /// Live counters (the server keeps running).
    pub fn stats(&self) -> ServeStats {
        self.metrics.snapshot()
    }

    /// Stop accepting work, drain the queue (the in-flight batch is
    /// served, requests queued behind the stop are failed with
    /// [`ServeError::Closed`]), join the batcher and return the final
    /// stats. Outstanding [`ServeClient`]s observe
    /// [`ServeError::Closed`] afterwards.
    pub fn shutdown(mut self) -> ServeStats {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for PolicyServer {
    fn drop(&mut self) {
        // blocking send: if the queue is momentarily full the batcher is
        // draining it, so a slot frees up; on a dead batcher the channel
        // is disconnected and send returns immediately.
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// A cheap, cloneable handle for submitting single observations.
#[derive(Clone)]
pub struct ServeClient {
    tx: mpsc::SyncSender<Msg>,
    metrics: Arc<Metrics>,
    overload: OverloadPolicy,
    obs_dim: usize,
    act_dim: usize,
}

impl ServeClient {
    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn act_dim(&self) -> usize {
        self.act_dim
    }

    /// Submit one observation and block for its action. The reply is
    /// bitwise identical to a serial `act_batch(obs, 1)` on the backend.
    pub fn act(&self, obs: &[f32]) -> Result<Vec<f32>, ServeError> {
        if obs.len() != self.obs_dim {
            return Err(ServeError::BadObsLen { want: self.obs_dim, got: obs.len() });
        }
        let (rtx, rrx) = mpsc::sync_channel(1);
        // tidy-allow(alloc): the request's obs must be owned to cross the
        // channel to the batcher thread
        let req = Request { obs: obs.to_vec(), enqueued: Instant::now(), reply: rtx };
        match self.overload {
            // backpressure: block until the batcher frees a slot
            OverloadPolicy::Block => {
                self.tx.send(Msg::Req(req)).map_err(|_| ServeError::Closed)?;
            }
            // load shedding: a full queue fails fast instead of blocking
            OverloadPolicy::Shed | OverloadPolicy::Deadline => {
                match self.tx.try_send(Msg::Req(req)) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(_)) => {
                        self.metrics.record_shed();
                        return Err(ServeError::Overloaded);
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => return Err(ServeError::Closed),
                }
            }
        }
        match rrx.recv() {
            Ok(res) => res,
            Err(_) => Err(ServeError::Closed),
        }
    }
}

fn batch_loop(
    backend: Arc<dyn PolicyBackend>,
    rx: mpsc::Receiver<Msg>,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
) {
    let obs_dim = backend.obs_dim();
    let act_dim = backend.act_dim();
    let flush = Duration::from_micros(cfg.flush_us);
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    let mut stop = false;
    while !stop {
        // block for the first request of the next batch
        match rx.recv() {
            Ok(Msg::Req(r)) => pending.push(r),
            Ok(Msg::Stop) | Err(_) => break,
        }
        // coalesce until the batch fills or the flush deadline passes
        let deadline = Instant::now() + flush;
        while pending.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Stop) => {
                    stop = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    stop = true;
                    break;
                }
            }
        }
        shed_stale(&cfg, &mut pending, &metrics);
        flush_batch(&*backend, &mut pending, obs_dim, act_dim, &metrics);
    }
    // graceful shutdown: the in-flight batch above was still served;
    // everything queued behind the Stop gets the typed shutdown error —
    // every accepted request is answered, no reply channel is dropped
    // unanswered
    while let Ok(msg) = rx.try_recv() {
        if let Msg::Req(r) = msg {
            metrics.record_error();
            let _ = r.reply.send(Err(ServeError::Closed));
        }
    }
}

/// Under `overload=deadline`, fail queued requests whose reply would
/// arrive past the staleness bound instead of spending backend time on
/// them.
fn shed_stale(cfg: &ServeConfig, pending: &mut Vec<Request>, metrics: &Metrics) {
    if cfg.overload != OverloadPolicy::Deadline {
        return;
    }
    let limit = Duration::from_micros(cfg.deadline_us);
    pending.retain(|r| {
        if r.enqueued.elapsed() > limit {
            metrics.record_shed();
            let _ = r.reply.send(Err(ServeError::Overloaded));
            false
        } else {
            true
        }
    });
}

/// One batched forward + per-request fan-out.
fn flush_batch(
    backend: &dyn PolicyBackend,
    pending: &mut Vec<Request>,
    obs_dim: usize,
    act_dim: usize,
    metrics: &Metrics,
) {
    if pending.is_empty() {
        return;
    }
    let b = pending.len();
    // tidy-allow(alloc): per-flush staging buffer sized by the batch that
    // actually coalesced; requests are owned rows from other threads
    let mut flat = Vec::with_capacity(b * obs_dim);
    for r in pending.iter() {
        flat.extend_from_slice(&r.obs);
    }
    let t0 = Instant::now();
    let result = backend.act_batch(&flat, b);
    metrics.record_batch(b, t0.elapsed());
    match result {
        Ok(acts) => {
            for (i, req) in pending.drain(..).enumerate() {
                // tidy-allow(alloc): the reply must be owned to cross the
                // channel back to the requesting thread
                let a = acts[i * act_dim..(i + 1) * act_dim].to_vec();
                if a.iter().all(|v| v.is_finite()) {
                    metrics.record_request(req.enqueued.elapsed());
                    let _ = req.reply.send(Ok(a));
                } else {
                    metrics.record_error();
                    let _ = req.reply.send(Err(ServeError::NonFinite));
                }
            }
        }
        Err(e) => {
            for req in pending.drain(..) {
                metrics.record_error();
                // tidy-allow(alloc): error fan-out clones the message per requester
                let _ = req.reply.send(Err(ServeError::Backend(e.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A backend that doubles each observation element pairwise, so the
    /// reply for a request is a pure function of its own row.
    struct Doubler {
        obs: usize,
    }

    impl PolicyBackend for Doubler {
        fn obs_dim(&self) -> usize {
            self.obs
        }
        fn act_dim(&self) -> usize {
            self.obs
        }
        fn act_batch(&self, obs: &[f32], batch: usize) -> Result<Vec<f32>, String> {
            assert_eq!(obs.len(), batch * self.obs);
            Ok(obs.iter().map(|v| 2.0 * v).collect())
        }
        fn name(&self) -> &'static str {
            "doubler"
        }
    }

    #[test]
    fn requests_round_trip() {
        let server = PolicyServer::start(
            Arc::new(Doubler { obs: 3 }),
            ServeConfig { max_batch: 4, flush_us: 500, queue_cap: 16, ..ServeConfig::default() },
        );
        let client = server.client();
        assert_eq!(client.obs_dim(), 3);
        assert_eq!(client.act_dim(), 3);
        let a = client.act(&[1.0, -2.0, 0.5]).unwrap();
        assert_eq!(a, vec![2.0, -4.0, 1.0]);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn wrong_length_is_rejected_client_side() {
        let server = PolicyServer::start(Arc::new(Doubler { obs: 3 }), ServeConfig::default());
        let client = server.client();
        assert_eq!(
            client.act(&[1.0]),
            Err(ServeError::BadObsLen { want: 3, got: 1 })
        );
        let stats = server.shutdown();
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn closed_server_reports_closed() {
        let server = PolicyServer::start(Arc::new(Doubler { obs: 2 }), ServeConfig::default());
        let client = server.client();
        let _ = server.shutdown();
        assert_eq!(client.act(&[0.0, 0.0]), Err(ServeError::Closed));
    }

    #[test]
    fn concurrent_clients_coalesce_into_batches() {
        let server = PolicyServer::start(
            Arc::new(Doubler { obs: 2 }),
            ServeConfig { max_batch: 8, flush_us: 20_000, queue_cap: 64, ..ServeConfig::default() },
        );
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for i in 0..16 {
                let client = server.client();
                handles.push(s.spawn(move || {
                    let obs = [i as f32, -(i as f32)];
                    client.act(&obs).unwrap()
                }));
            }
            for (i, h) in handles.into_iter().enumerate() {
                let a = h.join().unwrap();
                assert_eq!(a, vec![2.0 * i as f32, -2.0 * i as f32]);
            }
        });
        let stats = server.shutdown();
        assert_eq!(stats.requests, 16);
        assert!(
            stats.batches < 16,
            "16 concurrent requests must coalesce, got {} batches",
            stats.batches
        );
        assert!(stats.mean_batch > 1.0);
    }

    #[test]
    fn overload_policy_parses() {
        assert_eq!(OverloadPolicy::parse("block"), Ok(OverloadPolicy::Block));
        assert_eq!(OverloadPolicy::parse("shed"), Ok(OverloadPolicy::Shed));
        assert_eq!(OverloadPolicy::parse("deadline"), Ok(OverloadPolicy::Deadline));
        assert!(OverloadPolicy::parse("panic").is_err());
    }

    /// A backend that announces each entered forward and then blocks
    /// until the test releases it — makes saturation deterministic.
    struct Gated {
        entered: mpsc::SyncSender<()>,
        release: std::sync::Mutex<mpsc::Receiver<()>>,
    }

    impl PolicyBackend for Gated {
        fn obs_dim(&self) -> usize {
            1
        }
        fn act_dim(&self) -> usize {
            1
        }
        fn act_batch(&self, obs: &[f32], _batch: usize) -> Result<Vec<f32>, String> {
            let _ = self.entered.send(());
            let _ = self.release.lock().unwrap().recv();
            Ok(obs.to_vec())
        }
        fn name(&self) -> &'static str {
            "gated"
        }
    }

    #[test]
    fn shed_policy_fails_fast_on_a_full_queue() {
        let (etx, erx) = mpsc::sync_channel(8);
        let (rtx, rrx) = mpsc::sync_channel(8);
        let server = PolicyServer::start(
            Arc::new(Gated { entered: etx, release: std::sync::Mutex::new(rrx) }),
            ServeConfig {
                max_batch: 1,
                flush_us: 0,
                queue_cap: 1,
                overload: OverloadPolicy::Shed,
                ..ServeConfig::default()
            },
        );
        // occupy the batcher: req1 is popped and blocks inside the backend
        let (r1tx, r1rx) = mpsc::sync_channel(1);
        server
            .tx
            .send(Msg::Req(Request { obs: vec![1.0], enqueued: Instant::now(), reply: r1tx }))
            .unwrap();
        erx.recv().unwrap();
        // fill the (cap-1) queue behind it
        let (r2tx, r2rx) = mpsc::sync_channel(1);
        server
            .tx
            .send(Msg::Req(Request { obs: vec![2.0], enqueued: Instant::now(), reply: r2tx }))
            .unwrap();
        // a shedding client now fails fast instead of blocking forever
        let client = server.client();
        assert_eq!(client.act(&[3.0]), Err(ServeError::Overloaded));
        // release the backend: both accepted requests are still served
        rtx.send(()).unwrap();
        rtx.send(()).unwrap();
        assert_eq!(r1rx.recv().unwrap(), Ok(vec![1.0]));
        assert_eq!(r2rx.recv().unwrap(), Ok(vec![2.0]));
        let stats = server.shutdown();
        assert_eq!(stats.shed, 1, "the rejected request is counted");
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn deadline_policy_sheds_stale_requests_at_flush() {
        // deadline_us = 0: every request is already stale when its batch
        // assembles, so it must be failed without touching the backend
        let server = PolicyServer::start(
            Arc::new(Doubler { obs: 2 }),
            ServeConfig {
                overload: OverloadPolicy::Deadline,
                deadline_us: 0,
                ..ServeConfig::default()
            },
        );
        let client = server.client();
        assert_eq!(client.act(&[1.0, 1.0]), Err(ServeError::Overloaded));
        let stats = server.shutdown();
        assert_eq!(stats.shed, 1);
        assert_eq!(stats.requests, 0);
        assert_eq!(stats.batches, 0, "stale requests never reach the backend");

        // a generous deadline serves normally
        let server = PolicyServer::start(
            Arc::new(Doubler { obs: 2 }),
            ServeConfig {
                overload: OverloadPolicy::Deadline,
                deadline_us: 60_000_000,
                ..ServeConfig::default()
            },
        );
        let client = server.client();
        assert_eq!(client.act(&[1.0, -1.0]), Ok(vec![2.0, -2.0]));
        let stats = server.shutdown();
        assert_eq!(stats.shed, 0);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn shutdown_fails_queued_requests_and_leaks_no_reply_channel() {
        // drive batch_loop directly with a hand-built queue: one request
        // in flight, then Stop, then two requests queued behind it
        let (tx, rx) = mpsc::sync_channel(16);
        let metrics = Arc::new(Metrics::default());
        let mk = |v: f32| {
            let (rtx, rrx) = mpsc::sync_channel(1);
            (Msg::Req(Request { obs: vec![v, v], enqueued: Instant::now(), reply: rtx }), rrx)
        };
        let (m1, r1) = mk(1.0);
        let (m2, r2) = mk(2.0);
        let (m3, r3) = mk(3.0);
        tx.send(m1).unwrap();
        tx.send(Msg::Stop).unwrap();
        tx.send(m2).unwrap();
        tx.send(m3).unwrap();
        drop(tx);
        batch_loop(
            Arc::new(Doubler { obs: 2 }),
            rx,
            ServeConfig { max_batch: 4, flush_us: 0, queue_cap: 16, ..ServeConfig::default() },
            Arc::clone(&metrics),
        );
        // the in-flight request was served...
        assert_eq!(r1.recv().unwrap(), Ok(vec![2.0, 2.0]));
        // ...and the queued ones got the typed shutdown error. recv()
        // returning a *sent* value (not RecvError) is the no-leak
        // property: the batcher answered every reply channel it ever
        // received before dropping it
        assert_eq!(r2.recv().unwrap(), Err(ServeError::Closed));
        assert_eq!(r3.recv().unwrap(), Err(ServeError::Closed));
        let s = metrics.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.errors, 2, "failed-on-shutdown requests are counted");
    }

    #[test]
    fn nonfinite_actions_surface_per_request() {
        struct NanMaker;
        impl PolicyBackend for NanMaker {
            fn obs_dim(&self) -> usize {
                1
            }
            fn act_dim(&self) -> usize {
                1
            }
            fn act_batch(&self, obs: &[f32], _batch: usize) -> Result<Vec<f32>, String> {
                Ok(obs.iter().map(|&v| if v < 0.0 { f32::NAN } else { v }).collect())
            }
            fn name(&self) -> &'static str {
                "nan"
            }
        }
        let server = PolicyServer::start(Arc::new(NanMaker), ServeConfig::default());
        let client = server.client();
        assert_eq!(client.act(&[1.0]), Ok(vec![1.0]));
        assert_eq!(client.act(&[-1.0]), Err(ServeError::NonFinite));
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.errors, 1);
    }
}
