//! The micro-batching policy server.
//!
//! N client threads each submit one observation at a time; a single
//! batcher thread coalesces whatever is queued into one batched forward
//! (flushing at `max_batch` rows or when the oldest request has waited
//! `flush_us`), then fans the per-row actions back out to the waiting
//! clients. Because the backend's batched forward is row-invariant
//! (see [`crate::sac::Policy::act_batch`]), every client receives
//! bitwise the same action it would have gotten from a serial call —
//! micro-batching is a pure throughput optimization.
//!
//! The request queue is bounded (`queue_cap`): saturated clients block
//! in `send`, which is the backpressure story — the queue cannot grow
//! without limit ahead of a slow backend.

use super::backend::PolicyBackend;
use super::metrics::{Metrics, ServeStats};
use std::fmt;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Tuning knobs for [`PolicyServer`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// … or when the oldest queued request has waited this long (µs).
    pub flush_us: u64,
    /// Bound on the request queue (backpressure: senders block).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 32, flush_us: 200, queue_cap: 1024 }
    }
}

/// Errors a [`ServeClient`] can observe.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The observation had the wrong flat length.
    BadObsLen { want: usize, got: usize },
    /// The server has shut down.
    Closed,
    /// The backend rejected the batch.
    Backend(String),
    /// The policy produced a non-finite action for this observation
    /// (the paper's crash condition, surfaced per request).
    NonFinite,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::BadObsLen { want, got } => {
                write!(f, "bad observation length: want {want} floats, got {got}")
            }
            ServeError::Closed => write!(f, "policy server is shut down"),
            ServeError::Backend(e) => write!(f, "backend error: {e}"),
            ServeError::NonFinite => write!(f, "policy produced a non-finite action"),
        }
    }
}

impl std::error::Error for ServeError {}

struct Request {
    obs: Vec<f32>,
    enqueued: Instant,
    reply: mpsc::SyncSender<Result<Vec<f32>, ServeError>>,
}

enum Msg {
    Req(Request),
    Stop,
}

/// A micro-batching inference server over any [`PolicyBackend`].
/// Create with [`PolicyServer::start`], hand [`ServeClient`]s to
/// request threads, and call [`PolicyServer::shutdown`] for the final
/// stats.
pub struct PolicyServer {
    tx: mpsc::SyncSender<Msg>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    obs_dim: usize,
    act_dim: usize,
}

impl PolicyServer {
    /// Spawn the batcher thread and start serving.
    pub fn start(backend: Arc<dyn PolicyBackend>, cfg: ServeConfig) -> Self {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        assert!(cfg.queue_cap >= 1, "queue_cap must be at least 1");
        let (tx, rx) = mpsc::sync_channel(cfg.queue_cap);
        let metrics = Arc::new(Metrics::default());
        let obs_dim = backend.obs_dim();
        let act_dim = backend.act_dim();
        let m = Arc::clone(&metrics);
        let worker = std::thread::spawn(move || batch_loop(backend, rx, cfg, m));
        PolicyServer { tx, worker: Some(worker), metrics, obs_dim, act_dim }
    }

    /// A handle request threads use to submit observations. Clone one
    /// per thread.
    pub fn client(&self) -> ServeClient {
        ServeClient { tx: self.tx.clone(), obs_dim: self.obs_dim, act_dim: self.act_dim }
    }

    /// Live counters (the server keeps running).
    pub fn stats(&self) -> ServeStats {
        self.metrics.snapshot()
    }

    /// Stop accepting work, drain the queue, join the batcher and
    /// return the final stats. Outstanding [`ServeClient`]s observe
    /// [`ServeError::Closed`] afterwards.
    pub fn shutdown(mut self) -> ServeStats {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for PolicyServer {
    fn drop(&mut self) {
        // blocking send: if the queue is momentarily full the batcher is
        // draining it, so a slot frees up; on a dead batcher the channel
        // is disconnected and send returns immediately.
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// A cheap, cloneable handle for submitting single observations.
#[derive(Clone)]
pub struct ServeClient {
    tx: mpsc::SyncSender<Msg>,
    obs_dim: usize,
    act_dim: usize,
}

impl ServeClient {
    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn act_dim(&self) -> usize {
        self.act_dim
    }

    /// Submit one observation and block for its action. The reply is
    /// bitwise identical to a serial `act_batch(obs, 1)` on the backend.
    pub fn act(&self, obs: &[f32]) -> Result<Vec<f32>, ServeError> {
        if obs.len() != self.obs_dim {
            return Err(ServeError::BadObsLen { want: self.obs_dim, got: obs.len() });
        }
        let (rtx, rrx) = mpsc::sync_channel(1);
        // tidy-allow(alloc): the request's obs must be owned to cross the
        // channel to the batcher thread
        let req = Request { obs: obs.to_vec(), enqueued: Instant::now(), reply: rtx };
        self.tx.send(Msg::Req(req)).map_err(|_| ServeError::Closed)?;
        match rrx.recv() {
            Ok(res) => res,
            Err(_) => Err(ServeError::Closed),
        }
    }
}

fn batch_loop(
    backend: Arc<dyn PolicyBackend>,
    rx: mpsc::Receiver<Msg>,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
) {
    let obs_dim = backend.obs_dim();
    let act_dim = backend.act_dim();
    let flush = Duration::from_micros(cfg.flush_us);
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    let mut stop = false;
    while !stop {
        // block for the first request of the next batch
        match rx.recv() {
            Ok(Msg::Req(r)) => pending.push(r),
            Ok(Msg::Stop) | Err(_) => break,
        }
        // coalesce until the batch fills or the flush deadline passes
        let deadline = Instant::now() + flush;
        while pending.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Stop) => {
                    stop = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    stop = true;
                    break;
                }
            }
        }
        flush_batch(&*backend, &mut pending, obs_dim, act_dim, &metrics);
    }
    // drain whatever made it into the queue before Stop
    while let Ok(Msg::Req(r)) = rx.try_recv() {
        pending.push(r);
        if pending.len() == cfg.max_batch {
            flush_batch(&*backend, &mut pending, obs_dim, act_dim, &metrics);
        }
    }
    flush_batch(&*backend, &mut pending, obs_dim, act_dim, &metrics);
}

/// One batched forward + per-request fan-out.
fn flush_batch(
    backend: &dyn PolicyBackend,
    pending: &mut Vec<Request>,
    obs_dim: usize,
    act_dim: usize,
    metrics: &Metrics,
) {
    if pending.is_empty() {
        return;
    }
    let b = pending.len();
    // tidy-allow(alloc): per-flush staging buffer sized by the batch that
    // actually coalesced; requests are owned rows from other threads
    let mut flat = Vec::with_capacity(b * obs_dim);
    for r in pending.iter() {
        flat.extend_from_slice(&r.obs);
    }
    let t0 = Instant::now();
    let result = backend.act_batch(&flat, b);
    metrics.record_batch(b, t0.elapsed());
    match result {
        Ok(acts) => {
            for (i, req) in pending.drain(..).enumerate() {
                // tidy-allow(alloc): the reply must be owned to cross the
                // channel back to the requesting thread
                let a = acts[i * act_dim..(i + 1) * act_dim].to_vec();
                if a.iter().all(|v| v.is_finite()) {
                    metrics.record_request(req.enqueued.elapsed());
                    let _ = req.reply.send(Ok(a));
                } else {
                    metrics.record_error();
                    let _ = req.reply.send(Err(ServeError::NonFinite));
                }
            }
        }
        Err(e) => {
            for req in pending.drain(..) {
                metrics.record_error();
                // tidy-allow(alloc): error fan-out clones the message per requester
                let _ = req.reply.send(Err(ServeError::Backend(e.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A backend that doubles each observation element pairwise, so the
    /// reply for a request is a pure function of its own row.
    struct Doubler {
        obs: usize,
    }

    impl PolicyBackend for Doubler {
        fn obs_dim(&self) -> usize {
            self.obs
        }
        fn act_dim(&self) -> usize {
            self.obs
        }
        fn act_batch(&self, obs: &[f32], batch: usize) -> Result<Vec<f32>, String> {
            assert_eq!(obs.len(), batch * self.obs);
            Ok(obs.iter().map(|v| 2.0 * v).collect())
        }
        fn name(&self) -> &'static str {
            "doubler"
        }
    }

    #[test]
    fn requests_round_trip() {
        let server = PolicyServer::start(
            Arc::new(Doubler { obs: 3 }),
            ServeConfig { max_batch: 4, flush_us: 500, queue_cap: 16 },
        );
        let client = server.client();
        assert_eq!(client.obs_dim(), 3);
        assert_eq!(client.act_dim(), 3);
        let a = client.act(&[1.0, -2.0, 0.5]).unwrap();
        assert_eq!(a, vec![2.0, -4.0, 1.0]);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn wrong_length_is_rejected_client_side() {
        let server = PolicyServer::start(Arc::new(Doubler { obs: 3 }), ServeConfig::default());
        let client = server.client();
        assert_eq!(
            client.act(&[1.0]),
            Err(ServeError::BadObsLen { want: 3, got: 1 })
        );
        let stats = server.shutdown();
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn closed_server_reports_closed() {
        let server = PolicyServer::start(Arc::new(Doubler { obs: 2 }), ServeConfig::default());
        let client = server.client();
        let _ = server.shutdown();
        assert_eq!(client.act(&[0.0, 0.0]), Err(ServeError::Closed));
    }

    #[test]
    fn concurrent_clients_coalesce_into_batches() {
        let server = PolicyServer::start(
            Arc::new(Doubler { obs: 2 }),
            ServeConfig { max_batch: 8, flush_us: 20_000, queue_cap: 64 },
        );
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for i in 0..16 {
                let client = server.client();
                handles.push(s.spawn(move || {
                    let obs = [i as f32, -(i as f32)];
                    client.act(&obs).unwrap()
                }));
            }
            for (i, h) in handles.into_iter().enumerate() {
                let a = h.join().unwrap();
                assert_eq!(a, vec![2.0 * i as f32, -2.0 * i as f32]);
            }
        });
        let stats = server.shutdown();
        assert_eq!(stats.requests, 16);
        assert!(
            stats.batches < 16,
            "16 concurrent requests must coalesce, got {} batches",
            stats.batches
        );
        assert!(stats.mean_batch > 1.0);
    }

    #[test]
    fn nonfinite_actions_surface_per_request() {
        struct NanMaker;
        impl PolicyBackend for NanMaker {
            fn obs_dim(&self) -> usize {
                1
            }
            fn act_dim(&self) -> usize {
                1
            }
            fn act_batch(&self, obs: &[f32], _batch: usize) -> Result<Vec<f32>, String> {
                Ok(obs.iter().map(|&v| if v < 0.0 { f32::NAN } else { v }).collect())
            }
            fn name(&self) -> &'static str {
                "nan"
            }
        }
        let server = PolicyServer::start(Arc::new(NanMaker), ServeConfig::default());
        let client = server.client();
        assert_eq!(client.act(&[1.0]), Ok(vec![1.0]));
        assert_eq!(client.act(&[-1.0]), Err(ServeError::NonFinite));
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.errors, 1);
    }
}
