//! Serve-layer telemetry: throughput/latency counters shared between
//! the batcher thread and observers. Atomic counters plus a bounded
//! sliding window of request latencies: once the window is full the
//! oldest samples are overwritten, so the percentiles always describe
//! recent traffic (an append-and-stop buffer would freeze p50/p99 at
//! the server's first-hour behaviour forever).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Size of the sliding latency window (per-request samples).
const MAX_LATENCY_SAMPLES: usize = 1 << 20;

/// Live counters owned by a [`super::PolicyServer`].
#[derive(Debug, Default)]
pub(crate) struct Metrics {
    requests: AtomicU64,
    batches: AtomicU64,
    max_batch_rows: AtomicU64,
    backend_us: AtomicU64,
    errors: AtomicU64,
    shed: AtomicU64,
    latencies_us: Mutex<LatencyWindow>,
}

/// Fixed-capacity ring of the most recent request latencies.
#[derive(Debug, Default)]
struct LatencyWindow {
    samples: Vec<u64>,
    next: usize,
}

impl LatencyWindow {
    fn push(&mut self, us: u64) {
        if self.samples.len() < MAX_LATENCY_SAMPLES {
            self.samples.push(us);
        } else {
            self.samples[self.next] = us;
        }
        self.next = (self.next + 1) % MAX_LATENCY_SAMPLES;
    }
}

impl Metrics {
    /// One request answered; `latency` is enqueue → reply.
    pub fn record_request(&self, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().push(latency.as_micros() as u64); // tidy-allow(panic): poisoned lock — another thread already panicked
    }

    /// One batch flushed through the backend.
    pub fn record_batch(&self, rows: usize, backend: Duration) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.max_batch_rows.fetch_max(rows as u64, Ordering::Relaxed);
        self.backend_us.fetch_add(backend.as_micros() as u64, Ordering::Relaxed);
    }

    /// One request answered with an error.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One request shed under load (full queue or missed deadline).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ServeStats {
        let mut lat = self.latencies_us.lock().unwrap().samples.clone(); // tidy-allow(panic): poisoned lock — another thread already panicked
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * p) as usize]
            }
        };
        let requests = self.requests.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        ServeStats {
            requests,
            batches,
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            mean_batch: if batches == 0 { 0.0 } else { requests as f64 / batches as f64 },
            max_batch: self.max_batch_rows.load(Ordering::Relaxed) as usize,
            p50_us: pct(0.50),
            p99_us: pct(0.99),
            backend_us: self.backend_us.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time snapshot of the serve counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    /// Requests answered successfully.
    pub requests: u64,
    /// Batched forwards executed.
    pub batches: u64,
    /// Requests answered with an error.
    pub errors: u64,
    /// Requests shed under load (`overload=shed|deadline`).
    pub shed: u64,
    /// Mean rows per flushed batch — the micro-batching win.
    pub mean_batch: f64,
    /// Largest batch flushed.
    pub max_batch: usize,
    /// End-to-end (enqueue → reply) request latency over the sliding
    /// window of recent requests, 50th percentile, µs.
    pub p50_us: u64,
    /// End-to-end request latency over the sliding window, 99th
    /// percentile, µs.
    pub p99_us: u64,
    /// Total wall time spent inside the backend's batched forward, µs.
    pub backend_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_aggregate() {
        let m = Metrics::default();
        m.record_batch(4, Duration::from_micros(100));
        for i in 0..4u64 {
            m.record_request(Duration::from_micros(10 * (i + 1)));
        }
        m.record_batch(2, Duration::from_micros(50));
        for _ in 0..2 {
            m.record_request(Duration::from_micros(1000));
        }
        m.record_error();
        m.record_shed();
        m.record_shed();
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.shed, 2);
        assert_eq!(s.max_batch, 4);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        assert_eq!(s.backend_us, 150);
        assert!(s.p50_us <= s.p99_us);
        assert_eq!(s.p99_us, 1000);
    }

    #[test]
    fn latency_window_overwrites_oldest_when_full() {
        let mut w = LatencyWindow::default();
        for _ in 0..MAX_LATENCY_SAMPLES {
            w.push(1);
        }
        assert_eq!(w.samples.len(), MAX_LATENCY_SAMPLES);
        for _ in 0..5 {
            w.push(99);
        }
        assert_eq!(w.samples.len(), MAX_LATENCY_SAMPLES, "window stays bounded");
        assert_eq!(&w.samples[..5], &[99; 5], "oldest samples are overwritten");
        assert_eq!(w.samples[5], 1);
    }
}
