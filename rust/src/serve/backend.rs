//! The [`PolicyBackend`] trait: one deterministic batched-inference
//! request path for both execution engines — the native Rust engine
//! (via [`crate::sac::Policy`]) and the PJRT artifact runtime (via
//! [`crate::runtime::TrainSession`]). `lprl serve --engine native|pjrt`
//! and the micro-batching [`super::PolicyServer`] only ever see this
//! trait.

use crate::runtime::TrainSession;
use crate::sac::{ActMode, Policy};
use std::path::Path;
use std::sync::Mutex;

/// A deterministic batched policy-inference engine. Implementations
/// must be thread-safe: the serve layer calls `act_batch` from its
/// batcher thread while clients inspect dims from theirs.
pub trait PolicyBackend: Send + Sync {
    /// Flat f32 length of one observation.
    fn obs_dim(&self) -> usize;
    /// Length of one action.
    fn act_dim(&self) -> usize;
    /// Deterministic inference over `batch` row-major observations
    /// (`batch · obs_dim` floats in, `batch · act_dim` floats out).
    fn act_batch(&self, obs: &[f32], batch: usize) -> Result<Vec<f32>, String>;
    /// Engine name for logs/telemetry.
    fn name(&self) -> &'static str;
}

/// Native-engine backend: an immutable [`Policy`] snapshot. The batched
/// forward runs on the process-wide GEMM worker pool, so micro-batched
/// requests share both the GEMMs and the pool.
pub struct NativeBackend {
    policy: Policy,
}

impl NativeBackend {
    pub fn new(policy: Policy) -> Self {
        NativeBackend { policy }
    }
}

impl PolicyBackend for NativeBackend {
    fn obs_dim(&self) -> usize {
        self.policy.obs_len()
    }

    fn act_dim(&self) -> usize {
        self.policy.act_dim()
    }

    fn act_batch(&self, obs: &[f32], batch: usize) -> Result<Vec<f32>, String> {
        if obs.len() != batch * self.policy.obs_len() {
            // tidy-allow(alloc): error path of the serve boundary
            return Err(format!(
                "native backend: want {} floats for batch {batch}, got {}",
                batch * self.policy.obs_len(),
                obs.len()
            ));
        }
        let t = self.policy.obs_tensor(obs, batch);
        Ok(self.policy.act_batch(&t, ActMode::Deterministic).data)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT artifact backend: the `act_<variant>` artifact executed through
/// [`TrainSession`]. The artifact is compiled for a single observation,
/// so a batch is served as a loop under one session lock — the request
/// path is still the shared [`PolicyBackend`] one, and a future batched
/// artifact drops in without touching the server. Deterministic actions
/// come from ε = 0 (`tanh(μ + 0·σ) = tanh(μ)`).
pub struct PjrtBackend {
    sess: Mutex<TrainSession>,
    obs_dim: usize,
    act_dim: usize,
}

impl PjrtBackend {
    /// Open an artifact directory (errors cleanly when the artifacts or
    /// the real `xla` bindings are absent — see `runtime::xla`).
    pub fn new(artifact_dir: impl AsRef<Path>, variant: &str) -> anyhow::Result<Self> {
        let sess = TrainSession::new(artifact_dir, variant)?;
        let (obs_dim, act_dim, _) = sess.dims();
        Ok(PjrtBackend { sess: Mutex::new(sess), obs_dim, act_dim })
    }
}

impl PolicyBackend for PjrtBackend {
    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.act_dim
    }

    fn act_batch(&self, obs: &[f32], batch: usize) -> Result<Vec<f32>, String> {
        if obs.len() != batch * self.obs_dim {
            // tidy-allow(alloc): error path of the serve boundary
            return Err(format!(
                "pjrt backend: want {} floats for batch {batch}, got {}",
                batch * self.obs_dim,
                obs.len()
            ));
        }
        let mut sess = self.sess.lock().map_err(|e| e.to_string())?;
        // tidy-allow(alloc): per-request buffers at the serve/runtime boundary
        let eps = vec![0.0f32; self.act_dim];
        // tidy-allow(alloc): owned reply buffer crosses back to the server thread
        let mut out = Vec::with_capacity(batch * self.act_dim);
        for r in 0..batch {
            let a = sess
                .act(&obs[r * self.obs_dim..(r + 1) * self.obs_dim], &eps)
                .map_err(|e| e.to_string())?;
            out.extend_from_slice(&a);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::Precision;
    use crate::rngs::Pcg64;
    use crate::sac::{Methods, SacAgent, SacConfig};

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn backends_are_send_sync() {
        assert_send_sync::<NativeBackend>();
        assert_send_sync::<PjrtBackend>();
    }

    #[test]
    fn native_backend_matches_policy() {
        let agent =
            SacAgent::new(SacConfig::states(4, 2, 16), Methods::ours(), Precision::fp16(), 1);
        let policy = agent.policy();
        let backend = NativeBackend::new(policy.clone());
        assert_eq!(backend.obs_dim(), 4);
        assert_eq!(backend.act_dim(), 2);
        assert_eq!(backend.name(), "native");
        let mut rng = Pcg64::seed(2);
        let obs: Vec<f32> = (0..3 * 4).map(|_| rng.normal_f32()).collect();
        let got = backend.act_batch(&obs, 3).unwrap();
        let want = policy.act_batch(&policy.obs_tensor(&obs, 3), ActMode::Deterministic);
        assert_eq!(got, want.data);
        assert!(backend.act_batch(&obs, 2).is_err(), "length mismatch must error");
    }
}
